//! Multi-task serving correctness demo: prove that a MIXED batch through
//! the coordinator returns exactly the same logits as serving each task
//! alone — the §3.1 claim that per-task state can be stacked in a batch.
//!
//!     cargo run --release --example multitask_serving

use std::collections::BTreeMap;

use aotpt::config::Manifest;
use aotpt::coordinator::{Coordinator, CoordinatorConfig, Request, TaskRegistry};
use aotpt::runtime::{Runtime, WeightCache};
use aotpt::tensor::Tensor;
use aotpt::util::Pcg64;

fn main() -> aotpt::Result<()> {
    let manifest = Manifest::load(&aotpt::artifacts_dir())?;
    let runtime = Runtime::new()?;
    let model = manifest.model("small")?;
    let weights = WeightCache::from_ckpt(
        &runtime,
        &aotpt::artifacts_dir().join("backbone_small.aotckpt"),
    )?;
    let emb = weights.host("emb_tok")?.clone();

    let registry = TaskRegistry::new(
        model.n_layers,
        model.vocab_size,
        model.d_model,
        manifest.multitask_classes,
    );
    let mut rng = Pcg64::new(11);
    let task_names = ["alpha", "beta", "gamma"];
    for task in task_names {
        let (l, d, r) = (model.n_layers, model.d_model, 16);
        let mut tr = BTreeMap::new();
        tr.insert("t.fc.w1".into(), Tensor::from_f32(&[l, d, r], rng.normal_vec(l * d * r, 0.05)));
        tr.insert("t.fc.b1".into(), Tensor::from_f32(&[l, r], rng.normal_vec(l * r, 0.02)));
        tr.insert("t.fc.w2".into(), Tensor::from_f32(&[l, r, d], rng.normal_vec(l * r * d, 0.05)));
        tr.insert("t.fc.b2".into(), Tensor::from_f32(&[l, d], rng.normal_vec(l * d, 0.02)));
        tr.insert("t.head_w".into(), Tensor::from_f32(&[d, 3], rng.normal_vec(d * 3, 0.05)));
        tr.insert("t.head_b".into(), Tensor::from_f32(&[3], rng.normal_vec(3, 0.05)));
        registry.register_fc(task, &emb, &tr)?;
    }

    let coordinator = Coordinator::new(
        runtime,
        &manifest,
        registry,
        CoordinatorConfig {
            model: "small".into(),
            linger_ms: 5,
            signature: "aot".into(),
            ..Default::default()
        },
    )?;

    // One fixed input per task.
    let inputs: Vec<Vec<i32>> = (0..task_names.len())
        .map(|i| {
            let mut ids = vec![aotpt::tokenizer::CLS];
            let mut r = Pcg64::new(100 + i as u64);
            for _ in 0..10 {
                ids.push(r.range(5, model.vocab_size as i64) as i32);
            }
            ids
        })
        .collect();

    // Solo: one request at a time (forced batch of 1..padded bucket).
    let mut solo = Vec::new();
    for (task, ids) in task_names.iter().zip(&inputs) {
        let resp = coordinator.classify(task, ids.clone())?;
        solo.push(resp.logits);
    }

    // Mixed: all three tasks submitted together -> one shared invocation.
    let mut rxs = Vec::new();
    for (task, ids) in task_names.iter().zip(&inputs) {
        rxs.push(coordinator.submit(Request { task: task.to_string(), ids: ids.clone() })?);
    }
    let mut mixed = Vec::new();
    let mut batch_sizes = Vec::new();
    for rx in rxs {
        let resp = rx.recv().unwrap()?;
        batch_sizes.push(resp.batch_size);
        mixed.push(resp.logits);
    }

    println!("mixed batch sizes: {batch_sizes:?}");
    for ((task, s), m) in task_names.iter().zip(&solo).zip(&mixed) {
        let max_delta = s
            .iter()
            .zip(m)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0f32, f32::max);
        println!("{task}: solo {s:?} vs mixed {m:?} (max delta {max_delta:.2e})");
        assert!(max_delta < 1e-4, "multi-task batching changed the answer!");
    }
    println!("OK: mixed-task batching is exact — the paper's §3.1 claim holds end-to-end.");
    Ok(())
}
