//! End-to-end driver (DESIGN.md §7, experiment `e2e`): fine-tune a
//! backbone on real synthetic workloads with FC AoT P-Tuning for a few
//! hundred steps, log the loss curve, fuse the trained tables, then serve
//! all tasks from ONE backbone through the multi-task coordinator and
//! report latency/throughput.  Recorded in EXPERIMENTS.md.
//!
//!     cargo run --release --example e2e_train_serve [-- --model small]

use std::collections::BTreeMap;
use std::sync::Arc;
use std::time::Instant;

use aotpt::config::Manifest;
use aotpt::coordinator::{Coordinator, CoordinatorConfig, Request, TaskRegistry};
use aotpt::data::{self, Lexicon};
use aotpt::json::Json;
use aotpt::peft::fuse;
use aotpt::runtime::{Runtime, WeightCache};
use aotpt::train::{grid, TrainConfig, Trainer};

const TASKS: [&str; 3] = ["sst2", "rte", "wic"];

fn main() -> aotpt::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let model = args
        .iter()
        .position(|a| a == "--model")
        .and_then(|i| args.get(i + 1).cloned())
        .unwrap_or_else(|| "small".to_string());

    let manifest = Manifest::load(&aotpt::artifacts_dir())?;
    let runtime = Runtime::new()?;
    let info = manifest.model(&model)?;
    let weights = Arc::new(WeightCache::from_ckpt(
        &runtime,
        &aotpt::artifacts_dir().join(format!("backbone_{model}.aotckpt")),
    )?);
    let lex = Lexicon::generate(0);

    // ---- Phase 1: fine-tune each task with FC AoT P-Tuning --------------
    let registry = TaskRegistry::new(
        info.n_layers,
        info.vocab_size,
        info.d_model,
        manifest.multitask_classes,
    );
    let emb = weights.host("emb_tok")?.clone();
    let mut tasks = BTreeMap::new();
    let mut report = Json::obj();
    for task_name in TASKS {
        let task = data::make_task(&lex, task_name, 2024, 512, 256, 64)?;
        let assignments = grid::assignments_for(&manifest, &model, "aot-fc", task.classes, &[5e-3]);
        let a = assignments
            .first()
            .ok_or_else(|| anyhow::anyhow!("no aot-fc artifacts for {model}"))?;
        let trainer = Trainer::new(&runtime, &manifest, Arc::clone(&weights), &a.train_stem, &a.eval_stem)?;
        let t0 = Instant::now();
        let result = trainer.run(
            &task,
            &TrainConfig { lr: a.lr, seed: 0, max_epochs: 10, patience: 3, max_steps: 320 },
        )?;
        println!(
            "[train] {task_name}: {} steps in {:.1}s, dev {} = {:.3} (epoch {})",
            result.steps_run,
            t0.elapsed().as_secs_f64(),
            task.metric.name(),
            result.best_metric,
            result.best_epoch,
        );
        print!("        loss curve:");
        for (i, l) in result.losses.iter().enumerate() {
            if i % (result.losses.len() / 12).max(1) == 0 {
                print!(" {l:.3}");
            }
        }
        println!();
        let first = *result.losses.first().unwrap_or(&0.0);
        let last = *result.losses.last().unwrap_or(&0.0);
        anyhow::ensure!(last < first, "loss did not decrease ({first} -> {last})");

        // Fuse Equation 3 once and register for serving.
        let p = fuse::fuse_fc(&emb, &result.best_state)?;
        let head_w = result.best_state["t.head_w"].clone();
        let head_b = result.best_state["t.head_b"].clone();
        registry.register_fused(task_name, p, &head_w, &head_b)?;

        let mut jt = Json::obj();
        jt.set("dev_metric", Json::Num(result.best_metric));
        jt.set("steps", Json::Num(result.steps_run as f64));
        jt.set(
            "losses",
            Json::Arr(result.losses.iter().map(|&l| Json::Num(l as f64)).collect()),
        );
        report.set(task_name, jt);
        tasks.insert(task_name, task);
    }
    println!(
        "[fuse] {} tasks registered; fused P tables hold {:.1} MiB host RAM",
        registry.len(),
        registry.ram_bytes() as f64 / (1024.0 * 1024.0)
    );

    // ---- Phase 2: serve all tasks from one backbone ---------------------
    let coordinator = Coordinator::new(
        Arc::clone(&runtime),
        &manifest,
        registry,
        CoordinatorConfig {
            model: model.clone(),
            linger_ms: 2,
            signature: "aot".into(),
            ..Default::default()
        },
    )?;

    let t_serve = Instant::now();
    let mut correct = 0usize;
    let mut total = 0usize;
    let mut receivers = Vec::new();
    for (task_name, task) in &tasks {
        for ex in task.dev.iter().take(64) {
            let len = ex.mask.iter().filter(|&&m| m > 0.0).count();
            let rx = coordinator.submit(Request {
                task: task_name.to_string(),
                ids: ex.ids[..len].to_vec(),
            })?;
            receivers.push((rx, ex.label as i64));
        }
    }
    for (rx, gold) in receivers {
        let resp = rx.recv().unwrap()?;
        total += 1;
        if resp.argmax() == gold {
            correct += 1;
        }
    }
    let secs = t_serve.elapsed().as_secs_f64();
    let snap = coordinator.metrics().snapshot();
    println!(
        "[serve] {total} mixed-task requests in {secs:.2}s ({:.1} req/s), accuracy {:.3}",
        total as f64 / secs,
        correct as f64 / total as f64
    );
    println!("[serve] {}", snap.render());

    report.set("serve_requests", Json::Num(total as f64));
    report.set("serve_throughput_rps", Json::Num(total as f64 / secs));
    report.set("serve_accuracy", Json::Num(correct as f64 / total as f64));
    report.set("serve_p50_ms", Json::Num(snap.latency_p50_ms));
    report.set("serve_gather_fraction", Json::Num(snap.gather_fraction));
    aotpt::json::save(&aotpt::repo_root().join("results/e2e.json"), &report)?;
    println!("wrote results/e2e.json");
    Ok(())
}
