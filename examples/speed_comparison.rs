//! Quick per-method inference-speed comparison (a pocket Figure 3).
//!
//!     cargo run --release --example speed_comparison [-- --model base --seq 128]
//!
//! For the full paper grids use `aotpt exp fig3|fig8|fig9`.

use aotpt::config::Manifest;
use aotpt::experiments::speed;
use aotpt::model::predicted_overhead;
use aotpt::runtime::Runtime;

fn main() -> aotpt::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let get = |key: &str, default: &str| -> String {
        args.iter()
            .position(|a| a == key)
            .and_then(|i| args.get(i + 1).cloned())
            .unwrap_or_else(|| default.to_string())
    };
    let model = get("--model", "base");
    let seq: usize = get("--seq", "128").parse()?;
    let batch: usize = get("--batch", "16").parse()?;

    let manifest = Manifest::load(&aotpt::artifacts_dir())?;
    let runtime = Runtime::new()?;
    let cells = speed::run_grid(&runtime, &manifest, &model, &[(batch, seq)], 5.0)?;

    let info = manifest.model(&model)?;
    println!("\n{model} @ batch {batch}, seq {seq} — measured vs analytic FLOPs model:");
    for c in &cells {
        let predicted = predicted_overhead(info, &c.method, batch, seq, 16, 20);
        println!(
            "  {:<12} measured {:.3} predicted {:.3}  ({:.2} ms)",
            c.method,
            c.ratio,
            predicted,
            c.measurement.mean_secs * 1e3
        );
    }
    Ok(())
}
