//! Quickstart: load the AOT artifacts, register two tasks with fused AoT
//! P-Tuning tables, and serve a mixed batch through the coordinator.
//!
//!     cargo run --release --example quickstart
//!
//! (Run `make artifacts` first.)

use std::collections::BTreeMap;

use aotpt::config::Manifest;
use aotpt::coordinator::{Coordinator, CoordinatorConfig, Request, TaskRegistry};
use aotpt::data::Lexicon;
use aotpt::runtime::Runtime;
use aotpt::tensor::Tensor;
use aotpt::util::Pcg64;

fn main() -> aotpt::Result<()> {
    let manifest = Manifest::load(&aotpt::artifacts_dir())?;
    let runtime = Runtime::new()?;
    let model = manifest.model("small")?;

    // 1. Register tasks.  Real deployments load trained state (see the
    //    e2e_train_serve example); here we use seeded stand-in heads + FC
    //    reparametrization weights to show the fuse-at-registration flow.
    let registry = TaskRegistry::new(
        model.n_layers,
        model.vocab_size,
        model.d_model,
        manifest.multitask_classes,
    );
    let weights = aotpt::runtime::WeightCache::from_ckpt(
        &runtime,
        &aotpt::artifacts_dir().join("backbone_small.aotckpt"),
    )?;
    let emb = weights.host("emb_tok")?.clone();
    let mut rng = Pcg64::new(7);
    for (task, rank) in [("sentiment", 32), ("entailment", 64)] {
        let (l, d) = (model.n_layers, model.d_model);
        let mut trained = BTreeMap::new();
        trained.insert("t.fc.w1".into(), Tensor::from_f32(&[l, d, rank], rng.normal_vec(l * d * rank, 0.02)));
        trained.insert("t.fc.b1".into(), Tensor::from_f32(&[l, rank], vec![0.0; l * rank]));
        trained.insert("t.fc.w2".into(), Tensor::from_f32(&[l, rank, d], rng.normal_vec(l * rank * d, 0.02)));
        trained.insert("t.fc.b2".into(), Tensor::from_f32(&[l, d], vec![0.0; l * d]));
        trained.insert("t.head_w".into(), Tensor::from_f32(&[d, 2], rng.normal_vec(d * 2, 0.05)));
        trained.insert("t.head_b".into(), Tensor::from_f32(&[2], vec![0.0; 2]));
        // Fuse Equation 3 once; serving cost is now independent of rank.
        registry.register_fc(task, &emb, &trained)?;
        println!("registered {task} (rank {rank}); P store now {} MiB in host RAM",
                 registry.ram_bytes() / (1024 * 1024));
    }

    // 2. Start the coordinator and serve a mixed multi-task burst.
    let coordinator = Coordinator::new(
        runtime,
        &manifest,
        registry,
        CoordinatorConfig {
            model: "small".into(),
            linger_ms: 2,
            signature: "aot".into(),
            ..Default::default()
        },
    )?;
    let lex = Lexicon::generate(0);
    let mut receivers = Vec::new();
    for i in 0..8 {
        let task = if i % 2 == 0 { "sentiment" } else { "entailment" };
        let mut ids = vec![aotpt::tokenizer::CLS];
        for _ in 0..12 {
            ids.push(lex.any_word(&mut rng));
        }
        ids.push(aotpt::tokenizer::SEP);
        receivers.push((task, coordinator.submit(Request { task: task.into(), ids })?));
    }
    for (task, rx) in receivers {
        let resp = rx.recv().unwrap()?;
        println!(
            "{task:<11} -> class {} (logits {:?}, batched {} wide in bucket b{}n{})",
            resp.argmax(),
            resp.logits.iter().map(|x| (x * 100.0).round() / 100.0).collect::<Vec<_>>(),
            resp.batch_size,
            resp.bucket_batch,
            resp.bucket_seq,
        );
    }
    println!("metrics: {}", coordinator.metrics().snapshot().render());
    Ok(())
}
