//! Bench: paper Figure 3 — per-method inference speed on the DeBERTa-XL
//! analog (`large`) at seq 384, normalized by fine-tuning.
//!
//!     cargo bench --bench fig3_speed
//!
//! Custom harness (criterion is unavailable offline); see `aotpt exp fig3`
//! for the configurable driver.

use aotpt::config::Manifest;
use aotpt::experiments::speed;
use aotpt::runtime::Runtime;

fn main() {
    let Ok(manifest) = Manifest::load(&aotpt::artifacts_dir()) else {
        eprintln!("fig3_speed: artifacts missing (run `make artifacts`); skipping");
        return;
    };
    let runtime = Runtime::new().unwrap();
    // b=64 @ n384 on `large` needs minutes/iteration on one core — the
    // bench covers b=1 and b=16; `aotpt exp fig3 --scale full` adds b=64.
    let cells = speed::run_grid(&runtime, &manifest, "large", &[(1, 384), (16, 384)], 6.0)
        .expect("bench grid");
    println!("{}", speed::report("fig3", &cells).unwrap());
}
