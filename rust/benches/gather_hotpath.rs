//! Bench: the L3 hot path — the ahead-of-time P-row gather from host RAM.
//!
//! Part 1 compares the pre-pipeline path (fresh `[l, b, n, d]` buffer per
//! batch, serial over layers, filler rows gathered and discarded) against
//! the staged pipeline's path (arena-reused buffer, layer-parallel
//! `gather_batch`, filler rows skipped).  DESIGN.md §9 targets: effective
//! copy bandwidth in the GB/s range, **zero steady-state allocations**
//! (verified here via the arena counters), and a measurable speedup at
//! b ≥ 16.
//!
//! Part 2 prices the resident storage tiers against each other
//! (DESIGN.md §10): f32 vs f16 vs int8.  The narrower tiers pay a
//! per-element dequant on the gather to shrink resident RAM (2× for f16,
//! ~4× for int8); the table reports ns/row, bytes/row and max-abs-err per
//! tier, every tier is asserted within its dequant tolerance (1e-2 for
//! f16, 2e-2 for int8 at unit-scale rows), and all three gathers are
//! asserted zero-alloc against the shared arena.
//!
//! Part 3 prices the double-buffered serving split (DESIGN.md §11): the
//! serial `prepare` + `complete` sum against the overlapped path where a
//! dedicated thread executes batch N while the caller gathers batch N+1.
//! On a multi-core host the overlapped ns/batch must beat the serial sum
//! — that inequality is asserted here.
//!
//! Results land in `BENCH_gather.json` at the repo root (ns/batch,
//! ns/row, arena alloc counts) for CI artifact upload.
//!
//!     cargo bench --bench gather_hotpath [-- --test]
//!
//! `--test` is the CI smoke mode: tiny shapes and budgets, perf
//! assertions skipped — it only proves the bench still runs end to end.

use std::sync::mpsc::{channel, sync_channel};
use std::sync::Arc;

use aotpt::bench::{measure, render_table, BenchConfig};
use aotpt::coordinator::{
    Bucket, HostBackend, Metrics, Pipeline, Request, TaskRegistry, WorkItem,
};
use aotpt::json::Json;
use aotpt::peft::kernel;
use aotpt::peft::{AdapterConfig, AdapterDType, GatherArena, PStore, TaskP};
use aotpt::tensor::Tensor;
use aotpt::util::Pcg64;

fn main() {
    let test_mode = std::env::args().any(|a| a == "--test");
    let threads = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    println!("gather threads: {threads}{}", if test_mode { " (smoke --test mode)" } else { "" });
    let cell_cfg = if test_mode {
        BenchConfig { warmup_iters: 1, min_iters: 2, max_iters: 3, budget_secs: 0.05 }
    } else {
        BenchConfig { warmup_iters: 2, min_iters: 10, max_iters: 200, budget_secs: 2.0 }
    };
    let vocab = if test_mode { 512 } else { 8192 };
    let mut cases = Json::Arr(Vec::new());

    let mut rows = Vec::new();
    // (layers, d) per model analog, over representative bucket shapes.
    let models: &[(&str, usize, usize)] = if test_mode {
        &[("small", 4, 128)]
    } else {
        &[("small", 4, 128), ("base", 6, 256), ("large", 12, 512)]
    };
    // (bucket batch, bucket seq, live rows): live < batch exercises the
    // filler-row skip the legacy path did not have.
    let cells: &[(usize, usize, usize)] = if test_mode {
        &[(1, 16, 1), (8, 16, 8)]
    } else {
        &[(1, 64, 1), (16, 64, 16), (16, 384, 12), (64, 128, 48)]
    };
    for &(model, l, d) in models {
        let store = PStore::new(l, vocab, d);
        let mut rng = Pcg64::new(1);
        for name in ["t0", "t1", "t2", "t3"] {
            store
                .insert(name, TaskP::new(l, vocab, d, rng.normal_vec(l * vocab * d, 1.0)).unwrap())
                .unwrap();
        }
        for &(b, n, live) in cells {
            let assignments: Vec<&str> = (0..b).map(|i| ["t0", "t1", "t2", "t3"][i % 4]).collect();
            let ids: Vec<i32> = (0..b * n).map(|_| rng.range(0, vocab as i64) as i32).collect();

            // Legacy path: allocate per call, gather every bucket row.
            let legacy = measure(&format!("{model}/b{b}n{n}/legacy"), &cell_cfg, || {
                let mut out = vec![0f32; l * b * n * d];
                store.gather_into(&assignments, &ids, n, &mut out).unwrap();
                std::hint::black_box(&out);
            });

            // Pipeline path: arena checkout, parallel layers, live rows only.
            let arena = GatherArena::new();
            let live_assignments = &assignments[..live];
            let staged = measure(&format!("{model}/b{b}n{n}/arena"), &cell_cfg, || {
                let mut out = arena.take_f32(b, n, "bias", l * b * n * d);
                store
                    .gather_batch(live_assignments, &ids, n, b, threads, &mut out)
                    .unwrap();
                std::hint::black_box(&out);
                arena.put_f32(b, n, "bias", out);
            });
            // The zero-alloc invariant: only the very first checkout (in
            // warmup) allocates; every timed iteration reuses.
            assert_eq!(
                arena.allocs(),
                1,
                "steady-state gather must not allocate (got {} allocs)",
                arena.allocs()
            );

            for m in [&legacy, &staged] {
                let mut case = m.to_json();
                case.set("ns_per_batch", Json::Num(m.mean_secs * 1e9));
                case.set("ns_per_row", Json::Num(m.mean_secs * 1e9 / live as f64));
                case.set("allocs", Json::Num(arena.allocs() as f64));
                case.set("reuses", Json::Num(arena.reuses() as f64));
                cases.push(case);
            }

            let bytes = (l * live * n * d * 4) as f64;
            let gbps = bytes / staged.mean_secs / 1e9;
            rows.push(vec![
                model.to_string(),
                format!("b{b}n{n}"),
                format!("{live}"),
                format!("{:.3}", legacy.mean_secs * 1e3),
                format!("{:.3}", staged.mean_secs * 1e3),
                format!("{:.2}x", legacy.mean_secs / staged.mean_secs),
                format!("{gbps:.2}"),
                format!("{}", arena.reuses()),
            ]);
        }
    }
    println!(
        "{}",
        render_table(
            &["model", "bucket", "live", "legacy ms", "arena ms", "speedup", "GB/s", "reuses"],
            &rows,
        )
    );
    println!("(speedup column should exceed 1.00x at b>=16; allocs asserted == 1 per cell)");

    // ---- Part 2: resident tiers: f32 vs f16 vs int8 (DESIGN.md §10) -----
    let mut tier_rows = Vec::new();
    let tier_models: &[(&str, usize, usize)] =
        if test_mode { &[("small", 4, 128)] } else { &[("small", 4, 128), ("base", 6, 256)] };
    let tier_cells: &[(usize, usize)] =
        if test_mode { &[(4, 16)] } else { &[(16, 64), (64, 128)] };
    // (tier name, storage dtype, arena slot, max-abs-err bound vs the f32
    // reference at unit-scale rows).
    let tiers: &[(&str, AdapterDType, &str, f32)] = &[
        ("f32", AdapterDType::F32, "bias32", 0.0),
        ("f16", AdapterDType::F16, "bias16", 1e-2),
        ("int8", AdapterDType::I8, "bias8", 2e-2),
    ];
    for &(model, l, d) in tier_models {
        let stores: Vec<PStore> = tiers
            .iter()
            .map(|&(_, dtype, _, _)| {
                PStore::with_config(l, vocab, d, AdapterConfig { dtype, ..Default::default() })
            })
            .collect();
        let mut rng = Pcg64::new(2);
        for name in ["t0", "t1", "t2", "t3"] {
            let data = rng.normal_vec(l * vocab * d, 1.0);
            for store in &stores {
                store.insert(name, TaskP::new(l, vocab, d, data.clone()).unwrap()).unwrap();
            }
        }
        // Logical P rows resident across the 4 registered tasks.
        let logical_rows = (4 * l * vocab) as f64;
        for &(b, n) in tier_cells {
            let assignments: Vec<&str> = (0..b).map(|i| ["t0", "t1", "t2", "t3"][i % 4]).collect();
            let ids: Vec<i32> = (0..b * n).map(|_| rng.range(0, vocab as i64) as i32).collect();

            // Correctness first: every tier within its dequant tolerance
            // of the f32 reference.
            let mut reference = vec![0f32; l * b * n * d];
            stores[0].gather_batch(&assignments, &ids, n, b, threads, &mut reference).unwrap();

            let arena = GatherArena::new();
            let mut timed = Vec::new();
            for (store, &(tier, _, slot, tol)) in stores.iter().zip(tiers) {
                let mut out = vec![0f32; l * b * n * d];
                store.gather_batch(&assignments, &ids, n, b, threads, &mut out).unwrap();
                let max_err =
                    out.iter().zip(&reference).map(|(x, y)| (x - y).abs()).fold(0f32, f32::max);
                assert!(max_err <= tol, "{tier} tier diverged: max abs err {max_err} > {tol}");

                let m = measure(&format!("{model}/b{b}n{n}/{tier}"), &cell_cfg, || {
                    let mut out = arena.take_f32(b, n, slot, l * b * n * d);
                    store.gather_batch(&assignments, &ids, n, b, threads, &mut out).unwrap();
                    std::hint::black_box(&out);
                    arena.put_f32(b, n, slot, out);
                });
                timed.push((tier, m, max_err, store.bytes()));
            }
            // All three tiers stay zero-alloc in steady state: one
            // checkout per slot key, ever — f16 and int8 dequant straight
            // into the arena buffer, never through a scratch Vec.
            assert_eq!(arena.allocs(), 3, "resident tiers must not allocate per batch");

            for (tier, m, max_err, bytes) in &timed {
                let mut case = m.to_json();
                case.set("tier", Json::Str(tier.to_string()));
                case.set("ns_per_batch", Json::Num(m.mean_secs * 1e9));
                case.set("ns_per_row", Json::Num(m.mean_secs * 1e9 / (l * b * n) as f64));
                case.set("bytes_per_row", Json::Num(*bytes as f64 / logical_rows));
                case.set("max_abs_err", Json::Num(*max_err as f64));
                case.set("allocs", Json::Num(arena.allocs() as f64));
                cases.push(case);
            }

            tier_rows.push(vec![
                model.to_string(),
                format!("b{b}n{n}"),
                format!("{:.3}", timed[0].1.mean_secs * 1e3),
                format!("{:.3}", timed[1].1.mean_secs * 1e3),
                format!("{:.3}", timed[2].1.mean_secs * 1e3),
                format!(
                    "{:.0}/{:.0}/{:.0}",
                    timed[0].3 as f64 / logical_rows,
                    timed[1].3 as f64 / logical_rows,
                    timed[2].3 as f64 / logical_rows
                ),
                format!("{:.1e}/{:.1e}", timed[1].2, timed[2].2),
            ]);
        }
    }
    println!(
        "{}",
        render_table(
            &[
                "model",
                "bucket",
                "f32 ms",
                "f16 ms",
                "int8 ms",
                "B/row f32/f16/int8",
                "err f16/int8",
            ],
            &tier_rows,
        )
    );
    println!(
        "(f16 halves and int8 quarters resident bytes/row; dequant cost shows in \
         the tier ms columns; int8 max-abs-err asserted < 2e-2)"
    );

    // ---- Part 2b: cold tier — mmap vs positioned reads (DESIGN.md §13) --
    // One task spilled under a half-table budget, so every gather serves
    // cold; the mapped and positioned legs run the identical workload and
    // their outputs are asserted bit-identical.  No speed assertion: the
    // page cache makes both legs fast and noisy on CI — the JSON rows are
    // the deliverable.
    {
        let (l, d) = if test_mode { (2, 64) } else { (4, 128) };
        let cold_vocab = if test_mode { 128 } else { 2048 };
        let (b, n) = if test_mode { (2usize, 8usize) } else { (8, 64) };
        let table_bytes = l * cold_vocab * d * 4;
        let mut rng = Pcg64::new(3);
        let data = rng.normal_vec(l * cold_vocab * d, 1.0);
        let assignments: Vec<&str> = (0..b).map(|_| "t").collect();
        let ids: Vec<i32> = (0..b * n).map(|_| rng.range(0, cold_vocab as i64) as i32).collect();
        let modes: &[(&str, bool)] = &[("cold-mmap", true), ("cold-pread", false)];
        let mut outs = Vec::new();
        let mut timed = Vec::new();
        for &(label, use_mmap) in modes {
            let store = PStore::with_config(
                l,
                cold_vocab,
                d,
                AdapterConfig {
                    ram_budget_bytes: table_bytes / 2,
                    mmap: use_mmap,
                    ..Default::default()
                },
            );
            store.insert("t", TaskP::new(l, cold_vocab, d, data.clone()).unwrap()).unwrap();
            let mut out = vec![0f32; l * b * n * d];
            store.gather_batch(&assignments, &ids, n, b, threads, &mut out).unwrap();
            outs.push(out);
            let m = measure(&format!("cold/b{b}n{n}/{label}"), &cell_cfg, || {
                let mut out = vec![0f32; l * b * n * d];
                store.gather_batch(&assignments, &ids, n, b, threads, &mut out).unwrap();
                std::hint::black_box(&out);
            });
            let stats = store.stats();
            if use_mmap {
                if stats.mmap_opens > 0 {
                    assert!(stats.cold_rows_mapped > 0, "mapped leg never used the mapping");
                    assert_eq!(stats.cold_rows_positioned, 0, "mapped leg fell back: {stats:?}");
                } else {
                    assert!(stats.mmap_fallbacks > 0, "mapping neither opened nor fell back");
                }
            } else {
                assert_eq!(stats.mmap_opens, 0, "pread leg must not map: {stats:?}");
                assert_eq!(stats.mmap_fallbacks, 0, "mmap off is not a fallback: {stats:?}");
                assert!(stats.cold_rows_positioned > 0, "pread leg never read: {stats:?}");
            }
            timed.push((label, m, stats));
        }
        assert_eq!(
            outs[0], outs[1],
            "mapped and positioned cold gathers must be bit-identical"
        );
        let mut cold_rows = Vec::new();
        for (label, m, stats) in &timed {
            let mut case = m.to_json();
            case.set("tier", Json::Str(label.to_string()));
            case.set("ns_per_batch", Json::Num(m.mean_secs * 1e9));
            case.set("ns_per_row", Json::Num(m.mean_secs * 1e9 / (l * b * n) as f64));
            case.set("mmap_opens", Json::Num(stats.mmap_opens as f64));
            case.set("mmap_fallbacks", Json::Num(stats.mmap_fallbacks as f64));
            case.set("rows_mapped", Json::Num(stats.cold_rows_mapped as f64));
            case.set("rows_positioned", Json::Num(stats.cold_rows_positioned as f64));
            cases.push(case);
            cold_rows.push(vec![
                label.to_string(),
                format!("{:.3}", m.mean_secs * 1e3),
                format!("{:.0}", m.mean_secs * 1e9 / (l * b * n) as f64),
                format!("{}", stats.cold_rows_mapped),
                format!("{}", stats.cold_rows_positioned),
            ]);
        }
        println!(
            "{}",
            render_table(
                &["cold tier", "ms/batch", "ns/row", "rows mapped", "rows positioned"],
                &cold_rows,
            )
        );
        println!("(cold outputs asserted bit-identical between the mmap and pread legs)");
    }

    // ---- Part 3: serial vs overlapped gather/execute (DESIGN.md §11) ----
    // A full Pipeline over the HostBackend: the serial path chains
    // `prepare` + `complete` on one thread (the gather+execute sum); the
    // overlapped path hands each PreparedBatch to a dedicated execute
    // thread through the same two-slot queue the coordinator uses, so the
    // gather for batch N+1 runs while batch N executes.
    let (l, ov_vocab, d, classes) = if test_mode { (2, 256, 16, 4) } else { (6, 4096, 256, 4) };
    let (b, n) = if test_mode { (4usize, 16usize) } else { (16, 128) };
    let task_names = ["t0", "t1", "t2", "t3"];
    let registry = TaskRegistry::new(l, ov_vocab, d, classes);
    let mut rng = Pcg64::new(7);
    for name in task_names {
        let table = TaskP::new(l, ov_vocab, d, rng.normal_vec(l * ov_vocab * d, 0.5)).unwrap();
        let head_w = Tensor::from_f32(&[d, 2], rng.normal_vec(d * 2, 0.2));
        let head_b = Tensor::from_f32(&[2], vec![0.0; 2]);
        registry.register_fused(name, table, &head_w, &head_b).unwrap();
    }
    let pipeline = Arc::new(Pipeline::new(
        Arc::new(registry),
        vec![Bucket { batch: b, seq: n }],
        classes,
        Arc::new(HostBackend),
        Arc::new(Metrics::new()),
        threads,
        false,
    ));
    // One flushed batch: b live rows over the 4 tasks.  Only the last
    // row's receiver is kept — recv on it means the whole batch fanned
    // out (responses are delivered in row order).
    let batch = |rng: &mut Pcg64| {
        let mut items = Vec::with_capacity(b);
        let mut last_rx = None;
        for j in 0..b {
            let (tx, rx) = channel();
            let ids: Vec<i32> =
                (0..n).map(|_| rng.range(0, ov_vocab as i64) as i32).collect();
            items.push(WorkItem::new(
                Request { task: task_names[j % 4].into(), ids },
                tx,
            ));
            last_rx = Some(rx);
        }
        (items, last_rx.unwrap())
    };
    const BATCHES_PER_ITER: usize = 4;
    let overlap_cfg = if test_mode {
        cell_cfg
    } else {
        BenchConfig { warmup_iters: 2, min_iters: 10, max_iters: 100, budget_secs: 4.0 }
    };

    let serial = measure("overlap/serial", &overlap_cfg, || {
        for _ in 0..BATCHES_PER_ITER {
            let (items, rx) = batch(&mut rng);
            if let Some(prepared) = pipeline.prepare(items) {
                pipeline.complete(prepared);
            }
            rx.recv().unwrap().unwrap();
        }
    });

    let (ptx, prx) = sync_channel(1);
    let exec_pipeline = Arc::clone(&pipeline);
    let executor = std::thread::Builder::new()
        .name("bench-execute".into())
        .spawn(move || {
            while let Ok(prepared) = prx.recv() {
                exec_pipeline.complete(prepared);
            }
        })
        .unwrap();
    // Reach the double-buffered steady state (two checkouts in flight)
    // before recording the alloc baseline.
    {
        let mut rxs = Vec::new();
        for _ in 0..4 {
            let (items, rx) = batch(&mut rng);
            if let Some(prepared) = pipeline.prepare(items) {
                ptx.send(prepared).unwrap();
            }
            rxs.push(rx);
        }
        for rx in rxs {
            rx.recv().unwrap().unwrap();
        }
    }
    let allocs_baseline = pipeline.arena().allocs();
    let overlapped = measure("overlap/double-buffered", &overlap_cfg, || {
        let mut rxs = Vec::with_capacity(BATCHES_PER_ITER);
        for _ in 0..BATCHES_PER_ITER {
            let (items, rx) = batch(&mut rng);
            if let Some(prepared) = pipeline.prepare(items) {
                ptx.send(prepared).unwrap();
            }
            rxs.push(rx);
        }
        for rx in rxs {
            rx.recv().unwrap().unwrap();
        }
    });
    assert_eq!(
        pipeline.arena().allocs(),
        allocs_baseline,
        "the overlapped steady state must not allocate (double buffering is bounded)"
    );
    drop(ptx);
    executor.join().unwrap();

    let serial_ns = serial.mean_secs / BATCHES_PER_ITER as f64 * 1e9;
    let overlapped_ns = overlapped.mean_secs / BATCHES_PER_ITER as f64 * 1e9;
    let overlap_rows = vec![
        vec!["serial prepare+complete".into(), format!("{:.0}", serial_ns / 1e3), String::new()],
        vec![
            "overlapped (2-slot queue)".into(),
            format!("{:.0}", overlapped_ns / 1e3),
            format!("{:.2}x", serial_ns / overlapped_ns),
        ],
    ];
    println!("{}", render_table(&["path", "us/batch", "speedup"], &overlap_rows));
    for (m, ns) in [(&serial, serial_ns), (&overlapped, overlapped_ns)] {
        let mut case = m.to_json();
        case.set("ns_per_batch", Json::Num(ns));
        case.set("ns_per_row", Json::Num(ns / b as f64));
        case.set("allocs", Json::Num(pipeline.arena().allocs() as f64));
        cases.push(case);
    }
    // The overlap win is only physical with spare cores; the smoke mode
    // and small hosts just report the numbers.
    if !test_mode && threads >= 4 {
        assert!(
            overlapped_ns < serial_ns,
            "overlapped ns/batch ({overlapped_ns:.0}) must beat the serial \
             gather+execute sum ({serial_ns:.0})"
        );
        println!("(asserted: overlapped ns/batch < serial gather+execute sum)");
    }

    // ---- Part 4: row kernels — scalar vs SIMD per dtype (DESIGN.md §14) --
    // Each available kernel is forced in turn and the full gather re-run
    // over resident, cold-mmap and cold-pread stores of every dtype; all
    // legs are asserted bit-identical to the scalar resident reference,
    // the resident leg is timed per kernel (ns/row into the JSON), and on
    // AVX2 hosts the SIMD f16/int8 dequant must be >= 2x the scalar leg.
    {
        let (kl, kd) = if test_mode { (2usize, 64usize) } else { (4, 256) };
        let k_vocab = if test_mode { 128 } else { 4096 };
        let (kb, kn) = if test_mode { (2usize, 8usize) } else { (8, 64) };
        let kernels = kernel::available();
        #[cfg(target_arch = "x86_64")]
        let has_avx2 = std::is_x86_feature_detected!("avx2");
        #[cfg(not(target_arch = "x86_64"))]
        let has_avx2 = false;
        let dtypes: &[(&str, AdapterDType)] =
            &[("f32", AdapterDType::F32), ("f16", AdapterDType::F16), ("int8", AdapterDType::I8)];
        let mut rng = Pcg64::new(5);
        let data = rng.normal_vec(kl * k_vocab * kd, 1.0);
        let assignments: Vec<&str> = (0..kb).map(|_| "t").collect();
        let ids: Vec<i32> = (0..kb * kn).map(|_| rng.range(0, k_vocab as i64) as i32).collect();
        let mut kernel_rows = Vec::new();
        for &(dname, dtype) in dtypes {
            let table_bytes = kl * k_vocab * kd * dtype.size();
            let mk_store = |budget: usize, mmap: bool| {
                let s = PStore::with_config(
                    kl,
                    k_vocab,
                    kd,
                    AdapterConfig { dtype, ram_budget_bytes: budget, mmap, ..Default::default() },
                );
                s.insert("t", TaskP::new(kl, k_vocab, kd, data.clone()).unwrap()).unwrap();
                s
            };
            let resident = mk_store(0, true);
            // Half-table budgets force the disk tier, so the cold legs
            // also exercise the sorted gather plan under every kernel.
            let cold_map = mk_store(table_bytes / 2, true);
            let cold_pread = mk_store(table_bytes / 2, false);

            kernel::force(kernel::scalar());
            let mut reference = vec![0f32; kl * kb * kn * kd];
            resident.gather_batch(&assignments, &ids, kn, kb, threads, &mut reference).unwrap();

            let arena = GatherArena::new();
            let mut ns_per_kernel: Vec<(&str, f64)> = Vec::new();
            for &k in &kernels {
                kernel::force(k);
                let legs: [(&str, &PStore); 3] = [
                    ("resident", &resident),
                    ("cold-mmap", &cold_map),
                    ("cold-pread", &cold_pread),
                ];
                for (leg, store) in legs {
                    let mut out = vec![0f32; kl * kb * kn * kd];
                    store.gather_batch(&assignments, &ids, kn, kb, threads, &mut out).unwrap();
                    let same = out.iter().zip(&reference).all(|(x, y)| x.to_bits() == y.to_bits());
                    assert!(
                        same,
                        "{dname}/{leg} under kernel {} diverges from the scalar reference",
                        k.name
                    );
                }
                let m = measure(&format!("kernel/{dname}/{}", k.name), &cell_cfg, || {
                    let mut out = arena.take_f32(kb, kn, "kbias", kl * kb * kn * kd);
                    resident.gather_batch(&assignments, &ids, kn, kb, threads, &mut out).unwrap();
                    std::hint::black_box(&out);
                    arena.put_f32(kb, kn, "kbias", out);
                });
                let ns_row = m.mean_secs * 1e9 / (kl * kb * kn) as f64;
                let mut case = m.to_json();
                case.set("kernel", Json::Str(k.name.to_string()));
                case.set("tier", Json::Str(dname.to_string()));
                case.set("ns_per_batch", Json::Num(m.mean_secs * 1e9));
                case.set("ns_per_row", Json::Num(ns_row));
                case.set("allocs", Json::Num(arena.allocs() as f64));
                cases.push(case);
                ns_per_kernel.push((k.name, ns_row));
            }
            // Zero-alloc invariant holds under every kernel: one arena
            // checkout per dtype, reused across all kernel legs.
            assert_eq!(arena.allocs(), 1, "{dname}: kernel legs must reuse one arena buffer");
            // Plan-sort counters: cold batches walk sorted plans, the
            // resident-only batches never build one.
            assert!(
                cold_map.stats().gather_rows_sorted > 0,
                "{dname}: cold gathers must count sorted rows"
            );
            let rstats = resident.stats();
            assert_eq!(rstats.gather_rows_sorted, 0, "{dname}: resident gathers built a plan");
            assert!(rstats.gather_rows_unsorted > 0, "{dname}: unsorted rows uncounted");

            let scalar_ns = ns_per_kernel[0].1;
            for &(kname, ns_row) in &ns_per_kernel {
                kernel_rows.push(vec![
                    dname.to_string(),
                    kname.to_string(),
                    format!("{ns_row:.1}"),
                    format!("{:.2}x", scalar_ns / ns_row),
                ]);
            }
            let &(best_name, best_ns) = ns_per_kernel.last().unwrap();
            if !test_mode && has_avx2 && (dname == "f16" || dname == "int8") {
                assert!(
                    best_ns * 2.0 <= scalar_ns,
                    "{dname}: SIMD {best_name} ({best_ns:.1} ns/row) must be >= 2x faster \
                     than scalar ({scalar_ns:.1} ns/row)"
                );
            }
        }
        let auto = kernel::set_active(kernel::KernelMode::Auto);
        println!("{}", render_table(&["dtype", "kernel", "ns/row", "vs scalar"], &kernel_rows));
        println!(
            "(auto-dispatch selects {}; resident/cold-mmap/cold-pread legs asserted \
             bit-identical to scalar for every kernel)",
            auto.name
        );
    }

    let doc = Json::from_pairs(vec![
        ("bench", Json::Str("gather_hotpath".into())),
        ("threads", Json::Num(threads as f64)),
        ("test_mode", Json::Bool(test_mode)),
        ("kernel", Json::Str(kernel::active().name.to_string())),
        ("cases", cases),
    ]);
    let path = aotpt::repo_root().join("BENCH_gather.json");
    aotpt::json::save(&path, &doc).unwrap();
    println!("wrote {}", path.display());
}
