//! Bench: the L3 hot path — the ahead-of-time P-row gather from host RAM.
//!
//! Compares the pre-pipeline path (fresh `[l, b, n, d]` buffer per batch,
//! serial over layers, filler rows gathered and discarded) against the
//! staged pipeline's path (arena-reused buffer, layer-parallel
//! `gather_batch`, filler rows skipped).  DESIGN.md §9 targets: effective
//! copy bandwidth in the GB/s range, **zero steady-state allocations**
//! (verified here via the arena counters), and a measurable speedup at
//! b ≥ 16.
//!
//!     cargo bench --bench gather_hotpath

use aotpt::bench::{measure, render_table, BenchConfig};
use aotpt::peft::{GatherArena, PStore, TaskP};
use aotpt::util::Pcg64;

fn main() {
    let threads = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    println!("gather threads: {threads}");
    let mut rows = Vec::new();
    // (layers, d) per model analog, over representative bucket shapes.
    for (model, l, d) in [("small", 4usize, 128usize), ("base", 6, 256), ("large", 12, 512)] {
        let vocab = 8192;
        let mut store = PStore::new(l, vocab, d);
        let mut rng = Pcg64::new(1);
        for name in ["t0", "t1", "t2", "t3"] {
            store
                .insert(name, TaskP::new(l, vocab, d, rng.normal_vec(l * vocab * d, 1.0)).unwrap())
                .unwrap();
        }
        // (bucket batch, bucket seq, live rows): live < batch exercises the
        // filler-row skip the legacy path did not have.
        for (b, n, live) in [(1usize, 64usize, 1usize), (16, 64, 16), (16, 384, 12), (64, 128, 48)]
        {
            let assignments: Vec<&str> = (0..b).map(|i| ["t0", "t1", "t2", "t3"][i % 4]).collect();
            let ids: Vec<i32> = (0..b * n).map(|_| rng.range(0, vocab as i64) as i32).collect();
            let cfg =
                BenchConfig { warmup_iters: 2, min_iters: 10, max_iters: 200, budget_secs: 2.0 };

            // Legacy path: allocate per call, gather every bucket row.
            let legacy = measure(&format!("{model}/b{b}n{n}/legacy"), &cfg, || {
                let mut out = vec![0f32; l * b * n * d];
                store.gather_into(&assignments, &ids, n, &mut out).unwrap();
                std::hint::black_box(&out);
            });

            // Pipeline path: arena checkout, parallel layers, live rows only.
            let arena = GatherArena::new();
            let live_assignments = &assignments[..live];
            let staged = measure(&format!("{model}/b{b}n{n}/arena"), &cfg, || {
                let mut out = arena.take_f32(b, n, "bias", l * b * n * d);
                store
                    .gather_batch(live_assignments, &ids, n, b, threads, &mut out)
                    .unwrap();
                std::hint::black_box(&out);
                arena.put_f32(b, n, "bias", out);
            });
            // The zero-alloc invariant: only the very first checkout (in
            // warmup) allocates; every timed iteration reuses.
            assert_eq!(
                arena.allocs(),
                1,
                "steady-state gather must not allocate (got {} allocs)",
                arena.allocs()
            );

            let bytes = (l * live * n * d * 4) as f64;
            let gbps = bytes / staged.mean_secs / 1e9;
            rows.push(vec![
                model.to_string(),
                format!("b{b}n{n}"),
                format!("{live}"),
                format!("{:.3}", legacy.mean_secs * 1e3),
                format!("{:.3}", staged.mean_secs * 1e3),
                format!("{:.2}x", legacy.mean_secs / staged.mean_secs),
                format!("{gbps:.2}"),
                format!("{}", arena.reuses()),
            ]);
        }
    }
    println!(
        "{}",
        render_table(
            &["model", "bucket", "live", "legacy ms", "arena ms", "speedup", "GB/s", "reuses"],
            &rows,
        )
    );
    println!("(speedup column should exceed 1.00x at b>=16; allocs asserted == 1 per cell)");
}
