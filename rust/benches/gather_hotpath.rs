//! Bench: the L3 hot path — the ahead-of-time P-row gather from host RAM
//! (`PStore::gather_into`).  DESIGN.md §9 target: effective copy
//! bandwidth in the GB/s range so the gather never rivals the backbone
//! execute.
//!
//!     cargo bench --bench gather_hotpath

use aotpt::bench::{measure, render_table, BenchConfig};
use aotpt::peft::{PStore, TaskP};
use aotpt::util::Pcg64;

fn main() {
    let mut rows = Vec::new();
    // (layers, d) per model analog, over representative bucket shapes.
    for (model, l, d) in [("small", 4usize, 128usize), ("base", 6, 256), ("large", 12, 512)] {
        let vocab = 8192;
        let mut store = PStore::new(l, vocab, d);
        let mut rng = Pcg64::new(1);
        for name in ["t0", "t1", "t2", "t3"] {
            store
                .insert(name, TaskP::new(l, vocab, d, rng.normal_vec(l * vocab * d, 1.0)).unwrap())
                .unwrap();
        }
        for (b, n) in [(1usize, 64usize), (16, 64), (16, 384), (64, 128)] {
            let assignments: Vec<&str> = (0..b).map(|i| ["t0", "t1", "t2", "t3"][i % 4]).collect();
            let ids: Vec<i32> = (0..b * n).map(|_| rng.range(0, vocab as i64) as i32).collect();
            let mut out = vec![0f32; l * b * n * d];
            let cfg =
                BenchConfig { warmup_iters: 2, min_iters: 10, max_iters: 200, budget_secs: 2.0 };
            let m = measure(&format!("{model}/b{b}n{n}"), &cfg, || {
                store.gather_into(&assignments, &ids, n, &mut out).unwrap();
            });
            let bytes = (l * b * n * d * 4) as f64;
            let gbps = bytes / m.mean_secs / 1e9;
            rows.push(vec![
                model.to_string(),
                format!("b{b}n{n}"),
                format!("{:.3}", m.mean_secs * 1e3),
                format!("{gbps:.2}"),
                format!("{}", m.iters),
            ]);
        }
    }
    println!(
        "{}",
        render_table(&["model", "bucket", "mean ms", "GB/s", "iters"], &rows)
    );
}
