//! Bench: the L3 hot path — the ahead-of-time P-row gather from host RAM.
//!
//! Part 1 compares the pre-pipeline path (fresh `[l, b, n, d]` buffer per
//! batch, serial over layers, filler rows gathered and discarded) against
//! the staged pipeline's path (arena-reused buffer, layer-parallel
//! `gather_batch`, filler rows skipped).  DESIGN.md §9 targets: effective
//! copy bandwidth in the GB/s range, **zero steady-state allocations**
//! (verified here via the arena counters), and a measurable speedup at
//! b ≥ 16.
//!
//! Part 2 compares the f32 resident tier against the f16 tier (DESIGN.md
//! §10): the f16 gather pays a per-element dequant to halve resident RAM;
//! this table prices that trade, and the outputs are asserted within the
//! 1e-2 tier tolerance.
//!
//!     cargo bench --bench gather_hotpath

use aotpt::bench::{measure, render_table, BenchConfig};
use aotpt::peft::{AdapterConfig, AdapterDType, GatherArena, PStore, TaskP};
use aotpt::util::Pcg64;

fn main() {
    let threads = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    println!("gather threads: {threads}");
    let mut rows = Vec::new();
    // (layers, d) per model analog, over representative bucket shapes.
    for (model, l, d) in [("small", 4usize, 128usize), ("base", 6, 256), ("large", 12, 512)] {
        let vocab = 8192;
        let store = PStore::new(l, vocab, d);
        let mut rng = Pcg64::new(1);
        for name in ["t0", "t1", "t2", "t3"] {
            store
                .insert(name, TaskP::new(l, vocab, d, rng.normal_vec(l * vocab * d, 1.0)).unwrap())
                .unwrap();
        }
        // (bucket batch, bucket seq, live rows): live < batch exercises the
        // filler-row skip the legacy path did not have.
        for (b, n, live) in [(1usize, 64usize, 1usize), (16, 64, 16), (16, 384, 12), (64, 128, 48)]
        {
            let assignments: Vec<&str> = (0..b).map(|i| ["t0", "t1", "t2", "t3"][i % 4]).collect();
            let ids: Vec<i32> = (0..b * n).map(|_| rng.range(0, vocab as i64) as i32).collect();
            let cfg =
                BenchConfig { warmup_iters: 2, min_iters: 10, max_iters: 200, budget_secs: 2.0 };

            // Legacy path: allocate per call, gather every bucket row.
            let legacy = measure(&format!("{model}/b{b}n{n}/legacy"), &cfg, || {
                let mut out = vec![0f32; l * b * n * d];
                store.gather_into(&assignments, &ids, n, &mut out).unwrap();
                std::hint::black_box(&out);
            });

            // Pipeline path: arena checkout, parallel layers, live rows only.
            let arena = GatherArena::new();
            let live_assignments = &assignments[..live];
            let staged = measure(&format!("{model}/b{b}n{n}/arena"), &cfg, || {
                let mut out = arena.take_f32(b, n, "bias", l * b * n * d);
                store
                    .gather_batch(live_assignments, &ids, n, b, threads, &mut out)
                    .unwrap();
                std::hint::black_box(&out);
                arena.put_f32(b, n, "bias", out);
            });
            // The zero-alloc invariant: only the very first checkout (in
            // warmup) allocates; every timed iteration reuses.
            assert_eq!(
                arena.allocs(),
                1,
                "steady-state gather must not allocate (got {} allocs)",
                arena.allocs()
            );

            let bytes = (l * live * n * d * 4) as f64;
            let gbps = bytes / staged.mean_secs / 1e9;
            rows.push(vec![
                model.to_string(),
                format!("b{b}n{n}"),
                format!("{live}"),
                format!("{:.3}", legacy.mean_secs * 1e3),
                format!("{:.3}", staged.mean_secs * 1e3),
                format!("{:.2}x", legacy.mean_secs / staged.mean_secs),
                format!("{gbps:.2}"),
                format!("{}", arena.reuses()),
            ]);
        }
    }
    println!(
        "{}",
        render_table(
            &["model", "bucket", "live", "legacy ms", "arena ms", "speedup", "GB/s", "reuses"],
            &rows,
        )
    );
    println!("(speedup column should exceed 1.00x at b>=16; allocs asserted == 1 per cell)");

    // ---- Part 2: f32 resident tier vs f16 tier (DESIGN.md §10) ----------
    let mut tier_rows = Vec::new();
    for (model, l, d) in [("small", 4usize, 128usize), ("base", 6, 256)] {
        let vocab = 8192;
        let f32_store = PStore::new(l, vocab, d);
        let f16_store = PStore::with_config(
            l,
            vocab,
            d,
            AdapterConfig { dtype: AdapterDType::F16, ..Default::default() },
        );
        let mut rng = Pcg64::new(2);
        for name in ["t0", "t1", "t2", "t3"] {
            let data = rng.normal_vec(l * vocab * d, 1.0);
            f32_store
                .insert(name, TaskP::new(l, vocab, d, data.clone()).unwrap())
                .unwrap();
            f16_store.insert(name, TaskP::new(l, vocab, d, data).unwrap()).unwrap();
        }
        for (b, n) in [(16usize, 64usize), (64, 128)] {
            let assignments: Vec<&str> = (0..b).map(|i| ["t0", "t1", "t2", "t3"][i % 4]).collect();
            let ids: Vec<i32> = (0..b * n).map(|_| rng.range(0, vocab as i64) as i32).collect();
            let cfg =
                BenchConfig { warmup_iters: 2, min_iters: 10, max_iters: 200, budget_secs: 2.0 };

            // Correctness first: the tiers agree within tolerance.
            let mut f32_out = vec![0f32; l * b * n * d];
            let mut f16_out = vec![0f32; l * b * n * d];
            f32_store.gather_batch(&assignments, &ids, n, b, threads, &mut f32_out).unwrap();
            f16_store.gather_batch(&assignments, &ids, n, b, threads, &mut f16_out).unwrap();
            for (x, y) in f16_out.iter().zip(&f32_out) {
                assert!((x - y).abs() < 1e-2, "f16 tier diverged: {x} vs {y}");
            }

            let arena = GatherArena::new();
            let t32 = measure(&format!("{model}/b{b}n{n}/f32"), &cfg, || {
                let mut out = arena.take_f32(b, n, "bias32", l * b * n * d);
                f32_store.gather_batch(&assignments, &ids, n, b, threads, &mut out).unwrap();
                std::hint::black_box(&out);
                arena.put_f32(b, n, "bias32", out);
            });
            let t16 = measure(&format!("{model}/b{b}n{n}/f16"), &cfg, || {
                let mut out = arena.take_f32(b, n, "bias16", l * b * n * d);
                f16_store.gather_batch(&assignments, &ids, n, b, threads, &mut out).unwrap();
                std::hint::black_box(&out);
                arena.put_f32(b, n, "bias16", out);
            });
            // Both tiers stay zero-alloc in steady state (one checkout
            // per slot key, ever).
            assert_eq!(arena.allocs(), 2, "resident tiers must not allocate per batch");

            tier_rows.push(vec![
                model.to_string(),
                format!("b{b}n{n}"),
                format!("{:.3}", t32.mean_secs * 1e3),
                format!("{:.3}", t16.mean_secs * 1e3),
                format!("{:.2}x", t32.mean_secs / t16.mean_secs),
                format!(
                    "{:.0}/{:.0}",
                    f32_store.bytes() as f64 / (1 << 20) as f64,
                    f16_store.bytes() as f64 / (1 << 20) as f64
                ),
            ]);
        }
    }
    println!(
        "{}",
        render_table(
            &["model", "bucket", "f32 ms", "f16 ms", "f16 speed", "MiB f32/f16"],
            &tier_rows,
        )
    );
    println!("(f16 halves resident MiB; dequant cost shows in the f16 ms column)");
}
