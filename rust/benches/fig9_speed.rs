//! Bench: paper Appendix Figure 9 — per-method speed at short sequences
//! (16, 64), where the paper reports AoT's only visible overhead (small
//! model, small batch, short sequence).
//!
//!     cargo bench --bench fig9_speed

use aotpt::config::Manifest;
use aotpt::experiments::speed;
use aotpt::runtime::Runtime;

fn main() {
    let Ok(manifest) = Manifest::load(&aotpt::artifacts_dir()) else {
        eprintln!("fig9_speed: artifacts missing (run `make artifacts`); skipping");
        return;
    };
    let runtime = Runtime::new().unwrap();
    let mut all = Vec::new();
    for model in ["small", "base", "large"] {
        all.extend(
            speed::run_grid(&runtime, &manifest, model, &[(1, 16), (1, 64), (16, 64)], 4.0)
                .unwrap(),
        );
    }
    println!("{}", speed::report("fig9", &all).unwrap());
}
