//! Bench: coordinator overhead — request latency through the full
//! router/batcher/gather/execute pipeline vs the raw backbone execute.
//! DESIGN.md §9 L3 target: the coordinator's own work must stay a small
//! fraction of the backbone execute.
//!
//!     cargo bench --bench coordinator_overhead

use std::collections::BTreeMap;
use std::sync::Arc;

use aotpt::bench::{measure, render_table, BenchConfig};
use aotpt::config::Manifest;
use aotpt::coordinator::{Coordinator, CoordinatorConfig, Request, TaskRegistry};
use aotpt::runtime::{Runtime, WeightCache};
use aotpt::tensor::Tensor;
use aotpt::util::Pcg64;

fn main() {
    let Ok(manifest) = Manifest::load(&aotpt::artifacts_dir()) else {
        eprintln!("coordinator_overhead: artifacts missing (run `make artifacts`); skipping");
        return;
    };
    let runtime = Runtime::new().unwrap();
    let model = manifest.model("small").unwrap().clone();
    let weights = WeightCache::from_ckpt(
        &runtime,
        &aotpt::artifacts_dir().join("backbone_small.aotckpt"),
    )
    .unwrap();
    let emb = weights.host("emb_tok").unwrap().clone();

    let registry = TaskRegistry::new(
        model.n_layers,
        model.vocab_size,
        model.d_model,
        manifest.multitask_classes,
    );
    let mut rng = Pcg64::new(3);
    for name in ["a", "b"] {
        let (l, d, r) = (model.n_layers, model.d_model, 8);
        let mut tr = BTreeMap::new();
        tr.insert("t.fc.w1".into(), Tensor::from_f32(&[l, d, r], rng.normal_vec(l * d * r, 0.05)));
        tr.insert("t.fc.b1".into(), Tensor::from_f32(&[l, r], vec![0.0; l * r]));
        tr.insert("t.fc.w2".into(), Tensor::from_f32(&[l, r, d], rng.normal_vec(l * r * d, 0.05)));
        tr.insert("t.fc.b2".into(), Tensor::from_f32(&[l, d], vec![0.0; l * d]));
        tr.insert("t.head_w".into(), Tensor::from_f32(&[d, 2], rng.normal_vec(d * 2, 0.05)));
        tr.insert("t.head_b".into(), Tensor::from_f32(&[2], vec![0.0; 2]));
        registry.register_fc(name, &emb, &tr).unwrap();
    }
    let coordinator = match Coordinator::new(
        Arc::clone(&runtime),
        &manifest,
        registry,
        CoordinatorConfig { model: "small".into(), linger_ms: 1, signature: "aot".into() },
    ) {
        Ok(c) => c,
        Err(e) => {
            eprintln!("coordinator_overhead: cannot build PJRT coordinator ({e:#}); skipping");
            return;
        }
    };

    let make_ids = |seed: u64| {
        let mut r = Pcg64::new(seed);
        let mut v = vec![aotpt::tokenizer::CLS];
        for _ in 0..50 {
            v.push(r.range(5, model.vocab_size as i64) as i32);
        }
        v
    };
    // Warm the bucket executables.
    let _ = coordinator.classify("a", make_ids(0)).unwrap();

    let cfg = BenchConfig { warmup_iters: 3, min_iters: 10, max_iters: 100, budget_secs: 8.0 };
    let mut rows = Vec::new();

    // Single request end to end (batch of 1 after linger).
    let single = measure("coordinator/1-request", &cfg, || {
        coordinator.classify("a", make_ids(1)).unwrap();
    });

    // Burst of 16 mixed-task requests (one shared invocation).
    let burst = measure("coordinator/16-burst", &cfg, || {
        let rxs: Vec<_> = (0..16)
            .map(|i| {
                coordinator
                    .submit(Request {
                        task: if i % 2 == 0 { "a".into() } else { "b".into() },
                        ids: make_ids(i),
                    })
                    .unwrap()
            })
            .collect();
        for rx in rxs {
            rx.recv().unwrap().unwrap();
        }
    });

    let snap = coordinator.metrics().snapshot();
    rows.push(vec![
        "1 request".into(),
        format!("{:.3}", single.mean_secs * 1e3),
        format!("{}", single.iters),
    ]);
    rows.push(vec![
        "16-request burst".into(),
        format!("{:.3}", burst.mean_secs * 1e3),
        format!("{}", burst.iters),
    ]);
    rows.push(vec![
        "per-request @16".into(),
        format!("{:.3}", burst.mean_secs * 1e3 / 16.0),
        String::new(),
    ]);
    println!("{}", render_table(&["case", "mean ms", "iters"], &rows));
    println!(
        "gather fraction of device work: {:.2}% (target: small; must stay below the \
         pre-pipeline baseline) — {}",
        snap.gather_fraction * 100.0,
        snap.render()
    );
    println!(
        "pipeline: backend={} arena allocs={} reuses={} (allocs must stay flat in steady state)",
        coordinator.pipeline().backend_name(),
        coordinator.pipeline().arena().allocs(),
        coordinator.pipeline().arena().reuses(),
    );
}
