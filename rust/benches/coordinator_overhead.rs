//! Bench: coordinator overhead — request latency through the full
//! router/batcher/gather/execute pipeline vs the raw backbone execute.
//! DESIGN.md §9 L3 target: the coordinator's own work must stay a small
//! fraction of the backbone execute.
//!
//! Runs against the PJRT coordinator when serving artifacts are present,
//! and falls back to the deterministic [`HostBackend`] otherwise — either
//! way the results land in `BENCH_coordinator.json` at the repo root for
//! CI artifact upload.
//!
//!     cargo bench --bench coordinator_overhead [-- --test]
//!
//! `--test` is the CI smoke mode: tiny budgets, no perf conclusions —
//! it only proves the bench still runs end to end.

use std::collections::BTreeMap;
use std::sync::Arc;

use aotpt::bench::{measure, render_table, BenchConfig};
use aotpt::config::Manifest;
use aotpt::coordinator::{
    Bucket, Coordinator, CoordinatorConfig, HostBackend, Request, TaskRegistry,
};
use aotpt::json::Json;
use aotpt::peft::TaskP;
use aotpt::runtime::{Runtime, WeightCache};
use aotpt::tensor::Tensor;
use aotpt::util::Pcg64;

/// The production path: a PJRT coordinator over real serving artifacts.
/// `None` (with a note) when the artifacts or the PJRT runtime are
/// unavailable; the caller then falls back to [`build_host`].
fn build_pjrt() -> Option<(Coordinator, usize)> {
    let Ok(manifest) = Manifest::load(&aotpt::artifacts_dir()) else {
        eprintln!(
            "coordinator_overhead: artifacts missing (run `make artifacts`); \
             falling back to the HostBackend"
        );
        return None;
    };
    let runtime = match Runtime::new() {
        Ok(r) => r,
        Err(e) => {
            eprintln!("coordinator_overhead: no PJRT runtime ({e:#}); falling back");
            return None;
        }
    };
    let model = manifest.model("small").ok()?.clone();
    let weights = match WeightCache::from_ckpt(
        &runtime,
        &aotpt::artifacts_dir().join("backbone_small.aotckpt"),
    ) {
        Ok(w) => w,
        Err(e) => {
            eprintln!("coordinator_overhead: cannot load backbone weights ({e:#}); falling back");
            return None;
        }
    };
    let emb = weights.host("emb_tok").ok()?.clone();

    let registry = TaskRegistry::new(
        model.n_layers,
        model.vocab_size,
        model.d_model,
        manifest.multitask_classes,
    );
    let mut rng = Pcg64::new(3);
    for name in ["a", "b"] {
        let (l, d, r) = (model.n_layers, model.d_model, 8);
        let mut tr = BTreeMap::new();
        tr.insert("t.fc.w1".into(), Tensor::from_f32(&[l, d, r], rng.normal_vec(l * d * r, 0.05)));
        tr.insert("t.fc.b1".into(), Tensor::from_f32(&[l, r], vec![0.0; l * r]));
        tr.insert("t.fc.w2".into(), Tensor::from_f32(&[l, r, d], rng.normal_vec(l * r * d, 0.05)));
        tr.insert("t.fc.b2".into(), Tensor::from_f32(&[l, d], vec![0.0; l * d]));
        tr.insert("t.head_w".into(), Tensor::from_f32(&[d, 2], rng.normal_vec(d * 2, 0.05)));
        tr.insert("t.head_b".into(), Tensor::from_f32(&[2], vec![0.0; 2]));
        registry.register_fc(name, &emb, &tr).ok()?;
    }
    match Coordinator::new(
        Arc::clone(&runtime),
        &manifest,
        registry,
        CoordinatorConfig {
            model: "small".into(),
            linger_ms: 1,
            signature: "aot".into(),
            ..Default::default()
        },
    ) {
        Ok(c) => Some((c, model.vocab_size)),
        Err(e) => {
            eprintln!(
                "coordinator_overhead: cannot build PJRT coordinator ({e:#}); \
                 falling back to the HostBackend"
            );
            None
        }
    }
}

/// Accelerator-free fallback: the same coordinator (overlap, prefetch and
/// the gather pool all on their defaults) over the deterministic
/// [`HostBackend`], so the bench runs — and its JSON artifact lands — on
/// any machine.
fn build_host() -> (Coordinator, usize) {
    let (layers, vocab, d_model, classes) = (4usize, 2048usize, 64usize, 4usize);
    let registry = TaskRegistry::new(layers, vocab, d_model, classes);
    let mut rng = Pcg64::new(3);
    for name in ["a", "b"] {
        let table = TaskP::new(
            layers,
            vocab,
            d_model,
            rng.normal_vec(layers * vocab * d_model, 0.5),
        )
        .unwrap();
        let head_w = Tensor::from_f32(&[d_model, 2], rng.normal_vec(d_model * 2, 0.2));
        let head_b = Tensor::from_f32(&[2], vec![0.0; 2]);
        registry.register_fused(name, table, &head_w, &head_b).unwrap();
    }
    let buckets = vec![Bucket { batch: 1, seq: 64 }, Bucket { batch: 16, seq: 64 }];
    let coordinator = Coordinator::with_backend(
        registry,
        buckets,
        classes,
        CoordinatorConfig {
            model: "host".into(),
            linger_ms: 1,
            signature: "aot".into(),
            ..Default::default()
        },
        Arc::new(HostBackend),
    )
    .unwrap();
    (coordinator, vocab)
}

fn main() {
    let test_mode = std::env::args().any(|a| a == "--test");
    let (coordinator, vocab) = match build_pjrt() {
        Some(built) => built,
        None => build_host(),
    };
    let backend = coordinator.pipeline().backend_name();
    println!("coordinator backend: {backend}{}", if test_mode { " (smoke --test mode)" } else { "" });

    let make_ids = |seed: u64| {
        let mut r = Pcg64::new(seed);
        let mut v = vec![aotpt::tokenizer::CLS];
        for _ in 0..50 {
            v.push(r.range(5, vocab as i64) as i32);
        }
        v
    };
    // Warm the bucket executables (and the coordinator's overlap queue).
    let _ = coordinator.classify("a", make_ids(0)).unwrap();

    let cfg = if test_mode {
        BenchConfig { warmup_iters: 1, min_iters: 2, max_iters: 3, budget_secs: 0.05 }
    } else {
        BenchConfig { warmup_iters: 3, min_iters: 10, max_iters: 100, budget_secs: 8.0 }
    };
    let mut rows = Vec::new();

    // Single request end to end (batch of 1 after linger).
    let single = measure("coordinator/1-request", &cfg, || {
        coordinator.classify("a", make_ids(1)).unwrap();
    });

    // Burst of 16 mixed-task requests (one shared invocation).
    let burst = measure("coordinator/16-burst", &cfg, || {
        let rxs: Vec<_> = (0..16)
            .map(|i| {
                coordinator
                    .submit(Request {
                        task: if i % 2 == 0 { "a".into() } else { "b".into() },
                        ids: make_ids(i),
                    })
                    .unwrap()
            })
            .collect();
        for rx in rxs {
            rx.recv().unwrap().unwrap();
        }
    });

    let snap = coordinator.metrics().snapshot();
    rows.push(vec![
        "1 request".into(),
        format!("{:.3}", single.mean_secs * 1e3),
        format!("{}", single.iters),
    ]);
    rows.push(vec![
        "16-request burst".into(),
        format!("{:.3}", burst.mean_secs * 1e3),
        format!("{}", burst.iters),
    ]);
    rows.push(vec![
        "per-request @16".into(),
        format!("{:.3}", burst.mean_secs * 1e3 / 16.0),
        String::new(),
    ]);
    println!("{}", render_table(&["case", "mean ms", "iters"], &rows));
    println!(
        "gather fraction of device work: {:.2}% (target: small; must stay below the \
         pre-pipeline baseline) — {}",
        snap.gather_fraction * 100.0,
        snap.render()
    );
    let allocs = coordinator.pipeline().arena().allocs();
    let reuses = coordinator.pipeline().arena().reuses();
    println!(
        "pipeline: backend={backend} arena allocs={allocs} reuses={reuses} \
         (allocs must stay flat in steady state)"
    );

    let mut cases = Json::Arr(Vec::new());
    for (m, requests_per_iter) in [(&single, 1.0f64), (&burst, 16.0)] {
        let mut case = m.to_json();
        case.set("ns_per_batch", Json::Num(m.mean_secs * 1e9));
        case.set("ns_per_request", Json::Num(m.mean_secs * 1e9 / requests_per_iter));
        cases.push(case);
    }
    let doc = Json::from_pairs(vec![
        ("bench", Json::Str("coordinator_overhead".into())),
        ("backend", Json::Str(backend.into())),
        ("test_mode", Json::Bool(test_mode)),
        ("gather_fraction", Json::Num(snap.gather_fraction)),
        ("arena_allocs", Json::Num(allocs as f64)),
        ("arena_reuses", Json::Num(reuses as f64)),
        ("cases", cases),
    ]);
    let path = aotpt::repo_root().join("BENCH_coordinator.json");
    aotpt::json::save(&path, &doc).unwrap();
    println!("wrote {}", path.display());
    coordinator.shutdown();
}
