//! Bench: paper Appendix Figure 8 — per-method speed at seq 384 across
//! all backbone analogs.
//!
//!     cargo bench --bench fig8_speed

use aotpt::config::Manifest;
use aotpt::experiments::speed;
use aotpt::runtime::Runtime;

fn main() {
    let Ok(manifest) = Manifest::load(&aotpt::artifacts_dir()) else {
        eprintln!("fig8_speed: artifacts missing (run `make artifacts`); skipping");
        return;
    };
    let runtime = Runtime::new().unwrap();
    let mut all = Vec::new();
    for model in ["small", "base"] {
        all.extend(
            speed::run_grid(&runtime, &manifest, model, &[(1, 384), (16, 384)], 5.0).unwrap(),
        );
    }
    // `large` b16 n384 is covered by fig3; keep this bench under ~10 min.
    all.extend(speed::run_grid(&runtime, &manifest, "large", &[(1, 384)], 5.0).unwrap());
    println!("{}", speed::report("fig8", &all).unwrap());
}
