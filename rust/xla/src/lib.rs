//! CPU-only stub of the `xla` crate (PJRT C API bindings) API surface that
//! `aotpt` uses.
//!
//! The real dependency wraps the PJRT C API and needs a system XLA plugin,
//! which is not available on a bare build machine.  This stub keeps the
//! whole crate compiling and makes the *host-side* pieces genuinely work:
//!
//! * [`Literal`] is a real host container (shape + dtype + bytes), so
//!   tensor ⇄ literal marshalling round-trips and its unit tests pass;
//! * [`PjRtBuffer`] wraps a host literal, so upload → `to_literal_sync`
//!   round-trips too;
//! * compilation and execution entry points return a descriptive
//!   [`Error`] — anything that actually needs an accelerator fails loudly
//!   instead of silently, and callers (the coordinator's prewarm stage,
//!   the experiment drivers) surface the error at startup.
//!
//! To run real artifacts, vendor a PJRT-backed `xla` crate and point the
//! workspace at it:
//!
//! ```toml
//! [patch."crates-io"]        # or a [patch] of this path dependency
//! xla = { path = "third_party/xla-rs" }
//! ```
//!
//! then build with `--features pjrt`.

#[cfg(feature = "pjrt")]
compile_error!(
    "the `pjrt` feature requires a real PJRT-backed `xla` crate; \
     replace the rust/xla stub (e.g. via [patch]) to run on hardware"
);

use std::fmt;

/// Stub error type; mirrors the real crate's `xla::Error` Display surface.
#[derive(Debug, Clone)]
pub struct Error(pub String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for Error {}

fn unavailable(what: &str) -> Error {
    Error(format!(
        "{what} requires the PJRT backend, which is not compiled in \
         (this build uses the CPU stub; see rust/xla/src/lib.rs)"
    ))
}

/// Element types mirrored from the real crate (subset + padding variants so
/// wildcard match arms stay reachable).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ElementType {
    Pred,
    S8,
    S16,
    S32,
    S64,
    U8,
    U16,
    U32,
    U64,
    F16,
    Bf16,
    F32,
    F64,
}

impl ElementType {
    fn byte_size(self) -> usize {
        match self {
            ElementType::Pred | ElementType::S8 | ElementType::U8 => 1,
            ElementType::S16 | ElementType::U16 | ElementType::F16 | ElementType::Bf16 => 2,
            ElementType::S32 | ElementType::U32 | ElementType::F32 => 4,
            ElementType::S64 | ElementType::U64 | ElementType::F64 => 8,
        }
    }
}

/// Host element marker, used to type `copy_raw_to` / host uploads.
pub trait ArrayElement: Copy {
    const TY: ElementType;
}

impl ArrayElement for f32 {
    const TY: ElementType = ElementType::F32;
}

impl ArrayElement for i32 {
    const TY: ElementType = ElementType::S32;
}

impl ArrayElement for i64 {
    const TY: ElementType = ElementType::S64;
}

/// Array shape: dimensions + element type.
#[derive(Clone, Debug)]
pub struct ArrayShape {
    dims: Vec<i64>,
    ty: ElementType,
}

impl ArrayShape {
    pub fn dims(&self) -> &[i64] {
        &self.dims
    }

    pub fn ty(&self) -> ElementType {
        self.ty
    }
}

/// A literal's shape: an array or a tuple of shapes.
#[derive(Clone, Debug)]
pub enum Shape {
    Array(ArrayShape),
    Tuple(Vec<Shape>),
}

/// A host tensor container (fully functional in the stub).
#[derive(Clone, Debug)]
pub struct Literal {
    ty: ElementType,
    dims: Vec<i64>,
    data: Vec<u8>,
}

impl Literal {
    pub fn create_from_shape_and_untyped_data(
        ty: ElementType,
        dims: &[usize],
        data: &[u8],
    ) -> Result<Literal, Error> {
        let count: usize = dims.iter().product();
        let expect = count * ty.byte_size();
        if data.len() != expect {
            return Err(Error(format!(
                "literal: {} bytes for {:?} {:?} (expected {})",
                data.len(),
                ty,
                dims,
                expect
            )));
        }
        Ok(Literal {
            ty,
            dims: dims.iter().map(|&d| d as i64).collect(),
            data: data.to_vec(),
        })
    }

    pub fn shape(&self) -> Result<Shape, Error> {
        Ok(Shape::Array(ArrayShape { dims: self.dims.clone(), ty: self.ty }))
    }

    pub fn array_shape(&self) -> Result<ArrayShape, Error> {
        Ok(ArrayShape { dims: self.dims.clone(), ty: self.ty })
    }

    pub fn element_count(&self) -> usize {
        self.dims.iter().map(|&d| d as usize).product()
    }

    pub fn copy_raw_to<T: ArrayElement>(&self, dst: &mut [T]) -> Result<(), Error> {
        if T::TY != self.ty {
            return Err(Error(format!(
                "copy_raw_to: literal is {:?}, destination is {:?}",
                self.ty,
                T::TY
            )));
        }
        let have = std::mem::size_of_val(dst);
        if have != self.data.len() {
            return Err(Error(format!(
                "copy_raw_to: destination holds {have} bytes, literal has {}",
                self.data.len()
            )));
        }
        // Raw byte copy; T is Copy (via ArrayElement) and sizes match.
        unsafe {
            std::ptr::copy_nonoverlapping(
                self.data.as_ptr(),
                dst.as_mut_ptr() as *mut u8,
                self.data.len(),
            );
        }
        Ok(())
    }

    pub fn decompose_tuple(&mut self) -> Result<Vec<Literal>, Error> {
        Err(Error("stub literal is an array, not a tuple".into()))
    }
}

/// A "device" buffer — in the stub, a host literal.
#[derive(Clone, Debug)]
pub struct PjRtBuffer {
    literal: Literal,
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal, Error> {
        Ok(self.literal.clone())
    }
}

/// Parsed HLO module — never constructible in the stub (parsing errors).
#[derive(Debug)]
pub struct HloModuleProto {
    _private: (),
}

impl HloModuleProto {
    pub fn from_text_file(path: &str) -> Result<HloModuleProto, Error> {
        Err(unavailable(&format!("parsing HLO text {path}")))
    }
}

/// An XLA computation wrapper.
#[derive(Debug)]
pub struct XlaComputation {
    _private: (),
}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { _private: () }
    }
}

/// The PJRT client.  The stub "CPU platform" supports host marshalling
/// (buffer upload / literal readback) but not compilation or execution.
#[derive(Debug)]
pub struct PjRtClient {
    _private: (),
}

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient, Error> {
        Ok(PjRtClient { _private: () })
    }

    pub fn platform_name(&self) -> String {
        "cpu-stub".to_string()
    }

    pub fn device_count(&self) -> usize {
        1
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable, Error> {
        Err(unavailable("XLA compilation"))
    }

    pub fn buffer_from_host_buffer<T: ArrayElement>(
        &self,
        data: &[T],
        dims: &[usize],
        _device: Option<usize>,
    ) -> Result<PjRtBuffer, Error> {
        let count: usize = dims.iter().product();
        if data.len() != count {
            return Err(Error(format!(
                "buffer_from_host_buffer: {} elements for shape {:?}",
                data.len(),
                dims
            )));
        }
        let bytes = unsafe {
            std::slice::from_raw_parts(data.as_ptr() as *const u8, std::mem::size_of_val(data))
        };
        Ok(PjRtBuffer {
            literal: Literal::create_from_shape_and_untyped_data(T::TY, dims, bytes)?,
        })
    }
}

/// A compiled executable — never constructible in the stub.
#[derive(Debug)]
pub struct PjRtLoadedExecutable {
    _private: (),
}

impl PjRtLoadedExecutable {
    pub fn execute<L>(&self, _args: &[L]) -> Result<Vec<Vec<PjRtBuffer>>, Error> {
        Err(unavailable("executable.execute"))
    }

    pub fn execute_b(&self, _args: &[&PjRtBuffer]) -> Result<Vec<Vec<PjRtBuffer>>, Error> {
        Err(unavailable("executable.execute_b"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_roundtrip_f32() {
        let values = [1.0f32, -2.5, 3.0];
        let bytes: Vec<u8> = values.iter().flat_map(|v| v.to_le_bytes()).collect();
        let lit =
            Literal::create_from_shape_and_untyped_data(ElementType::F32, &[3], &bytes).unwrap();
        assert_eq!(lit.element_count(), 3);
        let mut out = [0f32; 3];
        lit.copy_raw_to(&mut out).unwrap();
        assert_eq!(out, values);
        let shape = lit.array_shape().unwrap();
        assert_eq!(shape.dims(), &[3]);
        assert_eq!(shape.ty(), ElementType::F32);
    }

    #[test]
    fn literal_rejects_bad_sizes() {
        assert!(
            Literal::create_from_shape_and_untyped_data(ElementType::F32, &[2], &[0u8; 7]).is_err()
        );
        let lit = Literal::create_from_shape_and_untyped_data(ElementType::F32, &[1], &[0u8; 4])
            .unwrap();
        let mut wrong_ty = [0i32; 1];
        assert!(lit.copy_raw_to(&mut wrong_ty).is_err());
        let mut wrong_len = [0f32; 2];
        assert!(lit.copy_raw_to(&mut wrong_len).is_err());
    }

    #[test]
    fn client_upload_roundtrip() {
        let client = PjRtClient::cpu().unwrap();
        assert_eq!(client.device_count(), 1);
        let buf = client.buffer_from_host_buffer::<i32>(&[7, 8], &[2], None).unwrap();
        let lit = buf.to_literal_sync().unwrap();
        let mut out = [0i32; 2];
        lit.copy_raw_to(&mut out).unwrap();
        assert_eq!(out, [7, 8]);
    }

    #[test]
    fn compile_paths_error_cleanly() {
        assert!(HloModuleProto::from_text_file("nope.hlo").is_err());
        let client = PjRtClient::cpu().unwrap();
        let comp = XlaComputation { _private: () };
        assert!(client.compile(&comp).is_err());
    }
}
