//! Bench harness (criterion is not available offline): warmup + timed
//! iterations with adaptive iteration counts, mean/p50/p99 reporting, and
//! JSON result output under `results/`.

use crate::json::Json;
use crate::util::{stats, Timer};

/// Harness configuration.
#[derive(Clone, Copy, Debug)]
pub struct BenchConfig {
    pub warmup_iters: usize,
    pub min_iters: usize,
    pub max_iters: usize,
    /// Stop once this much wall time has been spent measuring one case.
    pub budget_secs: f64,
}

impl Default for BenchConfig {
    fn default() -> Self {
        BenchConfig { warmup_iters: 3, min_iters: 5, max_iters: 300, budget_secs: 10.0 }
    }
}

impl BenchConfig {
    /// The paper's §4.4 protocol: 300 evaluations at batch 1, 100 above —
    /// bounded here by a wall-clock budget per cell (single CPU core).
    pub fn paper(batch: usize, budget_secs: f64) -> BenchConfig {
        BenchConfig {
            warmup_iters: 3,
            min_iters: 5,
            max_iters: if batch == 1 { 300 } else { 100 },
            budget_secs,
        }
    }
}

/// One measured case.
#[derive(Clone, Debug)]
pub struct Measurement {
    pub name: String,
    pub iters: usize,
    pub mean_secs: f64,
    pub p50_secs: f64,
    pub p99_secs: f64,
    pub std_secs: f64,
}

impl Measurement {
    pub fn to_json(&self) -> Json {
        Json::from_pairs(vec![
            ("name", Json::Str(self.name.clone())),
            ("iters", Json::Num(self.iters as f64)),
            ("mean_ms", Json::Num(self.mean_secs * 1e3)),
            ("p50_ms", Json::Num(self.p50_secs * 1e3)),
            ("p99_ms", Json::Num(self.p99_secs * 1e3)),
            ("std_ms", Json::Num(self.std_secs * 1e3)),
        ])
    }
}

/// Measure a closure under the config.
pub fn measure(name: &str, cfg: &BenchConfig, mut f: impl FnMut()) -> Measurement {
    for _ in 0..cfg.warmup_iters {
        f();
    }
    let mut samples = Vec::new();
    let budget = Timer::start();
    while samples.len() < cfg.max_iters
        && (samples.len() < cfg.min_iters || budget.secs() < cfg.budget_secs)
    {
        let t = Timer::start();
        f();
        samples.push(t.secs());
    }
    Measurement {
        name: name.to_string(),
        iters: samples.len(),
        mean_secs: stats::mean(&samples),
        p50_secs: stats::percentile(&samples, 50.0),
        p99_secs: stats::percentile(&samples, 99.0),
        std_secs: stats::std(&samples),
    }
}

/// Render an aligned text table.
pub fn render_table(headers: &[&str], rows: &[Vec<String>]) -> String {
    let mut widths: Vec<usize> = headers.iter().map(|h| h.len()).collect();
    for row in rows {
        for (i, cell) in row.iter().enumerate() {
            if i < widths.len() {
                widths[i] = widths[i].max(cell.len());
            }
        }
    }
    let mut out = String::new();
    let fmt_row = |cells: &[String], widths: &[usize]| -> String {
        let mut line = String::from("|");
        for (c, w) in cells.iter().zip(widths) {
            line.push_str(&format!(" {c:<w$} |"));
        }
        line.push('\n');
        line
    };
    out.push_str(&fmt_row(
        &headers.iter().map(|h| h.to_string()).collect::<Vec<_>>(),
        &widths,
    ));
    out.push_str(&fmt_row(
        &widths.iter().map(|w| "-".repeat(*w)).collect::<Vec<_>>(),
        &widths,
    ));
    for row in rows {
        out.push_str(&fmt_row(row, &widths));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measure_counts_iterations() {
        let cfg = BenchConfig { warmup_iters: 1, min_iters: 4, max_iters: 10, budget_secs: 60.0 };
        let mut count = 0;
        let m = measure("noop", &cfg, || {
            count += 1;
        });
        assert!(m.iters >= 4 && m.iters <= 10);
        assert_eq!(count, m.iters + cfg.warmup_iters);
        assert!(m.mean_secs >= 0.0);
        assert!(m.p99_secs >= m.p50_secs);
    }

    #[test]
    fn budget_caps_iterations() {
        let cfg = BenchConfig { warmup_iters: 0, min_iters: 2, max_iters: 10_000, budget_secs: 0.05 };
        let m = measure("sleepy", &cfg, || {
            std::thread::sleep(std::time::Duration::from_millis(5));
        });
        assert!(m.iters < 100, "{}", m.iters);
    }

    #[test]
    fn table_renders_aligned() {
        let t = render_table(
            &["method", "ratio"],
            &[vec!["aot".into(), "1.00".into()], vec!["pt2".into(), "1.31".into()]],
        );
        assert!(t.contains("| aot    | 1.00  |"));
    }

    #[test]
    fn paper_config_matches_protocol() {
        assert_eq!(BenchConfig::paper(1, 10.0).max_iters, 300);
        assert_eq!(BenchConfig::paper(16, 10.0).max_iters, 100);
    }
}
