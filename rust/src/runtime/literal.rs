//! Host `Tensor` ⇄ xla `Literal` / `PjRtBuffer` marshalling.

use anyhow::anyhow;

use crate::tensor::{DType, Tensor};
use crate::Result;

use super::wrap;

/// Host tensor -> host literal (no device transfer yet).
pub fn tensor_to_literal(t: &Tensor) -> Result<xla::Literal> {
    let ty = element_type(t.dtype);
    xla::Literal::create_from_shape_and_untyped_data(ty, &t.shape, t.bytes()).map_err(wrap)
}

/// Host tensor -> device buffer.
pub fn tensor_to_buffer(client: &xla::PjRtClient, t: &Tensor) -> Result<xla::PjRtBuffer> {
    // The typed entry point is used (not raw bytes): the crate's raw-bytes
    // variant passes the wrong enum discriminant to the C layer.
    match t.dtype {
        DType::F32 => f32_to_buffer(client, &t.shape, t.as_f32()?),
        DType::I32 => i32_to_buffer(client, &t.shape, t.as_i32()?),
        DType::I64 => Err(anyhow!("i64 upload not needed by any artifact")),
    }
}

/// Host f32 slice -> device buffer.  The serving hot path uploads straight
/// from arena-managed buffers, so no `Tensor` (and no copy into one) is
/// ever materialized per batch.
pub fn f32_to_buffer(
    client: &xla::PjRtClient,
    dims: &[usize],
    data: &[f32],
) -> Result<xla::PjRtBuffer> {
    client.buffer_from_host_buffer::<f32>(data, dims, None).map_err(wrap)
}

/// Host i32 slice -> device buffer (see [`f32_to_buffer`]).
pub fn i32_to_buffer(
    client: &xla::PjRtClient,
    dims: &[usize],
    data: &[i32],
) -> Result<xla::PjRtBuffer> {
    client.buffer_from_host_buffer::<i32>(data, dims, None).map_err(wrap)
}

/// Host literal -> host tensor.
pub fn literal_to_tensor(lit: &xla::Literal) -> Result<Tensor> {
    let shape = lit.array_shape().map_err(wrap)?;
    let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
    let dtype = match shape.ty() {
        xla::ElementType::F32 => DType::F32,
        xla::ElementType::S32 => DType::I32,
        xla::ElementType::S64 => DType::I64,
        other => return Err(anyhow!("unsupported output element type {other:?}")),
    };
    match dtype {
        DType::F32 => {
            let mut data = vec![0f32; lit.element_count()];
            lit.copy_raw_to::<f32>(&mut data).map_err(wrap)?;
            Ok(Tensor::from_f32(&dims, data))
        }
        DType::I32 => {
            let mut data = vec![0i32; lit.element_count()];
            lit.copy_raw_to::<i32>(&mut data).map_err(wrap)?;
            Ok(Tensor::from_i32(&dims, data))
        }
        DType::I64 => {
            let mut data = vec![0i64; lit.element_count()];
            lit.copy_raw_to::<i64>(&mut data).map_err(wrap)?;
            // Narrow to i32 (no artifact emits i64 payloads we keep).
            let narrowed: Vec<i32> = data.into_iter().map(|x| x as i32).collect();
            Ok(Tensor::from_i32(&dims, narrowed))
        }
    }
}

fn element_type(dtype: DType) -> xla::ElementType {
    match dtype {
        DType::F32 => xla::ElementType::F32,
        DType::I32 => xla::ElementType::S32,
        DType::I64 => xla::ElementType::S64,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tensor_literal_roundtrip() {
        let t = Tensor::from_f32(&[2, 2], vec![1.0, -2.0, 3.5, 0.0]);
        let lit = tensor_to_literal(&t).unwrap();
        let back = literal_to_tensor(&lit).unwrap();
        assert_eq!(back.shape, vec![2, 2]);
        assert_eq!(back.as_f32().unwrap(), t.as_f32().unwrap());
    }

    #[test]
    fn i32_roundtrip() {
        let t = Tensor::from_i32(&[3], vec![7, -1, 2]);
        let lit = tensor_to_literal(&t).unwrap();
        let back = literal_to_tensor(&lit).unwrap();
        assert_eq!(back.as_i32().unwrap(), &[7, -1, 2]);
    }

    #[test]
    fn scalar_roundtrip() {
        let t = Tensor::scalar_f32(2.5);
        let lit = tensor_to_literal(&t).unwrap();
        let back = literal_to_tensor(&lit).unwrap();
        assert_eq!(back.shape, Vec::<usize>::new());
        assert_eq!(back.as_f32().unwrap(), &[2.5]);
    }
}
