//! Device-resident weight cache.
//!
//! The paper's multi-task serving story keeps ONE backbone on the
//! accelerator while per-task state stays in host RAM.  `WeightCache`
//! uploads each `w.*` tensor once; every bucket/method executable of the
//! same model shape then shares the buffers via `execute_b` — weight bytes
//! never move again.

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::anyhow;

use super::{tensor_to_buffer, Runtime};
use crate::tensor::{ckpt, Tensor};
use crate::Result;

pub struct WeightCache {
    buffers: BTreeMap<String, xla::PjRtBuffer>,
    host: BTreeMap<String, Tensor>,
}

unsafe impl Send for WeightCache {}
unsafe impl Sync for WeightCache {}

impl WeightCache {
    /// Load a checkpoint and upload every tensor.
    pub fn from_ckpt(runtime: &Runtime, path: &Path) -> Result<WeightCache> {
        let host = ckpt::load(path)?;
        Self::from_tensors(runtime, host)
    }

    pub fn from_tensors(
        runtime: &Runtime,
        host: BTreeMap<String, Tensor>,
    ) -> Result<WeightCache> {
        let mut buffers = BTreeMap::new();
        for (name, t) in &host {
            buffers.insert(name.clone(), tensor_to_buffer(runtime.client(), t)?);
        }
        Ok(WeightCache { buffers, host })
    }

    pub fn buffer(&self, name: &str) -> Result<&xla::PjRtBuffer> {
        self.buffers
            .get(name)
            .ok_or_else(|| anyhow!("weight cache has no tensor {name}"))
    }

    /// Host copy (for fuse-time math and analysis).
    pub fn host(&self, name: &str) -> Result<&Tensor> {
        self.host
            .get(name)
            .ok_or_else(|| anyhow!("weight cache has no tensor {name}"))
    }

    pub fn names(&self) -> impl Iterator<Item = &String> {
        self.buffers.keys()
    }

    /// Insert/replace a tensor (e.g. the fused P table for device-gather).
    pub fn insert(&mut self, runtime: &Runtime, name: &str, t: Tensor) -> Result<()> {
        self.buffers
            .insert(name.to_string(), tensor_to_buffer(runtime.client(), &t)?);
        self.host.insert(name.to_string(), t);
        Ok(())
    }

    pub fn len(&self) -> usize {
        self.buffers.len()
    }

    pub fn is_empty(&self) -> bool {
        self.buffers.is_empty()
    }
}
