//! PJRT runtime: load AOT-compiled HLO-text artifacts and execute them.
//!
//! Wraps the `xla` crate (PJRT C API):
//! `PjRtClient::cpu()` → `HloModuleProto::from_text_file` →
//! `client.compile` → `execute` / `execute_b`.
//!
//! Conventions established by `python/compile/aot.py`:
//! * interchange is HLO **text** (64-bit-id proto incompatibility, see
//!   /opt/xla-example/README.md);
//! * artifacts are lowered with `return_tuple=False`, so single-output
//!   graphs return a bare array and multi-output graphs a tuple —
//!   `run` normalizes both to `Vec<Tensor>`;
//! * weights (`w.*`) are uploaded once per model and kept device-resident
//!   (`WeightCache`); only per-call inputs move on the hot path.

pub mod literal;
pub mod weights;

use std::collections::HashMap;
use std::path::Path;
use std::sync::{Arc, Mutex};

use anyhow::{anyhow, Context};

use crate::config::{ArtifactSpec, Manifest};
use crate::tensor::Tensor;
use crate::Result;

pub use literal::{
    f32_to_buffer, i32_to_buffer, literal_to_tensor, tensor_to_buffer, tensor_to_literal,
};
pub use weights::WeightCache;

/// Shared PJRT client + compiled-executable cache.
///
/// Compilation happens once per artifact stem; executables are shared
/// behind `Arc` so the coordinator's workers and the bench harness reuse
/// them freely.
pub struct Runtime {
    client: xla::PjRtClient,
    cache: Mutex<HashMap<String, Arc<Executable>>>,
}

// The PJRT CPU client is internally synchronized; the `xla` crate just
// doesn't mark its pointer wrappers Send/Sync.
unsafe impl Send for Runtime {}
unsafe impl Sync for Runtime {}

impl Runtime {
    pub fn new() -> Result<Arc<Runtime>> {
        let client = xla::PjRtClient::cpu().map_err(wrap)?;
        crate::info!(
            "PJRT client: platform={} devices={}",
            client.platform_name(),
            client.device_count()
        );
        Ok(Arc::new(Runtime { client, cache: Mutex::new(HashMap::new()) }))
    }

    pub fn client(&self) -> &xla::PjRtClient {
        &self.client
    }

    /// Load + compile an artifact (cached by stem).
    pub fn load(self: &Arc<Self>, manifest: &Manifest, stem: &str) -> Result<Arc<Executable>> {
        if let Some(exe) = self.cache.lock().unwrap().get(stem) {
            return Ok(Arc::clone(exe));
        }
        let spec = manifest.artifact(stem)?.clone();
        let exe = Arc::new(self.compile_spec(spec)?);
        self.cache
            .lock()
            .unwrap()
            .insert(stem.to_string(), Arc::clone(&exe));
        Ok(exe)
    }

    /// Compile an artifact spec without touching the cache.
    pub fn compile_spec(self: &Arc<Self>, spec: ArtifactSpec) -> Result<Executable> {
        let t = crate::util::Timer::start();
        let exe = self.compile_file(&spec.file)?;
        crate::debugln!("compiled {} in {:.2}s", spec.stem, t.secs());
        Ok(Executable { runtime: Arc::clone(self), exe, spec })
    }

    fn compile_file(&self, path: &Path) -> Result<xla::PjRtLoadedExecutable> {
        let path_str = path
            .to_str()
            .ok_or_else(|| anyhow!("non-utf8 path {}", path.display()))?;
        let proto = xla::HloModuleProto::from_text_file(path_str)
            .map_err(wrap)
            .with_context(|| format!("parsing HLO text {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        self.client
            .compile(&comp)
            .map_err(wrap)
            .with_context(|| format!("XLA compile of {}", path.display()))
    }

    /// Number of executables compiled so far (metrics / tests).
    pub fn compiled_count(&self) -> usize {
        self.cache.lock().unwrap().len()
    }
}

/// A compiled artifact plus its manifest signature.
pub struct Executable {
    runtime: Arc<Runtime>,
    exe: xla::PjRtLoadedExecutable,
    pub spec: ArtifactSpec,
}

unsafe impl Send for Executable {}
unsafe impl Sync for Executable {}

impl Executable {
    pub fn runtime(&self) -> &Arc<Runtime> {
        &self.runtime
    }

    /// Execute with host tensors; weights and inputs all uploaded per call.
    /// Validates count and shapes against the manifest signature.
    pub fn run(&self, args: &[Tensor]) -> Result<Vec<Tensor>> {
        self.check_args(args)?;
        let literals = args
            .iter()
            .map(tensor_to_literal)
            .collect::<Result<Vec<_>>>()?;
        let outs = self.exe.execute::<xla::Literal>(&literals).map_err(wrap)?;
        self.collect_outputs(outs)
    }

    /// Execute with device-resident buffers (the hot path: weights stay on
    /// device via `WeightCache`, per-call tensors are uploaded by caller).
    pub fn run_buffers(&self, args: &[&xla::PjRtBuffer]) -> Result<Vec<Tensor>> {
        if args.len() != self.spec.inputs.len() {
            anyhow::bail!(
                "{}: got {} args, signature has {}",
                self.spec.stem,
                args.len(),
                self.spec.inputs.len()
            );
        }
        let outs = self.exe.execute_b(args).map_err(wrap)?;
        self.collect_outputs(outs)
    }

    /// Upload a host tensor to the device (for caller-managed buffers).
    pub fn upload(&self, t: &Tensor) -> Result<xla::PjRtBuffer> {
        tensor_to_buffer(&self.runtime.client, t)
    }

    /// Upload an f32 slice without materializing a `Tensor` (the staged
    /// pipeline uploads arena buffers directly).
    pub fn upload_f32(&self, dims: &[usize], data: &[f32]) -> Result<xla::PjRtBuffer> {
        literal::f32_to_buffer(&self.runtime.client, dims, data)
    }

    /// Upload an i32 slice without materializing a `Tensor`.
    pub fn upload_i32(&self, dims: &[usize], data: &[i32]) -> Result<xla::PjRtBuffer> {
        literal::i32_to_buffer(&self.runtime.client, dims, data)
    }

    fn check_args(&self, args: &[Tensor]) -> Result<()> {
        if args.len() != self.spec.inputs.len() {
            anyhow::bail!(
                "{}: got {} args, signature has {}",
                self.spec.stem,
                args.len(),
                self.spec.inputs.len()
            );
        }
        for (arg, spec) in args.iter().zip(&self.spec.inputs) {
            if arg.shape != spec.shape || arg.dtype != spec.dtype {
                anyhow::bail!(
                    "{}: input {} expects {:?} {:?}, got {:?} {:?}",
                    self.spec.stem,
                    spec.name,
                    spec.dtype,
                    spec.shape,
                    arg.dtype,
                    arg.shape
                );
            }
        }
        Ok(())
    }

    fn collect_outputs(&self, outs: Vec<Vec<xla::PjRtBuffer>>) -> Result<Vec<Tensor>> {
        let replica = outs
            .into_iter()
            .next()
            .ok_or_else(|| anyhow!("{}: no replica outputs", self.spec.stem))?;
        let mut tensors = Vec::new();
        for buf in replica {
            let lit = buf.to_literal_sync().map_err(wrap)?;
            // Multi-output graphs come back as one tuple literal.
            match lit.shape().map_err(wrap)? {
                xla::Shape::Tuple(_) => {
                    let mut lit = lit;
                    for part in lit.decompose_tuple().map_err(wrap)? {
                        tensors.push(literal_to_tensor(&part)?);
                    }
                }
                _ => tensors.push(literal_to_tensor(&lit)?),
            }
        }
        if tensors.len() != self.spec.outputs.len() {
            anyhow::bail!(
                "{}: got {} outputs, manifest declares {}",
                self.spec.stem,
                tensors.len(),
                self.spec.outputs.len()
            );
        }
        Ok(tensors)
    }
}

/// Convert the xla crate's error type into anyhow.
pub(crate) fn wrap(e: xla::Error) -> anyhow::Error {
    anyhow!("{e}")
}
