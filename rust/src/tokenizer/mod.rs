//! Tokenization substrate.
//!
//! Two tokenizers are provided:
//!
//! * `WordVocab` — the vocabulary the synthetic benchmark suite runs on:
//!   a closed lexicon of generated words mapped to ids, with the special
//!   tokens the encoder expects.  The §4.3 analysis tables need the
//!   id → string map to label high-norm `P` rows, so the vocabulary is
//!   serializable.
//! * `Bpe` — a trainable byte-pair encoder (greedy merges over a word
//!   histogram).  It backs the `corpus` MLM-pretraining path and shows the
//!   substrate is real; the task generators use `WordVocab` for
//!   interpretability.

pub mod bpe;

use std::collections::HashMap;

use anyhow::{anyhow, bail};

use crate::Result;

pub use bpe::Bpe;

/// Special token ids (fixed, shared with the data pipeline).
pub const CLS: i32 = 0;
pub const SEP: i32 = 1;
pub const PAD: i32 = 2;
pub const MASK: i32 = 3;
pub const UNK: i32 = 4;
pub const N_SPECIAL: usize = 5;

/// A closed word-level vocabulary.
pub struct WordVocab {
    word_to_id: HashMap<String, i32>,
    id_to_word: Vec<String>,
}

impl WordVocab {
    /// Build from a lexicon (ids are assigned after the special tokens in
    /// the given order).
    pub fn new(words: impl IntoIterator<Item = String>, capacity: usize) -> Result<WordVocab> {
        let mut id_to_word: Vec<String> =
            ["[CLS]", "[SEP]", "[PAD]", "[MASK]", "[UNK]"].iter().map(|s| s.to_string()).collect();
        let mut word_to_id = HashMap::new();
        for (i, w) in id_to_word.iter().enumerate() {
            word_to_id.insert(w.clone(), i as i32);
        }
        for w in words {
            if word_to_id.contains_key(&w) {
                bail!("duplicate word {w} in lexicon");
            }
            if id_to_word.len() >= capacity {
                bail!("lexicon exceeds vocab capacity {capacity}");
            }
            word_to_id.insert(w.clone(), id_to_word.len() as i32);
            id_to_word.push(w);
        }
        Ok(WordVocab { word_to_id, id_to_word })
    }

    pub fn len(&self) -> usize {
        self.id_to_word.len()
    }

    pub fn is_empty(&self) -> bool {
        false
    }

    pub fn id(&self, word: &str) -> i32 {
        self.word_to_id.get(word).copied().unwrap_or(UNK)
    }

    pub fn word(&self, id: i32) -> Result<&str> {
        self.id_to_word
            .get(id as usize)
            .map(String::as_str)
            .ok_or_else(|| anyhow!("id {id} out of vocabulary"))
    }

    /// Encode a whitespace-separated sentence (no CLS/SEP added).
    pub fn encode(&self, text: &str) -> Vec<i32> {
        text.split_whitespace().map(|w| self.id(w)).collect()
    }

    pub fn decode(&self, ids: &[i32]) -> String {
        ids.iter()
            .filter_map(|&i| self.word(i).ok())
            .collect::<Vec<_>>()
            .join(" ")
    }
}

/// Wrap token ids as a classifier input: `[CLS] a… ([SEP] b…) [SEP]`,
/// truncated+padded to `seq`; returns (ids, mask).
pub fn pack_pair(a: &[i32], b: Option<&[i32]>, seq: usize) -> (Vec<i32>, Vec<f32>) {
    let mut ids = Vec::with_capacity(seq);
    ids.push(CLS);
    ids.extend_from_slice(a);
    if let Some(b) = b {
        ids.push(SEP);
        ids.extend_from_slice(b);
    }
    ids.push(SEP);
    ids.truncate(seq);
    let used = ids.len();
    ids.resize(seq, PAD);
    let mut mask = vec![0f32; seq];
    for m in mask.iter_mut().take(used) {
        *m = 1.0;
    }
    (ids, mask)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn vocab_roundtrip() {
        let v = WordVocab::new(["alpha".into(), "beta".into()], 100).unwrap();
        assert_eq!(v.id("alpha"), N_SPECIAL as i32);
        assert_eq!(v.word(N_SPECIAL as i32 + 1).unwrap(), "beta");
        assert_eq!(v.id("missing"), UNK);
        assert_eq!(v.decode(&v.encode("beta alpha")), "beta alpha");
    }

    #[test]
    fn vocab_rejects_duplicates_and_overflow() {
        assert!(WordVocab::new(["x".into(), "x".into()], 100).is_err());
        assert!(WordVocab::new(["a".into(), "b".into()], 6).is_err());
    }

    #[test]
    fn pack_pair_layout() {
        let (ids, mask) = pack_pair(&[10, 11], Some(&[20]), 8);
        assert_eq!(ids, vec![CLS, 10, 11, SEP, 20, SEP, PAD, PAD]);
        assert_eq!(mask, vec![1.0, 1.0, 1.0, 1.0, 1.0, 1.0, 0.0, 0.0]);
    }

    #[test]
    fn pack_truncates() {
        let (ids, mask) = pack_pair(&[10, 11, 12, 13], None, 4);
        assert_eq!(ids.len(), 4);
        assert_eq!(ids[0], CLS);
        assert!(mask.iter().all(|&m| m == 1.0));
    }
}
