//! A byte-pair encoder trained by greedy pair merging over a word
//! histogram (Sennrich et al. 2016 style, word-internal merges only).

use std::collections::HashMap;

/// A trained BPE model: base bytes + ordered merges.
pub struct Bpe {
    /// merge rank: (left, right) -> merged symbol id
    merges: HashMap<(u32, u32), u32>,
    /// symbol id -> byte string
    symbols: Vec<Vec<u8>>,
}

impl Bpe {
    /// Train on a corpus until `n_merges` merges (or no pair repeats).
    pub fn train(corpus: &str, n_merges: usize) -> Bpe {
        // Word histogram.
        let mut word_counts: HashMap<Vec<u32>, usize> = HashMap::new();
        for word in corpus.split_whitespace() {
            let symbols: Vec<u32> = word.bytes().map(|b| b as u32).collect();
            if symbols.is_empty() {
                continue;
            }
            *word_counts.entry(symbols).or_insert(0) += 1;
        }
        let mut words: Vec<(Vec<u32>, usize)> = word_counts.into_iter().collect();
        words.sort(); // deterministic iteration

        let mut symbols: Vec<Vec<u8>> = (0..=255u32).map(|b| vec![b as u8]).collect();
        let mut merges = HashMap::new();

        for _ in 0..n_merges {
            // Count adjacent pairs.
            let mut pair_counts: HashMap<(u32, u32), usize> = HashMap::new();
            for (w, c) in &words {
                for pair in w.windows(2) {
                    *pair_counts.entry((pair[0], pair[1])).or_insert(0) += c;
                }
            }
            // Best pair (deterministic tie-break on the pair itself).
            let Some((&pair, &count)) = pair_counts
                .iter()
                .max_by_key(|(p, c)| (**c, std::cmp::Reverse(**p)))
            else {
                break;
            };
            if count < 2 {
                break;
            }
            let new_id = symbols.len() as u32;
            let mut merged_bytes = symbols[pair.0 as usize].clone();
            merged_bytes.extend_from_slice(&symbols[pair.1 as usize]);
            symbols.push(merged_bytes);
            merges.insert(pair, new_id);
            // Apply the merge to every word.
            for (w, _) in words.iter_mut() {
                *w = apply_merge(w, pair, new_id);
            }
        }
        Bpe { merges, symbols }
    }

    /// Encode text into symbol ids.
    pub fn encode(&self, text: &str) -> Vec<u32> {
        let mut out = Vec::new();
        for word in text.split_whitespace() {
            let mut syms: Vec<u32> = word.bytes().map(|b| b as u32).collect();
            // Repeatedly apply the lowest-id (earliest-learned) applicable merge.
            loop {
                let mut best: Option<(usize, u32)> = None; // (position, merged id)
                for (i, pair) in syms.windows(2).enumerate() {
                    if let Some(&m) = self.merges.get(&(pair[0], pair[1])) {
                        if best.map_or(true, |(_, bm)| m < bm) {
                            best = Some((i, m));
                        }
                    }
                }
                match best {
                    Some((i, m)) => {
                        syms.splice(i..i + 2, [m]);
                    }
                    None => break,
                }
            }
            out.extend(syms);
        }
        out
    }

    /// Decode symbol ids back to a byte string.
    pub fn decode(&self, ids: &[u32]) -> String {
        let mut bytes = Vec::new();
        for &id in ids {
            if let Some(sym) = self.symbols.get(id as usize) {
                bytes.extend_from_slice(sym);
            }
        }
        String::from_utf8_lossy(&bytes).into_owned()
    }

    pub fn vocab_size(&self) -> usize {
        self.symbols.len()
    }
}

fn apply_merge(w: &[u32], pair: (u32, u32), new_id: u32) -> Vec<u32> {
    let mut out = Vec::with_capacity(w.len());
    let mut i = 0;
    while i < w.len() {
        if i + 1 < w.len() && (w[i], w[i + 1]) == pair {
            out.push(new_id);
            i += 2;
        } else {
            out.push(w[i]);
            i += 1;
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    const CORPUS: &str = "the cat sat on the mat the cat ran the cat sat";

    #[test]
    fn roundtrip_after_training() {
        let bpe = Bpe::train(CORPUS, 50);
        for text in ["the cat", "sat on the mat", "unseen words too"] {
            let ids = bpe.encode(text);
            assert_eq!(bpe.decode(&ids), text.replace(' ', ""));
        }
    }

    #[test]
    fn merges_shrink_frequent_words() {
        let bpe = Bpe::train(CORPUS, 50);
        // "the" is the most frequent word: must encode to one symbol.
        assert_eq!(bpe.encode("the").len(), 1);
        // A word never seen still encodes (as bytes / partial merges).
        assert!(!bpe.encode("zzzq").is_empty());
    }

    #[test]
    fn vocab_grows_by_merges() {
        let bpe = Bpe::train(CORPUS, 10);
        assert!(bpe.vocab_size() > 256);
        assert!(bpe.vocab_size() <= 266);
    }

    #[test]
    fn zero_merges_is_byte_level() {
        let bpe = Bpe::train(CORPUS, 0);
        assert_eq!(bpe.vocab_size(), 256);
        assert_eq!(bpe.encode("ab"), vec![97, 98]);
    }
}
