//! Tiny CLI argument parser (clap is not available offline).
//!
//! Supports `--flag`, `--key value`, `--key=value` and positional args;
//! generates usage text from declared options.  Empty values (`--key=`)
//! and repeated occurrences of the same option or flag are parse errors
//! — never silent last-wins.

use std::collections::BTreeMap;

/// Declarative option spec + parsed values.
pub struct Args {
    program: String,
    about: String,
    specs: Vec<Spec>,
    values: BTreeMap<String, String>,
    flags: Vec<String>,
    positional: Vec<String>,
}

struct Spec {
    name: String,
    help: String,
    default: Option<String>,
    is_flag: bool,
}

impl Args {
    pub fn new(program: &str, about: &str) -> Self {
        Args {
            program: program.to_string(),
            about: about.to_string(),
            specs: Vec::new(),
            values: BTreeMap::new(),
            flags: Vec::new(),
            positional: Vec::new(),
        }
    }

    /// Declare `--name <value>` with an optional default.
    pub fn opt(mut self, name: &str, default: Option<&str>, help: &str) -> Self {
        self.specs.push(Spec {
            name: name.to_string(),
            help: help.to_string(),
            default: default.map(str::to_string),
            is_flag: false,
        });
        self
    }

    /// Declare a boolean `--name` flag.
    pub fn flag(mut self, name: &str, help: &str) -> Self {
        self.specs.push(Spec {
            name: name.to_string(),
            help: help.to_string(),
            default: None,
            is_flag: true,
        });
        self
    }

    /// Parse a raw argument list (excluding argv[0]).
    pub fn parse(mut self, argv: &[String]) -> Result<Self, String> {
        let mut i = 0;
        while i < argv.len() {
            let arg = &argv[i];
            if arg == "--help" || arg == "-h" {
                return Err(self.usage());
            }
            if let Some(stripped) = arg.strip_prefix("--") {
                let (key, inline) = match stripped.split_once('=') {
                    Some((k, v)) => (k.to_string(), Some(v.to_string())),
                    None => (stripped.to_string(), None),
                };
                let spec = self
                    .specs
                    .iter()
                    .find(|s| s.name == key)
                    .ok_or_else(|| format!("unknown option --{key}\n\n{}", self.usage()))?;
                if spec.is_flag {
                    if inline.is_some() {
                        return Err(format!("--{key} is a flag and takes no value"));
                    }
                    if self.flags.contains(&key) {
                        return Err(format!("--{key} given more than once"));
                    }
                    self.flags.push(key);
                } else {
                    let value = match inline {
                        Some(v) => v,
                        None => {
                            i += 1;
                            argv.get(i)
                                .cloned()
                                .ok_or_else(|| format!("--{key} requires a value"))?
                        }
                    };
                    // An empty value (`--key=` or `--key ""`) would only
                    // fail later, deep inside get_usize/get_via, with a
                    // message that no longer names the culprit; reject it
                    // here where the flag is still in hand.
                    if value.is_empty() {
                        return Err(format!("--{key} requires a non-empty value"));
                    }
                    // Duplicates are an explicit error rather than silent
                    // last-wins: a typo'd retry of a long command line
                    // should not quietly serve half of it.
                    if self.values.insert(key.clone(), value).is_some() {
                        return Err(format!("--{key} given more than once"));
                    }
                }
            } else {
                self.positional.push(arg.clone());
            }
            i += 1;
        }
        Ok(self)
    }

    pub fn usage(&self) -> String {
        let mut out = format!("{} — {}\n\nOptions:\n", self.program, self.about);
        for s in &self.specs {
            let default = s
                .default
                .as_ref()
                .map(|d| format!(" (default: {d})"))
                .unwrap_or_default();
            let arg = if s.is_flag {
                format!("--{}", s.name)
            } else {
                format!("--{} <value>", s.name)
            };
            out.push_str(&format!("  {arg:<28} {}{}\n", s.help, default));
        }
        out
    }

    // -- accessors ---------------------------------------------------------

    pub fn get(&self, name: &str) -> Option<String> {
        if let Some(v) = self.values.get(name) {
            return Some(v.clone());
        }
        self.specs
            .iter()
            .find(|s| s.name == name)
            .and_then(|s| s.default.clone())
    }

    pub fn require(&self, name: &str) -> Result<String, String> {
        self.get(name)
            .ok_or_else(|| format!("missing required --{name}\n\n{}", self.usage()))
    }

    pub fn get_usize(&self, name: &str) -> Result<usize, String> {
        let v = self.require(name)?;
        v.parse().map_err(|e| format!("--{name}={v}: {e}"))
    }

    pub fn get_f64(&self, name: &str) -> Result<f64, String> {
        let v = self.require(name)?;
        v.parse().map_err(|e| format!("--{name}={v}: {e}"))
    }

    /// Parse an option through a custom parser (byte sizes, dtypes, …),
    /// attributing failures to the flag in the error message.
    pub fn get_via<T>(
        &self,
        name: &str,
        parse: impl Fn(&str) -> anyhow::Result<T>,
    ) -> Result<T, String> {
        let v = self.require(name)?;
        parse(&v).map_err(|e| format!("--{name}={v}: {e}"))
    }

    pub fn has(&self, flag: &str) -> bool {
        self.flags.iter().any(|f| f == flag)
    }

    pub fn positional(&self) -> &[String] {
        &self.positional
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(s: &[&str]) -> Vec<String> {
        s.iter().map(|x| x.to_string()).collect()
    }

    fn base() -> Args {
        Args::new("test", "test tool")
            .opt("model", Some("small"), "model shape")
            .opt("steps", None, "step count")
            .flag("verbose", "chatty")
    }

    #[test]
    fn parses_values_flags_positional() {
        let a = base()
            .parse(&argv(&["run", "--model", "base", "--verbose", "--steps=10", "extra"]))
            .unwrap();
        assert_eq!(a.get("model").unwrap(), "base");
        assert_eq!(a.get_usize("steps").unwrap(), 10);
        assert!(a.has("verbose"));
        assert_eq!(a.positional(), &["run".to_string(), "extra".to_string()]);
    }

    #[test]
    fn defaults_apply() {
        let a = base().parse(&argv(&[])).unwrap();
        assert_eq!(a.get("model").unwrap(), "small");
        assert_eq!(a.get("steps"), None);
        assert!(a.require("steps").is_err());
    }

    #[test]
    fn rejects_unknown_and_missing_value() {
        assert!(base().parse(&argv(&["--nope"])).is_err());
        assert!(base().parse(&argv(&["--steps"])).is_err());
        assert!(base().parse(&argv(&["--verbose=1"])).is_err());
    }

    #[test]
    fn rejects_empty_values() {
        let err = base().parse(&argv(&["--model="])).unwrap_err();
        assert!(err.contains("--model requires a non-empty value"), "{err}");
        let err = base().parse(&argv(&["--model", ""])).unwrap_err();
        assert!(err.contains("--model requires a non-empty value"), "{err}");
    }

    #[test]
    fn rejects_duplicate_options_and_flags() {
        let err = base().parse(&argv(&["--model", "base", "--model=tiny"])).unwrap_err();
        assert!(err.contains("--model given more than once"), "{err}");
        let err = base().parse(&argv(&["--verbose", "--verbose"])).unwrap_err();
        assert!(err.contains("--verbose given more than once"), "{err}");
    }

    #[test]
    fn get_via_attributes_parse_errors_to_the_flag() {
        let args = Args::new("test", "t")
            .opt("budget", Some("4k"), "bytes")
            .parse(&argv(&[]))
            .unwrap();
        let ok = args.get_via("budget", crate::peft::parse_bytes).unwrap();
        assert_eq!(ok, 4096);
        let args = Args::new("test", "t")
            .opt("budget", None, "bytes")
            .parse(&argv(&["--budget", "nope"]))
            .unwrap();
        let err = args.get_via("budget", crate::peft::parse_bytes).unwrap_err();
        assert!(err.contains("--budget=nope"), "{err}");
    }

    #[test]
    fn help_is_error_with_usage() {
        let Err(err) = base().parse(&argv(&["--help"])) else {
            panic!("--help should surface usage as Err");
        };
        assert!(err.contains("--model"));
        assert!(err.contains("default: small"));
    }
}
