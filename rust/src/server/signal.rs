//! Async-signal-safe SIGTERM/SIGINT latch for graceful drain.
//!
//! Dependency-free: on unix we call `signal(2)` directly through the C
//! ABI and the handler only stores to an `AtomicBool` (the one thing a
//! signal handler may safely do).  The serve loop polls [`triggered`]
//! and runs the drain itself, outside signal context.  On non-unix
//! targets installation is a no-op and shutdown comes from the
//! management endpoint only.

use std::sync::atomic::{AtomicBool, Ordering};

static TRIGGERED: AtomicBool = AtomicBool::new(false);

#[cfg(unix)]
mod sys {
    pub const SIGINT: i32 = 2;
    pub const SIGTERM: i32 = 15;
    extern "C" {
        pub fn signal(signum: i32, handler: extern "C" fn(i32)) -> usize;
    }
}

#[cfg(unix)]
extern "C" fn on_signal(_signum: i32) {
    TRIGGERED.store(true, Ordering::SeqCst);
}

/// Install the latch for SIGTERM and SIGINT.  Idempotent.
pub fn install() {
    #[cfg(unix)]
    unsafe {
        sys::signal(sys::SIGTERM, on_signal);
        sys::signal(sys::SIGINT, on_signal);
    }
}

/// Has a termination signal arrived since install?
pub fn triggered() -> bool {
    TRIGGERED.load(Ordering::SeqCst)
}
