//! Route table + handlers for both planes (DESIGN.md §15).
//!
//! The data plane exposes exactly `POST /v1/classify` (plus `/healthz`);
//! everything operational — metrics, adapter lifecycle, shutdown — lives
//! on the management plane so a public-facing data listener never
//! carries control authority.
//!
//! Handlers return `Ok(Reply)` for request-level failures (the body was
//! fully consumed, the connection stays usable) and `Err(HttpError)`
//! only when the connection framing is no longer trustworthy.

use std::io::Write;
use std::net::TcpStream;
use std::path::PathBuf;
use std::sync::atomic::Ordering;
use std::sync::mpsc::RecvTimeoutError;
use std::time::Duration;

use crate::coordinator::Request;
use crate::json::{self, Json};
use crate::peft::TaskP;
use crate::tensor::ckpt;

use super::http::{self, HttpError, Reply, RequestHead};
use super::{Plane, ServerInner};

/// Cap for bodies on routes that ignore them (we still must consume the
/// bytes to keep keep-alive framing intact).
const DRAIN_BODY_CAP: usize = 64 * 1024;

pub(crate) fn dispatch(
    inner: &ServerInner,
    head: &RequestHead,
    stream: &mut TcpStream,
    carry: &mut Vec<u8>,
    plane: Plane,
) -> Result<Reply, HttpError> {
    let body_len = head.content_length()?;
    match (plane, head.method.as_str(), head.path.as_str()) {
        (_, "GET", "/healthz") => {
            drain_body(stream, carry, body_len)?;
            Ok(Reply::text(200, "ok\n"))
        }
        (Plane::Data, "POST", "/v1/classify") => classify(inner, head, stream, carry, body_len),
        (Plane::Mgmt, "GET", "/metrics") => {
            drain_body(stream, carry, body_len)?;
            Ok(metrics_reply(inner, head))
        }
        (Plane::Mgmt, "GET", "/mgmt/adapters") => {
            drain_body(stream, carry, body_len)?;
            Ok(list_adapters(inner))
        }
        (Plane::Mgmt, "POST", "/mgmt/adapters") => {
            register_adapter(inner, head, stream, carry, body_len)
        }
        (Plane::Mgmt, "DELETE", "/mgmt/adapters") => {
            drain_body(stream, carry, body_len)?;
            Ok(unregister_adapter(inner, head))
        }
        (Plane::Mgmt, "POST", "/mgmt/adapters/pin") => {
            drain_body(stream, carry, body_len)?;
            Ok(pin_adapter(inner, head))
        }
        (Plane::Mgmt, "POST", "/mgmt/shutdown") => {
            drain_body(stream, carry, body_len)?;
            inner.shutdown_requested.store(true, Ordering::SeqCst);
            let mut doc = Json::obj();
            doc.set("status", Json::Str("draining".into()));
            Ok(Reply::json(200, &doc))
        }
        // Known paths with the wrong method: 405 + `allow`.
        (_, _, "/healthz") => method_not_allowed(stream, carry, body_len, head, "GET"),
        (Plane::Data, _, "/v1/classify") => {
            method_not_allowed(stream, carry, body_len, head, "POST")
        }
        (Plane::Mgmt, _, "/metrics") => method_not_allowed(stream, carry, body_len, head, "GET"),
        (Plane::Mgmt, _, "/mgmt/adapters") => {
            method_not_allowed(stream, carry, body_len, head, "GET, POST, DELETE")
        }
        (Plane::Mgmt, _, "/mgmt/adapters/pin") => {
            method_not_allowed(stream, carry, body_len, head, "POST")
        }
        (Plane::Mgmt, _, "/mgmt/shutdown") => {
            method_not_allowed(stream, carry, body_len, head, "POST")
        }
        _ => {
            drain_body(stream, carry, body_len)?;
            Ok(Reply::error(
                404,
                &format!("no route for {} {}", head.method, head.path),
            ))
        }
    }
}

fn method_not_allowed(
    stream: &mut TcpStream,
    carry: &mut Vec<u8>,
    body_len: usize,
    head: &RequestHead,
    allow: &'static str,
) -> Result<Reply, HttpError> {
    drain_body(stream, carry, body_len)?;
    Ok(
        Reply::error(405, &format!("{} not allowed on {}", head.method, head.path))
            .with_header("allow", allow),
    )
}

/// Consume and discard a request body so the next keep-alive request
/// starts at a frame boundary.
fn drain_body(
    stream: &mut TcpStream,
    carry: &mut Vec<u8>,
    len: usize,
) -> Result<(), HttpError> {
    if len == 0 {
        return Ok(());
    }
    let mut sink = std::io::sink();
    http::read_body_into(stream, carry, len, DRAIN_BODY_CAP, &mut sink)
}

// ---------------------------------------------------------------- data plane

/// In-flight admission token.  Bounds concurrent requests *per server*
/// ahead of the coordinator queue so overload turns into a fast 429
/// instead of a pile of blocked connection threads.
struct InflightGuard<'a> {
    inner: &'a ServerInner,
}

impl<'a> InflightGuard<'a> {
    fn admit(inner: &'a ServerInner) -> Option<InflightGuard<'a>> {
        let limit = inner.cfg.queue_limit;
        inner
            .inflight
            .fetch_update(Ordering::SeqCst, Ordering::SeqCst, |n| {
                (n < limit).then_some(n + 1)
            })
            .ok()
            .map(|_| InflightGuard { inner })
    }
}

impl Drop for InflightGuard<'_> {
    fn drop(&mut self) {
        self.inner.inflight.fetch_sub(1, Ordering::SeqCst);
    }
}

fn classify(
    inner: &ServerInner,
    head: &RequestHead,
    stream: &mut TcpStream,
    carry: &mut Vec<u8>,
    body_len: usize,
) -> Result<Reply, HttpError> {
    let body = http::read_body(stream, carry, body_len, inner.cfg.max_body)?;
    // Body fully consumed — everything below is a request-level reply.
    let text = match std::str::from_utf8(&body) {
        Ok(t) => t,
        Err(_) => return Ok(Reply::error(400, "body is not valid UTF-8")),
    };
    let doc = match json::parse(text) {
        Ok(d) => d,
        Err(e) => return Ok(Reply::error(400, &format!("bad JSON body: {e}"))),
    };
    let request = match Request::from_json(&doc) {
        Ok(r) => r,
        Err(e) => return Ok(Reply::error(400, &e)),
    };
    let deadline = match request_deadline(inner, &doc) {
        Ok(d) => d,
        Err(e) => return Ok(Reply::error(400, &e)),
    };
    let _guard = match InflightGuard::admit(inner) {
        Some(g) => g,
        None => {
            return Ok(Reply::error(
                429,
                &format!("server at capacity ({} requests in flight)", inner.cfg.queue_limit),
            )
            .with_header("retry-after", "1"))
        }
    };
    let rx = match inner.coordinator.submit(request) {
        Ok(rx) => rx,
        Err(e) => return Ok(submit_error_reply(&e.to_string())),
    };
    match rx.recv_timeout(deadline) {
        Ok(Ok(response)) => Ok(Reply::json(200, &response.to_json())),
        Ok(Err(e)) => Ok(submit_error_reply(&e.to_string())),
        Err(RecvTimeoutError::Timeout) => Ok(Reply::error(
            504,
            &format!("deadline exceeded after {}ms", deadline.as_millis()),
        )),
        Err(RecvTimeoutError::Disconnected) => {
            Ok(Reply::error(500, "coordinator dropped the request"))
        }
    }
}

/// Effective deadline: client `timeout_ms`, clamped by the server cap.
fn request_deadline(inner: &ServerInner, doc: &Json) -> Result<Duration, String> {
    let cap = inner.cfg.request_deadline;
    match doc.get("timeout_ms") {
        None => Ok(cap),
        Some(v) => {
            let ms = v
                .as_f64()
                .ok_or_else(|| "timeout_ms must be a number".to_string())?;
            if !ms.is_finite() || ms < 1.0 {
                return Err(format!("timeout_ms must be >= 1, got {ms}"));
            }
            Ok(Duration::from_millis(ms as u64).min(cap))
        }
    }
}

/// Map a coordinator error message onto the HTTP error table
/// (DESIGN.md §15): unknown task → 404, lifecycle refusals → 503 with
/// retry-after, admission/shape rejections → 400, the rest → 500.
fn submit_error_reply(msg: &str) -> Reply {
    if msg.contains("unknown task") {
        Reply::error(404, msg)
    } else if msg.contains("draining") || msg.contains("shut down") || msg.contains("worker exited")
    {
        Reply::error(503, msg).with_header("retry-after", "1")
    } else if msg.contains("length") || msg.contains("empty") || msg.contains("bucket") {
        Reply::error(400, msg)
    } else {
        Reply::error(500, msg)
    }
}

// ---------------------------------------------------------- management plane

fn metrics_reply(inner: &ServerInner, head: &RequestHead) -> Reply {
    let snap = inner.coordinator.metrics().snapshot();
    let wants_json = head.query_param("format") == Some("json")
        || head
            .header("accept")
            .is_some_and(|a| a.contains("application/json"));
    if wants_json {
        Reply::json(200, &snap.to_json())
    } else {
        Reply::text(200, format!("{}\n", snap.render()))
    }
}

fn list_adapters(inner: &ServerInner) -> Reply {
    let registry = inner.coordinator.registry();
    let mut tasks = Json::Arr(Vec::new());
    for info in registry.pstore().task_infos() {
        let mut t = Json::obj();
        t.set("name", Json::Str(info.name.clone()));
        t.set("pinned", Json::Bool(info.pinned));
        t.set("tier", Json::Str(info.tier.to_string()));
        t.set("dtype", Json::Str(info.dtype.to_string()));
        t.set("resident_bytes", Json::Num(info.resident_bytes as f64));
        if let Ok(state) = registry.get(&info.name) {
            t.set("classes", Json::Num(state.classes as f64));
        }
        tasks.push(t);
    }
    let mut doc = Json::obj();
    doc.set("tasks", tasks);
    Reply::json(200, &doc)
}

fn valid_task_name(name: &str) -> bool {
    !name.is_empty()
        && name.len() <= 128
        && name
            .bytes()
            .all(|b| b.is_ascii_alphanumeric() || b == b'.' || b == b'_' || b == b'-')
}

/// Required, validated `?name=` parameter.
fn task_name_param(head: &RequestHead) -> Result<String, String> {
    match head.query_param("name") {
        Some(name) if valid_task_name(name) => Ok(name.to_string()),
        Some(name) => Err(format!(
            "invalid task name {name:?} (want [A-Za-z0-9._-]{{1,128}})"
        )),
        None => Err("missing required query parameter `name`".to_string()),
    }
}

/// Temp file for a streamed `.aotckpt` upload; removed on drop.
struct TempUpload {
    path: PathBuf,
}

impl TempUpload {
    fn new(inner: &ServerInner) -> TempUpload {
        let seq = inner.upload_seq.fetch_add(1, Ordering::SeqCst);
        TempUpload {
            path: std::env::temp_dir().join(format!(
                "aotpt-upload-{}-{seq}.aotckpt",
                std::process::id()
            )),
        }
    }
}

impl Drop for TempUpload {
    fn drop(&mut self) {
        let _ = std::fs::remove_file(&self.path);
    }
}

/// `POST /mgmt/adapters?name=X[&pin=true]` — body is an `.aotckpt`
/// checkpoint holding `p` `[l,V,d]`, `head_w` `[d,c]`, `head_b` `[c]`.
/// Registers (or hot-replaces) the task while serving continues.
fn register_adapter(
    inner: &ServerInner,
    head: &RequestHead,
    stream: &mut TcpStream,
    carry: &mut Vec<u8>,
    body_len: usize,
) -> Result<Reply, HttpError> {
    let name = match task_name_param(head) {
        Ok(name) => name,
        // Bad name: reject without reading the (possibly huge) body; the
        // connection-level error path closes the socket for us.
        Err(msg) => return Err(HttpError::new(400, msg)),
    };
    if body_len == 0 {
        return Ok(Reply::error(400, "empty body; expected an .aotckpt checkpoint"));
    }
    let tmp = TempUpload::new(inner);
    {
        let file = std::fs::File::create(&tmp.path)
            .map_err(|e| HttpError::new(500, format!("cannot stage upload: {e}")))?;
        let mut sink = std::io::BufWriter::new(file);
        http::read_body_into(stream, carry, body_len, inner.cfg.max_upload, &mut sink)?;
        sink.flush()
            .map_err(|e| HttpError::new(500, format!("cannot stage upload: {e}")))?;
    }
    let tensors = match ckpt::load(&tmp.path) {
        Ok(t) => t,
        Err(e) => return Ok(Reply::error(400, &format!("bad checkpoint: {e}"))),
    };
    let (p, head_w, head_b) = match (
        tensors.get("p"),
        tensors.get("head_w"),
        tensors.get("head_b"),
    ) {
        (Some(p), Some(w), Some(b)) => (p, w, b),
        _ => {
            return Ok(Reply::error(
                400,
                "checkpoint must contain tensors `p`, `head_w` and `head_b`",
            ))
        }
    };
    let registry = inner.coordinator.registry();
    let task_p = match TaskP::from_tensor(
        registry.layers(),
        registry.vocab(),
        registry.d_model(),
        p,
    ) {
        Ok(t) => t,
        Err(e) => return Ok(Reply::error(400, &format!("bad `p` tensor: {e}"))),
    };
    let replaced = registry.get(&name).is_ok();
    let classes = head_b.len();
    if let Err(e) = registry.register_fused(&name, task_p, head_w, head_b) {
        return Ok(Reply::error(400, &e.to_string()));
    }
    let pin = matches!(head.query_param("pin"), Some("true") | Some("1") | Some("on"));
    if pin {
        if let Err(e) = registry.pin_task(&name, true) {
            return Ok(Reply::error(500, &format!("registered but pin failed: {e}")));
        }
    }
    let mut doc = Json::obj();
    doc.set("task", Json::Str(name));
    doc.set("classes", Json::Num(classes as f64));
    doc.set("pinned", Json::Bool(pin));
    doc.set("replaced", Json::Bool(replaced));
    Ok(Reply::json(200, &doc))
}

fn unregister_adapter(inner: &ServerInner, head: &RequestHead) -> Reply {
    let name = match task_name_param(head) {
        Ok(name) => name,
        Err(msg) => return Reply::error(400, &msg),
    };
    match inner.coordinator.registry().unregister(&name) {
        Ok(()) => {
            let mut doc = Json::obj();
            doc.set("unregistered", Json::Str(name));
            Reply::json(200, &doc)
        }
        Err(e) => Reply::error(404, &e.to_string()),
    }
}

/// `POST /mgmt/adapters/pin?name=X[&state=on|off]` (default `on`).
fn pin_adapter(inner: &ServerInner, head: &RequestHead) -> Reply {
    let name = match task_name_param(head) {
        Ok(name) => name,
        Err(msg) => return Reply::error(400, &msg),
    };
    let state = match head.query_param("state").unwrap_or("on") {
        "on" | "true" | "1" => true,
        "off" | "false" | "0" => false,
        other => {
            return Reply::error(400, &format!("bad pin state {other:?} (want on|off)"));
        }
    };
    match inner.coordinator.registry().pin_task(&name, state) {
        Ok(()) => {
            let mut doc = Json::obj();
            doc.set("task", Json::Str(name));
            doc.set("pinned", Json::Bool(state));
            Reply::json(200, &doc)
        }
        Err(e) => Reply::error(404, &e.to_string()),
    }
}
