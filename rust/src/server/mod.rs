//! Dependency-free HTTP/1.1 serving front end (DESIGN.md §15).
//!
//! Two planes, two listeners:
//!
//! * **data** (`--addr`) — `POST /v1/classify` + `/healthz`.  Admission
//!   is bounded (`queue_limit` in-flight → fast 429) and every request
//!   carries a deadline (client `timeout_ms` clamped by the server cap →
//!   504 past it).
//! * **management** (`--mgmt-addr`, optional) — `/metrics`,
//!   `/mgmt/adapters` (list / streamed `.aotckpt` register / unregister
//!   / pin) and `/mgmt/shutdown`.  A separate listener means the public
//!   data port never carries control authority.
//!
//! Threading: one nonblocking accept thread per plane (10ms sleep-poll,
//! so stopping is just a flag), one thread per connection with read and
//! write timeouts (slow-loris defense), keep-alive with a carry buffer.
//!
//! Graceful drain ([`Server::drain`]): refuse new connections, join the
//! accept threads, [`Coordinator::drain`] the admitted backlog (every
//! queued request is answered), then join the connection threads — which
//! exit promptly because responses during drain set `connection: close`.

pub mod http;
mod routes;
pub mod signal;

use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::Duration;

use anyhow::Context;

use crate::coordinator::{Coordinator, MetricsSnapshot};
use crate::Result;

use http::{write_reply, Reply};

/// Which listener a connection arrived on.  Routing is plane-scoped:
/// data routes 404 on the management port and vice versa.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub(crate) enum Plane {
    Data,
    Mgmt,
}

#[derive(Clone, Debug)]
pub struct ServerConfig {
    /// Data-plane bind address (`host:port`; port 0 picks one).
    pub addr: String,
    /// Management-plane bind address; `None` disables the plane.
    pub mgmt_addr: Option<String>,
    /// Server-side cap on the per-request deadline.
    pub request_deadline: Duration,
    /// Max classify requests in flight before 429.
    pub queue_limit: usize,
    /// Per-connection read/write timeout.
    pub io_timeout: Duration,
    /// Max concurrent connections per server before refusing with 503.
    pub max_conns: usize,
    /// Max JSON body size on the data plane.
    pub max_body: usize,
    /// Max `.aotckpt` upload size on the management plane.
    pub max_upload: usize,
}

impl Default for ServerConfig {
    fn default() -> ServerConfig {
        ServerConfig {
            addr: "127.0.0.1:0".to_string(),
            mgmt_addr: None,
            request_deadline: Duration::from_secs(30),
            queue_limit: 256,
            io_timeout: Duration::from_secs(10),
            max_conns: 256,
            max_body: 1 << 20,
            max_upload: 1 << 30,
        }
    }
}

/// State shared by accept loops, connection threads and route handlers.
pub(crate) struct ServerInner {
    pub(crate) coordinator: Arc<Coordinator>,
    pub(crate) cfg: ServerConfig,
    pub(crate) draining: AtomicBool,
    pub(crate) shutdown_requested: AtomicBool,
    pub(crate) inflight: AtomicUsize,
    pub(crate) conns: AtomicUsize,
    pub(crate) upload_seq: AtomicUsize,
}

pub struct Server {
    inner: Arc<ServerInner>,
    data_addr: SocketAddr,
    mgmt_addr: Option<SocketAddr>,
    stop_accept: Arc<AtomicBool>,
    accept_handles: Vec<JoinHandle<()>>,
    conn_handles: Arc<Mutex<Vec<JoinHandle<()>>>>,
}

impl Server {
    /// Bind both planes and start accepting.
    pub fn bind(coordinator: Arc<Coordinator>, cfg: ServerConfig) -> Result<Server> {
        let data_listener = TcpListener::bind(&cfg.addr)
            .with_context(|| format!("binding data plane on {}", cfg.addr))?;
        data_listener.set_nonblocking(true)?;
        let data_addr = data_listener.local_addr()?;
        let mgmt_listener = match &cfg.mgmt_addr {
            Some(addr) => {
                let l = TcpListener::bind(addr)
                    .with_context(|| format!("binding management plane on {addr}"))?;
                l.set_nonblocking(true)?;
                Some(l)
            }
            None => None,
        };
        let mgmt_addr = match &mgmt_listener {
            Some(l) => Some(l.local_addr()?),
            None => None,
        };

        let inner = Arc::new(ServerInner {
            coordinator,
            cfg,
            draining: AtomicBool::new(false),
            shutdown_requested: AtomicBool::new(false),
            inflight: AtomicUsize::new(0),
            conns: AtomicUsize::new(0),
            upload_seq: AtomicUsize::new(0),
        });
        let stop_accept = Arc::new(AtomicBool::new(false));
        let conn_handles = Arc::new(Mutex::new(Vec::new()));

        let mut accept_handles = Vec::new();
        let planes = std::iter::once((data_listener, Plane::Data, "aotpt-accept-data"))
            .chain(mgmt_listener.map(|l| (l, Plane::Mgmt, "aotpt-accept-mgmt")));
        for (listener, plane, name) in planes {
            let inner = Arc::clone(&inner);
            let stop = Arc::clone(&stop_accept);
            let handles = Arc::clone(&conn_handles);
            accept_handles.push(
                std::thread::Builder::new()
                    .name(name.to_string())
                    .spawn(move || accept_loop(listener, inner, stop, plane, handles))?,
            );
        }

        Ok(Server {
            inner,
            data_addr,
            mgmt_addr,
            stop_accept,
            accept_handles,
            conn_handles,
        })
    }

    pub fn data_addr(&self) -> SocketAddr {
        self.data_addr
    }

    pub fn mgmt_addr(&self) -> Option<SocketAddr> {
        self.mgmt_addr
    }

    pub fn coordinator(&self) -> &Arc<Coordinator> {
        &self.inner.coordinator
    }

    /// Has `POST /mgmt/shutdown` been received?
    pub fn shutdown_requested(&self) -> bool {
        self.inner.shutdown_requested.load(Ordering::SeqCst)
    }

    /// Graceful drain: stop accepting, flush every admitted request,
    /// join all threads.  Returns the final metrics snapshot — the
    /// queue-depth gauge must read 0 in it.
    pub fn drain(mut self) -> MetricsSnapshot {
        self.inner.draining.store(true, Ordering::SeqCst);
        self.stop_accept.store(true, Ordering::SeqCst);
        for handle in self.accept_handles.drain(..) {
            let _ = handle.join();
        }
        // Answer everything already admitted; new submits get 503.
        self.inner.coordinator.drain();
        let handles: Vec<JoinHandle<()>> = {
            let mut guard = self.conn_handles.lock().unwrap();
            guard.drain(..).collect()
        };
        for handle in handles {
            let _ = handle.join();
        }
        self.inner.coordinator.metrics().snapshot()
    }
}

/// Decrements the live-connection gauge when a connection thread exits,
/// panic or not.
struct ConnGuard(Arc<ServerInner>);

impl Drop for ConnGuard {
    fn drop(&mut self) {
        self.0.conns.fetch_sub(1, Ordering::SeqCst);
    }
}

fn accept_loop(
    listener: TcpListener,
    inner: Arc<ServerInner>,
    stop: Arc<AtomicBool>,
    plane: Plane,
    conn_handles: Arc<Mutex<Vec<JoinHandle<()>>>>,
) {
    loop {
        if stop.load(Ordering::SeqCst) {
            return;
        }
        match listener.accept() {
            Ok((stream, _peer)) => {
                if inner.draining.load(Ordering::SeqCst) {
                    refuse(stream, "server is draining");
                    continue;
                }
                if inner.conns.load(Ordering::SeqCst) >= inner.cfg.max_conns {
                    refuse(stream, "too many connections");
                    continue;
                }
                inner.conns.fetch_add(1, Ordering::SeqCst);
                let conn_inner = Arc::clone(&inner);
                let spawned = std::thread::Builder::new()
                    .name("aotpt-conn".to_string())
                    .spawn(move || {
                        let _guard = ConnGuard(Arc::clone(&conn_inner));
                        serve_conn(stream, conn_inner, plane);
                    });
                match spawned {
                    Ok(handle) => {
                        let mut handles = conn_handles.lock().unwrap();
                        handles.retain(|h| !h.is_finished());
                        handles.push(handle);
                    }
                    Err(_) => {
                        inner.conns.fetch_sub(1, Ordering::SeqCst);
                    }
                }
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                std::thread::sleep(Duration::from_millis(10));
            }
            Err(_) => std::thread::sleep(Duration::from_millis(10)),
        }
    }
}

/// Turn away a connection before it gets a thread.
fn refuse(mut stream: TcpStream, msg: &str) {
    let _ = stream.set_nonblocking(false);
    let _ = stream.set_write_timeout(Some(Duration::from_secs(1)));
    let reply = Reply::error(503, msg).with_header("retry-after", "1");
    let _ = write_reply(&mut stream, &reply, true);
}

fn serve_conn(mut stream: TcpStream, inner: Arc<ServerInner>, plane: Plane) {
    // Sockets accepted from a nonblocking listener inherit nonblocking
    // mode on some platforms; force blocking-with-timeouts semantics.
    if stream.set_nonblocking(false).is_err() {
        return;
    }
    let _ = stream.set_read_timeout(Some(inner.cfg.io_timeout));
    let _ = stream.set_write_timeout(Some(inner.cfg.io_timeout));
    let _ = stream.set_nodelay(true);
    let mut carry: Vec<u8> = Vec::new();
    loop {
        let head = match http::read_head(&mut stream, &mut carry) {
            Ok(Some(head)) => head,
            Ok(None) => return,
            Err(err) => {
                let _ = write_reply(&mut stream, &Reply::error(err.status, &err.message), true);
                return;
            }
        };
        let close = head.wants_close() || inner.draining.load(Ordering::SeqCst);
        match routes::dispatch(&inner, &head, &mut stream, &mut carry, plane) {
            Ok(reply) => {
                if write_reply(&mut stream, &reply, close).is_err() || close {
                    return;
                }
            }
            Err(err) => {
                // Framing is unknown (body unread / head truncated):
                // answer and close.
                let _ = write_reply(&mut stream, &Reply::error(err.status, &err.message), true);
                return;
            }
        }
    }
}
