//! Minimal dependency-free HTTP/1.1 request/response support
//! (DESIGN.md §15).  Scope is deliberately small: request-head parsing
//! with hard size caps, exact `Content-Length` bodies (no chunked
//! encoding), keep-alive with a shared carry buffer, and length-framed
//! responses.  Everything beyond that is the routing layer's problem.
//!
//! Error model: an [`HttpError`] is a *connection-level* failure — the
//! stream may be out of sync with the request framing (unread body,
//! truncated head), so the connection loop answers it and closes.
//! Request-level failures on an in-sync connection come back as ordinary
//! [`Reply`] values and keep the connection usable.

use std::io::{ErrorKind, Read, Write};
use std::net::TcpStream;

use crate::json::Json;

/// Hard cap on the request head (request line + all headers).  Covers
/// both the oversized-header and the endless-request-line attack.
pub const MAX_HEAD_BYTES: usize = 16 * 1024;
/// Hard cap on the header count.
pub const MAX_HEADERS: usize = 64;

/// A connection-level error: one HTTP status + client-facing message.
#[derive(Debug)]
pub struct HttpError {
    pub status: u16,
    pub message: String,
}

impl HttpError {
    pub fn new(status: u16, message: impl Into<String>) -> HttpError {
        HttpError { status, message: message.into() }
    }
}

/// A parsed request head (the body, if any, is still on the wire).
#[derive(Debug)]
pub struct RequestHead {
    pub method: String,
    pub path: String,
    /// Decoded query parameters, in order of appearance.
    pub query: Vec<(String, String)>,
    /// Header names lowercased, values trimmed.
    pub headers: Vec<(String, String)>,
}

impl RequestHead {
    pub fn header(&self, name: &str) -> Option<&str> {
        self.headers.iter().find(|(k, _)| k == name).map(|(_, v)| v.as_str())
    }

    pub fn query_param(&self, name: &str) -> Option<&str> {
        self.query.iter().find(|(k, _)| k == name).map(|(_, v)| v.as_str())
    }

    /// Declared body length (`Content-Length`, default 0).  Chunked
    /// transfer encoding is out of scope.
    pub fn content_length(&self) -> Result<usize, HttpError> {
        if self.header("transfer-encoding").is_some() {
            return Err(HttpError::new(501, "chunked transfer encoding is not supported"));
        }
        match self.header("content-length") {
            None => Ok(0),
            Some(v) => v
                .parse()
                .map_err(|_| HttpError::new(400, format!("bad content-length {v:?}"))),
        }
    }

    pub fn wants_close(&self) -> bool {
        self.header("connection").is_some_and(|v| v.eq_ignore_ascii_case("close"))
    }
}

/// Read one request head from the connection.  `carry` holds bytes read
/// past the previous request's framing (keep-alive pipelining); leftover
/// bytes after the head (the body's prefix) stay in it.
///
/// Returns `Ok(None)` on a clean close between requests — including an
/// idle keep-alive connection hitting the read timeout with nothing
/// buffered.  A timeout *mid-head* is the slow-loris case and comes back
/// as 408.
pub fn read_head(
    stream: &mut TcpStream,
    carry: &mut Vec<u8>,
) -> Result<Option<RequestHead>, HttpError> {
    loop {
        if let Some((end, term)) = find_head_end(carry) {
            let head = parse_head(&carry[..end])?;
            carry.drain(..end + term);
            return Ok(Some(head));
        }
        if carry.len() > MAX_HEAD_BYTES {
            return Err(HttpError::new(
                431,
                format!("request head exceeds {MAX_HEAD_BYTES} bytes"),
            ));
        }
        let mut tmp = [0u8; 4096];
        match stream.read(&mut tmp) {
            Ok(0) => {
                return if carry.iter().all(|b| b.is_ascii_whitespace()) {
                    Ok(None)
                } else {
                    Err(HttpError::new(400, "connection closed mid-request-head"))
                };
            }
            Ok(n) => carry.extend_from_slice(&tmp[..n]),
            Err(e) if e.kind() == ErrorKind::WouldBlock || e.kind() == ErrorKind::TimedOut => {
                return if carry.is_empty() {
                    Ok(None)
                } else {
                    Err(HttpError::new(408, "timed out reading request head"))
                };
            }
            Err(e) if e.kind() == ErrorKind::Interrupted => {}
            Err(e) => return Err(HttpError::new(400, format!("read error: {e}"))),
        }
    }
}

/// Position and length of the head terminator (`\r\n\r\n` or `\n\n`),
/// whichever comes first.
fn find_head_end(buf: &[u8]) -> Option<(usize, usize)> {
    let crlf = buf.windows(4).position(|w| w == b"\r\n\r\n").map(|p| (p, 4));
    let lf = buf.windows(2).position(|w| w == b"\n\n").map(|p| (p, 2));
    match (crlf, lf) {
        (Some(a), Some(b)) => Some(if a.0 <= b.0 { a } else { b }),
        (a, b) => a.or(b),
    }
}

fn parse_head(raw: &[u8]) -> Result<RequestHead, HttpError> {
    let text = std::str::from_utf8(raw)
        .map_err(|_| HttpError::new(400, "request head is not valid UTF-8"))?;
    let mut lines = text.split('\n').map(|l| l.strip_suffix('\r').unwrap_or(l));
    let request_line = lines.next().unwrap_or("");
    let mut parts = request_line.split_ascii_whitespace();
    let (method, target, version) = match (parts.next(), parts.next(), parts.next(), parts.next())
    {
        (Some(m), Some(t), Some(v), None) => (m, t, v),
        _ => {
            return Err(HttpError::new(
                400,
                format!("malformed request line {request_line:?}"),
            ))
        }
    };
    if !method.bytes().all(|b| b.is_ascii_uppercase()) {
        return Err(HttpError::new(400, format!("malformed method {method:?}")));
    }
    if !version.starts_with("HTTP/1.") {
        return Err(HttpError::new(505, format!("unsupported protocol version {version}")));
    }
    if !target.starts_with('/') {
        return Err(HttpError::new(400, format!("malformed request target {target:?}")));
    }
    let (path, query_str) = match target.split_once('?') {
        Some((p, q)) => (p, q),
        None => (target, ""),
    };
    let mut query = Vec::new();
    for pair in query_str.split('&').filter(|s| !s.is_empty()) {
        let (k, v) = pair.split_once('=').unwrap_or((pair, ""));
        query.push((percent_decode(k)?, percent_decode(v)?));
    }
    let mut headers = Vec::new();
    for line in lines {
        if line.is_empty() {
            continue;
        }
        if headers.len() >= MAX_HEADERS {
            return Err(HttpError::new(431, format!("more than {MAX_HEADERS} headers")));
        }
        let (name, value) = line
            .split_once(':')
            .ok_or_else(|| HttpError::new(400, format!("malformed header line {line:?}")))?;
        headers.push((name.trim().to_ascii_lowercase(), value.trim().to_string()));
    }
    Ok(RequestHead {
        method: method.to_string(),
        path: path.to_string(),
        query,
        headers,
    })
}

fn percent_decode(s: &str) -> Result<String, HttpError> {
    let bytes = s.as_bytes();
    let mut out = Vec::with_capacity(bytes.len());
    let mut i = 0;
    while i < bytes.len() {
        match bytes[i] {
            b'%' => {
                let byte = bytes
                    .get(i + 1..i + 3)
                    .and_then(|h| std::str::from_utf8(h).ok())
                    .and_then(|h| u8::from_str_radix(h, 16).ok())
                    .ok_or_else(|| {
                        HttpError::new(400, format!("bad percent escape in {s:?}"))
                    })?;
                out.push(byte);
                i += 3;
            }
            b'+' => {
                out.push(b' ');
                i += 1;
            }
            b => {
                out.push(b);
                i += 1;
            }
        }
    }
    String::from_utf8(out)
        .map_err(|_| HttpError::new(400, format!("query value is not UTF-8 after decoding: {s:?}")))
}

/// Read exactly `len` body bytes — the carry buffer first, then the
/// stream — into `sink`.  `cap` bounds admission; the upload route
/// streams to a file under a much larger cap than the JSON data plane.
pub fn read_body_into(
    stream: &mut TcpStream,
    carry: &mut Vec<u8>,
    len: usize,
    cap: usize,
    sink: &mut dyn Write,
) -> Result<(), HttpError> {
    if len > cap {
        return Err(HttpError::new(
            413,
            format!("body of {len} bytes exceeds the {cap}-byte limit"),
        ));
    }
    let take = len.min(carry.len());
    sink.write_all(&carry[..take]).map_err(sink_error)?;
    carry.drain(..take);
    let mut remaining = len - take;
    let mut tmp = [0u8; 16 * 1024];
    while remaining > 0 {
        let want = remaining.min(tmp.len());
        match stream.read(&mut tmp[..want]) {
            Ok(0) => {
                return Err(HttpError::new(
                    400,
                    format!("truncated body: {remaining} of {len} bytes missing"),
                ))
            }
            Ok(n) => {
                sink.write_all(&tmp[..n]).map_err(sink_error)?;
                remaining -= n;
            }
            Err(e) if e.kind() == ErrorKind::WouldBlock || e.kind() == ErrorKind::TimedOut => {
                return Err(HttpError::new(408, "timed out reading request body"));
            }
            Err(e) if e.kind() == ErrorKind::Interrupted => {}
            Err(e) => return Err(HttpError::new(400, format!("read error: {e}"))),
        }
    }
    Ok(())
}

fn sink_error(e: std::io::Error) -> HttpError {
    HttpError::new(500, format!("failed to store request body: {e}"))
}

/// `read_body_into` buffered into RAM (the JSON data plane).
pub fn read_body(
    stream: &mut TcpStream,
    carry: &mut Vec<u8>,
    len: usize,
    cap: usize,
) -> Result<Vec<u8>, HttpError> {
    let mut body = Vec::with_capacity(len.min(1 << 20));
    read_body_into(stream, carry, len, cap, &mut body)?;
    Ok(body)
}

/// A routed response.  Always written with `Content-Length`, so the
/// connection framing survives for keep-alive.
#[derive(Debug)]
pub struct Reply {
    pub status: u16,
    pub content_type: &'static str,
    pub headers: Vec<(&'static str, String)>,
    pub body: Vec<u8>,
}

impl Reply {
    pub fn json(status: u16, doc: &Json) -> Reply {
        let mut body = doc.to_string_compact().into_bytes();
        body.push(b'\n');
        Reply {
            status,
            content_type: "application/json",
            headers: Vec::new(),
            body,
        }
    }

    pub fn text(status: u16, body: impl Into<String>) -> Reply {
        Reply {
            status,
            content_type: "text/plain; charset=utf-8",
            headers: Vec::new(),
            body: body.into().into_bytes(),
        }
    }

    /// `{"error": message}` — every error body has this shape.
    pub fn error(status: u16, message: &str) -> Reply {
        let mut doc = Json::obj();
        doc.set("error", Json::Str(message.to_string()));
        Reply::json(status, &doc)
    }

    pub fn with_header(mut self, name: &'static str, value: impl Into<String>) -> Reply {
        self.headers.push((name, value.into()));
        self
    }
}

pub fn write_reply(stream: &mut TcpStream, reply: &Reply, close: bool) -> std::io::Result<()> {
    use std::fmt::Write as _;
    let mut head = String::with_capacity(256);
    let _ = write!(
        head,
        "HTTP/1.1 {} {}\r\ncontent-type: {}\r\ncontent-length: {}\r\n",
        reply.status,
        status_reason(reply.status),
        reply.content_type,
        reply.body.len()
    );
    for (k, v) in &reply.headers {
        let _ = write!(head, "{k}: {v}\r\n");
    }
    if close {
        head.push_str("connection: close\r\n");
    }
    head.push_str("\r\n");
    stream.write_all(head.as_bytes())?;
    stream.write_all(&reply.body)?;
    stream.flush()
}

pub fn status_reason(status: u16) -> &'static str {
    match status {
        200 => "OK",
        400 => "Bad Request",
        404 => "Not Found",
        405 => "Method Not Allowed",
        408 => "Request Timeout",
        413 => "Payload Too Large",
        429 => "Too Many Requests",
        431 => "Request Header Fields Too Large",
        500 => "Internal Server Error",
        501 => "Not Implemented",
        503 => "Service Unavailable",
        504 => "Gateway Timeout",
        505 => "HTTP Version Not Supported",
        _ => "Response",
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_request_line_query_and_headers() {
        let head = parse_head(
            b"POST /v1/classify?name=a%20b&pin=true HTTP/1.1\r\n\
              Host: localhost\r\n\
              Content-Length: 12\r\n\
              Connection: Close\r\n",
        )
        .unwrap();
        assert_eq!(head.method, "POST");
        assert_eq!(head.path, "/v1/classify");
        assert_eq!(head.query_param("name"), Some("a b"));
        assert_eq!(head.query_param("pin"), Some("true"));
        assert_eq!(head.header("host"), Some("localhost"));
        assert_eq!(head.content_length().unwrap(), 12);
        assert!(head.wants_close());
    }

    #[test]
    fn rejects_malformed_heads() {
        for (raw, status) in [
            (&b"GARBAGE\r\n"[..], 400),
            (&b"GET /x HTTP/1.1 EXTRA\r\n"[..], 400),
            (&b"get /x HTTP/1.1\r\n"[..], 400),
            (&b"GET x HTTP/1.1\r\n"[..], 400),
            (&b"GET /x SPDY/3\r\n"[..], 505),
            (&b"GET /x HTTP/1.1\r\nno-colon-here\r\n"[..], 400),
        ] {
            let err = parse_head(raw).unwrap_err();
            assert_eq!(err.status, status, "{raw:?}: {}", err.message);
        }
    }

    #[test]
    fn rejects_bad_content_length_and_chunked() {
        let head = parse_head(b"POST /x HTTP/1.1\r\nContent-Length: nope\r\n").unwrap();
        assert_eq!(head.content_length().unwrap_err().status, 400);
        let head = parse_head(b"POST /x HTTP/1.1\r\nTransfer-Encoding: chunked\r\n").unwrap();
        assert_eq!(head.content_length().unwrap_err().status, 501);
    }

    #[test]
    fn caps_header_count() {
        let mut raw = b"GET /x HTTP/1.1\r\n".to_vec();
        for i in 0..(MAX_HEADERS + 1) {
            raw.extend_from_slice(format!("x-h{i}: v\r\n").as_bytes());
        }
        assert_eq!(parse_head(&raw).unwrap_err().status, 431);
    }

    #[test]
    fn finds_both_terminators() {
        assert_eq!(find_head_end(b"GET / HTTP/1.1\r\n\r\nrest"), Some((14, 4)));
        assert_eq!(find_head_end(b"GET / HTTP/1.1\n\nrest"), Some((14, 2)));
        assert_eq!(find_head_end(b"GET / HTTP/1.1\r\n"), None);
    }

    #[test]
    fn percent_decoding() {
        assert_eq!(percent_decode("a%2Fb+c").unwrap(), "a/b c");
        assert!(percent_decode("%zz").is_err());
        assert!(percent_decode("%2").is_err());
    }
}
