//! Analytic performance models for the backbone shapes: per-method FLOPs
//! (used to sanity-check the measured Figure 3 overheads and to fill grid
//! cells that are too slow to time on one CPU core) and the TPU VMEM/MXU
//! roofline estimates for the Pallas kernels (DESIGN.md §9, L1).

pub mod roofline;

use crate::config::ModelInfo;

/// FLOPs of one dense forward pass (multiply-accumulate = 2 FLOPs).
///
/// Per token, per layer: QKVO projections `4·2·d²`, attention scores +
/// weighted sum `2·2·n·d`, FFN `2·2·d·ff`.  Embedding lookups are free;
/// the classification head is negligible.
pub fn forward_flops(m: &ModelInfo, batch: usize, seq: usize) -> f64 {
    flops_with_seq(m, batch, seq, seq)
}

fn flops_with_seq(m: &ModelInfo, batch: usize, seq_q: usize, seq_kv: usize) -> f64 {
    let d = m.d_model as f64;
    let ff = m.d_ff as f64;
    let l = m.n_layers as f64;
    let b = batch as f64;
    let nq = seq_q as f64;
    let nk = seq_kv as f64;
    let proj = 4.0 * 2.0 * nq * d * d;
    let attn = 2.0 * 2.0 * nq * nk * d;
    let ffn = 2.0 * 2.0 * nq * d * ff;
    b * l * (proj + attn + ffn)
}

/// Analytic per-method inference FLOPs, mirroring the causes of overhead
/// the paper names in §4.4:
/// * pt1/pt2 lengthen the (key) sequence by `prefix`;
/// * unfused LoRA adds 4 low-rank matmul pairs per layer;
/// * Adapters add 2 bottleneck MLPs per layer;
/// * AoT (fused) and BitFit add only vector adds — `O(n·d)`;
/// * AoT unfused recomputes P rows through the FC reparametrization.
pub fn method_flops(
    m: &ModelInfo,
    method: &str,
    batch: usize,
    seq: usize,
    rank: usize,
    prefix: usize,
) -> f64 {
    let d = m.d_model as f64;
    let l = m.n_layers as f64;
    let b = batch as f64;
    let n = seq as f64;
    let r = rank as f64;
    let base = forward_flops(m, batch, seq);
    match method {
        "fine-tune" | "lora-fused" => base,
        "bitfit" => base + b * l * n * d * 6.0, // per-element bias adds
        "aot" => base + b * l * n * d,          // ONE add per layer (Eq. 1)
        "aot-unfused" => {
            // gelu(E[ids]·W1 + b1)·W2 + b2 per layer: two [n,d]x[d,r] matmuls
            base + b * l * (2.0 * n * d * r * 2.0) + b * l * n * d
        }
        "lora" => base + b * l * 4.0 * (2.0 * n * d * r) * 2.0,
        "adapters" => base + b * l * 2.0 * (2.0 * n * d * r) * 2.0,
        "pt1" => flops_with_seq(m, batch, seq + prefix, seq + prefix),
        "pt2" => {
            // queries stay n, keys/values grow by prefix in every layer
            let extra_attn = 2.0 * 2.0 * n * (prefix as f64) * d;
            base + b * l * extra_attn
        }
        _ => base,
    }
}

/// Predicted Figure-3 ratio (method time / fine-tune time) from the FLOPs
/// model alone.  Measured ratios should land within ~±10% of this for
/// compute-bound cells.
pub fn predicted_overhead(
    m: &ModelInfo,
    method: &str,
    batch: usize,
    seq: usize,
    rank: usize,
    prefix: usize,
) -> f64 {
    method_flops(m, method, batch, seq, rank, prefix) / forward_flops(m, batch, seq)
}

/// Host-RAM bytes of one task's fused P (paper §3.3: "roughly 2.4 GB" for
/// RoBERTa-Large at half precision; we store f32).
pub fn fused_p_bytes(m: &ModelInfo) -> usize {
    m.n_layers * m.vocab_size * m.d_model * 4
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ModelInfo;

    fn small() -> ModelInfo {
        ModelInfo {
            name: "small".into(),
            d_model: 128,
            n_layers: 4,
            n_heads: 4,
            d_ff: 512,
            vocab_size: 8192,
            max_positions: 512,
            params: 1_800_000,
            kron_a: 91,
            kron_b: 91,
        }
    }

    #[test]
    fn flops_scale_linearly_in_batch() {
        let m = small();
        let f1 = forward_flops(&m, 1, 64);
        let f4 = forward_flops(&m, 4, 64);
        assert!((f4 / f1 - 4.0).abs() < 1e-9);
    }

    #[test]
    fn paper_ordering_of_overheads() {
        // The qualitative Figure 3 ordering must hold analytically:
        // aot ≈ bitfit ≈ 1 < pt2 < lora, and pt1 > 1.
        let m = small();
        let ov = |method: &str| predicted_overhead(&m, method, 16, 128, 16, 20);
        assert!(ov("aot") < 1.01);
        assert!(ov("bitfit") < 1.02);
        assert!(ov("pt2") > 1.01);
        assert!(ov("pt1") > ov("pt2") * 0.99); // pt1 also lengthens queries
        assert!(ov("lora") > ov("aot"));
        assert!(ov("adapters") > ov("aot"));
        assert!(ov("aot-unfused") > ov("aot"));
    }

    #[test]
    fn fused_p_ram_matches_paper_scale() {
        // RoBERTa-Large analog check: |V|·d·l·4 bytes.
        let m = small();
        assert_eq!(fused_p_bytes(&m), 8192 * 128 * 4 * 4);
    }
}
