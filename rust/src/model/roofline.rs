//! TPU roofline estimates for the L1 kernels (mirrors the analytic models
//! in `python/compile/kernels/*.py`; interpret-mode wallclock is not a TPU
//! proxy, so structure is what we optimize and report).

/// VMEM budget of one TPU core (v4-class).
pub const VMEM_BYTES: usize = 16 * 1024 * 1024;
/// MXU systolic array dimension.
pub const MXU: usize = 128;
/// Assumed HBM bandwidth (bytes/s) for roofline ratios (v4-class, ~1.2 TB/s).
pub const HBM_BPS: f64 = 1.2e12;
/// Assumed peak bf16 MACs/s of one core (~275 TFLOP/s => 137e12 MACs).
pub const PEAK_MACS: f64 = 137.5e12;

/// VMEM footprint of one aot_bias program instance (f32).
pub fn aot_bias_vmem(block_n: usize, d: usize) -> usize {
    block_n * 4 + 2 * block_n * d * 4 + 2 * d * 4
}

/// VMEM footprint of one attention program instance (f32).
pub fn attention_vmem(block_q: usize, block_k: usize, dh: usize) -> usize {
    4 * (2 * block_q * dh + 2 * block_k * dh + block_k + block_q * dh + 2 * block_q)
}

/// Fraction of MXU issue slots doing useful MACs for the attention tiles.
pub fn attention_mxu_utilization(block_q: usize, block_k: usize, dh: usize) -> f64 {
    let round = |x: usize| x.div_ceil(MXU) * MXU;
    (block_q as f64 / round(block_q) as f64)
        * (block_k as f64 / round(block_k) as f64)
        * (dh as f64 / round(dh) as f64)
}

/// Seconds the aot_bias gather+add costs at the HBM roofline: it moves
/// 3·n·d·4 bytes (H in, P rows in, H' out) and does n·d adds.
pub fn aot_bias_roofline_secs(batch: usize, seq: usize, d: usize, layers: usize) -> f64 {
    let bytes = 3.0 * (batch * seq * d * layers) as f64 * 4.0;
    bytes / HBM_BPS
}

/// Seconds of one forward pass at the MXU roofline (for the overhead ratio).
pub fn forward_roofline_secs(flops: f64) -> f64 {
    (flops / 2.0) / PEAK_MACS
}

/// The paper's Figure-3 claim, restated as a roofline ratio: the AoT bias
/// add must be a vanishing fraction of the forward pass.
pub fn aot_overhead_ratio(
    m: &crate::config::ModelInfo,
    batch: usize,
    seq: usize,
) -> f64 {
    let fwd = forward_roofline_secs(crate::model::forward_flops(m, batch, seq));
    let bias = aot_bias_roofline_secs(batch, seq, m.d_model, m.n_layers);
    bias / fwd
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_blocks_fit_vmem() {
        assert!(aot_bias_vmem(128, 1024) < VMEM_BYTES);
        assert!(attention_vmem(128, 128, 128) < VMEM_BYTES);
    }

    #[test]
    fn utilization_bounds() {
        let u = attention_mxu_utilization(128, 128, 64);
        assert!(u > 0.0 && u <= 1.0);
        assert_eq!(attention_mxu_utilization(128, 128, 128), 1.0);
    }

    #[test]
    fn aot_bias_is_negligible_at_paper_scale() {
        // The REAL DeBERTa-XL geometry (d=1024, l=48): even the WORST case
        // (bias stream fully serialized against a forward running at 100%
        // MXU peak) bounds the overhead at ~11%; the measured Figure 3
        // number is ~0 because the add overlaps with compute and real
        // forwards run well under peak.  This bounds the claim analytically.
        let xl = crate::config::ModelInfo {
            name: "deberta-xl".into(),
            d_model: 1024,
            n_layers: 48,
            n_heads: 16,
            d_ff: 4096,
            vocab_size: 128_100,
            max_positions: 512,
            params: 900_000_000,
            kron_a: 360,
            kron_b: 360,
        };
        assert!(aot_overhead_ratio(&xl, 16, 384) < 0.12);

        // Our scaled `large` analog has a thinner d, so the worst-case
        // (zero-overlap) ratio is larger but still bounded; the measured
        // Figure 3 numbers are far below this because the add overlaps
        // with compute.
        let analog = crate::config::ModelInfo {
            name: "large".into(),
            d_model: 512,
            n_layers: 12,
            n_heads: 8,
            d_ff: 2048,
            vocab_size: 8192,
            max_positions: 512,
            params: 40_000_000,
            kron_a: 91,
            kron_b: 91,
        };
        assert!(aot_overhead_ratio(&analog, 16, 384) < 0.25);
    }
}
