//! Statistics used across the experiment harness: summaries (mean/std/
//! median/percentiles) and the paper's task metrics (accuracy, F1,
//! Matthews correlation, Pearson/Spearman) from Appendix Table 3.

/// Mean of a slice (0.0 for empty input).
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Population standard deviation.
pub fn std(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / xs.len() as f64).sqrt()
}

/// Median (average of the middle two for even lengths).
pub fn median(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let n = v.len();
    if n % 2 == 1 {
        v[n / 2]
    } else {
        0.5 * (v[n / 2 - 1] + v[n / 2])
    }
}

/// Percentile in [0, 100] with linear interpolation.
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let rank = (p / 100.0) * (v.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        v[lo]
    } else {
        v[lo] + (rank - lo as f64) * (v[hi] - v[lo])
    }
}

/// Classification accuracy.
pub fn accuracy(pred: &[i64], gold: &[i64]) -> f64 {
    assert_eq!(pred.len(), gold.len());
    if pred.is_empty() {
        return 0.0;
    }
    let hits = pred.iter().zip(gold).filter(|(p, g)| p == g).count();
    hits as f64 / pred.len() as f64
}

/// Binary F1 with `positive` as the positive class.
pub fn f1_binary(pred: &[i64], gold: &[i64], positive: i64) -> f64 {
    let mut tp = 0.0;
    let mut fp = 0.0;
    let mut fndash = 0.0;
    for (&p, &g) in pred.iter().zip(gold) {
        if p == positive && g == positive {
            tp += 1.0;
        } else if p == positive {
            fp += 1.0;
        } else if g == positive {
            fndash += 1.0;
        }
    }
    if tp == 0.0 {
        return 0.0;
    }
    let precision = tp / (tp + fp);
    let recall = tp / (tp + fndash);
    2.0 * precision * recall / (precision + recall)
}

/// Macro-averaged F1 over the classes present in `gold`.
pub fn f1_macro(pred: &[i64], gold: &[i64]) -> f64 {
    let mut classes: Vec<i64> = gold.to_vec();
    classes.sort_unstable();
    classes.dedup();
    if classes.is_empty() {
        return 0.0;
    }
    let total: f64 = classes.iter().map(|&c| f1_binary(pred, gold, c)).sum();
    total / classes.len() as f64
}

/// Matthews correlation coefficient (CoLA's metric).
pub fn matthews(pred: &[i64], gold: &[i64]) -> f64 {
    let (mut tp, mut tn, mut fp, mut fndash) = (0.0f64, 0.0, 0.0, 0.0);
    for (&p, &g) in pred.iter().zip(gold) {
        match (p != 0, g != 0) {
            (true, true) => tp += 1.0,
            (false, false) => tn += 1.0,
            (true, false) => fp += 1.0,
            (false, true) => fndash += 1.0,
        }
    }
    let denom = ((tp + fp) * (tp + fndash) * (tn + fp) * (tn + fndash)).sqrt();
    if denom == 0.0 {
        0.0
    } else {
        (tp * tn - fp * fndash) / denom
    }
}

/// Pearson correlation (STS-B).
pub fn pearson(x: &[f64], y: &[f64]) -> f64 {
    assert_eq!(x.len(), y.len());
    if x.len() < 2 {
        return 0.0;
    }
    let mx = mean(x);
    let my = mean(y);
    let mut num = 0.0;
    let mut dx = 0.0;
    let mut dy = 0.0;
    for (a, b) in x.iter().zip(y) {
        num += (a - mx) * (b - my);
        dx += (a - mx) * (a - mx);
        dy += (b - my) * (b - my);
    }
    if dx == 0.0 || dy == 0.0 {
        0.0
    } else {
        num / (dx.sqrt() * dy.sqrt())
    }
}

/// Spearman rank correlation (STS-B). Average ranks for ties.
pub fn spearman(x: &[f64], y: &[f64]) -> f64 {
    pearson(&ranks(x), &ranks(y))
}

fn ranks(xs: &[f64]) -> Vec<f64> {
    let mut idx: Vec<usize> = (0..xs.len()).collect();
    idx.sort_by(|&a, &b| xs[a].partial_cmp(&xs[b]).unwrap());
    let mut out = vec![0.0; xs.len()];
    let mut i = 0;
    while i < idx.len() {
        let mut j = i;
        while j + 1 < idx.len() && xs[idx[j + 1]] == xs[idx[i]] {
            j += 1;
        }
        let avg = (i + j) as f64 / 2.0 + 1.0;
        for k in i..=j {
            out[idx[k]] = avg;
        }
        i = j + 1;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_stats() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(mean(&xs), 2.5);
        assert_eq!(median(&xs), 2.5);
        assert_eq!(median(&[3.0, 1.0, 2.0]), 2.0);
        assert!((std(&xs) - 1.118033988749895).abs() < 1e-12);
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 100.0), 4.0);
        assert_eq!(percentile(&xs, 50.0), 2.5);
    }

    #[test]
    fn accuracy_and_f1() {
        let gold = [1, 1, 0, 0, 1];
        let pred = [1, 0, 0, 1, 1];
        assert!((accuracy(&pred, &gold) - 0.6).abs() < 1e-12);
        // tp=2 fp=1 fn=1 -> precision 2/3, recall 2/3, f1 2/3
        assert!((f1_binary(&pred, &gold, 1) - 2.0 / 3.0).abs() < 1e-12);
    }

    #[test]
    fn matthews_perfect_and_inverse() {
        let gold = [1, 0, 1, 0];
        assert!((matthews(&gold, &gold) - 1.0).abs() < 1e-12);
        let inv = [0, 1, 0, 1];
        assert!((matthews(&inv, &gold) + 1.0).abs() < 1e-12);
    }

    #[test]
    fn pearson_linear() {
        let x = [1.0, 2.0, 3.0, 4.0];
        let y = [2.0, 4.0, 6.0, 8.0];
        assert!((pearson(&x, &y) - 1.0).abs() < 1e-12);
        let yneg = [8.0, 6.0, 4.0, 2.0];
        assert!((pearson(&x, &yneg) + 1.0).abs() < 1e-12);
    }

    #[test]
    fn spearman_monotone_nonlinear() {
        let x = [1.0, 2.0, 3.0, 4.0, 5.0];
        let y = [1.0, 8.0, 27.0, 64.0, 125.0]; // monotone => rho = 1
        assert!((spearman(&x, &y) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn spearman_handles_ties() {
        let x = [1.0, 2.0, 2.0, 3.0];
        let y = [1.0, 2.0, 2.0, 3.0];
        assert!((spearman(&x, &y) - 1.0).abs() < 1e-12);
    }
}
