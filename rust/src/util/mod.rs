//! Small in-tree substrates (no external crates are available offline):
//! RNG, statistics, thread pool, logging, wall-clock timing.

pub mod log;
pub mod pool;
pub mod rng;
pub mod stats;
pub mod timer;

pub use rng::Pcg64;
pub use timer::Timer;
