//! Small in-tree substrates (no external crates are available offline):
//! RNG, statistics, thread pool, logging, wall-clock timing, mmap.

pub mod log;
pub mod mmap;
pub mod pool;
pub mod rng;
pub mod stats;
pub mod timer;

pub use mmap::Mmap;
pub use rng::Pcg64;
pub use timer::Timer;
