//! Vendored read-only memory mapping — a minimal `extern "C"` shim over
//! `mmap`/`munmap` (std already links libc on unix; no external crate).
//!
//! The adapter disk tier maps each spill file once at open and serves
//! cold gathers straight from the mapping, so the OS page cache — not
//! the store's LRU — owns cold-row residency (DESIGN.md §13).  Scope is
//! deliberately tiny: whole-file, read-only, `MAP_PRIVATE` mappings with
//! length-checked slices.  On platforms without the shim, or when the
//! syscall fails, [`Mmap::map_file`] returns an error and callers fall
//! back to positioned reads.

use std::fs::File;

use anyhow::bail;

use crate::Result;

#[cfg(all(unix, target_pointer_width = "64"))]
mod sys {
    use std::os::raw::{c_int, c_void};

    pub const PROT_READ: c_int = 1;
    pub const MAP_PRIVATE: c_int = 2;
    /// `MAP_FAILED` is `(void *) -1`, not null.
    pub const MAP_FAILED: *mut c_void = usize::MAX as *mut c_void;

    // The `off_t` offset is declared `i64`: correct on every 64-bit unix
    // (where `mmap` and `mmap64` coincide); 32-bit targets are cfg'd out
    // above rather than risking an off_t ABI mismatch.
    extern "C" {
        pub fn mmap(
            addr: *mut c_void,
            len: usize,
            prot: c_int,
            flags: c_int,
            fd: c_int,
            offset: i64,
        ) -> *mut c_void;
        pub fn munmap(addr: *mut c_void, len: usize) -> c_int;
    }
}

/// A whole-file, read-only, private mapping, unmapped on drop.
///
/// The pages are immutable for the mapping's lifetime as far as safe
/// code can tell — but truncating the *file* underneath a live mapping
/// turns loads past the new EOF into `SIGBUS` on every unix, which is
/// why the adapter cold tier validates the payload extent against the
/// file length before trusting a mapping (`peft::residency`).
pub struct Mmap {
    ptr: *const u8,
    len: usize,
}

// Safety: the mapping is read-only and uniquely owned.  Shared
// references only ever hand out `&[u8]`, and the pages stay valid until
// `Drop` (which needs `&mut self`, so no borrow can outlive them).
unsafe impl Send for Mmap {}
unsafe impl Sync for Mmap {}

impl Mmap {
    /// Whether this build can map files at all.  The shim is declared
    /// for 64-bit unix; everywhere else `map_file` always errors.
    pub fn supported() -> bool {
        cfg!(all(unix, target_pointer_width = "64"))
    }

    /// Map `file` read-only in its entirety (its length at call time).
    #[cfg(all(unix, target_pointer_width = "64"))]
    pub fn map_file(file: &File) -> Result<Mmap> {
        use std::os::unix::io::AsRawFd;
        let len = file.metadata()?.len();
        if len == 0 {
            // mmap(len = 0) is EINVAL; an empty file needs no pages.
            return Ok(Mmap { ptr: std::ptr::NonNull::<u8>::dangling().as_ptr(), len: 0 });
        }
        let len = usize::try_from(len)
            .map_err(|_| anyhow::anyhow!("file of {len} bytes is too large to map"))?;
        let ptr = unsafe {
            sys::mmap(
                std::ptr::null_mut(),
                len,
                sys::PROT_READ,
                sys::MAP_PRIVATE,
                file.as_raw_fd(),
                0,
            )
        };
        if ptr == sys::MAP_FAILED || ptr.is_null() {
            bail!("mmap of {len} bytes failed: {}", std::io::Error::last_os_error());
        }
        Ok(Mmap { ptr: ptr as *const u8, len })
    }

    /// Unsupported platform: always an error; callers fall back to
    /// positioned reads.
    #[cfg(not(all(unix, target_pointer_width = "64")))]
    pub fn map_file(_file: &File) -> Result<Mmap> {
        bail!("memory mapping is not supported on this platform")
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// The whole mapping as a byte slice.
    pub fn as_slice(&self) -> &[u8] {
        if self.len == 0 {
            return &[];
        }
        // Safety: `ptr` is a live PROT_READ mapping of exactly `len`
        // bytes for as long as `self` is borrowed.
        unsafe { std::slice::from_raw_parts(self.ptr, self.len) }
    }

    /// A length-checked window: a typed error — never a fault — when the
    /// requested range runs past the mapping.
    pub fn slice(&self, offset: u64, len: usize) -> Result<&[u8]> {
        let end = offset
            .checked_add(len as u64)
            .ok_or_else(|| anyhow::anyhow!("mmap slice range overflows"))?;
        if end > self.len as u64 {
            bail!(
                "mmap slice [{offset}, {end}) exceeds mapping of {} bytes",
                self.len
            );
        }
        let offset = offset as usize;
        Ok(&self.as_slice()[offset..offset + len])
    }
}

impl Drop for Mmap {
    fn drop(&mut self) {
        #[cfg(all(unix, target_pointer_width = "64"))]
        if self.len > 0 {
            // Safety: `ptr`/`len` are exactly what mmap returned, and
            // `Mmap` is not `Clone`, so this is the only unmap.
            unsafe {
                sys::munmap(self.ptr as *mut std::os::raw::c_void, self.len);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Write;
    use std::path::PathBuf;

    fn tmp_file(name: &str, data: &[u8]) -> PathBuf {
        let dir = std::env::temp_dir().join(format!("aotpt-mmap-tests-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join(name);
        let mut f = File::create(&path).unwrap();
        f.write_all(data).unwrap();
        path
    }

    #[test]
    fn mmap_is_send_and_sync() {
        fn assert_send_sync<T: Send + Sync>() {}
        assert_send_sync::<Mmap>();
    }

    #[test]
    fn maps_whole_file_and_length_checks_slices() {
        if !Mmap::supported() {
            return;
        }
        let data: Vec<u8> = (0..1000u32).map(|i| (i % 251) as u8).collect();
        let path = tmp_file("roundtrip.bin", &data);
        let map = Mmap::map_file(&File::open(&path).unwrap()).unwrap();
        assert_eq!(map.len(), data.len());
        assert_eq!(map.as_slice(), &data[..]);
        assert_eq!(map.slice(100, 50).unwrap(), &data[100..150]);
        assert_eq!(map.slice(data.len() as u64, 0).unwrap(), &[] as &[u8]);
        // Past-the-end windows are typed errors, not faults.
        let err = map.slice(996, 8).unwrap_err();
        assert!(err.to_string().contains("exceeds mapping"), "{err}");
        assert!(map.slice(u64::MAX, 2).is_err());
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn empty_file_maps_as_empty() {
        if !Mmap::supported() {
            return;
        }
        let path = tmp_file("empty.bin", &[]);
        let map = Mmap::map_file(&File::open(&path).unwrap()).unwrap();
        assert!(map.is_empty());
        assert_eq!(map.as_slice(), &[] as &[u8]);
        assert_eq!(map.slice(0, 0).unwrap(), &[] as &[u8]);
        assert!(map.slice(0, 1).is_err());
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn unsupported_platform_reports_error() {
        if Mmap::supported() {
            return;
        }
        let path = tmp_file("unsupported.bin", &[1, 2, 3]);
        assert!(Mmap::map_file(&File::open(&path).unwrap()).is_err());
        let _ = std::fs::remove_file(&path);
    }
}
