//! PCG-XSL-RR 128/64 pseudo-random generator + the distributions the
//! framework needs (uniform, normal, categorical, permutation).
//!
//! The paper's protocol evaluates every hyperparameter set under multiple
//! seeds (§4.1); all stochastic choices in the Rust layer (data generation,
//! trainable-parameter init, shuffling) flow through this deterministic
//! generator so runs are exactly reproducible from `(experiment, seed)`.

/// PCG-XSL-RR 128/64 (O'Neill 2014). 128-bit state, 64-bit output.
#[derive(Clone, Debug)]
pub struct Pcg64 {
    state: u128,
    inc: u128,
}

const PCG_MULT: u128 = 0x2360ed051fc65da44385df649fccf645;

impl Pcg64 {
    /// Seed with an arbitrary 64-bit value; stream constant fixed.
    pub fn new(seed: u64) -> Self {
        Self::with_stream(seed, 0xda3e39cb94b95bdb)
    }

    /// Seed with an explicit stream (distinct streams never collide).
    pub fn with_stream(seed: u64, stream: u64) -> Self {
        let mut rng = Pcg64 {
            state: 0,
            inc: ((stream as u128) << 1) | 1,
        };
        rng.next_u64();
        rng.state = rng.state.wrapping_add(seed as u128);
        rng.next_u64();
        rng
    }

    /// Derive an independent generator for a labeled sub-task.
    /// Mirrors `jax.random.fold_in` usage on the Python side.
    pub fn fold(&self, label: u64) -> Pcg64 {
        Pcg64::with_stream(self.state as u64 ^ label, (self.state >> 64) as u64 ^ label.rotate_left(17))
    }

    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_mul(PCG_MULT).wrapping_add(self.inc);
        let rot = (self.state >> 122) as u32;
        let xored = ((self.state >> 64) as u64) ^ (self.state as u64);
        xored.rotate_right(rot)
    }

    /// Uniform in [0, 1).
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in [0, 1).
    pub fn f32(&mut self) -> f32 {
        self.f64() as f32
    }

    /// Uniform integer in [0, bound) without modulo bias (Lemire).
    pub fn below(&mut self, bound: u64) -> u64 {
        assert!(bound > 0);
        let mut x = self.next_u64();
        let mut m = (x as u128) * (bound as u128);
        let mut l = m as u64;
        if l < bound {
            let t = bound.wrapping_neg() % bound;
            while l < t {
                x = self.next_u64();
                m = (x as u128) * (bound as u128);
                l = m as u64;
            }
        }
        (m >> 64) as u64
    }

    /// Uniform in [lo, hi).
    pub fn range(&mut self, lo: i64, hi: i64) -> i64 {
        assert!(hi > lo);
        lo + self.below((hi - lo) as u64) as i64
    }

    /// Standard normal via Box-Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = loop {
            let u = self.f64();
            if u > 0.0 {
                break u;
            }
        };
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Vector of N(0, std) f32 values.
    pub fn normal_vec(&mut self, n: usize, std: f32) -> Vec<f32> {
        (0..n).map(|_| self.normal() as f32 * std).collect()
    }

    /// Bernoulli(p).
    pub fn bool(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Sample an index from unnormalized non-negative weights.
    pub fn categorical(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        assert!(total > 0.0, "categorical weights must not all be zero");
        let mut x = self.f64() * total;
        for (i, w) in weights.iter().enumerate() {
            x -= w;
            if x <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }

    /// Choose one element of a slice.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.below(xs.len() as u64) as usize]
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }

    /// A random permutation of 0..n.
    pub fn permutation(&mut self, n: usize) -> Vec<usize> {
        let mut p: Vec<usize> = (0..n).collect();
        self.shuffle(&mut p);
        p
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_per_seed() {
        let a: Vec<u64> = {
            let mut r = Pcg64::new(42);
            (0..8).map(|_| r.next_u64()).collect()
        };
        let b: Vec<u64> = {
            let mut r = Pcg64::new(42);
            (0..8).map(|_| r.next_u64()).collect()
        };
        assert_eq!(a, b);
        let c: Vec<u64> = {
            let mut r = Pcg64::new(43);
            (0..8).map(|_| r.next_u64()).collect()
        };
        assert_ne!(a, c);
    }

    #[test]
    fn uniform_range() {
        let mut r = Pcg64::new(7);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
            let k = r.range(-5, 9);
            assert!((-5..9).contains(&k));
        }
    }

    #[test]
    fn below_is_unbiased_enough() {
        let mut r = Pcg64::new(1);
        let mut counts = [0usize; 3];
        for _ in 0..30_000 {
            counts[r.below(3) as usize] += 1;
        }
        for c in counts {
            assert!((9_000..11_000).contains(&c), "{counts:?}");
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Pcg64::new(3);
        let xs: Vec<f64> = (0..50_000).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / xs.len() as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / xs.len() as f64;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Pcg64::new(5);
        let p = r.permutation(100);
        let mut sorted = p.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn fold_derives_independent_streams() {
        let base = Pcg64::new(11);
        let mut a = base.fold(1);
        let mut b = base.fold(2);
        let va: Vec<u64> = (0..4).map(|_| a.next_u64()).collect();
        let vb: Vec<u64> = (0..4).map(|_| b.next_u64()).collect();
        assert_ne!(va, vb);
    }

    #[test]
    fn categorical_respects_weights() {
        let mut r = Pcg64::new(9);
        let mut hits = [0usize; 2];
        for _ in 0..20_000 {
            hits[r.categorical(&[1.0, 3.0])] += 1;
        }
        let frac = hits[1] as f64 / 20_000.0;
        assert!((0.72..0.78).contains(&frac), "{frac}");
    }
}
