//! Minimal leveled logger (stderr), controlled by `AOTPT_LOG`
//! (`error|warn|info|debug|trace`, default `info`).

use std::io::Write;
use std::sync::atomic::{AtomicU8, Ordering};
use std::sync::OnceLock;
use std::time::{SystemTime, UNIX_EPOCH};

#[derive(Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Debug)]
pub enum Level {
    Error = 0,
    Warn = 1,
    Info = 2,
    Debug = 3,
    Trace = 4,
}

static LEVEL: AtomicU8 = AtomicU8::new(2);
static INIT: OnceLock<()> = OnceLock::new();

fn ensure_init() {
    INIT.get_or_init(|| {
        if let Ok(v) = std::env::var("AOTPT_LOG") {
            let lv = match v.to_ascii_lowercase().as_str() {
                "error" => Level::Error,
                "warn" => Level::Warn,
                "debug" => Level::Debug,
                "trace" => Level::Trace,
                _ => Level::Info,
            };
            LEVEL.store(lv as u8, Ordering::Relaxed);
        }
    });
}

pub fn set_level(level: Level) {
    ensure_init();
    LEVEL.store(level as u8, Ordering::Relaxed);
}

pub fn enabled(level: Level) -> bool {
    ensure_init();
    (level as u8) <= LEVEL.load(Ordering::Relaxed)
}

pub fn log(level: Level, module: &str, msg: &str) {
    if !enabled(level) {
        return;
    }
    let now = SystemTime::now()
        .duration_since(UNIX_EPOCH)
        .unwrap_or_default();
    let tag = match level {
        Level::Error => "ERROR",
        Level::Warn => "WARN ",
        Level::Info => "INFO ",
        Level::Debug => "DEBUG",
        Level::Trace => "TRACE",
    };
    let mut err = std::io::stderr().lock();
    let _ = writeln!(
        err,
        "[{:>10}.{:03} {} {}] {}",
        now.as_secs(),
        now.subsec_millis(),
        tag,
        module,
        msg
    );
}

#[macro_export]
macro_rules! info {
    ($($arg:tt)*) => {
        $crate::util::log::log($crate::util::log::Level::Info, module_path!(), &format!($($arg)*))
    };
}

#[macro_export]
macro_rules! warnln {
    ($($arg:tt)*) => {
        $crate::util::log::log($crate::util::log::Level::Warn, module_path!(), &format!($($arg)*))
    };
}

#[macro_export]
macro_rules! debugln {
    ($($arg:tt)*) => {
        $crate::util::log::log($crate::util::log::Level::Debug, module_path!(), &format!($($arg)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_gating() {
        set_level(Level::Warn);
        assert!(enabled(Level::Error));
        assert!(enabled(Level::Warn));
        assert!(!enabled(Level::Info));
        set_level(Level::Info);
    }
}
