//! A fixed-size thread pool over std mpsc channels (no tokio offline).
//!
//! The coordinator uses it for request fan-in/fan-out; experiments use
//! `scoped_map` for data-parallel sweeps.

use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;

type Job = Box<dyn FnOnce() + Send + 'static>;

/// Fixed-size worker pool.
pub struct ThreadPool {
    tx: Option<Sender<Job>>,
    workers: Vec<JoinHandle<()>>,
}

impl ThreadPool {
    pub fn new(threads: usize) -> Self {
        assert!(threads > 0);
        let (tx, rx) = channel::<Job>();
        let rx = Arc::new(Mutex::new(rx));
        let workers = (0..threads)
            .map(|_| {
                let rx: Arc<Mutex<Receiver<Job>>> = Arc::clone(&rx);
                std::thread::spawn(move || loop {
                    let job = {
                        let guard = rx.lock().unwrap();
                        guard.recv()
                    };
                    match job {
                        Ok(job) => job(),
                        Err(_) => break, // sender dropped => shut down
                    }
                })
            })
            .collect();
        ThreadPool { tx: Some(tx), workers }
    }

    /// Submit a job.
    pub fn execute(&self, job: impl FnOnce() + Send + 'static) {
        self.tx
            .as_ref()
            .expect("pool shut down")
            .send(Box::new(job))
            .expect("worker channel closed");
    }

    /// Number of worker threads.
    pub fn size(&self) -> usize {
        self.workers.len()
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        drop(self.tx.take());
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

/// Apply `f` to each item on `threads` OS threads, preserving order.
pub fn scoped_map<T, R, F>(items: Vec<T>, threads: usize, f: F) -> Vec<R>
where
    T: Send,
    R: Send,
    F: Fn(T) -> R + Sync,
{
    let n = items.len();
    if n == 0 {
        return Vec::new();
    }
    let threads = threads.max(1).min(n);
    let mut out: Vec<Option<R>> = (0..n).map(|_| None).collect();
    let work: Vec<(usize, T)> = items.into_iter().enumerate().collect();
    let queue = Mutex::new(work);
    let results = Mutex::new(&mut out);
    std::thread::scope(|scope| {
        for _ in 0..threads {
            scope.spawn(|| loop {
                let item = { queue.lock().unwrap().pop() };
                match item {
                    Some((i, x)) => {
                        let r = f(x);
                        results.lock().unwrap()[i] = Some(r);
                    }
                    None => break,
                }
            });
        }
    });
    out.into_iter().map(|r| r.unwrap()).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn pool_runs_all_jobs() {
        let pool = ThreadPool::new(4);
        let counter = Arc::new(AtomicUsize::new(0));
        let (tx, rx) = channel();
        for _ in 0..100 {
            let c = Arc::clone(&counter);
            let tx = tx.clone();
            pool.execute(move || {
                c.fetch_add(1, Ordering::SeqCst);
                let _ = tx.send(());
            });
        }
        for _ in 0..100 {
            rx.recv_timeout(std::time::Duration::from_secs(5)).unwrap();
        }
        assert_eq!(counter.load(Ordering::SeqCst), 100);
    }

    #[test]
    fn scoped_map_preserves_order() {
        let out = scoped_map((0..50).collect::<Vec<i32>>(), 8, |x| x * x);
        assert_eq!(out, (0..50).map(|x| x * x).collect::<Vec<_>>());
    }

    #[test]
    fn pool_drop_joins_cleanly() {
        let pool = ThreadPool::new(2);
        pool.execute(|| {});
        drop(pool);
    }
}
