//! Typed reader for `artifacts/manifest.json`.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, bail, Context};

use crate::json::{self, Json};
use crate::tensor::DType;
use crate::Result;

/// One positional input/output tensor of an artifact.
#[derive(Clone, Debug)]
pub struct TensorSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: DType,
}

impl TensorSpec {
    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }
}

/// How the Rust training driver materializes a trainable tensor.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum InitKind {
    /// All zeros (the paper's zero-init convention, §4.1).
    Zeros,
    /// N(0, std).
    Normal,
    /// Copy of the backbone tensor of the same (suffix) name.
    Backbone,
}

#[derive(Clone, Debug)]
pub struct InitSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub kind: InitKind,
    pub std: f32,
}

/// One artifact's full signature.
#[derive(Clone, Debug)]
pub struct ArtifactSpec {
    pub stem: String,
    pub file: PathBuf,
    pub kind: String,
    pub model: String,
    pub method: String,
    pub batch: usize,
    pub seq: usize,
    pub rank: usize,
    pub prefix: usize,
    pub classes: usize,
    pub steps_per_call: usize,
    pub inputs: Vec<TensorSpec>,
    pub outputs: Vec<String>,
    pub trainable_order: Vec<String>,
    pub init: Vec<InitSpec>,
}

impl ArtifactSpec {
    pub fn input_index(&self, name: &str) -> Result<usize> {
        self.inputs
            .iter()
            .position(|t| t.name == name)
            .ok_or_else(|| anyhow!("{}: no input named {name}", self.stem))
    }

    pub fn input(&self, name: &str) -> Result<&TensorSpec> {
        Ok(&self.inputs[self.input_index(name)?])
    }

    pub fn output_index(&self, name: &str) -> Result<usize> {
        self.outputs
            .iter()
            .position(|t| t == name)
            .ok_or_else(|| anyhow!("{}: no output named {name}", self.stem))
    }

    /// Names of inputs with a given prefix (`w.`, `t.`, `in.` …), in order.
    pub fn inputs_with_prefix(&self, prefix: &str) -> Vec<&TensorSpec> {
        self.inputs.iter().filter(|t| t.name.starts_with(prefix)).collect()
    }
}

/// Geometry of one model shape family.
#[derive(Clone, Debug)]
pub struct ModelInfo {
    pub name: String,
    pub d_model: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    pub d_ff: usize,
    pub vocab_size: usize,
    pub max_positions: usize,
    pub params: usize,
    pub kron_a: usize,
    pub kron_b: usize,
}

/// The whole manifest.
pub struct Manifest {
    pub dir: PathBuf,
    pub vocab_size: usize,
    pub multitask_classes: usize,
    pub models: BTreeMap<String, ModelInfo>,
    pub method_properties: BTreeMap<String, (bool, bool, bool)>,
    pub paper_analog: BTreeMap<String, String>,
    artifacts: BTreeMap<String, ArtifactSpec>,
}

impl Manifest {
    /// Load `<dir>/manifest.json`.
    pub fn load(dir: &Path) -> Result<Manifest> {
        let root = json::load(&dir.join("manifest.json"))
            .context("loading artifact manifest (run `make artifacts` first)")?;
        let vocab_size = root
            .get("vocab_size")
            .and_then(Json::as_usize)
            .ok_or_else(|| anyhow!("manifest: missing vocab_size"))?;
        let multitask_classes = root
            .get("multitask_classes")
            .and_then(Json::as_usize)
            .unwrap_or(4);

        let mut models = BTreeMap::new();
        for (name, m) in root
            .get("models")
            .and_then(Json::as_obj)
            .ok_or_else(|| anyhow!("manifest: missing models"))?
        {
            let geti = |k: &str| -> Result<usize> {
                m.get(k)
                    .and_then(Json::as_usize)
                    .ok_or_else(|| anyhow!("model {name}: missing {k}"))
            };
            models.insert(
                name.clone(),
                ModelInfo {
                    name: name.clone(),
                    d_model: geti("d_model")?,
                    n_layers: geti("n_layers")?,
                    n_heads: geti("n_heads")?,
                    d_ff: geti("d_ff")?,
                    vocab_size: geti("vocab_size")?,
                    max_positions: geti("max_positions")?,
                    params: geti("params")?,
                    kron_a: geti("kron_a")?,
                    kron_b: geti("kron_b")?,
                },
            );
        }

        let mut method_properties = BTreeMap::new();
        if let Some(props) = root.get("method_properties").and_then(Json::as_obj) {
            for (name, p) in props {
                method_properties.insert(
                    name.clone(),
                    (
                        p.get("parameter_efficient").and_then(Json::as_bool).unwrap_or(false),
                        p.get("zero_cost").and_then(Json::as_bool).unwrap_or(false),
                        p.get("multi_task").and_then(Json::as_bool).unwrap_or(false),
                    ),
                );
            }
        }

        let mut paper_analog = BTreeMap::new();
        if let Some(pa) = root.get("paper_analog").and_then(Json::as_obj) {
            for (k, v) in pa {
                if let Some(s) = v.as_str() {
                    paper_analog.insert(k.clone(), s.to_string());
                }
            }
        }

        let mut artifacts = BTreeMap::new();
        for (stem, a) in root
            .get("artifacts")
            .and_then(Json::as_obj)
            .ok_or_else(|| anyhow!("manifest: missing artifacts"))?
        {
            artifacts.insert(stem.clone(), parse_artifact(dir, stem, a)?);
        }

        Ok(Manifest {
            dir: dir.to_path_buf(),
            vocab_size,
            multitask_classes,
            models,
            method_properties,
            paper_analog,
            artifacts,
        })
    }

    pub fn artifact(&self, stem: &str) -> Result<&ArtifactSpec> {
        self.artifacts
            .get(stem)
            .ok_or_else(|| anyhow!("manifest has no artifact {stem}"))
    }

    pub fn model(&self, name: &str) -> Result<&ModelInfo> {
        self.models
            .get(name)
            .ok_or_else(|| anyhow!("manifest has no model {name}"))
    }

    pub fn artifacts(&self) -> impl Iterator<Item = &ArtifactSpec> {
        self.artifacts.values()
    }

    /// Find artifacts matching (kind, model, method); further filtering is
    /// on the caller.
    pub fn find(&self, kind: &str, model: &str, method: &str) -> Vec<&ArtifactSpec> {
        self.artifacts
            .values()
            .filter(|a| a.kind == kind && a.model == model && a.method == method)
            .collect()
    }

    /// The unique artifact for (kind, model, method, batch, seq); errors if
    /// missing or ambiguous without extra filters.
    pub fn find_bucket(
        &self,
        kind: &str,
        model: &str,
        method: &str,
        batch: usize,
        seq: usize,
    ) -> Result<&ArtifactSpec> {
        let hits: Vec<_> = self
            .find(kind, model, method)
            .into_iter()
            .filter(|a| a.batch == batch && a.seq == seq)
            .collect();
        match hits.len() {
            0 => bail!("no artifact for {kind}/{model}/{method} b{batch}n{seq}"),
            1 => Ok(hits[0]),
            _ => Ok(hits[0]), // several hp variants share the bucket; first is fine
        }
    }
}

fn parse_artifact(dir: &Path, stem: &str, a: &Json) -> Result<ArtifactSpec> {
    let gets = |k: &str| a.get(k).and_then(Json::as_str).map(str::to_string);
    let geti = |k: &str| a.get(k).and_then(Json::as_usize).unwrap_or(0);
    let file = gets("file").ok_or_else(|| anyhow!("{stem}: missing file"))?;

    let mut inputs = Vec::new();
    for t in a
        .get("inputs")
        .and_then(Json::as_arr)
        .ok_or_else(|| anyhow!("{stem}: missing inputs"))?
    {
        inputs.push(parse_tensor_spec(stem, t)?);
    }
    let outputs = a
        .get("outputs")
        .and_then(Json::as_arr)
        .ok_or_else(|| anyhow!("{stem}: missing outputs"))?
        .iter()
        .filter_map(|o| o.as_str().map(str::to_string))
        .collect();

    let trainable_order = a
        .get("trainable_order")
        .and_then(Json::as_arr)
        .map(|v| v.iter().filter_map(|x| x.as_str().map(str::to_string)).collect())
        .unwrap_or_default();

    let mut init = Vec::new();
    if let Some(entries) = a.get("init").and_then(Json::as_arr) {
        for e in entries {
            let name = e
                .get("name")
                .and_then(Json::as_str)
                .ok_or_else(|| anyhow!("{stem}: init entry missing name"))?
                .to_string();
            let shape = e
                .get("shape")
                .and_then(Json::as_arr)
                .ok_or_else(|| anyhow!("{stem}: init {name} missing shape"))?
                .iter()
                .filter_map(Json::as_usize)
                .collect();
            let kind = match e.get("init").and_then(Json::as_str) {
                Some("zeros") => InitKind::Zeros,
                Some("normal") => InitKind::Normal,
                Some("backbone") => InitKind::Backbone,
                other => bail!("{stem}: init {name}: unknown kind {other:?}"),
            };
            let std = e.get("std").and_then(Json::as_f64).unwrap_or(0.0) as f32;
            init.push(InitSpec { name, shape, kind, std });
        }
    }

    Ok(ArtifactSpec {
        stem: stem.to_string(),
        file: dir.join(&file),
        kind: gets("kind").unwrap_or_default(),
        model: gets("model").unwrap_or_default(),
        method: gets("method").unwrap_or_default(),
        batch: geti("batch"),
        seq: geti("seq"),
        rank: geti("rank"),
        prefix: geti("prefix"),
        classes: geti("classes"),
        steps_per_call: geti("steps_per_call"),
        inputs,
        outputs,
        trainable_order,
        init,
    })
}

fn parse_tensor_spec(stem: &str, t: &Json) -> Result<TensorSpec> {
    let name = t
        .get("name")
        .and_then(Json::as_str)
        .ok_or_else(|| anyhow!("{stem}: input missing name"))?
        .to_string();
    let shape = t
        .get("shape")
        .and_then(Json::as_arr)
        .ok_or_else(|| anyhow!("{stem}: input {name} missing shape"))?
        .iter()
        .filter_map(Json::as_usize)
        .collect();
    let dtype = DType::from_name(
        t.get("dtype")
            .and_then(Json::as_str)
            .ok_or_else(|| anyhow!("{stem}: input {name} missing dtype"))?,
    )?;
    Ok(TensorSpec { name, shape, dtype })
}

#[cfg(test)]
mod tests {
    use super::*;

    /// These tests exercise the real manifest when artifacts exist (they are
    /// generated by `make artifacts`); otherwise they are skipped.
    fn manifest() -> Option<Manifest> {
        let dir = crate::artifacts_dir();
        if dir.join("manifest.json").exists() {
            Some(Manifest::load(&dir).expect("manifest parses"))
        } else {
            None
        }
    }

    #[test]
    fn loads_models_and_artifacts() {
        let Some(m) = manifest() else { return };
        assert!(m.vocab_size >= 1024);
        let tiny = m.model("tiny").unwrap();
        assert_eq!(tiny.d_model, 64);
        assert!(tiny.kron_a * tiny.kron_b >= m.vocab_size);
        assert!(m.artifacts().count() > 50);
    }

    #[test]
    fn fwd_artifact_signature_sane() {
        let Some(m) = manifest() else { return };
        let a = m.find_bucket("fwd", "tiny", "aot", 2, 16).unwrap();
        assert_eq!(a.outputs, vec!["logits".to_string()]);
        // ids/mask/bias/head present after the 20 stacked backbone weights
        assert_eq!(a.inputs_with_prefix("w.").len(), 20);
        assert!(a.input("in.ids").is_ok());
        assert!(a.input("in.bias").is_ok());
        let ids = a.input("in.ids").unwrap();
        assert_eq!(ids.shape, vec![2, 16]);
        assert_eq!(ids.dtype, DType::I32);
    }

    #[test]
    fn train_artifact_has_init_specs() {
        let Some(m) = manifest() else { return };
        let hits = m.find("train", "small", "aot-fc");
        assert!(!hits.is_empty());
        let a = hits[0];
        assert!(!a.trainable_order.is_empty());
        assert_eq!(a.init.len(), a.trainable_order.len());
        assert!(a.init.iter().any(|i| i.kind == InitKind::Zeros));
        assert!(a.init.iter().any(|i| i.kind == InitKind::Normal));
        // outputs = t.* + m.* + v.* + step + loss
        assert_eq!(a.outputs.len(), 3 * a.trainable_order.len() + 2);
    }
}
