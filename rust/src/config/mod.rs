//! Config system: typed views over the artifact manifest plus experiment
//! scale configs.  `artifacts/manifest.json` (written by `compile/aot.py`)
//! is the single source of truth for every artifact's positional
//! input/output signature — Rust never parses HLO to discover shapes.

pub mod manifest;

pub use manifest::{ArtifactSpec, InitKind, InitSpec, Manifest, ModelInfo, TensorSpec};

/// Experiment scale knob: every experiment driver accepts one of these so
/// the paper's full protocol is encoded while a laptop-scale default runs
/// in CI time (DESIGN.md §2, grid-search substitution).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Scale {
    /// Seconds-scale smoke run (tiny model, few steps).
    Smoke,
    /// Minutes-scale default, the one recorded in EXPERIMENTS.md.
    Quick,
    /// The full configured protocol (hours on this testbed).
    Full,
}

impl Scale {
    pub fn parse(s: &str) -> crate::Result<Self> {
        Ok(match s {
            "smoke" => Scale::Smoke,
            "quick" => Scale::Quick,
            "full" => Scale::Full,
            other => anyhow::bail!("unknown scale {other} (smoke|quick|full)"),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scale_parses() {
        assert_eq!(Scale::parse("quick").unwrap(), Scale::Quick);
        assert!(Scale::parse("nope").is_err());
    }
}
