//! Tiered residency for adapter tables: RAM budget, LRU spill to disk,
//! on-demand fault-in, pinning, and the hot task lifecycle (DESIGN.md §10).
//!
//! Every registered task owns one immutable table (an `Arc<dyn
//! RowSource>`).  The residency manager moves tables between two tiers:
//!
//! * **resident** — the table lives in host RAM (f32 or f16 per
//!   `AdapterConfig::dtype`) and gathers copy rows straight out of it;
//! * **spilled** — the table lives in a `.aotckpt` file; a [`ColdTable`]
//!   keeps the file open — and, with `--adapter-mmap on` (the default
//!   where supported), memory-mapped — serving rows straight from the
//!   page cache, or by positioned reads as the fallback; the next
//!   resolve *faults the table back in* if the RAM budget allows
//!   (DESIGN.md §13).
//!
//! Mutability rules (the lifecycle invariants the concurrency tests
//! assert):
//!
//! * all operations take `&self` — tasks are registered, replaced,
//!   unregistered, pinned and evicted **while the pipeline is serving**;
//! * tables are immutable once registered; `replace` installs a fresh
//!   entry, it never mutates in place;
//! * a gather resolves each assignment to an `Arc` **snapshot** up
//!   front — eviction and unregistration only drop the store's reference,
//!   so in-flight gathers always finish against the exact table they
//!   started with (snapshot isolation), and the memory is freed when the
//!   last in-flight reference drops;
//! * eviction uses `try_lock` on victims, so no lock-ordering cycle
//!   exists between concurrent fault-ins — a contended victim is retried
//!   briefly (bounded back-off, see `try_reserve`) and, if nothing can be
//!   evicted, the gather is served straight from the disk tier instead of
//!   blocking;
//! * gather-aware **prefetch** (DESIGN.md §11): the pipeline announces a
//!   batch's tasks the moment the plan is known, and a background thread
//!   faults spilled tables in while the batch is still being staged, so
//!   the gather's `resolve` finds them warm.  Hit/miss/wasted counters
//!   are exported through [`AdapterStats`].

use std::collections::{HashMap, HashSet};
use std::fs::File;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Sender};
use std::sync::{Arc, Mutex, OnceLock, RwLock, Weak};
use std::thread::JoinHandle;

use anyhow::{anyhow, bail, Context};

use crate::tensor::{ckpt, DType};
use crate::util::mmap::Mmap;
use crate::Result;

use super::quant::{AdapterDType, Int8TaskP, QuantizedTaskP};
use super::store::{DedupTaskP, RowCounts, RowSource, TaskP};

/// Name of the main table tensor inside a spill file.  Tiered layouts
/// add sidecar tensors next to it: `p.index` (`u32` dedup indirection,
/// stored as i32 bits), `p.scale`/`p.zero` (per-row int8 affine params).
const SPILL_TENSOR: &str = "p";
const SPILL_INDEX: &str = "p.index";
const SPILL_SCALE: &str = "p.scale";
const SPILL_ZERO: &str = "p.zero";

/// Adapter-store configuration (CLI: `--adapter-ram-budget`,
/// `--adapter-dtype`, `--adapter-dedup`).
#[derive(Clone, Debug)]
pub struct AdapterConfig {
    /// Max bytes of resident adapter tables; 0 means unlimited (never
    /// spill).
    pub ram_budget_bytes: usize,
    /// Storage dtype of resident tables (fused-time quantization).
    pub dtype: AdapterDType,
    /// Where spilled tables go.  `None` auto-creates a per-process
    /// directory under the system temp dir, removed when the store drops.
    pub spill_dir: Option<PathBuf>,
    /// Collapse near-zero and bit-identical rows at fuse time behind a
    /// `u32` row-index indirection (DESIGN.md §12).
    pub dedup: bool,
    /// Rows with every `|x| ≤ dedup_eps` snap to the shared zero row.
    /// The default `0.0` collapses only exactly-zero rows, keeping the
    /// dedup'd gather bit-exact; larger values are an explicit opt-in to
    /// lossy snapping.
    pub dedup_eps: f32,
    /// Serve the disk tier from a read-only `mmap` of each spill file
    /// (CLI `--adapter-mmap`; DESIGN.md §13).  Where the mapping shim is
    /// unavailable or the syscall fails, the cold tier falls back to
    /// positioned reads and counts the fallback.
    pub mmap: bool,
}

impl Default for AdapterConfig {
    fn default() -> Self {
        AdapterConfig {
            ram_budget_bytes: 0,
            dtype: AdapterDType::F32,
            spill_dir: None,
            dedup: false,
            dedup_eps: 0.0,
            mmap: default_mmap(),
        }
    }
}

/// Default for [`AdapterConfig::mmap`] (CLI `--adapter-mmap auto`): on,
/// unless the `AOTPT_ADAPTER_MMAP` environment variable says `off` (or
/// `0`/`false`/`no`).  The env override is how CI runs the whole test
/// suite as an mmap on/off matrix without touching every constructor.
pub fn default_mmap() -> bool {
    match std::env::var("AOTPT_ADAPTER_MMAP") {
        Ok(v) => !matches!(
            v.trim().to_ascii_lowercase().as_str(),
            "off" | "0" | "false" | "no"
        ),
        Err(_) => true,
    }
}

/// Parse a human byte size: plain bytes, or a `k`/`m`/`g` (or
/// `KiB`/`MiB`/`GiB`) suffix in binary units.  `0`, `none` and
/// `unlimited` disable the budget.
pub fn parse_bytes(s: &str) -> Result<usize> {
    let t = s.trim().to_ascii_lowercase();
    if t.is_empty() {
        bail!("empty byte size");
    }
    if t == "none" || t == "unlimited" {
        return Ok(0);
    }
    let (num, mult) = if let Some(rest) = t.strip_suffix("kib").or_else(|| t.strip_suffix("kb")) {
        (rest, 1usize << 10)
    } else if let Some(rest) = t.strip_suffix("mib").or_else(|| t.strip_suffix("mb")) {
        (rest, 1 << 20)
    } else if let Some(rest) = t.strip_suffix("gib").or_else(|| t.strip_suffix("gb")) {
        (rest, 1 << 30)
    } else if let Some(rest) = t.strip_suffix('k') {
        (rest, 1 << 10)
    } else if let Some(rest) = t.strip_suffix('m') {
        (rest, 1 << 20)
    } else if let Some(rest) = t.strip_suffix('g') {
        (rest, 1 << 30)
    } else if let Some(rest) = t.strip_suffix('b') {
        (rest, 1)
    } else {
        (t.as_str(), 1)
    };
    let num = num.trim();
    let value: f64 = num
        .parse()
        .map_err(|e| anyhow!("bad byte size {s:?}: {e}"))?;
    if !value.is_finite() || value < 0.0 {
        bail!("bad byte size {s:?}");
    }
    Ok((value * mult as f64).round() as usize)
}

/// Point-in-time residency counters, exported through `MetricsSnapshot`.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct AdapterStats {
    /// Bytes of adapter tables currently resident in the store (in-flight
    /// gather snapshots of evicted tables are not counted — they free
    /// themselves when the gather finishes).
    pub resident_bytes: usize,
    pub resident_tasks: usize,
    pub spilled_tasks: usize,
    /// Resolves served from the resident tier.
    pub hits: usize,
    /// Resolves that faulted a spilled table back into RAM.
    pub faults: usize,
    /// Resolves served straight from the disk tier (budget too tight to
    /// fault in).
    pub cold_serves: usize,
    /// Tables evicted from RAM to the disk tier.
    pub evictions: usize,
    /// Spill files written (first eviction per table version; later
    /// evictions reuse the file — tables are immutable).
    pub spill_writes: usize,
    /// Resolves that found a table resident *because* the prefetcher
    /// warmed it (each prefetched fault-in is counted at most once).
    pub prefetch_hits: usize,
    /// Prefetch attempts that could not warm the table (entry lock
    /// contended, RAM budget exhausted, or the disk load failed).
    pub prefetch_misses: usize,
    /// Prefetched tables evicted or retired before any resolve used
    /// them, plus prefetches cancelled by unregistration mid-queue.
    pub prefetch_wasted: usize,
    /// Logical rows (layers × vocab) across all registered tables.
    pub dedup_logical_rows: usize,
    /// Rows physically stored across all registered tables (== logical
    /// for dense tables; the pool sizes for dedup'd ones).
    pub dedup_stored_rows: usize,
    /// Logical rows served by the shared all-zero row.
    pub dedup_zero_rows: usize,
    /// Spill files successfully memory-mapped at `ColdTable::open`.
    pub mmap_opens: usize,
    /// Requested mappings that fell back to positioned reads (shim
    /// unavailable on this platform, or the syscall failed).
    pub mmap_fallbacks: usize,
    /// Bytes currently memory-mapped (a gauge, not a counter).  Mapped
    /// pages are page-cache-owned and charged ~0 against the RAM budget;
    /// the gauge settles to zero once the last reference to every cold
    /// table — store entry or in-flight gather snapshot — drops.
    pub mapped_bytes: usize,
    /// Cold rows decoded straight out of a mapping.
    pub cold_rows_mapped: usize,
    /// Cold rows served by positioned reads.
    pub cold_rows_positioned: usize,
    /// The row kernel currently dispatching every copy/dequant
    /// (DESIGN.md §14): "avx2", "sse2", "neon" or "scalar".
    pub kernel: &'static str,
    /// Rows gathered through a sorted gather plan (batches touching the
    /// disk tier walk their cold tables in (table, token) order).
    pub gather_rows_sorted: usize,
    /// Rows gathered in plain token order (all-resident batches).
    pub gather_rows_unsorted: usize,
}

impl AdapterStats {
    /// Rows the store answers for per row it stores: `logical / stored`.
    /// 1.0 for dense stores; ≥ 1 with dedup (DESIGN.md §12).
    pub fn dedup_ratio(&self) -> f64 {
        if self.dedup_stored_rows == 0 {
            return 1.0;
        }
        self.dedup_logical_rows as f64 / self.dedup_stored_rows as f64
    }
}

/// One task's row in the management listing (`GET /mgmt/adapters`).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct TaskInfo {
    pub name: String,
    pub pinned: bool,
    /// Tier label (`"ram-f32"`, `"disk"`, …), or `"busy"` when the
    /// entry's state lock was contended at listing time.
    pub tier: &'static str,
    /// Storage dtype name; empty for `"busy"` entries.
    pub dtype: &'static str,
    /// Host RAM pinned by this task (0 for the disk tier).
    pub resident_bytes: usize,
}

/// Cold-tier mmap counters, shared (`Arc`) between the residency
/// manager and every [`ColdTable`] it opens.  Sharing — instead of
/// folding these into the manager's own atomics — keeps the
/// `mapped_bytes` gauge honest for tables that outlive their store
/// entry inside in-flight gather snapshots: the decrement runs in
/// `ColdTable::drop`, i.e. exactly when the last reference unmaps.
#[derive(Debug, Default)]
pub struct ColdCounters {
    /// Spill files successfully mapped at open.
    pub mmap_opens: AtomicUsize,
    /// Requested mappings that fell back to positioned reads.
    pub mmap_fallbacks: AtomicUsize,
    /// Bytes currently mapped (gauge: added at open, subtracted on the
    /// owning table's last drop).
    pub mapped_bytes: AtomicUsize,
    /// Cold rows decoded straight out of a mapping.
    pub rows_mapped: AtomicUsize,
    /// Cold rows served by positioned reads.
    pub rows_positioned: AtomicUsize,
}

enum Tier {
    Resident {
        table: Arc<dyn RowSource>,
        /// Write-once spill cache: once a table version has hit disk, a
        /// re-eviction swaps tiers without rewriting the file.
        spill: Option<Arc<ColdTable>>,
    },
    Spilled { cold: Arc<ColdTable> },
}

struct Entry {
    name: String,
    /// Distinguishes spill files across replace cycles of the same name.
    generation: u64,
    pinned: AtomicBool,
    last_used: AtomicU64,
    /// Set when the prefetcher faulted this table in; cleared (and
    /// counted as a hit) by the first resolve that benefits, or counted
    /// as wasted if the table is evicted/retired still flagged.
    prefetched: AtomicBool,
    state: Mutex<Tier>,
}

/// The residency manager behind [`super::PStore`].
pub struct Residency {
    layers: usize,
    vocab: usize,
    d_model: usize,
    cfg: AdapterConfig,
    entries: RwLock<HashMap<String, Arc<Entry>>>,
    resident_bytes: AtomicUsize,
    /// Tier gauges kept as atomics so `stats()` (called by the pipeline
    /// after every batch) never touches an entry's state lock — those are
    /// held across full-table disk I/O during spill and fault-in.
    resident_tasks: AtomicUsize,
    spilled_tasks: AtomicUsize,
    /// Serializes the budget check-and-reserve sequence: without it, two
    /// concurrent fault-ins could each pass the check and jointly
    /// overshoot the RAM budget.
    budget_gate: Mutex<()>,
    clock: AtomicU64,
    generation: AtomicU64,
    spill_dir: OnceLock<PathBuf>,
    /// True once we created `spill_dir` ourselves (then we remove it on
    /// drop; a user-supplied directory is left alone).
    owns_spill_dir: AtomicBool,
    hits: AtomicUsize,
    faults: AtomicUsize,
    cold_serves: AtomicUsize,
    evictions: AtomicUsize,
    spill_writes: AtomicUsize,
    /// Names queued or in flight on the prefetch thread (dedup guard:
    /// a task is never queued twice concurrently).
    prefetch_pending: Mutex<HashSet<String>>,
    /// The background prefetcher, spawned lazily on the first
    /// [`Residency::prefetch`] call.
    prefetcher: OnceLock<Prefetcher>,
    prefetch_hits: AtomicUsize,
    prefetch_misses: AtomicUsize,
    prefetch_wasted: AtomicUsize,
    /// Row-count gauges (added at insert, subtracted at retire).  A
    /// table's `RowCounts` are identical on every tier of one version,
    /// so spill/fault-in never touch these.
    dedup_logical_rows: AtomicUsize,
    dedup_stored_rows: AtomicUsize,
    dedup_zero_rows: AtomicUsize,
    /// Rows gathered through a sorted plan vs in token order
    /// (DESIGN.md §14; fed by `PStore` after every gather batch).
    gather_rows_sorted: AtomicUsize,
    gather_rows_unsorted: AtomicUsize,
    /// Shared with every [`ColdTable`] this store opens (see
    /// [`ColdCounters`] for why the gauge lives outside the manager).
    cold_counters: Arc<ColdCounters>,
}

/// The lazily-spawned background prefetch worker.  It holds only a
/// `Weak<Residency>` — dropping the store drops this handle's sender,
/// which wakes and exits the thread (no `Arc` cycle, no leak).
struct Prefetcher {
    /// `Sender` is not `Sync`; the mutex makes it shareable.  `None`
    /// after shutdown.
    tx: Mutex<Option<Sender<String>>>,
    worker: Mutex<Option<JoinHandle<()>>>,
}

impl Prefetcher {
    fn spawn(weak: Weak<Residency>) -> Prefetcher {
        let (tx, rx) = channel::<String>();
        let worker = std::thread::Builder::new()
            .name("aotpt-prefetch".into())
            .spawn(move || {
                while let Ok(name) = rx.recv() {
                    let Some(res) = weak.upgrade() else { break };
                    res.prefetch_one(&name);
                }
            })
            .expect("spawn prefetch worker");
        Prefetcher { tx: Mutex::new(Some(tx)), worker: Mutex::new(Some(worker)) }
    }
}

/// Outcome of one eviction attempt (see `try_reserve`).
enum EvictOutcome {
    /// A victim was spilled; the caller may re-check the budget.
    Evicted,
    /// Every viable victim's state lock was contended — RAM may become
    /// reclaimable in a moment, so the caller retries briefly.
    Contended,
    /// Nothing evictable exists (all pinned, spilled or excluded).
    Exhausted,
}

/// How often `try_reserve` re-runs eviction when every victim was merely
/// lock-contended before giving up: 8 spins then 100 µs sleeps, ~50 ms
/// worst case.  Giving up is safe — the caller cold-serves from disk.
const MAX_EVICT_RETRIES: usize = 500;

/// Outcome of one background prefetch attempt (counter wiring only).
enum PrefetchOutcome {
    /// Faulted in; `resolve` will count the hit when it benefits.
    Warmed,
    /// Resident already — nothing to do, nothing to count.
    AlreadyWarm,
    /// Task unregistered while the prefetch sat in the queue.
    Cancelled,
    /// Could not warm (lock contended, budget exhausted, load failed).
    Missed,
}

impl Residency {
    pub fn new(layers: usize, vocab: usize, d_model: usize, cfg: AdapterConfig) -> Residency {
        Residency {
            layers,
            vocab,
            d_model,
            cfg,
            entries: RwLock::new(HashMap::new()),
            resident_bytes: AtomicUsize::new(0),
            resident_tasks: AtomicUsize::new(0),
            spilled_tasks: AtomicUsize::new(0),
            budget_gate: Mutex::new(()),
            clock: AtomicU64::new(0),
            generation: AtomicU64::new(0),
            spill_dir: OnceLock::new(),
            owns_spill_dir: AtomicBool::new(false),
            hits: AtomicUsize::new(0),
            faults: AtomicUsize::new(0),
            cold_serves: AtomicUsize::new(0),
            evictions: AtomicUsize::new(0),
            spill_writes: AtomicUsize::new(0),
            prefetch_pending: Mutex::new(HashSet::new()),
            prefetcher: OnceLock::new(),
            prefetch_hits: AtomicUsize::new(0),
            prefetch_misses: AtomicUsize::new(0),
            prefetch_wasted: AtomicUsize::new(0),
            dedup_logical_rows: AtomicUsize::new(0),
            dedup_stored_rows: AtomicUsize::new(0),
            dedup_zero_rows: AtomicUsize::new(0),
            gather_rows_sorted: AtomicUsize::new(0),
            gather_rows_unsorted: AtomicUsize::new(0),
            cold_counters: Arc::new(ColdCounters::default()),
        }
    }

    /// Record one gather batch's row count against the sorted or
    /// unsorted counter (called by `PStore` after the batch completes).
    pub(super) fn note_gather_rows(&self, rows: usize, sorted: bool) {
        if sorted {
            self.gather_rows_sorted.fetch_add(rows, Ordering::Relaxed);
        } else {
            self.gather_rows_unsorted.fetch_add(rows, Ordering::Relaxed);
        }
    }

    pub fn config(&self) -> &AdapterConfig {
        &self.cfg
    }

    /// Dense-table resident footprint at the configured dtype — an
    /// *estimate* for sizing/demo output only.  Budget accounting uses
    /// each table's own `resident_bytes`/[`ColdTable::resident_cost`],
    /// which are tier- and dedup-aware (int8 sidecars, index, pool).
    pub fn table_bytes(&self) -> usize {
        self.layers * self.vocab * self.d_model * self.cfg.dtype.size()
    }

    fn tick(&self) -> u64 {
        self.clock.fetch_add(1, Ordering::Relaxed)
    }

    fn spill_dir(&self) -> Result<&Path> {
        if let Some(dir) = self.spill_dir.get() {
            return Ok(dir);
        }
        let (dir, owned) = match &self.cfg.spill_dir {
            Some(d) => (d.clone(), false),
            None => {
                let unique = format!(
                    "aotpt-adapters-{}-{:p}",
                    std::process::id(),
                    self as *const _
                );
                (std::env::temp_dir().join(unique), true)
            }
        };
        std::fs::create_dir_all(&dir)
            .with_context(|| format!("create adapter spill dir {}", dir.display()))?;
        let dir = self.spill_dir.get_or_init(|| dir);
        if owned {
            self.owns_spill_dir.store(true, Ordering::Relaxed);
        }
        Ok(dir)
    }

    /// Register (or replace) a task's table.  Always succeeds within disk
    /// limits: a table that cannot fit the RAM budget even after evicting
    /// everything else is written straight to the disk tier.
    ///
    /// Replacement is atomic with respect to concurrent resolves: the new
    /// entry is fully built before it swaps into the map, so a gather
    /// racing a replace sees either the old or the new table — never a
    /// missing task.  The old version is retired after the swap;
    /// in-flight snapshots of it finish unaffected.
    pub fn insert(&self, name: &str, table: Arc<dyn RowSource>) -> Result<()> {
        let need = table.resident_bytes();
        let rows = table.row_stats();
        let generation = self.generation.fetch_add(1, Ordering::Relaxed);
        // Peek the entry being replaced: its resident bytes are about to
        // be freed by the retire below, so they are *discounted* from the
        // budget check (a replace at capacity must not spill the new
        // table), and its pinned flag carries over to the new version.
        let prior = self.entries.read().unwrap().get(name).cloned();
        let (discount, pinned) = match &prior {
            Some(e) => {
                let bytes = match &*e.state.lock().unwrap() {
                    Tier::Resident { table, .. } => table.resident_bytes(),
                    Tier::Spilled { .. } => 0,
                };
                (bytes, e.pinned.load(Ordering::Relaxed))
            }
            None => (0, false),
        };
        drop(prior);
        let tier = if self.try_reserve(need, discount, Some(name)) {
            self.resident_tasks.fetch_add(1, Ordering::Relaxed);
            Tier::Resident { table, spill: None }
        } else {
            let cold = self.write_spill(name, generation, table.as_ref())?;
            self.spilled_tasks.fetch_add(1, Ordering::Relaxed);
            Tier::Spilled { cold }
        };
        let entry = Arc::new(Entry {
            name: name.to_string(),
            generation,
            pinned: AtomicBool::new(pinned),
            last_used: AtomicU64::new(self.tick()),
            prefetched: AtomicBool::new(false),
            state: Mutex::new(tier),
        });
        self.dedup_logical_rows.fetch_add(rows.logical, Ordering::Relaxed);
        self.dedup_stored_rows.fetch_add(rows.stored, Ordering::Relaxed);
        self.dedup_zero_rows.fetch_add(rows.zero_shared, Ordering::Relaxed);
        let old = self.entries.write().unwrap().insert(name.to_string(), entry);
        if let Some(old) = old {
            self.retire(&old);
        }
        Ok(())
    }

    /// Unregister a task.  In-flight gathers holding a snapshot finish
    /// unaffected; the spill file (if any) is deleted best-effort — open
    /// descriptors keep serving on platforms that allow unlink-while-open.
    pub fn remove(&self, name: &str) -> Result<()> {
        let entry = self
            .entries
            .write()
            .unwrap()
            .remove(name)
            .ok_or_else(|| anyhow!("no fused P registered for task {name}"))?;
        self.retire(&entry);
        Ok(())
    }

    /// Release an entry's RAM accounting and spill file after it left the
    /// map (unregister or replace).
    fn retire(&self, entry: &Entry) {
        // A retire blocks on the state lock, so it serializes *after* any
        // in-flight prefetch fault-in of this entry — whatever tier the
        // prefetcher installed is accounted (and freed) right here; no
        // bytes can leak through the race.
        let st = entry.state.lock().unwrap();
        if entry.prefetched.swap(false, Ordering::Relaxed) {
            self.prefetch_wasted.fetch_add(1, Ordering::Relaxed);
        }
        // Row counts are identical on both tiers of one table version,
        // so either source is correct to subtract from the gauges.
        let rows = match &*st {
            Tier::Resident { table, spill } => {
                self.resident_bytes.fetch_sub(table.resident_bytes(), Ordering::Relaxed);
                self.resident_tasks.fetch_sub(1, Ordering::Relaxed);
                if let Some(cold) = spill {
                    let _ = std::fs::remove_file(&cold.path);
                }
                table.row_stats()
            }
            Tier::Spilled { cold } => {
                self.spilled_tasks.fetch_sub(1, Ordering::Relaxed);
                let _ = std::fs::remove_file(&cold.path);
                cold.row_stats()
            }
        };
        self.dedup_logical_rows.fetch_sub(rows.logical, Ordering::Relaxed);
        self.dedup_stored_rows.fetch_sub(rows.stored, Ordering::Relaxed);
        self.dedup_zero_rows.fetch_sub(rows.zero_shared, Ordering::Relaxed);
    }

    /// Pin (or unpin) a task: pinned tasks are never chosen for eviction.
    pub fn pin(&self, name: &str, pinned: bool) -> Result<()> {
        let entry = self.entry(name)?;
        entry.pinned.store(pinned, Ordering::Relaxed);
        Ok(())
    }

    fn entry(&self, name: &str) -> Result<Arc<Entry>> {
        self.entries
            .read()
            .unwrap()
            .get(name)
            .cloned()
            .ok_or_else(|| anyhow!("no fused P registered for task {name}"))
    }

    /// Resolve a task to a gatherable row source, faulting the table in
    /// from disk when the budget allows, and touching its LRU clock.
    pub fn resolve(&self, name: &str) -> Result<Arc<dyn RowSource>> {
        let entry = self.entry(name)?;
        entry.last_used.store(self.tick(), Ordering::Relaxed);
        let mut st = entry.state.lock().unwrap();
        let cold = match &*st {
            Tier::Resident { table, .. } => {
                self.hits.fetch_add(1, Ordering::Relaxed);
                if entry.prefetched.swap(false, Ordering::Relaxed) {
                    // The prefetcher warmed this table before we needed
                    // it — the fault-in latency was hidden (DESIGN.md §11).
                    self.prefetch_hits.fetch_add(1, Ordering::Relaxed);
                }
                return Ok(Arc::clone(table));
            }
            Tier::Spilled { cold } => Arc::clone(cold),
        };
        // Per-table cost, not the dense estimate: a dedup'd or int8
        // table faults back in at exactly this many resident bytes.
        let need = cold.resident_cost();
        if self.try_reserve(need, 0, None) {
            let table = match cold.load_resident() {
                Ok(table) => table,
                Err(e) => {
                    // Roll the reservation back; the table stays spilled.
                    self.resident_bytes.fetch_sub(need, Ordering::Relaxed);
                    return Err(e);
                }
            };
            self.resident_tasks.fetch_add(1, Ordering::Relaxed);
            self.spilled_tasks.fetch_sub(1, Ordering::Relaxed);
            self.faults.fetch_add(1, Ordering::Relaxed);
            *st = Tier::Resident { table: Arc::clone(&table), spill: Some(cold) };
            Ok(table)
        } else {
            // Budget too tight: serve rows straight from disk.
            self.cold_serves.fetch_add(1, Ordering::Relaxed);
            Ok(cold)
        }
    }

    /// Queue background fault-in for every named task currently on the
    /// disk tier (gather-aware prefetch, DESIGN.md §11).  Fire-and-forget:
    /// the prefetch thread faults tables in while the caller goes on to
    /// stage the batch, and the gather's `resolve` finds them warm.
    ///
    /// An associated fn rather than a method because the worker must hold
    /// a `Weak` back-reference (so dropping the store still shuts the
    /// thread down).
    pub fn prefetch(this: &Arc<Residency>, tasks: &[String]) {
        if this.cfg.ram_budget_bytes == 0 {
            return; // unlimited budget: nothing is ever spilled
        }
        for name in tasks {
            // Cheap non-blocking pre-filter: resident tables need no
            // prefetch.  A contended lock means *something* is happening
            // to the entry — queue it and let the worker sort it out.
            let Some(entry) = this.entries.read().unwrap().get(name).cloned() else {
                continue;
            };
            if let Ok(st) = entry.state.try_lock() {
                if matches!(&*st, Tier::Resident { .. }) {
                    continue;
                }
            }
            if !this.prefetch_pending.lock().unwrap().insert(name.clone()) {
                continue; // already queued or in flight
            }
            let prefetcher = this
                .prefetcher
                .get_or_init(|| Prefetcher::spawn(Arc::downgrade(this)));
            let sent = match &*prefetcher.tx.lock().unwrap() {
                Some(tx) => tx.send(name.clone()).is_ok(),
                None => false,
            };
            if !sent {
                // Worker already shut down (teardown): drop the mark.
                this.prefetch_pending.lock().unwrap().remove(name);
            }
        }
    }

    /// Number of prefetches queued or in flight (0 = drained).  Tests use
    /// this to wait for the background worker deterministically.
    pub fn prefetch_backlog(&self) -> usize {
        self.prefetch_pending.lock().unwrap().len()
    }

    /// One background fault-in, on the prefetch thread.  Never blocks on
    /// an entry lock (`try_lock` only) so it cannot stall or deadlock the
    /// serving path; lock order inside matches `resolve` (entry state →
    /// `budget_gate`).
    fn prefetch_one(&self, name: &str) {
        let warmed = self.prefetch_fault_in(name);
        match warmed {
            PrefetchOutcome::Warmed | PrefetchOutcome::AlreadyWarm => {}
            PrefetchOutcome::Cancelled => {
                // Unregistered between queue and dequeue: the prefetch is
                // cancelled.  (An unregister racing the fault-in itself is
                // handled by `retire`, which blocks on the state lock and
                // frees whatever tier it finds.)
                self.prefetch_wasted.fetch_add(1, Ordering::Relaxed);
            }
            PrefetchOutcome::Missed => {
                self.prefetch_misses.fetch_add(1, Ordering::Relaxed);
            }
        }
        // Clear the dedup mark last, so `prefetch_backlog() == 0` implies
        // every counter update above is visible.
        self.prefetch_pending.lock().unwrap().remove(name);
    }

    fn prefetch_fault_in(&self, name: &str) -> PrefetchOutcome {
        let Some(entry) = self.entries.read().unwrap().get(name).cloned() else {
            return PrefetchOutcome::Cancelled;
        };
        let Ok(mut st) = entry.state.try_lock() else {
            // A resolve is already serving (or faulting in) this entry;
            // prefetching now would add nothing.
            return PrefetchOutcome::Missed;
        };
        let cold = match &*st {
            Tier::Resident { .. } => return PrefetchOutcome::AlreadyWarm,
            Tier::Spilled { cold } => Arc::clone(cold),
        };
        let need = cold.resident_cost();
        if !self.try_reserve(need, 0, None) {
            return PrefetchOutcome::Missed;
        }
        match cold.load_resident() {
            Ok(table) => {
                self.resident_tasks.fetch_add(1, Ordering::Relaxed);
                self.spilled_tasks.fetch_sub(1, Ordering::Relaxed);
                entry.prefetched.store(true, Ordering::Relaxed);
                *st = Tier::Resident { table, spill: Some(cold) };
                PrefetchOutcome::Warmed
            }
            Err(e) => {
                // Roll the reservation back; the table stays spilled.
                self.resident_bytes.fetch_sub(need, Ordering::Relaxed);
                crate::warnln!("prefetch of task {name} failed: {e:#}");
                PrefetchOutcome::Missed
            }
        }
    }

    /// Atomically check the budget and reserve `need` bytes, spilling LRU
    /// victims to make room.  `discount` bytes are about to be freed by
    /// the caller (a replace retiring the old version) and relax the
    /// check; `exclude` names an entry that must not be evicted (the one
    /// being replaced — evicting it would waste a spill write).
    ///
    /// The check-and-add runs under `budget_gate`, so concurrent
    /// fault-ins cannot jointly overshoot the budget; eviction only ever
    /// *subtracts* concurrently, which is always safe.  On success the
    /// bytes are already added — a caller whose load then fails must
    /// subtract them back.
    fn try_reserve(&self, need: usize, discount: usize, exclude: Option<&str>) -> bool {
        let budget = self.cfg.ram_budget_bytes;
        if budget == 0 {
            self.resident_bytes.fetch_add(need, Ordering::Relaxed);
            return true;
        }
        if need > budget {
            return false;
        }
        let _gate = self.budget_gate.lock().unwrap();
        let mut contended_tries = 0usize;
        while self.resident_bytes.load(Ordering::Relaxed) + need > budget + discount {
            match self.evict_lru(exclude) {
                EvictOutcome::Evicted => contended_tries = 0,
                EvictOutcome::Contended => {
                    // Every viable victim's lock was held for a moment (a
                    // resolve touching it, or the prefetcher mid-load).
                    // Retry with back-off instead of failing the
                    // reservation while RAM is actually reclaimable; the
                    // bound keeps the cold-serve fallback reachable.
                    contended_tries += 1;
                    if contended_tries > MAX_EVICT_RETRIES {
                        return false;
                    }
                    if contended_tries <= 8 {
                        std::thread::yield_now();
                    } else {
                        std::thread::sleep(std::time::Duration::from_micros(100));
                    }
                }
                EvictOutcome::Exhausted => return false,
            }
        }
        self.resident_bytes.fetch_add(need, Ordering::Relaxed);
        true
    }

    /// Spill the least-recently-used unpinned resident table.  Victims
    /// whose state lock is contended are skipped (no blocking, no
    /// deadlock), but that contention is reported so `try_reserve` can
    /// retry instead of spuriously failing while RAM is reclaimable.
    fn evict_lru(&self, exclude: Option<&str>) -> EvictOutcome {
        let mut candidates: Vec<(u64, Arc<Entry>)> = self
            .entries
            .read()
            .unwrap()
            .values()
            .filter(|e| exclude != Some(e.name.as_str()) && !e.pinned.load(Ordering::Relaxed))
            .map(|e| (e.last_used.load(Ordering::Relaxed), Arc::clone(e)))
            .collect();
        candidates.sort_by_key(|(used, _)| *used);
        let mut saw_contended = false;
        for (_, entry) in candidates {
            let Ok(mut st) = entry.state.try_lock() else {
                saw_contended = true;
                continue;
            };
            // Extract owned values first so no borrow of `st` survives
            // into the tier swap below.
            let spilled = {
                let Tier::Resident { table, spill } = &*st else { continue };
                let cold = match spill {
                    Some(cold) => Arc::clone(cold),
                    None => {
                        match self.write_spill(&entry.name, entry.generation, table.as_ref()) {
                            Ok(cold) => cold,
                            Err(e) => {
                                crate::warnln!("spill of task {} failed: {e:#}", entry.name);
                                continue;
                            }
                        }
                    }
                };
                (table.resident_bytes(), cold)
            };
            let (freed, cold) = spilled;
            self.resident_bytes.fetch_sub(freed, Ordering::Relaxed);
            self.resident_tasks.fetch_sub(1, Ordering::Relaxed);
            self.spilled_tasks.fetch_add(1, Ordering::Relaxed);
            self.evictions.fetch_add(1, Ordering::Relaxed);
            if entry.prefetched.swap(false, Ordering::Relaxed) {
                // Warmed by the prefetcher but evicted before any
                // resolve used it.
                self.prefetch_wasted.fetch_add(1, Ordering::Relaxed);
            }
            *st = Tier::Spilled { cold };
            return EvictOutcome::Evicted;
        }
        if saw_contended {
            EvictOutcome::Contended
        } else {
            EvictOutcome::Exhausted
        }
    }

    /// Write a table to its spill file and open the cold reader.
    ///
    /// The layout is tier-faithful (the faulted-in table is identical to
    /// the one spilled): `p` is the dense `[l, V, d]` payload for dense
    /// tables or the `[1, U, d]` unique-row pool for dedup'd ones, with
    /// `p.index` (dedup) and `p.scale`/`p.zero` (int8) sidecar tensors
    /// as the table requires.
    fn write_spill(&self, name: &str, generation: u64, table: &dyn RowSource) -> Result<Arc<ColdTable>> {
        let safe: String = name
            .chars()
            .map(|c| if c.is_ascii_alphanumeric() || c == '-' || c == '_' { c } else { '_' })
            .collect();
        let path = self.spill_dir()?.join(format!("{safe}-{generation}.aotckpt"));
        let dtype = table.dtype();
        let index = table.dedup_index();
        let quant = table.quant_params();
        let p_shape: Vec<usize> = match index {
            // The pool: one pseudo-layer of U unique rows.
            Some(_) => vec![1, table.row_stats().stored, self.d_model],
            None => vec![self.layers, self.vocab, self.d_model],
        };
        let index_shape = [self.layers, self.vocab];
        let quant_rows = [quant.map_or(0, |(s, _)| s.len())];
        let mut p_payload = |w: &mut dyn std::io::Write| table.spill_into(w);
        let mut index_payload = |w: &mut dyn std::io::Write| -> Result<()> {
            for &ix in index.unwrap() {
                w.write_all(&ix.to_le_bytes())?;
            }
            Ok(())
        };
        let mut scale_payload = |w: &mut dyn std::io::Write| -> Result<()> {
            for &s in quant.unwrap().0 {
                w.write_all(&s.to_le_bytes())?;
            }
            Ok(())
        };
        let mut zero_payload = |w: &mut dyn std::io::Write| -> Result<()> {
            for &z in quant.unwrap().1 {
                w.write_all(&z.to_le_bytes())?;
            }
            Ok(())
        };
        let mut parts: Vec<ckpt::TensorPart<'_>> = Vec::with_capacity(4);
        parts.push(ckpt::TensorPart {
            name: SPILL_TENSOR,
            dtype: dtype.tensor_dtype(),
            shape: &p_shape,
            payload: &mut p_payload,
        });
        if index.is_some() {
            parts.push(ckpt::TensorPart {
                name: SPILL_INDEX,
                // u32 bits stored under the i32 dtype code (same width;
                // the reader reinterprets).
                dtype: DType::I32,
                shape: &index_shape,
                payload: &mut index_payload,
            });
        }
        if quant.is_some() {
            parts.push(ckpt::TensorPart {
                name: SPILL_SCALE,
                dtype: DType::F32,
                shape: &quant_rows,
                payload: &mut scale_payload,
            });
            parts.push(ckpt::TensorPart {
                name: SPILL_ZERO,
                dtype: DType::F32,
                shape: &quant_rows,
                payload: &mut zero_payload,
            });
        }
        ckpt::save_multi_with(&path, &mut parts)?;
        self.spill_writes.fetch_add(1, Ordering::Relaxed);
        let cold = ColdTable::open(
            &path,
            self.layers,
            self.vocab,
            self.d_model,
            dtype,
            index.is_some(),
            self.cfg.mmap,
            Arc::clone(&self.cold_counters),
        )?;
        Ok(Arc::new(cold))
    }

    pub fn names_sorted(&self) -> Vec<String> {
        let mut names: Vec<String> =
            self.entries.read().unwrap().keys().cloned().collect();
        names.sort();
        names
    }

    /// Per-task rows for the management listing (`GET /mgmt/adapters`),
    /// sorted by name.  Uses `try_lock` on each entry's state — the lock
    /// is held across spill/fault-in disk I/O, and the control plane must
    /// never stall the data plane — so a contended entry reports tier
    /// `"busy"` instead of blocking.
    pub fn task_infos(&self) -> Vec<TaskInfo> {
        let entries = self.entries.read().unwrap();
        let mut sorted: Vec<&Arc<Entry>> = entries.values().collect();
        sorted.sort_by(|a, b| a.name.cmp(&b.name));
        let mut out = Vec::with_capacity(sorted.len());
        for entry in sorted {
            let pinned = entry.pinned.load(Ordering::Relaxed);
            let info = match entry.state.try_lock() {
                Ok(state) => match &*state {
                    Tier::Resident { table, .. } => TaskInfo {
                        name: entry.name.clone(),
                        pinned,
                        tier: table.tier(),
                        dtype: table.dtype().name(),
                        resident_bytes: table.resident_bytes(),
                    },
                    Tier::Spilled { cold } => TaskInfo {
                        name: entry.name.clone(),
                        pinned,
                        tier: cold.tier(),
                        dtype: cold.dtype().name(),
                        resident_bytes: cold.resident_bytes(),
                    },
                },
                Err(_) => TaskInfo {
                    name: entry.name.clone(),
                    pinned,
                    tier: "busy",
                    dtype: "",
                    resident_bytes: 0,
                },
            };
            out.push(info);
        }
        out
    }

    pub fn contains(&self, name: &str) -> bool {
        self.entries.read().unwrap().contains_key(name)
    }

    pub fn len(&self) -> usize {
        self.entries.read().unwrap().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn resident_bytes(&self) -> usize {
        self.resident_bytes.load(Ordering::Relaxed)
    }

    /// Lock-free (atomics only): safe to call from the pipeline after
    /// every batch even while another thread holds an entry lock across
    /// spill/fault-in disk I/O.
    pub fn stats(&self) -> AdapterStats {
        AdapterStats {
            resident_bytes: self.resident_bytes.load(Ordering::Relaxed),
            resident_tasks: self.resident_tasks.load(Ordering::Relaxed),
            spilled_tasks: self.spilled_tasks.load(Ordering::Relaxed),
            hits: self.hits.load(Ordering::Relaxed),
            faults: self.faults.load(Ordering::Relaxed),
            cold_serves: self.cold_serves.load(Ordering::Relaxed),
            evictions: self.evictions.load(Ordering::Relaxed),
            spill_writes: self.spill_writes.load(Ordering::Relaxed),
            prefetch_hits: self.prefetch_hits.load(Ordering::Relaxed),
            prefetch_misses: self.prefetch_misses.load(Ordering::Relaxed),
            prefetch_wasted: self.prefetch_wasted.load(Ordering::Relaxed),
            dedup_logical_rows: self.dedup_logical_rows.load(Ordering::Relaxed),
            dedup_stored_rows: self.dedup_stored_rows.load(Ordering::Relaxed),
            dedup_zero_rows: self.dedup_zero_rows.load(Ordering::Relaxed),
            mmap_opens: self.cold_counters.mmap_opens.load(Ordering::Relaxed),
            mmap_fallbacks: self.cold_counters.mmap_fallbacks.load(Ordering::Relaxed),
            mapped_bytes: self.cold_counters.mapped_bytes.load(Ordering::Relaxed),
            cold_rows_mapped: self.cold_counters.rows_mapped.load(Ordering::Relaxed),
            cold_rows_positioned: self.cold_counters.rows_positioned.load(Ordering::Relaxed),
            kernel: super::kernel::active().name,
            gather_rows_sorted: self.gather_rows_sorted.load(Ordering::Relaxed),
            gather_rows_unsorted: self.gather_rows_unsorted.load(Ordering::Relaxed),
        }
    }
}

impl Drop for Residency {
    fn drop(&mut self) {
        // Shut the prefetch worker down first (its spill-file reads must
        // not race the directory removal below).  Dropping the sender
        // wakes the worker out of `recv`; its `Weak` can no longer
        // upgrade, so it exits either way.
        if let Some(p) = self.prefetcher.get_mut() {
            p.tx.get_mut().unwrap().take();
            if let Some(worker) = p.worker.get_mut().unwrap().take() {
                // The worker itself can run this drop (it held the last
                // upgraded `Arc`); joining yourself deadlocks — detach.
                if worker.thread().id() != std::thread::current().id() {
                    let _ = worker.join();
                }
            }
        }
        if !self.owns_spill_dir.load(Ordering::Relaxed) {
            return; // a user-supplied spill dir is left alone
        }
        if let Some(dir) = self.spill_dir.get() {
            let _ = std::fs::remove_dir_all(dir);
        }
    }
}

/// The disk tier: a spilled table served from its `.aotckpt` file —
/// preferably through a read-only mmap established once at open
/// (DESIGN.md §13), falling back to positioned reads where mapping is
/// unavailable.  Rows dequantize into the caller's buffer exactly like
/// the resident tiers, so a cold gather is bit-identical to the
/// resident result of the same storage dtype (exact for f32; the
/// dequantized values for f16/int8), and bit-identical between the
/// mapped and positioned paths (they share one decoder).
///
/// The big `p` payload (codes/pool) stays on disk; the small sidecars —
/// dedup index, int8 scale/zero — are kept resident at open, because a
/// positioned read per row would need them anyway to find and decode the
/// row.  `resident_bytes` still reports 0: sidecars are metadata
/// overhead of the open file handle, and mapped pages are owned by the
/// page cache — neither is budget-managed table storage (see
/// `resident_cost` for what a fault-in will charge).
pub struct ColdTable {
    path: PathBuf,
    file: Mutex<File>,
    /// Whole-file read-only mapping; `None` in positioned-read mode.
    /// Snapshot-safe by construction: in-flight gathers hold the
    /// `Arc<ColdTable>`, so `munmap` runs only when the last reference
    /// drops, after unregister/evict.
    map: Option<Mmap>,
    /// Shared cold-tier counters; the `mapped_bytes` gauge is
    /// decremented in this table's `Drop`.
    counters: Arc<ColdCounters>,
    data_offset: u64,
    layers: usize,
    vocab: usize,
    d_model: usize,
    dtype: AdapterDType,
    /// Physically stored rows behind `data_offset` (`l·V` dense, the
    /// pool's `U` for dedup'd tables).
    stored_rows: usize,
    /// Resident dedup indirection (`None` for dense tables).
    index: Option<Vec<u32>>,
    /// Logical rows mapped to the shared zero row.
    zero_rows: usize,
    /// Resident int8 per-row scale/zero (`None` for exact dtypes).
    scale: Option<Vec<f32>>,
    zero: Option<Vec<f32>>,
}

impl ColdTable {
    /// Open a spill file and validate its header against the store
    /// geometry, dtype and layout (`dedup` says whether a `p.index`
    /// indirection is required).  Rejects stale files whose layout does
    /// not match what the current configuration would have written.
    ///
    /// With `use_mmap` the whole file is mapped read-only once, here,
    /// and rows are decoded straight from the mapping; a failed mapping
    /// (unsupported platform, syscall error) is counted and degrades to
    /// positioned reads — never an open failure.
    #[allow(clippy::too_many_arguments)]
    pub fn open(
        path: &Path,
        layers: usize,
        vocab: usize,
        d_model: usize,
        dtype: AdapterDType,
        dedup: bool,
        use_mmap: bool,
        counters: Arc<ColdCounters>,
    ) -> Result<ColdTable> {
        let meta = ckpt::locate(path, SPILL_TENSOR)?;
        let stored_rows = if dedup {
            if meta.shape.len() != 3 || meta.shape[0] != 1 || meta.shape[2] != d_model {
                bail!(
                    "{}: dedup pool shape {:?} is not [1, U, {d_model}]",
                    path.display(),
                    meta.shape
                );
            }
            meta.shape[1]
        } else {
            if meta.shape != [layers, vocab, d_model] {
                bail!(
                    "{}: spilled table shape {:?} != [{layers}, {vocab}, {d_model}]",
                    path.display(),
                    meta.shape
                );
            }
            layers * vocab
        };
        let want: DType = dtype.tensor_dtype();
        if meta.dtype != want {
            bail!(
                "{}: spilled table dtype {:?} != {:?}",
                path.display(),
                meta.dtype,
                want
            );
        }
        let payload_len = stored_rows * d_model * dtype.size();
        if meta.data_len as usize != payload_len {
            bail!(
                "{}: spilled table payload is {} bytes, expected {payload_len}",
                path.display(),
                meta.data_len
            );
        }
        let sidecar_f32 = |name: &str, want_len: usize| -> Result<Vec<f32>> {
            let m = ckpt::locate(path, name)?;
            if m.dtype != DType::F32 || m.data_len as usize != want_len * 4 {
                bail!("{}: sidecar {name} has wrong dtype/length", path.display());
            }
            let mut raw = vec![0u8; m.data_len as usize];
            read_exact_at_path(path, m.data_offset, &mut raw)?;
            Ok(raw
                .chunks_exact(4)
                .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                .collect())
        };
        let (index, zero_rows) = if dedup {
            let m = ckpt::locate(path, SPILL_INDEX)?;
            let want_len = layers * vocab;
            if m.dtype != DType::I32 || m.data_len as usize != want_len * 4 {
                bail!("{}: dedup index has wrong dtype/length", path.display());
            }
            let mut raw = vec![0u8; m.data_len as usize];
            read_exact_at_path(path, m.data_offset, &mut raw)?;
            let index: Vec<u32> = raw
                .chunks_exact(4)
                .map(|c| u32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                .collect();
            if let Some(&bad) = index.iter().find(|&&ix| ix as usize > stored_rows) {
                bail!("{}: dedup index entry {bad} exceeds pool of {stored_rows}", path.display());
            }
            let zeros = index.iter().filter(|&&ix| ix == 0).count();
            (Some(index), zeros)
        } else {
            (None, 0)
        };
        let (scale, zero) = if dtype == AdapterDType::I8 {
            (
                Some(sidecar_f32(SPILL_SCALE, stored_rows)?),
                Some(sidecar_f32(SPILL_ZERO, stored_rows)?),
            )
        } else {
            (None, None)
        };
        let file = File::open(path).with_context(|| format!("open {}", path.display()))?;
        let map = if use_mmap {
            match Mmap::map_file(&file) {
                Ok(m) => {
                    // `locate` already validated the payload extent
                    // against the file length, but the file could have
                    // been truncated between that read and the mapping —
                    // and a mapped load past EOF is SIGBUS, not an error.
                    // Re-check against the mapping itself.
                    if meta.data_offset + payload_len as u64 > m.len() as u64 {
                        bail!(
                            "{}: mapping of {} bytes ends before the payload at [{}, {}) (truncated)",
                            path.display(),
                            m.len(),
                            meta.data_offset,
                            meta.data_offset + payload_len as u64
                        );
                    }
                    counters.mmap_opens.fetch_add(1, Ordering::Relaxed);
                    counters.mapped_bytes.fetch_add(m.len(), Ordering::Relaxed);
                    Some(m)
                }
                Err(e) => {
                    counters.mmap_fallbacks.fetch_add(1, Ordering::Relaxed);
                    crate::warnln!(
                        "mmap of {} unavailable ({e:#}); serving cold rows by positioned reads",
                        path.display()
                    );
                    None
                }
            }
        } else {
            None
        };
        Ok(ColdTable {
            path: path.to_path_buf(),
            file: Mutex::new(file),
            map,
            counters,
            data_offset: meta.data_offset,
            layers,
            vocab,
            d_model,
            dtype,
            stored_rows,
            index,
            zero_rows,
            scale,
            zero,
        })
    }

    pub fn path(&self) -> &Path {
        &self.path
    }

    /// Exactly the `resident_bytes` the faulted-in table will report —
    /// `resolve`/prefetch reserve this many budget bytes before loading,
    /// so accounting cannot drift across spill/fault-in cycles.
    pub fn resident_cost(&self) -> usize {
        let mut cost = self.stored_rows * self.d_model * self.dtype.size();
        if self.dtype == AdapterDType::I8 {
            cost += self.stored_rows * 8; // f32 scale + zero per row
        }
        if let Some(ix) = &self.index {
            cost += ix.len() * 4;
        }
        cost
    }

    fn read_at(&self, byte_offset: u64, buf: &mut [u8]) -> Result<()> {
        let file = self.file.lock().unwrap();
        read_full_at(&*file, self.data_offset + byte_offset, buf)
            .with_context(|| format!("read {}", self.path.display()))
    }

    /// Decode one *stored* row (by physical index) into `out`.
    fn read_stored_row(&self, stored: usize, out: &mut [f32]) -> Result<()> {
        let d = self.d_model;
        let esize = self.dtype.size();
        let offset = (stored * d * esize) as u64;
        if let Some(map) = &self.map {
            // Mapped cold serve: dequantize straight out of the page
            // cache — no read syscall, no scratch copy (DESIGN.md §13).
            let raw = map.slice(self.data_offset + offset, d * esize)?;
            self.counters.rows_mapped.fetch_add(1, Ordering::Relaxed);
            return self.decode_row(stored, raw, out);
        }
        // The positioned-read path allocates a row-sized scratch read;
        // only gathers that miss both RAM tiers and the mapping pay this
        // (the resident hot path stays allocation-free, DESIGN.md §9).
        let mut raw = vec![0u8; d * esize];
        self.read_at(offset, &mut raw)?;
        self.counters.rows_positioned.fetch_add(1, Ordering::Relaxed);
        self.decode_row(stored, &raw, out)
    }

    /// Dequantize one stored row's raw bytes into `out` — shared by the
    /// mapped and positioned cold paths, so the two are bit-identical by
    /// construction.
    fn decode_row(&self, stored: usize, raw: &[u8], out: &mut [f32]) -> Result<()> {
        let k = super::kernel::active();
        match self.dtype {
            AdapterDType::F32 => k.decode_f32_le(raw, out),
            AdapterDType::F16 => k.dequant_f16_le(raw, out),
            AdapterDType::I8 => {
                let scale = self.scale.as_ref().expect("i8 cold table has scale")[stored];
                let zero = self.zero.as_ref().expect("i8 cold table has zero")[stored];
                k.dequant_i8_bytes(raw, scale, zero, out);
            }
        }
        Ok(())
    }

    /// Fault the whole table back into a resident source of the same
    /// tier shape (dense stays dense, dedup'd stays dedup'd).  The
    /// faulted-in copy is *real* RAM (charged against the budget), so
    /// the payload is copied out of the mapping — or read — either way.
    pub fn load_resident(&self) -> Result<Arc<dyn RowSource>> {
        let elems = self.stored_rows * self.d_model;
        let nbytes = elems * self.dtype.size();
        let raw: Vec<u8> = match &self.map {
            Some(map) => map.slice(self.data_offset, nbytes)?.to_vec(),
            None => {
                let mut raw = vec![0u8; nbytes];
                self.read_at(0, &mut raw)?;
                raw
            }
        };
        // The stored payload's geometry: the full table for dense spills,
        // the `[1, U, d]` pool for dedup'd ones.
        let (l, v) = match &self.index {
            Some(_) => (1, self.stored_rows),
            None => (self.layers, self.vocab),
        };
        let dense: Arc<dyn RowSource> = match self.dtype {
            AdapterDType::F32 => {
                let data: Vec<f32> = raw
                    .chunks_exact(4)
                    .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                    .collect();
                Arc::new(TaskP::new(l, v, self.d_model, data)?)
            }
            AdapterDType::F16 => {
                let data: Vec<u16> = raw
                    .chunks_exact(2)
                    .map(|c| u16::from_le_bytes([c[0], c[1]]))
                    .collect();
                Arc::new(QuantizedTaskP::new(l, v, self.d_model, data)?)
            }
            AdapterDType::I8 => {
                let data: Vec<i8> = raw.iter().map(|&b| b as i8).collect();
                Arc::new(Int8TaskP::new(
                    l,
                    v,
                    self.d_model,
                    data,
                    self.scale.clone().expect("i8 cold table has scale"),
                    self.zero.clone().expect("i8 cold table has zero"),
                )?)
            }
        };
        match &self.index {
            Some(ix) => Ok(Arc::new(DedupTaskP::new(
                self.layers,
                self.vocab,
                self.d_model,
                ix.clone(),
                dense,
            )?)),
            None => Ok(dense),
        }
    }
}

impl Drop for ColdTable {
    fn drop(&mut self) {
        // The mapped-bytes gauge comes down only here — on the *last*
        // reference — so it correctly includes mappings kept alive by
        // in-flight gather snapshots after unregister/evict, and settles
        // to zero exactly when the last such snapshot drops.
        if let Some(m) = &self.map {
            self.counters.mapped_bytes.fetch_sub(m.len(), Ordering::Relaxed);
        }
    }
}

/// One positioned-read attempt, syscall-shaped: it may return fewer
/// bytes than asked (a short read) or fail with `EINTR`.  The retry
/// loop lives in [`read_full_at`]; tests drive it through a pipe-like
/// shim that doles bytes out a few at a time and injects interruptions.
pub(crate) trait ReadAt {
    fn read_at_offset(&self, buf: &mut [u8], offset: u64) -> std::io::Result<usize>;
}

impl ReadAt for File {
    fn read_at_offset(&self, buf: &mut [u8], offset: u64) -> std::io::Result<usize> {
        #[cfg(unix)]
        {
            use std::os::unix::fs::FileExt;
            self.read_at(buf, offset)
        }
        #[cfg(not(unix))]
        {
            use std::io::{Read, Seek, SeekFrom};
            let mut f = self;
            f.seek(SeekFrom::Start(offset))?;
            f.read(buf)
        }
    }
}

/// Fill `buf` from `offset`, retrying short reads and `EINTR` instead
/// of erroring on partial reads (a pipe- or network-backed spill store
/// legally returns them).  Running out of data mid-range is a typed
/// error — a truncated spill file fails the affected request, it never
/// panics.
pub(crate) fn read_full_at<R: ReadAt + ?Sized>(
    src: &R,
    mut offset: u64,
    mut buf: &mut [u8],
) -> Result<()> {
    while !buf.is_empty() {
        match src.read_at_offset(buf, offset) {
            Ok(0) => bail!(
                "unexpected end of file at offset {offset} ({} bytes missing)",
                buf.len()
            ),
            Ok(n) => {
                let rest = buf;
                buf = &mut rest[n..];
                offset += n as u64;
            }
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
            Err(e) => return Err(e.into()),
        }
    }
    Ok(())
}

/// Positioned read during `ColdTable::open`, before the long-lived file
/// handle exists.
fn read_exact_at_path(path: &Path, offset: u64, buf: &mut [u8]) -> Result<()> {
    let file = File::open(path).with_context(|| format!("open {}", path.display()))?;
    read_full_at(&file, offset, buf).with_context(|| format!("read {}", path.display()))
}

impl RowSource for ColdTable {
    fn layers(&self) -> usize {
        self.layers
    }

    fn vocab(&self) -> usize {
        self.vocab
    }

    fn d_model(&self) -> usize {
        self.d_model
    }

    fn dtype(&self) -> AdapterDType {
        self.dtype
    }

    fn tier(&self) -> &'static str {
        "disk"
    }

    fn resident_bytes(&self) -> usize {
        0
    }

    fn copy_row(&self, layer: usize, token: usize, out: &mut [f32]) -> Result<()> {
        match &self.index {
            Some(ix) => match ix[layer * self.vocab + token] {
                0 => {
                    out.fill(0.0);
                    Ok(())
                }
                slot => self.read_stored_row((slot - 1) as usize, out),
            },
            None => self.read_stored_row(layer * self.vocab + token, out),
        }
    }

    fn spill_into(&self, _w: &mut dyn std::io::Write) -> Result<()> {
        bail!("disk-tier table is already spilled")
    }

    fn quant_params(&self) -> Option<(&[f32], &[f32])> {
        match (&self.scale, &self.zero) {
            (Some(s), Some(z)) => Some((s, z)),
            _ => None,
        }
    }

    fn dedup_index(&self) -> Option<&[u32]> {
        self.index.as_deref()
    }

    fn row_stats(&self) -> RowCounts {
        RowCounts {
            logical: self.layers * self.vocab,
            stored: self.stored_rows,
            zero_shared: self.zero_rows,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Pcg64;

    fn table(seed: u64, l: usize, v: usize, d: usize) -> Arc<dyn RowSource> {
        let mut rng = Pcg64::new(seed);
        Arc::new(TaskP::new(l, v, d, rng.normal_vec(l * v * d, 1.0)).unwrap())
    }

    fn constant_table(c: f32, l: usize, v: usize, d: usize) -> Arc<dyn RowSource> {
        Arc::new(TaskP::new(l, v, d, vec![c; l * v * d]).unwrap())
    }

    fn row_of(src: &dyn RowSource, layer: usize, tok: usize) -> Vec<f32> {
        let mut out = vec![0f32; src.d_model()];
        src.copy_row(layer, tok, &mut out).unwrap();
        out
    }

    #[test]
    fn parse_bytes_accepts_suffixes() {
        assert_eq!(parse_bytes("0").unwrap(), 0);
        assert_eq!(parse_bytes("unlimited").unwrap(), 0);
        assert_eq!(parse_bytes("1024").unwrap(), 1024);
        assert_eq!(parse_bytes("4k").unwrap(), 4096);
        assert_eq!(parse_bytes("2MiB").unwrap(), 2 << 20);
        assert_eq!(parse_bytes("1.5g").unwrap(), 3 << 29);
        assert_eq!(parse_bytes("512b").unwrap(), 512);
        assert!(parse_bytes("nope").is_err());
        assert!(parse_bytes("-1").is_err());
    }

    #[test]
    fn unlimited_budget_keeps_everything_resident() {
        let (l, v, d) = (2, 16, 4);
        let r = Residency::new(l, v, d, AdapterConfig::default());
        for i in 0..4 {
            r.insert(&format!("t{i}"), table(i as u64 + 1, l, v, d)).unwrap();
        }
        let s = r.stats();
        assert_eq!(s.resident_tasks, 4);
        assert_eq!(s.spilled_tasks, 0);
        assert_eq!(s.resident_bytes, 4 * l * v * d * 4);
        assert_eq!(s.evictions, 0);
    }

    #[test]
    fn over_budget_spills_lru_and_faults_back() {
        let (l, v, d) = (2, 16, 4);
        let bytes = l * v * d * 4;
        // Budget fits exactly two tables.
        let cfg = AdapterConfig { ram_budget_bytes: 2 * bytes, ..Default::default() };
        let r = Residency::new(l, v, d, cfg);
        r.insert("a", constant_table(1.0, l, v, d)).unwrap();
        r.insert("b", constant_table(2.0, l, v, d)).unwrap();
        assert_eq!(r.stats().resident_tasks, 2);
        // Touch a so b becomes the LRU, then insert c: b must spill.
        let _ = r.resolve("a").unwrap();
        r.insert("c", constant_table(3.0, l, v, d)).unwrap();
        let s = r.stats();
        assert_eq!(s.resident_tasks, 2);
        assert_eq!(s.spilled_tasks, 1);
        assert_eq!(s.evictions, 1);
        assert_eq!(s.spill_writes, 1);
        assert_eq!(s.resident_bytes, 2 * bytes);
        // b still serves (fault-in evicts the new LRU) with exact values.
        let src = r.resolve("b").unwrap();
        assert_eq!(row_of(src.as_ref(), 1, 3), vec![2.0; d]);
        assert_eq!(r.stats().faults, 1);
        // All three keep serving correct values in any order.
        for (name, c) in [("a", 1.0f32), ("c", 3.0), ("b", 2.0)] {
            let src = r.resolve(name).unwrap();
            assert_eq!(row_of(src.as_ref(), 0, 0), vec![c; d], "task {name}");
        }
    }

    #[test]
    fn budget_below_one_table_serves_cold_bit_identical() {
        let (l, v, d) = (2, 20, 4);
        let bytes = l * v * d * 4;
        let cfg = AdapterConfig { ram_budget_bytes: bytes / 2, ..Default::default() };
        let r = Residency::new(l, v, d, cfg);
        let mut rng = Pcg64::new(5);
        let data = rng.normal_vec(l * v * d, 1.0);
        let reference = TaskP::new(l, v, d, data.clone()).unwrap();
        r.insert("x", Arc::new(TaskP::new(l, v, d, data).unwrap())).unwrap();
        let s = r.stats();
        assert_eq!(s.resident_tasks, 0);
        assert_eq!(s.spilled_tasks, 1);
        let src = r.resolve("x").unwrap();
        assert_eq!(src.tier(), "disk");
        assert_eq!(r.stats().cold_serves, 1);
        // Disk-tier rows are bit-identical to the resident f32 rows.
        for layer in 0..l {
            for tok in 0..v {
                let got = row_of(src.as_ref(), layer, tok);
                assert_eq!(got.as_slice(), reference.row(layer, tok));
            }
        }
    }

    #[test]
    fn pinned_tasks_are_never_evicted() {
        let (l, v, d) = (1, 16, 4);
        let bytes = l * v * d * 4;
        let cfg = AdapterConfig { ram_budget_bytes: bytes, ..Default::default() };
        let r = Residency::new(l, v, d, cfg);
        r.insert("keep", constant_table(7.0, l, v, d)).unwrap();
        r.pin("keep", true).unwrap();
        // A second insert cannot evict the pinned table: it spills itself.
        r.insert("other", constant_table(8.0, l, v, d)).unwrap();
        let s = r.stats();
        assert_eq!(s.resident_tasks, 1);
        assert_eq!(s.spilled_tasks, 1);
        let src = r.resolve("keep").unwrap();
        assert_ne!(src.tier(), "disk");
        // Unpin: now "other" can fault in and evict "keep".
        r.pin("keep", false).unwrap();
        let src = r.resolve("other").unwrap();
        assert_ne!(src.tier(), "disk");
        assert_eq!(row_of(src.as_ref(), 0, 1), vec![8.0; d]);
        assert!(r.stats().evictions >= 1);
    }

    #[test]
    fn replace_at_capacity_stays_resident_and_keeps_pin() {
        let (l, v, d) = (1, 16, 4);
        let bytes = l * v * d * 4;
        let cfg = AdapterConfig { ram_budget_bytes: bytes, ..Default::default() };
        let r = Residency::new(l, v, d, cfg);
        r.insert("x", constant_table(1.0, l, v, d)).unwrap();
        r.pin("x", true).unwrap();
        // The old version's bytes are freed by the replace, so the new
        // version must land resident — no spill write, no fault-in.
        r.insert("x", constant_table(2.0, l, v, d)).unwrap();
        let s = r.stats();
        assert_eq!(s.resident_tasks, 1, "{s:?}");
        assert_eq!(s.spilled_tasks, 0, "{s:?}");
        assert_eq!(s.spill_writes, 0, "replace at capacity must not spill: {s:?}");
        assert_eq!(s.resident_bytes, bytes);
        let src = r.resolve("x").unwrap();
        assert_eq!(row_of(src.as_ref(), 0, 0), vec![2.0; d]);
        // The pin survives the replace: a competitor cannot evict x.
        r.insert("y", constant_table(3.0, l, v, d)).unwrap();
        assert_ne!(r.resolve("x").unwrap().tier(), "disk");
        assert_eq!(r.resolve("y").unwrap().tier(), "disk");
    }

    #[test]
    fn remove_frees_budget_and_errors_on_missing() {
        let (l, v, d) = (1, 8, 4);
        let r = Residency::new(l, v, d, AdapterConfig::default());
        r.insert("x", constant_table(1.0, l, v, d)).unwrap();
        assert_eq!(r.resident_bytes(), l * v * d * 4);
        r.remove("x").unwrap();
        assert_eq!(r.resident_bytes(), 0);
        assert!(r.remove("x").is_err());
        assert!(r.resolve("x").is_err());
    }

    #[test]
    fn replace_serves_the_new_table() {
        let (l, v, d) = (1, 8, 4);
        let r = Residency::new(l, v, d, AdapterConfig::default());
        r.insert("x", constant_table(1.0, l, v, d)).unwrap();
        let old = r.resolve("x").unwrap();
        r.insert("x", constant_table(2.0, l, v, d)).unwrap();
        // The in-flight snapshot still reads the old version...
        assert_eq!(row_of(old.as_ref(), 0, 0), vec![1.0; d]);
        // ...while new resolves see the replacement.
        let new = r.resolve("x").unwrap();
        assert_eq!(row_of(new.as_ref(), 0, 0), vec![2.0; d]);
        assert_eq!(r.len(), 1);
    }

    #[test]
    fn f16_residency_spills_and_reloads_quantized() {
        let (l, v, d) = (2, 12, 4);
        let bytes16 = l * v * d * 2;
        let cfg = AdapterConfig {
            ram_budget_bytes: bytes16,
            dtype: AdapterDType::F16,
            ..Default::default()
        };
        let r = Residency::new(l, v, d, cfg);
        let mut rng = Pcg64::new(8);
        let a = rng.normal_vec(l * v * d, 1.0);
        let b = rng.normal_vec(l * v * d, 1.0);
        let pa = TaskP::new(l, v, d, a.clone()).unwrap();
        let pb = TaskP::new(l, v, d, b.clone()).unwrap();
        r.insert("a", Arc::new(QuantizedTaskP::from_taskp(&pa))).unwrap();
        r.insert("b", Arc::new(QuantizedTaskP::from_taskp(&pb))).unwrap();
        // Ping-pong so both spill and fault at least once.
        for _ in 0..3 {
            for (name, data) in [("a", &a), ("b", &b)] {
                let src = r.resolve(name).unwrap();
                let got = row_of(src.as_ref(), 1, 5);
                for (k, &g) in got.iter().enumerate() {
                    let want = data[(v + 5) * d + k];
                    assert!((g - want).abs() < 1e-2, "{name} k{k}: {g} vs {want}");
                }
            }
        }
        let s = r.stats();
        assert!(s.evictions >= 1, "expected evictions, got {s:?}");
        assert!(s.faults >= 1, "expected faults, got {s:?}");
        assert!(s.resident_bytes <= bytes16);
    }

    /// The satellite regression test: a single contended victim must not
    /// make a reservation spuriously fail while RAM is reclaimable.  The
    /// seed's `try_lock`-only eviction returned `false` immediately here
    /// and the fault-in degraded to a cold serve.
    #[test]
    fn contended_victim_retries_instead_of_spurious_failure() {
        let (l, v, d) = (1, 16, 4);
        let bytes = l * v * d * 4;
        let cfg = AdapterConfig { ram_budget_bytes: bytes, ..Default::default() };
        let r = Arc::new(Residency::new(l, v, d, cfg));
        r.insert("victim", constant_table(1.0, l, v, d)).unwrap();
        r.pin("victim", true).unwrap();
        // With the budget full and "victim" pinned, "faulter" spills.
        r.insert("faulter", constant_table(2.0, l, v, d)).unwrap();
        assert_eq!(r.stats().spilled_tasks, 1);
        r.pin("victim", false).unwrap();

        // Hold the victim's state lock (as an in-flight resolve would)
        // while another thread faults "faulter" in.
        let victim = r.entry("victim").unwrap();
        let guard = victim.state.lock().unwrap();
        let (started_tx, started_rx) = std::sync::mpsc::channel();
        let resolver = {
            let r = Arc::clone(&r);
            std::thread::spawn(move || {
                started_tx.send(()).unwrap();
                r.resolve("faulter").unwrap()
            })
        };
        started_rx.recv().unwrap();
        // Keep the lock contended long enough that the resolver has
        // certainly entered its eviction loop.
        std::thread::sleep(std::time::Duration::from_millis(5));
        drop(guard);
        let src = resolver.join().unwrap();
        // The fix: the resolver retried, evicted the victim once its lock
        // freed, and served resident — no spurious cold serve.
        assert_ne!(src.tier(), "disk", "fault-in fell back to a cold serve");
        let s = r.stats();
        assert!(s.evictions >= 1, "{s:?}");
        assert_eq!(s.cold_serves, 0, "{s:?}");
    }

    #[test]
    fn prefetch_warms_spilled_table_and_counts_hit() {
        let (l, v, d) = (1, 16, 4);
        let bytes = l * v * d * 4;
        let cfg = AdapterConfig { ram_budget_bytes: 2 * bytes, ..Default::default() };
        let r = Arc::new(Residency::new(l, v, d, cfg));
        r.insert("a", constant_table(1.0, l, v, d)).unwrap();
        r.insert("b", constant_table(2.0, l, v, d)).unwrap();
        r.insert("c", constant_table(3.0, l, v, d)).unwrap(); // evicts "a"
        assert_eq!(r.stats().spilled_tasks, 1);

        Residency::prefetch(&r, &["a".to_string(), "b".to_string()]);
        for _ in 0..2000 {
            if r.prefetch_backlog() == 0 {
                break;
            }
            std::thread::sleep(std::time::Duration::from_millis(1));
        }
        assert_eq!(r.prefetch_backlog(), 0, "prefetch did not drain");

        // "a" was warmed in the background; the resolve is a hit that
        // never touches the disk path, and the hit is attributed.
        let src = r.resolve("a").unwrap();
        assert_ne!(src.tier(), "disk");
        assert_eq!(row_of(src.as_ref(), 0, 0), vec![1.0; d]);
        let s = r.stats();
        assert_eq!(s.prefetch_hits, 1, "{s:?}");
        // "b" was already resident: filtered out before queueing.
        assert_eq!(s.prefetch_misses, 0, "{s:?}");
        // A second resolve of "a" is a plain hit, not a prefetch hit.
        let _ = r.resolve("a").unwrap();
        assert_eq!(r.stats().prefetch_hits, 1);
    }

    #[test]
    fn prefetch_of_unregistered_task_is_cancelled_not_leaked() {
        let (l, v, d) = (1, 16, 4);
        let bytes = l * v * d * 4;
        let cfg = AdapterConfig { ram_budget_bytes: bytes, ..Default::default() };
        let r = Arc::new(Residency::new(l, v, d, cfg));
        r.insert("x", constant_table(1.0, l, v, d)).unwrap();
        r.pin("x", true).unwrap();
        // x is pinned and fills the budget, so y spills itself.
        r.insert("y", constant_table(2.0, l, v, d)).unwrap();
        assert_eq!(r.stats().spilled_tasks, 1);
        r.remove("y").unwrap();
        // Drive the worker path deterministically: a dequeued prefetch
        // for a task that vanished is cancelled and counted wasted.
        r.prefetch_one("y");
        let s = r.stats();
        assert_eq!(s.prefetch_wasted, 1, "{s:?}");
        assert_eq!(s.resident_bytes, bytes, "only x's bytes remain");
        r.remove("x").unwrap();
        assert_eq!(r.stats().resident_bytes, 0, "no leaked residency bytes");
    }

    #[test]
    fn prefetched_table_evicted_unused_counts_wasted() {
        let (l, v, d) = (1, 16, 4);
        let bytes = l * v * d * 4;
        let cfg = AdapterConfig { ram_budget_bytes: bytes, ..Default::default() };
        let r = Arc::new(Residency::new(l, v, d, cfg));
        r.insert("a", constant_table(1.0, l, v, d)).unwrap();
        r.pin("a", true).unwrap();
        r.insert("b", constant_table(2.0, l, v, d)).unwrap(); // spills itself
        r.pin("a", false).unwrap();
        // Deterministic worker call: warm "b" (evicts "a").
        r.prefetch_one("b");
        assert_eq!(r.stats().evictions, 1);
        // Now fault "a" back in before anything resolves "b": the
        // prefetched "b" is evicted unused → wasted.
        let _ = r.resolve("a").unwrap();
        let s = r.stats();
        assert_eq!(s.prefetch_wasted, 1, "{s:?}");
        assert_eq!(s.prefetch_hits, 0, "{s:?}");
        assert!(s.resident_bytes <= bytes);
    }

    /// Int8 tables must survive a spill/fault-in cycle *tier-faithfully*:
    /// the `.aotckpt` stores the codes plus scale/zero sidecars, and both
    /// the cold positioned reads and the faulted-in table dequantize
    /// bit-identically to the original resident int8 tier.
    #[test]
    fn int8_spill_and_fault_in_are_tier_faithful() {
        let (l, v, d) = (2, 12, 8);
        let mut rng = Pcg64::new(31);
        let p = TaskP::new(l, v, d, rng.normal_vec(l * v * d, 1.0)).unwrap();
        let resident = Int8TaskP::from_taskp(&p);
        let mut want = Vec::new();
        for layer in 0..l {
            for tok in 0..v {
                want.push(row_of(&resident, layer, tok));
            }
        }
        let bytes = resident.resident_bytes();
        assert_eq!(bytes, l * v * d + l * v * 8);
        let cfg = AdapterConfig {
            ram_budget_bytes: bytes,
            dtype: AdapterDType::I8,
            ..Default::default()
        };
        let r = Residency::new(l, v, d, cfg);
        r.insert("a", Arc::new(resident)).unwrap();
        assert_eq!(r.resident_bytes(), bytes);
        let q2 = Int8TaskP::from_taskp(&TaskP::new(l, v, d, rng.normal_vec(l * v * d, 1.0)).unwrap());
        r.insert("b", Arc::new(q2)).unwrap(); // evicts "a" to disk
        assert_eq!(r.stats().spilled_tasks, 1);
        // Cold serve (pin "b" so "a" cannot fault in): positioned reads
        // decode through the resident scale/zero sidecars, bit-exactly.
        r.pin("b", true).unwrap();
        let cold = r.resolve("a").unwrap();
        assert_eq!(cold.tier(), "disk");
        assert_eq!(cold.dtype(), AdapterDType::I8);
        for layer in 0..l {
            for tok in 0..v {
                assert_eq!(row_of(cold.as_ref(), layer, tok), want[layer * v + tok], "cold l{layer} t{tok}");
            }
        }
        // Fault-in: the reloaded table is the same tier at the same cost.
        r.pin("b", false).unwrap();
        let warm = r.resolve("a").unwrap();
        assert_eq!(warm.tier(), "ram-int8");
        for layer in 0..l {
            for tok in 0..v {
                assert_eq!(row_of(warm.as_ref(), layer, tok), want[layer * v + tok], "warm l{layer} t{tok}");
            }
        }
        // `ColdTable::resident_cost` promised exactly the faulted-in
        // footprint — accounting is exact, not estimated.
        assert_eq!(warm.resident_bytes(), bytes);
        assert_eq!(r.resident_bytes(), bytes);
    }

    /// A dedup'd table spills as pool + index (+ int8 sidecars) and
    /// faults back in as the same dedup'd int8 tier: same row stats, same
    /// bytes, bit-identical rows, and the gauges return to zero on remove.
    #[test]
    fn dedup_spill_and_fault_in_keep_index_and_pool() {
        let (l, v, d) = (2, 16, 4);
        // Tokens 0..8 fuse to zero in both layers; tokens 8 and 9 share
        // one bit-identical row; the rest are distinct.
        let mut data = vec![0f32; l * v * d];
        for layer in 0..l {
            for tok in 8..v {
                let row = &mut data[(layer * v + tok) * d..(layer * v + tok + 1) * d];
                let base = if tok < 10 { 1.0 } else { (layer * v + tok) as f32 };
                for (k, x) in row.iter_mut().enumerate() {
                    *x = base + k as f32;
                }
            }
        }
        let p = TaskP::new(l, v, d, data).unwrap();
        let plan = crate::peft::fuse::dedup_rows(&p, 0.0);
        let make = || {
            Arc::new(DedupTaskP::from_plan(l, v, &plan, AdapterDType::I8).unwrap())
                as Arc<dyn RowSource>
        };
        let table = make();
        let mut want = Vec::new();
        for layer in 0..l {
            for tok in 0..v {
                want.push(row_of(table.as_ref(), layer, tok));
            }
        }
        let bytes = table.resident_bytes();
        let counts = table.row_stats();
        assert_eq!(counts.logical, l * v);
        assert_eq!(counts.stored, plan.unique_rows());
        assert_eq!(counts.zero_shared, plan.zero_rows);
        let cfg = AdapterConfig {
            ram_budget_bytes: bytes,
            dtype: AdapterDType::I8,
            dedup: true,
            ..Default::default()
        };
        let r = Residency::new(l, v, d, cfg);
        r.insert("a", table).unwrap();
        let s = r.stats();
        assert_eq!(s.resident_bytes, bytes);
        assert_eq!(
            (s.dedup_logical_rows, s.dedup_stored_rows, s.dedup_zero_rows),
            (counts.logical, counts.stored, counts.zero_shared)
        );
        r.insert("b", make()).unwrap(); // evicts "a" to disk
        assert_eq!(r.stats().spilled_tasks, 1);
        // Row counts are tier-invariant: the spilled "a" still counts.
        assert_eq!(r.stats().dedup_logical_rows, 2 * counts.logical);
        // Cold serve goes through the resident index (zero rows never
        // touch the file), bit-exactly.
        r.pin("b", true).unwrap();
        let cold = r.resolve("a").unwrap();
        assert_eq!(cold.tier(), "disk");
        assert_eq!(cold.row_stats(), counts);
        for layer in 0..l {
            for tok in 0..v {
                assert_eq!(row_of(cold.as_ref(), layer, tok), want[layer * v + tok], "cold l{layer} t{tok}");
            }
        }
        // Fault back in: same dedup'd int8 tier, exact same footprint.
        r.pin("b", false).unwrap();
        let warm = r.resolve("a").unwrap();
        assert_eq!(warm.tier(), "ram-int8+dedup");
        assert_eq!(warm.row_stats(), counts);
        assert_eq!(warm.resident_bytes(), bytes);
        for layer in 0..l {
            for tok in 0..v {
                assert_eq!(row_of(warm.as_ref(), layer, tok), want[layer * v + tok], "warm l{layer} t{tok}");
            }
        }
        assert_eq!(r.resident_bytes(), bytes);
        // Retiring both tasks returns every gauge exactly to zero.
        r.remove("a").unwrap();
        r.remove("b").unwrap();
        let s = r.stats();
        assert_eq!(s.resident_bytes, 0);
        assert_eq!(
            (s.dedup_logical_rows, s.dedup_stored_rows, s.dedup_zero_rows),
            (0, 0, 0)
        );
    }

    /// Satellite regression: positioned cold reads must survive a reader
    /// that returns partial reads and `EINTR` (pipe semantics) instead of
    /// erroring, and must report running out of data as a typed error.
    #[test]
    fn read_full_at_retries_short_reads_and_interrupts() {
        /// A pipe-backed reader shim: at most `chunk` bytes per call,
        /// with an injected `EINTR` before every other attempt.
        struct PipeReader {
            data: Vec<u8>,
            chunk: usize,
            calls: AtomicUsize,
        }
        impl ReadAt for PipeReader {
            fn read_at_offset(&self, buf: &mut [u8], offset: u64) -> std::io::Result<usize> {
                if self.calls.fetch_add(1, Ordering::Relaxed) % 2 == 0 {
                    return Err(std::io::Error::from(std::io::ErrorKind::Interrupted));
                }
                let off = offset as usize;
                if off >= self.data.len() {
                    return Ok(0);
                }
                let n = buf.len().min(self.chunk).min(self.data.len() - off);
                buf[..n].copy_from_slice(&self.data[off..off + n]);
                Ok(n)
            }
        }

        let data: Vec<u8> = (0..100u8).collect();
        let pipe = PipeReader { data: data.clone(), chunk: 7, calls: AtomicUsize::new(0) };
        let mut buf = vec![0u8; 100];
        read_full_at(&pipe, 0, &mut buf).unwrap();
        assert_eq!(buf, data);
        // An offset read stitches the same bytes together.
        let mut mid = vec![0u8; 20];
        read_full_at(&pipe, 40, &mut mid).unwrap();
        assert_eq!(mid, data[40..60]);
        // Running out of data mid-range is a typed error, not a panic or
        // a hang.
        let mut over = vec![0u8; 10];
        let err = read_full_at(&pipe, 95, &mut over).unwrap_err();
        assert!(err.to_string().contains("unexpected end of file"), "{err}");
    }

    #[test]
    fn mmap_off_serves_cold_by_positioned_reads_only() {
        let (l, v, d) = (1, 16, 4);
        let bytes = l * v * d * 4;
        let cfg = AdapterConfig {
            ram_budget_bytes: bytes / 2,
            mmap: false,
            ..Default::default()
        };
        let r = Residency::new(l, v, d, cfg);
        r.insert("x", constant_table(1.0, l, v, d)).unwrap();
        let src = r.resolve("x").unwrap();
        assert_eq!(src.tier(), "disk");
        assert_eq!(row_of(src.as_ref(), 0, 3), vec![1.0; d]);
        let s = r.stats();
        assert_eq!(s.mmap_opens, 0, "{s:?}");
        assert_eq!(s.mmap_fallbacks, 0, "mmap off is not a fallback: {s:?}");
        assert_eq!(s.mapped_bytes, 0, "{s:?}");
        assert_eq!(s.cold_rows_mapped, 0, "{s:?}");
        assert_eq!(s.cold_rows_positioned, 1, "{s:?}");
    }

    #[test]
    fn mmap_on_maps_spill_and_gauge_settles_on_last_drop() {
        let (l, v, d) = (1, 16, 4);
        let bytes = l * v * d * 4;
        let cfg = AdapterConfig {
            ram_budget_bytes: bytes / 2,
            mmap: true,
            ..Default::default()
        };
        let r = Residency::new(l, v, d, cfg);
        r.insert("x", constant_table(2.0, l, v, d)).unwrap();
        let src = r.resolve("x").unwrap();
        assert_eq!(src.tier(), "disk");
        assert_eq!(row_of(src.as_ref(), 0, 5), vec![2.0; d]);
        let s = r.stats();
        if !Mmap::supported() {
            // No shim on this platform: the open degraded gracefully.
            assert_eq!(s.mmap_fallbacks, 1, "{s:?}");
            assert_eq!(s.cold_rows_positioned, 1, "{s:?}");
            return;
        }
        assert_eq!(s.mmap_opens, 1, "{s:?}");
        assert!(s.mapped_bytes > 0, "{s:?}");
        assert_eq!(s.cold_rows_mapped, 1, "{s:?}");
        assert_eq!(s.cold_rows_positioned, 0, "{s:?}");
        // The snapshot keeps the mapping alive across unregister...
        r.remove("x").unwrap();
        assert!(r.stats().mapped_bytes > 0, "mapping dropped under a live snapshot");
        assert_eq!(row_of(src.as_ref(), 0, 7), vec![2.0; d]);
        // ...and the gauge settles to zero on the last drop.
        drop(src);
        assert_eq!(r.stats().mapped_bytes, 0);
    }

    #[test]
    fn prefetch_with_unlimited_budget_is_a_noop() {
        let (l, v, d) = (1, 8, 4);
        let r = Arc::new(Residency::new(l, v, d, AdapterConfig::default()));
        r.insert("x", constant_table(1.0, l, v, d)).unwrap();
        Residency::prefetch(&r, &["x".to_string(), "missing".to_string()]);
        assert_eq!(r.prefetch_backlog(), 0);
        let s = r.stats();
        assert_eq!((s.prefetch_hits, s.prefetch_misses, s.prefetch_wasted), (0, 0, 0));
    }
}
