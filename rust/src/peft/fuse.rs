//! Host-side fuse math for the two reparametrizations of P.
//!
//! At task-registration time the coordinator turns trained reparametrized
//! weights into a dense `P[l, V, d]` (paper §3.3: "P could be fused once
//! training is complete, and thus the rank of factorization r does not
//! affect inference speed").  The same math also exists as `fuse_*` HLO
//! artifacts; integration tests assert both paths agree, so either can be
//! used (the host path avoids a device round-trip for large V·d).
//!
//! Fusing always happens in f32.  The fused [`TaskP`] is handed to the
//! tiered adapter store, which quantizes it to the configured storage
//! dtype (`--adapter-dtype f16` halves resident RAM) and may later spill
//! it to disk under the RAM budget — see `peft::{quant, residency}` and
//! DESIGN.md §10.  Fuse-time is the right moment to pay quantization:
//! it is off the serving hot path and runs once per registration.

use std::collections::BTreeMap;

use anyhow::{anyhow, bail};

use crate::tensor::Tensor;
use crate::Result;

use super::store::TaskP;

/// tanh-approximated GELU, bit-matching `kernels/ref.py`.
#[inline]
pub fn gelu(x: f32) -> f32 {
    let c = (2.0f32 / std::f32::consts::PI).sqrt();
    0.5 * x * (1.0 + (c * (x + 0.044715 * x * x * x)).tanh())
}

/// FC AoT fuse: `P[i] = gelu(E W1_i + b1_i) W2_i + b2_i` per layer
/// (paper Equation 3).
///
/// `emb`: `[V, d]`; per-layer stacks `w1 [l,d,r]`, `b1 [l,r]`,
/// `w2 [l,r,d]`, `b2 [l,d]` under the checkpoint names `t.fc.*`.
pub fn fuse_fc(emb: &Tensor, trained: &BTreeMap<String, Tensor>) -> Result<TaskP> {
    let (w1, b1, w2, b2) = (
        need(trained, "t.fc.w1")?,
        need(trained, "t.fc.b1")?,
        need(trained, "t.fc.w2")?,
        need(trained, "t.fc.b2")?,
    );
    let (v, d) = dims2(emb)?;
    let l = w1.shape[0];
    let r = w1.shape[2];
    if w1.shape != [l, d, r] || b1.shape != [l, r] || w2.shape != [l, r, d] || b2.shape != [l, d] {
        bail!("fuse_fc: inconsistent trained shapes");
    }
    let e = emb.as_f32()?;
    let w1 = w1.as_f32()?;
    let b1 = b1.as_f32()?;
    let w2 = w2.as_f32()?;
    let b2 = b2.as_f32()?;

    let mut out = vec![0f32; l * v * d];
    let mut hidden = vec![0f32; r];
    for layer in 0..l {
        let w1l = &w1[layer * d * r..(layer + 1) * d * r]; // [d, r]
        let b1l = &b1[layer * r..(layer + 1) * r];
        let w2l = &w2[layer * r * d..(layer + 1) * r * d]; // [r, d]
        let b2l = &b2[layer * d..(layer + 1) * d];
        for tok in 0..v {
            let e_row = &e[tok * d..(tok + 1) * d];
            // hidden = gelu(e_row @ W1 + b1)
            hidden.copy_from_slice(b1l);
            for (i, &ev) in e_row.iter().enumerate() {
                if ev == 0.0 {
                    continue;
                }
                let w_row = &w1l[i * r..(i + 1) * r];
                for (h, &w) in hidden.iter_mut().zip(w_row) {
                    *h += ev * w;
                }
            }
            for h in hidden.iter_mut() {
                *h = gelu(*h);
            }
            // out_row = hidden @ W2 + b2
            let out_row = &mut out[(layer * v + tok) * d..(layer * v + tok + 1) * d];
            out_row.copy_from_slice(b2l);
            for (j, &hv) in hidden.iter().enumerate() {
                if hv == 0.0 {
                    continue;
                }
                let w_row = &w2l[j * d..(j + 1) * d];
                for (o, &w) in out_row.iter_mut().zip(w_row) {
                    *o += hv * w;
                }
            }
        }
    }
    TaskP::new(l, v, d, out)
}

/// Kronecker AoT fuse: `P[i·bf+j] = Σ_{u,v} WL[i,u]·WM[j,v]·WR[u·r+v]`,
/// truncated to the first V rows (paper Equation 2 + footnote 1).
pub fn fuse_kron(
    vocab: usize,
    trained: &BTreeMap<String, Tensor>,
) -> Result<TaskP> {
    let (wl, wm, wr) = (
        need(trained, "t.kron.wl")?,
        need(trained, "t.kron.wm")?,
        need(trained, "t.kron.wr")?,
    );
    let l = wl.shape[0];
    let a = wl.shape[1];
    let r = wl.shape[2];
    let bf = wm.shape[1];
    let d = wr.shape[2];
    if wm.shape != [l, bf, r] || wr.shape != [l, r * r, d] {
        bail!("fuse_kron: inconsistent trained shapes");
    }
    if a * bf < vocab {
        bail!("fuse_kron: a*bf = {} < vocab {vocab}", a * bf);
    }
    let wl = wl.as_f32()?;
    let wm = wm.as_f32()?;
    let wr = wr.as_f32()?;

    let mut out = vec![0f32; l * vocab * d];
    // coeff[u*r+v] = WL[i,u] * WM[j,v]; row = coeff @ WR.
    let mut coeff = vec![0f32; r * r];
    for layer in 0..l {
        let wll = &wl[layer * a * r..(layer + 1) * a * r];
        let wml = &wm[layer * bf * r..(layer + 1) * bf * r];
        let wrl = &wr[layer * r * r * d..(layer + 1) * r * r * d];
        for tok in 0..vocab {
            let i = tok / bf;
            let j = tok % bf;
            let wli = &wll[i * r..(i + 1) * r];
            let wmj = &wml[j * r..(j + 1) * r];
            for u in 0..r {
                for v_ in 0..r {
                    coeff[u * r + v_] = wli[u] * wmj[v_];
                }
            }
            let out_row = &mut out[(layer * vocab + tok) * d..(layer * vocab + tok + 1) * d];
            out_row.fill(0.0);
            for (c_idx, &c) in coeff.iter().enumerate() {
                if c == 0.0 {
                    continue;
                }
                let w_row = &wrl[c_idx * d..(c_idx + 1) * d];
                for (o, &w) in out_row.iter_mut().zip(w_row) {
                    *o += c * w;
                }
            }
        }
    }
    TaskP::new(l, vocab, d, out)
}

fn need<'a>(map: &'a BTreeMap<String, Tensor>, name: &str) -> Result<&'a Tensor> {
    map.get(name).ok_or_else(|| anyhow!("fuse: missing tensor {name}"))
}

fn dims2(t: &Tensor) -> Result<(usize, usize)> {
    if t.shape.len() != 2 {
        bail!("expected 2-D tensor, got {:?}", t.shape);
    }
    Ok((t.shape[0], t.shape[1]))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Pcg64;

    #[test]
    fn fc_fuse_zero_weights_gives_zero_table() {
        // The paper's zero-init: W2 = b1 = b2 = 0 => P = 0.
        let (v, d, r, l) = (20, 6, 4, 2);
        let mut rng = Pcg64::new(3);
        let emb = Tensor::from_f32(&[v, d], rng.normal_vec(v * d, 1.0));
        let mut tr = BTreeMap::new();
        tr.insert("t.fc.w1".into(), Tensor::from_f32(&[l, d, r], rng.normal_vec(l * d * r, 1.0)));
        tr.insert("t.fc.b1".into(), Tensor::zeros(crate::tensor::DType::F32, &[l, r]));
        tr.insert("t.fc.w2".into(), Tensor::zeros(crate::tensor::DType::F32, &[l, r, d]));
        tr.insert("t.fc.b2".into(), Tensor::zeros(crate::tensor::DType::F32, &[l, d]));
        let p = fuse_fc(&emb, &tr).unwrap();
        assert!(p.row_norms(0).iter().all(|&n| n == 0.0));
    }

    #[test]
    fn kron_fuse_matches_naive() {
        let (a, bf, r, d, l, v) = (6, 4, 3, 5, 2, 22);
        let mut rng = Pcg64::new(4);
        let wl = rng.normal_vec(l * a * r, 1.0);
        let wm = rng.normal_vec(l * bf * r, 1.0);
        let wr = rng.normal_vec(l * r * r * d, 1.0);
        let mut tr = BTreeMap::new();
        tr.insert("t.kron.wl".into(), Tensor::from_f32(&[l, a, r], wl.clone()));
        tr.insert("t.kron.wm".into(), Tensor::from_f32(&[l, bf, r], wm.clone()));
        tr.insert("t.kron.wr".into(), Tensor::from_f32(&[l, r * r, d], wr.clone()));
        let p = fuse_kron(v, &tr).unwrap();
        // naive triple loop
        for layer in 0..l {
            for tok in 0..v {
                let (i, j) = (tok / bf, tok % bf);
                for dd in 0..d {
                    let mut want = 0f32;
                    for u in 0..r {
                        for vv in 0..r {
                            want += wl[(layer * a + i) * r + u]
                                * wm[(layer * bf + j) * r + vv]
                                * wr[(layer * r * r + u * r + vv) * d + dd];
                        }
                    }
                    let got = p.row(layer, tok)[dd];
                    assert!((got - want).abs() < 1e-4, "l{layer} t{tok} d{dd}: {got} vs {want}");
                }
            }
        }
    }

    #[test]
    fn gelu_matches_reference_values() {
        // Values from the jnp implementation.
        assert!((gelu(0.0) - 0.0).abs() < 1e-7);
        assert!((gelu(1.0) - 0.841192).abs() < 1e-5);
        assert!((gelu(-1.0) + 0.158808).abs() < 1e-5);
    }
}
