//! Host-side fuse math for the two reparametrizations of P.
//!
//! At task-registration time the coordinator turns trained reparametrized
//! weights into a dense `P[l, V, d]` (paper §3.3: "P could be fused once
//! training is complete, and thus the rank of factorization r does not
//! affect inference speed").  The same math also exists as `fuse_*` HLO
//! artifacts; integration tests assert both paths agree, so either can be
//! used (the host path avoids a device round-trip for large V·d).
//!
//! Fusing always happens in f32.  The fused [`TaskP`] is handed to the
//! tiered adapter store, which quantizes it to the configured storage
//! dtype (`--adapter-dtype f16` halves resident RAM) and may later spill
//! it to disk under the RAM budget — see `peft::{quant, residency}` and
//! DESIGN.md §10.  Fuse-time is the right moment to pay quantization:
//! it is off the serving hot path and runs once per registration.
//!
//! Fuse-time is also when [`dedup_rows`] runs: the paper observes that
//! trained ‖P_x‖ is near zero for most tokens (§4.3), so most fused rows
//! carry no task signal.  The plan it returns backs the store's dedup'd
//! tier — each unique row stored once behind a per-layer `u32` row-index
//! indirection, the all-zero row shared implicitly (DESIGN.md §12).

use std::collections::{BTreeMap, HashMap};

use anyhow::{anyhow, bail};

use crate::tensor::Tensor;
use crate::Result;

use super::kernel;
use super::store::TaskP;

/// tanh-approximated GELU, bit-matching `kernels/ref.py`.
#[inline]
pub fn gelu(x: f32) -> f32 {
    let c = (2.0f32 / std::f32::consts::PI).sqrt();
    0.5 * x * (1.0 + (c * (x + 0.044715 * x * x * x)).tanh())
}

/// FC AoT fuse: `P[i] = gelu(E W1_i + b1_i) W2_i + b2_i` per layer
/// (paper Equation 3).
///
/// `emb`: `[V, d]`; per-layer stacks `w1 [l,d,r]`, `b1 [l,r]`,
/// `w2 [l,r,d]`, `b2 [l,d]` under the checkpoint names `t.fc.*`.
pub fn fuse_fc(emb: &Tensor, trained: &BTreeMap<String, Tensor>) -> Result<TaskP> {
    let (w1, b1, w2, b2) = (
        need(trained, "t.fc.w1")?,
        need(trained, "t.fc.b1")?,
        need(trained, "t.fc.w2")?,
        need(trained, "t.fc.b2")?,
    );
    let (v, d) = dims2(emb)?;
    let l = w1.shape[0];
    let r = w1.shape[2];
    if w1.shape != [l, d, r] || b1.shape != [l, r] || w2.shape != [l, r, d] || b2.shape != [l, d] {
        bail!("fuse_fc: inconsistent trained shapes");
    }
    let e = emb.as_f32()?;
    let w1 = w1.as_f32()?;
    let b1 = b1.as_f32()?;
    let w2 = w2.as_f32()?;
    let b2 = b2.as_f32()?;

    let mut out = vec![0f32; l * v * d];
    let mut hidden = vec![0f32; r];
    for layer in 0..l {
        let w1l = &w1[layer * d * r..(layer + 1) * d * r]; // [d, r]
        let b1l = &b1[layer * r..(layer + 1) * r];
        let w2l = &w2[layer * r * d..(layer + 1) * r * d]; // [r, d]
        let b2l = &b2[layer * d..(layer + 1) * d];
        for tok in 0..v {
            let e_row = &e[tok * d..(tok + 1) * d];
            // hidden = gelu(e_row @ W1 + b1)
            hidden.copy_from_slice(b1l);
            for (i, &ev) in e_row.iter().enumerate() {
                if ev == 0.0 {
                    continue;
                }
                let w_row = &w1l[i * r..(i + 1) * r];
                for (h, &w) in hidden.iter_mut().zip(w_row) {
                    *h += ev * w;
                }
            }
            for h in hidden.iter_mut() {
                *h = gelu(*h);
            }
            // out_row = hidden @ W2 + b2
            let out_row = &mut out[(layer * v + tok) * d..(layer * v + tok + 1) * d];
            out_row.copy_from_slice(b2l);
            for (j, &hv) in hidden.iter().enumerate() {
                if hv == 0.0 {
                    continue;
                }
                let w_row = &w2l[j * d..(j + 1) * d];
                for (o, &w) in out_row.iter_mut().zip(w_row) {
                    *o += hv * w;
                }
            }
        }
    }
    TaskP::new(l, v, d, out)
}

/// Kronecker AoT fuse: `P[i·bf+j] = Σ_{u,v} WL[i,u]·WM[j,v]·WR[u·r+v]`,
/// truncated to the first V rows (paper Equation 2 + footnote 1).
pub fn fuse_kron(
    vocab: usize,
    trained: &BTreeMap<String, Tensor>,
) -> Result<TaskP> {
    let (wl, wm, wr) = (
        need(trained, "t.kron.wl")?,
        need(trained, "t.kron.wm")?,
        need(trained, "t.kron.wr")?,
    );
    let l = wl.shape[0];
    let a = wl.shape[1];
    let r = wl.shape[2];
    let bf = wm.shape[1];
    let d = wr.shape[2];
    if wm.shape != [l, bf, r] || wr.shape != [l, r * r, d] {
        bail!("fuse_kron: inconsistent trained shapes");
    }
    if a * bf < vocab {
        bail!("fuse_kron: a*bf = {} < vocab {vocab}", a * bf);
    }
    let wl = wl.as_f32()?;
    let wm = wm.as_f32()?;
    let wr = wr.as_f32()?;

    let mut out = vec![0f32; l * vocab * d];
    // coeff[u*r+v] = WL[i,u] * WM[j,v]; row = coeff @ WR.
    let mut coeff = vec![0f32; r * r];
    for layer in 0..l {
        let wll = &wl[layer * a * r..(layer + 1) * a * r];
        let wml = &wm[layer * bf * r..(layer + 1) * bf * r];
        let wrl = &wr[layer * r * r * d..(layer + 1) * r * r * d];
        for tok in 0..vocab {
            let i = tok / bf;
            let j = tok % bf;
            let wli = &wll[i * r..(i + 1) * r];
            let wmj = &wml[j * r..(j + 1) * r];
            for u in 0..r {
                for v_ in 0..r {
                    coeff[u * r + v_] = wli[u] * wmj[v_];
                }
            }
            let out_row = &mut out[(layer * vocab + tok) * d..(layer * vocab + tok + 1) * d];
            out_row.fill(0.0);
            for (c_idx, &c) in coeff.iter().enumerate() {
                if c == 0.0 {
                    continue;
                }
                let w_row = &wrl[c_idx * d..(c_idx + 1) * d];
                for (o, &w) in out_row.iter_mut().zip(w_row) {
                    *o += c * w;
                }
            }
        }
    }
    TaskP::new(l, vocab, d, out)
}

/// Output of the fuse-time shared-row dedup pass (DESIGN.md §12).
///
/// `index[layer·V + token]` is the `u32` indirection the store gathers
/// through: `0` means the shared all-zero row (stored nowhere), `k > 0`
/// means row `k − 1` of `unique`, a dense `[1, U, d]` pool of the
/// distinct rows in first-appearance order.
#[derive(Clone, Debug)]
pub struct DedupPlan {
    pub index: Vec<u32>,
    pub unique: Vec<f32>,
    pub d_model: usize,
    /// Rows that collapsed onto the shared zero row.
    pub zero_rows: usize,
}

impl DedupPlan {
    /// Number of distinct stored rows (the pool's `U`).
    pub fn unique_rows(&self) -> usize {
        self.unique.len() / self.d_model.max(1)
    }
}

/// Detect near-zero and bit-identical rows of a fused table.
///
/// A row whose elements are all `|x| ≤ eps` maps to the shared zero row
/// (index 0); with the default `eps = 0` only exactly-zero rows collapse,
/// so the dedup'd gather stays **bit-exact** — `eps > 0` is an explicit
/// opt-in to lossy snapping.  Remaining rows dedup by bit pattern, so two
/// tokens (or two layers) that fused to the identical row share storage.
pub fn dedup_rows(p: &TaskP, eps: f32) -> DedupPlan {
    let d = p.d_model;
    let rows = p.layers * p.vocab;
    let data = p.data();
    let mut index = Vec::with_capacity(rows);
    let mut unique: Vec<f32> = Vec::new();
    let mut zero_rows = 0usize;
    // Compare rows by their exact bytes: f32 compare would conflate
    // 0.0/-0.0 and choke on NaN; bytes make dedup deterministic.  Rows
    // bucket by `kernel::row_hash` and candidates are confirmed with the
    // dispatched `rows_equal` (SIMD memcmp) instead of materializing a
    // `Vec<u32>` key per row — hashing plus one vector compare per
    // candidate beats a per-row key allocation on large V·d tables.
    let k = kernel::active();
    let mut seen: HashMap<u64, Vec<u32>> = HashMap::new();
    for r in 0..rows {
        let row = &data[r * d..(r + 1) * d];
        if row.iter().all(|&x| x.abs() <= eps) {
            index.push(0);
            zero_rows += 1;
            continue;
        }
        let bytes = kernel::f32_bytes(row);
        let bucket = seen.entry(kernel::row_hash(bytes)).or_default();
        let hit = bucket.iter().copied().find(|&slot| {
            let s = (slot - 1) as usize * d;
            k.rows_equal(kernel::f32_bytes(&unique[s..s + d]), bytes)
        });
        let slot = hit.unwrap_or_else(|| {
            let next = (unique.len() / d + 1) as u32;
            unique.extend_from_slice(row);
            bucket.push(next);
            next
        });
        index.push(slot);
    }
    DedupPlan { index, unique, d_model: d, zero_rows }
}

fn need<'a>(map: &'a BTreeMap<String, Tensor>, name: &str) -> Result<&'a Tensor> {
    map.get(name).ok_or_else(|| anyhow!("fuse: missing tensor {name}"))
}

fn dims2(t: &Tensor) -> Result<(usize, usize)> {
    if t.shape.len() != 2 {
        bail!("expected 2-D tensor, got {:?}", t.shape);
    }
    Ok((t.shape[0], t.shape[1]))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Pcg64;

    #[test]
    fn fc_fuse_zero_weights_gives_zero_table() {
        // The paper's zero-init: W2 = b1 = b2 = 0 => P = 0.
        let (v, d, r, l) = (20, 6, 4, 2);
        let mut rng = Pcg64::new(3);
        let emb = Tensor::from_f32(&[v, d], rng.normal_vec(v * d, 1.0));
        let mut tr = BTreeMap::new();
        tr.insert("t.fc.w1".into(), Tensor::from_f32(&[l, d, r], rng.normal_vec(l * d * r, 1.0)));
        tr.insert("t.fc.b1".into(), Tensor::zeros(crate::tensor::DType::F32, &[l, r]));
        tr.insert("t.fc.w2".into(), Tensor::zeros(crate::tensor::DType::F32, &[l, r, d]));
        tr.insert("t.fc.b2".into(), Tensor::zeros(crate::tensor::DType::F32, &[l, d]));
        let p = fuse_fc(&emb, &tr).unwrap();
        assert!(p.row_norms(0).iter().all(|&n| n == 0.0));
    }

    #[test]
    fn kron_fuse_matches_naive() {
        let (a, bf, r, d, l, v) = (6, 4, 3, 5, 2, 22);
        let mut rng = Pcg64::new(4);
        let wl = rng.normal_vec(l * a * r, 1.0);
        let wm = rng.normal_vec(l * bf * r, 1.0);
        let wr = rng.normal_vec(l * r * r * d, 1.0);
        let mut tr = BTreeMap::new();
        tr.insert("t.kron.wl".into(), Tensor::from_f32(&[l, a, r], wl.clone()));
        tr.insert("t.kron.wm".into(), Tensor::from_f32(&[l, bf, r], wm.clone()));
        tr.insert("t.kron.wr".into(), Tensor::from_f32(&[l, r * r, d], wr.clone()));
        let p = fuse_kron(v, &tr).unwrap();
        // naive triple loop
        for layer in 0..l {
            for tok in 0..v {
                let (i, j) = (tok / bf, tok % bf);
                for dd in 0..d {
                    let mut want = 0f32;
                    for u in 0..r {
                        for vv in 0..r {
                            want += wl[(layer * a + i) * r + u]
                                * wm[(layer * bf + j) * r + vv]
                                * wr[(layer * r * r + u * r + vv) * d + dd];
                        }
                    }
                    let got = p.row(layer, tok)[dd];
                    assert!((got - want).abs() < 1e-4, "l{layer} t{tok} d{dd}: {got} vs {want}");
                }
            }
        }
    }

    #[test]
    fn dedup_collapses_zero_and_identical_rows() {
        let (l, v, d) = (2, 8, 4);
        // Layout per layer: tokens 0..4 zero, 4/5 share row A, 6 row B, 7 row C;
        // layer 1 repeats layer 0's rows exactly → cross-layer dedup too.
        let row_a = [1.0f32, -2.0, 3.0, 0.5];
        let row_b = [0.25f32, 0.0, -0.125, 9.0];
        let row_c = [-0.0f32, 0.0, 0.0, 1e-30];
        let mut data = Vec::new();
        for _layer in 0..l {
            for tok in 0..v {
                match tok {
                    0..=3 => data.extend_from_slice(&[0.0; 4]),
                    4 | 5 => data.extend_from_slice(&row_a),
                    6 => data.extend_from_slice(&row_b),
                    _ => data.extend_from_slice(&row_c),
                }
            }
        }
        let p = TaskP::new(l, v, d, data).unwrap();
        let plan = dedup_rows(&p, 0.0);
        // 16 logical rows → 3 stored (A, B, C), 8 zero.
        assert_eq!(plan.index.len(), l * v);
        assert_eq!(plan.zero_rows, 8);
        assert_eq!(plan.unique_rows(), 3);
        // -0.0 and 1e-30 are NOT zero at eps = 0 (bit-exactness).
        assert_ne!(plan.index[7], 0);
        // Shared rows point at the same pool slot across tokens and layers.
        assert_eq!(plan.index[4], plan.index[5]);
        assert_eq!(plan.index[4], plan.index[v + 4]);
        assert_eq!(plan.index[0], 0);
        // Pool row contents are the originals, first-appearance order.
        assert_eq!(&plan.unique[0..4], &row_a);
        assert_eq!(&plan.unique[4..8], &row_b);
        // eps > 0 additionally snaps the near-zero row C to the zero row.
        let lossy = dedup_rows(&p, 1e-6);
        assert_eq!(lossy.index[7], 0);
        assert_eq!(lossy.zero_rows, 12);
        assert_eq!(lossy.unique_rows(), 2);
    }

    #[test]
    fn dedup_of_all_distinct_rows_stores_everything() {
        let (l, v, d) = (1, 6, 3);
        let mut rng = Pcg64::new(17);
        let data = rng.normal_vec(l * v * d, 1.0);
        let p = TaskP::new(l, v, d, data.clone()).unwrap();
        let plan = dedup_rows(&p, 0.0);
        assert_eq!(plan.zero_rows, 0);
        assert_eq!(plan.unique_rows(), v);
        assert_eq!(plan.unique, data);
        for (tok, &ix) in plan.index.iter().enumerate() {
            assert_eq!(ix as usize, tok + 1);
        }
    }

    #[test]
    fn gelu_matches_reference_values() {
        // Values from the jnp implementation.
        assert!((gelu(0.0) - 0.0).abs() < 1e-7);
        assert!((gelu(1.0) - 0.841192).abs() < 1e-5);
        assert!((gelu(-1.0) + 0.158808).abs() < 1e-5);
    }
}
