//! The f16 and int8 storage tiers: fused-time quantization of P tables.
//!
//! Paper §3.3 prices multi-task serving in host RAM — `l×V×d×4` bytes per
//! task is 16–100 MB per layer at the paper's scales (DESIGN.md §3), so
//! the resident-table dtype is the single biggest lever on how many tasks
//! one serving process holds.  Storing P as IEEE 754 binary16 halves the
//! footprint; per-row affine int8 quarters it (plus 8 bytes/row of f32
//! scale/zero sidecars).  Rows are dequantized straight into the gather's
//! arena buffer (`RowSource::copy_row`), so the device-visible bias is
//! always f32 and no artifact changes shape.  f16 relative error is
//! ≤ 2⁻¹¹ per element (round-to-nearest-even), far inside the 1e-2 tier
//! tolerance asserted by the tests; int8 absolute error is ≤ scale/2 =
//! (max−min)/510 per row, asserted under 2e-2 for unit-normal fuses
//! (DESIGN.md §10).
//!
//! The f16 conversions are software implementations (no `half` crate in
//! the offline build) matching IEEE 754 semantics: subnormals are
//! preserved, overflow saturates to ±inf, NaN stays NaN.

use anyhow::bail;

use crate::tensor::DType;
use crate::Result;

use super::store::{RowSource, TaskP};

/// Storage dtype of a resident adapter table (CLI: `--adapter-dtype`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum AdapterDType {
    F32,
    F16,
    I8,
}

impl AdapterDType {
    /// Bytes per stored element (excluding the int8 tier's 8-bytes/row
    /// scale/zero sidecars, which `resident_bytes` accounts separately).
    pub fn size(self) -> usize {
        match self {
            AdapterDType::F32 => 4,
            AdapterDType::F16 => 2,
            AdapterDType::I8 => 1,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            AdapterDType::F32 => "f32",
            AdapterDType::F16 => "f16",
            AdapterDType::I8 => "int8",
        }
    }

    pub fn parse(s: &str) -> Result<AdapterDType> {
        Ok(match s {
            "f32" => AdapterDType::F32,
            "f16" => AdapterDType::F16,
            "int8" | "i8" => AdapterDType::I8,
            other => bail!("unknown adapter dtype {other:?} (expected one of: f32, f16, int8)"),
        })
    }

    /// The `.aotckpt` dtype used when a table of this tier spills to disk.
    pub fn tensor_dtype(self) -> DType {
        match self {
            AdapterDType::F32 => DType::F32,
            AdapterDType::F16 => DType::F16,
            AdapterDType::I8 => DType::I8,
        }
    }
}

/// Convert one f32 to IEEE binary16 bits, round-to-nearest-even.
pub fn f32_to_f16_bits(value: f32) -> u16 {
    let x = value.to_bits();
    let sign = ((x >> 16) & 0x8000) as u16;
    let exp = ((x >> 23) & 0xff) as i32;
    let mant = x & 0x007f_ffff;

    if exp == 255 {
        // Inf / NaN; keep a payload bit so NaN stays NaN.
        let nan = if mant != 0 { 0x0200 } else { 0 };
        return sign | 0x7c00 | nan;
    }
    let unbiased = exp - 127;
    if unbiased > 15 {
        return sign | 0x7c00; // overflow saturates to ±inf
    }
    if unbiased >= -14 {
        // Normal half: 23→10 mantissa bits, round to nearest even.  A
        // rounding carry may overflow into the exponent; that is exactly
        // the correct rounded result (up to and including ±inf).
        let mut h = (((unbiased + 15) as u32) << 10) | (mant >> 13);
        let rem = mant & 0x1fff;
        if rem > 0x1000 || (rem == 0x1000 && (h & 1) == 1) {
            h += 1;
        }
        return sign | h as u16;
    }
    if unbiased < -25 {
        return sign; // below half the smallest subnormal: ±0
    }
    // Subnormal half: shift the implicit-one mantissa into place.
    let full = mant | 0x0080_0000;
    let shift = (-unbiased - 1) as u32; // 14 (unbiased -15) ..= 24 (unbiased -25)
    let mut h = (full >> shift) as u16;
    let rem = full & ((1u32 << shift) - 1);
    let halfway = 1u32 << (shift - 1);
    if rem > halfway || (rem == halfway && (h & 1) == 1) {
        h += 1; // carry into the exponent yields the smallest normal: correct
    }
    sign | h
}

/// Convert IEEE binary16 bits back to f32 (exact).
pub fn f16_bits_to_f32(h: u16) -> f32 {
    let sign = ((h & 0x8000) as u32) << 16;
    let exp = ((h >> 10) & 0x1f) as u32;
    let mant = (h & 0x03ff) as u32;
    if exp == 0 {
        // ±0 and subnormals: value = mant · 2⁻²⁴ (exact in f32).
        let mag = mant as f32 / 16_777_216.0;
        return if sign != 0 { -mag } else { mag };
    }
    if exp == 31 {
        return f32::from_bits(sign | 0x7f80_0000 | (mant << 13));
    }
    f32::from_bits(sign | ((exp + 112) << 23) | (mant << 13))
}

/// Quantize a whole slice (fused-time, off the hot path).
pub fn quantize(values: &[f32]) -> Vec<u16> {
    values.iter().map(|&v| f32_to_f16_bits(v)).collect()
}

/// Dequantize `bits` into `out` (the on-gather direction; `out` is an
/// arena-owned slice, so this performs no allocation).  Runs on the
/// active SIMD row kernel (DESIGN.md §14), bit-identical to the scalar
/// [`f16_bits_to_f32`] per element.
///
/// Contract: `bits.len() == out.len()`.  Mismatched lengths are a caller
/// bug — debug builds assert; release builds dequantize only the common
/// prefix (the historical `zip` behavior).
#[inline]
pub fn dequantize_into(bits: &[u16], out: &mut [f32]) {
    debug_assert_eq!(bits.len(), out.len(), "dequantize_into: bits/out length mismatch");
    super::kernel::active().dequant_f16(bits, out);
}

/// One task's fused table stored as binary16 — the RAM-halving middle
/// tier between resident f32 and the disk tier (DESIGN.md §10).
pub struct QuantizedTaskP {
    layers: usize,
    vocab: usize,
    d_model: usize,
    data: Vec<u16>,
}

impl QuantizedTaskP {
    pub fn new(layers: usize, vocab: usize, d_model: usize, data: Vec<u16>) -> Result<QuantizedTaskP> {
        if data.len() != layers * vocab * d_model {
            bail!(
                "QuantizedTaskP: data length {} != {layers}x{vocab}x{d_model}",
                data.len()
            );
        }
        Ok(QuantizedTaskP { layers, vocab, d_model, data })
    }

    /// Fused-time quantization of an f32 table.
    pub fn from_taskp(p: &TaskP) -> QuantizedTaskP {
        QuantizedTaskP {
            layers: p.layers,
            vocab: p.vocab,
            d_model: p.d_model,
            data: quantize(p.data()),
        }
    }

    /// The stored bits of row (layer, token).
    #[inline]
    pub fn row_bits(&self, layer: usize, token: usize) -> &[u16] {
        let d = self.d_model;
        let start = (layer * self.vocab + token) * d;
        &self.data[start..start + d]
    }
}

impl RowSource for QuantizedTaskP {
    fn layers(&self) -> usize {
        self.layers
    }

    fn vocab(&self) -> usize {
        self.vocab
    }

    fn d_model(&self) -> usize {
        self.d_model
    }

    fn dtype(&self) -> AdapterDType {
        AdapterDType::F16
    }

    fn tier(&self) -> &'static str {
        "ram-f16"
    }

    fn resident_bytes(&self) -> usize {
        self.data.len() * 2
    }

    #[inline]
    fn copy_row(&self, layer: usize, token: usize, out: &mut [f32]) -> Result<()> {
        dequantize_into(self.row_bits(layer, token), out);
        Ok(())
    }

    fn spill_into(&self, w: &mut dyn std::io::Write) -> Result<()> {
        for &b in &self.data {
            w.write_all(&b.to_le_bytes())?;
        }
        Ok(())
    }
}

/// Quantize one row to per-row affine int8.  Returns `(scale, zero)`
/// with `scale = (max−min)/255` and `zero = min + 128·scale`, chosen so
/// the gather-side dequant is the single fused-multiply
/// `x' = scale·q + zero` with no per-element branch.  Codes are
/// `round((x−min)/scale) − 128`, clamped to `[-128, 127]`; absolute
/// error is ≤ scale/2.  Constant rows (including all-zero rows, which
/// paper §4.3 says dominate) get `scale = 0` and dequantize **exactly**
/// to their value.
pub fn quantize_row_i8(row: &[f32], codes: &mut [i8]) -> (f32, f32) {
    debug_assert_eq!(row.len(), codes.len());
    let mut min = f32::INFINITY;
    let mut max = f32::NEG_INFINITY;
    for &x in row {
        min = min.min(x);
        max = max.max(x);
    }
    if !(min.is_finite() && max.is_finite()) || max == min {
        // Empty, non-finite, or constant row: scale 0 ⇒ x' = zero exactly.
        let zero = if min.is_finite() { min } else { 0.0 };
        codes.fill(0);
        return (0.0, zero);
    }
    let scale = (max - min) / 255.0;
    let inv = 255.0 / (max - min);
    for (c, &x) in codes.iter_mut().zip(row) {
        let q = ((x - min) * inv).round() as i32 - 128;
        *c = q.clamp(-128, 127) as i8;
    }
    (scale, min + 128.0 * scale)
}

/// Dequantize one int8 row into `out` (the on-gather direction; `out`
/// is an arena-owned slice, so this performs no allocation).  Runs on
/// the active SIMD row kernel (DESIGN.md §14): `scale·q + zero` per
/// element, multiply-then-add on every path (no FMA contraction), so
/// SIMD and scalar agree bit for bit.
///
/// Contract: `codes.len() == out.len()`.  Mismatched lengths are a
/// caller bug — debug builds assert; release builds dequantize only the
/// common prefix (the historical `zip` behavior).
#[inline]
pub fn dequantize_i8_into(codes: &[i8], scale: f32, zero: f32, out: &mut [f32]) {
    debug_assert_eq!(codes.len(), out.len(), "dequantize_i8_into: codes/out length mismatch");
    super::kernel::active().dequant_i8(codes, scale, zero, out);
}

/// One task's fused table stored as per-row affine int8 — quarter the
/// f32 footprint plus 8 bytes/row of f32 scale/zero (DESIGN.md §10).
pub struct Int8TaskP {
    layers: usize,
    vocab: usize,
    d_model: usize,
    data: Vec<i8>,
    scale: Vec<f32>,
    zero: Vec<f32>,
}

impl Int8TaskP {
    pub fn new(
        layers: usize,
        vocab: usize,
        d_model: usize,
        data: Vec<i8>,
        scale: Vec<f32>,
        zero: Vec<f32>,
    ) -> Result<Int8TaskP> {
        let rows = layers * vocab;
        if data.len() != rows * d_model {
            bail!("Int8TaskP: data length {} != {layers}x{vocab}x{d_model}", data.len());
        }
        if scale.len() != rows || zero.len() != rows {
            bail!(
                "Int8TaskP: scale/zero lengths {}/{} != {rows} rows",
                scale.len(),
                zero.len()
            );
        }
        Ok(Int8TaskP { layers, vocab, d_model, data, scale, zero })
    }

    /// Fused-time quantization of an f32 table, row by row.
    pub fn from_taskp(p: &TaskP) -> Int8TaskP {
        Self::from_rows(p.layers, p.vocab, p.d_model, p.data())
    }

    /// Quantize `rows` (a dense `[layers*vocab, d_model]` f32 buffer).
    pub fn from_rows(layers: usize, vocab: usize, d_model: usize, values: &[f32]) -> Int8TaskP {
        let rows = layers * vocab;
        debug_assert_eq!(values.len(), rows * d_model);
        let mut data = vec![0i8; values.len()];
        let mut scale = Vec::with_capacity(rows);
        let mut zero = Vec::with_capacity(rows);
        for r in 0..rows {
            let span = r * d_model..(r + 1) * d_model;
            let (s, z) = quantize_row_i8(&values[span.clone()], &mut data[span]);
            scale.push(s);
            zero.push(z);
        }
        Int8TaskP { layers, vocab, d_model, data, scale, zero }
    }

    /// The stored codes of row (layer, token).
    #[inline]
    pub fn row_codes(&self, layer: usize, token: usize) -> &[i8] {
        let d = self.d_model;
        let start = (layer * self.vocab + token) * d;
        &self.data[start..start + d]
    }
}

impl RowSource for Int8TaskP {
    fn layers(&self) -> usize {
        self.layers
    }

    fn vocab(&self) -> usize {
        self.vocab
    }

    fn d_model(&self) -> usize {
        self.d_model
    }

    fn dtype(&self) -> AdapterDType {
        AdapterDType::I8
    }

    fn tier(&self) -> &'static str {
        "ram-int8"
    }

    fn resident_bytes(&self) -> usize {
        self.data.len() + (self.scale.len() + self.zero.len()) * 4
    }

    #[inline]
    fn copy_row(&self, layer: usize, token: usize, out: &mut [f32]) -> Result<()> {
        let r = layer * self.vocab + token;
        dequantize_i8_into(self.row_codes(layer, token), self.scale[r], self.zero[r], out);
        Ok(())
    }

    fn quant_params(&self) -> Option<(&[f32], &[f32])> {
        Some((&self.scale, &self.zero))
    }

    fn spill_into(&self, w: &mut dyn std::io::Write) -> Result<()> {
        // i8 and u8 share layout; one bulk write of the codes tensor.
        let bytes =
            unsafe { std::slice::from_raw_parts(self.data.as_ptr() as *const u8, self.data.len()) };
        w.write_all(bytes)?;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Pcg64;

    #[test]
    fn exact_values_roundtrip() {
        // Values exactly representable in binary16 must survive bit-exact.
        for v in [
            0.0f32, -0.0, 1.0, -1.0, 0.5, 2.0, 1.5, 0.25, 65504.0, -65504.0, 6.103_515_6e-5,
        ] {
            let back = f16_bits_to_f32(f32_to_f16_bits(v));
            assert_eq!(back.to_bits(), v.to_bits(), "{v} -> {back}");
        }
    }

    #[test]
    fn specials() {
        assert_eq!(f32_to_f16_bits(f32::INFINITY), 0x7c00);
        assert_eq!(f32_to_f16_bits(f32::NEG_INFINITY), 0xfc00);
        assert!(f16_bits_to_f32(f32_to_f16_bits(f32::NAN)).is_nan());
        // Overflow saturates to inf.
        assert_eq!(f32_to_f16_bits(1e6), 0x7c00);
        assert_eq!(f32_to_f16_bits(-1e6), 0xfc00);
        // Tiny values flush to signed zero.
        assert_eq!(f32_to_f16_bits(1e-10), 0x0000);
        assert_eq!(f32_to_f16_bits(-1e-10), 0x8000);
        // Smallest subnormal (2^-24) survives.
        let sub = f16_bits_to_f32(0x0001);
        assert!((sub - 5.960_464_5e-8).abs() < 1e-12);
        assert_eq!(f32_to_f16_bits(sub), 0x0001);
    }

    #[test]
    fn roundtrip_error_is_bounded() {
        // Relative error of one f32→f16→f32 trip is at most 2^-11 for
        // normal halves; the tier tolerance (1e-2 absolute, DESIGN §10)
        // holds for all values the fuse produces.
        let mut rng = Pcg64::new(9);
        for &std in &[0.1f32, 1.0, 4.0] {
            for v in rng.normal_vec(4096, std) {
                let back = f16_bits_to_f32(f32_to_f16_bits(v));
                let tol = (v.abs() * 4.9e-4).max(6e-8);
                assert!(
                    (back - v).abs() <= tol,
                    "{v} -> {back} (err {})",
                    (back - v).abs()
                );
            }
        }
    }

    #[test]
    fn rounds_to_nearest_even() {
        // 1 + 2^-11 is exactly halfway between 1.0 and the next half;
        // nearest-even rounds down to 1.0.
        let halfway = 1.0f32 + 2.0f32.powi(-11);
        assert_eq!(f32_to_f16_bits(halfway), 0x3c00);
        // 1 + 3·2^-11 is halfway between 1+2^-10 and 1+2^-9; nearest-even
        // rounds up to the even mantissa 2.
        let halfway_up = 1.0f32 + 3.0 * 2.0f32.powi(-11);
        assert_eq!(f32_to_f16_bits(halfway_up), 0x3c02);
    }

    #[test]
    fn quantized_table_rows_match_scalar_path() {
        let (l, v, d) = (2, 12, 6);
        let mut rng = Pcg64::new(11);
        let data = rng.normal_vec(l * v * d, 1.0);
        let p = TaskP::new(l, v, d, data.clone()).unwrap();
        let q = QuantizedTaskP::from_taskp(&p);
        assert_eq!(q.resident_bytes(), l * v * d * 2);
        let mut row = vec![0f32; d];
        for layer in 0..l {
            for tok in 0..v {
                q.copy_row(layer, tok, &mut row).unwrap();
                for (k, &got) in row.iter().enumerate() {
                    let want = data[(layer * v + tok) * d + k];
                    assert!((got - want).abs() < 1e-2, "l{layer} t{tok} k{k}");
                    assert_eq!(got.to_bits(), f16_bits_to_f32(f32_to_f16_bits(want)).to_bits());
                }
            }
        }
    }

    #[test]
    fn dtype_parse_and_sizes() {
        assert_eq!(AdapterDType::parse("f32").unwrap(), AdapterDType::F32);
        assert_eq!(AdapterDType::parse("f16").unwrap(), AdapterDType::F16);
        assert_eq!(AdapterDType::parse("int8").unwrap(), AdapterDType::I8);
        assert_eq!(AdapterDType::parse("i8").unwrap(), AdapterDType::I8);
        let err = AdapterDType::parse("int4").unwrap_err().to_string();
        assert!(err.contains("f32, f16, int8"), "parse error must list valid values: {err}");
        assert_eq!(AdapterDType::F32.size(), 4);
        assert_eq!(AdapterDType::F16.size(), 2);
        assert_eq!(AdapterDType::I8.size(), 1);
        assert_eq!(AdapterDType::F16.tensor_dtype(), DType::F16);
        assert_eq!(AdapterDType::I8.tensor_dtype(), DType::I8);
        assert_eq!(AdapterDType::I8.name(), "int8");
    }

    #[test]
    fn i8_row_quant_error_is_bounded_by_half_scale() {
        let mut rng = Pcg64::new(21);
        let d = 64;
        let mut codes = vec![0i8; d];
        let mut out = vec![0f32; d];
        for &std in &[0.1f32, 1.0, 4.0] {
            let row = rng.normal_vec(d, std);
            let (scale, zero) = quantize_row_i8(&row, &mut codes);
            dequantize_i8_into(&codes, scale, zero, &mut out);
            for (k, (&got, &want)) in out.iter().zip(&row).enumerate() {
                let err = (got - want).abs();
                // Half a quantization step, plus f32 rounding headroom.
                assert!(err <= scale * 0.5 + 1e-6, "k{k}: {want} -> {got} (err {err}, scale {scale})");
            }
        }
    }

    #[test]
    fn i8_constant_and_zero_rows_dequantize_exactly() {
        let mut codes = vec![0i8; 8];
        let mut out = vec![9f32; 8];
        let (scale, zero) = quantize_row_i8(&[0.0; 8], &mut codes);
        dequantize_i8_into(&codes, scale, zero, &mut out);
        assert_eq!(scale, 0.0);
        assert!(out.iter().all(|&x| x == 0.0), "all-zero row must survive bit-exact");
        let (scale, zero) = quantize_row_i8(&[2.5; 8], &mut codes);
        dequantize_i8_into(&codes, scale, zero, &mut out);
        assert_eq!(scale, 0.0);
        assert!(out.iter().all(|&x| x == 2.5), "constant row must survive bit-exact");
        // Extremes of a row map inside the code range (no clamp bias).
        let (scale, zero) = quantize_row_i8(&[-1.0, 1.0], &mut codes[..2]);
        let mut two = [0f32; 2];
        dequantize_i8_into(&codes[..2], scale, zero, &mut two);
        assert!((two[0] + 1.0).abs() <= scale * 0.5 + 1e-6);
        assert!((two[1] - 1.0).abs() <= scale * 0.5 + 1e-6);
    }

    #[test]
    fn int8_table_quarter_footprint_and_tolerance() {
        let (l, v, d) = (2, 16, 128);
        let mut rng = Pcg64::new(13);
        let data = rng.normal_vec(l * v * d, 1.0);
        let p = TaskP::new(l, v, d, data.clone()).unwrap();
        let q = Int8TaskP::from_taskp(&p);
        // codes + 8 bytes/row of scale/zero; ≤ 0.27× f32 at d=128.
        assert_eq!(q.resident_bytes(), l * v * d + l * v * 8);
        let f32_bytes = l * v * d * 4;
        assert!(
            (q.resident_bytes() as f64) <= 0.27 * f32_bytes as f64,
            "int8 resident {} > 0.27 × f32 {}",
            q.resident_bytes(),
            f32_bytes
        );
        let (scales, _zeros) = q.quant_params().unwrap();
        let mut row = vec![0f32; d];
        for layer in 0..l {
            for tok in 0..v {
                q.copy_row(layer, tok, &mut row).unwrap();
                let scale = scales[layer * v + tok];
                for (k, &got) in row.iter().enumerate() {
                    let want = data[(layer * v + tok) * d + k];
                    let err = (got - want).abs();
                    assert!(err <= scale * 0.5 + 1e-6, "l{layer} t{tok} k{k}: err {err}");
                    // Stated tier bound (unit-normal fuse): 2e-2 absolute.
                    assert!(err < 2e-2, "l{layer} t{tok} k{k}: err {err} breaches tier bound");
                }
            }
        }
    }
}
