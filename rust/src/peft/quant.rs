//! The f16 storage tier: fused-time quantization of P tables.
//!
//! Paper §3.3 prices multi-task serving in host RAM — `l×V×d×4` bytes per
//! task is 16–100 MB per layer at the paper's scales (DESIGN.md §3), so
//! the resident-table dtype is the single biggest lever on how many tasks
//! one serving process holds.  Storing P as IEEE 754 binary16 halves the
//! footprint; rows are dequantized straight into the gather's arena
//! buffer (`RowSource::copy_row`), so the device-visible bias is always
//! f32 and no artifact changes shape.  Relative error is ≤ 2⁻¹¹ per
//! element (round-to-nearest-even), far inside the 1e-2 tier tolerance
//! asserted by the tests (DESIGN.md §10).
//!
//! The conversions are software implementations (no `half` crate in the
//! offline build) matching IEEE 754 semantics: subnormals are preserved,
//! overflow saturates to ±inf, NaN stays NaN.

use anyhow::bail;

use crate::tensor::DType;
use crate::Result;

use super::store::{RowSource, TaskP};

/// Storage dtype of a resident adapter table (CLI: `--adapter-dtype`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum AdapterDType {
    F32,
    F16,
}

impl AdapterDType {
    /// Bytes per stored element.
    pub fn size(self) -> usize {
        match self {
            AdapterDType::F32 => 4,
            AdapterDType::F16 => 2,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            AdapterDType::F32 => "f32",
            AdapterDType::F16 => "f16",
        }
    }

    pub fn parse(s: &str) -> Result<AdapterDType> {
        Ok(match s {
            "f32" => AdapterDType::F32,
            "f16" => AdapterDType::F16,
            other => bail!("unknown adapter dtype {other} (expected f32|f16)"),
        })
    }

    /// The `.aotckpt` dtype used when a table of this tier spills to disk.
    pub fn tensor_dtype(self) -> DType {
        match self {
            AdapterDType::F32 => DType::F32,
            AdapterDType::F16 => DType::F16,
        }
    }
}

/// Convert one f32 to IEEE binary16 bits, round-to-nearest-even.
pub fn f32_to_f16_bits(value: f32) -> u16 {
    let x = value.to_bits();
    let sign = ((x >> 16) & 0x8000) as u16;
    let exp = ((x >> 23) & 0xff) as i32;
    let mant = x & 0x007f_ffff;

    if exp == 255 {
        // Inf / NaN; keep a payload bit so NaN stays NaN.
        let nan = if mant != 0 { 0x0200 } else { 0 };
        return sign | 0x7c00 | nan;
    }
    let unbiased = exp - 127;
    if unbiased > 15 {
        return sign | 0x7c00; // overflow saturates to ±inf
    }
    if unbiased >= -14 {
        // Normal half: 23→10 mantissa bits, round to nearest even.  A
        // rounding carry may overflow into the exponent; that is exactly
        // the correct rounded result (up to and including ±inf).
        let mut h = (((unbiased + 15) as u32) << 10) | (mant >> 13);
        let rem = mant & 0x1fff;
        if rem > 0x1000 || (rem == 0x1000 && (h & 1) == 1) {
            h += 1;
        }
        return sign | h as u16;
    }
    if unbiased < -25 {
        return sign; // below half the smallest subnormal: ±0
    }
    // Subnormal half: shift the implicit-one mantissa into place.
    let full = mant | 0x0080_0000;
    let shift = (-unbiased - 1) as u32; // 14 (unbiased -15) ..= 24 (unbiased -25)
    let mut h = (full >> shift) as u16;
    let rem = full & ((1u32 << shift) - 1);
    let halfway = 1u32 << (shift - 1);
    if rem > halfway || (rem == halfway && (h & 1) == 1) {
        h += 1; // carry into the exponent yields the smallest normal: correct
    }
    sign | h
}

/// Convert IEEE binary16 bits back to f32 (exact).
pub fn f16_bits_to_f32(h: u16) -> f32 {
    let sign = ((h & 0x8000) as u32) << 16;
    let exp = ((h >> 10) & 0x1f) as u32;
    let mant = (h & 0x03ff) as u32;
    if exp == 0 {
        // ±0 and subnormals: value = mant · 2⁻²⁴ (exact in f32).
        let mag = mant as f32 / 16_777_216.0;
        return if sign != 0 { -mag } else { mag };
    }
    if exp == 31 {
        return f32::from_bits(sign | 0x7f80_0000 | (mant << 13));
    }
    f32::from_bits(sign | ((exp + 112) << 23) | (mant << 13))
}

/// Quantize a whole slice (fused-time, off the hot path).
pub fn quantize(values: &[f32]) -> Vec<u16> {
    values.iter().map(|&v| f32_to_f16_bits(v)).collect()
}

/// Dequantize `bits` into `out` (the on-gather direction; `out` is an
/// arena-owned slice, so this performs no allocation).
#[inline]
pub fn dequantize_into(bits: &[u16], out: &mut [f32]) {
    for (o, &b) in out.iter_mut().zip(bits) {
        *o = f16_bits_to_f32(b);
    }
}

/// One task's fused table stored as binary16 — the RAM-halving middle
/// tier between resident f32 and the disk tier (DESIGN.md §10).
pub struct QuantizedTaskP {
    layers: usize,
    vocab: usize,
    d_model: usize,
    data: Vec<u16>,
}

impl QuantizedTaskP {
    pub fn new(layers: usize, vocab: usize, d_model: usize, data: Vec<u16>) -> Result<QuantizedTaskP> {
        if data.len() != layers * vocab * d_model {
            bail!(
                "QuantizedTaskP: data length {} != {layers}x{vocab}x{d_model}",
                data.len()
            );
        }
        Ok(QuantizedTaskP { layers, vocab, d_model, data })
    }

    /// Fused-time quantization of an f32 table.
    pub fn from_taskp(p: &TaskP) -> QuantizedTaskP {
        QuantizedTaskP {
            layers: p.layers,
            vocab: p.vocab,
            d_model: p.d_model,
            data: quantize(p.data()),
        }
    }

    /// The stored bits of row (layer, token).
    #[inline]
    pub fn row_bits(&self, layer: usize, token: usize) -> &[u16] {
        let d = self.d_model;
        let start = (layer * self.vocab + token) * d;
        &self.data[start..start + d]
    }
}

impl RowSource for QuantizedTaskP {
    fn layers(&self) -> usize {
        self.layers
    }

    fn vocab(&self) -> usize {
        self.vocab
    }

    fn d_model(&self) -> usize {
        self.d_model
    }

    fn dtype(&self) -> AdapterDType {
        AdapterDType::F16
    }

    fn tier(&self) -> &'static str {
        "ram-f16"
    }

    fn resident_bytes(&self) -> usize {
        self.data.len() * 2
    }

    #[inline]
    fn copy_row(&self, layer: usize, token: usize, out: &mut [f32]) -> Result<()> {
        dequantize_into(self.row_bits(layer, token), out);
        Ok(())
    }

    fn spill_into(&self, w: &mut dyn std::io::Write) -> Result<()> {
        for &b in &self.data {
            w.write_all(&b.to_le_bytes())?;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Pcg64;

    #[test]
    fn exact_values_roundtrip() {
        // Values exactly representable in binary16 must survive bit-exact.
        for v in [
            0.0f32, -0.0, 1.0, -1.0, 0.5, 2.0, 1.5, 0.25, 65504.0, -65504.0, 6.103_515_6e-5,
        ] {
            let back = f16_bits_to_f32(f32_to_f16_bits(v));
            assert_eq!(back.to_bits(), v.to_bits(), "{v} -> {back}");
        }
    }

    #[test]
    fn specials() {
        assert_eq!(f32_to_f16_bits(f32::INFINITY), 0x7c00);
        assert_eq!(f32_to_f16_bits(f32::NEG_INFINITY), 0xfc00);
        assert!(f16_bits_to_f32(f32_to_f16_bits(f32::NAN)).is_nan());
        // Overflow saturates to inf.
        assert_eq!(f32_to_f16_bits(1e6), 0x7c00);
        assert_eq!(f32_to_f16_bits(-1e6), 0xfc00);
        // Tiny values flush to signed zero.
        assert_eq!(f32_to_f16_bits(1e-10), 0x0000);
        assert_eq!(f32_to_f16_bits(-1e-10), 0x8000);
        // Smallest subnormal (2^-24) survives.
        let sub = f16_bits_to_f32(0x0001);
        assert!((sub - 5.960_464_5e-8).abs() < 1e-12);
        assert_eq!(f32_to_f16_bits(sub), 0x0001);
    }

    #[test]
    fn roundtrip_error_is_bounded() {
        // Relative error of one f32→f16→f32 trip is at most 2^-11 for
        // normal halves; the tier tolerance (1e-2 absolute, DESIGN §10)
        // holds for all values the fuse produces.
        let mut rng = Pcg64::new(9);
        for &std in &[0.1f32, 1.0, 4.0] {
            for v in rng.normal_vec(4096, std) {
                let back = f16_bits_to_f32(f32_to_f16_bits(v));
                let tol = (v.abs() * 4.9e-4).max(6e-8);
                assert!(
                    (back - v).abs() <= tol,
                    "{v} -> {back} (err {})",
                    (back - v).abs()
                );
            }
        }
    }

    #[test]
    fn rounds_to_nearest_even() {
        // 1 + 2^-11 is exactly halfway between 1.0 and the next half;
        // nearest-even rounds down to 1.0.
        let halfway = 1.0f32 + 2.0f32.powi(-11);
        assert_eq!(f32_to_f16_bits(halfway), 0x3c00);
        // 1 + 3·2^-11 is halfway between 1+2^-10 and 1+2^-9; nearest-even
        // rounds up to the even mantissa 2.
        let halfway_up = 1.0f32 + 3.0 * 2.0f32.powi(-11);
        assert_eq!(f32_to_f16_bits(halfway_up), 0x3c02);
    }

    #[test]
    fn quantized_table_rows_match_scalar_path() {
        let (l, v, d) = (2, 12, 6);
        let mut rng = Pcg64::new(11);
        let data = rng.normal_vec(l * v * d, 1.0);
        let p = TaskP::new(l, v, d, data.clone()).unwrap();
        let q = QuantizedTaskP::from_taskp(&p);
        assert_eq!(q.resident_bytes(), l * v * d * 2);
        let mut row = vec![0f32; d];
        for layer in 0..l {
            for tok in 0..v {
                q.copy_row(layer, tok, &mut row).unwrap();
                for (k, &got) in row.iter().enumerate() {
                    let want = data[(layer * v + tok) * d + k];
                    assert!((got - want).abs() < 1e-2, "l{layer} t{tok} k{k}");
                    assert_eq!(got.to_bits(), f16_bits_to_f32(f32_to_f16_bits(want)).to_bits());
                }
            }
        }
    }

    #[test]
    fn dtype_parse_and_sizes() {
        assert_eq!(AdapterDType::parse("f32").unwrap(), AdapterDType::F32);
        assert_eq!(AdapterDType::parse("f16").unwrap(), AdapterDType::F16);
        assert!(AdapterDType::parse("int8").is_err());
        assert_eq!(AdapterDType::F32.size(), 4);
        assert_eq!(AdapterDType::F16.size(), 2);
        assert_eq!(AdapterDType::F16.tensor_dtype(), DType::F16);
    }
}
