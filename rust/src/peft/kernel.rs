//! Runtime-dispatched SIMD row kernels for the gather/dequant hot path.
//!
//! Every row the gather serves — resident f32 copies, f16/int8 dequant,
//! cold-tier byte decodes, and the dedup pass's row comparisons — funnels
//! through one of four primitive kernels (DESIGN.md §14):
//!
//! * `f16_le`    — little-endian IEEE binary16 payload → f32,
//! * `i8_affine` — int8 codes → `scale · q + zero` f32,
//! * `f32_le`    — little-endian f32 payload → f32 (wide row copy),
//! * `bytes_eq`  — bytewise row equality (f32 bit-pattern equality).
//!
//! Each primitive has a portable scalar implementation plus SIMD variants
//! selected **at run time** via `std::arch` feature detection on first
//! use: AVX2 and SSE2 on x86_64, NEON on little-endian aarch64.  The
//! selection is overridable — `AOTPT_KERNEL=scalar|auto` (the CI matrix
//! lever, mirroring `AOTPT_ADAPTER_MMAP`) and the `--kernel` CLI flag —
//! so the scalar fallback stays exercised everywhere the SIMD paths run.
//!
//! **Bit parity is the contract**: every SIMD path must produce the exact
//! bit pattern of the scalar path for every input (asserted exhaustively
//! over all 65536 f16 patterns in `rust/tests/kernel_parity.rs`).  The
//! f16 kernels therefore use a branch-free integer construction of the
//! scalar conversion (never the F16C `vcvtph2ps` instruction, which
//! quietens signaling NaNs), and the int8 kernels use an explicit
//! multiply-then-add (never FMA, which Rust's scalar `scale * q + zero`
//! does not contract to).  All kernels accept unaligned pointers and any
//! length; odd tails fall through to the scalar loop.
//!
//! Dispatch is one relaxed atomic pointer load per call — negligible next
//! to a row's worth of work — and swapping the active kernel at run time
//! (`set_active`) is how the bench and the parity tests drive every
//! implementation through the same gather code.

use std::sync::atomic::{AtomicPtr, Ordering};

use anyhow::bail;

use crate::Result;

use super::quant::f16_bits_to_f32;

/// One dispatchable implementation set.  The function pointers are
/// `unsafe` because they take raw pointers; the safe methods below do the
/// length bookkeeping.
pub struct RowKernel {
    /// Implementation name (`scalar`, `sse2`, `avx2`, `neon`) — surfaced
    /// through `AdapterStats` and `BENCH_gather.json`.
    pub name: &'static str,
    f16_le: unsafe fn(*const u8, *mut f32, usize),
    i8_affine: unsafe fn(*const i8, f32, f32, *mut f32, usize),
    f32_le: unsafe fn(*const u8, *mut f32, usize),
    bytes_eq: unsafe fn(*const u8, *const u8, usize) -> bool,
}

impl RowKernel {
    /// Decode a little-endian f16 payload into f32.
    ///
    /// Contract: `src.len() == 2 * dst.len()` (debug-asserted; release
    /// builds decode the common prefix).
    #[inline]
    pub fn dequant_f16_le(&self, src: &[u8], dst: &mut [f32]) {
        debug_assert_eq!(src.len(), dst.len() * 2, "f16 payload/output length mismatch");
        let n = dst.len().min(src.len() / 2);
        unsafe { (self.f16_le)(src.as_ptr(), dst.as_mut_ptr(), n) }
    }

    /// Dequantize native-order f16 bit patterns into f32.
    ///
    /// Contract: `bits.len() == dst.len()` (debug-asserted; release
    /// builds decode the common prefix).
    #[inline]
    pub fn dequant_f16(&self, bits: &[u16], dst: &mut [f32]) {
        debug_assert_eq!(bits.len(), dst.len(), "f16 bits/output length mismatch");
        let n = bits.len().min(dst.len());
        if cfg!(target_endian = "little") {
            unsafe { (self.f16_le)(bits.as_ptr() as *const u8, dst.as_mut_ptr(), n) }
        } else {
            for (o, &b) in dst[..n].iter_mut().zip(bits) {
                *o = f16_bits_to_f32(b);
            }
        }
    }

    /// Dequantize int8 codes: `dst[i] = scale * codes[i] + zero`.
    ///
    /// Contract: `codes.len() == dst.len()` (debug-asserted; release
    /// builds decode the common prefix).
    #[inline]
    pub fn dequant_i8(&self, codes: &[i8], scale: f32, zero: f32, dst: &mut [f32]) {
        debug_assert_eq!(codes.len(), dst.len(), "i8 codes/output length mismatch");
        let n = codes.len().min(dst.len());
        unsafe { (self.i8_affine)(codes.as_ptr(), scale, zero, dst.as_mut_ptr(), n) }
    }

    /// Same as [`dequant_i8`](Self::dequant_i8) over a raw byte payload
    /// (the cold tier's stored rows).
    #[inline]
    pub fn dequant_i8_bytes(&self, raw: &[u8], scale: f32, zero: f32, dst: &mut [f32]) {
        debug_assert_eq!(raw.len(), dst.len(), "i8 payload/output length mismatch");
        let n = raw.len().min(dst.len());
        unsafe { (self.i8_affine)(raw.as_ptr() as *const i8, scale, zero, dst.as_mut_ptr(), n) }
    }

    /// Decode a little-endian f32 payload into f32.
    ///
    /// Contract: `src.len() == 4 * dst.len()` (debug-asserted; release
    /// builds decode the common prefix).
    #[inline]
    pub fn decode_f32_le(&self, src: &[u8], dst: &mut [f32]) {
        debug_assert_eq!(src.len(), dst.len() * 4, "f32 payload/output length mismatch");
        let n = dst.len().min(src.len() / 4);
        unsafe { (self.f32_le)(src.as_ptr(), dst.as_mut_ptr(), n) }
    }

    /// Wide f32 row copy (the resident f32 tier's gather move).
    ///
    /// Contract: `src.len() == dst.len()` (debug-asserted; release builds
    /// copy the common prefix).
    #[inline]
    pub fn copy_f32(&self, src: &[f32], dst: &mut [f32]) {
        debug_assert_eq!(src.len(), dst.len(), "f32 row copy length mismatch");
        let n = src.len().min(dst.len());
        if cfg!(target_endian = "little") {
            unsafe { (self.f32_le)(src.as_ptr() as *const u8, dst.as_mut_ptr(), n) }
        } else {
            dst[..n].copy_from_slice(&src[..n]);
        }
    }

    /// Bytewise equality over two rows (f32 bit-pattern equality — NaNs
    /// with equal payloads compare equal, `+0.0` and `-0.0` differ).
    /// Slices of different lengths are never equal.
    #[inline]
    pub fn rows_equal(&self, a: &[u8], b: &[u8]) -> bool {
        a.len() == b.len() && unsafe { (self.bytes_eq)(a.as_ptr(), b.as_ptr(), a.len()) }
    }
}

// ---------------------------------------------------------------------
// Scalar (portable reference — always available, endian-correct).
// ---------------------------------------------------------------------

unsafe fn f16_le_scalar(src: *const u8, dst: *mut f32, n: usize) {
    for i in 0..n {
        let b = u16::from_le_bytes([*src.add(2 * i), *src.add(2 * i + 1)]);
        *dst.add(i) = f16_bits_to_f32(b);
    }
}

unsafe fn i8_affine_scalar(src: *const i8, scale: f32, zero: f32, dst: *mut f32, n: usize) {
    for i in 0..n {
        *dst.add(i) = scale * (*src.add(i) as f32) + zero;
    }
}

unsafe fn f32_le_scalar(src: *const u8, dst: *mut f32, n: usize) {
    for i in 0..n {
        let p = src.add(4 * i);
        *dst.add(i) = f32::from_le_bytes([*p, *p.add(1), *p.add(2), *p.add(3)]);
    }
}

unsafe fn bytes_eq_scalar(a: *const u8, b: *const u8, n: usize) -> bool {
    // Word-at-a-time over unaligned 8-byte chunks, byte tail.
    let words = n / 8;
    for i in 0..words {
        let x = (a.add(8 * i) as *const u64).read_unaligned();
        let y = (b.add(8 * i) as *const u64).read_unaligned();
        if x != y {
            return false;
        }
    }
    for i in words * 8..n {
        if *a.add(i) != *b.add(i) {
            return false;
        }
    }
    true
}

static SCALAR: RowKernel = RowKernel {
    name: "scalar",
    f16_le: f16_le_scalar,
    i8_affine: i8_affine_scalar,
    f32_le: f32_le_scalar,
    bytes_eq: bytes_eq_scalar,
};

// ---------------------------------------------------------------------
// x86_64: SSE2 (baseline) and AVX2 (detected).
//
// The f16 path is the branch-free construction of the scalar conversion
// (after Giesen): shift the 15 payload bits up 13, add 112 to the
// exponent field; lanes whose f16 exponent saturated (inf/NaN) get the
// bias added once more (31 + 224 = 255), and subnormal lanes (exponent
// zero) are rebuilt exactly as `mant · 2⁻²⁴` by setting the implicit-one
// bit and subtracting 2⁻¹⁴ — an exact f32 subtraction, so the result is
// bit-identical to the scalar `mant as f32 / 16_777_216.0`.
// ---------------------------------------------------------------------

#[cfg(target_arch = "x86_64")]
mod x86 {
    use std::arch::x86_64::*;

    use super::{bytes_eq_scalar, f16_le_scalar, f32_le_scalar, i8_affine_scalar, RowKernel};

    #[target_feature(enable = "sse2")]
    unsafe fn f16_le_sse2(src: *const u8, dst: *mut f32, n: usize) {
        let exp_mask = _mm_set1_epi32(0x7c00 << 13);
        let magic = _mm_set1_epi32(112 << 23);
        let one_mant = _mm_set1_epi32(1 << 23);
        let sub_bias = _mm_castsi128_ps(_mm_set1_epi32(113 << 23));
        let zero = _mm_setzero_si128();
        let mut i = 0;
        while i + 4 <= n {
            let h = _mm_loadl_epi64(src.add(2 * i) as *const __m128i);
            let hu = _mm_unpacklo_epi16(h, zero);
            let sign = _mm_slli_epi32::<16>(_mm_and_si128(hu, _mm_set1_epi32(0x8000)));
            let em = _mm_slli_epi32::<13>(_mm_and_si128(hu, _mm_set1_epi32(0x7fff)));
            let exp = _mm_and_si128(em, exp_mask);
            let base = _mm_add_epi32(em, magic);
            let is_inf_nan = _mm_cmpeq_epi32(exp, exp_mask);
            let norm = _mm_add_epi32(base, _mm_and_si128(is_inf_nan, magic));
            let is_sub = _mm_cmpeq_epi32(exp, zero);
            let subval = _mm_sub_ps(_mm_castsi128_ps(_mm_add_epi32(base, one_mant)), sub_bias);
            // SSE2 has no blendv: select via and/andnot/or on the mask.
            let val = _mm_or_si128(
                _mm_and_si128(is_sub, _mm_castps_si128(subval)),
                _mm_andnot_si128(is_sub, norm),
            );
            let out = _mm_or_ps(_mm_castsi128_ps(val), _mm_castsi128_ps(sign));
            _mm_storeu_ps(dst.add(i), out);
            i += 4;
        }
        f16_le_scalar(src.add(2 * i), dst.add(i), n - i);
    }

    #[target_feature(enable = "sse2")]
    unsafe fn i8_affine_sse2(src: *const i8, scale: f32, zero: f32, dst: *mut f32, n: usize) {
        let s = _mm_set1_ps(scale);
        let z = _mm_set1_ps(zero);
        let mut i = 0;
        while i + 4 <= n {
            let raw = (src.add(i) as *const i32).read_unaligned();
            let q = _mm_cvtsi32_si128(raw);
            // Sign-extend i8 → i32: duplicate each byte up through the
            // lane, then arithmetic-shift the top byte down.
            let w16 = _mm_unpacklo_epi8(q, q);
            let w32 = _mm_unpacklo_epi16(w16, w16);
            let w = _mm_srai_epi32::<24>(w32);
            let f = _mm_cvtepi32_ps(w);
            // mul-then-add, not FMA: bit parity with the scalar
            // `scale * q + zero`, which Rust never contracts.
            _mm_storeu_ps(dst.add(i), _mm_add_ps(_mm_mul_ps(f, s), z));
            i += 4;
        }
        i8_affine_scalar(src.add(i), scale, zero, dst.add(i), n - i);
    }

    #[target_feature(enable = "sse2")]
    unsafe fn f32_le_sse2(src: *const u8, dst: *mut f32, n: usize) {
        let mut i = 0;
        while i + 4 <= n {
            let v = _mm_loadu_si128(src.add(4 * i) as *const __m128i);
            _mm_storeu_si128(dst.add(i) as *mut __m128i, v);
            i += 4;
        }
        f32_le_scalar(src.add(4 * i), dst.add(i), n - i);
    }

    #[target_feature(enable = "sse2")]
    unsafe fn bytes_eq_sse2(a: *const u8, b: *const u8, n: usize) -> bool {
        let mut i = 0;
        while i + 16 <= n {
            let x = _mm_loadu_si128(a.add(i) as *const __m128i);
            let y = _mm_loadu_si128(b.add(i) as *const __m128i);
            if _mm_movemask_epi8(_mm_cmpeq_epi8(x, y)) != 0xffff {
                return false;
            }
            i += 16;
        }
        bytes_eq_scalar(a.add(i), b.add(i), n - i)
    }

    #[target_feature(enable = "avx2")]
    unsafe fn f16_le_avx2(src: *const u8, dst: *mut f32, n: usize) {
        let exp_mask = _mm256_set1_epi32(0x7c00 << 13);
        let magic = _mm256_set1_epi32(112 << 23);
        let one_mant = _mm256_set1_epi32(1 << 23);
        let sub_bias = _mm256_castsi256_ps(_mm256_set1_epi32(113 << 23));
        let zero = _mm256_setzero_si256();
        let mut i = 0;
        while i + 8 <= n {
            let h = _mm_loadu_si128(src.add(2 * i) as *const __m128i);
            let hu = _mm256_cvtepu16_epi32(h);
            let sign = _mm256_slli_epi32::<16>(_mm256_and_si256(hu, _mm256_set1_epi32(0x8000)));
            let em = _mm256_slli_epi32::<13>(_mm256_and_si256(hu, _mm256_set1_epi32(0x7fff)));
            let exp = _mm256_and_si256(em, exp_mask);
            let base = _mm256_add_epi32(em, magic);
            let is_inf_nan = _mm256_cmpeq_epi32(exp, exp_mask);
            let norm = _mm256_add_epi32(base, _mm256_and_si256(is_inf_nan, magic));
            let is_sub = _mm256_cmpeq_epi32(exp, zero);
            let grown = _mm256_castsi256_ps(_mm256_add_epi32(base, one_mant));
            let subval = _mm256_sub_ps(grown, sub_bias);
            let val = _mm256_blendv_ps(
                _mm256_castsi256_ps(norm),
                subval,
                _mm256_castsi256_ps(is_sub),
            );
            let out = _mm256_or_ps(val, _mm256_castsi256_ps(sign));
            _mm256_storeu_ps(dst.add(i), out);
            i += 8;
        }
        f16_le_sse2(src.add(2 * i), dst.add(i), n - i);
    }

    #[target_feature(enable = "avx2")]
    unsafe fn i8_affine_avx2(src: *const i8, scale: f32, zero: f32, dst: *mut f32, n: usize) {
        let s = _mm256_set1_ps(scale);
        let z = _mm256_set1_ps(zero);
        let mut i = 0;
        // Unrolled ×2: 16 codes per iteration.
        while i + 16 <= n {
            let q0 = _mm_loadl_epi64(src.add(i) as *const __m128i);
            let q1 = _mm_loadl_epi64(src.add(i + 8) as *const __m128i);
            let f0 = _mm256_cvtepi32_ps(_mm256_cvtepi8_epi32(q0));
            let f1 = _mm256_cvtepi32_ps(_mm256_cvtepi8_epi32(q1));
            _mm256_storeu_ps(dst.add(i), _mm256_add_ps(_mm256_mul_ps(f0, s), z));
            _mm256_storeu_ps(dst.add(i + 8), _mm256_add_ps(_mm256_mul_ps(f1, s), z));
            i += 16;
        }
        while i + 8 <= n {
            let q = _mm_loadl_epi64(src.add(i) as *const __m128i);
            let f = _mm256_cvtepi32_ps(_mm256_cvtepi8_epi32(q));
            _mm256_storeu_ps(dst.add(i), _mm256_add_ps(_mm256_mul_ps(f, s), z));
            i += 8;
        }
        i8_affine_scalar(src.add(i), scale, zero, dst.add(i), n - i);
    }

    #[target_feature(enable = "avx2")]
    unsafe fn f32_le_avx2(src: *const u8, dst: *mut f32, n: usize) {
        let mut i = 0;
        while i + 8 <= n {
            let v = _mm256_loadu_si256(src.add(4 * i) as *const __m256i);
            _mm256_storeu_si256(dst.add(i) as *mut __m256i, v);
            i += 8;
        }
        f32_le_sse2(src.add(4 * i), dst.add(i), n - i);
    }

    #[target_feature(enable = "avx2")]
    unsafe fn bytes_eq_avx2(a: *const u8, b: *const u8, n: usize) -> bool {
        let mut i = 0;
        while i + 32 <= n {
            let x = _mm256_loadu_si256(a.add(i) as *const __m256i);
            let y = _mm256_loadu_si256(b.add(i) as *const __m256i);
            if _mm256_movemask_epi8(_mm256_cmpeq_epi8(x, y)) != -1 {
                return false;
            }
            i += 32;
        }
        bytes_eq_sse2(a.add(i), b.add(i), n - i)
    }

    pub(super) static SSE2: RowKernel = RowKernel {
        name: "sse2",
        f16_le: f16_le_sse2,
        i8_affine: i8_affine_sse2,
        f32_le: f32_le_sse2,
        bytes_eq: bytes_eq_sse2,
    };

    pub(super) static AVX2: RowKernel = RowKernel {
        name: "avx2",
        f16_le: f16_le_avx2,
        i8_affine: i8_affine_avx2,
        f32_le: f32_le_avx2,
        bytes_eq: bytes_eq_avx2,
    };
}

// ---------------------------------------------------------------------
// aarch64 (little-endian): NEON — part of the aarch64 baseline, but
// detected anyway so an exotic runtime can still demote to scalar.
// ---------------------------------------------------------------------

#[cfg(all(target_arch = "aarch64", target_endian = "little"))]
mod arm {
    use std::arch::aarch64::*;

    use super::{bytes_eq_scalar, f16_le_scalar, f32_le_scalar, i8_affine_scalar, RowKernel};

    #[target_feature(enable = "neon")]
    unsafe fn f16_le_neon(src: *const u8, dst: *mut f32, n: usize) {
        let exp_mask = vdupq_n_u32(0x7c00 << 13);
        let magic = vdupq_n_u32(112 << 23);
        let one_mant = vdupq_n_u32(1 << 23);
        let sub_bias = vreinterpretq_f32_u32(vdupq_n_u32(113 << 23));
        let mut i = 0;
        while i + 4 <= n {
            let h = vld1_u16(src.add(2 * i) as *const u16);
            let hu = vmovl_u16(h);
            let sign = vshlq_n_u32::<16>(vandq_u32(hu, vdupq_n_u32(0x8000)));
            let em = vshlq_n_u32::<13>(vandq_u32(hu, vdupq_n_u32(0x7fff)));
            let exp = vandq_u32(em, exp_mask);
            let base = vaddq_u32(em, magic);
            let is_inf_nan = vceqq_u32(exp, exp_mask);
            let norm = vaddq_u32(base, vandq_u32(is_inf_nan, magic));
            let is_sub = vceqq_u32(exp, vdupq_n_u32(0));
            let subval = vsubq_f32(vreinterpretq_f32_u32(vaddq_u32(base, one_mant)), sub_bias);
            let val = vbslq_u32(is_sub, vreinterpretq_u32_f32(subval), norm);
            let out = vorrq_u32(val, sign);
            vst1q_f32(dst.add(i), vreinterpretq_f32_u32(out));
            i += 4;
        }
        f16_le_scalar(src.add(2 * i), dst.add(i), n - i);
    }

    #[target_feature(enable = "neon")]
    unsafe fn i8_affine_neon(src: *const i8, scale: f32, zero: f32, dst: *mut f32, n: usize) {
        let s = vdupq_n_f32(scale);
        let z = vdupq_n_f32(zero);
        let mut i = 0;
        while i + 8 <= n {
            let q = vld1_s8(src.add(i));
            let w = vmovl_s8(q);
            let lo = vcvtq_f32_s32(vmovl_s16(vget_low_s16(w)));
            let hi = vcvtq_f32_s32(vmovl_s16(vget_high_s16(w)));
            // mul-then-add, not vfma: bit parity with the scalar path.
            vst1q_f32(dst.add(i), vaddq_f32(vmulq_f32(lo, s), z));
            vst1q_f32(dst.add(i + 4), vaddq_f32(vmulq_f32(hi, s), z));
            i += 8;
        }
        i8_affine_scalar(src.add(i), scale, zero, dst.add(i), n - i);
    }

    #[target_feature(enable = "neon")]
    unsafe fn f32_le_neon(src: *const u8, dst: *mut f32, n: usize) {
        let mut i = 0;
        while i + 4 <= n {
            vst1q_u8(dst.add(i) as *mut u8, vld1q_u8(src.add(4 * i)));
            i += 4;
        }
        f32_le_scalar(src.add(4 * i), dst.add(i), n - i);
    }

    #[target_feature(enable = "neon")]
    unsafe fn bytes_eq_neon(a: *const u8, b: *const u8, n: usize) -> bool {
        let mut i = 0;
        while i + 16 <= n {
            let eq = vceqq_u8(vld1q_u8(a.add(i)), vld1q_u8(b.add(i)));
            if vminvq_u8(eq) != 0xff {
                return false;
            }
            i += 16;
        }
        bytes_eq_scalar(a.add(i), b.add(i), n - i)
    }

    pub(super) static NEON: RowKernel = RowKernel {
        name: "neon",
        f16_le: f16_le_neon,
        i8_affine: i8_affine_neon,
        f32_le: f32_le_neon,
        bytes_eq: bytes_eq_neon,
    };
}

// ---------------------------------------------------------------------
// Selection and dispatch.
// ---------------------------------------------------------------------

/// How to pick the active kernel (CLI `--kernel`, env `AOTPT_KERNEL`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum KernelMode {
    /// Best detected SIMD set — unless `AOTPT_KERNEL=scalar` overrides
    /// (the env is the CI matrix lever, mirroring `AOTPT_ADAPTER_MMAP`).
    Auto,
    /// The portable scalar reference, unconditionally.
    Scalar,
}

impl KernelMode {
    pub fn parse(s: &str) -> Result<KernelMode> {
        Ok(match s {
            "auto" => KernelMode::Auto,
            "scalar" => KernelMode::Scalar,
            other => bail!("unknown kernel mode {other:?} (expected one of: auto, scalar)"),
        })
    }
}

/// The globally active kernel; null until first use.
static ACTIVE: AtomicPtr<RowKernel> = AtomicPtr::new(std::ptr::null_mut());

/// The active kernel, selecting on first use (env override, then CPU
/// feature detection).
#[inline]
pub fn active() -> &'static RowKernel {
    let p = ACTIVE.load(Ordering::Acquire);
    if p.is_null() {
        let k = select(KernelMode::Auto);
        ACTIVE.store(k as *const RowKernel as *mut RowKernel, Ordering::Release);
        k
    } else {
        unsafe { &*p }
    }
}

/// Re-select the active kernel (the `--kernel` flag; also how the bench
/// flips scalar ↔ SIMD in-process).  Returns the selection.
pub fn set_active(mode: KernelMode) -> &'static RowKernel {
    force(select(mode))
}

/// Install a specific kernel (benches/tests iterating `available()`).
pub fn force(k: &'static RowKernel) -> &'static RowKernel {
    ACTIVE.store(k as *const RowKernel as *mut RowKernel, Ordering::Release);
    k
}

/// The portable scalar reference kernel.
pub fn scalar() -> &'static RowKernel {
    &SCALAR
}

/// Every kernel runnable on this host, scalar first, best last.
pub fn available() -> Vec<&'static RowKernel> {
    let mut v: Vec<&'static RowKernel> = vec![&SCALAR];
    #[cfg(target_arch = "x86_64")]
    {
        v.push(&x86::SSE2);
        if std::is_x86_feature_detected!("avx2") {
            v.push(&x86::AVX2);
        }
    }
    #[cfg(all(target_arch = "aarch64", target_endian = "little"))]
    {
        if std::arch::is_aarch64_feature_detected!("neon") {
            v.push(&arm::NEON);
        }
    }
    v
}

fn select(mode: KernelMode) -> &'static RowKernel {
    if mode == KernelMode::Scalar {
        return &SCALAR;
    }
    if let Ok(v) = std::env::var("AOTPT_KERNEL") {
        match KernelMode::parse(v.trim()) {
            Ok(KernelMode::Scalar) => return &SCALAR,
            Ok(KernelMode::Auto) => {}
            Err(_) => {
                eprintln!("warning: ignoring invalid AOTPT_KERNEL={v:?} (expected auto|scalar)")
            }
        }
    }
    detect()
}

#[cfg(target_arch = "x86_64")]
fn detect() -> &'static RowKernel {
    if std::is_x86_feature_detected!("avx2") {
        &x86::AVX2
    } else {
        // SSE2 is part of the x86_64 baseline.
        &x86::SSE2
    }
}

#[cfg(all(target_arch = "aarch64", target_endian = "little"))]
fn detect() -> &'static RowKernel {
    if std::arch::is_aarch64_feature_detected!("neon") {
        &arm::NEON
    } else {
        &SCALAR
    }
}

#[cfg(not(any(target_arch = "x86_64", all(target_arch = "aarch64", target_endian = "little"))))]
fn detect() -> &'static RowKernel {
    &SCALAR
}

// ---------------------------------------------------------------------
// Row hashing (the dedup pass's bucket key).
// ---------------------------------------------------------------------

/// FNV-1a over the row bytes, eight bytes at a time.  Not cryptographic —
/// hash collisions only cost an extra `rows_equal` check in the dedup
/// pass, never a wrong merge.
pub fn row_hash(bytes: &[u8]) -> u64 {
    const OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
    const PRIME: u64 = 0x0000_0100_0000_01b3;
    let mut h = OFFSET;
    let mut chunks = bytes.chunks_exact(8);
    for c in &mut chunks {
        h ^= u64::from_le_bytes(c.try_into().unwrap());
        h = h.wrapping_mul(PRIME);
    }
    for &b in chunks.remainder() {
        h ^= b as u64;
        h = h.wrapping_mul(PRIME);
    }
    h
}

/// View an f32 row as raw bytes (for `row_hash`/`rows_equal`).
pub fn f32_bytes(row: &[f32]) -> &[u8] {
    // Safety: f32 has no padding and u8 has alignment 1; the length in
    // bytes cannot overflow because the slice exists.
    unsafe { std::slice::from_raw_parts(row.as_ptr() as *const u8, std::mem::size_of_val(row)) }
}

#[cfg(test)]
mod tests {
    use super::super::quant::f32_to_f16_bits;
    use super::*;

    #[test]
    fn scalar_matches_quant_reference() {
        let values = [0.0f32, -0.0, 1.0, -2.5, 1e-4, 6.1e-5, f32::INFINITY, f32::NAN];
        let bits: Vec<u16> = values.iter().map(|&v| f32_to_f16_bits(v)).collect();
        let mut out = vec![0f32; bits.len()];
        scalar().dequant_f16(&bits, &mut out);
        for (&b, &o) in bits.iter().zip(&out) {
            assert_eq!(o.to_bits(), f16_bits_to_f32(b).to_bits());
        }
    }

    #[test]
    fn every_available_kernel_is_bit_exact_on_specials() {
        // Smoke parity here; the exhaustive 65536-pattern sweep lives in
        // rust/tests/kernel_parity.rs.
        let bits: Vec<u16> = vec![
            0x0000, 0x8000, 0x0001, 0x8001, 0x03ff, 0x0400, 0x7bff, 0x7c00, 0xfc00, 0x7c01,
            0x7e00, 0xfe55, 0x3c00, 0xbc00, 0x5555, 0xaaaa,
        ];
        let mut reference = vec![0f32; bits.len()];
        scalar().dequant_f16(&bits, &mut reference);
        for k in available() {
            let mut out = vec![0f32; bits.len()];
            k.dequant_f16(&bits, &mut out);
            for (i, (r, o)) in reference.iter().zip(&out).enumerate() {
                assert_eq!(
                    r.to_bits(),
                    o.to_bits(),
                    "kernel {} diverges from scalar on f16 bits {:#06x}",
                    k.name,
                    bits[i]
                );
            }
        }
    }

    #[test]
    fn i8_affine_matches_scalar_for_every_kernel() {
        let codes: Vec<i8> = (-128i16..=127).map(|q| q as i8).collect();
        for &(scale, zero) in &[(0.031f32, -1.5f32), (0.0, 0.0), (-2.25e-3, 7.0)] {
            let mut reference = vec![0f32; codes.len()];
            scalar().dequant_i8(&codes, scale, zero, &mut reference);
            for k in available() {
                let mut out = vec![0f32; codes.len()];
                k.dequant_i8(&codes, scale, zero, &mut out);
                for (r, o) in reference.iter().zip(&out) {
                    assert_eq!(r.to_bits(), o.to_bits(), "kernel {} i8 divergence", k.name);
                }
            }
        }
    }

    #[test]
    fn rows_equal_is_bytewise() {
        for k in available() {
            let a: Vec<u8> = (0..100u8).collect();
            let mut b = a.clone();
            assert!(k.rows_equal(&a, &b), "{}", k.name);
            b[99] = 0xff;
            assert!(!k.rows_equal(&a, &b), "{} missed a tail diff", k.name);
            b[99] = 99;
            b[40] = 0xff;
            assert!(!k.rows_equal(&a, &b), "{} missed a body diff", k.name);
            assert!(!k.rows_equal(&a, &a[..99]), "{} ignored length", k.name);
            assert!(k.rows_equal(&[], &[]), "{} empty rows are equal", k.name);
        }
    }

    #[test]
    fn row_hash_discriminates_and_is_stable() {
        let a = f32_bytes(&[1.0, 2.0, 3.0]);
        let b = f32_bytes(&[1.0, 2.0, 4.0]);
        assert_eq!(row_hash(a), row_hash(a));
        assert_ne!(row_hash(a), row_hash(b));
        // +0.0 and -0.0 have different bit patterns, so different keys.
        assert_ne!(row_hash(f32_bytes(&[0.0])), row_hash(f32_bytes(&[-0.0])));
    }

    #[test]
    fn mode_parses_and_rejects() {
        assert_eq!(KernelMode::parse("auto").unwrap(), KernelMode::Auto);
        assert_eq!(KernelMode::parse("scalar").unwrap(), KernelMode::Scalar);
        let err = KernelMode::parse("avx512").unwrap_err().to_string();
        assert!(err.contains("auto"), "error should list valid modes: {err}");
    }

    #[test]
    fn available_starts_with_scalar() {
        let v = available();
        assert_eq!(v[0].name, "scalar");
        assert!(!v.is_empty());
    }
}
