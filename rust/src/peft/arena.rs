//! The gather arena: per-bucket reusable host staging buffers.
//!
//! The serving hot path needs five host buffers per batch (token ids,
//! attention mask, the gathered `[l, b, n, d]` AoT bias, and the packed
//! per-row classification heads).  Allocating them per batch made the Rust
//! side rival the backbone execute at small models — exactly the overhead
//! the paper says AoT serving must not have.  The arena checks buffers out
//! by `(batch, seq, slot)` key and checks them back in after the device
//! execute, so the steady state performs **zero heap allocation** on the
//! gather path (DESIGN.md §9; verified by the reuse counters and
//! `benches/gather_hotpath.rs`).
//!
//! Lifecycle and staleness rules:
//! * a buffer is zero-initialized once, when first allocated;
//! * checked-in buffers keep their previous contents — every stage that
//!   writes a slot either overwrites the full region it owns (ids, mask,
//!   heads) or is allowed to leave stale-but-finite rows (the bias filler
//!   rows, whose logits are dropped after execute);
//! * geometry is part of the key, so a bucket change never resizes a
//!   buffer in place; a stale-length buffer is dropped and re-allocated;
//! * a cold batch may *copy* rows in plan-sorted order (DESIGN.md §14),
//!   but every row lands in its fixed output slot, so a checked-out
//!   buffer's contents never depend on the copy order — the filler-row
//!   and overwrite rules above hold unchanged under the plan sort;
//! * under overlapped serving (DESIGN.md §11) up to **two** checkouts per
//!   bucket are in flight at once — one `PreparedBatch` queued while
//!   another executes — so the flat steady state is at most two buffer
//!   sets per active bucket, bounded by the two-slot handoff queue.

use std::collections::HashMap;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

/// Identifies one staging slot of one serving bucket.
type Key = (usize, usize, &'static str);

/// Reusable pool of per-bucket staging buffers with reuse accounting.
#[derive(Default)]
pub struct GatherArena {
    f32_pools: Mutex<HashMap<Key, Vec<Vec<f32>>>>,
    i32_pools: Mutex<HashMap<Key, Vec<Vec<i32>>>>,
    allocs: AtomicUsize,
    reuses: AtomicUsize,
}

impl GatherArena {
    pub fn new() -> GatherArena {
        GatherArena::default()
    }

    /// Check out an f32 buffer of exactly `len` for `(batch, seq, slot)`.
    /// Fresh buffers are zeroed; reused buffers keep prior contents.
    pub fn take_f32(&self, batch: usize, seq: usize, slot: &'static str, len: usize) -> Vec<f32> {
        let pooled = self
            .f32_pools
            .lock()
            .unwrap()
            .get_mut(&(batch, seq, slot))
            .and_then(Vec::pop);
        match pooled {
            Some(buf) if buf.len() == len => {
                self.reuses.fetch_add(1, Ordering::Relaxed);
                buf
            }
            _ => {
                self.allocs.fetch_add(1, Ordering::Relaxed);
                vec![0.0; len]
            }
        }
    }

    /// Check an f32 buffer back in for later reuse.
    pub fn put_f32(&self, batch: usize, seq: usize, slot: &'static str, buf: Vec<f32>) {
        self.f32_pools
            .lock()
            .unwrap()
            .entry((batch, seq, slot))
            .or_default()
            .push(buf);
    }

    /// Check out an i32 buffer of exactly `len` for `(batch, seq, slot)`.
    pub fn take_i32(&self, batch: usize, seq: usize, slot: &'static str, len: usize) -> Vec<i32> {
        let pooled = self
            .i32_pools
            .lock()
            .unwrap()
            .get_mut(&(batch, seq, slot))
            .and_then(Vec::pop);
        match pooled {
            Some(buf) if buf.len() == len => {
                self.reuses.fetch_add(1, Ordering::Relaxed);
                buf
            }
            _ => {
                self.allocs.fetch_add(1, Ordering::Relaxed);
                vec![0; len]
            }
        }
    }

    /// Check an i32 buffer back in for later reuse.
    pub fn put_i32(&self, batch: usize, seq: usize, slot: &'static str, buf: Vec<i32>) {
        self.i32_pools
            .lock()
            .unwrap()
            .entry((batch, seq, slot))
            .or_default()
            .push(buf);
    }

    /// Buffers allocated fresh (should stay flat once every bucket has
    /// been visited — the zero-alloc steady-state invariant).
    pub fn allocs(&self) -> usize {
        self.allocs.load(Ordering::Relaxed)
    }

    /// Buffers served from the pool without allocating.
    pub fn reuses(&self) -> usize {
        self.reuses.load(Ordering::Relaxed)
    }

    /// Buffers currently checked in, across all keys (tests/metrics).
    pub fn pooled(&self) -> usize {
        let f: usize = self.f32_pools.lock().unwrap().values().map(Vec::len).sum();
        let i: usize = self.i32_pools.lock().unwrap().values().map(Vec::len).sum();
        f + i
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fresh_then_reuse() {
        let arena = GatherArena::new();
        let a = arena.take_f32(4, 16, "bias", 64);
        assert_eq!(a.len(), 64);
        assert!(a.iter().all(|&x| x == 0.0));
        assert_eq!(arena.allocs(), 1);
        assert_eq!(arena.reuses(), 0);

        arena.put_f32(4, 16, "bias", a);
        assert_eq!(arena.pooled(), 1);
        let b = arena.take_f32(4, 16, "bias", 64);
        assert_eq!(arena.allocs(), 1);
        assert_eq!(arena.reuses(), 1);
        assert_eq!(b.len(), 64);
        assert_eq!(arena.pooled(), 0);
    }

    #[test]
    fn reuse_keeps_contents() {
        let arena = GatherArena::new();
        let mut a = arena.take_f32(1, 8, "bias", 4);
        a[2] = 7.0;
        arena.put_f32(1, 8, "bias", a);
        let b = arena.take_f32(1, 8, "bias", 4);
        assert_eq!(b[2], 7.0, "checked-in buffers keep prior contents");
    }

    #[test]
    fn distinct_keys_do_not_share() {
        let arena = GatherArena::new();
        arena.put_f32(1, 8, "bias", vec![1.0; 4]);
        // Different bucket, different slot: both miss the pool.
        let a = arena.take_f32(2, 8, "bias", 4);
        assert!(a.iter().all(|&x| x == 0.0));
        let b = arena.take_f32(1, 8, "mask", 4);
        assert!(b.iter().all(|&x| x == 0.0));
        assert_eq!(arena.allocs(), 2);
    }

    #[test]
    fn stale_length_is_dropped_not_reused() {
        let arena = GatherArena::new();
        arena.put_f32(1, 8, "bias", vec![3.0; 5]);
        let a = arena.take_f32(1, 8, "bias", 4);
        assert_eq!(a.len(), 4);
        assert!(a.iter().all(|&x| x == 0.0));
        assert_eq!(arena.allocs(), 1);
        assert_eq!(arena.reuses(), 0);
    }

    #[test]
    fn i32_pool_roundtrip() {
        let arena = GatherArena::new();
        let ids = arena.take_i32(2, 4, "ids", 8);
        arena.put_i32(2, 4, "ids", ids);
        let again = arena.take_i32(2, 4, "ids", 8);
        assert_eq!(again.len(), 8);
        assert_eq!(arena.reuses(), 1);
    }
}
