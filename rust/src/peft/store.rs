//! The AoT P store: tiered per-task fused prompt tables + the
//! ahead-of-time row gather.
//!
//! Paper §3.3: "During the evaluation, there is no need to store the full
//! P in GPU memory.  Instead, it could be stored in RAM, and only rows of
//! these matrices should be placed in GPU memory to be added to the hidden
//! states before each layer."  `gather_batch` is exactly that operation
//! and is the coordinator's per-request hot path — it is benchmarked by
//! `benches/gather_hotpath.rs` and must never dominate the backbone
//! execute (DESIGN.md §9, L3 target).
//!
//! Storage is tiered (DESIGN.md §10): the gather never assumes a resident
//! f32 `Vec` — it speaks to every tier through [`RowSource`], so tables
//! may live in RAM as f32 ([`TaskP`]), in RAM as f16
//! ([`super::quant::QuantizedTaskP`]), or on disk
//! ([`super::residency::ColdTable`] — mmap-backed where supported, with a
//! positioned-read fallback; DESIGN.md §13), moving between tiers under
//! an LRU RAM budget while the pipeline is serving.  All lifecycle operations
//! (`insert`/`remove`/`pin`) take `&self`; in-flight gathers hold `Arc`
//! snapshots, so eviction and unregistration never corrupt a running
//! batch.

use std::sync::{Arc, Mutex};

use anyhow::{bail, Context};

use crate::tensor::Tensor;
use crate::Result;

use super::pool::GatherPool;
use super::quant::AdapterDType;
use super::residency::{AdapterConfig, AdapterStats, Residency, TaskInfo};

/// Logical-vs-stored row counts of a source — the dedup observability
/// that feeds `AdapterStats::dedup_ratio` (DESIGN.md §12).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct RowCounts {
    /// Rows the table answers for: `layers × vocab`, every tier.
    pub logical: usize,
    /// Rows physically stored (the dedup pool's `U`; == `logical` for
    /// dense tables).
    pub stored: usize,
    /// Logical rows served by the shared all-zero row (stored nowhere).
    pub zero_shared: usize,
}

/// One tier's view of a task table: "give me row (layer, token)".
///
/// Implementations: [`TaskP`] (resident f32),
/// [`super::quant::QuantizedTaskP`] (resident f16),
/// [`super::quant::Int8TaskP`] (resident int8), [`DedupTaskP`] (a
/// `u32` row-index indirection over any of those),
/// [`super::residency::ColdTable`] (disk).  `copy_row` always produces
/// f32 into the caller's (arena-owned) buffer, so the device-visible bias
/// layout is identical across tiers.
pub trait RowSource: Send + Sync {
    fn layers(&self) -> usize;
    fn vocab(&self) -> usize;
    fn d_model(&self) -> usize;
    /// Storage dtype of this source.
    fn dtype(&self) -> AdapterDType;
    /// Tier label (`"ram-f32"`, `"ram-f16"`, `"ram-int8"`,
    /// `"ram-*+dedup"`, `"disk"`) for tests/logs.
    fn tier(&self) -> &'static str;
    /// Host RAM pinned by this source (0 for disk-backed tables).
    fn resident_bytes(&self) -> usize;
    /// Copy row (layer, token), dequantized to f32, into `out`
    /// (length `d_model`).  Only the disk tier can fail.
    fn copy_row(&self, layer: usize, token: usize, out: &mut [f32]) -> Result<()>;
    /// Stream the raw table payload (little-endian, storage dtype) for
    /// spilling to disk.  Disk-backed sources decline.
    fn spill_into(&self, w: &mut dyn std::io::Write) -> Result<()>;
    /// Per-stored-row `(scale, zero)` of an affine-quantized source
    /// (the int8 tier); `None` for exact dtypes.  The spill path writes
    /// these as f32 sidecar tensors.
    fn quant_params(&self) -> Option<(&[f32], &[f32])> {
        None
    }
    /// The `u32` row-index indirection of a dedup'd source (`0` = shared
    /// zero row, `k` = stored row `k − 1`); `None` for dense tables.
    fn dedup_index(&self) -> Option<&[u32]> {
        None
    }
    /// Logical/stored/zero-shared row counts.  Dense default: every
    /// logical row is stored.  Must be identical for every tier of the
    /// same table version (residency accounting adds these at insert and
    /// subtracts at retire, across spills and fault-ins).
    fn row_stats(&self) -> RowCounts {
        let logical = self.layers() * self.vocab();
        RowCounts { logical, stored: logical, zero_shared: 0 }
    }
}

/// L2 norms of every vocabulary row at `layer` — the §4.3 analysis
/// ("tokens with the largest ‖P_x‖₂"), tier-agnostic.
pub fn row_norms(src: &dyn RowSource, layer: usize) -> Result<Vec<f32>> {
    let d = src.d_model();
    let mut row = vec![0f32; d];
    let mut out = Vec::with_capacity(src.vocab());
    for tok in 0..src.vocab() {
        src.copy_row(layer, tok, &mut row)?;
        out.push(row.iter().map(|x| x * x).sum::<f32>().sqrt());
    }
    Ok(out)
}

/// One task's fused table resident as f32, laid out `[l, V, d]` row-major
/// so a (layer, token) row is one contiguous `d`-float slice.
pub struct TaskP {
    pub layers: usize,
    pub vocab: usize,
    pub d_model: usize,
    data: Vec<f32>,
}

impl TaskP {
    pub fn new(layers: usize, vocab: usize, d_model: usize, data: Vec<f32>) -> Result<TaskP> {
        if data.len() != layers * vocab * d_model {
            bail!(
                "TaskP: data length {} != {}x{}x{}",
                data.len(),
                layers,
                vocab,
                d_model
            );
        }
        Ok(TaskP { layers, vocab, d_model, data })
    }

    pub fn from_tensor(layers: usize, vocab: usize, d_model: usize, t: &Tensor) -> Result<TaskP> {
        t.check_shape(&[layers, vocab, d_model])?;
        TaskP::new(layers, vocab, d_model, t.as_f32()?.to_vec())
    }

    /// A zero table (a fresh/untrained task is exactly the backbone).
    pub fn zeros(layers: usize, vocab: usize, d_model: usize) -> TaskP {
        TaskP { layers, vocab, d_model, data: vec![0.0; layers * vocab * d_model] }
    }

    #[inline]
    pub fn row(&self, layer: usize, token: usize) -> &[f32] {
        let d = self.d_model;
        let start = (layer * self.vocab + token) * d;
        &self.data[start..start + d]
    }

    /// The full `[l·V·d]` payload (fused-time quantization reads this).
    pub fn data(&self) -> &[f32] {
        &self.data
    }

    /// Host-RAM footprint in bytes (paper §3.3's RAM-vs-speed trade-off).
    pub fn bytes(&self) -> usize {
        self.data.len() * 4
    }

    /// L2 norms of every vocabulary row at `layer` — the §4.3 analysis
    /// ("tokens with the largest ‖P_x‖₂").
    pub fn row_norms(&self, layer: usize) -> Vec<f32> {
        (0..self.vocab)
            .map(|t| self.row(layer, t).iter().map(|x| x * x).sum::<f32>().sqrt())
            .collect()
    }
}

impl RowSource for TaskP {
    fn layers(&self) -> usize {
        self.layers
    }

    fn vocab(&self) -> usize {
        self.vocab
    }

    fn d_model(&self) -> usize {
        self.d_model
    }

    fn dtype(&self) -> AdapterDType {
        AdapterDType::F32
    }

    fn tier(&self) -> &'static str {
        "ram-f32"
    }

    fn resident_bytes(&self) -> usize {
        self.bytes()
    }

    #[inline]
    fn copy_row(&self, layer: usize, token: usize, out: &mut [f32]) -> Result<()> {
        super::kernel::active().copy_f32(self.row(layer, token), out);
        Ok(())
    }

    fn spill_into(&self, w: &mut dyn std::io::Write) -> Result<()> {
        for &v in &self.data {
            w.write_all(&v.to_le_bytes())?;
        }
        Ok(())
    }
}

/// A dedup'd task table: a per-layer `u32` row-index indirection over a
/// pool of unique rows (DESIGN.md §12).
///
/// `index[layer·V + token] == 0` is the all-zero row every task shares —
/// `copy_row` fills zeros without touching storage (paper §4.3: most
/// trained ‖P_x‖ are near zero, so most gathers land here).  Nonzero
/// entries point into `rows`, an ordinary dense [`RowSource`] of
/// geometry `[1, U, d]`, so dedup composes with every storage dtype
/// (f32/f16/int8 pools).  Index and pool live behind one `Arc` snapshot:
/// in-flight gathers can never see a new index over an old pool.
pub struct DedupTaskP {
    layers: usize,
    vocab: usize,
    d_model: usize,
    index: Vec<u32>,
    rows: Arc<dyn RowSource>,
    zero_rows: usize,
}

impl DedupTaskP {
    pub fn new(
        layers: usize,
        vocab: usize,
        d_model: usize,
        index: Vec<u32>,
        rows: Arc<dyn RowSource>,
    ) -> Result<DedupTaskP> {
        if index.len() != layers * vocab {
            bail!("DedupTaskP: index length {} != {layers}x{vocab}", index.len());
        }
        if (rows.layers(), rows.d_model()) != (1, d_model) {
            bail!(
                "DedupTaskP: pool geometry [{}, {}, {}] is not [1, U, {d_model}]",
                rows.layers(),
                rows.vocab(),
                rows.d_model()
            );
        }
        let pool_rows = rows.vocab() as u32;
        if let Some(&bad) = index.iter().find(|&&ix| ix > pool_rows) {
            bail!("DedupTaskP: index entry {bad} exceeds pool of {pool_rows} rows");
        }
        let zero_rows = index.iter().filter(|&&ix| ix == 0).count();
        Ok(DedupTaskP { layers, vocab, d_model, index, rows, zero_rows })
    }

    /// Build from a fuse-time [`super::fuse::DedupPlan`], quantizing the
    /// unique-row pool to the configured storage dtype.
    pub fn from_plan(
        layers: usize,
        vocab: usize,
        plan: &super::fuse::DedupPlan,
        dtype: AdapterDType,
    ) -> Result<DedupTaskP> {
        let d = plan.d_model;
        let unique = plan.unique_rows();
        let rows: Arc<dyn RowSource> = match dtype {
            AdapterDType::F32 => Arc::new(TaskP::new(1, unique, d, plan.unique.clone())?),
            AdapterDType::F16 => Arc::new(super::quant::QuantizedTaskP::new(
                1,
                unique,
                d,
                super::quant::quantize(&plan.unique),
            )?),
            AdapterDType::I8 => {
                Arc::new(super::quant::Int8TaskP::from_rows(1, unique, d, &plan.unique))
            }
        };
        DedupTaskP::new(layers, vocab, d, plan.index.clone(), rows)
    }

    /// The unique-row pool (the residency layer streams it on spill).
    pub fn rows(&self) -> &Arc<dyn RowSource> {
        &self.rows
    }
}

impl RowSource for DedupTaskP {
    fn layers(&self) -> usize {
        self.layers
    }

    fn vocab(&self) -> usize {
        self.vocab
    }

    fn d_model(&self) -> usize {
        self.d_model
    }

    fn dtype(&self) -> AdapterDType {
        self.rows.dtype()
    }

    fn tier(&self) -> &'static str {
        match self.rows.dtype() {
            AdapterDType::F32 => "ram-f32+dedup",
            AdapterDType::F16 => "ram-f16+dedup",
            AdapterDType::I8 => "ram-int8+dedup",
        }
    }

    fn resident_bytes(&self) -> usize {
        self.index.len() * 4 + self.rows.resident_bytes()
    }

    #[inline]
    fn copy_row(&self, layer: usize, token: usize, out: &mut [f32]) -> Result<()> {
        match self.index[layer * self.vocab + token] {
            0 => {
                out.fill(0.0);
                Ok(())
            }
            slot => self.rows.copy_row(0, (slot - 1) as usize, out),
        }
    }

    fn spill_into(&self, w: &mut dyn std::io::Write) -> Result<()> {
        // The "p" tensor of a dedup'd spill is the pool; the index and
        // any quant sidecars are separate tensors (residency::write_spill).
        self.rows.spill_into(w)
    }

    fn quant_params(&self) -> Option<(&[f32], &[f32])> {
        self.rows.quant_params()
    }

    fn dedup_index(&self) -> Option<&[u32]> {
        Some(&self.index)
    }

    fn row_stats(&self) -> RowCounts {
        RowCounts {
            logical: self.layers * self.vocab,
            stored: self.rows.vocab(),
            zero_shared: self.zero_rows,
        }
    }
}

/// Minimum live elements per layer before the gather fans out to scoped
/// threads (below this, spawn overhead rivals the copy itself).
const PARALLEL_MIN_ELEMS: usize = 16 * 1024;

/// All registered tasks' tables, tiered and hot-mutable: registration,
/// replacement, unregistration and eviction all run on `&self` while
/// gathers are in flight (snapshot isolation via per-gather `Arc`
/// resolution — DESIGN.md §10).
pub struct PStore {
    layers: usize,
    vocab: usize,
    d_model: usize,
    /// Shared with the background prefetch worker (which holds a `Weak`),
    /// hence the `Arc`.
    residency: Arc<Residency>,
    /// Recycled gather-plan index buffers (cold batches only — resident
    /// batches never build a plan), so the sorted cold gather stays
    /// allocation-free in steady state too (DESIGN.md §14).
    plan_pool: Mutex<Vec<Vec<u32>>>,
}

impl PStore {
    /// A store with default tiering: resident f32, unlimited RAM budget
    /// (the seed behavior).
    pub fn new(layers: usize, vocab: usize, d_model: usize) -> PStore {
        PStore::with_config(layers, vocab, d_model, AdapterConfig::default())
    }

    /// A store with explicit tiering (dtype, RAM budget, spill dir).
    pub fn with_config(
        layers: usize,
        vocab: usize,
        d_model: usize,
        cfg: AdapterConfig,
    ) -> PStore {
        PStore {
            layers,
            vocab,
            d_model,
            residency: Arc::new(Residency::new(layers, vocab, d_model, cfg)),
            plan_pool: Mutex::new(Vec::new()),
        }
    }

    pub fn config(&self) -> &AdapterConfig {
        self.residency.config()
    }

    /// Register (or hot-replace) a task's fused table.  The table is
    /// dedup'd (when `--adapter-dedup` is on) and quantized to the
    /// configured storage dtype here, at fuse time; a table that cannot
    /// fit the RAM budget goes straight to the disk tier.  In-flight
    /// gathers against a replaced table finish on their snapshot.
    pub fn insert(&self, task: &str, p: TaskP) -> Result<()> {
        if (p.layers, p.vocab, p.d_model) != (self.layers, self.vocab, self.d_model) {
            bail!("task {task}: table geometry mismatch");
        }
        let cfg = self.residency.config();
        let table: Arc<dyn RowSource> = if cfg.dedup {
            let plan = super::fuse::dedup_rows(&p, cfg.dedup_eps);
            Arc::new(DedupTaskP::from_plan(p.layers, p.vocab, &plan, cfg.dtype)?)
        } else {
            match cfg.dtype {
                AdapterDType::F32 => Arc::new(p),
                AdapterDType::F16 => Arc::new(super::quant::QuantizedTaskP::from_taskp(&p)),
                AdapterDType::I8 => Arc::new(super::quant::Int8TaskP::from_taskp(&p)),
            }
        };
        self.residency.insert(task, table)
    }

    /// Unregister a task while serving.  In-flight gathers finish on
    /// their snapshots; later resolves error.
    pub fn remove(&self, task: &str) -> Result<()> {
        self.residency.remove(task)
    }

    /// Pin a task into RAM (never evicted) or release it.
    pub fn pin(&self, task: &str, pinned: bool) -> Result<()> {
        self.residency.pin(task, pinned)
    }

    /// Resolve a task to its current tier's row source (faulting the
    /// table in from disk if the budget allows).  This is the per-gather
    /// snapshot point: the returned `Arc` stays valid across any
    /// concurrent eviction, replacement or unregistration.
    pub fn get(&self, task: &str) -> Result<Arc<dyn RowSource>> {
        self.residency.resolve(task)
    }

    /// Registered task names, sorted (deterministic across runs; same
    /// order and type as `TaskRegistry::task_names`).
    pub fn task_names(&self) -> Vec<String> {
        self.residency.names_sorted()
    }

    /// Per-task management rows (name, pinned, tier, dtype, resident
    /// bytes), sorted by name; never blocks on a contended entry.
    pub fn task_infos(&self) -> Vec<TaskInfo> {
        self.residency.task_infos()
    }

    pub fn len(&self) -> usize {
        self.residency.len()
    }

    pub fn is_empty(&self) -> bool {
        self.residency.is_empty()
    }

    /// Host RAM currently held by resident tables (spilled tables count
    /// zero — the paper's §3.3 trade-off, now under an explicit budget).
    pub fn bytes(&self) -> usize {
        self.residency.resident_bytes()
    }

    /// Residency/tier counters for `MetricsSnapshot`.
    pub fn stats(&self) -> AdapterStats {
        self.residency.stats()
    }

    /// Table geometry accessors (the serving pipeline sizes its arena
    /// buffers from these).
    pub fn layers(&self) -> usize {
        self.layers
    }

    pub fn vocab(&self) -> usize {
        self.vocab
    }

    pub fn d_model(&self) -> usize {
        self.d_model
    }

    /// THE hot path: gather bias `[l, b, n, d]` for a multi-task batch.
    ///
    /// `assignments[j]` names the task of batch row `j`; `ids` is the
    /// padded `[b, n]` token matrix.  The output layout matches the
    /// serving artifact's `in.bias` input exactly, so the result is
    /// uploaded without any further reshuffling.
    pub fn gather(&self, assignments: &[&str], ids: &[i32], n: usize) -> Result<Tensor> {
        let b = assignments.len();
        if ids.len() != b * n {
            bail!("gather: ids length {} != {b}x{n}", ids.len());
        }
        let d = self.d_model;
        let mut out = vec![0f32; self.layers * b * n * d];
        self.gather_into(assignments, ids, n, &mut out)?;
        Ok(Tensor::from_f32(&[self.layers, b, n, d], out))
    }

    /// Allocation-free serial variant for a caller-managed buffer, one
    /// assignment per bucket row (the pre-pipeline behavior).
    pub fn gather_into(
        &self,
        assignments: &[&str],
        ids: &[i32],
        n: usize,
        out: &mut [f32],
    ) -> Result<()> {
        self.gather_batch(assignments, ids, n, assignments.len(), 1, out)
    }

    /// The serving pipeline's gather: fill `out = [l, b, n, d]` for a
    /// bucket of `b` rows of which only the first `assignments.len()` are
    /// live requests.  Filler rows (their logits are dropped after the
    /// execute) are skipped entirely — their region of `out` keeps
    /// whatever finite values it held, which is safe because backbone
    /// rows are computed independently.  Layers are gathered on up to
    /// `threads` scoped threads.
    ///
    /// Each live row's task is resolved to an `Arc` snapshot up front, so
    /// concurrent eviction/unregistration never affects this batch, and
    /// the resident-tier steady state stays free of arena allocations.
    ///
    /// Token ids of live rows are validated against the vocabulary and
    /// rejected with an error — a bad id must never panic the worker
    /// (release builds would otherwise die on the slice bound).
    pub fn gather_batch(
        &self,
        assignments: &[&str],
        ids: &[i32],
        n: usize,
        b: usize,
        threads: usize,
        out: &mut [f32],
    ) -> Result<()> {
        let Some(sources) = self.gather_prep(assignments, ids, n, b, out.len())? else {
            return Ok(()); // degenerate geometry or no live rows
        };
        let live = assignments.len();
        let d = self.d_model;
        let layer_block = b * n * d;
        let plan = self.build_plan(&sources, ids, n);
        // Scoped threads cost tens of microseconds to spawn; only go
        // parallel when the per-layer copy is large enough to repay that
        // (single-row/short-sequence batches stay serial).
        let threads = if live * n * d < PARALLEL_MIN_ELEMS {
            1
        } else {
            threads.clamp(1, self.layers)
        };
        let result = if threads == 1 {
            let mut res = Ok(());
            for (layer, layer_out) in out.chunks_mut(layer_block).enumerate() {
                res = gather_layer(&sources, layer, ids, n, d, &plan, layer_out);
                if res.is_err() {
                    break;
                }
            }
            res
        } else {
            let layers_per = self.layers.div_ceil(threads);
            // Only the disk tier can fail mid-copy; the first error wins
            // and fails the whole batch (partial output is discarded
            // upstream).
            let first_err: Mutex<Option<anyhow::Error>> = Mutex::new(None);
            std::thread::scope(|scope| {
                for (chunk_idx, chunk) in out.chunks_mut(layers_per * layer_block).enumerate() {
                    let sources = &sources;
                    let first_err = &first_err;
                    let plan = &plan;
                    scope.spawn(move || {
                        for (i, layer_out) in chunk.chunks_mut(layer_block).enumerate() {
                            let layer = chunk_idx * layers_per + i;
                            if let Err(e) = gather_layer(sources, layer, ids, n, d, plan, layer_out)
                            {
                                *first_err.lock().unwrap() = Some(e);
                                return;
                            }
                        }
                    });
                }
            });
            match first_err.into_inner().unwrap() {
                Some(e) => Err(e),
                None => Ok(()),
            }
        };
        self.residency.note_gather_rows(live * n * self.layers, !plan.is_empty());
        self.retire_plan(plan);
        result
    }

    /// The overlapped pipeline's gather: identical semantics and geometry
    /// checks to [`PStore::gather_batch`], but layer shards run on the
    /// persistent [`GatherPool`] (spawned once per pipeline) instead of
    /// per-batch scoped threads — the serving hot path pays a channel
    /// send per shard, not a thread spawn (DESIGN.md §11).
    pub fn gather_batch_pooled(
        &self,
        assignments: &[&str],
        ids: &[i32],
        n: usize,
        b: usize,
        pool: &GatherPool,
        out: &mut [f32],
    ) -> Result<()> {
        let Some(sources) = self.gather_prep(assignments, ids, n, b, out.len())? else {
            return Ok(()); // degenerate geometry or no live rows
        };
        let live = assignments.len();
        let d = self.d_model;
        let layer_block = b * n * d;
        let plan = self.build_plan(&sources, ids, n);
        let result = if live * n * d < PARALLEL_MIN_ELEMS || pool.threads() == 1 {
            let mut res = Ok(());
            for (layer, layer_out) in out.chunks_mut(layer_block).enumerate() {
                res = gather_layer(&sources, layer, ids, n, d, &plan, layer_out);
                if res.is_err() {
                    break;
                }
            }
            res
        } else {
            pool.gather(&sources, ids, n, d, layer_block, &plan, out)
        };
        self.residency.note_gather_rows(live * n * self.layers, !plan.is_empty());
        self.retire_plan(plan);
        result
    }

    /// Build the per-batch gather plan (DESIGN.md §14): when any live
    /// row serves from the disk tier, order the row copies by
    /// (source table, token id) so cold/mmap reads walk the spill file —
    /// and the page cache behind it — near-sequentially instead of in
    /// token order.  One plan covers every layer (the sort key does not
    /// depend on the layer).  Resident-only batches return the empty
    /// plan and allocate nothing: RAM rows gain nothing from reordering,
    /// and the zero-alloc steady state must hold.  Every planned copy
    /// still writes to its fixed `[l, b, n, d]` slot, so the output is
    /// bit-identical to the unplanned walk.
    fn build_plan(&self, sources: &[Arc<dyn RowSource>], ids: &[i32], n: usize) -> Vec<u32> {
        if n == 0 || !sources.iter().any(|s| s.tier() == "disk") {
            return Vec::new();
        }
        let mut plan = self.plan_pool.lock().unwrap().pop().unwrap_or_default();
        plan.clear();
        plan.extend(0..(sources.len() * n) as u32);
        plan.sort_unstable_by_key(|&e| {
            let j = e as usize / n;
            // Thin-pointer cast drops the vtable half of the fat pointer:
            // the sort only needs a stable per-table identity.
            (Arc::as_ptr(&sources[j]) as *const u8 as usize, ids[e as usize])
        });
        plan
    }

    /// Return a plan buffer to the pool (bounded), so steady-state cold
    /// gathers reuse instead of allocating.
    fn retire_plan(&self, plan: Vec<u32>) {
        if plan.capacity() == 0 {
            return;
        }
        let mut pool = self.plan_pool.lock().unwrap();
        if pool.len() < 8 {
            pool.push(plan);
        }
    }

    /// Shared validation + snapshot resolution for the gather entry
    /// points.  Resolves tiers once per row, not once per token — the
    /// snapshot point for eviction/unregister isolation.  Returns `None`
    /// when there is nothing to copy (degenerate geometry, no live rows).
    fn gather_prep(
        &self,
        assignments: &[&str],
        ids: &[i32],
        n: usize,
        b: usize,
        out_len: usize,
    ) -> Result<Option<Vec<Arc<dyn RowSource>>>> {
        let live = assignments.len();
        let d = self.d_model;
        if live > b {
            bail!("gather_batch: {live} live rows exceed bucket batch {b}");
        }
        if ids.len() != b * n {
            bail!("gather_batch: ids length {} != {b}x{n}", ids.len());
        }
        if out_len != self.layers * b * n * d {
            bail!(
                "gather_batch: output length {out_len} != {}x{b}x{n}x{d}",
                self.layers
            );
        }
        if live * n * d * self.layers == 0 {
            return Ok(None);
        }
        self.validate_ids(assignments, &ids[..live * n], n)?;
        let sources: Vec<Arc<dyn RowSource>> = assignments
            .iter()
            .map(|t| self.get(t))
            .collect::<Result<_>>()?;
        Ok(Some(sources))
    }

    /// Queue background fault-in for any of `tasks` currently on the disk
    /// tier (gather-aware prefetch: the planner calls this the moment a
    /// batch's tasks are known, so the gather's `get` finds them warm).
    pub fn prefetch(&self, tasks: &[String]) {
        Residency::prefetch(&self.residency, tasks);
    }

    /// Prefetches queued or in flight on the background worker (0 =
    /// drained).  Tests use this to wait for prefetch deterministically.
    pub fn prefetch_backlog(&self) -> usize {
        self.residency.prefetch_backlog()
    }

    fn validate_ids(&self, assignments: &[&str], ids: &[i32], n: usize) -> Result<()> {
        for (j, task) in assignments.iter().enumerate() {
            for (t, &tok) in ids[j * n..(j + 1) * n].iter().enumerate() {
                if tok < 0 || tok as usize >= self.vocab {
                    bail!(
                        "task {task:?} (batch row {j}, seq position {t}): token id {tok} \
                         outside vocabulary [0, {})",
                        self.vocab
                    );
                }
            }
        }
        Ok(())
    }
}

/// Copy one layer's rows for every live assignment (ids pre-validated).
/// Shared by the scoped-thread path, the pooled path and the serial
/// fallback — `pub(crate)` so [`GatherPool`] workers can run it.  With a
/// non-empty `plan` (cold batches, DESIGN.md §14) rows are copied in
/// (source table, token id) order; each copy still writes to the fixed
/// slot of its (row, position) pair, so the output layout is identical
/// to the unplanned walk.
pub(crate) fn gather_layer(
    sources: &[Arc<dyn RowSource>],
    layer: usize,
    ids: &[i32],
    n: usize,
    d: usize,
    plan: &[u32],
    out: &mut [f32],
) -> Result<()> {
    if plan.is_empty() {
        for (j, src) in sources.iter().enumerate() {
            let row_base = j * n * d;
            for t in 0..n {
                let tok = ids[j * n + t] as usize;
                let slot = &mut out[row_base + t * d..row_base + (t + 1) * d];
                src.copy_row(layer, tok, slot).with_context(|| {
                    format!("gather: layer {layer}, batch row {j}, token {tok}")
                })?;
            }
        }
        return Ok(());
    }
    for &e in plan {
        let e = e as usize;
        let (j, tok) = (e / n, ids[e] as usize);
        let base = e * d;
        sources[j].copy_row(layer, tok, &mut out[base..base + d]).with_context(|| {
            format!("gather: layer {layer}, batch row {j}, token {tok}")
        })?;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::peft::residency::parse_bytes;
    use crate::util::Pcg64;

    fn store(layers: usize, vocab: usize, d: usize) -> PStore {
        let s = PStore::new(layers, vocab, d);
        let mut rng = Pcg64::new(1);
        for task in ["a", "b"] {
            let data = rng.normal_vec(layers * vocab * d, 1.0);
            s.insert(task, TaskP::new(layers, vocab, d, data).unwrap()).unwrap();
        }
        s
    }

    fn row_of(src: &dyn RowSource, layer: usize, tok: usize) -> Vec<f32> {
        let mut out = vec![0f32; src.d_model()];
        src.copy_row(layer, tok, &mut out).unwrap();
        out
    }

    #[test]
    fn gather_matches_manual_lookup() {
        let (l, v, d, n) = (3, 50, 8, 5);
        let s = store(l, v, d);
        let mut rng = Pcg64::new(2);
        let ids: Vec<i32> = (0..2 * n).map(|_| rng.range(0, v as i64) as i32).collect();
        let out = s.gather(&["a", "b"], &ids, n).unwrap();
        assert_eq!(out.shape, vec![l, 2, n, d]);
        let data = out.as_f32().unwrap();
        for layer in 0..l {
            for (j, task) in ["a", "b"].iter().enumerate() {
                let table = s.get(task).unwrap();
                for t in 0..n {
                    let tok = ids[j * n + t] as usize;
                    let got = &data[((layer * 2 + j) * n + t) * d..((layer * 2 + j) * n + t + 1) * d];
                    assert_eq!(got, row_of(table.as_ref(), layer, tok), "layer {layer} row {j} tok {t}");
                }
            }
        }
    }

    #[test]
    fn zero_table_gathers_zeros() {
        let s = PStore::new(2, 10, 4);
        s.insert("z", TaskP::zeros(2, 10, 4)).unwrap();
        let out = s.gather(&["z"], &[1, 2, 3], 3).unwrap();
        assert!(out.as_f32().unwrap().iter().all(|&x| x == 0.0));
    }

    #[test]
    fn geometry_mismatch_rejected() {
        let s = PStore::new(2, 10, 4);
        assert!(s.insert("bad", TaskP::zeros(3, 10, 4)).is_err());
        assert!(s.get("missing").is_err());
    }

    #[test]
    fn row_norms_pick_out_heavy_tokens() {
        let (l, v, d) = (1, 8, 4);
        let mut data = vec![0f32; l * v * d];
        for x in &mut data[5 * d..6 * d] {
            *x = 3.0; // token 5 gets a heavy row
        }
        let p = TaskP::new(l, v, d, data).unwrap();
        let norms = p.row_norms(0);
        let argmax = norms
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .unwrap()
            .0;
        assert_eq!(argmax, 5);
        assert!((norms[5] - 6.0).abs() < 1e-6); // sqrt(4 * 9)
        // The tier-agnostic helper agrees with the inherent method.
        assert_eq!(super::row_norms(&p, 0).unwrap(), norms);
    }

    #[test]
    fn ram_accounting() {
        let s = store(2, 10, 4);
        assert_eq!(s.bytes(), 2 * 2 * 10 * 4 * 4);
    }

    #[test]
    fn task_names_are_sorted_and_deterministic() {
        let s = PStore::new(1, 4, 2);
        for name in ["zeta", "alpha", "mid"] {
            s.insert(name, TaskP::zeros(1, 4, 2)).unwrap();
        }
        assert_eq!(s.task_names(), vec!["alpha", "mid", "zeta"]);
        assert_eq!(s.len(), 3);
    }

    #[test]
    fn hot_remove_and_replace() {
        let (l, v, d) = (1, 6, 2);
        let s = PStore::new(l, v, d);
        s.insert("x", TaskP::new(l, v, d, vec![1.0; l * v * d]).unwrap()).unwrap();
        let snapshot = s.get("x").unwrap();
        s.insert("x", TaskP::new(l, v, d, vec![2.0; l * v * d]).unwrap()).unwrap();
        // Snapshot isolation: the old Arc still reads the old values.
        assert_eq!(row_of(snapshot.as_ref(), 0, 0), vec![1.0; d]);
        assert_eq!(row_of(s.get("x").unwrap().as_ref(), 0, 0), vec![2.0; d]);
        s.remove("x").unwrap();
        assert!(s.get("x").is_err());
        assert!(s.remove("x").is_err());
        assert_eq!(s.bytes(), 0);
    }

    #[test]
    fn f16_store_gathers_within_tolerance() {
        let (l, v, d, n) = (2, 30, 8, 6);
        let cfg = AdapterConfig { dtype: AdapterDType::F16, ..Default::default() };
        let f16_store = PStore::with_config(l, v, d, cfg);
        let f32_store = PStore::new(l, v, d);
        let mut rng = Pcg64::new(21);
        let data = rng.normal_vec(l * v * d, 1.0);
        f16_store.insert("t", TaskP::new(l, v, d, data.clone()).unwrap()).unwrap();
        f32_store.insert("t", TaskP::new(l, v, d, data).unwrap()).unwrap();
        assert_eq!(f16_store.bytes() * 2, f32_store.bytes());
        assert_eq!(f16_store.get("t").unwrap().tier(), "ram-f16");
        let ids: Vec<i32> = (0..n).map(|_| rng.range(0, v as i64) as i32).collect();
        let a = f16_store.gather(&["t"], &ids, n).unwrap();
        let b = f32_store.gather(&["t"], &ids, n).unwrap();
        for (x, y) in a.as_f32().unwrap().iter().zip(b.as_f32().unwrap()) {
            assert!((x - y).abs() < 1e-2, "{x} vs {y}");
        }
    }

    #[test]
    fn spilled_store_gather_is_bit_identical_to_resident() {
        let (l, v, d, n) = (2, 25, 4, 7);
        let mut rng = Pcg64::new(22);
        let data = rng.normal_vec(l * v * d, 1.0);
        // Budget below one table: everything serves from the disk tier.
        let table_bytes = l * v * d * 4;
        let cfg = AdapterConfig { ram_budget_bytes: table_bytes / 2, ..Default::default() };
        let cold_store = PStore::with_config(l, v, d, cfg);
        let hot_store = PStore::new(l, v, d);
        cold_store.insert("t", TaskP::new(l, v, d, data.clone()).unwrap()).unwrap();
        hot_store.insert("t", TaskP::new(l, v, d, data).unwrap()).unwrap();
        assert_eq!(cold_store.get("t").unwrap().tier(), "disk");
        let ids: Vec<i32> = (0..n).map(|_| rng.range(0, v as i64) as i32).collect();
        let cold = cold_store.gather(&["t"], &ids, n).unwrap();
        let hot = hot_store.gather(&["t"], &ids, n).unwrap();
        assert_eq!(cold.as_f32().unwrap(), hot.as_f32().unwrap());
        let stats = cold_store.stats();
        assert!(stats.cold_serves >= 1);
        assert_eq!(stats.resident_tasks, 0);
        assert_eq!(stats.spilled_tasks, 1);
    }

    #[test]
    fn budgeted_store_serves_more_bytes_than_budget() {
        // The §3.3 claim under a budget: register far more task bytes
        // than RAM allows; every task still serves correct values via
        // spill + fault-in, and the counters show the traffic.
        let (l, v, d, n) = (2, 32, 4, 5);
        let table_bytes = l * v * d * 4;
        let cfg = AdapterConfig { ram_budget_bytes: 2 * table_bytes, ..Default::default() };
        let s = PStore::with_config(l, v, d, cfg);
        let n_tasks = 6;
        for i in 0..n_tasks {
            let c = (i + 1) as f32;
            s.insert(&format!("t{i}"), TaskP::new(l, v, d, vec![c; l * v * d]).unwrap())
                .unwrap();
        }
        assert!(s.bytes() <= 2 * table_bytes, "resident {} over budget", s.bytes());
        let ids: Vec<i32> = (0..n).map(|t| (t % v) as i32).collect();
        for round in 0..2 {
            for i in 0..n_tasks {
                let name = format!("t{i}");
                let out = s.gather(&[name.as_str()], &ids, n).unwrap();
                let want = (i + 1) as f32;
                assert!(
                    out.as_f32().unwrap().iter().all(|&x| x == want),
                    "round {round} task {name}"
                );
            }
        }
        let stats = s.stats();
        assert!(stats.evictions >= 1, "{stats:?}");
        assert!(stats.faults >= 1, "{stats:?}");
        assert!(stats.spilled_tasks + stats.resident_tasks == n_tasks);
        assert!(stats.resident_bytes <= 2 * table_bytes);
    }

    #[test]
    fn oov_token_is_an_error_not_a_panic() {
        let s = store(2, 10, 4);
        assert!(s.gather(&["a"], &[0, 9, 3], 3).is_ok());
        let err = s.gather(&["a"], &[0, 10, 3], 3).unwrap_err();
        assert!(err.to_string().contains("outside vocabulary"), "{err}");
        assert!(s.gather(&["a"], &[0, -1, 3], 3).is_err());
    }

    #[test]
    fn gather_batch_parallel_matches_serial() {
        // live * n * d exceeds PARALLEL_MIN_ELEMS so the scoped-thread
        // path actually runs (smaller batches fall back to serial).
        let (l, v, d, b, n) = (5, 40, 64, 8, 40);
        assert!(b * n * d >= super::PARALLEL_MIN_ELEMS);
        let s = store(l, v, d);
        let mut rng = Pcg64::new(3);
        let ids: Vec<i32> = (0..b * n).map(|_| rng.range(0, v as i64) as i32).collect();
        let assignments = ["a", "b", "a", "b", "a", "b", "a", "b"];
        let mut serial = vec![0f32; l * b * n * d];
        s.gather_into(&assignments, &ids, n, &mut serial).unwrap();
        for threads in [2, 3, 8] {
            let mut parallel = vec![0f32; l * b * n * d];
            s.gather_batch(&assignments, &ids, n, b, threads, &mut parallel).unwrap();
            assert_eq!(serial, parallel, "threads={threads}");
        }
    }

    #[test]
    fn gather_batch_pooled_matches_serial() {
        use crate::peft::pool::GatherPool;
        let (l, v, d, b, n) = (5, 40, 64, 8, 40);
        assert!(b * n * d >= super::PARALLEL_MIN_ELEMS);
        let s = store(l, v, d);
        let mut rng = Pcg64::new(6);
        let ids: Vec<i32> = (0..b * n).map(|_| rng.range(0, v as i64) as i32).collect();
        let assignments = ["a", "b", "a", "b", "a", "b", "a", "b"];
        let mut serial = vec![0f32; l * b * n * d];
        s.gather_into(&assignments, &ids, n, &mut serial).unwrap();
        for threads in [1, 2, 3, 8] {
            let pool = GatherPool::new(threads);
            let mut pooled = vec![0f32; l * b * n * d];
            // Reuse the same pool across repeats: no per-batch spawn.
            for _ in 0..3 {
                pooled.fill(0.0);
                s.gather_batch_pooled(&assignments, &ids, n, b, &pool, &mut pooled).unwrap();
                assert_eq!(serial, pooled, "threads={threads}");
            }
        }
        // Small batches fall back to the serial inline path.
        let small_ids = &ids[..b * 2];
        let pool = GatherPool::new(4);
        let mut small_serial = vec![0f32; l * b * 2 * d];
        s.gather_batch(&assignments, small_ids, 2, b, 1, &mut small_serial).unwrap();
        let mut small_pooled = vec![0f32; l * b * 2 * d];
        s.gather_batch_pooled(&assignments, small_ids, 2, b, &pool, &mut small_pooled).unwrap();
        assert_eq!(small_serial, small_pooled);
    }

    #[test]
    fn empty_batch_is_a_noop_not_a_panic() {
        let s = store(2, 10, 4);
        // The seed's gather_into accepted empty assignment lists; the
        // staged path must keep that a no-op.
        let mut empty: Vec<f32> = Vec::new();
        assert!(s.gather_into(&[], &[], 3, &mut empty).is_ok());
        // No live rows in a real bucket: buffer untouched, no panic.
        let mut out = vec![7.0f32; 2 * 2 * 3 * 4];
        s.gather_batch(&[], &[0; 6], 3, 2, 4, &mut out).unwrap();
        assert!(out.iter().all(|&x| x == 7.0));
    }

    #[test]
    fn gather_batch_skips_filler_rows() {
        let (l, v, d, b, n) = (2, 20, 4, 3, 5);
        let s = store(l, v, d);
        let mut rng = Pcg64::new(4);
        let ids: Vec<i32> = (0..b * n).map(|_| rng.range(0, v as i64) as i32).collect();
        let sentinel = 9.0f32;
        let mut out = vec![sentinel; l * b * n * d];
        // One live row out of three.
        s.gather_batch(&["a"], &ids, n, b, 2, &mut out).unwrap();
        let table = s.get("a").unwrap();
        for layer in 0..l {
            let layer_base = layer * b * n * d;
            for t in 0..n {
                let got = &out[layer_base + t * d..layer_base + (t + 1) * d];
                assert_eq!(got, row_of(table.as_ref(), layer, ids[t] as usize));
            }
            // Filler rows 1..3 are untouched.
            for x in &out[layer_base + n * d..layer_base + b * n * d] {
                assert_eq!(*x, sentinel);
            }
        }
    }

    #[test]
    fn gather_batch_rejects_bad_geometry() {
        let s = store(2, 10, 4);
        let mut out = vec![0f32; 2 * 2 * 3 * 4];
        // live > bucket rows
        assert!(s.gather_batch(&["a", "b", "a"], &[0; 6], 3, 2, 1, &mut out).is_err());
        // wrong ids length
        assert!(s.gather_batch(&["a"], &[0; 5], 3, 2, 1, &mut out).is_err());
        // wrong out length
        let mut short = vec![0f32; 5];
        assert!(s.gather_batch(&["a"], &[0; 6], 3, 2, 1, &mut short).is_err());
    }

    #[test]
    fn with_config_parses_cli_shapes() {
        // The CLI wiring: budget string + dtype string → config.
        let cfg = AdapterConfig {
            ram_budget_bytes: parse_bytes("4KiB").unwrap(),
            dtype: AdapterDType::parse("f16").unwrap(),
            spill_dir: None,
            dedup: true,
            dedup_eps: 0.0,
            mmap: true,
        };
        let s = PStore::with_config(1, 8, 4, cfg);
        assert_eq!(s.config().ram_budget_bytes, 4096);
        assert_eq!(s.config().dtype, AdapterDType::F16);
        assert!(s.config().dedup);
    }

    #[test]
    fn int8_store_quarter_bytes_and_tolerance() {
        // d = 128 so the 8 bytes/row of scale/zero stay under the 0.27×
        // acceptance ratio: (128 + 8) / (4·128) = 0.2656.
        let (l, v, d, n) = (2, 30, 128, 6);
        let cfg = AdapterConfig { dtype: AdapterDType::I8, ..Default::default() };
        let i8_store = PStore::with_config(l, v, d, cfg);
        let f32_store = PStore::new(l, v, d);
        let mut rng = Pcg64::new(23);
        let data = rng.normal_vec(l * v * d, 1.0);
        i8_store.insert("t", TaskP::new(l, v, d, data.clone()).unwrap()).unwrap();
        f32_store.insert("t", TaskP::new(l, v, d, data).unwrap()).unwrap();
        // Resident bytes via the stats gauge: ≤ 0.27× the f32 tier.
        let (i8b, f32b) = (i8_store.stats().resident_bytes, f32_store.stats().resident_bytes);
        assert_eq!(f32b, l * v * d * 4);
        assert!(
            (i8b as f64) <= 0.27 * f32b as f64,
            "int8 resident {i8b} > 0.27 × f32 {f32b}"
        );
        assert_eq!(i8_store.get("t").unwrap().tier(), "ram-int8");
        let ids: Vec<i32> = (0..n).map(|_| rng.range(0, v as i64) as i32).collect();
        let a = i8_store.gather(&["t"], &ids, n).unwrap();
        let b = f32_store.gather(&["t"], &ids, n).unwrap();
        // Stated int8 tier bound for unit-normal fuses: 2e-2 absolute.
        for (x, y) in a.as_f32().unwrap().iter().zip(b.as_f32().unwrap()) {
            assert!((x - y).abs() < 2e-2, "{x} vs {y}");
        }
    }

    /// The dedup acceptance fixture: ≥50% near-zero rows must show a
    /// dedup ratio ≥ 2× in the stats and gather bit-exactly like the
    /// dense store of the same dtype.
    #[test]
    fn dedup_store_halves_rows_and_stays_bit_exact() {
        let (l, v, d, n) = (2, 32, 16, 8);
        let mut rng = Pcg64::new(24);
        // 24 of 32 tokens fuse to exactly zero per layer (75% > 50%);
        // tokens 0 and 1 share one bit-identical row in both layers.
        let mut data = vec![0f32; l * v * d];
        let shared = rng.normal_vec(d, 1.0);
        for layer in 0..l {
            for tok in 0..8 {
                let row = &mut data[(layer * v + tok) * d..(layer * v + tok + 1) * d];
                if tok < 2 {
                    row.copy_from_slice(&shared);
                } else {
                    for (k, x) in row.iter_mut().enumerate() {
                        *x = (layer * v + tok) as f32 + k as f32 * 0.5;
                    }
                }
            }
        }
        for dtype in [AdapterDType::F32, AdapterDType::F16, AdapterDType::I8] {
            let dense = PStore::with_config(
                l,
                v,
                d,
                AdapterConfig { dtype, ..Default::default() },
            );
            let dedup = PStore::with_config(
                l,
                v,
                d,
                AdapterConfig { dtype, dedup: true, ..Default::default() },
            );
            let p = TaskP::new(l, v, d, data.clone()).unwrap();
            dense.insert("t", TaskP::new(l, v, d, data.clone()).unwrap()).unwrap();
            dedup.insert("t", p).unwrap();
            let stats = dedup.stats();
            assert_eq!(stats.dedup_logical_rows, l * v);
            assert!(
                stats.dedup_ratio() >= 2.0,
                "{dtype:?}: ratio {} (stored {})",
                stats.dedup_ratio(),
                stats.dedup_stored_rows
            );
            assert!(stats.dedup_zero_rows * 2 >= l * v, "{dtype:?}: {stats:?}");
            // Dedup'd storage is smaller than dense even with the index.
            assert!(
                dedup.stats().resident_bytes < dense.stats().resident_bytes,
                "{dtype:?}"
            );
            let table = dedup.get("t").unwrap();
            assert!(table.tier().ends_with("+dedup"), "{}", table.tier());
            assert!(table.dedup_index().is_some());
            let ids: Vec<i32> = (0..n).map(|i| (i * 3 % v) as i32).collect();
            let a = dedup.gather(&["t"], &ids, n).unwrap();
            let b = dense.gather(&["t"], &ids, n).unwrap();
            // Bit-exact vs the non-dedup'd store at the same dtype.
            for (x, y) in a.as_f32().unwrap().iter().zip(b.as_f32().unwrap()) {
                assert_eq!(x.to_bits(), y.to_bits(), "{dtype:?}: {x} vs {y}");
            }
        }
    }

    #[test]
    fn dedup_task_p_validates_geometry() {
        let pool: Arc<dyn RowSource> = Arc::new(TaskP::new(1, 2, 4, vec![1.0; 8]).unwrap());
        // Index shorter than layers×vocab.
        assert!(DedupTaskP::new(1, 4, 4, vec![0, 1], Arc::clone(&pool)).is_err());
        // Index entry beyond the pool.
        assert!(DedupTaskP::new(1, 2, 4, vec![0, 3], Arc::clone(&pool)).is_err());
        // Pool with the wrong d_model.
        assert!(DedupTaskP::new(1, 2, 8, vec![0, 1], Arc::clone(&pool)).is_err());
        let ok = DedupTaskP::new(1, 2, 4, vec![0, 2], pool).unwrap();
        assert_eq!(ok.row_stats(), RowCounts { logical: 2, stored: 2, zero_shared: 1 });
        let mut out = vec![9f32; 4];
        ok.copy_row(0, 0, &mut out).unwrap();
        assert_eq!(out, vec![0.0; 4]);
        ok.copy_row(0, 1, &mut out).unwrap();
        assert_eq!(out, vec![1.0; 4]);
    }
}
