//! The AoT P store: per-task fused prompt tables in host RAM + the
//! ahead-of-time row gather.
//!
//! Paper §3.3: "During the evaluation, there is no need to store the full
//! P in GPU memory.  Instead, it could be stored in RAM, and only rows of
//! these matrices should be placed in GPU memory to be added to the hidden
//! states before each layer."  `gather_into` is exactly that operation and
//! is the coordinator's per-request hot path — it is benchmarked by
//! `benches/gather_hotpath.rs` and must never dominate the backbone
//! execute (DESIGN.md §9, L3 target).

use std::collections::HashMap;
use std::sync::Arc;

use anyhow::{anyhow, bail};

use crate::tensor::Tensor;
use crate::Result;

/// One task's fused table, laid out `[l, V, d]` row-major so a (layer,
/// token) row is one contiguous `d`-float slice.
pub struct TaskP {
    pub layers: usize,
    pub vocab: usize,
    pub d_model: usize,
    data: Vec<f32>,
}

impl TaskP {
    pub fn new(layers: usize, vocab: usize, d_model: usize, data: Vec<f32>) -> Result<TaskP> {
        if data.len() != layers * vocab * d_model {
            bail!(
                "TaskP: data length {} != {}x{}x{}",
                data.len(),
                layers,
                vocab,
                d_model
            );
        }
        Ok(TaskP { layers, vocab, d_model, data })
    }

    pub fn from_tensor(layers: usize, vocab: usize, d_model: usize, t: &Tensor) -> Result<TaskP> {
        t.check_shape(&[layers, vocab, d_model])?;
        TaskP::new(layers, vocab, d_model, t.as_f32()?.to_vec())
    }

    /// A zero table (a fresh/untrained task is exactly the backbone).
    pub fn zeros(layers: usize, vocab: usize, d_model: usize) -> TaskP {
        TaskP { layers, vocab, d_model, data: vec![0.0; layers * vocab * d_model] }
    }

    #[inline]
    pub fn row(&self, layer: usize, token: usize) -> &[f32] {
        let d = self.d_model;
        let start = (layer * self.vocab + token) * d;
        &self.data[start..start + d]
    }

    /// Host-RAM footprint in bytes (paper §3.3's RAM-vs-speed trade-off).
    pub fn bytes(&self) -> usize {
        self.data.len() * 4
    }

    /// L2 norms of every vocabulary row at `layer` — the §4.3 analysis
    /// ("tokens with the largest ‖P_x‖₂").
    pub fn row_norms(&self, layer: usize) -> Vec<f32> {
        (0..self.vocab)
            .map(|t| self.row(layer, t).iter().map(|x| x * x).sum::<f32>().sqrt())
            .collect()
    }
}

/// Minimum live elements per layer before the gather fans out to scoped
/// threads (below this, spawn overhead rivals the copy itself).
const PARALLEL_MIN_ELEMS: usize = 16 * 1024;

/// All registered tasks' tables.
pub struct PStore {
    layers: usize,
    vocab: usize,
    d_model: usize,
    tasks: HashMap<String, Arc<TaskP>>,
}

impl PStore {
    pub fn new(layers: usize, vocab: usize, d_model: usize) -> PStore {
        PStore { layers, vocab, d_model, tasks: HashMap::new() }
    }

    pub fn insert(&mut self, task: &str, p: TaskP) -> Result<()> {
        if (p.layers, p.vocab, p.d_model) != (self.layers, self.vocab, self.d_model) {
            bail!("task {task}: table geometry mismatch");
        }
        self.tasks.insert(task.to_string(), Arc::new(p));
        Ok(())
    }

    pub fn get(&self, task: &str) -> Result<&Arc<TaskP>> {
        self.tasks
            .get(task)
            .ok_or_else(|| anyhow!("no fused P registered for task {task}"))
    }

    pub fn task_names(&self) -> Vec<&str> {
        self.tasks.keys().map(String::as_str).collect()
    }

    pub fn len(&self) -> usize {
        self.tasks.len()
    }

    pub fn is_empty(&self) -> bool {
        self.tasks.is_empty()
    }

    /// Total host RAM held by all tables.
    pub fn bytes(&self) -> usize {
        self.tasks.values().map(|p| p.bytes()).sum()
    }

    /// Table geometry accessors (the serving pipeline sizes its arena
    /// buffers from these).
    pub fn layers(&self) -> usize {
        self.layers
    }

    pub fn vocab(&self) -> usize {
        self.vocab
    }

    pub fn d_model(&self) -> usize {
        self.d_model
    }

    /// THE hot path: gather bias `[l, b, n, d]` for a multi-task batch.
    ///
    /// `assignments[j]` names the task of batch row `j`; `ids` is the
    /// padded `[b, n]` token matrix.  The output layout matches the
    /// serving artifact's `in.bias` input exactly, so the result is
    /// uploaded without any further reshuffling.
    pub fn gather(&self, assignments: &[&str], ids: &[i32], n: usize) -> Result<Tensor> {
        let b = assignments.len();
        if ids.len() != b * n {
            bail!("gather: ids length {} != {b}x{n}", ids.len());
        }
        let d = self.d_model;
        let mut out = vec![0f32; self.layers * b * n * d];
        self.gather_into(assignments, ids, n, &mut out)?;
        Ok(Tensor::from_f32(&[self.layers, b, n, d], out))
    }

    /// Allocation-free serial variant for a caller-managed buffer, one
    /// assignment per bucket row (the pre-pipeline behavior).
    pub fn gather_into(
        &self,
        assignments: &[&str],
        ids: &[i32],
        n: usize,
        out: &mut [f32],
    ) -> Result<()> {
        self.gather_batch(assignments, ids, n, assignments.len(), 1, out)
    }

    /// The serving pipeline's gather: fill `out = [l, b, n, d]` for a
    /// bucket of `b` rows of which only the first `assignments.len()` are
    /// live requests.  Filler rows (their logits are dropped after the
    /// execute) are skipped entirely — their region of `out` keeps
    /// whatever finite values it held, which is safe because backbone
    /// rows are computed independently.  Layers are gathered on up to
    /// `threads` scoped threads.
    ///
    /// Token ids of live rows are validated against the vocabulary and
    /// rejected with an error — a bad id must never panic the worker
    /// (release builds would otherwise die on the slice bound).
    pub fn gather_batch(
        &self,
        assignments: &[&str],
        ids: &[i32],
        n: usize,
        b: usize,
        threads: usize,
        out: &mut [f32],
    ) -> Result<()> {
        let live = assignments.len();
        let d = self.d_model;
        if live > b {
            bail!("gather_batch: {live} live rows exceed bucket batch {b}");
        }
        if ids.len() != b * n {
            bail!("gather_batch: ids length {} != {b}x{n}", ids.len());
        }
        if out.len() != self.layers * b * n * d {
            bail!(
                "gather_batch: output length {} != {}x{b}x{n}x{d}",
                out.len(),
                self.layers
            );
        }
        if live * n * d * self.layers == 0 {
            return Ok(()); // degenerate geometry or no live rows: nothing to copy
        }
        self.validate_ids(&ids[..live * n])?;
        // Resolve tasks once per row, not once per token.
        let tables: Vec<&Arc<TaskP>> = assignments
            .iter()
            .map(|t| self.get(t))
            .collect::<Result<_>>()?;

        let layer_block = b * n * d;
        // Scoped threads cost tens of microseconds to spawn; only go
        // parallel when the per-layer copy is large enough to repay that
        // (single-row/short-sequence batches stay serial).
        let threads = if live * n * d < PARALLEL_MIN_ELEMS {
            1
        } else {
            threads.clamp(1, self.layers)
        };
        if threads == 1 {
            for (layer, layer_out) in out.chunks_mut(layer_block).enumerate() {
                gather_layer(&tables, layer, ids, n, d, layer_out);
            }
            return Ok(());
        }
        let layers_per = self.layers.div_ceil(threads);
        std::thread::scope(|scope| {
            for (chunk_idx, chunk) in out.chunks_mut(layers_per * layer_block).enumerate() {
                let tables = &tables;
                scope.spawn(move || {
                    for (i, layer_out) in chunk.chunks_mut(layer_block).enumerate() {
                        gather_layer(tables, chunk_idx * layers_per + i, ids, n, d, layer_out);
                    }
                });
            }
        });
        Ok(())
    }

    fn validate_ids(&self, ids: &[i32]) -> Result<()> {
        for &tok in ids {
            if tok < 0 || tok as usize >= self.vocab {
                bail!("token id {tok} outside vocabulary [0, {})", self.vocab);
            }
        }
        Ok(())
    }
}

/// Copy one layer's rows for every live assignment (ids pre-validated).
fn gather_layer(
    tables: &[&Arc<TaskP>],
    layer: usize,
    ids: &[i32],
    n: usize,
    d: usize,
    out: &mut [f32],
) {
    for (j, table) in tables.iter().enumerate() {
        let row_base = j * n * d;
        for t in 0..n {
            let tok = ids[j * n + t] as usize;
            let src = table.row(layer, tok);
            out[row_base + t * d..row_base + (t + 1) * d].copy_from_slice(src);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Pcg64;

    fn store(layers: usize, vocab: usize, d: usize) -> PStore {
        let mut s = PStore::new(layers, vocab, d);
        let mut rng = Pcg64::new(1);
        for task in ["a", "b"] {
            let data = rng.normal_vec(layers * vocab * d, 1.0);
            s.insert(task, TaskP::new(layers, vocab, d, data).unwrap()).unwrap();
        }
        s
    }

    #[test]
    fn gather_matches_manual_lookup() {
        let (l, v, d, n) = (3, 50, 8, 5);
        let s = store(l, v, d);
        let mut rng = Pcg64::new(2);
        let ids: Vec<i32> = (0..2 * n).map(|_| rng.range(0, v as i64) as i32).collect();
        let out = s.gather(&["a", "b"], &ids, n).unwrap();
        assert_eq!(out.shape, vec![l, 2, n, d]);
        let data = out.as_f32().unwrap();
        for layer in 0..l {
            for (j, task) in ["a", "b"].iter().enumerate() {
                let table = s.get(task).unwrap();
                for t in 0..n {
                    let tok = ids[j * n + t] as usize;
                    let got = &data[((layer * 2 + j) * n + t) * d..((layer * 2 + j) * n + t + 1) * d];
                    assert_eq!(got, table.row(layer, tok), "layer {layer} row {j} tok {t}");
                }
            }
        }
    }

    #[test]
    fn zero_table_gathers_zeros() {
        let mut s = PStore::new(2, 10, 4);
        s.insert("z", TaskP::zeros(2, 10, 4)).unwrap();
        let out = s.gather(&["z"], &[1, 2, 3], 3).unwrap();
        assert!(out.as_f32().unwrap().iter().all(|&x| x == 0.0));
    }

    #[test]
    fn geometry_mismatch_rejected() {
        let mut s = PStore::new(2, 10, 4);
        assert!(s.insert("bad", TaskP::zeros(3, 10, 4)).is_err());
        assert!(s.get("missing").is_err());
    }

    #[test]
    fn row_norms_pick_out_heavy_tokens() {
        let (l, v, d) = (1, 8, 4);
        let mut data = vec![0f32; l * v * d];
        for x in &mut data[5 * d..6 * d] {
            *x = 3.0; // token 5 gets a heavy row
        }
        let p = TaskP::new(l, v, d, data).unwrap();
        let norms = p.row_norms(0);
        let argmax = norms
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .unwrap()
            .0;
        assert_eq!(argmax, 5);
        assert!((norms[5] - 6.0).abs() < 1e-6); // sqrt(4 * 9)
    }

    #[test]
    fn ram_accounting() {
        let s = store(2, 10, 4);
        assert_eq!(s.bytes(), 2 * 2 * 10 * 4 * 4);
    }

    #[test]
    fn oov_token_is_an_error_not_a_panic() {
        let s = store(2, 10, 4);
        assert!(s.gather(&["a"], &[0, 9, 3], 3).is_ok());
        let err = s.gather(&["a"], &[0, 10, 3], 3).unwrap_err();
        assert!(err.to_string().contains("outside vocabulary"), "{err}");
        assert!(s.gather(&["a"], &[0, -1, 3], 3).is_err());
    }

    #[test]
    fn gather_batch_parallel_matches_serial() {
        // live * n * d exceeds PARALLEL_MIN_ELEMS so the scoped-thread
        // path actually runs (smaller batches fall back to serial).
        let (l, v, d, b, n) = (5, 40, 64, 8, 40);
        assert!(b * n * d >= super::PARALLEL_MIN_ELEMS);
        let s = store(l, v, d);
        let mut rng = Pcg64::new(3);
        let ids: Vec<i32> = (0..b * n).map(|_| rng.range(0, v as i64) as i32).collect();
        let assignments = ["a", "b", "a", "b", "a", "b", "a", "b"];
        let mut serial = vec![0f32; l * b * n * d];
        s.gather_into(&assignments, &ids, n, &mut serial).unwrap();
        for threads in [2, 3, 8] {
            let mut parallel = vec![0f32; l * b * n * d];
            s.gather_batch(&assignments, &ids, n, b, threads, &mut parallel).unwrap();
            assert_eq!(serial, parallel, "threads={threads}");
        }
    }

    #[test]
    fn empty_batch_is_a_noop_not_a_panic() {
        let s = store(2, 10, 4);
        // The seed's gather_into accepted empty assignment lists; the
        // staged path must keep that a no-op.
        let mut empty: Vec<f32> = Vec::new();
        assert!(s.gather_into(&[], &[], 3, &mut empty).is_ok());
        // No live rows in a real bucket: buffer untouched, no panic.
        let mut out = vec![7.0f32; 2 * 2 * 3 * 4];
        s.gather_batch(&[], &[0; 6], 3, 2, 4, &mut out).unwrap();
        assert!(out.iter().all(|&x| x == 7.0));
    }

    #[test]
    fn gather_batch_skips_filler_rows() {
        let (l, v, d, b, n) = (2, 20, 4, 3, 5);
        let s = store(l, v, d);
        let mut rng = Pcg64::new(4);
        let ids: Vec<i32> = (0..b * n).map(|_| rng.range(0, v as i64) as i32).collect();
        let sentinel = 9.0f32;
        let mut out = vec![sentinel; l * b * n * d];
        // One live row out of three.
        s.gather_batch(&["a"], &ids, n, b, 2, &mut out).unwrap();
        let table = s.get("a").unwrap();
        for layer in 0..l {
            let layer_base = layer * b * n * d;
            for t in 0..n {
                let got = &out[layer_base + t * d..layer_base + (t + 1) * d];
                assert_eq!(got, table.row(layer, ids[t] as usize));
            }
            // Filler rows 1..3 are untouched.
            for x in &out[layer_base + n * d..layer_base + b * n * d] {
                assert_eq!(*x, sentinel);
            }
        }
    }

    #[test]
    fn gather_batch_rejects_bad_geometry() {
        let s = store(2, 10, 4);
        let mut out = vec![0f32; 2 * 2 * 3 * 4];
        // live > bucket rows
        assert!(s.gather_batch(&["a", "b", "a"], &[0; 6], 3, 2, 1, &mut out).is_err());
        // wrong ids length
        assert!(s.gather_batch(&["a"], &[0; 5], 3, 2, 1, &mut out).is_err());
        // wrong out length
        let mut short = vec![0f32; 5];
        assert!(s.gather_batch(&["a"], &[0; 6], 3, 2, 1, &mut short).is_err());
    }
}
