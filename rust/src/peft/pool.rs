//! Persistent layer-sharded worker pool for the AoT gather hot path.
//!
//! The seed's `gather_batch` spawned `std::thread::scope` threads for
//! every batch; at serving rates that is tens of microseconds of spawn +
//! join overhead per batch, paid again and again on the hottest path in
//! the system (DESIGN.md §11).  [`GatherPool`] spawns its workers once —
//! `Pipeline::new` builds it through `GatherStage::new` — and parks them
//! in a channel `recv` between batches, so dispatching a batch costs one
//! channel send per shard instead of one thread spawn.
//!
//! The calling thread always participates: it gathers the first layer
//! shard inline while the workers run the rest, then blocks on a
//! countdown latch until every shard lands.  That latch is what makes the
//! borrowed-slice handoff sound — the caller's `sources`/`ids`/`out`
//! borrows are guaranteed live until the last worker finished, exactly
//! the guarantee `std::thread::scope` provided, enforced here without the
//! per-batch scope.

use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;

use crate::Result;

use super::store::{gather_layer, RowSource};

/// One contiguous block of layers shipped to a pool worker.
///
/// The raw pointers borrow from the calling gather's stack frame; the
/// caller blocks on [`ShardLatch`] before returning, so every pointer
/// outlives every worker access, and each shard's `out` region is a
/// disjoint `chunks_mut` slice of the batch bias buffer.
struct GatherShard {
    sources: *const Arc<dyn RowSource>,
    sources_len: usize,
    ids: *const i32,
    ids_len: usize,
    out: *mut f32,
    out_len: usize,
    plan: *const u32,
    plan_len: usize,
    first_layer: usize,
    layer_block: usize,
    n: usize,
    d: usize,
    latch: Arc<ShardLatch>,
}

// SAFETY: the pointed-to slices are only touched between the send and the
// caller's latch wait; the caller keeps the underlying borrows alive for
// that whole window, and no two shards overlap in `out`.
unsafe impl Send for GatherShard {}

/// Countdown latch: the caller waits until every shipped shard ran.
struct ShardLatch {
    remaining: Mutex<usize>,
    done: Condvar,
    err: Mutex<Option<anyhow::Error>>,
}

impl ShardLatch {
    fn new(shards: usize) -> ShardLatch {
        ShardLatch { remaining: Mutex::new(shards), done: Condvar::new(), err: Mutex::new(None) }
    }

    /// Record the first error (only the disk tier can fail mid-copy; the
    /// first error wins and fails the whole batch, like the seed).
    fn record(&self, e: anyhow::Error) {
        let mut slot = self.err.lock().unwrap();
        if slot.is_none() {
            *slot = Some(e);
        }
    }

    fn wait(&self) {
        let mut remaining = self.remaining.lock().unwrap();
        while *remaining > 0 {
            remaining = self.done.wait(remaining).unwrap();
        }
    }
}

/// Decrements the latch on drop — a panicking `copy_row` must still
/// release the caller, or the serving loop would hang forever.
struct LatchGuard<'a>(&'a ShardLatch);

impl Drop for LatchGuard<'_> {
    fn drop(&mut self) {
        let mut remaining = self.0.remaining.lock().unwrap();
        *remaining -= 1;
        if *remaining == 0 {
            self.0.done.notify_all();
        }
    }
}

fn run_shard(shard: &GatherShard) -> Result<()> {
    // SAFETY: see `GatherShard` — the caller keeps these borrows alive
    // until the latch opens, and `out` regions are disjoint per shard.
    let sources = unsafe { std::slice::from_raw_parts(shard.sources, shard.sources_len) };
    let ids = unsafe { std::slice::from_raw_parts(shard.ids, shard.ids_len) };
    let out = unsafe { std::slice::from_raw_parts_mut(shard.out, shard.out_len) };
    let plan = if shard.plan_len == 0 {
        &[][..]
    } else {
        unsafe { std::slice::from_raw_parts(shard.plan, shard.plan_len) }
    };
    for (i, layer_out) in out.chunks_mut(shard.layer_block).enumerate() {
        gather_layer(sources, shard.first_layer + i, ids, shard.n, shard.d, plan, layer_out)?;
    }
    Ok(())
}

fn worker_loop(rx: &Mutex<Receiver<GatherShard>>) {
    loop {
        // Workers park in `recv` between batches; dropping the pool drops
        // the sender, which wakes and exits every worker.
        let shard = {
            let guard = rx.lock().unwrap();
            guard.recv()
        };
        let shard = match shard {
            Ok(shard) => shard,
            Err(_) => break,
        };
        let _open = LatchGuard(&shard.latch);
        if let Err(e) = run_shard(&shard) {
            shard.latch.record(e);
        }
    }
}

/// Spawn-once worker pool for the layer-sharded gather.
pub struct GatherPool {
    /// `Sender` is not `Sync`; the mutex makes the pool shareable across
    /// pipeline threads (held only for the microseconds of a shard send).
    tx: Option<Mutex<Sender<GatherShard>>>,
    workers: Vec<JoinHandle<()>>,
    threads: usize,
}

impl GatherPool {
    /// Spawn `threads - 1` parked workers; the calling thread is the
    /// remaining participant (it always gathers the first shard inline).
    pub fn new(threads: usize) -> GatherPool {
        let threads = threads.max(1);
        let (tx, rx) = channel::<GatherShard>();
        let rx = Arc::new(Mutex::new(rx));
        let workers = (0..threads - 1)
            .map(|i| {
                let rx = Arc::clone(&rx);
                std::thread::Builder::new()
                    .name(format!("aotpt-gather-{i}"))
                    .spawn(move || worker_loop(&rx))
                    .expect("spawn gather worker")
            })
            .collect();
        GatherPool { tx: Some(Mutex::new(tx)), workers, threads }
    }

    /// Total gather parallelism: workers + the calling thread.
    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Gather every layer of `out` (`[l, b, n, d]` with
    /// `layer_block = b·n·d`, so `l = out.len() / layer_block`), sharding
    /// contiguous layer ranges across the pool.  The calling thread
    /// gathers the first shard itself while the workers run the rest,
    /// then blocks until every shard landed — the borrowed inputs never
    /// escape this call.  A non-empty `plan` (cold batches) makes every
    /// shard copy its rows in (source table, token id) order
    /// (DESIGN.md §14).
    #[allow(clippy::too_many_arguments)]
    pub fn gather(
        &self,
        sources: &[Arc<dyn RowSource>],
        ids: &[i32],
        n: usize,
        d: usize,
        layer_block: usize,
        plan: &[u32],
        out: &mut [f32],
    ) -> Result<()> {
        if out.is_empty() {
            return Ok(());
        }
        let total_layers = out.len() / layer_block;
        if total_layers <= 1 || self.threads == 1 {
            for (layer, layer_out) in out.chunks_mut(layer_block).enumerate() {
                gather_layer(sources, layer, ids, n, d, plan, layer_out)?;
            }
            return Ok(());
        }
        let shards = self.threads.min(total_layers);
        let layers_per = total_layers.div_ceil(shards);
        let n_shards = total_layers.div_ceil(layers_per);
        let latch = Arc::new(ShardLatch::new(n_shards - 1));
        let mut inline: Option<&mut [f32]> = None;
        {
            let tx = self.tx.as_ref().expect("gather pool shut down").lock().unwrap();
            for (idx, chunk) in out.chunks_mut(layers_per * layer_block).enumerate() {
                if idx == 0 {
                    inline = Some(chunk);
                    continue;
                }
                let shard = GatherShard {
                    sources: sources.as_ptr(),
                    sources_len: sources.len(),
                    ids: ids.as_ptr(),
                    ids_len: ids.len(),
                    out: chunk.as_mut_ptr(),
                    out_len: chunk.len(),
                    plan: plan.as_ptr(),
                    plan_len: plan.len(),
                    first_layer: idx * layers_per,
                    layer_block,
                    n,
                    d,
                    latch: Arc::clone(&latch),
                };
                // Workers only exit when the sender drops, which cannot
                // happen while `self` is alive — a failed send means a
                // worker panicked, which is a bug worth dying loudly for.
                tx.send(shard).expect("gather workers exited");
            }
        }
        if let Some(chunk) = inline {
            for (i, layer_out) in chunk.chunks_mut(layer_block).enumerate() {
                if let Err(e) = gather_layer(sources, i, ids, n, d, plan, layer_out) {
                    latch.record(e);
                    break;
                }
            }
        }
        // After this wait no borrow of `sources`/`ids`/`out` is live
        // anywhere but this frame.
        latch.wait();
        match latch.err.lock().unwrap().take() {
            Some(e) => Err(e),
            None => Ok(()),
        }
    }
}

impl Drop for GatherPool {
    fn drop(&mut self) {
        drop(self.tx.take());
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::peft::store::TaskP;
    use crate::util::Pcg64;

    fn sources(l: usize, v: usize, d: usize, rows: usize) -> Vec<Arc<dyn RowSource>> {
        let mut rng = Pcg64::new(7);
        (0..rows)
            .map(|_| {
                let data = rng.normal_vec(l * v * d, 1.0);
                Arc::new(TaskP::new(l, v, d, data).unwrap()) as Arc<dyn RowSource>
            })
            .collect()
    }

    fn serial(srcs: &[Arc<dyn RowSource>], ids: &[i32], n: usize, d: usize, l: usize) -> Vec<f32> {
        let b = srcs.len();
        let layer_block = b * n * d;
        let mut out = vec![0f32; l * layer_block];
        for (layer, layer_out) in out.chunks_mut(layer_block).enumerate() {
            gather_layer(srcs, layer, ids, n, d, &[], layer_out).unwrap();
        }
        out
    }

    #[test]
    fn pooled_gather_matches_serial() {
        let (l, v, d, b, n) = (7, 40, 16, 4, 10);
        let srcs = sources(l, v, d, b);
        let mut rng = Pcg64::new(9);
        let ids: Vec<i32> = (0..b * n).map(|_| rng.range(0, v as i64) as i32).collect();
        let want = serial(&srcs, &ids, n, d, l);
        for threads in [1, 2, 3, 8, 16] {
            let pool = GatherPool::new(threads);
            let mut got = vec![0f32; l * b * n * d];
            pool.gather(&srcs, &ids, n, d, b * n * d, &[], &mut got).unwrap();
            assert_eq!(want, got, "threads={threads}");
        }
    }

    #[test]
    fn pool_is_reused_across_many_batches() {
        // The whole point: one spawn, many batches.  Values must stay
        // exact on every reuse (no stale shard state).
        let (l, v, d, b, n) = (5, 30, 8, 3, 6);
        let srcs = sources(l, v, d, b);
        let pool = GatherPool::new(4);
        let mut rng = Pcg64::new(11);
        for batch in 0..50 {
            let ids: Vec<i32> = (0..b * n).map(|_| rng.range(0, v as i64) as i32).collect();
            let want = serial(&srcs, &ids, n, d, l);
            let mut got = vec![1e9f32; l * b * n * d];
            pool.gather(&srcs, &ids, n, d, b * n * d, &[], &mut got).unwrap();
            assert_eq!(want, got, "batch {batch}");
        }
    }

    #[test]
    fn more_threads_than_layers_is_clamped() {
        let (l, v, d, b, n) = (2, 20, 4, 2, 5);
        let srcs = sources(l, v, d, b);
        let pool = GatherPool::new(16);
        let want = serial(&srcs, &ids_of(b * n, v), n, d, l);
        let mut got = vec![0f32; l * b * n * d];
        pool.gather(&srcs, &ids_of(b * n, v), n, d, b * n * d, &[], &mut got).unwrap();
        assert_eq!(want, got);
    }

    fn ids_of(len: usize, v: usize) -> Vec<i32> {
        (0..len).map(|i| (i % v) as i32).collect()
    }

    #[test]
    fn drop_joins_parked_workers() {
        let pool = GatherPool::new(8);
        assert_eq!(pool.threads(), 8);
        drop(pool); // must not hang
    }
}
