//! PEFT method registry + the tiered AoT adapter store.
//!
//! * `Method` — every fine-tuning method in the paper with its Table 1
//!   property triple; `aotpt exp table1` prints the table from this
//!   registry (mirrored against the manifest in tests).
//! * `store` — the heart of AoT P-Tuning serving (§3.3): fused per-task
//!   `P ∈ R^{l×V×d}` matrices behind the [`store::RowSource`] tier
//!   abstraction, with the ahead-of-time row gather
//!   `bias[l,b,n,d] = P[l, ids[b,n], :]` as the coordinator's hot path.
//! * `quant` — the f16 and int8 storage tiers (fused-time quantization,
//!   on-gather dequant into the arena buffers; DESIGN.md §10).
//! * `residency` — the disk tier and hot task lifecycle: RAM budget, LRU
//!   spill to `.aotckpt`, mmap-backed cold serving with positioned-read
//!   fallback (`--adapter-mmap`; DESIGN.md §13), on-demand fault-in,
//!   pinning, and register/replace/unregister on `&self` while serving.
//! * `fuse` — host-side implementations of the FC/Kronecker fuse math,
//!   cross-checked against the `fuse_*` HLO artifacts in tests; also the
//!   fuse-time shared-row dedup pass behind `--adapter-dedup`
//!   (DESIGN.md §12).
//! * `arena` — reusable per-bucket staging buffers so the steady-state
//!   serving gather allocates nothing (DESIGN.md §9).
//! * `pool` — the persistent layer-sharded gather worker pool: spawned
//!   once per pipeline, parked between batches (DESIGN.md §11).
//! * `kernel` — runtime-dispatched SIMD row kernels (AVX2/SSE2/NEON with
//!   a scalar fallback, `--kernel`/`AOTPT_KERNEL` override) behind every
//!   row move, dequant and dedup comparison (DESIGN.md §14).

pub mod arena;
pub mod fuse;
pub mod kernel;
pub mod pool;
pub mod quant;
pub mod residency;
pub mod store;

pub use arena::GatherArena;
pub use kernel::{KernelMode, RowKernel};
pub use pool::GatherPool;
pub use quant::{AdapterDType, Int8TaskP, QuantizedTaskP};
pub use residency::{
    default_mmap, parse_bytes, AdapterConfig, AdapterStats, ColdCounters, ColdTable, TaskInfo,
};
pub use store::{row_norms, DedupTaskP, PStore, RowCounts, RowSource, TaskP};

/// Every fine-tuning method of the paper (Table 1).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Method {
    FineTune,
    Lora,
    LoraFused,
    Adapters,
    BitFit,
    Pt1,
    Pt2,
    AotKron,
    AotFc,
}

impl Method {
    pub const ALL: [Method; 9] = [
        Method::FineTune,
        Method::Lora,
        Method::LoraFused,
        Method::Adapters,
        Method::BitFit,
        Method::Pt1,
        Method::Pt2,
        Method::AotKron,
        Method::AotFc,
    ];

    pub fn name(self) -> &'static str {
        match self {
            Method::FineTune => "fine-tune",
            Method::Lora => "lora",
            Method::LoraFused => "lora-fused",
            Method::Adapters => "adapters",
            Method::BitFit => "bitfit",
            Method::Pt1 => "pt1",
            Method::Pt2 => "pt2",
            Method::AotKron => "aot-kron",
            Method::AotFc => "aot-fc",
        }
    }

    pub fn parse(s: &str) -> crate::Result<Method> {
        Method::ALL
            .into_iter()
            .find(|m| m.name() == s)
            .ok_or_else(|| anyhow::anyhow!("unknown method {s}"))
    }

    /// Paper display name (Table 1 row label).
    pub fn display(self) -> &'static str {
        match self {
            Method::FineTune => "Fine-Tuning",
            Method::Lora => "LoRA",
            Method::LoraFused => "LoRA Fused",
            Method::Adapters => "Adapters",
            Method::BitFit => "BitFit",
            Method::Pt1 => "P-Tuning v1",
            Method::Pt2 => "P-Tuning v2",
            Method::AotKron => "Kron. AoT P-Tuning (ours)",
            Method::AotFc => "FC AoT P-Tuning (ours)",
        }
    }

    /// Trains a small fraction of the model's parameters.
    pub fn parameter_efficient(self) -> bool {
        !matches!(self, Method::FineTune)
    }

    /// Zero computational overhead at inference (after fusing, if any).
    pub fn zero_cost(self) -> bool {
        matches!(
            self,
            Method::FineTune | Method::LoraFused | Method::BitFit | Method::AotKron | Method::AotFc
        )
    }

    /// Can serve many tasks from one backbone invocation.
    pub fn multi_task(self) -> bool {
        !matches!(self, Method::FineTune | Method::LoraFused)
    }

    /// The serving artifact signature this method uses after training.
    /// Both AoT reparametrizations fuse to the same `aot` signature —
    /// that is the paper's point (r no longer affects any shape, §4.2).
    pub fn serve_signature(self) -> &'static str {
        match self {
            Method::FineTune | Method::LoraFused => "fine-tune",
            Method::Lora => "lora",
            Method::Adapters => "adapters",
            Method::BitFit => "bitfit",
            Method::Pt1 => "pt1",
            Method::Pt2 => "pt2",
            Method::AotKron | Method::AotFc => "aot",
        }
    }

    /// Render the paper's Table 1 from the live registry.
    pub fn table1() -> String {
        let mut out = String::from(
            "| Method | Parameter Efficient | Zero-Cost | Multi-Task Inference |\n|---|---|---|---|\n",
        );
        let mark = |b: bool| if b { "yes" } else { "no" };
        for m in Method::ALL {
            out.push_str(&format!(
                "| {} | {} | {} | {} |\n",
                m.display(),
                mark(m.parameter_efficient()),
                mark(m.zero_cost()),
                mark(m.multi_task()),
            ));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table1_matches_paper() {
        // The paper's Table 1, row by row.
        let rows: Vec<(Method, bool, bool, bool)> = vec![
            (Method::FineTune, false, true, false),
            (Method::Lora, true, false, true),
            (Method::LoraFused, true, true, false),
            (Method::Adapters, true, false, true),
            (Method::BitFit, true, true, true),
            (Method::Pt1, true, false, true),
            (Method::Pt2, true, false, true),
            (Method::AotKron, true, true, true),
            (Method::AotFc, true, true, true),
        ];
        for (m, pe, zc, mt) in rows {
            assert_eq!(m.parameter_efficient(), pe, "{m:?} PE");
            assert_eq!(m.zero_cost(), zc, "{m:?} zero-cost");
            assert_eq!(m.multi_task(), mt, "{m:?} multi-task");
        }
    }

    #[test]
    fn only_aot_has_all_three() {
        // The paper's selling point: AoT is the unique method that is
        // parameter-efficient AND zero-cost AND multi-task... shared only
        // with BitFit, which it must beat on quality (Table 2).
        let winners: Vec<Method> = Method::ALL
            .into_iter()
            .filter(|m| m.parameter_efficient() && m.zero_cost() && m.multi_task())
            .collect();
        assert_eq!(winners, vec![Method::BitFit, Method::AotKron, Method::AotFc]);
    }

    #[test]
    fn aot_variants_share_serve_signature() {
        assert_eq!(Method::AotKron.serve_signature(), "aot");
        assert_eq!(Method::AotFc.serve_signature(), "aot");
    }

    #[test]
    fn parse_roundtrip() {
        for m in Method::ALL {
            assert_eq!(Method::parse(m.name()).unwrap(), m);
        }
        assert!(Method::parse("nope").is_err());
    }

    #[test]
    fn table1_renders_every_method() {
        let t = Method::table1();
        for m in Method::ALL {
            assert!(t.contains(m.display()), "{}", m.display());
        }
    }
}
