//! `aotpt` — the launcher.
//!
//! Subcommands:
//!   table1                         print the method property matrix
//!   exp <id>                       run one experiment (fig3|fig8|fig9|
//!                                  table2|table5|norms)
//!   adapters                       artifact-free tiered adapter-store
//!                                  demo (spill + fault-in under a RAM
//!                                  budget; HostBackend)
//!   serve                          HTTP serving front end: data plane
//!                                  (`--addr`) + optional management
//!                                  plane (`--mgmt-addr`), graceful
//!                                  drain on SIGTERM (DESIGN.md §15)
//!   info                           manifest / model inventory

use std::sync::Arc;
use std::time::Duration;

use aotpt::cli::Args;
use aotpt::config::{Manifest, Scale};
use aotpt::coordinator::{
    AdapterConfig, AdapterDType, Bucket, Coordinator, CoordinatorConfig, HostBackend, TaskRegistry,
};
use aotpt::experiments::{norms, quality, speed, table1};
use aotpt::peft::{parse_bytes, TaskP};
use aotpt::runtime::Runtime;
use aotpt::server::{signal, Server, ServerConfig};
use aotpt::util::Pcg64;
use aotpt::Result;

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    if let Err(e) = run(&argv) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn run(argv: &[String]) -> Result<()> {
    let args = Args::new(
        "aotpt",
        "Ahead-of-Time P-Tuning: multi-task PEFT serving + training framework",
    )
    .opt("scale", Some("quick"), "experiment scale: smoke|quick|full")
    .opt("model", None, "override model shape")
    .opt("budget", Some("8"), "per-cell bench budget seconds (speed figures)")
    .opt(
        "adapter-ram-budget",
        Some("0"),
        "max resident adapter-table bytes (e.g. 512MiB; 0 = unlimited)",
    )
    .opt("adapter-dtype", Some("f32"), "adapter table storage dtype: f32|f16|int8")
    .opt("adapter-dedup", Some("off"), "fuse-time shared-row dedup: on|off")
    .opt(
        "adapter-mmap",
        Some("auto"),
        "mmap cold-tier spill files: on|off|auto (auto = on where supported)",
    )
    .opt(
        "kernel",
        Some("auto"),
        "row-kernel dispatch: auto (best SIMD for this host) | scalar \
         (AOTPT_KERNEL overrides auto)",
    )
    .opt("gather-threads", Some("0"), "gather shard threads (0 = one per core)")
    .opt("prefetch", Some("on"), "gather-aware adapter prefetch: on|off")
    .opt("tasks", Some("8"), "task count (adapters demo)")
    .opt("requests", Some("64"), "request count (adapters demo)")
    .opt("addr", Some("127.0.0.1:7700"), "serve: data-plane bind address")
    .opt(
        "mgmt-addr",
        None,
        "serve: management-plane bind address (omit to disable the plane)",
    )
    .opt(
        "request-deadline-ms",
        Some("30000"),
        "serve: server-side cap on the per-request deadline",
    )
    .opt(
        "queue-limit",
        Some("256"),
        "serve: max classify requests in flight before 429",
    )
    .opt(
        "io-timeout-ms",
        Some("10000"),
        "serve: per-connection read/write timeout (slow-loris bound)",
    )
    .opt("max-conns", Some("256"), "serve: max concurrent connections")
    .opt(
        "backend",
        Some("host"),
        "serve: execute backend: host (self-contained demo tasks) | pjrt \
         (manifest-backed backbone)",
    )
    .opt(
        "demo-tasks",
        Some("4"),
        "serve --backend host: number of synthetic demo tasks to register",
    )
    .flag("verbose", "debug logging")
    .parse(argv)
    .map_err(|e| anyhow::anyhow!("{e}"))?;

    if args.has("verbose") {
        aotpt::util::log::set_level(aotpt::util::log::Level::Debug);
    }

    let positional = args.positional().to_vec();
    let command = positional.first().map(String::as_str).unwrap_or("info");

    // Adapter-store flags are validated up front for every command: a
    // typo'd --adapter-dtype fails here, listing the valid values, rather
    // than on the first task registration deep inside a running pipeline.
    let adapter_cfg = adapter_config_from_args(&args)?;

    // Pin the row-kernel dispatch before any gather runs (DESIGN.md §14).
    // `auto` still honors the AOTPT_KERNEL environment override.
    let kernel_mode = args
        .get_via("kernel", aotpt::peft::KernelMode::parse)
        .map_err(anyhow::Error::msg)?;
    let kernel = aotpt::peft::kernel::set_active(kernel_mode);
    aotpt::util::log::log(
        aotpt::util::log::Level::Debug,
        module_path!(),
        &format!("row kernel: {}", kernel.name),
    );

    // The adapters demo is artifact-free (HostBackend); everything else
    // reads the manifest.
    if command == "adapters" {
        return run_adapters_demo(&args, adapter_cfg);
    }
    if command == "serve" {
        return run_serve(&args, adapter_cfg);
    }
    let manifest = Manifest::load(&aotpt::artifacts_dir())?;

    match command {
        "info" => {
            println!("artifacts: {}", manifest.artifacts().count());
            println!("vocab: {}", manifest.vocab_size);
            for (name, m) in &manifest.models {
                let analog = manifest
                    .paper_analog
                    .get(name)
                    .map(|s| format!(" (~{s})"))
                    .unwrap_or_default();
                println!(
                    "  {name}: d={} l={} heads={} params={:.1}M{analog}",
                    m.d_model,
                    m.n_layers,
                    m.n_heads,
                    m.params as f64 / 1e6
                );
            }
        }
        "table1" => {
            println!("{}", table1(&manifest)?);
        }
        "exp" => {
            let id = positional
                .get(1)
                .ok_or_else(|| anyhow::anyhow!("usage: aotpt exp <id>"))?;
            let scale = Scale::parse(&args.get("scale").unwrap())?;
            let runtime = Runtime::new()?;
            run_experiment(&runtime, &manifest, id, scale, &args)?;
        }
        other => anyhow::bail!("unknown command {other} (info|table1|exp|adapters|serve)"),
    }
    Ok(())
}

/// Build the adapter-store config from the shared CLI flags.  Called
/// before command dispatch so bad values fail fast with the flag named.
fn adapter_config_from_args(args: &Args) -> Result<AdapterConfig> {
    let ram_budget_bytes = args
        .get_via("adapter-ram-budget", parse_bytes)
        .map_err(anyhow::Error::msg)?;
    let dtype = args
        .get_via("adapter-dtype", AdapterDType::parse)
        .map_err(anyhow::Error::msg)?;
    let dedup = args.get_via("adapter-dedup", parse_switch).map_err(anyhow::Error::msg)?;
    let mmap = args.get_via("adapter-mmap", parse_mmap_switch).map_err(anyhow::Error::msg)?;
    Ok(AdapterConfig { ram_budget_bytes, dtype, dedup, mmap, ..AdapterConfig::default() })
}

/// Artifact-free demo of the tiered adapter store (DESIGN.md §10, §12):
/// registers more task bytes than `--adapter-ram-budget` allows, serves a
/// mixed multi-task burst through the HostBackend pipeline, and prints
/// the residency counters that flowed into `MetricsSnapshot`.
fn run_adapters_demo(args: &Args, cfg: AdapterConfig) -> Result<()> {
    let n_tasks = args.get_usize("tasks").map_err(anyhow::Error::msg)?.max(1);
    let n_requests = args.get_usize("requests").map_err(anyhow::Error::msg)?.max(1);
    let gather_threads = args.get_usize("gather-threads").map_err(anyhow::Error::msg)?;
    let prefetch = args.get_via("prefetch", parse_switch).map_err(anyhow::Error::msg)?;
    let (ram_budget, dtype, dedup) = (cfg.ram_budget_bytes, cfg.dtype, cfg.dedup);

    // A small-model analog: big enough that a handful of tasks outgrow a
    // few-MiB budget, small enough to run in seconds on a laptop.
    let (layers, vocab, d_model, classes) = (4usize, 2048usize, 64usize, 4usize);
    let table_bytes = layers * vocab * d_model * dtype.size();
    let registry = TaskRegistry::with_adapter_config(layers, vocab, d_model, classes, cfg);

    let mut rng = Pcg64::new(17);
    let mut names = Vec::new();
    for i in 0..n_tasks {
        let name = format!("task{i:03}");
        let mut data = rng.normal_vec(layers * vocab * d_model, 0.5);
        if dedup {
            // Mimic the paper's §4.3 observation that most per-token
            // updates are near-zero: blank out half the vocab so the
            // fuse-time dedup pass has shared rows to collapse.
            for row in 0..layers * vocab {
                if row % 2 == 0 {
                    data[row * d_model..(row + 1) * d_model].fill(0.0);
                }
            }
        }
        let table = TaskP::new(layers, vocab, d_model, data)?;
        let head_w =
            aotpt::tensor::Tensor::from_f32(&[d_model, 2], rng.normal_vec(d_model * 2, 0.2));
        let head_b = aotpt::tensor::Tensor::from_f32(&[2], vec![0.0; 2]);
        registry.register_fused(&name, table, &head_w, &head_b)?;
        names.push(name);
    }
    println!(
        "registered {n_tasks} tasks x {:.1} MiB ({}) = {:.1} MiB total, RAM budget {:.1} MiB",
        table_bytes as f64 / (1 << 20) as f64,
        dtype.name(),
        (n_tasks * table_bytes) as f64 / (1 << 20) as f64,
        ram_budget as f64 / (1 << 20) as f64,
    );

    let buckets = vec![Bucket { batch: 1, seq: 32 }, Bucket { batch: 8, seq: 32 }];
    let coordinator = Coordinator::with_backend(
        registry,
        buckets,
        classes,
        CoordinatorConfig {
            model: "host".into(),
            linger_ms: 1,
            signature: "aot".into(),
            gather_threads,
            prefetch,
            ..Default::default()
        },
        Arc::new(HostBackend),
    )?;

    let mut ok = 0usize;
    for r in 0..n_requests {
        let task = &names[r % n_tasks];
        let len = 4 + (r % 24);
        let ids: Vec<i32> = (0..len).map(|_| rng.range(0, vocab as i64) as i32).collect();
        let response = coordinator.classify(task, ids)?;
        anyhow::ensure!(
            response.logits.iter().all(|x| x.is_finite()),
            "task {task}: non-finite logits"
        );
        ok += 1;
    }
    let snapshot = coordinator.metrics().snapshot();
    println!("served {ok}/{n_requests} requests across {n_tasks} tasks");
    println!("{}", snapshot.render());
    let a = snapshot.adapter;
    println!(
        "residency: {} resident / {} spilled tasks, {:.1} MiB resident, \
         {} hits, {} faults, {} cold serves, {} evictions, {} spill writes, \
         prefetch {}h/{}m/{}w",
        a.resident_tasks,
        a.spilled_tasks,
        a.resident_bytes as f64 / (1 << 20) as f64,
        a.hits,
        a.faults,
        a.cold_serves,
        a.evictions,
        a.spill_writes,
        a.prefetch_hits,
        a.prefetch_misses,
        a.prefetch_wasted,
    );
    println!(
        "cold tier: {} mmap opens / {} fallbacks, {:.1} MiB mapped, \
         rows served {} mapped / {} positioned",
        a.mmap_opens,
        a.mmap_fallbacks,
        a.mapped_bytes as f64 / (1 << 20) as f64,
        a.cold_rows_mapped,
        a.cold_rows_positioned,
    );
    if dedup {
        println!(
            "dedup: {:.2}x ({} logical rows -> {} stored, {} shared-zero)",
            a.dedup_ratio(),
            a.dedup_logical_rows,
            a.dedup_stored_rows,
            a.dedup_zero_rows,
        );
    }
    coordinator.shutdown();
    Ok(())
}

/// `aotpt serve`: the HTTP front end (DESIGN.md §15).  `--backend host`
/// is fully self-contained — it registers `--demo-tasks` synthetic tasks
/// over the HostBackend, so the serving stack (and the CI smoke job) run
/// without artifacts.  `--backend pjrt` serves the manifest-backed
/// backbone.  Runs until SIGTERM/SIGINT or `POST /mgmt/shutdown`, then
/// drains: the process exits non-zero if any admitted request was lost
/// (queue depth != 0 after drain).
fn run_serve(args: &Args, adapter_cfg: AdapterConfig) -> Result<()> {
    let cfg = ServerConfig {
        addr: args.get("addr").unwrap(),
        mgmt_addr: args.get("mgmt-addr"),
        request_deadline: Duration::from_millis(
            args.get_usize("request-deadline-ms").map_err(anyhow::Error::msg)?.max(1) as u64,
        ),
        queue_limit: args.get_usize("queue-limit").map_err(anyhow::Error::msg)?.max(1),
        io_timeout: Duration::from_millis(
            args.get_usize("io-timeout-ms").map_err(anyhow::Error::msg)?.max(1) as u64,
        ),
        max_conns: args.get_usize("max-conns").map_err(anyhow::Error::msg)?.max(1),
        ..ServerConfig::default()
    };
    let gather_threads = args.get_usize("gather-threads").map_err(anyhow::Error::msg)?;
    let prefetch = args.get_via("prefetch", parse_switch).map_err(anyhow::Error::msg)?;
    let backend = args.get("backend").unwrap();

    let coordinator = match backend.as_str() {
        "host" => {
            let n_tasks = args.get_usize("demo-tasks").map_err(anyhow::Error::msg)?.max(1);
            // Same small-model analog as the adapters demo.
            let (layers, vocab, d_model, classes) = (4usize, 2048usize, 64usize, 4usize);
            let registry =
                TaskRegistry::with_adapter_config(layers, vocab, d_model, classes, adapter_cfg);
            let mut rng = Pcg64::new(17);
            for i in 0..n_tasks {
                let name = format!("task{i:03}");
                let table = TaskP::new(
                    layers,
                    vocab,
                    d_model,
                    rng.normal_vec(layers * vocab * d_model, 0.5),
                )?;
                let head_w = aotpt::tensor::Tensor::from_f32(
                    &[d_model, 2],
                    rng.normal_vec(d_model * 2, 0.2),
                );
                let head_b = aotpt::tensor::Tensor::from_f32(&[2], vec![0.0; 2]);
                registry.register_fused(&name, table, &head_w, &head_b)?;
            }
            println!("registered {n_tasks} demo tasks (task000..task{:03})", n_tasks - 1);
            let buckets = vec![Bucket { batch: 1, seq: 32 }, Bucket { batch: 8, seq: 32 }];
            Coordinator::with_backend(
                registry,
                buckets,
                classes,
                CoordinatorConfig {
                    model: "host".into(),
                    linger_ms: 1,
                    signature: "aot".into(),
                    gather_threads,
                    prefetch,
                    ..Default::default()
                },
                Arc::new(HostBackend),
            )?
        }
        "pjrt" => {
            let manifest = Manifest::load(&aotpt::artifacts_dir())?;
            let model = args.get("model").unwrap_or_else(|| "small".into());
            let info = manifest.model(&model)?;
            let registry = TaskRegistry::with_adapter_config(
                info.n_layers,
                manifest.vocab_size,
                info.d_model,
                manifest.multitask_classes,
                adapter_cfg,
            );
            let runtime = Runtime::new()?;
            Coordinator::new(
                runtime,
                &manifest,
                registry,
                CoordinatorConfig { model, gather_threads, prefetch, ..Default::default() },
            )?
        }
        other => anyhow::bail!("unknown serve backend {other} (host|pjrt)"),
    };

    let server = Server::bind(Arc::new(coordinator), cfg)?;
    println!("data plane listening on {}", server.data_addr());
    if let Some(addr) = server.mgmt_addr() {
        println!("management plane listening on {addr}");
    }
    use std::io::Write as _;
    let _ = std::io::stdout().flush();

    signal::install();
    while !signal::triggered() && !server.shutdown_requested() {
        std::thread::sleep(Duration::from_millis(100));
    }
    println!("shutdown requested; draining");
    let snapshot = server.drain();
    println!("{}", snapshot.render());
    anyhow::ensure!(
        snapshot.queue_depth == 0,
        "drain left queue depth {} (lost replies)",
        snapshot.queue_depth
    );
    Ok(())
}

/// Parse `--adapter-mmap`: a plain on/off switch plus `auto`, which
/// defers to [`aotpt::peft::default_mmap`] (on, unless the
/// `AOTPT_ADAPTER_MMAP` environment variable disables it).
fn parse_mmap_switch(s: &str) -> Result<bool> {
    if s.trim().eq_ignore_ascii_case("auto") {
        return Ok(aotpt::peft::default_mmap());
    }
    parse_switch(s)
}

/// Parse an on/off CLI switch.
fn parse_switch(s: &str) -> Result<bool> {
    match s.trim().to_ascii_lowercase().as_str() {
        "on" | "true" | "1" | "yes" => Ok(true),
        "off" | "false" | "0" | "no" => Ok(false),
        other => anyhow::bail!("expected on|off, got {other}"),
    }
}

fn run_experiment(
    runtime: &Arc<Runtime>,
    manifest: &Manifest,
    id: &str,
    scale: Scale,
    args: &Args,
) -> Result<()> {
    let budget = args.get_f64("budget").map_err(|e| anyhow::anyhow!("{e}"))?;
    match id {
        "table1" => println!("{}", table1(manifest)?),
        // ---- speed figures (paper §4.4) -----------------------------------
        "fig3" => {
            // Fig 3: DeBERTa-XL analog (`large`), seq 384, batches 1/16/64.
            let model = args.get("model").unwrap_or_else(|| "large".into());
            let cells: Vec<(usize, usize)> = match scale {
                Scale::Smoke => vec![(1, 384)],
                Scale::Quick => vec![(1, 384), (16, 384)],
                Scale::Full => vec![(1, 384), (16, 384), (64, 384)],
            };
            let cells = speed::run_grid(runtime, manifest, &model, &cells, budget)?;
            println!("{}", speed::report("fig3", &cells)?);
        }
        "fig8" => {
            // Appendix Fig 8: all backbones at seq 384.
            let mut all = Vec::new();
            for model in ["small", "base", "large"] {
                let cells: Vec<(usize, usize)> = match scale {
                    Scale::Smoke => vec![(1, 384)],
                    Scale::Quick => vec![(1, 384), (16, 384)],
                    Scale::Full => vec![(1, 384), (16, 384), (64, 384)],
                };
                all.extend(speed::run_grid(runtime, manifest, model, &cells, budget)?);
            }
            println!("{}", speed::report("fig8", &all)?);
        }
        "fig9" => {
            // Appendix Fig 9: short sequences (16, 64).
            let mut all = Vec::new();
            for model in ["small", "base", "large"] {
                let cells: Vec<(usize, usize)> = match scale {
                    Scale::Smoke => vec![(1, 16)],
                    Scale::Quick => vec![(1, 16), (1, 64), (16, 64)],
                    Scale::Full => {
                        vec![(1, 16), (1, 64), (16, 16), (16, 64), (64, 16), (64, 64)]
                    }
                };
                all.extend(speed::run_grid(runtime, manifest, model, &cells, budget)?);
            }
            println!("{}", speed::report("fig9", &all)?);
        }
        // ---- quality tables + derived figures -----------------------------
        "table2" => {
            let protocol = quality::Protocol::for_scale(scale, &aotpt::data::SUPERGLUE_TASKS);
            let results = quality::run_suite(runtime, manifest, &protocol)?;
            println!("{}", quality::report("table2", &results)?);
            println!("{}", quality::evp_report("evp_superglue", &results, 64)?);
            println!("{}", quality::sweep_report("fig2", &results)?);
        }
        "table5" => {
            let protocol = quality::Protocol::for_scale(scale, &aotpt::data::GLUE_TASKS);
            let results = quality::run_suite(runtime, manifest, &protocol)?;
            println!("{}", quality::report("table5", &results)?);
            println!("{}", quality::evp_report("evp_glue", &results, 64)?);
            println!("{}", quality::sweep_report("fig4_6", &results)?);
        }
        // ---- analysis ------------------------------------------------------
        "norms" => {
            let model = args.get("model").unwrap_or_else(|| "tiny".into());
            let results = norms::run(runtime, manifest, &model, scale != Scale::Full)?;
            for r in results {
                println!(
                    "== {} (dev metric {:.3}, cue recall@25 {:.2}) ==\n{}",
                    r.task, r.best_metric, r.cue_recall, r.table
                );
            }
        }
        other => anyhow::bail!(
            "unknown experiment {other} (table1|fig3|fig8|fig9|table2|table5|norms)"
        ),
    }
    Ok(())
}
