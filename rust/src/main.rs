//! `aotpt` — the launcher.
//!
//! Subcommands:
//!   table1                         print the method property matrix
//!   exp <id>                       run one experiment (fig3|fig8|fig9|
//!                                  table2|table5|norms)
//!   info                           manifest / model inventory

use std::sync::Arc;

use aotpt::cli::Args;
use aotpt::config::{Manifest, Scale};
use aotpt::experiments::{norms, quality, speed, table1};
use aotpt::runtime::Runtime;
use aotpt::Result;

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    if let Err(e) = run(&argv) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn run(argv: &[String]) -> Result<()> {
    let args = Args::new(
        "aotpt",
        "Ahead-of-Time P-Tuning: multi-task PEFT serving + training framework",
    )
    .opt("scale", Some("quick"), "experiment scale: smoke|quick|full")
    .opt("model", None, "override model shape")
    .opt("budget", Some("8"), "per-cell bench budget seconds (speed figures)")
    .flag("verbose", "debug logging")
    .parse(argv)
    .map_err(|e| anyhow::anyhow!("{e}"))?;

    if args.has("verbose") {
        aotpt::util::log::set_level(aotpt::util::log::Level::Debug);
    }

    let manifest = Manifest::load(&aotpt::artifacts_dir())?;
    let positional = args.positional().to_vec();
    let command = positional.first().map(String::as_str).unwrap_or("info");

    match command {
        "info" => {
            println!("artifacts: {}", manifest.artifacts().count());
            println!("vocab: {}", manifest.vocab_size);
            for (name, m) in &manifest.models {
                let analog = manifest
                    .paper_analog
                    .get(name)
                    .map(|s| format!(" (~{s})"))
                    .unwrap_or_default();
                println!(
                    "  {name}: d={} l={} heads={} params={:.1}M{analog}",
                    m.d_model,
                    m.n_layers,
                    m.n_heads,
                    m.params as f64 / 1e6
                );
            }
        }
        "table1" => {
            println!("{}", table1(&manifest)?);
        }
        "exp" => {
            let id = positional
                .get(1)
                .ok_or_else(|| anyhow::anyhow!("usage: aotpt exp <id>"))?;
            let scale = Scale::parse(&args.get("scale").unwrap())?;
            let runtime = Runtime::new()?;
            run_experiment(&runtime, &manifest, id, scale, &args)?;
        }
        other => anyhow::bail!("unknown command {other} (info|table1|exp)"),
    }
    Ok(())
}

fn run_experiment(
    runtime: &Arc<Runtime>,
    manifest: &Manifest,
    id: &str,
    scale: Scale,
    args: &Args,
) -> Result<()> {
    let budget = args.get_f64("budget").map_err(|e| anyhow::anyhow!("{e}"))?;
    match id {
        "table1" => println!("{}", table1(manifest)?),
        // ---- speed figures (paper §4.4) -----------------------------------
        "fig3" => {
            // Fig 3: DeBERTa-XL analog (`large`), seq 384, batches 1/16/64.
            let model = args.get("model").unwrap_or_else(|| "large".into());
            let cells: Vec<(usize, usize)> = match scale {
                Scale::Smoke => vec![(1, 384)],
                Scale::Quick => vec![(1, 384), (16, 384)],
                Scale::Full => vec![(1, 384), (16, 384), (64, 384)],
            };
            let cells = speed::run_grid(runtime, manifest, &model, &cells, budget)?;
            println!("{}", speed::report("fig3", &cells)?);
        }
        "fig8" => {
            // Appendix Fig 8: all backbones at seq 384.
            let mut all = Vec::new();
            for model in ["small", "base", "large"] {
                let cells: Vec<(usize, usize)> = match scale {
                    Scale::Smoke => vec![(1, 384)],
                    Scale::Quick => vec![(1, 384), (16, 384)],
                    Scale::Full => vec![(1, 384), (16, 384), (64, 384)],
                };
                all.extend(speed::run_grid(runtime, manifest, model, &cells, budget)?);
            }
            println!("{}", speed::report("fig8", &all)?);
        }
        "fig9" => {
            // Appendix Fig 9: short sequences (16, 64).
            let mut all = Vec::new();
            for model in ["small", "base", "large"] {
                let cells: Vec<(usize, usize)> = match scale {
                    Scale::Smoke => vec![(1, 16)],
                    Scale::Quick => vec![(1, 16), (1, 64), (16, 64)],
                    Scale::Full => {
                        vec![(1, 16), (1, 64), (16, 16), (16, 64), (64, 16), (64, 64)]
                    }
                };
                all.extend(speed::run_grid(runtime, manifest, model, &cells, budget)?);
            }
            println!("{}", speed::report("fig9", &all)?);
        }
        // ---- quality tables + derived figures -----------------------------
        "table2" => {
            let protocol = quality::Protocol::for_scale(scale, &aotpt::data::SUPERGLUE_TASKS);
            let results = quality::run_suite(runtime, manifest, &protocol)?;
            println!("{}", quality::report("table2", &results)?);
            println!("{}", quality::evp_report("evp_superglue", &results, 64)?);
            println!("{}", quality::sweep_report("fig2", &results)?);
        }
        "table5" => {
            let protocol = quality::Protocol::for_scale(scale, &aotpt::data::GLUE_TASKS);
            let results = quality::run_suite(runtime, manifest, &protocol)?;
            println!("{}", quality::report("table5", &results)?);
            println!("{}", quality::evp_report("evp_glue", &results, 64)?);
            println!("{}", quality::sweep_report("fig4_6", &results)?);
        }
        // ---- analysis ------------------------------------------------------
        "norms" => {
            let model = args.get("model").unwrap_or_else(|| "tiny".into());
            let results = norms::run(runtime, manifest, &model, scale != Scale::Full)?;
            for r in results {
                println!(
                    "== {} (dev metric {:.3}, cue recall@25 {:.2}) ==\n{}",
                    r.task, r.best_metric, r.cue_recall, r.table
                );
            }
        }
        other => anyhow::bail!(
            "unknown experiment {other} (table1|fig3|fig8|fig9|table2|table5|norms)"
        ),
    }
    Ok(())
}
