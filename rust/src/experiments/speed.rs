//! Figures 3 / 8 / 9: inference-speed overhead of every method,
//! normalized by the vanilla fine-tuned model (paper §4.4).
//!
//! Protocol mirror: mean inference time over repeated executions (300 at
//! batch 1, 100 otherwise — wall-clock-capped per cell on this one-core
//! testbed; the per-cell iteration count is recorded in the output).
//! All methods share the identical backbone math (same jnp graph per
//! bucket), so the measured deltas isolate exactly what the paper
//! isolates: longer sequences (pt1/pt2), extra matmuls (lora/adapters/
//! aot-unfused), bias adds (bitfit/aot).

use std::sync::Arc;

use crate::bench::{measure, BenchConfig, Measurement};
use crate::config::Manifest;
use crate::json::Json;
use crate::runtime::{Runtime, WeightCache};
use crate::tensor::{DType, Tensor};
use crate::util::Pcg64;
use crate::Result;

pub const METHODS: [&str; 8] =
    ["fine-tune", "bitfit", "lora", "adapters", "pt1", "pt2", "aot", "aot-unfused"];

/// One grid cell result.
#[derive(Clone, Debug)]
pub struct Cell {
    pub model: String,
    pub method: String,
    pub batch: usize,
    pub seq: usize,
    pub measurement: Measurement,
    /// time / fine-tune time for the same (model, batch, seq).
    pub ratio: f64,
}

/// Run the speed grid for one model over (batch, seq) cells.
pub fn run_grid(
    runtime: &Arc<Runtime>,
    manifest: &Manifest,
    model: &str,
    cells: &[(usize, usize)],
    budget_secs: f64,
) -> Result<Vec<Cell>> {
    let weights = WeightCache::from_ckpt(
        runtime,
        &manifest.dir.join(format!("backbone_{model}.aotckpt")),
    )?;
    let mut out = Vec::new();
    for &(batch, seq) in cells {
        let mut base_mean = None;
        for method in METHODS {
            let Ok(spec) = manifest.find_bucket("fwd", model, method, batch, seq) else {
                continue;
            };
            let exe = runtime.load(manifest, &spec.stem)?;
            // Upload every per-call input once; iterate pure execute —
            // the paper times model evaluation, not host transfers.
            let mut rng = Pcg64::new(42);
            let mut uploads = Vec::new();
            for input in &exe.spec.inputs {
                if input.name.starts_with("w.") {
                    uploads.push(None);
                    continue;
                }
                let t = if input.name == "in.mask" {
                    Tensor::from_f32(&input.shape, vec![1.0; input.numel()])
                } else {
                    random_input(&mut rng, input.dtype, &input.shape, manifest.vocab_size)
                };
                uploads.push(Some(exe.upload(&t)?));
            }
            let mut args: Vec<&xla::PjRtBuffer> = Vec::new();
            for (input, upload) in exe.spec.inputs.iter().zip(&uploads) {
                match upload {
                    Some(b) => args.push(b),
                    None => args.push(weights.buffer(input.name.strip_prefix("w.").unwrap())?),
                }
            }
            let cfg = BenchConfig::paper(batch, budget_secs);
            let name = format!("{model}/{method}/b{batch}n{seq}");
            let m = measure(&name, &cfg, || {
                exe.run_buffers(&args).expect("execute");
            });
            if method == "fine-tune" {
                base_mean = Some(m.mean_secs);
            }
            let ratio = m.mean_secs / base_mean.unwrap_or(m.mean_secs);
            crate::info!("{name}: {:.3}ms ({} iters) ratio {:.3}", m.mean_secs * 1e3, m.iters, ratio);
            out.push(Cell {
                model: model.to_string(),
                method: method.to_string(),
                batch,
                seq,
                measurement: m,
                ratio,
            });
        }
    }
    Ok(out)
}

fn random_input(rng: &mut Pcg64, dtype: DType, shape: &[usize], vocab: usize) -> Tensor {
    let numel: usize = shape.iter().product();
    match dtype {
        DType::I32 => Tensor::from_i32(
            shape,
            (0..numel).map(|_| rng.range(5, vocab as i64) as i32).collect(),
        ),
        _ => {
            // mask-like inputs should be 1.0; generic inputs small-random.
            Tensor::from_f32(shape, (0..numel).map(|_| rng.f32() * 0.1).collect())
        }
    }
}

/// Render + serialize a set of cells as one figure's result.
pub fn report(id: &str, cells: &[Cell]) -> Result<String> {
    let mut rows = Vec::new();
    let mut json_rows = Json::Arr(vec![]);
    for c in cells {
        rows.push(vec![
            c.model.clone(),
            format!("b{}", c.batch),
            format!("n{}", c.seq),
            c.method.clone(),
            format!("{:.3}", c.measurement.mean_secs * 1e3),
            format!("{:.3}", c.ratio),
            format!("{}", c.measurement.iters),
        ]);
        let mut j = c.measurement.to_json();
        j.set("model", Json::Str(c.model.clone()));
        j.set("method", Json::Str(c.method.clone()));
        j.set("batch", Json::Num(c.batch as f64));
        j.set("seq", Json::Num(c.seq as f64));
        j.set("ratio", Json::Num(c.ratio));
        json_rows.push(j);
    }
    super::write_result(id, &json_rows)?;
    Ok(crate::bench::render_table(
        &["model", "batch", "seq", "method", "mean ms", "ratio vs FT", "iters"],
        &rows,
    ))
}
