//! Tables 2 / Appendix Table 3 (per-task quality), Figure 2 (macro vs
//! rank/prefix), Figures 4–7 (param-count + EVP curves).
//!
//! One machinery serves all of them: the grid search produces
//! (assignment × seed) scores per (task, method); the table reports the
//! best assignment's median ± std; the figures are re-slices of the same
//! score pool.

use std::collections::BTreeMap;
use std::sync::Arc;

use crate::config::{Manifest, Scale};
use crate::data::{self, Lexicon};
use crate::json::Json;
use crate::runtime::{Runtime, WeightCache};
use crate::train::{evp, grid, GridSearch, TrainConfig};
use crate::util::stats;
use crate::Result;

pub const METHODS: [&str; 8] =
    ["fine-tune", "bitfit", "lora", "adapters", "pt1", "pt2", "aot-kron", "aot-fc"];

/// Scaled protocol knobs (the paper's full grid is `Scale::Full`).
pub struct Protocol {
    pub model: String,
    pub tasks: Vec<String>,
    pub methods: Vec<String>,
    pub lrs: Vec<f32>,
    pub seeds: Vec<u64>,
    pub n_train: usize,
    pub n_dev: usize,
    pub max_epochs: usize,
    pub patience: usize,
    pub max_steps: usize,
}

impl Protocol {
    pub fn for_scale(scale: Scale, suite: &[&str]) -> Protocol {
        let tasks: Vec<String> = suite.iter().map(|s| s.to_string()).collect();
        match scale {
            Scale::Smoke => Protocol {
                model: "tiny".into(),
                tasks: tasks.into_iter().take(2).collect(),
                methods: vec!["bitfit".into(), "aot-fc".into()],
                lrs: vec![5e-3],
                seeds: vec![0],
                n_train: 128,
                n_dev: 64,
                max_epochs: 3,
                patience: 2,
                max_steps: 48,
            },
            Scale::Quick => Protocol {
                model: "tiny".into(),
                tasks,
                methods: METHODS.iter().map(|s| s.to_string()).collect(),
                lrs: vec![5e-3],
                seeds: vec![0, 1],
                n_train: 384,
                n_dev: 192,
                max_epochs: 6,
                patience: 3,
                max_steps: 192,
            },
            Scale::Full => Protocol {
                // The paper's Appendix Table 4 grid, at `small` scale.
                model: "small".into(),
                tasks,
                methods: METHODS.iter().map(|s| s.to_string()).collect(),
                lrs: vec![1e-4, 5e-4, 1e-3, 5e-3],
                seeds: vec![0, 1, 2, 3, 4],
                n_train: 2048,
                n_dev: 512,
                max_epochs: 30,
                patience: 8,
                max_steps: 0,
            },
        }
    }
}

/// (task, method) -> (best assignment label, median, std, all scores).
pub type QualityResults = BTreeMap<String, BTreeMap<String, (String, f64, f64, Vec<f64>)>>;

pub fn run_suite(
    runtime: &Arc<Runtime>,
    manifest: &Manifest,
    protocol: &Protocol,
) -> Result<QualityResults> {
    let lex = Lexicon::generate(0);
    let weights = Arc::new(WeightCache::from_ckpt(
        runtime,
        &manifest.dir.join(format!("backbone_{}.aotckpt", protocol.model)),
    )?);
    let seq = 64; // the training artifacts' bucket
    let mut results: QualityResults = BTreeMap::new();

    for task_name in &protocol.tasks {
        let classes = data::tasks::task_classes(task_name);
        let task = data::make_task(&lex, task_name, 1234, protocol.n_train, protocol.n_dev, seq)?;
        for method in &protocol.methods {
            let assignments =
                grid::assignments_for(manifest, &protocol.model, method, classes, &protocol.lrs);
            if assignments.is_empty() {
                crate::warnln!(
                    "no {} artifacts for {} classes={classes}; skipping",
                    method,
                    protocol.model
                );
                continue;
            }
            let search = GridSearch {
                runtime,
                manifest,
                weights: Arc::clone(&weights),
                assignments,
                seeds: protocol.seeds.clone(),
                train_cfg: TrainConfig {
                    lr: 0.0,
                    seed: 0,
                    max_epochs: protocol.max_epochs,
                    patience: protocol.patience,
                    max_steps: protocol.max_steps,
                },
            };
            let gr = search.run(&task)?;
            let (label, median, std) = gr
                .best()
                .ok_or_else(|| anyhow::anyhow!("no runs for {task_name}/{method}"))?;
            crate::info!("{task_name}/{method}: best {label} median {median:.4} ± {std:.4}");
            results
                .entry(task_name.clone())
                .or_default()
                .insert(method.clone(), (label, median, std, gr.all_scores()));
        }
    }
    Ok(results)
}

/// Render the Table-2-style report (per task + macro column) and persist.
pub fn report(id: &str, results: &QualityResults) -> Result<String> {
    let tasks: Vec<&String> = results.keys().collect();
    let mut methods: Vec<String> = Vec::new();
    for per in results.values() {
        for m in per.keys() {
            if !methods.contains(m) {
                methods.push(m.clone());
            }
        }
    }
    let mut rows = Vec::new();
    let mut json = Json::obj();
    for method in &methods {
        let mut row = vec![method.clone()];
        let mut scores = Vec::new();
        let mut jm = Json::obj();
        for task in &tasks {
            match results[*task].get(method) {
                Some((label, median, std, _)) => {
                    row.push(format!("{:.1}±{:.1}", median * 100.0, std * 100.0));
                    scores.push(*median);
                    jm.set(
                        task,
                        Json::from_pairs(vec![
                            ("median", Json::Num(*median)),
                            ("std", Json::Num(*std)),
                            ("assignment", Json::Str(label.clone())),
                        ]),
                    );
                }
                None => row.push("-".into()),
            }
        }
        let macro_score = stats::mean(&scores);
        row.push(format!("{:.1}", macro_score * 100.0));
        jm.set("macro", Json::Num(macro_score));
        json.set(method, jm);
        rows.push(row);
    }
    super::write_result(id, &json)?;
    let mut headers: Vec<&str> = vec!["method"];
    for t in &tasks {
        headers.push(t);
    }
    headers.push("macro");
    Ok(crate::bench::render_table(&headers, &rows))
}

/// Figure 5/7 analog: EVP curves per (task, method) from the score pools.
pub fn evp_report(id: &str, results: &QualityResults, max_budget: usize) -> Result<String> {
    let mut out = String::new();
    let mut json = Json::obj();
    for (task, per_method) in results {
        let mut jt = Json::obj();
        for (method, (_, _, _, scores)) in per_method {
            if scores.len() < 2 {
                continue;
            }
            let curve = evp::evp_curve(scores, max_budget.min(scores.len() * 4));
            let tail = curve.last().map(|&(_, v)| v).unwrap_or(0.0);
            out.push_str(&format!(
                "{task}/{method}: EVP(1)={:.3} EVP({})={:.3}\n",
                curve[0].1,
                curve.len(),
                tail
            ));
            jt.set(
                method,
                Json::Arr(curve.into_iter().map(|(_, v)| Json::Num(v)).collect()),
            );
        }
        json.set(task, jt);
    }
    super::write_result(id, &json)?;
    Ok(out)
}

/// Figure 2/4/6 analog: score vs hyperparameter (rank/prefix) per method,
/// read out of the per-assignment labels.
pub fn sweep_report(id: &str, results: &QualityResults) -> Result<String> {
    // group scores by assignment label across tasks
    let mut per_label: BTreeMap<String, Vec<f64>> = BTreeMap::new();
    for per_method in results.values() {
        for (method, (label, median, _, _)) in per_method {
            per_label
                .entry(format!("{method}:{label}"))
                .or_default()
                .push(*median);
        }
    }
    let mut rows = Vec::new();
    let mut json = Json::obj();
    for (label, scores) in &per_label {
        rows.push(vec![label.clone(), format!("{:.3}", stats::mean(scores))]);
        json.set(label, Json::Num(stats::mean(scores)));
    }
    super::write_result(id, &json)?;
    Ok(crate::bench::render_table(&["assignment", "mean best score"], &rows))
}
