//! Experiment drivers — one per paper table/figure (DESIGN.md §7).
//!
//! `aotpt exp <id> [--scale smoke|quick|full]` runs one; results are
//! printed as tables and written to `results/<id>.json`.

pub mod norms;
pub mod quality;
pub mod speed;

use std::path::PathBuf;

use crate::json::{self, Json};
use crate::Result;

/// Where experiment outputs land.
pub fn results_dir() -> PathBuf {
    let dir = crate::repo_root().join("results");
    let _ = std::fs::create_dir_all(&dir);
    dir
}

pub fn write_result(id: &str, value: &Json) -> Result<()> {
    let path = results_dir().join(format!("{id}.json"));
    json::save(&path, value)?;
    crate::info!("wrote {}", path.display());
    Ok(())
}

/// Table 1: the method property matrix, straight from the live registry
/// (and cross-checked against the manifest's copy).
pub fn table1(manifest: &crate::config::Manifest) -> Result<String> {
    let table = crate::peft::Method::table1();
    // Cross-check vs manifest (authored independently in python).
    for m in crate::peft::Method::ALL {
        if let Some(&(pe, zc, mt)) = manifest.method_properties.get(m.name()) {
            anyhow::ensure!(
                (pe, zc, mt) == (m.parameter_efficient(), m.zero_cost(), m.multi_task()),
                "manifest/registry disagree on {}",
                m.name()
            );
        }
    }
    let mut json = Json::obj();
    for m in crate::peft::Method::ALL {
        json.set(
            m.name(),
            Json::from_pairs(vec![
                ("parameter_efficient", Json::Bool(m.parameter_efficient())),
                ("zero_cost", Json::Bool(m.zero_cost())),
                ("multi_task", Json::Bool(m.multi_task())),
            ]),
        );
    }
    write_result("table1", &json)?;
    Ok(table)
}

#[cfg(test)]
mod tests {
    #[test]
    fn results_dir_exists() {
        assert!(super::results_dir().is_dir());
    }
}
