//! Appendix Tables 7–10 (paper §4.3): train FC AoT P-Tuning on WSC, COPA,
//! CB and RTE, fuse `P`, and list the tokens with the largest per-layer
//! row norms.  Our synthetic tasks make this quantitative: the generators'
//! cue tokens are known, so we also report cue recall among the top rows.

use std::sync::Arc;

use crate::analyze;
use crate::config::Manifest;
use crate::data::{self, Lexicon};
use crate::json::Json;
use crate::peft::fuse;
use crate::runtime::{Runtime, WeightCache};
use crate::train::{grid, TrainConfig, Trainer};
use crate::Result;

pub const TASKS: [&str; 4] = ["wsc", "copa", "cb", "rte"];

pub struct NormResult {
    pub task: String,
    pub table: String,
    pub cue_recall: f64,
    pub best_metric: f64,
}

pub fn run(
    runtime: &Arc<Runtime>,
    manifest: &Manifest,
    model: &str,
    quick: bool,
) -> Result<Vec<NormResult>> {
    let lex = Lexicon::generate(0);
    let weights = Arc::new(WeightCache::from_ckpt(
        runtime,
        &manifest.dir.join(format!("backbone_{model}.aotckpt")),
    )?);
    let emb = weights.host("emb_tok")?.clone();
    let mut out = Vec::new();
    let mut json = Json::obj();

    for task_name in TASKS {
        let classes = data::tasks::task_classes(task_name);
        let (n_train, steps) = if quick { (384, 192) } else { (1024, 0) };
        let task = data::make_task(&lex, task_name, 77, n_train, 192, 64)?;
        let assignments = grid::assignments_for(manifest, model, "aot-fc", classes, &[5e-3]);
        let Some(a) = assignments.first() else {
            anyhow::bail!("no aot-fc artifacts for {model} classes={classes}");
        };
        let trainer = Trainer::new(runtime, manifest, Arc::clone(&weights), &a.train_stem, &a.eval_stem)?;
        let result = trainer.run(
            &task,
            &TrainConfig { lr: a.lr, seed: 0, max_epochs: 8, patience: 3, max_steps: steps },
        )?;
        // Fuse the best state into a dense table (Equation 3).
        let p = fuse::fuse_fc(&emb, &result.best_state)?;
        let layers: Vec<usize> = (0..p.layers).collect();
        let table = analyze::norm_table(&p, &lex, &layers, 12);
        // cue recall averaged over layers
        let recall: f64 = layers
            .iter()
            .map(|&l| analyze::cue_recall_at(&p, l, 25, &task.cue_tokens))
            .sum::<f64>()
            / layers.len() as f64;
        crate::info!(
            "{task_name}: metric {:.3}, cue recall@25 {:.2}",
            result.best_metric,
            recall
        );
        json.set(
            task_name,
            Json::from_pairs(vec![
                ("metric", Json::Num(result.best_metric)),
                ("cue_recall_at25", Json::Num(recall)),
            ]),
        );
        out.push(NormResult {
            task: task_name.to_string(),
            table,
            cue_recall: recall,
            best_metric: result.best_metric,
        });
    }
    super::write_result("norms", &json)?;
    Ok(out)
}
