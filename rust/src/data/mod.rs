//! Synthetic GLUE/SuperGLUE-analog benchmark suite (DESIGN.md §2).
//!
//! Real GLUE/SuperGLUE are not downloadable offline, so each task is
//! replaced by a generator with the same *decision structure*: sentence
//! classification driven by token-identity cues (SST-2/CoLA analogs),
//! sentence-pair reasoning (MRPC/QQP/MNLI/RTE/QNLI analogs), and the
//! SuperGLUE tasks whose §4.3 analysis the paper reports (WSC's
//! pronoun/name cues, COPA's verb cues, WiC's sense clusters).  Because
//! the generators' cue tokens are *known*, the Appendix 7–10 row-norm
//! analysis becomes a sharp check instead of a qualitative one.
//!
//! Every generator draws from one shared `Lexicon` so a single backbone
//! vocabulary serves all tasks (multi-task serving needs this, §3.1).
//! Labels carry 3% symmetric noise to keep ceilings below 100%.

pub mod lexicon;
pub mod tasks;

pub use lexicon::Lexicon;
pub use tasks::{make_task, Example, Metric, TaskData, GLUE_TASKS, SUPERGLUE_TASKS};

use crate::util::Pcg64;

/// Sample an MLM pre-training corpus: sentences of filler/content words.
/// Returns token-id sentences (no CLS/SEP; the pretrain driver packs them).
pub fn corpus(lex: &Lexicon, seed: u64, n_sentences: usize, max_len: usize) -> Vec<Vec<i32>> {
    let mut rng = Pcg64::new(seed).fold(0xC0FFEE);
    (0..n_sentences)
        .map(|_| {
            let len = rng.range(5, max_len as i64) as usize;
            (0..len).map(|_| lex.any_word(&mut rng)).collect()
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn corpus_tokens_in_vocab() {
        let lex = Lexicon::generate(1);
        let c = corpus(&lex, 2, 50, 30);
        assert_eq!(c.len(), 50);
        for sent in &c {
            assert!(!sent.is_empty());
            for &t in sent {
                assert!((t as usize) < lex.vocab_size(), "{t}");
            }
        }
    }
}
