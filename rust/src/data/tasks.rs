//! The fifteen task generators (8 GLUE analogs + 7 SuperGLUE analogs,
//! RTE appearing in both, matching the paper's evaluation inventory) and
//! the per-task metrics of Appendix Table 3.

use crate::tokenizer::pack_pair;
use crate::util::{stats, Pcg64};
use crate::Result;

use super::lexicon::Lexicon;

pub const GLUE_TASKS: [&str; 8] =
    ["cola", "sst2", "mrpc", "stsb", "qqp", "mnli", "qnli", "rte"];
pub const SUPERGLUE_TASKS: [&str; 7] =
    ["boolq", "cb", "copa", "multirc", "rte", "wic", "wsc"];

const LABEL_NOISE: f64 = 0.03;

/// One classification example, already packed to `[CLS] … [SEP]` + padding.
#[derive(Clone, Debug)]
pub struct Example {
    pub ids: Vec<i32>,
    pub mask: Vec<f32>,
    pub label: f32,
}

/// Per-task metric (paper Appendix Table 3).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Metric {
    Accuracy,
    /// (Accuracy + F1) / 2
    AccF1,
    Matthews,
    /// (Pearson + Spearman) / 2 on the ordinal labels (STS-B analog).
    PearsonSpearman,
}

impl Metric {
    pub fn compute(self, pred: &[i64], gold: &[i64]) -> f64 {
        match self {
            Metric::Accuracy => stats::accuracy(pred, gold),
            Metric::AccF1 => {
                0.5 * (stats::accuracy(pred, gold) + stats::f1_macro(pred, gold))
            }
            Metric::Matthews => stats::matthews(pred, gold),
            Metric::PearsonSpearman => {
                let p: Vec<f64> = pred.iter().map(|&x| x as f64).collect();
                let g: Vec<f64> = gold.iter().map(|&x| x as f64).collect();
                0.5 * (stats::pearson(&p, &g) + stats::spearman(&p, &g))
            }
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            Metric::Accuracy => "accuracy",
            Metric::AccF1 => "(acc+f1)/2",
            Metric::Matthews => "matthews",
            Metric::PearsonSpearman => "(pearson+spearman)/2",
        }
    }
}

/// A generated task: train/dev splits + metadata.
pub struct TaskData {
    pub name: String,
    pub metric: Metric,
    pub classes: usize,
    pub train: Vec<Example>,
    pub dev: Vec<Example>,
    /// The cue-token ids that *define* the task (ground truth for the
    /// §4.3 row-norm analysis — trained P should weight exactly these).
    pub cue_tokens: Vec<i32>,
}

/// Task registry entry.
pub fn task_metric(name: &str) -> Metric {
    match name {
        "cola" => Metric::Matthews,
        "stsb" => Metric::PearsonSpearman,
        "mrpc" | "qqp" | "multirc" | "cb" => Metric::AccF1,
        _ => Metric::Accuracy,
    }
}

pub fn task_classes(name: &str) -> usize {
    match name {
        "mnli" | "cb" | "stsb" => 3,
        _ => 2,
    }
}

/// Generate a task's train + dev splits.
pub fn make_task(
    lex: &Lexicon,
    name: &str,
    seed: u64,
    n_train: usize,
    n_dev: usize,
    seq: usize,
) -> Result<TaskData> {
    let mut rng = Pcg64::new(seed).fold(hash_name(name));
    let gen = generator(name)?;
    let make_split = |n: usize, rng: &mut Pcg64| -> Vec<Example> {
        (0..n)
            .map(|_| {
                let (a, b, mut label) = gen(lex, rng);
                if rng.bool(LABEL_NOISE) {
                    label = (label + 1) % task_classes(name) as i64;
                }
                let (ids, mask) = pack_pair(&a, b.as_deref(), seq);
                Example { ids, mask, label: label as f32 }
            })
            .collect()
    };
    let train = make_split(n_train, &mut rng);
    let dev = make_split(n_dev, &mut rng);
    Ok(TaskData {
        name: name.to_string(),
        metric: task_metric(name),
        classes: task_classes(name),
        train,
        dev,
        cue_tokens: cue_tokens(lex, name),
    })
}

fn hash_name(name: &str) -> u64 {
    name.bytes().fold(1469598103934665603u64, |h, b| {
        (h ^ b as u64).wrapping_mul(1099511628211)
    })
}

type Gen = fn(&Lexicon, &mut Pcg64) -> (Vec<i32>, Option<Vec<i32>>, i64);

fn generator(name: &str) -> Result<Gen> {
    Ok(match name {
        "sst2" => gen_sst2,
        "cola" => gen_cola,
        "mrpc" => gen_paraphrase,
        "qqp" => gen_paraphrase,
        "stsb" => gen_stsb,
        "mnli" => gen_nli3,
        "cb" => gen_nli3,
        "qnli" => gen_qnli,
        "rte" => gen_rte,
        "boolq" => gen_boolq,
        "copa" => gen_copa,
        "multirc" => gen_multirc,
        "wic" => gen_wic,
        "wsc" => gen_wsc,
        other => anyhow::bail!("unknown task {other}"),
    })
}

/// The tokens whose P rows should grow for each task (§4.3 ground truth).
fn cue_tokens(lex: &Lexicon, name: &str) -> Vec<i32> {
    match name {
        "sst2" | "stsb" => [lex.pos.clone(), lex.neg.clone()].concat(),
        "cola" => lex.func.clone(),
        "mnli" | "cb" | "rte" => {
            let mut v = vec![lex.negation];
            v.extend_from_slice(&lex.name_m[..20]);
            v.extend_from_slice(&lex.name_f[..20]);
            v
        }
        "copa" => [lex.vcause.clone(), lex.veffect.clone()].concat(),
        "wic" => lex.sense_word.clone(),
        "wsc" => {
            let mut v = vec![lex.pron_m, lex.pron_f];
            v.extend_from_slice(&lex.name_m);
            v.extend_from_slice(&lex.name_f);
            v
        }
        _ => Vec::new(),
    }
}

// ---------------------------------------------------------------------------
// Generators.  Each returns (sentence_a, optional sentence_b, label).
// ---------------------------------------------------------------------------

fn sentence(lex: &Lexicon, rng: &mut Pcg64, len: usize) -> Vec<i32> {
    (0..len).map(|_| lex.filler(rng)).collect()
}

/// SST-2 analog: polarity from the majority of sentiment-cue words.
fn gen_sst2(lex: &Lexicon, rng: &mut Pcg64) -> (Vec<i32>, Option<Vec<i32>>, i64) {
    let label = rng.below(2) as i64;
    let len = rng.range(8, 16) as usize;
    let mut s = sentence(lex, rng, len);
    let n_cues = rng.range(2, 5) as usize;
    for _ in 0..n_cues {
        let cue = if label == 1 { *rng.choose(&lex.pos) } else { *rng.choose(&lex.neg) };
        let pos = rng.below(s.len() as u64) as usize;
        s.insert(pos, cue);
    }
    // one distractor of the opposite polarity, sometimes
    if rng.bool(0.3) {
        let cue = if label == 1 { *rng.choose(&lex.neg) } else { *rng.choose(&lex.pos) };
        let pos = rng.below(s.len() as u64) as usize;
        s.insert(pos, cue);
    }
    (s, None, label)
}

/// CoLA analog: "grammatical" = the template func-adj-noun-verb cycle;
/// unacceptable = a shuffled version (word-order sensitive; Matthews).
fn gen_cola(lex: &Lexicon, rng: &mut Pcg64) -> (Vec<i32>, Option<Vec<i32>>, i64) {
    let label = rng.below(2) as i64;
    let cycles = rng.range(2, 4) as usize;
    let mut s = Vec::new();
    for _ in 0..cycles {
        s.push(*rng.choose(&lex.func));
        s.push(*rng.choose(&lex.adj));
        s.push(*rng.choose(&lex.noun));
        s.push(*rng.choose(&lex.vcause));
    }
    if label == 0 {
        rng.shuffle(&mut s);
    }
    (s, None, label)
}

/// MRPC/QQP analog: paraphrase = same content nouns (some swapped within
/// cluster neighbors), non-paraphrase = fresh sentence.
fn gen_paraphrase(lex: &Lexicon, rng: &mut Pcg64) -> (Vec<i32>, Option<Vec<i32>>, i64) {
    let label = rng.below(2) as i64;
    let content: Vec<i32> = (0..4).map(|_| *rng.choose(&lex.noun)).collect();
    let mut s1 = sentence(lex, rng, 6);
    for &c in &content {
        let pos = rng.below(s1.len() as u64) as usize;
        s1.insert(pos, c);
    }
    let s2 = if label == 1 {
        let mut s2 = sentence(lex, rng, 6);
        for &c in &content {
            let pos = rng.below(s2.len() as u64) as usize;
            s2.insert(pos, c);
        }
        s2
    } else {
        let other: Vec<i32> = (0..4).map(|_| *rng.choose(&lex.noun)).collect();
        let mut s2 = sentence(lex, rng, 6);
        for &c in &other {
            let pos = rng.below(s2.len() as u64) as usize;
            s2.insert(pos, c);
        }
        s2
    };
    (s1, Some(s2), label)
}

/// STS-B analog: 3-bin ordinal similarity by shared-content count.
fn gen_stsb(lex: &Lexicon, rng: &mut Pcg64) -> (Vec<i32>, Option<Vec<i32>>, i64) {
    let label = rng.below(3) as i64; // 0 = unrelated, 1 = partial, 2 = same
    let shared = match label {
        0 => 0,
        1 => 2,
        _ => 4,
    };
    let content: Vec<i32> = (0..4).map(|_| *rng.choose(&lex.noun)).collect();
    let mut s1 = sentence(lex, rng, 5);
    for &c in &content {
        s1.insert(rng.below(s1.len() as u64) as usize, c);
    }
    let mut s2 = sentence(lex, rng, 5);
    for &c in content.iter().take(shared) {
        s2.insert(rng.below(s2.len() as u64) as usize, c);
    }
    for _ in shared..4 {
        s2.insert(rng.below(s2.len() as u64) as usize, *rng.choose(&lex.noun));
    }
    (s1, Some(s2), label)
}

/// MNLI/CB analog: 3-class NLI. Entail: hypothesis ⊂ premise content.
/// Contradict: hypothesis repeats premise content + negation marker.
/// Neutral: disjoint content.
fn gen_nli3(lex: &Lexicon, rng: &mut Pcg64) -> (Vec<i32>, Option<Vec<i32>>, i64) {
    let label = rng.below(3) as i64; // 0 entail, 1 neutral, 2 contradict
    let content: Vec<i32> = (0..4).map(|_| *rng.choose(&lex.noun)).collect();
    let name = if rng.bool(0.5) { *rng.choose(&lex.name_m) } else { *rng.choose(&lex.name_f) };
    let mut prem = sentence(lex, rng, 5);
    prem.insert(0, name);
    for &c in &content {
        prem.insert(rng.below(prem.len() as u64) as usize, c);
    }
    let mut hyp = sentence(lex, rng, 3);
    match label {
        0 => {
            hyp.insert(0, name);
            for &c in content.iter().take(2) {
                hyp.insert(rng.below(hyp.len() as u64) as usize, c);
            }
        }
        2 => {
            hyp.insert(0, name);
            hyp.insert(1, lex.negation);
            for &c in content.iter().take(2) {
                hyp.insert(rng.below(hyp.len() as u64) as usize, c);
            }
        }
        _ => {
            let other_name =
                if rng.bool(0.5) { *rng.choose(&lex.name_m) } else { *rng.choose(&lex.name_f) };
            hyp.insert(0, other_name);
            for _ in 0..2 {
                hyp.insert(rng.below(hyp.len() as u64) as usize, *rng.choose(&lex.noun));
            }
        }
    }
    (prem, Some(hyp), label)
}

/// RTE analog: binary NLI (entail vs not).
fn gen_rte(lex: &Lexicon, rng: &mut Pcg64) -> (Vec<i32>, Option<Vec<i32>>, i64) {
    let (p, h, l3) = gen_nli3(lex, rng);
    (p, h, if l3 == 0 { 1 } else { 0 })
}

/// QNLI analog: does the sentence contain the questioned noun?
fn gen_qnli(lex: &Lexicon, rng: &mut Pcg64) -> (Vec<i32>, Option<Vec<i32>>, i64) {
    let label = rng.below(2) as i64;
    let target = *rng.choose(&lex.noun);
    let q = vec![lex.q_word, target];
    let mut s = sentence(lex, rng, 10);
    if label == 1 {
        s.insert(rng.below(s.len() as u64) as usize, target);
    }
    (q, Some(s), label)
}

/// BoolQ analog: question about a noun; passage answers yes iff it pairs
/// the noun with a positive-cue word.
fn gen_boolq(lex: &Lexicon, rng: &mut Pcg64) -> (Vec<i32>, Option<Vec<i32>>, i64) {
    let label = rng.below(2) as i64;
    let target = *rng.choose(&lex.noun);
    let q = vec![lex.q_word, target];
    let mut passage = sentence(lex, rng, 14);
    let cue = if label == 1 { *rng.choose(&lex.pos) } else { *rng.choose(&lex.neg) };
    let at = rng.below(passage.len() as u64 - 1) as usize;
    passage.insert(at, target);
    passage.insert(at + 1, cue);
    (q, Some(passage), label)
}

/// COPA analog: verbs come in (cause, effect) pairs; the alternative is
/// plausible iff its effect verb matches the premise's cause verb.
fn gen_copa(lex: &Lexicon, rng: &mut Pcg64) -> (Vec<i32>, Option<Vec<i32>>, i64) {
    let label = rng.below(2) as i64;
    let k = rng.below(lex.vcause.len() as u64) as usize;
    let mut prem = sentence(lex, rng, 6);
    prem.insert(rng.below(prem.len() as u64) as usize, lex.vcause[k]);
    let effect = if label == 1 {
        lex.veffect[k]
    } else {
        let mut j = rng.below(lex.veffect.len() as u64) as usize;
        if j == k {
            j = (j + 1) % lex.veffect.len();
        }
        lex.veffect[j]
    };
    let mut alt = sentence(lex, rng, 5);
    alt.insert(rng.below(alt.len() as u64) as usize, effect);
    (prem, Some(alt), label)
}

/// MultiRC analog: (passage+question, answer) — answer correct iff its
/// noun occurs in the passage.
fn gen_multirc(lex: &Lexicon, rng: &mut Pcg64) -> (Vec<i32>, Option<Vec<i32>>, i64) {
    let label = rng.below(2) as i64;
    let facts: Vec<i32> = (0..5).map(|_| *rng.choose(&lex.noun)).collect();
    let mut passage = sentence(lex, rng, 12);
    for &f in &facts {
        passage.insert(rng.below(passage.len() as u64) as usize, f);
    }
    passage.push(lex.q_word);
    let ans = if label == 1 {
        *rng.choose(&facts)
    } else {
        *rng.choose(&lex.noun)
    };
    (passage, Some(vec![ans]), label)
}

/// WiC analog: the polysemous word appears in two contexts; same sense iff
/// both contexts draw from the same sense cluster.
fn gen_wic(lex: &Lexicon, rng: &mut Pcg64) -> (Vec<i32>, Option<Vec<i32>>, i64) {
    let label = rng.below(2) as i64;
    let w = rng.below(lex.sense_word.len() as u64) as usize;
    let word = lex.sense_word[w];
    let sense1 = rng.below(2) as usize;
    let sense2 = if label == 1 { sense1 } else { 1 - sense1 };
    let ctx = |sense: usize, rng: &mut Pcg64| -> Vec<i32> {
        let cluster = if sense == 0 { &lex.sense_ctx_a[w] } else { &lex.sense_ctx_b[w] };
        let mut s = sentence(lex, rng, 5);
        s.insert(rng.below(s.len() as u64) as usize, word);
        for _ in 0..2 {
            s.insert(rng.below(s.len() as u64) as usize, *rng.choose(cluster));
        }
        s
    };
    let s1 = ctx(sense1, rng);
    let s2 = ctx(sense2, rng);
    (s1, Some(s2), label)
}

/// WSC analog: pronoun resolution by gender-cluster agreement: label 1 iff
/// the pronoun's gender matches the *first* name in the sentence.
fn gen_wsc(lex: &Lexicon, rng: &mut Pcg64) -> (Vec<i32>, Option<Vec<i32>>, i64) {
    let label = rng.below(2) as i64;
    let first_is_m = rng.bool(0.5);
    let (first, second) = if first_is_m {
        (*rng.choose(&lex.name_m), *rng.choose(&lex.name_f))
    } else {
        (*rng.choose(&lex.name_f), *rng.choose(&lex.name_m))
    };
    let pron_matches_first = label == 1;
    let pron = match (first_is_m, pron_matches_first) {
        (true, true) | (false, false) => lex.pron_m,
        _ => lex.pron_f,
    };
    let mut s = vec![first];
    s.extend(sentence(lex, rng, 3));
    s.push(second);
    s.extend(sentence(lex, rng, 2));
    s.push(pron);
    s.extend(sentence(lex, rng, 2));
    (s, None, label)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn lex() -> Lexicon {
        Lexicon::generate(0)
    }

    #[test]
    fn all_tasks_generate() {
        let lex = lex();
        for name in GLUE_TASKS.iter().chain(SUPERGLUE_TASKS.iter()) {
            let t = make_task(&lex, name, 1, 40, 10, 64).unwrap();
            assert_eq!(t.train.len(), 40, "{name}");
            assert_eq!(t.dev.len(), 10, "{name}");
            for ex in t.train.iter().chain(&t.dev) {
                assert_eq!(ex.ids.len(), 64, "{name}");
                assert_eq!(ex.mask.len(), 64, "{name}");
                assert!((ex.label as usize) < t.classes, "{name}: label {}", ex.label);
                for &id in &ex.ids {
                    assert!((id as usize) < lex.vocab_size(), "{name}: id {id}");
                }
            }
        }
    }

    #[test]
    fn labels_roughly_balanced() {
        let lex = lex();
        for name in ["sst2", "rte", "wic", "wsc", "copa", "boolq"] {
            let t = make_task(&lex, name, 2, 400, 0, 64).unwrap();
            let ones = t.train.iter().filter(|e| e.label == 1.0).count();
            assert!(
                (120..280).contains(&ones),
                "{name}: {ones}/400 positive"
            );
        }
    }

    #[test]
    fn generation_is_deterministic() {
        let lex = lex();
        let a = make_task(&lex, "sst2", 7, 20, 5, 32).unwrap();
        let b = make_task(&lex, "sst2", 7, 20, 5, 32).unwrap();
        for (x, y) in a.train.iter().zip(&b.train) {
            assert_eq!(x.ids, y.ids);
            assert_eq!(x.label, y.label);
        }
        let c = make_task(&lex, "sst2", 8, 20, 5, 32).unwrap();
        assert!(a.train.iter().zip(&c.train).any(|(x, y)| x.ids != y.ids));
    }

    #[test]
    fn sst2_cues_predict_labels() {
        // A trivial cue-counting classifier must get >90% on sst2 — the
        // task is learnable from token identity alone (AoT's regime).
        let lex = lex();
        let t = make_task(&lex, "sst2", 3, 500, 0, 64).unwrap();
        let mut correct = 0;
        for ex in &t.train {
            let pos = ex.ids.iter().filter(|i| lex.pos.contains(i)).count();
            let neg = ex.ids.iter().filter(|i| lex.neg.contains(i)).count();
            let pred = if pos > neg { 1.0 } else { 0.0 };
            if pred == ex.label {
                correct += 1;
            }
        }
        assert!(correct > 450, "cue classifier got {correct}/500");
    }

    #[test]
    fn metrics_dispatch() {
        assert_eq!(task_metric("cola"), Metric::Matthews);
        assert_eq!(task_metric("stsb"), Metric::PearsonSpearman);
        assert_eq!(task_metric("mrpc"), Metric::AccF1);
        assert_eq!(task_metric("rte"), Metric::Accuracy);
        assert_eq!(task_classes("mnli"), 3);
        assert_eq!(task_classes("wsc"), 2);
    }

    #[test]
    fn cue_tokens_nonempty_for_analysis_tasks() {
        let lex = lex();
        for name in ["wsc", "copa", "rte", "cb", "wic", "sst2"] {
            assert!(!cue_tokens(&lex, name).is_empty(), "{name}");
        }
    }
}
