//! The shared synthetic lexicon: clustered word classes whose ids drive
//! every task generator.  Word strings are interpretable (`pos17`,
//! `name_f3`, `vcause8`) so the §4.3 analysis tables read like the
//! paper's.

use crate::tokenizer::{WordVocab, N_SPECIAL};
use crate::util::Pcg64;

pub const N_POS: usize = 150; // sentiment-positive cues
pub const N_NEG: usize = 150; // sentiment-negative cues
pub const N_NAME_M: usize = 100; // "male" entity names (WSC analog)
pub const N_NAME_F: usize = 100; // "female" entity names
pub const N_VERB_PAIRS: usize = 100; // (cause, effect) verb pairs (COPA)
pub const N_NOUN: usize = 4000;
pub const N_ADJ: usize = 300;
pub const N_ADV: usize = 200;
pub const N_FUNC: usize = 60;
pub const N_SENSE: usize = 120; // polysemous words (WiC analog)
pub const SENSE_CTX: usize = 8; // context-cluster size per sense

pub struct Lexicon {
    vocab: WordVocab,
    pub pos: Vec<i32>,
    pub neg: Vec<i32>,
    pub name_m: Vec<i32>,
    pub name_f: Vec<i32>,
    pub vcause: Vec<i32>,
    pub veffect: Vec<i32>,
    pub noun: Vec<i32>,
    pub adj: Vec<i32>,
    pub adv: Vec<i32>,
    pub func: Vec<i32>,
    /// Polysemous words + their two sense-context clusters (noun ids).
    pub sense_word: Vec<i32>,
    pub sense_ctx_a: Vec<Vec<i32>>,
    pub sense_ctx_b: Vec<Vec<i32>>,
    /// Pronouns (function-word ids): he / she.
    pub pron_m: i32,
    pub pron_f: i32,
    /// Negation marker (MNLI/RTE contradiction cue).
    pub negation: i32,
    /// Question marker words.
    pub q_word: i32,
}

impl Lexicon {
    /// Deterministic lexicon for a seed (seed only affects the WiC sense
    /// context assignment; the word inventory itself is fixed).
    pub fn generate(seed: u64) -> Lexicon {
        let mut words: Vec<String> = Vec::new();
        let push_block = |prefix: &str, n: usize, words: &mut Vec<String>| -> Vec<usize> {
            let start = words.len();
            for i in 0..n {
                words.push(format!("{prefix}{i}"));
            }
            (start..start + n).collect()
        };

        let pos_ix = push_block("pos", N_POS, &mut words);
        let neg_ix = push_block("neg", N_NEG, &mut words);
        let name_m_ix = push_block("name_m", N_NAME_M, &mut words);
        let name_f_ix = push_block("name_f", N_NAME_F, &mut words);
        let vcause_ix = push_block("vcause", N_VERB_PAIRS, &mut words);
        let veffect_ix = push_block("veffect", N_VERB_PAIRS, &mut words);
        let noun_ix = push_block("noun", N_NOUN, &mut words);
        let adj_ix = push_block("adj", N_ADJ, &mut words);
        let adv_ix = push_block("adv", N_ADV, &mut words);
        let func_ix = push_block("func", N_FUNC, &mut words);
        let sense_ix = push_block("sense", N_SENSE, &mut words);
        // Dedicated pronouns / markers.
        let special_start = words.len();
        words.push("he".into());
        words.push("she".into());
        words.push("not".into());
        words.push("which".into());

        let vocab = WordVocab::new(words, 8192).expect("lexicon fits vocab");
        let to_ids = |ix: Vec<usize>| -> Vec<i32> {
            ix.into_iter().map(|i| (i + N_SPECIAL) as i32).collect()
        };

        let noun = to_ids(noun_ix);
        let mut rng = Pcg64::new(seed).fold(0x5EED);
        // Assign each polysemous word two disjoint noun context clusters.
        let mut sense_ctx_a = Vec::with_capacity(N_SENSE);
        let mut sense_ctx_b = Vec::with_capacity(N_SENSE);
        for _ in 0..N_SENSE {
            let perm = rng.permutation(noun.len());
            sense_ctx_a.push(perm[..SENSE_CTX].iter().map(|&i| noun[i]).collect());
            sense_ctx_b.push(perm[SENSE_CTX..2 * SENSE_CTX].iter().map(|&i| noun[i]).collect());
        }

        Lexicon {
            pos: to_ids(pos_ix),
            neg: to_ids(neg_ix),
            name_m: to_ids(name_m_ix),
            name_f: to_ids(name_f_ix),
            vcause: to_ids(vcause_ix),
            veffect: to_ids(veffect_ix),
            noun,
            adj: to_ids(adj_ix),
            adv: to_ids(adv_ix),
            func: to_ids(func_ix),
            sense_word: to_ids(sense_ix),
            sense_ctx_a,
            sense_ctx_b,
            pron_m: (special_start + N_SPECIAL) as i32,
            pron_f: (special_start + N_SPECIAL + 1) as i32,
            negation: (special_start + N_SPECIAL + 2) as i32,
            q_word: (special_start + N_SPECIAL + 3) as i32,
            vocab,
        }
    }

    pub fn vocab_size(&self) -> usize {
        self.vocab.len()
    }

    pub fn word(&self, id: i32) -> &str {
        self.vocab.word(id).unwrap_or("[?]")
    }

    /// A filler word (function/noun/adj mixture) for sentence padding.
    pub fn filler(&self, rng: &mut Pcg64) -> i32 {
        match rng.below(10) {
            0..=3 => *rng.choose(&self.func),
            4..=7 => *rng.choose(&self.noun),
            _ => *rng.choose(&self.adj),
        }
    }

    /// Any non-special word (MLM corpus sampling).
    pub fn any_word(&self, rng: &mut Pcg64) -> i32 {
        match rng.below(12) {
            0 => *rng.choose(&self.pos),
            1 => *rng.choose(&self.neg),
            2 => *rng.choose(&self.name_m),
            3 => *rng.choose(&self.name_f),
            4 => *rng.choose(&self.vcause),
            5 => *rng.choose(&self.veffect),
            6..=8 => *rng.choose(&self.noun),
            9 => *rng.choose(&self.adj),
            10 => *rng.choose(&self.adv),
            _ => *rng.choose(&self.func),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lexicon_fits_vocab_and_is_disjoint() {
        let lex = Lexicon::generate(0);
        assert!(lex.vocab_size() <= 8192);
        // Clusters must not overlap.
        let mut all: Vec<i32> = Vec::new();
        for block in [&lex.pos, &lex.neg, &lex.name_m, &lex.name_f, &lex.vcause,
                      &lex.veffect, &lex.noun, &lex.adj, &lex.adv, &lex.func,
                      &lex.sense_word] {
            all.extend_from_slice(block);
        }
        all.extend_from_slice(&[lex.pron_m, lex.pron_f, lex.negation, lex.q_word]);
        let n = all.len();
        all.sort_unstable();
        all.dedup();
        assert_eq!(all.len(), n, "clusters overlap");
    }

    #[test]
    fn word_strings_are_interpretable() {
        let lex = Lexicon::generate(0);
        assert_eq!(lex.word(lex.pos[3]), "pos3");
        assert_eq!(lex.word(lex.name_f[0]), "name_f0");
        assert_eq!(lex.word(lex.pron_m), "he");
        assert_eq!(lex.word(lex.negation), "not");
    }

    #[test]
    fn sense_clusters_are_disjoint_per_word() {
        let lex = Lexicon::generate(7);
        for i in 0..N_SENSE {
            for a in &lex.sense_ctx_a[i] {
                assert!(!lex.sense_ctx_b[i].contains(a));
            }
        }
    }

    #[test]
    fn lexicon_is_deterministic() {
        let a = Lexicon::generate(5);
        let b = Lexicon::generate(5);
        assert_eq!(a.sense_ctx_a, b.sense_ctx_a);
    }
}
