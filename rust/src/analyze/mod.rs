//! Trained-weight analysis (paper §4.3, Appendix Tables 7–10): rank the
//! vocabulary rows of a trained/fused `P` by L2 norm per layer and print
//! the corresponding token strings.
//!
//! Because our tasks are synthetic with *known* cue tokens
//! (`data::tasks::TaskData::cue_tokens`), the analysis here is sharper
//! than the paper's qualitative reading: `cue_recall_at` measures how
//! many of the top-norm rows are genuine task cues.

use crate::data::Lexicon;
use crate::peft::TaskP;

/// Top-k (token id, norm) rows at one layer.
pub fn top_rows(p: &TaskP, layer: usize, k: usize) -> Vec<(usize, f32)> {
    let norms = p.row_norms(layer);
    let mut idx: Vec<usize> = (0..norms.len()).collect();
    idx.sort_by(|&a, &b| norms[b].partial_cmp(&norms[a]).unwrap());
    idx.into_iter().take(k).map(|i| (i, norms[i])).collect()
}

/// Fraction of the top-k rows (at `layer`) that are task cue tokens.
pub fn cue_recall_at(p: &TaskP, layer: usize, k: usize, cues: &[i32]) -> f64 {
    if k == 0 {
        return 0.0;
    }
    let top = top_rows(p, layer, k);
    let hits = top.iter().filter(|(i, _)| cues.contains(&(*i as i32))).count();
    hits as f64 / k as f64
}

/// Render one Appendix-7-style table: per layer, the top-norm tokens.
pub fn norm_table(p: &TaskP, lex: &Lexicon, layers: &[usize], k: usize) -> String {
    let mut out = String::from("| layer | tokens x with largest ||P_x||_2 |\n|---|---|\n");
    for &layer in layers {
        let tokens: Vec<String> = top_rows(p, layer, k)
            .into_iter()
            .map(|(i, _)| lex.word(i as i32).to_string())
            .collect();
        out.push_str(&format!("| {layer} | {} |\n", tokens.join(", ")));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn top_rows_sorted_desc() {
        let mut data = vec![0f32; 2 * 10 * 4];
        // layer 0: token 3 heavy, token 7 medium
        for x in &mut data[3 * 4..4 * 4] {
            *x = 5.0;
        }
        for x in &mut data[7 * 4..8 * 4] {
            *x = 2.0;
        }
        let p = TaskP::new(2, 10, 4, data).unwrap();
        let top = top_rows(&p, 0, 3);
        assert_eq!(top[0].0, 3);
        assert_eq!(top[1].0, 7);
        assert!(top[0].1 > top[1].1);
    }

    #[test]
    fn cue_recall_counts_hits() {
        let mut data = vec![0f32; 10 * 4];
        for tok in [2usize, 5, 8] {
            for x in &mut data[tok * 4..(tok + 1) * 4] {
                *x = 1.0 + tok as f32;
            }
        }
        let p = TaskP::new(1, 10, 4, data).unwrap();
        let recall = cue_recall_at(&p, 0, 3, &[8, 5, 1]);
        assert!((recall - 2.0 / 3.0).abs() < 1e-9);
    }

    #[test]
    fn norm_table_uses_lexicon_strings() {
        let lex = Lexicon::generate(0);
        let v = lex.vocab_size();
        let mut data = vec![0f32; v * 4];
        let tok = lex.pos[0] as usize;
        for x in &mut data[tok * 4..(tok + 1) * 4] {
            *x = 9.0;
        }
        let p = TaskP::new(1, v, 4, data).unwrap();
        let table = norm_table(&p, &lex, &[0], 2);
        assert!(table.contains("pos0"), "{table}");
    }
}
