//! Training state: trainable tensors + Adam moments, materialized from the
//! manifest's init specs with a seed (the paper's zero-init conventions
//! live in those specs — see `python/compile/peft.py`).

use std::collections::BTreeMap;

use anyhow::{anyhow, bail};

use crate::config::{ArtifactSpec, InitKind};
use crate::runtime::WeightCache;
use crate::tensor::{DType, Tensor};
use crate::util::Pcg64;
use crate::Result;

pub struct TrainState {
    tensors: BTreeMap<String, Option<Tensor>>,
    pub step: i32,
    pub last_loss: f32,
}

impl TrainState {
    /// Materialize fresh state for `seed`.
    pub fn init(spec: &ArtifactSpec, weights: &WeightCache, seed: u64) -> Result<TrainState> {
        if spec.init.is_empty() {
            bail!("{}: artifact carries no init specs", spec.stem);
        }
        let mut rng = Pcg64::new(seed).fold(0x1217);
        let mut tensors = BTreeMap::new();
        for entry in &spec.init {
            let numel: usize = entry.shape.iter().product();
            let t = match entry.kind {
                InitKind::Zeros => Tensor::zeros(DType::F32, &entry.shape),
                InitKind::Normal => {
                    Tensor::from_f32(&entry.shape, rng.normal_vec(numel, entry.std))
                }
                InitKind::Backbone => {
                    // fine-tune: start from the backbone copy (`ft.<name>`).
                    let src = entry
                        .name
                        .strip_prefix("ft.")
                        .ok_or_else(|| anyhow!("backbone init on non-ft tensor {}", entry.name))?;
                    let w = weights.host(src)?;
                    w.check_shape(&entry.shape)?;
                    w.clone()
                }
            };
            tensors.insert(format!("t.{}", entry.name), Some(t));
            tensors.insert(
                format!("m.{}", entry.name),
                Some(Tensor::zeros(DType::F32, &entry.shape)),
            );
            tensors.insert(
                format!("v.{}", entry.name),
                Some(Tensor::zeros(DType::F32, &entry.shape)),
            );
        }
        Ok(TrainState { tensors, step: 0, last_loss: f32::NAN })
    }

    /// Move a tensor out (feeding the executable without a copy).
    pub fn take(&mut self, name: &str) -> Result<Tensor> {
        self.tensors
            .get_mut(name)
            .ok_or_else(|| anyhow!("train state has no tensor {name}"))?
            .take()
            .ok_or_else(|| anyhow!("tensor {name} already taken this call"))
    }

    /// Borrow a tensor (eval path).
    pub fn peek(&self, name: &str) -> Result<&Tensor> {
        self.tensors
            .get(name)
            .and_then(|o| o.as_ref())
            .ok_or_else(|| anyhow!("train state has no tensor {name}"))
    }

    /// Absorb a train call's outputs back into the state.
    pub fn absorb(&mut self, spec: &ArtifactSpec, outs: Vec<Tensor>) -> Result<()> {
        if outs.len() != spec.outputs.len() {
            bail!("absorb: {} outputs, expected {}", outs.len(), spec.outputs.len());
        }
        for (name, value) in spec.outputs.iter().zip(outs) {
            match name.as_str() {
                "step" => self.step = value.as_i32()?[0],
                "loss" => self.last_loss = value.as_f32()?[0],
                _ => {
                    let slot = self
                        .tensors
                        .get_mut(name)
                        .ok_or_else(|| anyhow!("absorb: unknown output {name}"))?;
                    *slot = Some(value);
                }
            }
        }
        Ok(())
    }

    /// Copy of the current trainable tensors (`t.*` only).
    pub fn trainable_map(&self, spec: &ArtifactSpec) -> BTreeMap<String, Tensor> {
        let mut out = BTreeMap::new();
        for name in &spec.trainable_order {
            let key = format!("t.{name}");
            if let Some(Some(t)) = self.tensors.get(&key) {
                out.insert(key, t.clone());
            }
        }
        out
    }

    /// Replace trainable tensors (e.g. to resume from a best checkpoint).
    pub fn load_trainable(&mut self, map: &BTreeMap<String, Tensor>) -> Result<()> {
        for (k, v) in map {
            let slot = self
                .tensors
                .get_mut(k)
                .ok_or_else(|| anyhow!("load_trainable: unknown tensor {k}"))?;
            *slot = Some(v.clone());
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{InitSpec, TensorSpec};

    fn fake_spec() -> ArtifactSpec {
        ArtifactSpec {
            stem: "test".into(),
            file: "/dev/null".into(),
            kind: "train".into(),
            model: "tiny".into(),
            method: "aot-fc".into(),
            batch: 2,
            seq: 4,
            rank: 2,
            prefix: 0,
            classes: 2,
            steps_per_call: 1,
            inputs: vec![TensorSpec {
                name: "t.fc.w1".into(),
                shape: vec![2, 3],
                dtype: DType::F32,
            }],
            outputs: vec!["t.fc.w1".into(), "step".into(), "loss".into()],
            trainable_order: vec!["fc.w1".into()],
            init: vec![
                InitSpec { name: "fc.w1".into(), shape: vec![2, 3], kind: InitKind::Normal, std: 0.02 },
            ],
        }
    }

    fn weights() -> WeightCache {
        // No backbone needed for these specs; build an empty cache.
        let rt = crate::runtime::Runtime::new().unwrap();
        WeightCache::from_tensors(&rt, BTreeMap::new()).unwrap()
    }

    #[test]
    fn init_is_seed_deterministic() {
        let spec = fake_spec();
        let w = weights();
        let a = TrainState::init(&spec, &w, 5).unwrap();
        let b = TrainState::init(&spec, &w, 5).unwrap();
        let c = TrainState::init(&spec, &w, 6).unwrap();
        assert_eq!(
            a.peek("t.fc.w1").unwrap().as_f32().unwrap(),
            b.peek("t.fc.w1").unwrap().as_f32().unwrap()
        );
        assert_ne!(
            a.peek("t.fc.w1").unwrap().as_f32().unwrap(),
            c.peek("t.fc.w1").unwrap().as_f32().unwrap()
        );
        // moments start at zero
        assert!(a.peek("m.fc.w1").unwrap().as_f32().unwrap().iter().all(|&x| x == 0.0));
    }

    #[test]
    fn take_absorb_cycle() {
        let spec = fake_spec();
        let w = weights();
        let mut s = TrainState::init(&spec, &w, 1).unwrap();
        let t = s.take("t.fc.w1").unwrap();
        assert!(s.take("t.fc.w1").is_err(), "double take must fail");
        let outs = vec![t, Tensor::scalar_i32(1), Tensor::scalar_f32(0.5)];
        s.absorb(&spec, outs).unwrap();
        assert_eq!(s.step, 1);
        assert_eq!(s.last_loss, 0.5);
        assert!(s.peek("t.fc.w1").is_ok());
    }
}
