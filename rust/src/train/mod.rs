//! The training driver: runs the paper's experimental protocol (§4.1) by
//! executing AOT train-step computations from Rust.
//!
//! * one XLA call = `steps_per_call` scanned Adam steps (host round-trips
//!   amortized — this xla-crate build cannot donate buffers);
//! * constant learning rate, patience-based early stopping on the dev
//!   metric (paper Appendix Table 6);
//! * trainable parameters are materialized from the manifest's init specs
//!   with the run's seed — Python is not involved in seed sweeps.

pub mod evp;
pub mod grid;
pub mod state;

use std::collections::BTreeMap;
use std::sync::Arc;

use anyhow::bail;

use crate::config::Manifest;
use crate::data::TaskData;
use crate::runtime::{Executable, Runtime, WeightCache};
use crate::tensor::Tensor;
use crate::Result;

pub use grid::{GridResult, GridSearch, RunResult};
pub use state::TrainState;

/// Hyperparameters of one training run.
#[derive(Clone, Debug)]
pub struct TrainConfig {
    pub lr: f32,
    pub seed: u64,
    pub max_epochs: usize,
    pub patience: usize,
    /// Cap on optimizer steps (0 = unlimited); keeps smoke runs fast.
    pub max_steps: usize,
}

impl Default for TrainConfig {
    fn default() -> Self {
        TrainConfig { lr: 1e-3, seed: 0, max_epochs: 20, patience: 5, max_steps: 0 }
    }
}

/// Outcome of one run.
pub struct TrainResult {
    pub best_metric: f64,
    pub best_epoch: usize,
    pub epochs_run: usize,
    pub steps_run: usize,
    /// Mean loss per train call, in order (the e2e loss curve).
    pub losses: Vec<f32>,
    /// Trainable tensors at the best dev epoch, keyed `t.<name>`.
    pub best_state: BTreeMap<String, Tensor>,
}

/// Drives one (model, method, hp) pair over one task.
pub struct Trainer {
    train_exe: Arc<Executable>,
    eval_exe: Arc<Executable>,
    weights: Arc<WeightCache>,
}

impl Trainer {
    pub fn new(
        runtime: &Arc<Runtime>,
        manifest: &Manifest,
        weights: Arc<WeightCache>,
        train_stem: &str,
        eval_stem: &str,
    ) -> Result<Trainer> {
        let train_exe = runtime.load(manifest, train_stem)?;
        let eval_exe = runtime.load(manifest, eval_stem)?;
        if train_exe.spec.trainable_order.is_empty() {
            bail!("{train_stem} is not a training artifact");
        }
        Ok(Trainer { train_exe, eval_exe, weights })
    }

    pub fn spec(&self) -> &crate::config::ArtifactSpec {
        &self.train_exe.spec
    }

    /// Run the full protocol on one task.
    pub fn run(&self, task: &TaskData, cfg: &TrainConfig) -> Result<TrainResult> {
        let spec = &self.train_exe.spec;
        let (k, b, n) = (spec.steps_per_call, spec.batch, spec.seq);
        if task.train.is_empty() || task.dev.is_empty() {
            bail!("task {} has empty splits", task.name);
        }
        if task.train[0].ids.len() != n {
            bail!(
                "task {} packs to seq {}, artifact expects {}",
                task.name,
                task.train[0].ids.len(),
                n
            );
        }

        let mut state = TrainState::init(spec, &self.weights, cfg.seed)?;
        let mut rng = crate::util::Pcg64::new(cfg.seed).fold(0x7EA1);

        let mut best_metric = f64::NEG_INFINITY;
        let mut best_epoch = 0;
        let mut best_state = state.trainable_map(spec);
        let mut losses = Vec::new();
        let mut epochs_run = 0;
        let mut steps_run = 0;
        let mut since_best = 0;

        'outer: for epoch in 0..cfg.max_epochs {
            epochs_run = epoch + 1;
            let order = rng.permutation(task.train.len());
            // Pack the epoch into K-step super-batches of b examples.
            let mut cursor = 0;
            while cursor < order.len() {
                let needed = k * b;
                let mut ids = Vec::with_capacity(needed * n);
                let mut mask = Vec::with_capacity(needed * n);
                let mut labels = Vec::with_capacity(needed);
                for slot in 0..needed {
                    // wrap around so every super-batch is full
                    let ex = &task.train[order[(cursor + slot) % order.len()]];
                    ids.extend_from_slice(&ex.ids);
                    mask.extend_from_slice(&ex.mask);
                    labels.push(ex.label);
                }
                cursor += needed;

                let loss = self.train_call(
                    &mut state,
                    Tensor::from_i32(&[k, b, n], ids),
                    Tensor::from_f32(&[k, b, n], mask),
                    Tensor::from_f32(&[k, b], labels),
                    cfg,
                )?;
                losses.push(loss);
                steps_run += k;
                if cfg.max_steps > 0 && steps_run >= cfg.max_steps {
                    let metric = self.evaluate(task, &state)?;
                    if metric > best_metric {
                        best_metric = metric;
                        best_epoch = epochs_run;
                        best_state = state.trainable_map(spec);
                    }
                    break 'outer;
                }
            }

            let metric = self.evaluate(task, &state)?;
            if metric > best_metric {
                best_metric = metric;
                best_epoch = epochs_run;
                best_state = state.trainable_map(spec);
                since_best = 0;
            } else {
                since_best += 1;
                // Paper protocol: stop once the dev score has not improved
                // for `patience` evaluations (Appendix Table 6).
                if since_best >= cfg.patience {
                    break;
                }
            }
        }

        Ok(TrainResult {
            best_metric,
            best_epoch,
            epochs_run,
            steps_run,
            losses,
            best_state,
        })
    }

    /// One train-executable invocation (K optimizer steps).
    fn train_call(
        &self,
        state: &mut TrainState,
        ids: Tensor,
        mask: Tensor,
        labels: Tensor,
        cfg: &TrainConfig,
    ) -> Result<f32> {
        let spec = &self.train_exe.spec;
        let mut args: Vec<Tensor> = Vec::with_capacity(spec.inputs.len());
        for input in &spec.inputs {
            let t = if let Some(name) = input.name.strip_prefix("w.") {
                self.weights.host(name)?.clone()
            } else if input.name.starts_with("t.")
                || input.name.starts_with("m.")
                || input.name.starts_with("v.")
            {
                state.take(&input.name)?
            } else {
                match input.name.as_str() {
                    "in.step" => Tensor::scalar_i32(state.step),
                    "in.ids" => ids.clone(),
                    "in.mask" => mask.clone(),
                    "in.labels" => labels.clone(),
                    "in.lr" => Tensor::scalar_f32(cfg.lr),
                    "in.seed" => Tensor::scalar_i32(cfg.seed as i32),
                    other => bail!("unexpected train input {other}"),
                }
            };
            args.push(t);
        }
        let outs = self.train_exe.run(&args)?;
        state.absorb(spec, outs)?;
        Ok(state.last_loss)
    }

    /// Dev-set evaluation with the eval executable; returns the task metric.
    pub fn evaluate(&self, task: &TaskData, state: &TrainState) -> Result<f64> {
        let preds = self.predict(&task.dev, state)?;
        let gold: Vec<i64> = task.dev.iter().map(|e| e.label as i64).collect();
        Ok(task.metric.compute(&preds, &gold))
    }

    /// Argmax predictions for a split.
    pub fn predict(
        &self,
        examples: &[crate::data::Example],
        state: &TrainState,
    ) -> Result<Vec<i64>> {
        let spec = &self.eval_exe.spec;
        let (eb, n) = (spec.batch, spec.seq);
        let mut preds: Vec<i64> = Vec::with_capacity(examples.len());
        let mut cursor = 0;
        while cursor < examples.len() {
            let take = (examples.len() - cursor).min(eb);
            let mut ids = Vec::with_capacity(eb * n);
            let mut mask = Vec::with_capacity(eb * n);
            for j in 0..eb {
                let ex = &examples[cursor + j.min(take - 1)];
                ids.extend_from_slice(&ex.ids);
                mask.extend_from_slice(&ex.mask);
            }
            let mut args: Vec<Tensor> = Vec::with_capacity(spec.inputs.len());
            for input in &spec.inputs {
                let t = if let Some(name) = input.name.strip_prefix("w.") {
                    self.weights.host(name)?.clone()
                } else if input.name.starts_with("t.") {
                    state.peek(&input.name)?.clone()
                } else {
                    match input.name.as_str() {
                        "in.ids" => Tensor::from_i32(&[eb, n], ids.clone()),
                        "in.mask" => Tensor::from_f32(&[eb, n], mask.clone()),
                        other => bail!("unexpected eval input {other}"),
                    }
                };
                args.push(t);
            }
            let outs = self.eval_exe.run(&args)?;
            let logits = outs[0].as_f32()?;
            let classes = logits.len() / eb;
            for j in 0..take {
                let row = &logits[j * classes..(j + 1) * classes];
                let arg = row
                    .iter()
                    .enumerate()
                    .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                    .map(|(i, _)| i as i64)
                    .unwrap_or(0);
                preds.push(arg);
            }
            cursor += take;
        }
        Ok(preds)
    }
}
