//! Expected Validation Performance (Dodge et al. 2019) — the paper's
//! Appendix Figures 5/7 machinery.
//!
//! Given validation scores of `n` hyperparameter assignments, EVP(k) is
//! the expectation of the maximum over `k` assignments drawn uniformly
//! WITH replacement (the closed form used by Dodge et al.):
//!
//!   E[max of k] = Σ_i s_(i) · [ (i/n)^k − ((i−1)/n)^k ]
//!
//! with s_(1) ≤ … ≤ s_(n) the sorted scores.

/// EVP at a single budget k.
pub fn evp_at(scores: &[f64], k: usize) -> f64 {
    if scores.is_empty() || k == 0 {
        return 0.0;
    }
    let mut s = scores.to_vec();
    s.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let n = s.len() as f64;
    let mut total = 0.0;
    for (i, score) in s.iter().enumerate() {
        let hi = ((i + 1) as f64 / n).powi(k as i32);
        let lo = (i as f64 / n).powi(k as i32);
        total += score * (hi - lo);
    }
    total
}

/// The whole curve for budgets 1..=max_k.
pub fn evp_curve(scores: &[f64], max_k: usize) -> Vec<(usize, f64)> {
    (1..=max_k).map(|k| (k, evp_at(scores, k))).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Pcg64;

    #[test]
    fn evp1_is_mean_and_evp_inf_is_max() {
        let scores = [0.2, 0.5, 0.8, 0.9];
        let mean: f64 = scores.iter().sum::<f64>() / 4.0;
        assert!((evp_at(&scores, 1) - mean).abs() < 1e-12);
        assert!((evp_at(&scores, 200) - 0.9).abs() < 1e-6);
    }

    #[test]
    fn evp_is_monotone_in_budget() {
        let scores = [0.1, 0.7, 0.4, 0.9, 0.3];
        let curve = evp_curve(&scores, 20);
        for w in curve.windows(2) {
            assert!(w[1].1 >= w[0].1 - 1e-12);
        }
    }

    #[test]
    fn evp_matches_monte_carlo() {
        let scores: Vec<f64> = {
            let mut rng = Pcg64::new(9);
            (0..30).map(|_| rng.f64()).collect()
        };
        let k = 5;
        let exact = evp_at(&scores, k);
        let mut rng = Pcg64::new(10);
        let trials = 200_000;
        let mut total = 0.0;
        for _ in 0..trials {
            let mut best = f64::NEG_INFINITY;
            for _ in 0..k {
                best = best.max(*rng.choose(&scores));
            }
            total += best;
        }
        let mc = total / trials as f64;
        assert!((exact - mc).abs() < 5e-3, "exact {exact} vs mc {mc}");
    }

    #[test]
    fn degenerate_inputs() {
        assert_eq!(evp_at(&[], 3), 0.0);
        assert_eq!(evp_at(&[0.5], 0), 0.0);
        assert!((evp_at(&[0.5], 7) - 0.5).abs() < 1e-12);
    }
}
