//! Grid search over (hyperparameter assignment × seed), the paper's §4.1
//! protocol: every assignment evaluated under several seeds, reporting
//! median ± std of the dev metric, plus the per-assignment score list the
//! EVP curves consume.

use std::sync::Arc;

use crate::config::Manifest;
use crate::data::TaskData;
use crate::runtime::{Runtime, WeightCache};
use crate::util::stats;
use crate::Result;

use super::{TrainConfig, Trainer};

/// One grid axis point: a concrete (train, eval) artifact pair + lr.
#[derive(Clone, Debug)]
pub struct Assignment {
    pub train_stem: String,
    pub eval_stem: String,
    pub lr: f32,
    /// Display label, e.g. "r=32,lr=1e-3".
    pub label: String,
}

/// Result of one (assignment, seed) run.
#[derive(Clone, Debug)]
pub struct RunResult {
    pub assignment: String,
    pub seed: u64,
    pub metric: f64,
    pub epochs: usize,
    pub steps: usize,
}

/// Aggregated over seeds per assignment + the flat score list.
pub struct GridResult {
    pub runs: Vec<RunResult>,
}

impl GridResult {
    /// (median, std) over seeds for the best assignment (paper Table 2
    /// reports median ± std of the best hyperparameter set).
    pub fn best(&self) -> Option<(String, f64, f64)> {
        let mut per: std::collections::BTreeMap<&str, Vec<f64>> = Default::default();
        for r in &self.runs {
            per.entry(&r.assignment).or_default().push(r.metric);
        }
        per.into_iter()
            .map(|(a, scores)| (a.to_string(), stats::median(&scores), stats::std(&scores)))
            .max_by(|x, y| x.1.partial_cmp(&y.1).unwrap())
    }

    /// All scores (assignment × seed), the EVP curve input.
    pub fn all_scores(&self) -> Vec<f64> {
        self.runs.iter().map(|r| r.metric).collect()
    }
}

/// Drives the grid for one (model, method) over one task.
pub struct GridSearch<'a> {
    pub runtime: &'a Arc<Runtime>,
    pub manifest: &'a Manifest,
    pub weights: Arc<WeightCache>,
    pub assignments: Vec<Assignment>,
    pub seeds: Vec<u64>,
    pub train_cfg: TrainConfig,
}

impl<'a> GridSearch<'a> {
    pub fn run(&self, task: &TaskData) -> Result<GridResult> {
        let mut runs = Vec::new();
        for a in &self.assignments {
            let trainer = Trainer::new(
                self.runtime,
                self.manifest,
                Arc::clone(&self.weights),
                &a.train_stem,
                &a.eval_stem,
            )?;
            for &seed in &self.seeds {
                let mut cfg = self.train_cfg.clone();
                cfg.lr = a.lr;
                cfg.seed = seed;
                let result = trainer.run(task, &cfg)?;
                crate::debugln!(
                    "grid {} seed {} -> {:.4} ({} epochs)",
                    a.label,
                    seed,
                    result.best_metric,
                    result.epochs_run
                );
                runs.push(RunResult {
                    assignment: a.label.clone(),
                    seed,
                    metric: result.best_metric,
                    epochs: result.epochs_run,
                    steps: result.steps_run,
                });
            }
        }
        Ok(GridResult { runs })
    }
}

/// Build the grid assignments available in the manifest for a method.
pub fn assignments_for(
    manifest: &Manifest,
    model: &str,
    method: &str,
    classes: usize,
    lrs: &[f32],
) -> Vec<Assignment> {
    let mut out = Vec::new();
    for train in manifest.find("train", model, method) {
        if train.classes != classes {
            continue;
        }
        // Find the eval artifact with matching hp.
        let eval = manifest
            .find("eval", model, method)
            .into_iter()
            .find(|e| {
                e.classes == classes && e.rank == train.rank && e.prefix == train.prefix
            });
        let Some(eval) = eval else { continue };
        for &lr in lrs {
            let hp_label = if matches!(method, "pt1" | "pt2") {
                format!("p={}", train.prefix)
            } else if matches!(method, "lora" | "adapters" | "aot-kron" | "aot-fc") {
                format!("r={}", train.rank)
            } else {
                "-".to_string()
            };
            out.push(Assignment {
                train_stem: train.stem.clone(),
                eval_stem: eval.stem.clone(),
                lr,
                label: format!("{method}[{hp_label},lr={lr}]"),
            });
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn grid_result_best_picks_highest_median() {
        let runs = vec![
            RunResult { assignment: "a".into(), seed: 0, metric: 0.6, epochs: 1, steps: 8 },
            RunResult { assignment: "a".into(), seed: 1, metric: 0.62, epochs: 1, steps: 8 },
            RunResult { assignment: "b".into(), seed: 0, metric: 0.9, epochs: 1, steps: 8 },
            RunResult { assignment: "b".into(), seed: 1, metric: 0.1, epochs: 1, steps: 8 },
        ];
        let g = GridResult { runs };
        let (name, median, _std) = g.best().unwrap();
        // a: median .61; b: median .5 -> a wins despite b's outlier
        assert_eq!(name, "a");
        assert!((median - 0.61).abs() < 1e-9);
        assert_eq!(g.all_scores().len(), 4);
    }

    #[test]
    fn assignments_for_finds_manifest_pairs() {
        let dir = crate::artifacts_dir();
        if !dir.join("manifest.json").exists() {
            return;
        }
        let m = Manifest::load(&dir).unwrap();
        let a = assignments_for(&m, "tiny", "aot-fc", 2, &[1e-3, 5e-3]);
        // two ranks x two lrs
        assert_eq!(a.len(), 4, "{a:?}");
        assert!(a.iter().all(|x| x.train_stem.contains("train_tiny_aot-fc")));
        assert!(a.iter().all(|x| x.eval_stem.contains("eval_tiny_aot-fc")));
    }
}
