//! Minimal JSON parser + writer (serde is not available offline).
//!
//! Handles the full JSON grammar the framework needs: the artifact
//! manifest, experiment configs, and results files.  Numbers are f64
//! (integers round-trip exactly up to 2^53, plenty for shapes/counters).

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A JSON value.  Object keys are ordered (BTreeMap) for stable output.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    // -- constructors ------------------------------------------------------

    pub fn obj() -> Json {
        Json::Obj(BTreeMap::new())
    }

    pub fn from_pairs(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    // -- accessors ---------------------------------------------------------

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(map) => map.get(key),
            _ => None,
        }
    }

    /// `get` that descends a dotted path: `a.b.c`.
    pub fn path(&self, path: &str) -> Option<&Json> {
        let mut cur = self;
        for part in path.split('.') {
            cur = cur.get(part)?;
        }
        Some(cur)
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_i64(&self) -> Option<i64> {
        self.as_f64().map(|x| x as i64)
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().and_then(|x| if x >= 0.0 { Some(x as usize) } else { None })
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    pub fn set(&mut self, key: &str, value: Json) {
        if let Json::Obj(map) = self {
            map.insert(key.to_string(), value);
        } else {
            panic!("Json::set on non-object");
        }
    }

    pub fn push(&mut self, value: Json) {
        if let Json::Arr(v) = self {
            v.push(value);
        } else {
            panic!("Json::push on non-array");
        }
    }

    // -- serialization -----------------------------------------------------

    pub fn to_string_pretty(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, 0, true);
        out
    }

    /// Single-line form (wire format for the HTTP server).
    pub fn to_string_compact(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, 0, false);
        out
    }

    fn write(&self, out: &mut String, indent: usize, pretty: bool) {
        let pad = |out: &mut String, n: usize| {
            if pretty {
                out.push('\n');
                for _ in 0..n {
                    out.push(' ');
                }
            }
        };
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => {
                if x.fract() == 0.0 && x.abs() < 9.0e15 {
                    let _ = write!(out, "{}", *x as i64);
                } else {
                    let _ = write!(out, "{x}");
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(v) => {
                out.push('[');
                for (i, item) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    pad(out, indent + 1);
                    item.write(out, indent + 1, pretty);
                }
                if !v.is_empty() {
                    pad(out, indent);
                }
                out.push(']');
            }
            Json::Obj(map) => {
                out.push('{');
                for (i, (k, val)) in map.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    pad(out, indent + 1);
                    write_escaped(out, k);
                    out.push(':');
                    if pretty {
                        out.push(' ');
                    }
                    val.write(out, indent + 1, pretty);
                }
                if !map.is_empty() {
                    pad(out, indent);
                }
                out.push('}');
            }
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

// ---------------------------------------------------------------------------
// Parser
// ---------------------------------------------------------------------------

/// Parse a JSON document.
pub fn parse(input: &str) -> Result<Json, String> {
    let mut p = Parser { bytes: input.as_bytes(), pos: 0 };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.pos != p.bytes.len() {
        return Err(format!("trailing data at byte {}", p.pos));
    }
    Ok(v)
}

/// Load and parse a JSON file.
pub fn load(path: &std::path::Path) -> crate::Result<Json> {
    let text = std::fs::read_to_string(path)
        .map_err(|e| anyhow::anyhow!("read {}: {e}", path.display()))?;
    parse(&text).map_err(|e| anyhow::anyhow!("parse {}: {e}", path.display()))
}

/// Serialize and write a JSON file.
pub fn save(path: &std::path::Path, value: &Json) -> crate::Result<()> {
    if let Some(dir) = path.parent() {
        std::fs::create_dir_all(dir)?;
    }
    std::fs::write(path, value.to_string_pretty())?;
    Ok(())
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.pos < self.bytes.len() && self.bytes[self.pos].is_ascii_whitespace() {
            self.pos += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn expect(&mut self, b: u8) -> Result<(), String> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(format!(
                "expected '{}' at byte {} (found {:?})",
                b as char,
                self.pos,
                self.peek().map(|c| c as char)
            ))
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            other => Err(format!("unexpected {:?} at byte {}", other.map(|c| c as char), self.pos)),
        }
    }

    fn literal(&mut self, word: &str, value: Json) -> Result<Json, String> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(value)
        } else {
            Err(format!("bad literal at byte {}", self.pos))
        }
    }

    fn object(&mut self) -> Result<Json, String> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(map));
                }
                other => {
                    return Err(format!(
                        "expected ',' or '}}' at byte {} (found {:?})",
                        self.pos,
                        other.map(|c| c as char)
                    ))
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, String> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            let val = self.value()?;
            items.push(val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(items));
                }
                other => {
                    return Err(format!(
                        "expected ',' or ']' at byte {} (found {:?})",
                        self.pos,
                        other.map(|c| c as char)
                    ))
                }
            }
        }
    }

    fn string(&mut self) -> Result<String, String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err("unterminated string".into()),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'n') => out.push('\n'),
                        Some(b'r') => out.push('\r'),
                        Some(b't') => out.push('\t'),
                        Some(b'u') => {
                            let hex = self
                                .bytes
                                .get(self.pos + 1..self.pos + 5)
                                .ok_or("bad \\u escape")?;
                            let code = u32::from_str_radix(
                                std::str::from_utf8(hex).map_err(|_| "bad \\u escape")?,
                                16,
                            )
                            .map_err(|_| "bad \\u escape")?;
                            self.pos += 4;
                            // Surrogate pairs.
                            let ch = if (0xD800..0xDC00).contains(&code) {
                                if self.bytes.get(self.pos + 1..self.pos + 3) != Some(b"\\u") {
                                    return Err("lone high surrogate".into());
                                }
                                let hex2 = self
                                    .bytes
                                    .get(self.pos + 3..self.pos + 7)
                                    .ok_or("bad surrogate")?;
                                let low = u32::from_str_radix(
                                    std::str::from_utf8(hex2).map_err(|_| "bad surrogate")?,
                                    16,
                                )
                                .map_err(|_| "bad surrogate")?;
                                self.pos += 6;
                                0x10000 + ((code - 0xD800) << 10) + (low - 0xDC00)
                            } else {
                                code
                            };
                            out.push(char::from_u32(ch).ok_or("invalid codepoint")?);
                        }
                        other => {
                            return Err(format!("bad escape {:?}", other.map(|c| c as char)))
                        }
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // Consume one UTF-8 scalar.
                    let start = self.pos;
                    let len = utf8_len(self.bytes[start]);
                    let chunk = self
                        .bytes
                        .get(start..start + len)
                        .ok_or("truncated UTF-8")?;
                    out.push_str(std::str::from_utf8(chunk).map_err(|_| "invalid UTF-8")?);
                    self.pos += len;
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e') | Some(b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+') | Some(b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|e| format!("bad number {text:?}: {e}"))
    }
}

fn utf8_len(b: u8) -> usize {
    if b < 0x80 {
        1
    } else if b < 0xE0 {
        2
    } else if b < 0xF0 {
        3
    } else {
        4
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Pcg64;

    #[test]
    fn parse_basics() {
        assert_eq!(parse("null").unwrap(), Json::Null);
        assert_eq!(parse(" true ").unwrap(), Json::Bool(true));
        assert_eq!(parse("-12.5e2").unwrap(), Json::Num(-1250.0));
        assert_eq!(parse(r#""a\nb""#).unwrap(), Json::Str("a\nb".into()));
        assert_eq!(
            parse(r#"[1, 2, 3]"#).unwrap(),
            Json::Arr(vec![Json::Num(1.0), Json::Num(2.0), Json::Num(3.0)])
        );
    }

    #[test]
    fn parse_nested_object() {
        let v = parse(r#"{"a": {"b": [1, {"c": "d"}]}}"#).unwrap();
        assert_eq!(v.path("a.b").unwrap().as_arr().unwrap().len(), 2);
        assert_eq!(
            v.path("a.b").unwrap().as_arr().unwrap()[1].get("c").unwrap().as_str(),
            Some("d")
        );
    }

    #[test]
    fn unicode_escapes() {
        assert_eq!(parse(r#""é""#).unwrap(), Json::Str("é".into()));
        assert_eq!(parse(r#""😀""#).unwrap(), Json::Str("😀".into()));
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("tru").is_err());
        assert!(parse("1 2").is_err());
        assert!(parse(r#"{"a" 1}"#).is_err());
    }

    #[test]
    fn roundtrip_pretty() {
        let text = r#"{"arr":[1,2.5,"x"],"b":true,"n":null,"o":{"k":"v"}}"#;
        let v = parse(text).unwrap();
        let again = parse(&v.to_string_pretty()).unwrap();
        assert_eq!(v, again);
    }

    /// Seeded fuzz: random values survive serialize -> parse round trips.
    #[test]
    fn fuzz_roundtrip() {
        fn random_json(rng: &mut Pcg64, depth: usize) -> Json {
            match if depth > 3 { rng.below(4) } else { rng.below(6) } {
                0 => Json::Null,
                1 => Json::Bool(rng.bool(0.5)),
                2 => Json::Num((rng.range(-1_000_000, 1_000_000) as f64) / 8.0),
                3 => {
                    let n = rng.below(12) as usize;
                    Json::Str(
                        (0..n)
                            .map(|_| {
                                *rng.choose(&['a', '"', '\\', 'é', '\n', '😀', 'z'])
                            })
                            .collect(),
                    )
                }
                4 => {
                    let n = rng.below(5) as usize;
                    Json::Arr((0..n).map(|_| random_json(rng, depth + 1)).collect())
                }
                _ => {
                    let n = rng.below(5) as usize;
                    let mut m = BTreeMap::new();
                    for i in 0..n {
                        m.insert(format!("k{i}"), random_json(rng, depth + 1));
                    }
                    Json::Obj(m)
                }
            }
        }
        let mut rng = Pcg64::new(2023);
        for _ in 0..200 {
            let v = random_json(&mut rng, 0);
            let text = v.to_string_pretty();
            let back = parse(&text).unwrap_or_else(|e| panic!("{e}\n{text}"));
            assert_eq!(v, back, "{text}");
        }
    }
}
