//! # aotpt — Ahead-of-Time P-Tuning
//!
//! A three-layer reproduction of *Ahead-of-Time P-Tuning* (Gavrilov &
//! Balagansky, 2023): a multi-task, zero-inference-overhead
//! parameter-efficient fine-tuning framework.
//!
//! * **L1/L2** live in `python/compile/` (Pallas kernels + JAX model), run
//!   once at build time, and are lowered to HLO-text artifacts.
//! * **L3** is this crate: a Rust coordinator that serves many fine-tuned
//!   tasks from a single backbone executable (fused per-task `P` matrices
//!   in a tiered adapter store — resident f32/f16 under a RAM budget,
//!   LRU-spilled to disk, hot-mutable while serving; ahead-of-time row
//!   gather on the request path) and a training driver that reproduces
//!   the paper's experimental protocol by executing AOT train-step
//!   computations.  Serving runs as a staged pipeline — admission →
//!   batch planning → AoT gather → device execute → fan-out
//!   (`coordinator::pipeline`) — with all host staging buffers drawn
//!   from a reusable [`peft::GatherArena`], so the steady-state hot path
//!   allocates nothing (DESIGN.md §9–§10).
//!
//! Builds without an accelerator use the in-tree `xla` CPU stub
//! (`rust/xla`); enable the `pjrt` cargo feature with a vendored PJRT
//! `xla` crate to execute real artifacts.
//!
//! See `DESIGN.md` for the full system inventory and experiment index.

pub mod analyze;
pub mod bench;
pub mod cli;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod experiments;
pub mod json;
pub mod model;
pub mod peft;
pub mod runtime;
pub mod server;
pub mod tensor;
pub mod tokenizer;
pub mod train;
pub mod util;

/// Crate-wide result type.
pub type Result<T> = anyhow::Result<T>;

/// Root of the repository, resolved at runtime.
///
/// Looks for `AOTPT_ROOT` first, then walks up from the current directory
/// until a directory containing `artifacts/` or `Cargo.toml` is found.
pub fn repo_root() -> std::path::PathBuf {
    if let Ok(root) = std::env::var("AOTPT_ROOT") {
        return std::path::PathBuf::from(root);
    }
    let mut dir = std::env::current_dir().unwrap_or_else(|_| std::path::PathBuf::from("."));
    loop {
        if dir.join("Cargo.toml").exists() || dir.join("artifacts").exists() {
            return dir;
        }
        if !dir.pop() {
            return std::path::PathBuf::from(".");
        }
    }
}

/// Path to the artifacts directory (AOT-compiled HLO text + manifest).
pub fn artifacts_dir() -> std::path::PathBuf {
    repo_root().join("artifacts")
}
