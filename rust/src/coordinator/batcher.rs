//! Bucket selection for the dynamic batcher (consumed by the pipeline's
//! batch-planning stage, `pipeline::BatchPlanner`).
//!
//! Artifacts are compiled for static (batch, seq) buckets; the batcher
//! maps `(pending requests, max token length)` onto the cheapest bucket
//! that fits.  Invariants (property-tested in `tests/prop_coordinator.rs`):
//! the selected bucket always fits, and is minimal in padded area
//! `batch × seq` among fitting buckets.

use anyhow::bail;

use crate::Result;

#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct Bucket {
    pub batch: usize,
    pub seq: usize,
}

/// The available buckets of one serving signature.
pub struct BucketSet {
    buckets: Vec<Bucket>,
    max_batch: usize,
    max_seq: usize,
}

impl BucketSet {
    pub fn new(mut buckets: Vec<Bucket>) -> BucketSet {
        buckets.sort_by_key(|b| (b.batch * b.seq, b.batch));
        buckets.dedup();
        let max_batch = buckets.iter().map(|b| b.batch).max().unwrap_or(0);
        let max_seq = buckets.iter().map(|b| b.seq).max().unwrap_or(0);
        BucketSet { buckets, max_batch, max_seq }
    }

    pub fn max_batch(&self) -> usize {
        self.max_batch
    }

    pub fn max_seq(&self) -> usize {
        self.max_seq
    }

    pub fn all(&self) -> &[Bucket] {
        &self.buckets
    }

    /// Smallest-area bucket with `batch >= count` and `seq >= max_len`.
    pub fn select(&self, count: usize, max_len: usize) -> Result<Bucket> {
        // buckets are sorted by area, so the first fit is minimal.
        for b in &self.buckets {
            if b.batch >= count && b.seq >= max_len {
                return Ok(*b);
            }
        }
        bail!(
            "no bucket fits {count} requests of length <= {max_len} \
             (max batch {}, max seq {})",
            self.max_batch,
            self.max_seq
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn set() -> BucketSet {
        BucketSet::new(vec![
            Bucket { batch: 1, seq: 16 },
            Bucket { batch: 1, seq: 64 },
            Bucket { batch: 16, seq: 16 },
            Bucket { batch: 16, seq: 64 },
            Bucket { batch: 64, seq: 64 },
        ])
    }

    #[test]
    fn selects_minimal_fitting_bucket() {
        let s = set();
        assert_eq!(s.select(1, 10).unwrap(), Bucket { batch: 1, seq: 16 });
        assert_eq!(s.select(1, 17).unwrap(), Bucket { batch: 1, seq: 64 });
        assert_eq!(s.select(2, 10).unwrap(), Bucket { batch: 16, seq: 16 });
        assert_eq!(s.select(17, 30).unwrap(), Bucket { batch: 64, seq: 64 });
    }

    #[test]
    fn rejects_oversize() {
        let s = set();
        assert!(s.select(65, 10).is_err());
        assert!(s.select(1, 100).is_err());
    }

    #[test]
    fn dedups_and_orders() {
        let s = BucketSet::new(vec![
            Bucket { batch: 4, seq: 8 },
            Bucket { batch: 4, seq: 8 },
            Bucket { batch: 2, seq: 8 },
        ]);
        assert_eq!(s.all().len(), 2);
        assert_eq!(s.select(1, 8).unwrap(), Bucket { batch: 2, seq: 8 });
    }
}
