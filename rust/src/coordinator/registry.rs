//! The task registry: per-task fused `P` tables (tiered adapter store,
//! via `PStore`) plus per-task classification heads.  Registering a task
//! is the fuse step of §3.3 — after it, serving cost is independent of
//! the method's training-time rank `r` (the paper's Figure 2 point).
//!
//! Every lifecycle operation takes `&self`: tasks are registered,
//! replaced, unregistered and pinned **while the pipeline is serving**
//! (the task map sits behind a `RwLock`, the table store behind the
//! residency manager's interior mutability — DESIGN.md §10).  In-flight
//! batches hold `Arc` snapshots of both the head state and the table, so
//! a concurrent unregister/replace never corrupts them.

use std::collections::BTreeMap;
use std::sync::{Arc, Mutex, RwLock};

use anyhow::{anyhow, bail};

use crate::peft::{fuse, AdapterConfig, AdapterStats, PStore, TaskP};
use crate::tensor::Tensor;
use crate::Result;

/// Per-task serving state (everything the coordinator needs at runtime).
#[derive(Clone)]
pub struct TaskState {
    pub classes: usize,
    /// Row-major [d, classes].
    pub head_w: Vec<f32>,
    pub head_b: Vec<f32>,
}

pub struct TaskRegistry {
    layers: usize,
    vocab: usize,
    d_model: usize,
    max_classes: usize,
    pstore: PStore,
    tasks: RwLock<BTreeMap<String, Arc<TaskState>>>,
    /// Serializes register/unregister so the head map and the table
    /// store always move together: without it, an unregister racing a
    /// re-register of the same name could delete the fresh table while
    /// leaving the fresh head (admission would then accept requests no
    /// gather can serve).  Reads (gathers, admission) never take this.
    lifecycle: Mutex<()>,
}

impl TaskRegistry {
    pub fn new(layers: usize, vocab: usize, d_model: usize, max_classes: usize) -> TaskRegistry {
        TaskRegistry::with_adapter_config(
            layers,
            vocab,
            d_model,
            max_classes,
            AdapterConfig::default(),
        )
    }

    /// A registry with explicit adapter tiering (storage dtype, RAM
    /// budget, spill directory — CLI `--adapter-dtype` /
    /// `--adapter-ram-budget`).
    pub fn with_adapter_config(
        layers: usize,
        vocab: usize,
        d_model: usize,
        max_classes: usize,
        cfg: AdapterConfig,
    ) -> TaskRegistry {
        TaskRegistry {
            layers,
            vocab,
            d_model,
            max_classes,
            pstore: PStore::with_config(layers, vocab, d_model, cfg),
            tasks: RwLock::new(BTreeMap::new()),
            lifecycle: Mutex::new(()),
        }
    }

    /// Register (or hot-replace) a task from an already-fused table.
    pub fn register_fused(
        &self,
        name: &str,
        p: TaskP,
        head_w: &Tensor,
        head_b: &Tensor,
    ) -> Result<()> {
        let _lifecycle = self.lifecycle.lock().unwrap();
        let classes = head_b.len();
        if classes > self.max_classes {
            bail!("task {name}: {classes} classes exceeds serving max {}", self.max_classes);
        }
        head_w.check_shape(&[self.d_model, classes])?;
        self.pstore.insert(name, p)?;
        self.tasks.write().unwrap().insert(
            name.to_string(),
            Arc::new(TaskState {
                classes,
                head_w: head_w.as_f32()?.to_vec(),
                head_b: head_b.as_f32()?.to_vec(),
            }),
        );
        Ok(())
    }

    /// Register an FC-AoT task from its *trained reparametrized* weights:
    /// runs the fuse (Equation 3) host-side, then stores the dense table.
    pub fn register_fc(
        &self,
        name: &str,
        emb: &Tensor,
        trained: &BTreeMap<String, Tensor>,
    ) -> Result<()> {
        let p = fuse::fuse_fc(emb, trained)?;
        let (head_w, head_b) = heads_from(trained)?;
        self.register_fused(name, p, &head_w, &head_b)
    }

    /// Register a Kronecker-AoT task (Equation 2 fuse).
    pub fn register_kron(
        &self,
        name: &str,
        trained: &BTreeMap<String, Tensor>,
    ) -> Result<()> {
        let p = fuse::fuse_kron(self.vocab, trained)?;
        let (head_w, head_b) = heads_from(trained)?;
        self.register_fused(name, p, &head_w, &head_b)
    }

    /// Register a task with a zero table (serves the frozen backbone +
    /// head; used as the BitFit-style sanity baseline and in tests).
    pub fn register_zero(
        &self,
        name: &str,
        head_w: &Tensor,
        head_b: &Tensor,
    ) -> Result<()> {
        self.register_fused(
            name,
            TaskP::zeros(self.layers, self.vocab, self.d_model),
            head_w,
            head_b,
        )
    }

    /// Unregister a task while serving.  In-flight batches finish on
    /// their snapshots; subsequent admissions for the task are rejected.
    pub fn unregister(&self, name: &str) -> Result<()> {
        let _lifecycle = self.lifecycle.lock().unwrap();
        let removed = self.tasks.write().unwrap().remove(name);
        if removed.is_none() {
            bail!("unknown task {name}");
        }
        // The head map is authoritative for admission; the table is
        // removed second, best-effort (a half-registered task cannot
        // exist: register inserts the table first, the head second).
        let _ = self.pstore.remove(name);
        Ok(())
    }

    /// Pin a task's table into RAM (exempt from eviction) or release it.
    pub fn pin_task(&self, name: &str, pinned: bool) -> Result<()> {
        self.pstore.pin(name, pinned)
    }

    /// Cheap shared handle to a task's serving state (the hot path packs
    /// heads straight from the shared slices — no per-lookup cloning).
    pub fn get(&self, name: &str) -> Result<Arc<TaskState>> {
        self.tasks
            .read()
            .unwrap()
            .get(name)
            .cloned()
            .ok_or_else(|| anyhow!("unknown task {name}"))
    }

    pub fn pstore(&self) -> &PStore {
        &self.pstore
    }

    /// Residency/tier counters of the adapter store (exported through
    /// `MetricsSnapshot`).
    pub fn adapter_stats(&self) -> AdapterStats {
        self.pstore.stats()
    }

    /// Geometry accessors (the serving pipeline sizes buffers from these).
    pub fn layers(&self) -> usize {
        self.layers
    }

    pub fn vocab(&self) -> usize {
        self.vocab
    }

    pub fn d_model(&self) -> usize {
        self.d_model
    }

    pub fn max_classes(&self) -> usize {
        self.max_classes
    }

    /// Registered task names, sorted (same order and type as
    /// `PStore::task_names`).
    pub fn task_names(&self) -> Vec<String> {
        self.tasks.read().unwrap().keys().cloned().collect()
    }

    pub fn len(&self) -> usize {
        self.tasks.read().unwrap().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Host RAM held by resident fused tables (the paper's §3.3
    /// trade-off, now bounded by the adapter RAM budget).
    pub fn ram_bytes(&self) -> usize {
        self.pstore.bytes()
    }
}

fn heads_from(trained: &BTreeMap<String, Tensor>) -> Result<(Tensor, Tensor)> {
    let w = trained
        .get("t.head_w")
        .or_else(|| trained.get("head_w"))
        .ok_or_else(|| anyhow!("trained state missing head_w"))?;
    let b = trained
        .get("t.head_b")
        .or_else(|| trained.get("head_b"))
        .ok_or_else(|| anyhow!("trained state missing head_b"))?;
    Ok((w.clone(), b.clone()))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::peft::AdapterDType;
    use crate::tensor::DType;

    #[test]
    fn register_and_lookup() {
        let reg = TaskRegistry::new(2, 100, 8, 4);
        let head_w = Tensor::from_f32(&[8, 2], vec![0.1; 16]);
        let head_b = Tensor::from_f32(&[2], vec![0.0, 0.0]);
        reg.register_zero("sst2", &head_w, &head_b).unwrap();
        let state = reg.get("sst2").unwrap();
        assert_eq!(state.classes, 2);
        assert_eq!(reg.task_names(), vec!["sst2".to_string()]);
        assert_eq!(reg.task_names(), reg.pstore().task_names());
        assert!(reg.get("nope").is_err());
        assert_eq!(reg.ram_bytes(), 2 * 100 * 8 * 4);
    }

    #[test]
    fn unregister_removes_head_and_table() {
        let reg = TaskRegistry::new(2, 50, 8, 4);
        let head_w = Tensor::from_f32(&[8, 2], vec![0.1; 16]);
        let head_b = Tensor::from_f32(&[2], vec![0.0, 0.0]);
        reg.register_zero("gone", &head_w, &head_b).unwrap();
        assert_eq!(reg.len(), 1);
        reg.unregister("gone").unwrap();
        assert_eq!(reg.len(), 0);
        assert!(reg.get("gone").is_err());
        assert!(reg.pstore().get("gone").is_err());
        assert_eq!(reg.ram_bytes(), 0);
        assert!(reg.unregister("gone").is_err());
    }

    #[test]
    fn replace_swaps_head_and_table() {
        let reg = TaskRegistry::new(1, 10, 4, 4);
        let w2 = Tensor::from_f32(&[4, 2], vec![0.1; 8]);
        let b2 = Tensor::from_f32(&[2], vec![0.0; 2]);
        let w3 = Tensor::from_f32(&[4, 3], vec![0.2; 12]);
        let b3 = Tensor::from_f32(&[3], vec![0.0; 3]);
        reg.register_zero("t", &w2, &b2).unwrap();
        assert_eq!(reg.get("t").unwrap().classes, 2);
        reg.register_zero("t", &w3, &b3).unwrap();
        assert_eq!(reg.get("t").unwrap().classes, 3);
        assert_eq!(reg.len(), 1);
        assert_eq!(reg.pstore().len(), 1);
    }

    #[test]
    fn adapter_config_flows_through() {
        let cfg = AdapterConfig { dtype: AdapterDType::F16, ..Default::default() };
        let reg = TaskRegistry::with_adapter_config(2, 40, 8, 4, cfg);
        let head_w = Tensor::from_f32(&[8, 2], vec![0.1; 16]);
        let head_b = Tensor::from_f32(&[2], vec![0.0, 0.0]);
        reg.register_zero("q", &head_w, &head_b).unwrap();
        // Half the f32 footprint, and the stats surface is wired.
        assert_eq!(reg.ram_bytes(), 2 * 40 * 8 * 2);
        assert_eq!(reg.adapter_stats().resident_tasks, 1);
        reg.pin_task("q", true).unwrap();
        reg.pin_task("q", false).unwrap();
        assert!(reg.pin_task("missing", true).is_err());
    }

    #[test]
    fn rejects_too_many_classes() {
        let reg = TaskRegistry::new(2, 100, 8, 2);
        let head_w = Tensor::from_f32(&[8, 3], vec![0.0; 24]);
        let head_b = Tensor::from_f32(&[3], vec![0.0; 3]);
        assert!(reg.register_zero("big", &head_w, &head_b).is_err());
    }

    #[test]
    fn rejects_wrong_head_shape() {
        let reg = TaskRegistry::new(2, 100, 8, 4);
        let head_w = Tensor::zeros(DType::F32, &[7, 2]);
        let head_b = Tensor::zeros(DType::F32, &[2]);
        assert!(reg.register_zero("bad", &head_w, &head_b).is_err());
    }

    #[test]
    fn register_fc_fuses_and_serves() {
        let (l, v, d, r) = (2, 30, 8, 4);
        let reg = TaskRegistry::new(l, v, d, 4);
        let mut rng = crate::util::Pcg64::new(5);
        let emb = Tensor::from_f32(&[v, d], rng.normal_vec(v * d, 1.0));
        let mut tr = BTreeMap::new();
        tr.insert("t.fc.w1".into(), Tensor::from_f32(&[l, d, r], rng.normal_vec(l * d * r, 0.1)));
        tr.insert("t.fc.b1".into(), Tensor::from_f32(&[l, r], rng.normal_vec(l * r, 0.1)));
        tr.insert("t.fc.w2".into(), Tensor::from_f32(&[l, r, d], rng.normal_vec(l * r * d, 0.1)));
        tr.insert("t.fc.b2".into(), Tensor::from_f32(&[l, d], rng.normal_vec(l * d, 0.1)));
        tr.insert("t.head_w".into(), Tensor::from_f32(&[d, 2], rng.normal_vec(d * 2, 0.1)));
        tr.insert("t.head_b".into(), Tensor::from_f32(&[2], vec![0.0; 2]));
        reg.register_fc("wic", &emb, &tr).unwrap();
        // A non-degenerate table must have non-zero norms.
        let p = reg.pstore().get("wic").unwrap();
        assert!(crate::peft::row_norms(p.as_ref(), 0)
            .unwrap()
            .iter()
            .any(|&n| n > 0.0));
    }
}
