//! The task registry: per-task fused `P` tables (host RAM, via `PStore`)
//! plus per-task classification heads.  Registering a task is the fuse
//! step of §3.3 — after it, serving cost is independent of the method's
//! training-time rank `r` (the paper's Figure 2 point).

use std::collections::BTreeMap;
use std::sync::{Arc, RwLock};

use anyhow::{anyhow, bail};

use crate::peft::{fuse, PStore, TaskP};
use crate::tensor::Tensor;
use crate::Result;

/// Per-task serving state (everything the coordinator needs at runtime).
#[derive(Clone)]
pub struct TaskState {
    pub classes: usize,
    /// Row-major [d, classes].
    pub head_w: Vec<f32>,
    pub head_b: Vec<f32>,
}

pub struct TaskRegistry {
    layers: usize,
    vocab: usize,
    d_model: usize,
    max_classes: usize,
    pstore: PStore,
    tasks: RwLock<BTreeMap<String, Arc<TaskState>>>,
}

impl TaskRegistry {
    pub fn new(layers: usize, vocab: usize, d_model: usize, max_classes: usize) -> TaskRegistry {
        TaskRegistry {
            layers,
            vocab,
            d_model,
            max_classes,
            pstore: PStore::new(layers, vocab, d_model),
            tasks: RwLock::new(BTreeMap::new()),
        }
    }

    /// Register a task from an already-fused table.
    pub fn register_fused(
        &mut self,
        name: &str,
        p: TaskP,
        head_w: &Tensor,
        head_b: &Tensor,
    ) -> Result<()> {
        let classes = head_b.len();
        if classes > self.max_classes {
            bail!("task {name}: {classes} classes exceeds serving max {}", self.max_classes);
        }
        head_w.check_shape(&[self.d_model, classes])?;
        self.pstore.insert(name, p)?;
        self.tasks.write().unwrap().insert(
            name.to_string(),
            Arc::new(TaskState {
                classes,
                head_w: head_w.as_f32()?.to_vec(),
                head_b: head_b.as_f32()?.to_vec(),
            }),
        );
        Ok(())
    }

    /// Register an FC-AoT task from its *trained reparametrized* weights:
    /// runs the fuse (Equation 3) host-side, then stores the dense table.
    pub fn register_fc(
        &mut self,
        name: &str,
        emb: &Tensor,
        trained: &BTreeMap<String, Tensor>,
    ) -> Result<()> {
        let p = fuse::fuse_fc(emb, trained)?;
        let (head_w, head_b) = heads_from(trained)?;
        self.register_fused(name, p, &head_w, &head_b)
    }

    /// Register a Kronecker-AoT task (Equation 2 fuse).
    pub fn register_kron(
        &mut self,
        name: &str,
        trained: &BTreeMap<String, Tensor>,
    ) -> Result<()> {
        let p = fuse::fuse_kron(self.vocab, trained)?;
        let (head_w, head_b) = heads_from(trained)?;
        self.register_fused(name, p, &head_w, &head_b)
    }

    /// Register a task with a zero table (serves the frozen backbone +
    /// head; used as the BitFit-style sanity baseline and in tests).
    pub fn register_zero(
        &mut self,
        name: &str,
        head_w: &Tensor,
        head_b: &Tensor,
    ) -> Result<()> {
        self.register_fused(
            name,
            TaskP::zeros(self.layers, self.vocab, self.d_model),
            head_w,
            head_b,
        )
    }

    /// Cheap shared handle to a task's serving state (the hot path packs
    /// heads straight from the shared slices — no per-lookup cloning).
    pub fn get(&self, name: &str) -> Result<Arc<TaskState>> {
        self.tasks
            .read()
            .unwrap()
            .get(name)
            .cloned()
            .ok_or_else(|| anyhow!("unknown task {name}"))
    }

    pub fn pstore(&self) -> &PStore {
        &self.pstore
    }

    /// Geometry accessors (the serving pipeline sizes buffers from these).
    pub fn layers(&self) -> usize {
        self.layers
    }

    pub fn vocab(&self) -> usize {
        self.vocab
    }

    pub fn d_model(&self) -> usize {
        self.d_model
    }

    pub fn max_classes(&self) -> usize {
        self.max_classes
    }

    pub fn task_names(&self) -> Vec<String> {
        self.tasks.read().unwrap().keys().cloned().collect()
    }

    pub fn len(&self) -> usize {
        self.tasks.read().unwrap().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Host RAM held by all fused tables (the paper's §3.3 trade-off).
    pub fn ram_bytes(&self) -> usize {
        self.pstore.bytes()
    }
}

fn heads_from(trained: &BTreeMap<String, Tensor>) -> Result<(Tensor, Tensor)> {
    let w = trained
        .get("t.head_w")
        .or_else(|| trained.get("head_w"))
        .ok_or_else(|| anyhow!("trained state missing head_w"))?;
    let b = trained
        .get("t.head_b")
        .or_else(|| trained.get("head_b"))
        .ok_or_else(|| anyhow!("trained state missing head_b"))?;
    Ok((w.clone(), b.clone()))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::DType;

    #[test]
    fn register_and_lookup() {
        let mut reg = TaskRegistry::new(2, 100, 8, 4);
        let head_w = Tensor::from_f32(&[8, 2], vec![0.1; 16]);
        let head_b = Tensor::from_f32(&[2], vec![0.0, 0.0]);
        reg.register_zero("sst2", &head_w, &head_b).unwrap();
        let state = reg.get("sst2").unwrap();
        assert_eq!(state.classes, 2);
        assert_eq!(reg.task_names(), vec!["sst2".to_string()]);
        assert!(reg.get("nope").is_err());
        assert_eq!(reg.ram_bytes(), 2 * 100 * 8 * 4);
    }

    #[test]
    fn rejects_too_many_classes() {
        let mut reg = TaskRegistry::new(2, 100, 8, 2);
        let head_w = Tensor::from_f32(&[8, 3], vec![0.0; 24]);
        let head_b = Tensor::from_f32(&[3], vec![0.0; 3]);
        assert!(reg.register_zero("big", &head_w, &head_b).is_err());
    }

    #[test]
    fn rejects_wrong_head_shape() {
        let mut reg = TaskRegistry::new(2, 100, 8, 4);
        let head_w = Tensor::zeros(DType::F32, &[7, 2]);
        let head_b = Tensor::zeros(DType::F32, &[2]);
        assert!(reg.register_zero("bad", &head_w, &head_b).is_err());
    }

    #[test]
    fn register_fc_fuses_and_serves() {
        let (l, v, d, r) = (2, 30, 8, 4);
        let mut reg = TaskRegistry::new(l, v, d, 4);
        let mut rng = crate::util::Pcg64::new(5);
        let emb = Tensor::from_f32(&[v, d], rng.normal_vec(v * d, 1.0));
        let mut tr = BTreeMap::new();
        tr.insert("t.fc.w1".into(), Tensor::from_f32(&[l, d, r], rng.normal_vec(l * d * r, 0.1)));
        tr.insert("t.fc.b1".into(), Tensor::from_f32(&[l, r], rng.normal_vec(l * r, 0.1)));
        tr.insert("t.fc.w2".into(), Tensor::from_f32(&[l, r, d], rng.normal_vec(l * r * d, 0.1)));
        tr.insert("t.fc.b2".into(), Tensor::from_f32(&[l, d], rng.normal_vec(l * d, 0.1)));
        tr.insert("t.head_w".into(), Tensor::from_f32(&[d, 2], rng.normal_vec(d * 2, 0.1)));
        tr.insert("t.head_b".into(), Tensor::from_f32(&[2], vec![0.0; 2]));
        reg.register_fc("wic", &emb, &tr).unwrap();
        // A non-degenerate table must have non-zero norms.
        let p = reg.pstore().get("wic").unwrap();
        assert!(p.row_norms(0).iter().any(|&n| n > 0.0));
    }
}
