//! Serving metrics: request latency, batch sizes, per-stage timings and
//! the split between the AoT gather and the backbone execute (the L3 perf
//! targets of DESIGN.md §9).
//!
//! Storage is bounded: distributions live in fixed-capacity ring buffers
//! (recent-window percentiles), while counts and time sums are monotonic
//! totals — under sustained traffic the metrics footprint is constant.
//! The staged pipeline additionally reports its queue depth and the
//! gather-arena reuse/alloc counters.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

use crate::json::Json;
use crate::peft::AdapterStats;
use crate::util::stats;

/// Ring capacity for each latency/size distribution (recent window).
pub const WINDOW: usize = 1024;

/// Fixed-capacity ring of f64 samples.
struct Ring {
    buf: Vec<f64>,
    cap: usize,
    next: usize,
}

impl Ring {
    fn new(cap: usize) -> Ring {
        assert!(cap > 0);
        Ring { buf: Vec::with_capacity(cap), cap, next: 0 }
    }

    fn push(&mut self, v: f64) {
        if self.buf.len() < self.cap {
            self.buf.push(v);
        } else {
            self.buf[self.next] = v;
        }
        self.next = (self.next + 1) % self.cap;
    }

    /// Samples currently held (unordered; fine for percentiles/means).
    fn window(&self) -> &[f64] {
        &self.buf
    }
}

struct MetricsInner {
    request_latencies: Ring,
    gather_secs: Ring,
    exec_secs: Ring,
    // Monotonic totals (never trimmed).
    requests_total: u64,
    batches_total: u64,
    batch_rows_total: u64,
    batch_secs_total: f64,
    gather_secs_total: f64,
    exec_secs_total: f64,
}

pub struct Metrics {
    inner: Mutex<MetricsInner>,
    /// Requests admitted but not yet answered (pipeline queue depth).
    queue_depth: AtomicUsize,
    /// Latest arena counters, copied in by the pipeline after each batch.
    arena_allocs: AtomicUsize,
    arena_reuses: AtomicUsize,
    /// Latest adapter-store residency counters (DESIGN.md §10), copied in
    /// by the pipeline after each batch.
    adapter: Mutex<AdapterStats>,
}

/// A point-in-time summary.  Counts are monotonic totals; millisecond
/// figures are over the recent [`WINDOW`]-sample ring; `gather_fraction`
/// is total gather time / total device-path time since startup.
#[derive(Clone, Debug)]
pub struct MetricsSnapshot {
    pub requests: usize,
    pub batches: usize,
    pub mean_batch_size: f64,
    pub latency_p50_ms: f64,
    pub latency_p99_ms: f64,
    pub mean_gather_ms: f64,
    pub mean_exec_ms: f64,
    /// gather / (gather + execute): must stay small — the coordinator's
    /// own work must not dominate the backbone (L3 target).
    pub gather_fraction: f64,
    /// Total wall time spent processing batches since startup.
    pub busy_secs: f64,
    /// Admitted-but-unanswered requests at snapshot time (approximate
    /// while a shutdown is racing in-flight work).
    pub queue_depth: usize,
    /// Gather-arena counters: fresh allocations (flat in steady state)
    /// and pool hits.
    pub arena_allocs: usize,
    pub arena_reuses: usize,
    /// Adapter-store residency: bytes/tasks per tier plus hit, fault,
    /// cold-serve and eviction totals (DESIGN.md §10).
    pub adapter: AdapterStats,
}

impl Metrics {
    pub fn new() -> Metrics {
        Metrics {
            inner: Mutex::new(MetricsInner {
                request_latencies: Ring::new(WINDOW),
                gather_secs: Ring::new(WINDOW),
                exec_secs: Ring::new(WINDOW),
                requests_total: 0,
                batches_total: 0,
                batch_rows_total: 0,
                batch_secs_total: 0.0,
                gather_secs_total: 0.0,
                exec_secs_total: 0.0,
            }),
            queue_depth: AtomicUsize::new(0),
            arena_allocs: AtomicUsize::new(0),
            arena_reuses: AtomicUsize::new(0),
            adapter: Mutex::new(AdapterStats::default()),
        }
    }

    pub fn observe_request(&self, latency_secs: f64) {
        let mut m = self.inner.lock().unwrap();
        m.requests_total += 1;
        m.request_latencies.push(latency_secs);
    }

    pub fn observe_batch(&self, size: usize, total: f64, gather: f64, exec: f64) {
        let mut m = self.inner.lock().unwrap();
        m.batches_total += 1;
        m.batch_rows_total += size as u64;
        m.batch_secs_total += total;
        m.gather_secs_total += gather;
        m.exec_secs_total += exec;
        m.gather_secs.push(gather);
        m.exec_secs.push(exec);
    }

    /// Pipeline bookkeeping: a request entered the queue.
    pub fn incr_queue_depth(&self) {
        self.queue_depth.fetch_add(1, Ordering::Relaxed);
    }

    /// Pipeline bookkeeping: a request was answered (ok or error).
    pub fn decr_queue_depth(&self) {
        // Saturating as a last-ditch guard; the WorkItem reply guard
        // makes increments/decrements pair exactly on every path.
        let _ = self.queue_depth.fetch_update(Ordering::Relaxed, Ordering::Relaxed, |d| {
            Some(d.saturating_sub(1))
        });
    }

    /// Copy the gather-arena counters into the exported metrics.
    pub fn set_arena_counters(&self, allocs: usize, reuses: usize) {
        self.arena_allocs.store(allocs, Ordering::Relaxed);
        self.arena_reuses.store(reuses, Ordering::Relaxed);
    }

    /// Copy the adapter-store residency counters into the exported
    /// metrics.
    pub fn set_adapter_counters(&self, stats: AdapterStats) {
        *self.adapter.lock().unwrap() = stats;
    }

    pub fn snapshot(&self) -> MetricsSnapshot {
        let m = self.inner.lock().unwrap();
        let gather_total = m.gather_secs_total;
        let exec_total = m.exec_secs_total;
        MetricsSnapshot {
            requests: m.requests_total as usize,
            batches: m.batches_total as usize,
            mean_batch_size: if m.batches_total > 0 {
                m.batch_rows_total as f64 / m.batches_total as f64
            } else {
                0.0
            },
            latency_p50_ms: stats::percentile(m.request_latencies.window(), 50.0) * 1e3,
            latency_p99_ms: stats::percentile(m.request_latencies.window(), 99.0) * 1e3,
            mean_gather_ms: stats::mean(m.gather_secs.window()) * 1e3,
            mean_exec_ms: stats::mean(m.exec_secs.window()) * 1e3,
            gather_fraction: if gather_total + exec_total > 0.0 {
                gather_total / (gather_total + exec_total)
            } else {
                0.0
            },
            busy_secs: m.batch_secs_total,
            queue_depth: self.queue_depth.load(Ordering::Relaxed),
            arena_allocs: self.arena_allocs.load(Ordering::Relaxed),
            arena_reuses: self.arena_reuses.load(Ordering::Relaxed),
            adapter: *self.adapter.lock().unwrap(),
        }
    }
}

impl Default for Metrics {
    fn default() -> Self {
        Self::new()
    }
}

impl MetricsSnapshot {
    pub fn render(&self) -> String {
        format!(
            "requests={} batches={} mean_batch={:.2} p50={:.2}ms p99={:.2}ms \
             gather={:.3}ms exec={:.3}ms gather_frac={:.1}% queue={} \
             arena_reuse={}/{} adapters={}r/{}s {:.1}MiB \
             hit={} fault={} cold={} evict={} prefetch={}h/{}m/{}w \
             dedup={:.2}x zero_rows={} \
             mmap={}o/{}f mapped={:.1}MiB cold_rows={}m/{}p \
             kernel={} gsort={}s/{}u",
            self.requests,
            self.batches,
            self.mean_batch_size,
            self.latency_p50_ms,
            self.latency_p99_ms,
            self.mean_gather_ms,
            self.mean_exec_ms,
            self.gather_fraction * 100.0,
            self.queue_depth,
            self.arena_reuses,
            self.arena_reuses + self.arena_allocs,
            self.adapter.resident_tasks,
            self.adapter.spilled_tasks,
            self.adapter.resident_bytes as f64 / (1024.0 * 1024.0),
            self.adapter.hits,
            self.adapter.faults,
            self.adapter.cold_serves,
            self.adapter.evictions,
            self.adapter.prefetch_hits,
            self.adapter.prefetch_misses,
            self.adapter.prefetch_wasted,
            self.adapter.dedup_ratio(),
            self.adapter.dedup_zero_rows,
            self.adapter.mmap_opens,
            self.adapter.mmap_fallbacks,
            self.adapter.mapped_bytes as f64 / (1024.0 * 1024.0),
            self.adapter.cold_rows_mapped,
            self.adapter.cold_rows_positioned,
            self.adapter.kernel,
            self.adapter.gather_rows_sorted,
            self.adapter.gather_rows_unsorted,
        )
    }

    /// The snapshot as a JSON document (`GET /metrics?format=json`).
    pub fn to_json(&self) -> Json {
        let n = |x: f64| Json::Num(x);
        let u = |x: usize| Json::Num(x as f64);
        let mut adapter = Json::obj();
        adapter.set("resident_bytes", u(self.adapter.resident_bytes));
        adapter.set("resident_tasks", u(self.adapter.resident_tasks));
        adapter.set("spilled_tasks", u(self.adapter.spilled_tasks));
        adapter.set("hits", u(self.adapter.hits));
        adapter.set("faults", u(self.adapter.faults));
        adapter.set("cold_serves", u(self.adapter.cold_serves));
        adapter.set("evictions", u(self.adapter.evictions));
        adapter.set("spill_writes", u(self.adapter.spill_writes));
        adapter.set("prefetch_hits", u(self.adapter.prefetch_hits));
        adapter.set("prefetch_misses", u(self.adapter.prefetch_misses));
        adapter.set("prefetch_wasted", u(self.adapter.prefetch_wasted));
        adapter.set("dedup_ratio", n(self.adapter.dedup_ratio()));
        adapter.set("dedup_zero_rows", u(self.adapter.dedup_zero_rows));
        adapter.set("mmap_opens", u(self.adapter.mmap_opens));
        adapter.set("mmap_fallbacks", u(self.adapter.mmap_fallbacks));
        adapter.set("mapped_bytes", u(self.adapter.mapped_bytes));
        adapter.set("cold_rows_mapped", u(self.adapter.cold_rows_mapped));
        adapter.set("cold_rows_positioned", u(self.adapter.cold_rows_positioned));
        adapter.set("kernel", Json::Str(self.adapter.kernel.to_string()));
        adapter.set("gather_rows_sorted", u(self.adapter.gather_rows_sorted));
        adapter.set("gather_rows_unsorted", u(self.adapter.gather_rows_unsorted));

        let mut root = Json::obj();
        root.set("requests", u(self.requests));
        root.set("batches", u(self.batches));
        root.set("mean_batch_size", n(self.mean_batch_size));
        root.set("latency_p50_ms", n(self.latency_p50_ms));
        root.set("latency_p99_ms", n(self.latency_p99_ms));
        root.set("mean_gather_ms", n(self.mean_gather_ms));
        root.set("mean_exec_ms", n(self.mean_exec_ms));
        root.set("gather_fraction", n(self.gather_fraction));
        root.set("busy_secs", n(self.busy_secs));
        root.set("queue_depth", u(self.queue_depth));
        root.set("arena_allocs", u(self.arena_allocs));
        root.set("arena_reuses", u(self.arena_reuses));
        root.set("adapter", adapter);
        root
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn snapshot_aggregates() {
        let m = Metrics::new();
        m.observe_request(0.010);
        m.observe_request(0.020);
        m.observe_batch(2, 0.015, 0.001, 0.012);
        let s = m.snapshot();
        assert_eq!(s.requests, 2);
        assert_eq!(s.batches, 1);
        assert!((s.mean_batch_size - 2.0).abs() < 1e-9);
        assert!(s.latency_p50_ms >= 10.0 && s.latency_p50_ms <= 20.0);
        assert!(s.gather_fraction > 0.0 && s.gather_fraction < 0.2);
        assert!(!s.render().is_empty());
    }

    #[test]
    fn empty_snapshot_is_zeroes() {
        let s = Metrics::new().snapshot();
        assert_eq!(s.requests, 0);
        assert_eq!(s.gather_fraction, 0.0);
        assert_eq!(s.queue_depth, 0);
    }

    #[test]
    fn rings_bound_memory_but_totals_keep_counting() {
        let m = Metrics::new();
        for i in 0..(3 * WINDOW) {
            m.observe_request(i as f64);
            m.observe_batch(1, 0.001, 0.0005, 0.0005);
        }
        let s = m.snapshot();
        // Totals are exact even though the rings dropped old samples.
        assert_eq!(s.requests, 3 * WINDOW);
        assert_eq!(s.batches, 3 * WINDOW);
        // The latency window only sees the most recent WINDOW samples.
        let oldest_kept = (2 * WINDOW) as f64;
        assert!(s.latency_p50_ms >= oldest_kept * 1e3);
        assert!((s.gather_fraction - 0.5).abs() < 1e-9);
    }

    #[test]
    fn queue_depth_saturates_at_zero() {
        let m = Metrics::new();
        m.decr_queue_depth();
        assert_eq!(m.snapshot().queue_depth, 0);
        m.incr_queue_depth();
        m.incr_queue_depth();
        m.decr_queue_depth();
        assert_eq!(m.snapshot().queue_depth, 1);
    }

    #[test]
    fn snapshot_to_json_round_trips() {
        let m = Metrics::new();
        m.observe_request(0.010);
        m.observe_batch(2, 0.015, 0.001, 0.012);
        m.incr_queue_depth();
        let s = m.snapshot();
        let doc = crate::json::parse(&s.to_json().to_string_compact()).unwrap();
        assert_eq!(doc.get("requests").and_then(Json::as_usize), Some(1));
        assert_eq!(doc.get("queue_depth").and_then(Json::as_usize), Some(1));
        assert_eq!(doc.path("adapter.kernel").and_then(Json::as_str), Some(s.adapter.kernel));
        let p50 = doc.get("latency_p50_ms").and_then(Json::as_f64).unwrap();
        assert_eq!(p50, s.latency_p50_ms, "f64 must round-trip exactly");
    }

    #[test]
    fn arena_counters_exported() {
        let m = Metrics::new();
        m.set_arena_counters(5, 95);
        let s = m.snapshot();
        assert_eq!(s.arena_allocs, 5);
        assert_eq!(s.arena_reuses, 95);
        assert!(s.render().contains("arena_reuse=95/100"));
    }

    #[test]
    fn adapter_counters_exported() {
        let m = Metrics::new();
        let stats = AdapterStats {
            resident_bytes: 3 << 20,
            resident_tasks: 2,
            spilled_tasks: 5,
            hits: 40,
            faults: 7,
            cold_serves: 3,
            evictions: 9,
            spill_writes: 5,
            prefetch_hits: 4,
            prefetch_misses: 2,
            prefetch_wasted: 1,
            dedup_logical_rows: 1000,
            dedup_stored_rows: 400,
            dedup_zero_rows: 550,
            mmap_opens: 3,
            mmap_fallbacks: 1,
            mapped_bytes: 2 << 20,
            cold_rows_mapped: 12,
            cold_rows_positioned: 34,
            kernel: "avx2",
            gather_rows_sorted: 64,
            gather_rows_unsorted: 1024,
        };
        m.set_adapter_counters(stats);
        let s = m.snapshot();
        assert_eq!(s.adapter, stats);
        assert!((s.adapter.dedup_ratio() - 2.5).abs() < 1e-12);
        let r = s.render();
        assert!(r.contains("adapters=2r/5s"), "{r}");
        assert!(r.contains("fault=7"), "{r}");
        assert!(r.contains("evict=9"), "{r}");
        assert!(r.contains("prefetch=4h/2m/1w"), "{r}");
        assert!(r.contains("dedup=2.50x"), "{r}");
        assert!(r.contains("zero_rows=550"), "{r}");
        assert!(r.contains("mmap=3o/1f"), "{r}");
        assert!(r.contains("mapped=2.0MiB"), "{r}");
        assert!(r.contains("cold_rows=12m/34p"), "{r}");
        assert!(r.contains("kernel=avx2"), "{r}");
        assert!(r.contains("gsort=64s/1024u"), "{r}");
    }
}
