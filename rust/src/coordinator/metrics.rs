//! Serving metrics: request latency, batch sizes, and the split between
//! the AoT gather and the backbone execute (the L3 perf targets of
//! DESIGN.md §9).

use std::sync::Mutex;

use crate::util::stats;

#[derive(Default)]
struct MetricsInner {
    request_latencies: Vec<f64>,
    batch_sizes: Vec<usize>,
    batch_total_secs: Vec<f64>,
    gather_secs: Vec<f64>,
    exec_secs: Vec<f64>,
}

pub struct Metrics {
    inner: Mutex<MetricsInner>,
}

/// A point-in-time summary.
#[derive(Clone, Debug)]
pub struct MetricsSnapshot {
    pub requests: usize,
    pub batches: usize,
    pub mean_batch_size: f64,
    pub latency_p50_ms: f64,
    pub latency_p99_ms: f64,
    pub mean_gather_ms: f64,
    pub mean_exec_ms: f64,
    /// gather / (gather + execute): must stay small — the coordinator's
    /// own work must not dominate the backbone (L3 target).
    pub gather_fraction: f64,
}

impl Metrics {
    pub fn new() -> Metrics {
        Metrics { inner: Mutex::new(MetricsInner::default()) }
    }

    pub fn observe_request(&self, latency_secs: f64) {
        self.inner.lock().unwrap().request_latencies.push(latency_secs);
    }

    pub fn observe_batch(&self, size: usize, total: f64, gather: f64, exec: f64) {
        let mut m = self.inner.lock().unwrap();
        m.batch_sizes.push(size);
        m.batch_total_secs.push(total);
        m.gather_secs.push(gather);
        m.exec_secs.push(exec);
    }

    pub fn snapshot(&self) -> MetricsSnapshot {
        let m = self.inner.lock().unwrap();
        let sizes: Vec<f64> = m.batch_sizes.iter().map(|&s| s as f64).collect();
        let gather_total: f64 = m.gather_secs.iter().sum();
        let exec_total: f64 = m.exec_secs.iter().sum();
        MetricsSnapshot {
            requests: m.request_latencies.len(),
            batches: m.batch_sizes.len(),
            mean_batch_size: stats::mean(&sizes),
            latency_p50_ms: stats::percentile(&m.request_latencies, 50.0) * 1e3,
            latency_p99_ms: stats::percentile(&m.request_latencies, 99.0) * 1e3,
            mean_gather_ms: stats::mean(&m.gather_secs) * 1e3,
            mean_exec_ms: stats::mean(&m.exec_secs) * 1e3,
            gather_fraction: if gather_total + exec_total > 0.0 {
                gather_total / (gather_total + exec_total)
            } else {
                0.0
            },
        }
    }
}

impl Default for Metrics {
    fn default() -> Self {
        Self::new()
    }
}

impl MetricsSnapshot {
    pub fn render(&self) -> String {
        format!(
            "requests={} batches={} mean_batch={:.2} p50={:.2}ms p99={:.2}ms \
             gather={:.3}ms exec={:.3}ms gather_frac={:.1}%",
            self.requests,
            self.batches,
            self.mean_batch_size,
            self.latency_p50_ms,
            self.latency_p99_ms,
            self.mean_gather_ms,
            self.mean_exec_ms,
            self.gather_fraction * 100.0
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn snapshot_aggregates() {
        let m = Metrics::new();
        m.observe_request(0.010);
        m.observe_request(0.020);
        m.observe_batch(2, 0.015, 0.001, 0.012);
        let s = m.snapshot();
        assert_eq!(s.requests, 2);
        assert_eq!(s.batches, 1);
        assert!((s.mean_batch_size - 2.0).abs() < 1e-9);
        assert!(s.latency_p50_ms >= 10.0 && s.latency_p50_ms <= 20.0);
        assert!(s.gather_fraction > 0.0 && s.gather_fraction < 0.2);
        assert!(!s.render().is_empty());
    }

    #[test]
    fn empty_snapshot_is_zeroes() {
        let s = Metrics::new().snapshot();
        assert_eq!(s.requests, 0);
        assert_eq!(s.gather_fraction, 0.0);
    }
}
