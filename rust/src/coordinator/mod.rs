//! The multi-task inference coordinator — the system the paper motivates
//! in §3.1 but never builds.
//!
//! One backbone executable (per bucket) serves every registered task:
//!
//! ```text
//!            ┌────────────┐   per-task fused P (host RAM)
//! requests → │  admission  │   ┌──────────────┐
//! (task,ids) │ + batch     │ → │ AoT gather    │ → [ids,mask,bias,heads]
//!            │   planning  │   │ P[l,ids,:]    │        │
//!            └────────────┘   └──────────────┘        ▼
//!                                            device execute (shared
//!                                            backbone, device-resident
//!                                            weights) → logits → fan-out
//!                                            back per request
//! ```
//!
//! * the **admission/planning** stages pack requests *from different
//!   tasks* into one batch (the paper's multi-task inference claim);
//! * the **registry** holds per-task fused `P` (the tiered adapter
//!   store: resident f32/f16 under a RAM budget, LRU-spilled to disk —
//!   DESIGN.md §10) + classification heads, hot-mutable while serving;
//! * the **gather** is the ahead-of-time lookup the method is named for,
//!   served from a reusable arena and parallel across layers;
//! * Python is nowhere on this path.
//!
//! The stages live in [`pipeline`] as named, individually testable types
//! (DESIGN.md §6); this module owns the worker thread, the linger-based
//! flush loop and the public `submit`/`classify` API.

pub mod batcher;
pub mod metrics;
pub mod pipeline;
pub mod registry;
pub mod request;

use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{channel, sync_channel, Receiver, RecvTimeoutError, Sender, SyncSender};
use std::sync::{Arc, Mutex, RwLock};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail};

use crate::config::Manifest;
use crate::runtime::Runtime;
use crate::Result;

pub use crate::peft::{AdapterConfig, AdapterDType, AdapterStats};
pub use batcher::{Bucket, BucketSet};
pub use metrics::{Metrics, MetricsSnapshot};
pub use pipeline::{
    Admission, Backend, BatchBuffers, BatchPlan, BatchPlanner, FanOut, GatherStage, HostBackend,
    Pipeline, PjrtBackend, PreparedBatch, WorkItem,
};
pub use registry::{TaskRegistry, TaskState};
pub use request::{Request, Response};

/// Coordinator configuration.
#[derive(Clone, Debug)]
pub struct CoordinatorConfig {
    pub model: String,
    /// Max time a request waits for batch-mates before the batch flushes.
    pub linger_ms: u64,
    /// Serving signature; the paper's system serves fused AoT (`"aot"`).
    pub signature: String,
    /// Gather shard threads (CLI `--gather-threads`); 0 = one per
    /// available core.
    pub gather_threads: usize,
    /// Gather-aware adapter prefetch (CLI `--prefetch`): announce each
    /// plan's tasks to the residency prefetcher before staging.
    pub prefetch: bool,
    /// Double-buffered serving: run execute + fan-out on a dedicated
    /// thread so the gather for batch N+1 overlaps the execute of batch N
    /// (DESIGN.md §11).  Off = the seed's strictly serial loop.
    pub overlap: bool,
}

impl Default for CoordinatorConfig {
    fn default() -> Self {
        CoordinatorConfig {
            model: "small".into(),
            linger_ms: 2,
            signature: "aot".into(),
            gather_threads: 0,
            prefetch: true,
            overlap: true,
        }
    }
}

/// The coordinator. `submit` is thread-safe; one worker thread owns the
/// execute loop (the PJRT CPU plugin is effectively single-streamed here)
/// and drives the staged pipeline batch by batch.
pub struct Coordinator {
    inner: Arc<Inner>,
    worker: Mutex<Option<JoinHandle<()>>>,
    /// The execute half of the overlapped pipeline (None when
    /// `cfg.overlap` is off).  Joined after the worker: the worker's exit
    /// drops the prepared-batch sender, which drains and stops this
    /// thread.
    executor: Mutex<Option<JoinHandle<()>>>,
    /// `None` once drain/shutdown closed the queue; dropping the sender
    /// is the worker's stop signal.
    tx: RwLock<Option<Sender<WorkItem>>>,
}

struct Inner {
    pipeline: Pipeline,
    registry: Arc<TaskRegistry>,
    metrics: Arc<Metrics>,
    cfg: CoordinatorConfig,
    running: AtomicBool,
    /// Cleared at the start of drain/shutdown: `submit` stops admitting
    /// while already-queued batches flush.
    accepting: AtomicBool,
}

impl Coordinator {
    /// Build a PJRT-backed coordinator for `cfg.model`: load backbone
    /// weights, discover the bucket set from the manifest and **prewarm**
    /// (compile) every bucket executable up front — the request path never
    /// touches the manifest or the compiler again.
    pub fn new(
        runtime: Arc<Runtime>,
        manifest: &Manifest,
        registry: TaskRegistry,
        cfg: CoordinatorConfig,
    ) -> Result<Coordinator> {
        let info = manifest.model(&cfg.model)?;
        if registry.d_model() != info.d_model {
            bail!(
                "registry d_model {} != model {} d_model {}",
                registry.d_model(),
                cfg.model,
                info.d_model
            );
        }
        let (backend, buckets) = PjrtBackend::prewarm(&runtime, manifest, &cfg)?;
        Self::with_backend(
            registry,
            buckets,
            manifest.multitask_classes,
            cfg,
            Arc::new(backend),
        )
    }

    /// Build a coordinator over an explicit bucket set and an arbitrary
    /// execute backend (tests and accelerator-free builds use
    /// [`HostBackend`]; production uses [`PjrtBackend`] via [`Self::new`]).
    pub fn with_backend(
        registry: TaskRegistry,
        buckets: Vec<Bucket>,
        classes: usize,
        cfg: CoordinatorConfig,
        backend: Arc<dyn Backend>,
    ) -> Result<Coordinator> {
        if buckets.is_empty() {
            bail!("coordinator needs at least one serving bucket");
        }
        let registry = Arc::new(registry);
        let metrics = Arc::new(Metrics::new());
        let gather_threads = if cfg.gather_threads > 0 {
            cfg.gather_threads
        } else {
            std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
        };
        let pipeline = Pipeline::new(
            Arc::clone(&registry),
            buckets,
            classes,
            backend,
            Arc::clone(&metrics),
            gather_threads,
            cfg.prefetch,
        );

        let (tx, rx) = channel::<WorkItem>();
        let inner = Arc::new(Inner {
            pipeline,
            registry,
            metrics,
            cfg,
            running: AtomicBool::new(true),
            accepting: AtomicBool::new(true),
        });
        // The two-slot overlap queue: capacity 1 means one batch can sit
        // prepared while another executes — exactly two arena checkouts in
        // flight, which bounds staging memory to double buffering.
        let (prepared_tx, executor) = if inner.cfg.overlap {
            let (ptx, prx) = sync_channel::<PreparedBatch>(1);
            let exec_inner = Arc::clone(&inner);
            let handle = std::thread::Builder::new()
                .name("aotpt-execute".into())
                .spawn(move || {
                    while let Ok(prepared) = prx.recv() {
                        // Contain fan-out/registry panics: the unwound
                        // batch's reply guards answer every item and the
                        // execute thread keeps serving.  (Backend panics
                        // are already converted to batch errors inside
                        // `complete`.)
                        let inner = Arc::clone(&exec_inner);
                        let _ = catch_unwind(AssertUnwindSafe(move || {
                            inner.pipeline.complete(prepared)
                        }));
                    }
                })
                .expect("spawn execute worker");
            (Some(ptx), Some(handle))
        } else {
            (None, None)
        };
        let worker_inner = Arc::clone(&inner);
        let worker = std::thread::Builder::new()
            .name("aotpt-coordinator".into())
            .spawn(move || worker_loop(worker_inner, rx, prepared_tx))
            .expect("spawn coordinator worker");

        Ok(Coordinator {
            inner,
            worker: Mutex::new(Some(worker)),
            executor: Mutex::new(executor),
            tx: RwLock::new(Some(tx)),
        })
    }

    /// Submit a request; returns a receiver for the response.
    pub fn submit(&self, request: Request) -> Result<Receiver<Result<Response>>> {
        if !self.inner.running.load(Ordering::SeqCst) {
            bail!("coordinator is shut down");
        }
        if !self.inner.accepting.load(Ordering::SeqCst) {
            bail!("coordinator is draining; not accepting new requests");
        }
        self.inner.pipeline.admission.admit(&request)?;
        let (respond, receiver) = channel();
        // The gauge is incremented here and decremented exactly once by
        // the item's first reply — fan-out, error path, or the drop guard
        // if shutdown lands between admission and the flush.
        self.inner.metrics.incr_queue_depth();
        let item = WorkItem::tracked(request, respond, Arc::clone(&self.inner.metrics));
        let sent = {
            let tx = self.tx.read().unwrap();
            match tx.as_ref() {
                // On send failure the item rides back in the error and
                // drops: the guard answers it and settles the gauge.
                Some(tx) => tx.send(item).is_ok(),
                None => false,
            }
        };
        if !sent {
            bail!("coordinator worker exited");
        }
        Ok(receiver)
    }

    /// Convenience: synchronous classify (no deadline).
    pub fn classify(&self, task: &str, ids: Vec<i32>) -> Result<Response> {
        self.classify_deadline(task, ids, None)
    }

    /// Synchronous classify with an optional reply deadline.  `None`
    /// blocks until the coordinator answers (every admitted item is
    /// answered, even across worker panics and shutdown — the `WorkItem`
    /// reply guard); `Some(d)` fails with a deadline error after `d`.
    pub fn classify_deadline(
        &self,
        task: &str,
        ids: Vec<i32>,
        deadline: Option<Duration>,
    ) -> Result<Response> {
        let rx = self.submit(Request { task: task.to_string(), ids })?;
        match deadline {
            None => rx.recv().map_err(|_| anyhow!("coordinator dropped the request"))?,
            Some(d) => match rx.recv_timeout(d) {
                Ok(result) => result,
                Err(RecvTimeoutError::Timeout) => {
                    bail!("deadline exceeded after {}ms", d.as_millis())
                }
                Err(RecvTimeoutError::Disconnected) => {
                    bail!("coordinator dropped the request")
                }
            },
        }
    }

    pub fn metrics(&self) -> &Metrics {
        self.inner.metrics.as_ref()
    }

    pub fn registry(&self) -> &TaskRegistry {
        self.inner.registry.as_ref()
    }

    /// The staged pipeline (stage-level introspection: arena counters,
    /// bucket limits, backend name).
    pub fn pipeline(&self) -> &Pipeline {
        &self.inner.pipeline
    }

    /// Graceful drain: stop admitting, close the queue, and let the
    /// worker serve everything already admitted before joining it (the
    /// worker's exit drops the prepared-batch sender, which drains and
    /// stops the execute thread).  Every admitted request is answered and
    /// the queue-depth gauge reads 0 afterwards.  Idempotent, and safe to
    /// interleave with `shutdown` (the joins are take-once).
    pub fn drain(&self) {
        self.inner.accepting.store(false, Ordering::SeqCst);
        // Closing the channel is the drain signal: the worker keeps
        // flushing batches until `recv` reports disconnected + empty.
        drop(self.tx.write().unwrap().take());
        if let Some(handle) = self.worker.lock().unwrap().take() {
            let _ = handle.join();
        }
        if let Some(handle) = self.executor.lock().unwrap().take() {
            let _ = handle.join();
        }
        self.inner.running.store(false, Ordering::SeqCst);
    }

    /// Hard stop: mark not-running (the worker breaks at the next batch
    /// boundary instead of flushing the backlog), close the queue and
    /// join.  Residual queued items are answered "shut down" by their
    /// reply guards when the queue drops — each decrements the gauge
    /// exactly once, so it still settles to 0.
    pub fn shutdown(&self) {
        if !self.inner.running.swap(false, Ordering::SeqCst) {
            return;
        }
        self.inner.accepting.store(false, Ordering::SeqCst);
        drop(self.tx.write().unwrap().take());
        if let Some(handle) = self.worker.lock().unwrap().take() {
            let _ = handle.join();
        }
        if let Some(handle) = self.executor.lock().unwrap().take() {
            let _ = handle.join();
        }
    }
}

impl Drop for Coordinator {
    fn drop(&mut self) {
        self.shutdown();
    }
}

fn worker_loop(
    inner: Arc<Inner>,
    rx: Receiver<WorkItem>,
    prepared_tx: Option<SyncSender<PreparedBatch>>,
) {
    let linger = std::time::Duration::from_millis(inner.cfg.linger_ms);
    let max_batch = inner.pipeline.max_batch();
    loop {
        // Block for the first item.
        let first = match rx.recv() {
            Ok(item) => item,
            Err(_) => break,
        };
        if !inner.running.load(Ordering::SeqCst) {
            break;
        }
        let mut pending = vec![first];
        // Linger to accumulate batch-mates, bounded by the largest bucket.
        let deadline = Instant::now() + linger;
        while pending.len() < max_batch {
            let now = Instant::now();
            if now >= deadline {
                break;
            }
            match rx.recv_timeout(deadline - now) {
                Ok(item) => {
                    if !inner.running.load(Ordering::SeqCst) {
                        return;
                    }
                    pending.push(item);
                }
                Err(_) => break,
            }
        }
        match &prepared_tx {
            // Overlapped: hand the gathered batch to the execute thread
            // and immediately return to accumulate + gather the next one.
            // The two-slot queue applies backpressure once one batch is
            // executing and another is already prepared.
            Some(ptx) => {
                // A panic inside `prepare` unwinds through the items —
                // their drop guards answer every request — and the worker
                // keeps serving instead of orphaning the queue.
                let prepared =
                    catch_unwind(AssertUnwindSafe(|| inner.pipeline.prepare(pending)));
                if let Ok(Some(prepared)) = prepared {
                    if let Err(send_err) = ptx.send(prepared) {
                        let e = anyhow!("coordinator execute thread exited");
                        inner.pipeline.abort(send_err.0, &e);
                    }
                }
            }
            // Serial (overlap off): both halves inline, the seed behavior.
            None => {
                let _ = catch_unwind(AssertUnwindSafe(|| inner.pipeline.process(pending)));
            }
        }
        if !inner.running.load(Ordering::SeqCst) {
            break;
        }
    }
}
