//! The multi-task inference coordinator — the system the paper motivates
//! in §3.1 but never builds.
//!
//! One backbone executable (per bucket) serves every registered task:
//!
//! ```text
//!            ┌────────────┐   per-task fused P (host RAM)
//! requests → │   router    │   ┌──────────────┐
//! (task,ids) │  + batcher  │ → │ AoT gather    │ → [ids,mask,bias,heads]
//!            │ cross-task  │   │ P[l,ids,:]    │        │
//!            └────────────┘   └──────────────┘        ▼
//!                                            PJRT executable (shared
//!                                            backbone, device-resident
//!                                            weights) → logits → split
//!                                            back per request
//! ```
//!
//! * the **router/batcher** packs requests *from different tasks* into one
//!   batch (the paper's multi-task inference claim);
//! * the **registry** holds per-task fused `P` (RAM) + classification
//!   heads;
//! * the **gather** is the ahead-of-time lookup the method is named for;
//! * Python is nowhere on this path.

pub mod batcher;
pub mod metrics;
pub mod registry;
pub mod request;

use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::Instant;

use anyhow::{anyhow, bail};

use crate::config::Manifest;
use crate::runtime::{Executable, Runtime, WeightCache};
use crate::tensor::Tensor;
use crate::tokenizer::PAD;
use crate::Result;

pub use batcher::{Bucket, BucketSet};
pub use metrics::Metrics;
pub use registry::{TaskRegistry, TaskState};
pub use request::{Request, Response};

/// Coordinator configuration.
#[derive(Clone, Debug)]
pub struct CoordinatorConfig {
    pub model: String,
    /// Max time a request waits for batch-mates before the batch flushes.
    pub linger_ms: u64,
    /// Serving signature; the paper's system serves fused AoT (`"aot"`).
    pub signature: String,
}

impl Default for CoordinatorConfig {
    fn default() -> Self {
        CoordinatorConfig { model: "small".into(), linger_ms: 2, signature: "aot".into() }
    }
}

/// The coordinator. `submit` is thread-safe; one worker thread owns the
/// PJRT execute loop (the CPU plugin is effectively single-streamed here).
pub struct Coordinator {
    inner: Arc<Inner>,
    worker: Mutex<Option<JoinHandle<()>>>,
    tx: Sender<WorkItem>,
}

struct Inner {
    runtime: Arc<Runtime>,
    weights: WeightCache,
    registry: TaskRegistry,
    buckets: BucketSet,
    executables: Mutex<HashMap<(usize, usize), Arc<Executable>>>,
    manifest_dir: std::path::PathBuf,
    stems: HashMap<(usize, usize), String>,
    cfg: CoordinatorConfig,
    metrics: Metrics,
    running: AtomicBool,
    d_model: usize,
    classes: usize,
}

struct WorkItem {
    request: Request,
    enqueued: Instant,
    respond: Sender<Result<Response>>,
}

impl Coordinator {
    /// Build a coordinator for `cfg.model`, loading backbone weights and
    /// discovering the bucket set from the manifest.
    pub fn new(
        runtime: Arc<Runtime>,
        manifest: &Manifest,
        registry: TaskRegistry,
        cfg: CoordinatorConfig,
    ) -> Result<Coordinator> {
        let info = manifest.model(&cfg.model)?;
        let weights = WeightCache::from_ckpt(
            &runtime,
            &manifest.dir.join(format!("backbone_{}.aotckpt", cfg.model)),
        )?;

        // Discover serving buckets + artifact stems for this signature.
        let mut stems = HashMap::new();
        let mut buckets = Vec::new();
        for a in manifest.find("fwd", &cfg.model, &cfg.signature) {
            buckets.push(Bucket { batch: a.batch, seq: a.seq });
            stems.insert((a.batch, a.seq), a.stem.clone());
        }
        if buckets.is_empty() {
            bail!("no fwd_{}_{} artifacts in manifest", cfg.model, cfg.signature);
        }

        let (tx, rx) = channel::<WorkItem>();
        let inner = Arc::new(Inner {
            runtime,
            weights,
            registry,
            buckets: BucketSet::new(buckets),
            executables: Mutex::new(HashMap::new()),
            manifest_dir: manifest.dir.clone(),
            stems,
            metrics: Metrics::new(),
            running: AtomicBool::new(true),
            d_model: info.d_model,
            classes: manifest.multitask_classes,
            cfg,
        });

        let worker_inner = Arc::clone(&inner);
        let worker = std::thread::Builder::new()
            .name("aotpt-coordinator".into())
            .spawn(move || worker_loop(worker_inner, rx))
            .expect("spawn coordinator worker");

        Ok(Coordinator { inner, worker: Mutex::new(Some(worker)), tx })
    }

    /// Submit a request; returns a receiver for the response.
    pub fn submit(&self, request: Request) -> Result<Receiver<Result<Response>>> {
        if !self.inner.running.load(Ordering::SeqCst) {
            bail!("coordinator is shut down");
        }
        self.inner.registry.get(&request.task)?; // fail fast on unknown task
        if request.ids.is_empty() || request.ids.len() > self.inner.buckets.max_seq() {
            bail!(
                "request length {} outside (0, {}]",
                request.ids.len(),
                self.inner.buckets.max_seq()
            );
        }
        let (respond, receiver) = channel();
        self.tx
            .send(WorkItem { request, enqueued: Instant::now(), respond })
            .map_err(|_| anyhow!("coordinator worker exited"))?;
        Ok(receiver)
    }

    /// Convenience: synchronous classify.
    pub fn classify(&self, task: &str, ids: Vec<i32>) -> Result<Response> {
        let rx = self.submit(Request { task: task.to_string(), ids })?;
        rx.recv().map_err(|_| anyhow!("coordinator dropped the request"))?
    }

    pub fn metrics(&self) -> &Metrics {
        &self.inner.metrics
    }

    pub fn registry(&self) -> &TaskRegistry {
        &self.inner.registry
    }

    /// Stop the worker and join it.
    pub fn shutdown(&self) {
        if !self.inner.running.swap(false, Ordering::SeqCst) {
            return;
        }
        if let Some(handle) = self.worker.lock().unwrap().take() {
            // Wake the worker with a sentinel so it observes `running=false`.
            let (fake_tx, _) = channel();
            let _ = self.tx.send(WorkItem {
                request: Request { task: String::new(), ids: vec![] },
                enqueued: Instant::now(),
                respond: fake_tx,
            });
            let _ = handle.join();
        }
    }
}

impl Drop for Coordinator {
    fn drop(&mut self) {
        self.shutdown();
    }
}

fn worker_loop(inner: Arc<Inner>, rx: Receiver<WorkItem>) {
    let linger = std::time::Duration::from_millis(inner.cfg.linger_ms);
    loop {
        // Block for the first item.
        let first = match rx.recv() {
            Ok(item) => item,
            Err(_) => break,
        };
        if !inner.running.load(Ordering::SeqCst) {
            break;
        }
        let mut pending = vec![first];
        // Linger to accumulate batch-mates, bounded by the largest bucket.
        let deadline = Instant::now() + linger;
        while pending.len() < inner.buckets.max_batch() {
            let now = Instant::now();
            if now >= deadline {
                break;
            }
            match rx.recv_timeout(deadline - now) {
                Ok(item) => {
                    if !inner.running.load(Ordering::SeqCst) {
                        return;
                    }
                    pending.push(item);
                }
                Err(_) => break,
            }
        }
        execute_batch(&inner, pending);
        if !inner.running.load(Ordering::SeqCst) {
            break;
        }
    }
}

fn execute_batch(inner: &Arc<Inner>, items: Vec<WorkItem>) {
    let t_batch = Instant::now();
    match build_and_run(inner, &items) {
        Ok((logits, bucket, gather_secs, exec_secs)) => {
            let classes = inner.classes;
            for (j, item) in items.iter().enumerate() {
                let row = &logits[j * classes..(j + 1) * classes];
                let state = inner.registry.get(&item.request.task).expect("validated");
                let response = Response {
                    logits: row[..state.classes].to_vec(),
                    task: item.request.task.clone(),
                    batch_size: items.len(),
                    bucket_batch: bucket.batch,
                    bucket_seq: bucket.seq,
                };
                inner
                    .metrics
                    .observe_request(item.enqueued.elapsed().as_secs_f64());
                let _ = item.respond.send(Ok(response));
            }
            inner.metrics.observe_batch(
                items.len(),
                t_batch.elapsed().as_secs_f64(),
                gather_secs,
                exec_secs,
            );
        }
        Err(e) => {
            let msg = format!("{e:#}");
            for item in items {
                let _ = item.respond.send(Err(anyhow!("{msg}")));
            }
        }
    }
}

/// Assemble the bucket inputs and run the backbone once for the batch.
#[allow(clippy::type_complexity)]
fn build_and_run(
    inner: &Arc<Inner>,
    items: &[WorkItem],
) -> Result<(Vec<f32>, Bucket, f64, f64)> {
    let count = items.len();
    let max_len = items.iter().map(|i| i.request.ids.len()).max().unwrap_or(1);
    let bucket = inner.buckets.select(count, max_len)?;
    let (b, n) = (bucket.batch, bucket.seq);
    let d = inner.d_model;
    let classes = inner.classes;

    // Pad ids/mask to the bucket; surplus rows repeat row 0's task with an
    // all-PAD sequence (their logits are dropped after execute).
    let mut ids = vec![PAD; b * n];
    let mut mask = vec![0f32; b * n];
    let mut assignments: Vec<&str> = Vec::with_capacity(b);
    for (j, item) in items.iter().enumerate() {
        let req = &item.request;
        for (t, &tok) in req.ids.iter().enumerate() {
            ids[j * n + t] = tok;
            mask[j * n + t] = 1.0;
        }
        assignments.push(&req.task);
    }
    let filler_task = items[0].request.task.as_str();
    for _ in count..b {
        assignments.push(filler_task);
    }

    // Heads: [b, d, C] / [b, C], zero-padded to the multitask class count.
    let mut head_w = vec![0f32; b * d * classes];
    let mut head_b = vec![0f32; b * classes];
    for (j, task) in assignments.iter().enumerate() {
        let state = inner.registry.get(task)?;
        for di in 0..d {
            let src = &state.head_w[di * state.classes..(di + 1) * state.classes];
            head_w[(j * d + di) * classes..(j * d + di) * classes + state.classes]
                .copy_from_slice(src);
        }
        head_b[j * classes..j * classes + state.classes].copy_from_slice(&state.head_b);
    }

    // THE ahead-of-time gather (paper Equation 1's serving form).
    let t_gather = Instant::now();
    let bias = inner.registry.pstore().gather(&assignments, &ids, n)?;
    let gather_secs = t_gather.elapsed().as_secs_f64();

    let exe = load_bucket(inner, bucket)?;

    // Assemble positional args: weights from the device cache, per-call
    // tensors uploaded here.
    let ids_t = Tensor::from_i32(&[b, n], ids);
    let mask_t = Tensor::from_f32(&[b, n], mask);
    let head_w_t = Tensor::from_f32(&[b, d, classes], head_w);
    let head_b_t = Tensor::from_f32(&[b, classes], head_b);

    let mut uploads = Vec::new();
    for spec in &exe.spec.inputs {
        let host: Option<&Tensor> = match spec.name.as_str() {
            "in.ids" => Some(&ids_t),
            "in.mask" => Some(&mask_t),
            "in.bias" => Some(&bias),
            "in.head_w" => Some(&head_w_t),
            "in.head_b" => Some(&head_b_t),
            _ => None,
        };
        match host {
            Some(t) => uploads.push(Some(exe.upload(t)?)),
            None => uploads.push(None),
        }
    }
    let mut args: Vec<&xla::PjRtBuffer> = Vec::with_capacity(exe.spec.inputs.len());
    for (spec, upload) in exe.spec.inputs.iter().zip(&uploads) {
        match upload {
            Some(buf) => args.push(buf),
            None => {
                let name = spec
                    .name
                    .strip_prefix("w.")
                    .ok_or_else(|| anyhow!("unexpected serving input {}", spec.name))?;
                args.push(inner.weights.buffer(name)?);
            }
        }
    }

    let t_exec = Instant::now();
    let outs = exe.run_buffers(&args)?;
    let exec_secs = t_exec.elapsed().as_secs_f64();

    let logits = outs[0].as_f32()?.to_vec();
    Ok((logits, bucket, gather_secs, exec_secs))
}

fn load_bucket(inner: &Arc<Inner>, bucket: Bucket) -> Result<Arc<Executable>> {
    let key = (bucket.batch, bucket.seq);
    if let Some(exe) = inner.executables.lock().unwrap().get(&key) {
        return Ok(Arc::clone(exe));
    }
    let stem = inner
        .stems
        .get(&key)
        .ok_or_else(|| anyhow!("no artifact for bucket b{}n{}", bucket.batch, bucket.seq))?;
    let manifest = Manifest::load(&inner.manifest_dir)?;
    let exe = inner.runtime.load(&manifest, stem)?;
    inner
        .executables
        .lock()
        .unwrap()
        .insert(key, Arc::clone(&exe));
    Ok(exe)
}
