//! Request/response types for the serving API.  A `Request` enters the
//! pipeline through the admission stage (`pipeline::Admission`); the
//! matching `Response` leaves through the fan-out stage.

/// A classification request: token ids already packed (`[CLS] … [SEP]`,
/// unpadded — the batcher pads to the chosen bucket).
#[derive(Clone, Debug)]
pub struct Request {
    pub task: String,
    pub ids: Vec<i32>,
}

/// The response: per-class logits for the request's task.
#[derive(Clone, Debug)]
pub struct Response {
    pub logits: Vec<f32>,
    pub task: String,
    /// How many live requests shared the backbone invocation.
    pub batch_size: usize,
    /// The (batch, seq) bucket that served the request.
    pub bucket_batch: usize,
    pub bucket_seq: usize,
}

impl Response {
    pub fn argmax(&self) -> i64 {
        self.logits
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .map(|(i, _)| i as i64)
            .unwrap_or(0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn argmax_picks_largest() {
        let r = Response {
            logits: vec![0.1, 2.0, -1.0],
            task: "t".into(),
            batch_size: 1,
            bucket_batch: 1,
            bucket_seq: 16,
        };
        assert_eq!(r.argmax(), 1);
    }
}
