//! Request/response types for the serving API.  A `Request` enters the
//! pipeline through the admission stage (`pipeline::Admission`); the
//! matching `Response` leaves through the fan-out stage.  The JSON
//! conversions here are the wire format of `POST /v1/classify`.

use crate::json::Json;

/// A classification request: token ids already packed (`[CLS] … [SEP]`,
/// unpadded — the batcher pads to the chosen bucket).
#[derive(Clone, Debug)]
pub struct Request {
    pub task: String,
    pub ids: Vec<i32>,
}

impl Request {
    /// Parse the `/v1/classify` body: `{"task": "...", "ids": [...]}`.
    /// Returns a client-facing message on malformed input.
    pub fn from_json(doc: &Json) -> std::result::Result<Request, String> {
        let task = doc
            .get("task")
            .and_then(Json::as_str)
            .ok_or_else(|| "missing or non-string field \"task\"".to_string())?;
        let ids_json = doc
            .get("ids")
            .and_then(Json::as_arr)
            .ok_or_else(|| "missing or non-array field \"ids\"".to_string())?;
        let mut ids = Vec::with_capacity(ids_json.len());
        for (i, v) in ids_json.iter().enumerate() {
            let x = v.as_f64().ok_or_else(|| format!("ids[{i}] is not a number"))?;
            if x.fract() != 0.0 || x < i32::MIN as f64 || x > i32::MAX as f64 {
                return Err(format!("ids[{i}] = {x} is not an i32 token id"));
            }
            ids.push(x as i32);
        }
        Ok(Request { task: task.to_string(), ids })
    }
}

/// The response: per-class logits for the request's task.
#[derive(Clone, Debug)]
pub struct Response {
    pub logits: Vec<f32>,
    pub task: String,
    /// How many live requests shared the backbone invocation.
    pub batch_size: usize,
    /// The (batch, seq) bucket that served the request.
    pub bucket_batch: usize,
    pub bucket_seq: usize,
}

impl Response {
    /// The `/v1/classify` response body.  Logits are emitted through f64
    /// (exact for every f32), so a client parsing them back to f32 sees
    /// bit-identical values to in-process `classify`.
    pub fn to_json(&self) -> Json {
        let mut out = Json::obj();
        out.set("task", Json::Str(self.task.clone()));
        out.set(
            "logits",
            Json::Arr(self.logits.iter().map(|&x| Json::Num(x as f64)).collect()),
        );
        out.set("argmax", Json::Num(self.argmax() as f64));
        out.set("batch_size", Json::Num(self.batch_size as f64));
        out.set("bucket_batch", Json::Num(self.bucket_batch as f64));
        out.set("bucket_seq", Json::Num(self.bucket_seq as f64));
        out
    }

    pub fn argmax(&self) -> i64 {
        self.logits
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .map(|(i, _)| i as i64)
            .unwrap_or(0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn argmax_picks_largest() {
        let r = Response {
            logits: vec![0.1, 2.0, -1.0],
            task: "t".into(),
            batch_size: 1,
            bucket_batch: 1,
            bucket_seq: 16,
        };
        assert_eq!(r.argmax(), 1);
    }

    #[test]
    fn request_from_json_parses_and_rejects() {
        let doc = crate::json::parse(r#"{"task":"sst2","ids":[1,2,3]}"#).unwrap();
        let req = Request::from_json(&doc).unwrap();
        assert_eq!(req.task, "sst2");
        assert_eq!(req.ids, vec![1, 2, 3]);

        for bad in [
            r#"{"ids":[1]}"#,
            r#"{"task":"t"}"#,
            r#"{"task":"t","ids":"nope"}"#,
            r#"{"task":"t","ids":[1.5]}"#,
            r#"{"task":"t","ids":[3000000000]}"#,
        ] {
            let doc = crate::json::parse(bad).unwrap();
            assert!(Request::from_json(&doc).is_err(), "{bad}");
        }
    }

    #[test]
    fn response_json_logits_round_trip_bit_exactly() {
        let r = Response {
            logits: vec![0.1, -2.25, 3.0e-8],
            task: "t".into(),
            batch_size: 2,
            bucket_batch: 4,
            bucket_seq: 16,
        };
        let doc = crate::json::parse(&r.to_json().to_string_compact()).unwrap();
        let back: Vec<f32> = doc
            .get("logits")
            .and_then(Json::as_arr)
            .unwrap()
            .iter()
            .map(|v| v.as_f64().unwrap() as f32)
            .collect();
        assert_eq!(back.len(), r.logits.len());
        for (a, b) in back.iter().zip(&r.logits) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        assert_eq!(doc.get("argmax").and_then(Json::as_i64), Some(0));
    }
}
