//! The staged serving pipeline (SionFlowRT-style explicit stages):
//!
//! ```text
//! admission → batch planning → AoT gather → device execute → fan-out
//! ```
//!
//! Each stage is a named type so it can be unit-tested, property-tested
//! and benchmarked on its own (DESIGN.md §6):
//!
//! * [`Admission`] — rejects unknown tasks and out-of-range lengths at
//!   submit time, before anything is queued;
//! * [`BatchPlanner`] — selects the serving bucket for a set of pending
//!   requests ([`BatchPlan`]) and stages ids/mask/heads into reusable
//!   [`BatchBuffers`];
//! * [`GatherStage`] — the ahead-of-time P-row gather (paper §3.3),
//!   parallel across layers and skipping filler rows;
//! * [`Backend`] — the device execute, behind a trait: [`PjrtBackend`]
//!   runs prewarmed PJRT executables, [`HostBackend`] is a deterministic
//!   CPU reference used by tests and accelerator-free builds;
//! * [`FanOut`] — splits batch logits back into per-request responses.
//!
//! All large host staging buffers come from a [`GatherArena`], so the
//! steady-state hot path performs no heap allocation (DESIGN.md §9).
//!
//! The pipeline is split at the gather/execute boundary for overlapped
//! serving (DESIGN.md §11): [`Pipeline::prepare`] runs plan → prefetch →
//! stage → gather and returns a [`PreparedBatch`]; [`Pipeline::complete`]
//! runs execute → fan-out.  The coordinator runs `complete` on a
//! dedicated execute thread, so the gather for batch N+1 overlaps the
//! backbone execute for batch N with two arena checkouts in flight.
//! [`Pipeline::process`] chains both for the serial path and tests.

use std::collections::HashMap;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::Sender;
use std::sync::Arc;
use std::time::Instant;

use anyhow::{anyhow, bail};

use crate::config::Manifest;
use crate::peft::{GatherArena, GatherPool};
use crate::runtime::{Executable, Runtime, WeightCache};
use crate::tokenizer::PAD;
use crate::Result;

use super::batcher::{Bucket, BucketSet};
use super::metrics::Metrics;
use super::registry::TaskRegistry;
use super::request::{Request, Response};
use super::CoordinatorConfig;

/// One queued request plus its response channel.
///
/// The reply path is guarded: [`WorkItem::reply`] delivers at most one
/// result per item and settles the queue-depth gauge exactly once, and
/// the `Drop` impl answers anything still unreplied — so a panicking
/// worker or a hard shutdown can drop items anywhere on the pipeline
/// without hanging the submitter or leaking the gauge.
pub struct WorkItem {
    request: Request,
    enqueued: Instant,
    respond: Sender<Result<Response>>,
    /// Present on the tracked `submit` path: the gauge that was
    /// incremented at admission and must be decremented exactly once.
    metrics: Option<Arc<Metrics>>,
    replied: AtomicBool,
}

impl WorkItem {
    /// An untracked item (tests, benches, direct pipeline callers): no
    /// queue-depth accounting.
    pub fn new(request: Request, respond: Sender<Result<Response>>) -> WorkItem {
        WorkItem {
            request,
            enqueued: Instant::now(),
            respond,
            metrics: None,
            replied: AtomicBool::new(false),
        }
    }

    /// A gauge-tracked item (the coordinator's `submit` path): the caller
    /// has already incremented the queue-depth gauge; the first reply —
    /// fan-out, error path, or the drop guard — decrements it.
    pub fn tracked(
        request: Request,
        respond: Sender<Result<Response>>,
        metrics: Arc<Metrics>,
    ) -> WorkItem {
        WorkItem {
            request,
            enqueued: Instant::now(),
            respond,
            metrics: Some(metrics),
            replied: AtomicBool::new(false),
        }
    }

    pub fn request(&self) -> &Request {
        &self.request
    }

    pub fn enqueued(&self) -> Instant {
        self.enqueued
    }

    /// Deliver `result` unless this item was already answered.  The first
    /// call wins: it settles the gauge and sends; later calls (e.g. the
    /// drop guard after a clean fan-out) are no-ops.
    pub fn reply(&self, result: Result<Response>) {
        if self.replied.swap(true, Ordering::SeqCst) {
            return;
        }
        if let Some(metrics) = &self.metrics {
            metrics.decr_queue_depth();
        }
        let _ = self.respond.send(result);
    }
}

impl Drop for WorkItem {
    fn drop(&mut self) {
        self.reply(Err(anyhow!(
            "request dropped without a reply (coordinator shut down or worker panicked)"
        )));
    }
}

/// Best-effort text from a `catch_unwind` payload.
pub(crate) fn panic_message(payload: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = payload.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else {
        "non-string panic payload".to_string()
    }
}

/// The batch-planning decision for one flush: which bucket serves the
/// pending requests, and which task each live row belongs to.  Filler
/// rows (indices `live()..bucket.batch`) carry no task — they are skipped
/// by the gather and their logits are dropped by the fan-out.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct BatchPlan {
    pub bucket: Bucket,
    /// Task of each live row, in submission order.
    pub tasks: Vec<String>,
}

impl BatchPlan {
    pub fn live(&self) -> usize {
        self.tasks.len()
    }
}

/// Reusable host staging buffers for one bucket, checked out of the
/// [`GatherArena`] per batch and checked back in after the execute.
pub struct BatchBuffers {
    pub bucket: Bucket,
    pub layers: usize,
    pub d_model: usize,
    /// The multitask class-pad width (serving artifact's head shape).
    pub classes: usize,
    /// `[b, n]` token ids, PAD-filled outside live tokens.
    pub ids: Vec<i32>,
    /// `[b, n]` attention mask (1.0 over live tokens).
    pub mask: Vec<f32>,
    /// `[l, b, n, d]` gathered AoT bias; filler rows may hold stale
    /// (finite) values from earlier batches — backbone rows are
    /// independent, and filler logits are dropped.
    pub bias: Vec<f32>,
    /// `[b, d, classes]` per-row head weights, zero-padded.
    pub head_w: Vec<f32>,
    /// `[b, classes]` per-row head biases, zero-padded.
    pub head_b: Vec<f32>,
}

/// Stage 1: admission control, run on the submitter's thread.
pub struct Admission {
    registry: Arc<TaskRegistry>,
    max_seq: usize,
}

impl Admission {
    pub fn new(registry: Arc<TaskRegistry>, max_seq: usize) -> Admission {
        Admission { registry, max_seq }
    }

    /// Fail fast on unknown tasks and lengths no bucket can hold.
    pub fn admit(&self, request: &Request) -> Result<()> {
        self.registry.get(&request.task)?;
        if request.ids.is_empty() || request.ids.len() > self.max_seq {
            bail!(
                "request length {} outside (0, {}]",
                request.ids.len(),
                self.max_seq
            );
        }
        Ok(())
    }
}

/// Stage 2: bucket selection + host-side batch assembly.
pub struct BatchPlanner {
    buckets: BucketSet,
    registry: Arc<TaskRegistry>,
}

impl BatchPlanner {
    pub fn new(buckets: BucketSet, registry: Arc<TaskRegistry>) -> BatchPlanner {
        BatchPlanner { buckets, registry }
    }

    pub fn buckets(&self) -> &BucketSet {
        &self.buckets
    }

    /// Pure planning: pick the minimal bucket that fits the pending
    /// requests and record each live row's task.
    pub fn plan(&self, requests: &[&Request]) -> Result<BatchPlan> {
        if requests.is_empty() {
            bail!("cannot plan an empty batch");
        }
        let max_len = requests.iter().map(|r| r.ids.len()).max().unwrap_or(1);
        let bucket = self.buckets.select(requests.len(), max_len)?;
        Ok(BatchPlan {
            bucket,
            tasks: requests.iter().map(|r| r.task.clone()).collect(),
        })
    }

    /// Stage ids, mask and per-row heads into the buffers.  Every region
    /// this stage owns is overwritten in full (ids/mask over the whole
    /// bucket, heads zero-padded per row), so reused arena buffers never
    /// leak previous batches into the inputs.
    pub fn stage(
        &self,
        plan: &BatchPlan,
        requests: &[&Request],
        bufs: &mut BatchBuffers,
    ) -> Result<()> {
        let (b, n) = (plan.bucket.batch, plan.bucket.seq);
        let (d, classes) = (bufs.d_model, bufs.classes);
        if requests.len() != plan.live() {
            bail!("stage: {} requests for a plan of {}", requests.len(), plan.live());
        }
        if plan.live() > b {
            bail!("stage: {} live rows exceed bucket batch {b}", plan.live());
        }

        bufs.ids.fill(PAD);
        bufs.mask.fill(0.0);
        for (j, req) in requests.iter().enumerate() {
            if req.ids.len() > n {
                bail!("stage: request length {} exceeds bucket seq {n}", req.ids.len());
            }
            bufs.ids[j * n..j * n + req.ids.len()].copy_from_slice(&req.ids);
            bufs.mask[j * n..j * n + req.ids.len()].fill(1.0);
        }

        // Heads: [b, d, C] / [b, C], zero-padded to the multitask class
        // count; filler rows stay all-zero.
        bufs.head_w.fill(0.0);
        bufs.head_b.fill(0.0);
        for (j, task) in plan.tasks.iter().enumerate() {
            let state = self.registry.get(task)?;
            for di in 0..d {
                let src = &state.head_w[di * state.classes..(di + 1) * state.classes];
                bufs.head_w[(j * d + di) * classes..(j * d + di) * classes + state.classes]
                    .copy_from_slice(src);
            }
            bufs.head_b[j * classes..j * classes + state.classes]
                .copy_from_slice(&state.head_b);
        }
        Ok(())
    }
}

/// Stage 3: THE ahead-of-time gather (paper Equation 1's serving form),
/// layer-sharded across a persistent [`GatherPool`] (spawned once here,
/// parked between batches — no per-batch thread creation), skipping
/// filler rows.
pub struct GatherStage {
    registry: Arc<TaskRegistry>,
    pool: GatherPool,
}

impl GatherStage {
    pub fn new(registry: Arc<TaskRegistry>, threads: usize) -> GatherStage {
        GatherStage { registry, pool: GatherPool::new(threads) }
    }

    pub fn threads(&self) -> usize {
        self.pool.threads()
    }

    pub fn gather(&self, plan: &BatchPlan, bufs: &mut BatchBuffers) -> Result<()> {
        let (b, n) = (bufs.bucket.batch, bufs.bucket.seq);
        let assignments: Vec<&str> = plan.tasks.iter().map(String::as_str).collect();
        self.registry.pstore().gather_batch_pooled(
            &assignments,
            &bufs.ids,
            n,
            b,
            &self.pool,
            &mut bufs.bias,
        )
    }
}

/// Stage 4: the device execute, behind a trait so the pipeline can run
/// against PJRT hardware or a host reference interchangeably.
pub trait Backend: Send + Sync {
    /// Run the backbone for one staged batch; returns flat logits
    /// `[bucket.batch * classes]` (filler rows included, dropped later).
    fn execute(&self, plan: &BatchPlan, bufs: &BatchBuffers) -> Result<Vec<f32>>;

    /// Human-readable backend name (metrics/logs).
    fn name(&self) -> &'static str;
}

/// PJRT-backed execute: device-resident backbone weights + prewarmed
/// (compiled-at-startup) per-bucket executables.  No manifest re-reads
/// and no compilation ever happen on the request path.
pub struct PjrtBackend {
    weights: WeightCache,
    executables: HashMap<(usize, usize), Arc<Executable>>,
}

impl PjrtBackend {
    /// The prewarm stage: load backbone weights onto the device and
    /// compile every serving bucket of `(cfg.model, cfg.signature)` once,
    /// up front.  Returns the backend plus the discovered bucket set.
    pub fn prewarm(
        runtime: &Arc<Runtime>,
        manifest: &Manifest,
        cfg: &CoordinatorConfig,
    ) -> Result<(PjrtBackend, Vec<Bucket>)> {
        let weights = WeightCache::from_ckpt(
            runtime,
            &manifest.dir.join(format!("backbone_{}.aotckpt", cfg.model)),
        )?;
        let mut buckets = Vec::new();
        let mut executables = HashMap::new();
        for a in manifest.find("fwd", &cfg.model, &cfg.signature) {
            let exe = runtime.load(manifest, &a.stem)?;
            buckets.push(Bucket { batch: a.batch, seq: a.seq });
            executables.insert((a.batch, a.seq), exe);
        }
        if buckets.is_empty() {
            bail!("no fwd_{}_{} artifacts in manifest", cfg.model, cfg.signature);
        }
        Ok((PjrtBackend { weights, executables }, buckets))
    }

    /// Compiled bucket executables (all of them, after prewarm).
    pub fn bucket_count(&self) -> usize {
        self.executables.len()
    }
}

impl Backend for PjrtBackend {
    fn execute(&self, _plan: &BatchPlan, bufs: &BatchBuffers) -> Result<Vec<f32>> {
        let (b, n) = (bufs.bucket.batch, bufs.bucket.seq);
        let (l, d, classes) = (bufs.layers, bufs.d_model, bufs.classes);
        let exe = self
            .executables
            .get(&(b, n))
            .ok_or_else(|| anyhow!("no prewarmed executable for bucket b{b}n{n}"))?;

        // Per-call tensors are uploaded straight from the arena buffers;
        // weights come from the device-resident cache.
        let mut uploads = Vec::with_capacity(exe.spec.inputs.len());
        for spec in &exe.spec.inputs {
            let upload = match spec.name.as_str() {
                "in.ids" => Some(exe.upload_i32(&[b, n], &bufs.ids)?),
                "in.mask" => Some(exe.upload_f32(&[b, n], &bufs.mask)?),
                "in.bias" => Some(exe.upload_f32(&[l, b, n, d], &bufs.bias)?),
                "in.head_w" => Some(exe.upload_f32(&[b, d, classes], &bufs.head_w)?),
                "in.head_b" => Some(exe.upload_f32(&[b, classes], &bufs.head_b)?),
                _ => None,
            };
            uploads.push(upload);
        }
        let mut args: Vec<&xla::PjRtBuffer> = Vec::with_capacity(exe.spec.inputs.len());
        for (spec, upload) in exe.spec.inputs.iter().zip(&uploads) {
            match upload {
                Some(buf) => args.push(buf),
                None => {
                    let name = spec
                        .name
                        .strip_prefix("w.")
                        .ok_or_else(|| anyhow!("unexpected serving input {}", spec.name))?;
                    args.push(self.weights.buffer(name)?);
                }
            }
        }
        let outs = exe.run_buffers(&args)?;
        Ok(outs[0].as_f32()?.to_vec())
    }

    fn name(&self) -> &'static str {
        "pjrt"
    }
}

/// Deterministic host reference backend: a fixed pseudo-embedding bag
/// model over unmasked tokens, plus the summed AoT bias, projected
/// through the per-row head.  Rows are computed independently and masked
/// positions are skipped entirely, so a row's logits are bit-identical
/// whether it is served solo or packed into any mixed batch — exactly the
/// invariant the concurrency tests assert.
pub struct HostBackend;

impl HostBackend {
    fn pseudo_embed(tok: i32, k: usize) -> f32 {
        ((tok as f32) * 0.013).sin() / (k as f32 + 1.0)
    }
}

impl Backend for HostBackend {
    fn execute(&self, plan: &BatchPlan, bufs: &BatchBuffers) -> Result<Vec<f32>> {
        let (b, n) = (bufs.bucket.batch, bufs.bucket.seq);
        let (l, d, classes) = (bufs.layers, bufs.d_model, bufs.classes);
        let mut logits = vec![0f32; b * classes];
        let mut h = vec![0f32; d];
        for j in 0..plan.live() {
            h.fill(0.0);
            for t in 0..n {
                if bufs.mask[j * n + t] == 0.0 {
                    continue;
                }
                let tok = bufs.ids[j * n + t];
                for (k, hk) in h.iter_mut().enumerate() {
                    let mut bias_sum = 0.0f32;
                    for layer in 0..l {
                        bias_sum += bufs.bias[((layer * b + j) * n + t) * d + k];
                    }
                    *hk += Self::pseudo_embed(tok, k) + bias_sum;
                }
            }
            for c in 0..classes {
                let mut acc = bufs.head_b[j * classes + c];
                for (k, hk) in h.iter().enumerate() {
                    acc += hk * bufs.head_w[(j * d + k) * classes + c];
                }
                logits[j * classes + c] = acc;
            }
        }
        Ok(logits)
    }

    fn name(&self) -> &'static str {
        "host-reference"
    }
}

/// Stage 5: split batch logits into per-request responses.
pub struct FanOut {
    registry: Arc<TaskRegistry>,
    metrics: Arc<Metrics>,
    classes: usize,
}

impl FanOut {
    pub fn new(registry: Arc<TaskRegistry>, metrics: Arc<Metrics>, classes: usize) -> FanOut {
        FanOut { registry, metrics, classes }
    }

    pub fn respond(&self, plan: &BatchPlan, items: &[WorkItem], logits: &[f32]) {
        for (j, item) in items.iter().enumerate() {
            let result = self.registry.get(&item.request.task).map(|state| {
                let row = &logits[j * self.classes..(j + 1) * self.classes];
                Response {
                    logits: row[..state.classes].to_vec(),
                    task: item.request.task.clone(),
                    batch_size: items.len(),
                    bucket_batch: plan.bucket.batch,
                    bucket_seq: plan.bucket.seq,
                }
            });
            self.metrics.observe_request(item.enqueued.elapsed().as_secs_f64());
            item.reply(result);
        }
    }

    /// Deliver one error to every pending item of a failed batch.
    pub fn respond_error(&self, items: &[WorkItem], error: &anyhow::Error) {
        let msg = format!("{error:#}");
        for item in items {
            item.reply(Err(anyhow!("{msg}")));
        }
    }
}

/// A batch that finished the host-side half of the pipeline (plan →
/// stage → gather) and is ready for execute + fan-out.  This is the
/// two-slot handoff object between the coordinator worker (running
/// [`Pipeline::prepare`]) and the execute thread (running
/// [`Pipeline::complete`]) — while it sits in the queue, its arena
/// checkout stays in flight, which is exactly the double-buffering
/// (DESIGN.md §11).
pub struct PreparedBatch {
    plan: BatchPlan,
    items: Vec<WorkItem>,
    bufs: BatchBuffers,
    t_batch: Instant,
    gather_secs: f64,
}

/// The assembled pipeline: owns every stage, the arena and the metrics.
pub struct Pipeline {
    pub admission: Admission,
    planner: BatchPlanner,
    gather: GatherStage,
    backend: Arc<dyn Backend>,
    fanout: FanOut,
    arena: GatherArena,
    metrics: Arc<Metrics>,
    registry: Arc<TaskRegistry>,
    layers: usize,
    d_model: usize,
    classes: usize,
    /// Announce each plan's tasks to the adapter prefetcher (gather-aware
    /// prefetch, DESIGN.md §11).
    prefetch: bool,
}

impl Pipeline {
    pub fn new(
        registry: Arc<TaskRegistry>,
        buckets: Vec<Bucket>,
        classes: usize,
        backend: Arc<dyn Backend>,
        metrics: Arc<Metrics>,
        gather_threads: usize,
        prefetch: bool,
    ) -> Pipeline {
        let buckets = BucketSet::new(buckets);
        let max_seq = buckets.max_seq();
        Pipeline {
            admission: Admission::new(Arc::clone(&registry), max_seq),
            planner: BatchPlanner::new(buckets, Arc::clone(&registry)),
            gather: GatherStage::new(Arc::clone(&registry), gather_threads),
            backend,
            fanout: FanOut::new(Arc::clone(&registry), Arc::clone(&metrics), classes),
            arena: GatherArena::new(),
            metrics,
            layers: registry.layers(),
            d_model: registry.d_model(),
            registry,
            classes,
            prefetch,
        }
    }

    pub fn max_batch(&self) -> usize {
        self.planner.buckets().max_batch()
    }

    pub fn max_seq(&self) -> usize {
        self.planner.buckets().max_seq()
    }

    pub fn backend_name(&self) -> &'static str {
        self.backend.name()
    }

    pub fn arena(&self) -> &GatherArena {
        &self.arena
    }

    /// Run one flushed batch through planning → gather → execute →
    /// fan-out, recording stage timings and arena counters.  The serial
    /// path (`overlap = off`, direct callers, tests): both pipeline
    /// halves back to back on the calling thread.
    pub fn process(&self, items: Vec<WorkItem>) {
        if let Some(prepared) = self.prepare(items) {
            self.complete(prepared);
        }
    }

    /// The host-side half: liveness filter → plan → adapter prefetch →
    /// stage → gather.  Returns `None` when nothing reached the gather
    /// (every item failed); failed items have already been answered.
    ///
    /// The returned [`PreparedBatch`] owns an arena checkout — it must be
    /// handed to [`Pipeline::complete`] (or [`Pipeline::abort`] if the
    /// execute side is gone) so the buffers return to the arena.
    pub fn prepare(&self, items: Vec<WorkItem>) -> Option<PreparedBatch> {
        let t_batch = Instant::now();
        // The hot task lifecycle means a task can be unregistered between
        // admission and this flush: fail only that task's requests here,
        // instead of letting the gather error poison the whole mixed
        // batch.  (A failure *inside* the stages — e.g. a disk-tier read
        // error — still fails the batch; those are not request-specific.)
        let mut live = Vec::with_capacity(items.len());
        for item in items {
            match self.registry.get(&item.request.task) {
                Ok(_) => live.push(item),
                Err(e) => self.fanout.respond_error(std::slice::from_ref(&item), &e),
            }
        }
        if live.is_empty() {
            self.publish_counters();
            return None;
        }
        let plan = {
            let requests: Vec<&Request> = live.iter().map(|i| &i.request).collect();
            match self.planner.plan(&requests) {
                Ok(plan) => plan,
                Err(e) => {
                    self.fanout.respond_error(&live, &e);
                    self.publish_counters();
                    return None;
                }
            }
        };
        // The moment the plan knows the batch's tasks, wake the adapter
        // prefetcher so spilled tables fault in while we stage the batch
        // — the gather's resolve then finds them warm (DESIGN.md §11).
        if self.prefetch {
            self.registry.pstore().prefetch(&plan.tasks);
        }
        let mut bufs = self.checkout(plan.bucket);
        let staged: Result<f64> = (|| {
            let requests: Vec<&Request> = live.iter().map(|i| &i.request).collect();
            self.planner.stage(&plan, &requests, &mut bufs)?;
            let t_gather = Instant::now();
            self.gather.gather(&plan, &mut bufs)?;
            Ok(t_gather.elapsed().as_secs_f64())
        })();
        match staged {
            Ok(gather_secs) => {
                Some(PreparedBatch { plan, items: live, bufs, t_batch, gather_secs })
            }
            Err(e) => {
                // Buffers go back to the arena on failure, too.
                self.check_in(bufs);
                self.fanout.respond_error(&live, &e);
                self.publish_counters();
                None
            }
        }
    }

    /// The device-side half: execute → fan-out.  Runs on the coordinator's
    /// execute thread under overlap, or inline for the serial path.
    pub fn complete(&self, prepared: PreparedBatch) {
        let PreparedBatch { plan, items, bufs, t_batch, gather_secs } = prepared;
        let t_exec = Instant::now();
        // A panicking backend must not take the execute thread (and every
        // waiting submitter) down with it: contain the unwind and fail
        // the batch like any other execute error.
        let executed = catch_unwind(AssertUnwindSafe(|| self.backend.execute(&plan, &bufs)))
            .unwrap_or_else(|payload| {
                Err(anyhow!("backend panicked: {}", panic_message(payload.as_ref())))
            });
        let exec_secs = t_exec.elapsed().as_secs_f64();
        // The checkout returns before any response is delivered, so a
        // submitter unblocked by the fan-out observes the same arena
        // steady state as the serial pipeline.
        self.check_in(bufs);
        match executed {
            Ok(logits) => {
                self.fanout.respond(&plan, &items, &logits);
                self.metrics.observe_batch(
                    items.len(),
                    t_batch.elapsed().as_secs_f64(),
                    gather_secs,
                    exec_secs,
                );
            }
            Err(e) => self.fanout.respond_error(&items, &e),
        }
        self.publish_counters();
    }

    /// Fail a prepared batch without executing it (the execute side went
    /// away mid-shutdown): buffers return to the arena, every item gets
    /// the error.
    pub fn abort(&self, prepared: PreparedBatch, error: &anyhow::Error) {
        self.check_in(prepared.bufs);
        self.fanout.respond_error(&prepared.items, error);
        self.publish_counters();
    }

    fn publish_counters(&self) {
        self.metrics.set_arena_counters(self.arena.allocs(), self.arena.reuses());
        self.metrics.set_adapter_counters(self.registry.adapter_stats());
    }

    /// Check a full buffer set out of the arena for one bucket.
    pub fn checkout(&self, bucket: Bucket) -> BatchBuffers {
        let (b, n) = (bucket.batch, bucket.seq);
        let (l, d, c) = (self.layers, self.d_model, self.classes);
        BatchBuffers {
            bucket,
            layers: l,
            d_model: d,
            classes: c,
            ids: self.arena.take_i32(b, n, "ids", b * n),
            mask: self.arena.take_f32(b, n, "mask", b * n),
            bias: self.arena.take_f32(b, n, "bias", l * b * n * d),
            head_w: self.arena.take_f32(b, n, "head_w", b * d * c),
            head_b: self.arena.take_f32(b, n, "head_b", b * c),
        }
    }

    /// Return a buffer set to the arena.
    pub fn check_in(&self, bufs: BatchBuffers) {
        let (b, n) = (bufs.bucket.batch, bufs.bucket.seq);
        self.arena.put_i32(b, n, "ids", bufs.ids);
        self.arena.put_f32(b, n, "mask", bufs.mask);
        self.arena.put_f32(b, n, "bias", bufs.bias);
        self.arena.put_f32(b, n, "head_w", bufs.head_w);
        self.arena.put_f32(b, n, "head_b", bufs.head_b);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::Tensor;

    fn registry(layers: usize, vocab: usize, d: usize, classes: usize) -> Arc<TaskRegistry> {
        let reg = TaskRegistry::new(layers, vocab, d, classes);
        let head_w = Tensor::from_f32(&[d, 2], vec![0.1; d * 2]);
        let head_b = Tensor::from_f32(&[2], vec![0.5, -0.5]);
        reg.register_zero("a", &head_w, &head_b).unwrap();
        reg.register_zero("b", &head_w, &head_b).unwrap();
        Arc::new(reg)
    }

    fn buckets() -> Vec<Bucket> {
        vec![
            Bucket { batch: 1, seq: 8 },
            Bucket { batch: 4, seq: 8 },
            Bucket { batch: 4, seq: 16 },
        ]
    }

    fn pipeline() -> Pipeline {
        let reg = registry(2, 50, 4, 3);
        Pipeline::new(
            reg,
            buckets(),
            3,
            Arc::new(HostBackend),
            Arc::new(Metrics::new()),
            2,
            true,
        )
    }

    #[test]
    fn admission_rejects_unknown_and_oversize() {
        let p = pipeline();
        assert!(p.admission.admit(&Request { task: "a".into(), ids: vec![1, 2] }).is_ok());
        assert!(p.admission.admit(&Request { task: "nope".into(), ids: vec![1] }).is_err());
        assert!(p.admission.admit(&Request { task: "a".into(), ids: vec![] }).is_err());
        assert!(p.admission.admit(&Request { task: "a".into(), ids: vec![1; 17] }).is_err());
    }

    #[test]
    fn planner_selects_bucket_and_stages_rows() {
        let reg = registry(2, 50, 4, 3);
        let planner = BatchPlanner::new(BucketSet::new(buckets()), Arc::clone(&reg));
        let r1 = Request { task: "a".into(), ids: vec![1, 2, 3] };
        let r2 = Request { task: "b".into(), ids: vec![4, 5] };
        let reqs = [&r1, &r2];
        let plan = planner.plan(&reqs).unwrap();
        assert_eq!(plan.bucket, Bucket { batch: 4, seq: 8 });
        assert_eq!(plan.tasks, vec!["a".to_string(), "b".to_string()]);
        assert_eq!(plan.live(), 2);

        let p = pipeline();
        let mut bufs = p.checkout(plan.bucket);
        // Poison the reusable regions to prove staging overwrites them.
        bufs.ids.fill(77);
        bufs.mask.fill(5.0);
        bufs.head_w.fill(9.0);
        planner.stage(&plan, &reqs, &mut bufs).unwrap();
        assert_eq!(&bufs.ids[..3], &[1, 2, 3]);
        assert_eq!(bufs.ids[3], PAD);
        assert_eq!(&bufs.ids[8..10], &[4, 5]);
        assert_eq!(&bufs.mask[..4], &[1.0, 1.0, 1.0, 0.0]);
        // Row 2 and 3 are filler: fully PAD / zero.
        assert!(bufs.ids[16..].iter().all(|&i| i == PAD));
        assert!(bufs.mask[16..].iter().all(|&m| m == 0.0));
        // Heads: classes=2 packed into the 3-wide pad; third column zero.
        assert_eq!(bufs.head_b[0], 0.5);
        assert_eq!(bufs.head_b[2], 0.0);
        assert!(bufs.head_w[2 * 4 * 3..].iter().all(|&x| x == 0.0));
    }

    #[test]
    fn planner_rejects_mismatched_stage_inputs() {
        let reg = registry(2, 50, 4, 3);
        let planner = BatchPlanner::new(BucketSet::new(buckets()), Arc::clone(&reg));
        let r1 = Request { task: "a".into(), ids: vec![1] };
        let plan = planner.plan(&[&r1]).unwrap();
        let p = pipeline();
        let mut bufs = p.checkout(plan.bucket);
        let r2 = Request { task: "b".into(), ids: vec![2] };
        assert!(planner.stage(&plan, &[&r1, &r2], &mut bufs).is_err());
    }

    #[test]
    fn host_backend_rows_are_independent() {
        let p = pipeline();
        let r1 = Request { task: "a".into(), ids: vec![7, 9] };
        let r2 = Request { task: "b".into(), ids: vec![3, 4, 5] };

        let solo = |req: &Request| -> Vec<f32> {
            let plan = p.planner.plan(&[req]).unwrap();
            let mut bufs = p.checkout(plan.bucket);
            p.planner.stage(&plan, &[req], &mut bufs).unwrap();
            p.gather.gather(&plan, &mut bufs).unwrap();
            let logits = p.backend.execute(&plan, &bufs).unwrap();
            p.check_in(bufs);
            logits[..p.classes].to_vec()
        };
        let solo1 = solo(&r1);
        let solo2 = solo(&r2);

        let plan = p.planner.plan(&[&r1, &r2]).unwrap();
        let mut bufs = p.checkout(plan.bucket);
        p.planner.stage(&plan, &[&r1, &r2], &mut bufs).unwrap();
        p.gather.gather(&plan, &mut bufs).unwrap();
        let mixed = p.backend.execute(&plan, &bufs).unwrap();
        p.check_in(bufs);

        assert_eq!(&mixed[..p.classes], &solo1[..], "row 0 changed in a mixed batch");
        assert_eq!(&mixed[p.classes..2 * p.classes], &solo2[..], "row 1 changed");
    }

    #[test]
    fn vanished_task_fails_only_its_own_requests() {
        // A task can disappear between admission and the flush (hot
        // unregister); its requests error individually while the rest of
        // the batch still serves.
        let p = pipeline();
        let (tx_a, rx_a) = std::sync::mpsc::channel();
        let (tx_bad, rx_bad) = std::sync::mpsc::channel();
        let items = vec![
            WorkItem::new(Request { task: "a".into(), ids: vec![1, 2] }, tx_a),
            WorkItem::new(Request { task: "ghost".into(), ids: vec![3] }, tx_bad),
        ];
        p.process(items);
        let ok = rx_a.recv().unwrap().unwrap();
        assert_eq!(ok.logits.len(), 2);
        let err = rx_bad.recv().unwrap().unwrap_err();
        assert!(err.to_string().contains("unknown task"), "{err}");
    }

    #[test]
    fn prepare_complete_split_matches_process_and_abort_returns_buffers() {
        let p = pipeline();
        let mk = |task: &str, ids: Vec<i32>| {
            let (tx, rx) = std::sync::mpsc::channel();
            let item = WorkItem::new(Request { task: task.into(), ids }, tx);
            (item, rx)
        };
        // Warm the arena through the chained path.
        let (item, rx) = mk("a", vec![1, 2]);
        p.process(vec![item]);
        let want = rx.recv().unwrap().unwrap();
        let allocs = p.arena().allocs();
        // The split path produces identical logits with no fresh allocs.
        let (item, rx) = mk("a", vec![1, 2]);
        let prepared = p.prepare(vec![item]).unwrap();
        p.complete(prepared);
        assert_eq!(rx.recv().unwrap().unwrap().logits, want.logits);
        assert_eq!(p.arena().allocs(), allocs);
        // Abort delivers the error and still returns the checkout.
        let (item, rx) = mk("a", vec![1, 2]);
        let prepared = p.prepare(vec![item]).unwrap();
        p.abort(prepared, &anyhow!("execute thread exited"));
        let err = rx.recv().unwrap().unwrap_err();
        assert!(err.to_string().contains("execute thread exited"), "{err}");
        assert_eq!(p.arena().allocs(), allocs);
    }

    #[test]
    fn dropped_item_replies_once_and_settles_gauge() {
        let metrics = Arc::new(Metrics::new());
        metrics.incr_queue_depth();
        let (tx, rx) = std::sync::mpsc::channel();
        let item = WorkItem::tracked(
            Request { task: "a".into(), ids: vec![1] },
            tx,
            Arc::clone(&metrics),
        );
        drop(item);
        let err = rx.recv().unwrap().unwrap_err();
        assert!(err.to_string().contains("dropped without a reply"), "{err}");
        assert_eq!(metrics.snapshot().queue_depth, 0);

        // An answered item decrements exactly once: the drop guard after a
        // clean reply is a no-op.
        metrics.incr_queue_depth();
        let (tx, rx) = std::sync::mpsc::channel();
        let item = WorkItem::tracked(
            Request { task: "a".into(), ids: vec![1] },
            tx,
            Arc::clone(&metrics),
        );
        item.reply(Err(anyhow!("first")));
        drop(item);
        let err = rx.recv().unwrap().unwrap_err();
        assert!(err.to_string().contains("first"), "{err}");
        assert!(rx.recv().is_err(), "second reply must not be delivered");
        assert_eq!(metrics.snapshot().queue_depth, 0);
    }

    struct PanickingBackend;

    impl Backend for PanickingBackend {
        fn execute(&self, _plan: &BatchPlan, _bufs: &BatchBuffers) -> Result<Vec<f32>> {
            panic!("synthetic backend crash");
        }

        fn name(&self) -> &'static str {
            "panicking"
        }
    }

    #[test]
    fn backend_panic_fails_the_batch_instead_of_unwinding() {
        let reg = registry(2, 50, 4, 3);
        let p = Pipeline::new(
            reg,
            buckets(),
            3,
            Arc::new(PanickingBackend),
            Arc::new(Metrics::new()),
            1,
            false,
        );
        let (tx, rx) = std::sync::mpsc::channel();
        let item = WorkItem::new(Request { task: "a".into(), ids: vec![1, 2] }, tx);
        p.process(vec![item]);
        let err = rx.recv().unwrap().unwrap_err();
        assert!(err.to_string().contains("backend panicked"), "{err}");
        assert!(err.to_string().contains("synthetic backend crash"), "{err}");
    }

    #[test]
    fn checkout_reuses_after_check_in() {
        let p = pipeline();
        let bucket = Bucket { batch: 4, seq: 8 };
        let before = p.arena().allocs();
        let bufs = p.checkout(bucket);
        p.check_in(bufs);
        assert_eq!(p.arena().allocs(), before + 5);
        let bufs = p.checkout(bucket);
        p.check_in(bufs);
        assert_eq!(p.arena().allocs(), before + 5, "second checkout must not allocate");
        assert!(p.arena().reuses() >= 5);
    }
}
