//! Reader/writer for the `aotckpt` binary format (see
//! `python/compile/ckpt.py` for the authoritative layout).

use std::collections::BTreeMap;
use std::io::{Read, Write};
use std::path::Path;

use anyhow::{bail, Context};

use super::{DType, Tensor};
use crate::Result;

const MAGIC: &[u8; 4] = b"ACKP";
const VERSION: u32 = 1;

/// Load every tensor in a checkpoint.
pub fn load(path: &Path) -> Result<BTreeMap<String, Tensor>> {
    let mut f = std::io::BufReader::new(
        std::fs::File::open(path).with_context(|| format!("open {}", path.display()))?,
    );
    let mut magic = [0u8; 4];
    f.read_exact(&mut magic)?;
    if &magic != MAGIC {
        bail!("{}: not an aotckpt file", path.display());
    }
    let version = read_u32(&mut f)?;
    if version != VERSION {
        bail!("{}: unsupported version {version}", path.display());
    }
    let count = read_u32(&mut f)?;
    let mut out = BTreeMap::new();
    for _ in 0..count {
        let name_len = read_u16(&mut f)? as usize;
        let mut name_buf = vec![0u8; name_len];
        f.read_exact(&mut name_buf)?;
        let name = String::from_utf8(name_buf)?;
        let mut meta = [0u8; 2];
        f.read_exact(&mut meta)?;
        let dtype = DType::from_code(meta[0])?;
        let ndim = meta[1] as usize;
        let mut shape = Vec::with_capacity(ndim);
        for _ in 0..ndim {
            shape.push(read_u32(&mut f)? as usize);
        }
        let nbytes = read_u64(&mut f)? as usize;
        let mut data = vec![0u8; nbytes];
        f.read_exact(&mut data)?;
        out.insert(name, Tensor::from_raw(dtype, shape, data)?);
    }
    Ok(out)
}

/// Save tensors (sorted by name for determinism).
pub fn save(path: &Path, tensors: &BTreeMap<String, Tensor>) -> Result<()> {
    if let Some(dir) = path.parent() {
        std::fs::create_dir_all(dir)?;
    }
    let mut f = std::io::BufWriter::new(
        std::fs::File::create(path).with_context(|| format!("create {}", path.display()))?,
    );
    f.write_all(MAGIC)?;
    f.write_all(&VERSION.to_le_bytes())?;
    f.write_all(&(tensors.len() as u32).to_le_bytes())?;
    for (name, t) in tensors {
        let nb = name.as_bytes();
        f.write_all(&(nb.len() as u16).to_le_bytes())?;
        f.write_all(nb)?;
        f.write_all(&[t.dtype.code(), t.shape.len() as u8])?;
        for &d in &t.shape {
            f.write_all(&(d as u32).to_le_bytes())?;
        }
        f.write_all(&(t.bytes().len() as u64).to_le_bytes())?;
        f.write_all(t.bytes())?;
    }
    Ok(())
}

fn read_u16(f: &mut impl Read) -> Result<u16> {
    let mut b = [0u8; 2];
    f.read_exact(&mut b)?;
    Ok(u16::from_le_bytes(b))
}

fn read_u32(f: &mut impl Read) -> Result<u32> {
    let mut b = [0u8; 4];
    f.read_exact(&mut b)?;
    Ok(u32::from_le_bytes(b))
}

fn read_u64(f: &mut impl Read) -> Result<u64> {
    let mut b = [0u8; 8];
    f.read_exact(&mut b)?;
    Ok(u64::from_le_bytes(b))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip() {
        let dir = std::env::temp_dir().join("aotpt_ckpt_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("t.aotckpt");
        let mut tensors = BTreeMap::new();
        tensors.insert("a".to_string(), Tensor::from_f32(&[2, 2], vec![1.0, 2.0, 3.0, 4.0]));
        tensors.insert("b.ids".to_string(), Tensor::from_i32(&[3], vec![7, 8, 9]));
        tensors.insert("scalar".to_string(), Tensor::scalar_f32(0.5));
        save(&path, &tensors).unwrap();
        let back = load(&path).unwrap();
        assert_eq!(back.len(), 3);
        assert_eq!(back["a"].as_f32().unwrap(), &[1.0, 2.0, 3.0, 4.0]);
        assert_eq!(back["a"].shape, vec![2, 2]);
        assert_eq!(back["b.ids"].as_i32().unwrap(), &[7, 8, 9]);
        assert_eq!(back["scalar"].shape, Vec::<usize>::new());
    }

    #[test]
    fn rejects_bad_magic() {
        let dir = std::env::temp_dir().join("aotpt_ckpt_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("bad.aotckpt");
        std::fs::write(&path, b"NOPE....").unwrap();
        assert!(load(&path).is_err());
    }
}
