//! Reader/writer for the `aotckpt` binary format (see
//! `python/compile/ckpt.py` for the authoritative layout).

use std::collections::BTreeMap;
use std::io::{BufReader, Read, Seek, Write};
use std::path::Path;

use anyhow::{bail, Context};

use super::{DType, Tensor};
use crate::Result;

const MAGIC: &[u8; 4] = b"ACKP";
const VERSION: u32 = 1;

/// Fixed header bytes: magic + version + tensor count.
const HEADER_LEN: u64 = 12;

/// Load every tensor in a checkpoint.
pub fn load(path: &Path) -> Result<BTreeMap<String, Tensor>> {
    let mut f = std::io::BufReader::new(
        std::fs::File::open(path).with_context(|| format!("open {}", path.display()))?,
    );
    let mut magic = [0u8; 4];
    f.read_exact(&mut magic)?;
    if &magic != MAGIC {
        bail!("{}: not an aotckpt file", path.display());
    }
    let version = read_u32(&mut f)?;
    if version != VERSION {
        bail!("{}: unsupported version {version}", path.display());
    }
    let count = read_u32(&mut f)?;
    let mut out = BTreeMap::new();
    for _ in 0..count {
        let name_len = read_u16(&mut f)? as usize;
        let mut name_buf = vec![0u8; name_len];
        f.read_exact(&mut name_buf)?;
        let name = String::from_utf8(name_buf)?;
        let mut meta = [0u8; 2];
        f.read_exact(&mut meta)?;
        let dtype = DType::from_code(meta[0])?;
        let ndim = meta[1] as usize;
        let mut shape = Vec::with_capacity(ndim);
        for _ in 0..ndim {
            shape.push(read_u32(&mut f)? as usize);
        }
        let nbytes = read_u64(&mut f)? as usize;
        let mut data = vec![0u8; nbytes];
        f.read_exact(&mut data)?;
        out.insert(name, Tensor::from_raw(dtype, shape, data)?);
    }
    Ok(out)
}

/// Save tensors (sorted by name for determinism).
pub fn save(path: &Path, tensors: &BTreeMap<String, Tensor>) -> Result<()> {
    if let Some(dir) = path.parent() {
        std::fs::create_dir_all(dir)?;
    }
    let mut f = std::io::BufWriter::new(
        std::fs::File::create(path).with_context(|| format!("create {}", path.display()))?,
    );
    f.write_all(MAGIC)?;
    f.write_all(&VERSION.to_le_bytes())?;
    f.write_all(&(tensors.len() as u32).to_le_bytes())?;
    for (name, t) in tensors {
        let nb = name.as_bytes();
        f.write_all(&(nb.len() as u16).to_le_bytes())?;
        f.write_all(nb)?;
        f.write_all(&[t.dtype.code(), t.shape.len() as u8])?;
        for &d in &t.shape {
            f.write_all(&(d as u32).to_le_bytes())?;
        }
        f.write_all(&(t.bytes().len() as u64).to_le_bytes())?;
        f.write_all(t.bytes())?;
    }
    Ok(())
}

/// Where one tensor's payload lives inside a checkpoint file — the
/// adapter disk tier (`peft::residency::ColdTable`) serves rows from an
/// mmap slice, or by positioned I/O, at `data_offset` without loading
/// the table.
#[derive(Clone, Debug)]
pub struct TensorEntryMeta {
    pub dtype: DType,
    pub shape: Vec<usize>,
    /// Absolute byte offset of the payload within the file.
    pub data_offset: u64,
    pub data_len: u64,
}

/// Find `name` in a checkpoint without reading any tensor payload.
///
/// The located payload extent is validated against the file's length, so
/// a truncated file is a typed error here — before anyone maps it and
/// faults, or positioned-reads into EOF halfway through a gather.
pub fn locate(path: &Path, name: &str) -> Result<TensorEntryMeta> {
    let file = std::fs::File::open(path).with_context(|| format!("open {}", path.display()))?;
    let file_len = file.metadata()?.len();
    let mut f = BufReader::new(file);
    let mut magic = [0u8; 4];
    f.read_exact(&mut magic)?;
    if &magic != MAGIC {
        bail!("{}: not an aotckpt file", path.display());
    }
    let version = read_u32(&mut f)?;
    if version != VERSION {
        bail!("{}: unsupported version {version}", path.display());
    }
    let count = read_u32(&mut f)?;
    let mut offset = HEADER_LEN;
    for _ in 0..count {
        let name_len = read_u16(&mut f)? as usize;
        let mut name_buf = vec![0u8; name_len];
        f.read_exact(&mut name_buf)?;
        let entry_name = String::from_utf8(name_buf)?;
        let mut meta = [0u8; 2];
        f.read_exact(&mut meta)?;
        let dtype = DType::from_code(meta[0])?;
        let ndim = meta[1] as usize;
        let mut shape = Vec::with_capacity(ndim);
        for _ in 0..ndim {
            shape.push(read_u32(&mut f)? as usize);
        }
        let data_len = read_u64(&mut f)?;
        offset += 2 + name_len as u64 + 2 + 4 * ndim as u64 + 8;
        if entry_name == name {
            if offset + data_len > file_len {
                bail!(
                    "{}: tensor {name} payload [{offset}, {}) runs past the {file_len}-byte file (truncated?)",
                    path.display(),
                    offset + data_len
                );
            }
            return Ok(TensorEntryMeta { dtype, shape, data_offset: offset, data_len });
        }
        f.seek_relative(data_len as i64)?;
        offset += data_len;
    }
    bail!("{}: no tensor named {name}", path.display())
}

/// One tensor of a streamed multi-tensor write (`save_multi_with`).
/// The payload callback must write exactly
/// `shape.product() * dtype.size()` little-endian bytes.
pub struct TensorPart<'a> {
    pub name: &'a str,
    pub dtype: DType,
    pub shape: &'a [usize],
    pub payload: &'a mut dyn FnMut(&mut dyn Write) -> Result<()>,
}

/// Write a single-tensor checkpoint, streaming the payload through
/// `payload` instead of materializing a `Tensor` (the adapter store
/// spills multi-megabyte tables this way without a second copy).  The
/// callback must write exactly `shape.product() * dtype.size()` bytes,
/// little-endian; the length is verified after the write.
pub fn save_one_with(
    path: &Path,
    name: &str,
    dtype: DType,
    shape: &[usize],
    payload: &mut dyn FnMut(&mut dyn Write) -> Result<()>,
) -> Result<()> {
    save_multi_with(path, &mut [TensorPart { name, dtype, shape, payload }])
}

/// Write a checkpoint of several streamed tensors in the order given
/// (the int8/dedup adapter tiers spill a codes tensor plus small
/// scale/zero/index sidecars this way).  Each part's written length is
/// verified against its header entry.
pub fn save_multi_with(path: &Path, parts: &mut [TensorPart<'_>]) -> Result<()> {
    if let Some(dir) = path.parent() {
        std::fs::create_dir_all(dir)?;
    }
    let mut f = std::io::BufWriter::new(
        std::fs::File::create(path).with_context(|| format!("create {}", path.display()))?,
    );
    f.write_all(MAGIC)?;
    f.write_all(&VERSION.to_le_bytes())?;
    f.write_all(&(parts.len() as u32).to_le_bytes())?;
    for part in parts {
        let nb = part.name.as_bytes();
        f.write_all(&(nb.len() as u16).to_le_bytes())?;
        f.write_all(nb)?;
        f.write_all(&[part.dtype.code(), part.shape.len() as u8])?;
        for &d in part.shape {
            f.write_all(&(d as u32).to_le_bytes())?;
        }
        let nbytes = (part.shape.iter().product::<usize>() * part.dtype.size()) as u64;
        f.write_all(&nbytes.to_le_bytes())?;
        let data_start = f.stream_position()?;
        (part.payload)(&mut f)?;
        let written = f.stream_position()? - data_start;
        if written != nbytes {
            bail!(
                "{}: tensor {} payload wrote {written} bytes, header declares {nbytes}",
                path.display(),
                part.name
            );
        }
    }
    f.flush()?;
    Ok(())
}

fn read_u16(f: &mut impl Read) -> Result<u16> {
    let mut b = [0u8; 2];
    f.read_exact(&mut b)?;
    Ok(u16::from_le_bytes(b))
}

fn read_u32(f: &mut impl Read) -> Result<u32> {
    let mut b = [0u8; 4];
    f.read_exact(&mut b)?;
    Ok(u32::from_le_bytes(b))
}

fn read_u64(f: &mut impl Read) -> Result<u64> {
    let mut b = [0u8; 8];
    f.read_exact(&mut b)?;
    Ok(u64::from_le_bytes(b))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip() {
        let dir = std::env::temp_dir().join("aotpt_ckpt_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("t.aotckpt");
        let mut tensors = BTreeMap::new();
        tensors.insert("a".to_string(), Tensor::from_f32(&[2, 2], vec![1.0, 2.0, 3.0, 4.0]));
        tensors.insert("b.ids".to_string(), Tensor::from_i32(&[3], vec![7, 8, 9]));
        tensors.insert("scalar".to_string(), Tensor::scalar_f32(0.5));
        save(&path, &tensors).unwrap();
        let back = load(&path).unwrap();
        assert_eq!(back.len(), 3);
        assert_eq!(back["a"].as_f32().unwrap(), &[1.0, 2.0, 3.0, 4.0]);
        assert_eq!(back["a"].shape, vec![2, 2]);
        assert_eq!(back["b.ids"].as_i32().unwrap(), &[7, 8, 9]);
        assert_eq!(back["scalar"].shape, Vec::<usize>::new());
    }

    #[test]
    fn f16_roundtrip() {
        let dir = std::env::temp_dir().join("aotpt_ckpt_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("f16.aotckpt");
        let bits = vec![0x3c00u16, 0xbc00, 0x7bff, 0x0001, 0x8000, 0x0000];
        let mut tensors = BTreeMap::new();
        tensors.insert("q".to_string(), Tensor::from_f16_bits(&[2, 3], bits.clone()));
        save(&path, &tensors).unwrap();
        let back = load(&path).unwrap();
        assert_eq!(back["q"].dtype, DType::F16);
        assert_eq!(back["q"].shape, vec![2, 3]);
        assert_eq!(back["q"].as_f16_bits().unwrap(), bits);
    }

    #[test]
    fn locate_finds_offsets_without_loading() {
        let dir = std::env::temp_dir().join("aotpt_ckpt_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("locate.aotckpt");
        let mut tensors = BTreeMap::new();
        tensors.insert("first".to_string(), Tensor::from_f32(&[3], vec![1.0, 2.0, 3.0]));
        tensors.insert("second".to_string(), Tensor::from_i32(&[2, 2], vec![4, 5, 6, 7]));
        save(&path, &tensors).unwrap();
        let meta = locate(&path, "second").unwrap();
        assert_eq!(meta.dtype, DType::I32);
        assert_eq!(meta.shape, vec![2, 2]);
        assert_eq!(meta.data_len, 16);
        // The located offset must point at the exact payload bytes.
        let raw = std::fs::read(&path).unwrap();
        let at = meta.data_offset as usize;
        let mut vals = Vec::new();
        for c in raw[at..at + 16].chunks_exact(4) {
            vals.push(i32::from_le_bytes([c[0], c[1], c[2], c[3]]));
        }
        assert_eq!(vals, vec![4, 5, 6, 7]);
        assert!(locate(&path, "missing").is_err());
    }

    #[test]
    fn save_one_with_streams_and_verifies_length() {
        let dir = std::env::temp_dir().join("aotpt_ckpt_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("one.aotckpt");
        let values = [1.5f32, -2.5, 0.25, 8.0];
        save_one_with(&path, "p", DType::F32, &[2, 2], &mut |w| {
            for v in values {
                w.write_all(&v.to_le_bytes())?;
            }
            Ok(())
        })
        .unwrap();
        let back = load(&path).unwrap();
        assert_eq!(back["p"].as_f32().unwrap(), &values);
        // A payload that writes the wrong number of bytes is rejected.
        let bad = dir.join("bad_len.aotckpt");
        let err = save_one_with(&bad, "p", DType::F32, &[2, 2], &mut |w| {
            w.write_all(&[0u8; 4])?;
            Ok(())
        });
        assert!(err.is_err());
    }

    #[test]
    fn rejects_bad_magic() {
        let dir = std::env::temp_dir().join("aotpt_ckpt_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("bad.aotckpt");
        std::fs::write(&path, b"NOPE....").unwrap();
        assert!(load(&path).is_err());
    }

    #[test]
    fn i8_roundtrip_with_sidecars() {
        let dir = std::env::temp_dir().join("aotpt_ckpt_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("i8.aotckpt");
        let codes = vec![-128i8, -7, 0, 7, 127, 1];
        let mut tensors = BTreeMap::new();
        tensors.insert("p".to_string(), Tensor::from_i8(&[2, 3], codes.clone()));
        tensors.insert("p.scale".to_string(), Tensor::from_f32(&[2], vec![0.5, 0.25]));
        tensors.insert("p.zero".to_string(), Tensor::from_f32(&[2], vec![-1.0, 2.0]));
        save(&path, &tensors).unwrap();
        let back = load(&path).unwrap();
        assert_eq!(back["p"].dtype, DType::I8);
        assert_eq!(back["p"].shape, vec![2, 3]);
        assert_eq!(back["p"].as_i8().unwrap(), &codes[..]);
        assert_eq!(back["p.scale"].as_f32().unwrap(), &[0.5, 0.25]);
        // locate() sees the i8 entry without a payload read too.
        let meta = locate(&path, "p").unwrap();
        assert_eq!(meta.dtype, DType::I8);
        assert_eq!(meta.data_len, 6);
    }

    #[test]
    fn save_multi_with_streams_every_part() {
        let dir = std::env::temp_dir().join("aotpt_ckpt_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("multi.aotckpt");
        let codes = [5i8, -5, 100];
        let scales = [2.0f32];
        save_multi_with(
            &path,
            &mut [
                TensorPart {
                    name: "p",
                    dtype: DType::I8,
                    shape: &[1, 3],
                    payload: &mut |w| {
                        w.write_all(&codes.map(|c| c as u8))?;
                        Ok(())
                    },
                },
                TensorPart {
                    name: "p.scale",
                    dtype: DType::F32,
                    shape: &[1],
                    payload: &mut |w| {
                        for s in scales {
                            w.write_all(&s.to_le_bytes())?;
                        }
                        Ok(())
                    },
                },
            ],
        )
        .unwrap();
        let back = load(&path).unwrap();
        assert_eq!(back.len(), 2);
        assert_eq!(back["p"].as_i8().unwrap(), &codes[..]);
        assert_eq!(back["p.scale"].as_f32().unwrap(), &scales[..]);
        // A part whose payload under-writes its header length is rejected.
        let bad = dir.join("multi_bad.aotckpt");
        let err = save_multi_with(
            &bad,
            &mut [TensorPart {
                name: "p",
                dtype: DType::I8,
                shape: &[4],
                payload: &mut |w| {
                    w.write_all(&[0u8; 2])?;
                    Ok(())
                },
            }],
        );
        assert!(err.is_err());
    }

    /// A file written by a build that predates a dtype code (or a corrupt
    /// one) must be rejected on load and on locate, not misread.
    #[test]
    fn stale_dtype_code_is_rejected() {
        let dir = std::env::temp_dir().join("aotpt_ckpt_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("stale.aotckpt");
        let mut tensors = BTreeMap::new();
        tensors.insert("p".to_string(), Tensor::from_i8(&[4], vec![1, 2, 3, 4]));
        save(&path, &tensors).unwrap();
        let mut raw = std::fs::read(&path).unwrap();
        // Header (12) + name len (2) + "p" (1) → dtype byte at offset 15.
        assert_eq!(raw[15], DType::I8.code());
        raw[15] = 9; // a code no version of the format has assigned
        std::fs::write(&path, &raw).unwrap();
        let err = load(&path).unwrap_err();
        assert!(err.to_string().contains("unknown dtype code 9"), "{err}");
        assert!(locate(&path, "p").is_err());
    }

    /// A truncated checkpoint must fail `locate` with a typed error —
    /// the mmap cold path relies on this extent check to never map (and
    /// later SIGBUS on) a payload the file does not actually contain.
    #[test]
    fn locate_rejects_truncated_payload() {
        let dir = std::env::temp_dir().join("aotpt_ckpt_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("trunc.aotckpt");
        let mut tensors = BTreeMap::new();
        tensors.insert("p".to_string(), Tensor::from_f32(&[4], vec![1.0, 2.0, 3.0, 4.0]));
        save(&path, &tensors).unwrap();
        let full = std::fs::read(&path).unwrap();
        std::fs::write(&path, &full[..full.len() - 5]).unwrap();
        let err = locate(&path, "p").unwrap_err();
        assert!(err.to_string().contains("truncated"), "{err}");
    }

    /// The python writer (`python/compile/ckpt.py`) and `DType::code`
    /// must agree on every dtype code — parsed from the python source so
    /// drift fails the build's tests rather than corrupting checkpoints.
    #[test]
    fn python_dtype_code_parity() {
        let py = crate::repo_root().join("python/compile/ckpt.py");
        let src = std::fs::read_to_string(&py)
            .unwrap_or_else(|e| panic!("read {}: {e}", py.display()));
        let expected = [
            ("float32", DType::F32),
            ("int32", DType::I32),
            ("int64", DType::I64),
            ("float16", DType::F16),
            ("int8", DType::I8),
        ];
        for (np_name, dt) in expected {
            let entry = format!("np.dtype(np.{np_name}): {}", dt.code());
            assert!(
                src.contains(&entry),
                "python _DTYPES missing or mismatched entry `{entry}`"
            );
            let inv = format!("{}: np.{np_name}", dt.code());
            assert!(
                src.contains(&inv),
                "python _DTYPES_INV missing or mismatched entry `{inv}`"
            );
        }
        // Same number of codes on both sides (count the map entries).
        let count = src.matches("np.dtype(np.").count();
        assert_eq!(count, expected.len(), "python _DTYPES has extra/missing dtypes");
    }
}
