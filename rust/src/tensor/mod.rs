//! Host tensors + the `aotckpt` checkpoint format shared with Python.

pub mod ckpt;

use crate::Result;
use anyhow::{anyhow, bail};

/// Element type of a host tensor (mirrors `python/compile/ckpt.py`).
///
/// `F16` holds raw IEEE binary16 bits (`u16` storage); conversion math
/// lives in `peft::quant`.  It exists for the adapter store's quantized
/// and spilled tables (DESIGN.md §10) and round-trips through `.aotckpt`
/// like every other dtype.  `I8` carries the int8 adapter tier's
/// quantized codes (per-row scale/zero live in sibling f32 tensors).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DType {
    F32,
    I32,
    I64,
    F16,
    I8,
}

impl DType {
    pub fn size(self) -> usize {
        match self {
            DType::F32 | DType::I32 => 4,
            DType::I64 => 8,
            DType::F16 => 2,
            DType::I8 => 1,
        }
    }

    pub fn code(self) -> u8 {
        match self {
            DType::F32 => 0,
            DType::I32 => 1,
            DType::I64 => 2,
            DType::F16 => 3,
            DType::I8 => 4,
        }
    }

    pub fn from_code(code: u8) -> Result<Self> {
        Ok(match code {
            0 => DType::F32,
            1 => DType::I32,
            2 => DType::I64,
            3 => DType::F16,
            4 => DType::I8,
            other => bail!("unknown dtype code {other}"),
        })
    }

    pub fn from_name(name: &str) -> Result<Self> {
        Ok(match name {
            "f32" => DType::F32,
            "i32" => DType::I32,
            "i64" => DType::I64,
            "f16" => DType::F16,
            "i8" => DType::I8,
            other => bail!("unknown dtype name {other}"),
        })
    }
}

/// A dense row-major host tensor.  Storage is raw bytes so all dtypes share
/// one container; typed views are provided for f32/i32.
#[derive(Clone, Debug)]
pub struct Tensor {
    pub dtype: DType,
    pub shape: Vec<usize>,
    data: Vec<u8>,
}

impl Tensor {
    pub fn zeros(dtype: DType, shape: &[usize]) -> Self {
        let n: usize = shape.iter().product();
        Tensor { dtype, shape: shape.to_vec(), data: vec![0u8; n * dtype.size()] }
    }

    pub fn from_f32(shape: &[usize], values: Vec<f32>) -> Self {
        assert_eq!(shape.iter().product::<usize>(), values.len(), "shape/value mismatch");
        let mut data = Vec::with_capacity(values.len() * 4);
        for v in &values {
            data.extend_from_slice(&v.to_le_bytes());
        }
        Tensor { dtype: DType::F32, shape: shape.to_vec(), data }
    }

    pub fn from_i32(shape: &[usize], values: Vec<i32>) -> Self {
        assert_eq!(shape.iter().product::<usize>(), values.len(), "shape/value mismatch");
        let mut data = Vec::with_capacity(values.len() * 4);
        for v in &values {
            data.extend_from_slice(&v.to_le_bytes());
        }
        Tensor { dtype: DType::I32, shape: shape.to_vec(), data }
    }

    /// Build an f16 tensor from raw IEEE binary16 bits (see
    /// `peft::quant` for the f32 conversions).
    pub fn from_f16_bits(shape: &[usize], bits: Vec<u16>) -> Self {
        assert_eq!(shape.iter().product::<usize>(), bits.len(), "shape/value mismatch");
        let mut data = Vec::with_capacity(bits.len() * 2);
        for b in &bits {
            data.extend_from_slice(&b.to_le_bytes());
        }
        Tensor { dtype: DType::F16, shape: shape.to_vec(), data }
    }

    /// Build an int8 tensor from quantized codes (see `peft::quant` for
    /// the per-row affine scale/zero math).
    pub fn from_i8(shape: &[usize], values: Vec<i8>) -> Self {
        assert_eq!(shape.iter().product::<usize>(), values.len(), "shape/value mismatch");
        let data = values.iter().map(|v| *v as u8).collect();
        Tensor { dtype: DType::I8, shape: shape.to_vec(), data }
    }

    pub fn scalar_f32(v: f32) -> Self {
        Tensor::from_f32(&[], vec![v])
    }

    pub fn scalar_i32(v: i32) -> Self {
        Tensor::from_i32(&[], vec![v])
    }

    pub fn from_raw(dtype: DType, shape: Vec<usize>, data: Vec<u8>) -> Result<Self> {
        let expect: usize = shape.iter().product::<usize>() * dtype.size();
        if data.len() != expect {
            bail!("raw tensor length {} != expected {expect}", data.len());
        }
        Ok(Tensor { dtype, shape, data })
    }

    pub fn len(&self) -> usize {
        self.shape.iter().product()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn bytes(&self) -> &[u8] {
        &self.data
    }

    pub fn as_f32(&self) -> Result<&[f32]> {
        if self.dtype != DType::F32 {
            bail!("tensor is {:?}, not f32", self.dtype);
        }
        Ok(unsafe {
            std::slice::from_raw_parts(self.data.as_ptr() as *const f32, self.len())
        })
    }

    pub fn as_f32_mut(&mut self) -> Result<&mut [f32]> {
        if self.dtype != DType::F32 {
            bail!("tensor is {:?}, not f32", self.dtype);
        }
        let n = self.len();
        Ok(unsafe {
            std::slice::from_raw_parts_mut(self.data.as_mut_ptr() as *mut f32, n)
        })
    }

    /// Raw IEEE binary16 bits of an f16 tensor (copying decode — the
    /// byte store has no alignment guarantee for wider views).
    pub fn as_f16_bits(&self) -> Result<Vec<u16>> {
        if self.dtype != DType::F16 {
            bail!("tensor is {:?}, not f16", self.dtype);
        }
        Ok(self
            .data
            .chunks_exact(2)
            .map(|c| u16::from_le_bytes([c[0], c[1]]))
            .collect())
    }

    /// Quantized int8 codes of an i8 tensor (byte storage reinterpreted;
    /// i8 and u8 share size and alignment so the view is always valid).
    pub fn as_i8(&self) -> Result<&[i8]> {
        if self.dtype != DType::I8 {
            bail!("tensor is {:?}, not i8", self.dtype);
        }
        Ok(unsafe {
            std::slice::from_raw_parts(self.data.as_ptr() as *const i8, self.len())
        })
    }

    pub fn as_i32(&self) -> Result<&[i32]> {
        if self.dtype != DType::I32 {
            bail!("tensor is {:?}, not i32", self.dtype);
        }
        Ok(unsafe {
            std::slice::from_raw_parts(self.data.as_ptr() as *const i32, self.len())
        })
    }

    /// Row `i` of a 2-D f32 tensor.
    pub fn row_f32(&self, i: usize) -> Result<&[f32]> {
        if self.shape.len() != 2 {
            bail!("row_f32 needs a 2-D tensor, got {:?}", self.shape);
        }
        let cols = self.shape[1];
        let all = self.as_f32()?;
        all.get(i * cols..(i + 1) * cols)
            .ok_or_else(|| anyhow!("row {i} out of bounds for {:?}", self.shape))
    }

    /// Flat element count sanity vs a declared shape.
    pub fn check_shape(&self, shape: &[usize]) -> Result<()> {
        if self.shape != shape {
            bail!("shape mismatch: have {:?}, want {:?}", self.shape, shape);
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn f32_roundtrip() {
        let t = Tensor::from_f32(&[2, 3], vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        assert_eq!(t.len(), 6);
        assert_eq!(t.as_f32().unwrap()[4], 5.0);
        assert_eq!(t.row_f32(1).unwrap(), &[4.0, 5.0, 6.0]);
        assert!(t.as_i32().is_err());
    }

    #[test]
    fn zeros_and_mutation() {
        let mut t = Tensor::zeros(DType::F32, &[4]);
        t.as_f32_mut().unwrap()[2] = 7.0;
        assert_eq!(t.as_f32().unwrap(), &[0.0, 0.0, 7.0, 0.0]);
    }

    #[test]
    fn scalars_have_empty_shape() {
        let s = Tensor::scalar_i32(5);
        assert_eq!(s.shape, Vec::<usize>::new());
        assert_eq!(s.len(), 1);
        assert_eq!(s.as_i32().unwrap(), &[5]);
    }

    #[test]
    fn from_raw_validates_length() {
        assert!(Tensor::from_raw(DType::F32, vec![2], vec![0u8; 8]).is_ok());
        assert!(Tensor::from_raw(DType::F32, vec![2], vec![0u8; 7]).is_err());
        assert!(Tensor::from_raw(DType::F16, vec![3], vec![0u8; 6]).is_ok());
        assert!(Tensor::from_raw(DType::F16, vec![3], vec![0u8; 12]).is_err());
    }

    #[test]
    fn f16_bits_roundtrip() {
        let bits = vec![0x3c00u16, 0xbc00, 0x0000, 0x7bff];
        let t = Tensor::from_f16_bits(&[2, 2], bits.clone());
        assert_eq!(t.dtype, DType::F16);
        assert_eq!(t.bytes().len(), 8);
        assert_eq!(t.as_f16_bits().unwrap(), bits);
        assert!(t.as_f32().is_err());
    }

    #[test]
    fn dtype_codes_roundtrip() {
        for dt in [DType::F32, DType::I32, DType::I64, DType::F16, DType::I8] {
            assert_eq!(DType::from_code(dt.code()).unwrap(), dt);
        }
        assert_eq!(DType::from_name("f16").unwrap(), DType::F16);
        assert_eq!(DType::F16.size(), 2);
        assert_eq!(DType::from_name("i8").unwrap(), DType::I8);
        assert_eq!(DType::I8.code(), 4);
        assert_eq!(DType::I8.size(), 1);
        assert!(DType::from_code(9).is_err());
    }

    #[test]
    fn i8_roundtrip() {
        let vals = vec![-128i8, -1, 0, 1, 127, 42];
        let t = Tensor::from_i8(&[2, 3], vals.clone());
        assert_eq!(t.dtype, DType::I8);
        assert_eq!(t.bytes().len(), 6);
        assert_eq!(t.as_i8().unwrap(), &vals[..]);
        assert!(t.as_f32().is_err());
        assert!(Tensor::from_raw(DType::I8, vec![4], vec![0u8; 4]).is_ok());
        assert!(Tensor::from_raw(DType::I8, vec![4], vec![0u8; 5]).is_err());
    }
}
