//! Integration tests for the staged serving pipeline that need **no AOT
//! artifacts and no accelerator**: the coordinator runs end to end over
//! the deterministic [`HostBackend`], so admission, planning, the arena
//! gather, execute dispatch and fan-out are all exercised in CI.

use std::sync::Arc;

use aotpt::coordinator::{
    Bucket, Coordinator, CoordinatorConfig, HostBackend, Request, TaskRegistry,
};
use aotpt::peft::TaskP;
use aotpt::tensor::Tensor;
use aotpt::util::Pcg64;

const LAYERS: usize = 3;
const VOCAB: usize = 200;
const D: usize = 8;
const CLASSES: usize = 4;

fn registry() -> TaskRegistry {
    let reg = TaskRegistry::new(LAYERS, VOCAB, D, CLASSES);
    let mut rng = Pcg64::new(42);
    for (name, classes) in [("a", 2usize), ("b", 3usize)] {
        let table = TaskP::new(LAYERS, VOCAB, D, rng.normal_vec(LAYERS * VOCAB * D, 0.5)).unwrap();
        let head_w = Tensor::from_f32(&[D, classes], rng.normal_vec(D * classes, 0.2));
        let head_b = Tensor::from_f32(&[classes], rng.normal_vec(classes, 0.2));
        reg.register_fused(name, table, &head_w, &head_b).unwrap();
    }
    reg
}

fn buckets() -> Vec<Bucket> {
    vec![
        Bucket { batch: 1, seq: 16 },
        Bucket { batch: 4, seq: 16 },
        Bucket { batch: 16, seq: 16 },
        Bucket { batch: 16, seq: 64 },
    ]
}

fn coordinator(linger_ms: u64) -> Coordinator {
    Coordinator::with_backend(
        registry(),
        buckets(),
        CLASSES,
        CoordinatorConfig {
            model: "host".into(),
            linger_ms,
            signature: "aot".into(),
            ..Default::default()
        },
        Arc::new(HostBackend),
    )
    .unwrap()
}

fn ids(seed: u64, len: usize) -> Vec<i32> {
    let mut rng = Pcg64::new(seed);
    (0..len).map(|_| rng.range(0, VOCAB as i64) as i32).collect()
}

#[test]
fn classify_returns_task_class_count() {
    let c = coordinator(1);
    let ra = c.classify("a", ids(1, 10)).unwrap();
    assert_eq!(ra.logits.len(), 2);
    let rb = c.classify("b", ids(2, 5)).unwrap();
    assert_eq!(rb.logits.len(), 3);
    assert!(ra.logits.iter().all(|x| x.is_finite()));
    assert_eq!(c.pipeline().backend_name(), "host-reference");
}

#[test]
fn admission_rejects_bad_requests() {
    let c = coordinator(1);
    assert!(c.classify("nope", ids(1, 5)).is_err());
    assert!(c.submit(Request { task: "a".into(), ids: vec![] }).is_err());
    assert!(c.submit(Request { task: "a".into(), ids: vec![1; 65] }).is_err());
}

#[test]
fn mixed_task_batch_equals_solo_exactly() {
    let c = coordinator(10);
    let ia = ids(3, 12);
    let ib = ids(4, 7);
    let solo_a = c.classify("a", ia.clone()).unwrap().logits;
    let solo_b = c.classify("b", ib.clone()).unwrap().logits;
    let rx_a = c.submit(Request { task: "a".into(), ids: ia }).unwrap();
    let rx_b = c.submit(Request { task: "b".into(), ids: ib }).unwrap();
    let mixed_a = rx_a.recv().unwrap().unwrap();
    let mixed_b = rx_b.recv().unwrap().unwrap();
    // The host backend computes rows independently, so mixing tasks in a
    // batch must be *bit-exact*, not just close.
    assert_eq!(solo_a, mixed_a.logits);
    assert_eq!(solo_b, mixed_b.logits);
    assert!(mixed_a.batch_size >= 1);
}

/// The satellite concurrency test: many submitter threads, every response
/// must equal a single-threaded reference run bit for bit.
#[test]
fn concurrent_submitters_match_single_threaded_reference() {
    // Reference: a dedicated coordinator served one request at a time.
    let reference = coordinator(0);
    let cases: Vec<(String, Vec<i32>)> = (0..32)
        .map(|i| {
            let task = if i % 2 == 0 { "a" } else { "b" };
            (task.to_string(), ids(1000 + i as u64, 3 + (i % 14)))
        })
        .collect();
    let expected: Vec<Vec<f32>> = cases
        .iter()
        .map(|(task, ids)| reference.classify(task, ids.clone()).unwrap().logits)
        .collect();

    // Concurrent: 8 threads × 4 requests against one shared coordinator
    // with a linger window that forces mixed batches.
    let c = Arc::new(coordinator(3));
    let cases = Arc::new(cases);
    let expected = Arc::new(expected);
    let mut handles = Vec::new();
    for thread in 0..8usize {
        let c = Arc::clone(&c);
        let cases = Arc::clone(&cases);
        let expected = Arc::clone(&expected);
        handles.push(std::thread::spawn(move || {
            for i in (thread * 4)..(thread * 4 + 4) {
                let (task, ids) = &cases[i];
                let got = c.classify(task, ids.clone()).unwrap();
                assert_eq!(
                    got.logits, expected[i],
                    "request {i} diverged from the single-threaded reference"
                );
            }
        }));
    }
    for h in handles {
        h.join().unwrap();
    }
    let snap = c.metrics().snapshot();
    assert_eq!(snap.requests, 32);
    assert_eq!(snap.queue_depth, 0, "queue must drain");
    assert!(snap.batches <= 32);
}

#[test]
fn out_of_vocab_token_errors_without_killing_worker() {
    let c = coordinator(1);
    let bad = vec![5, (VOCAB as i32) + 3, 7];
    let err = c.classify("a", bad).unwrap_err();
    assert!(err.to_string().contains("outside vocabulary"), "{err}");
    // The worker survives and keeps serving.
    let ok = c.classify("a", ids(9, 6)).unwrap();
    assert_eq!(ok.logits.len(), 2);
}

#[test]
fn steady_state_reuses_arena_buffers() {
    let c = coordinator(0);
    // Warm every slot of the bucket this shape selects.
    c.classify("a", ids(20, 10)).unwrap();
    let allocs_after_warm = c.pipeline().arena().allocs();
    for i in 0..10 {
        c.classify("a", ids(21 + i, 10)).unwrap();
    }
    assert_eq!(
        c.pipeline().arena().allocs(),
        allocs_after_warm,
        "steady-state batches must not allocate staging buffers"
    );
    assert!(c.pipeline().arena().reuses() >= 50, "5 buffers x 10 batches");
    let snap = c.metrics().snapshot();
    assert_eq!(snap.arena_allocs, allocs_after_warm);
}

#[test]
fn f16_registry_serves_and_reports_adapter_counters() {
    // An f16-tier registry behind the full pipeline: outputs stay within
    // the tier tolerance of the f32 reference, resident RAM halves, and
    // the residency counters surface in MetricsSnapshot.
    use aotpt::coordinator::{AdapterConfig, AdapterDType};
    let f32_reg = registry();
    let f16_reg = {
        let reg = TaskRegistry::with_adapter_config(
            LAYERS,
            VOCAB,
            D,
            CLASSES,
            AdapterConfig { dtype: AdapterDType::F16, ..Default::default() },
        );
        let mut rng = Pcg64::new(42);
        for (name, classes) in [("a", 2usize), ("b", 3usize)] {
            let table =
                TaskP::new(LAYERS, VOCAB, D, rng.normal_vec(LAYERS * VOCAB * D, 0.5)).unwrap();
            let head_w = Tensor::from_f32(&[D, classes], rng.normal_vec(D * classes, 0.2));
            let head_b = Tensor::from_f32(&[classes], rng.normal_vec(classes, 0.2));
            reg.register_fused(name, table, &head_w, &head_b).unwrap();
        }
        reg
    };
    assert_eq!(2 * f16_reg.ram_bytes(), f32_reg.ram_bytes());

    let cfg = CoordinatorConfig {
        model: "host".into(),
        linger_ms: 0,
        signature: "aot".into(),
        ..Default::default()
    };
    let reference = Coordinator::with_backend(
        f32_reg,
        buckets(),
        CLASSES,
        cfg.clone(),
        Arc::new(HostBackend),
    )
    .unwrap();
    let c = Coordinator::with_backend(f16_reg, buckets(), CLASSES, cfg, Arc::new(HostBackend))
        .unwrap();
    for i in 0..8 {
        let input = ids(500 + i, 4 + (i as usize % 10));
        let task = if i % 2 == 0 { "a" } else { "b" };
        let got = c.classify(task, input.clone()).unwrap().logits;
        let want = reference.classify(task, input).unwrap().logits;
        for (x, y) in got.iter().zip(&want) {
            // Logits sum ~n·l dequantized elements; scale the tier
            // tolerance accordingly.
            assert!((x - y).abs() < 0.5, "request {i}: {x} vs {y}");
        }
    }
    let snap = c.metrics().snapshot();
    assert_eq!(snap.adapter.resident_tasks, 2);
    assert_eq!(snap.adapter.spilled_tasks, 0);
    assert!(snap.adapter.hits > 0);
    assert_eq!(snap.adapter.evictions, 0);
    assert!(snap.adapter.resident_bytes > 0);
}

/// The overlap satellite: many submitter threads through the
/// double-buffered coordinator (overlap on, prefetch on, an adapter
/// budget tight enough to force tier traffic) must match a strictly
/// serial overlap-off coordinator bit for bit — running execute on a
/// dedicated thread while the next batch gathers must not change a
/// single logit.
#[test]
fn overlapped_pipeline_matches_serial_reference_bit_exact() {
    use aotpt::coordinator::AdapterConfig;
    let table_bytes = LAYERS * VOCAB * D * 4;
    // Budget fits one of the two task tables: every a/b alternation
    // spills, prefetches and faults while the batches overlap.
    let tight_registry = || {
        let reg = TaskRegistry::with_adapter_config(
            LAYERS,
            VOCAB,
            D,
            CLASSES,
            AdapterConfig { ram_budget_bytes: table_bytes, ..Default::default() },
        );
        let mut rng = Pcg64::new(42);
        for (name, classes) in [("a", 2usize), ("b", 3usize)] {
            let table =
                TaskP::new(LAYERS, VOCAB, D, rng.normal_vec(LAYERS * VOCAB * D, 0.5)).unwrap();
            let head_w = Tensor::from_f32(&[D, classes], rng.normal_vec(D * classes, 0.2));
            let head_b = Tensor::from_f32(&[classes], rng.normal_vec(classes, 0.2));
            reg.register_fused(name, table, &head_w, &head_b).unwrap();
        }
        reg
    };
    // Reference: the seed's strictly serial loop, no prefetch.
    let reference = Coordinator::with_backend(
        tight_registry(),
        buckets(),
        CLASSES,
        CoordinatorConfig {
            model: "host".into(),
            linger_ms: 0,
            signature: "aot".into(),
            prefetch: false,
            overlap: false,
            ..Default::default()
        },
        Arc::new(HostBackend),
    )
    .unwrap();
    let cases: Vec<(String, Vec<i32>)> = (0..32)
        .map(|i| {
            let task = if i % 2 == 0 { "a" } else { "b" };
            (task.to_string(), ids(2000 + i as u64, 3 + (i % 14)))
        })
        .collect();
    let expected: Vec<Vec<f32>> = cases
        .iter()
        .map(|(task, ids)| reference.classify(task, ids.clone()).unwrap().logits)
        .collect();

    // Overlapped: defaults (overlap + prefetch on), a linger window that
    // forces mixed batches through the two-slot queue.
    let c = Arc::new(Coordinator::with_backend(
        tight_registry(),
        buckets(),
        CLASSES,
        CoordinatorConfig {
            model: "host".into(),
            linger_ms: 3,
            signature: "aot".into(),
            ..Default::default()
        },
        Arc::new(HostBackend),
    )
    .unwrap());
    let cases = Arc::new(cases);
    let expected = Arc::new(expected);
    let mut handles = Vec::new();
    for thread in 0..8usize {
        let c = Arc::clone(&c);
        let cases = Arc::clone(&cases);
        let expected = Arc::clone(&expected);
        handles.push(std::thread::spawn(move || {
            for i in (thread * 4)..(thread * 4 + 4) {
                let (task, ids) = &cases[i];
                let got = c.classify(task, ids.clone()).unwrap();
                assert_eq!(
                    got.logits, expected[i],
                    "request {i} diverged from the serial overlap-off reference"
                );
            }
        }));
    }
    for h in handles {
        h.join().unwrap();
    }
    let snap = c.metrics().snapshot();
    assert_eq!(snap.requests, 32);
    assert_eq!(snap.queue_depth, 0, "queue must drain");
    // The tight budget really exercised the tiers while overlapped.
    let a = snap.adapter;
    assert!(
        a.evictions + a.cold_serves + a.faults > 0,
        "one-table budget never forced tier traffic: {a:?}"
    );
    // Shutdown joins the worker and then the execute thread.
    c.shutdown();
    assert!(c.classify("a", ids(1, 3)).is_err());
}

#[test]
fn metrics_accumulate_and_shutdown_is_idempotent() {
    let c = coordinator(1);
    for i in 0..6 {
        c.classify(if i % 2 == 0 { "a" } else { "b" }, ids(30 + i, 7)).unwrap();
    }
    let snap = c.metrics().snapshot();
    assert_eq!(snap.requests, 6);
    assert!(snap.batches >= 1 && snap.batches <= 6);
    assert!(snap.mean_exec_ms >= 0.0);
    assert!(snap.gather_fraction >= 0.0 && snap.gather_fraction <= 1.0);
    c.shutdown();
    c.shutdown();
    assert!(c.classify("a", ids(1, 3)).is_err(), "post-shutdown submits fail");
}
