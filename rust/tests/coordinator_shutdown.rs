//! Regression tests for the shutdown/accounting bug sweep: every
//! admitted request is answered exactly once, the queue-depth gauge
//! settles to 0 on every exit path, and a panicking worker/backend turns
//! into request errors instead of hung clients.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;
use std::time::Duration;

use aotpt::coordinator::{
    Backend, BatchBuffers, BatchPlan, Bucket, Coordinator, CoordinatorConfig, HostBackend,
    Request, TaskRegistry,
};
use aotpt::peft::TaskP;
use aotpt::tensor::Tensor;
use aotpt::util::Pcg64;

const LAYERS: usize = 2;
const VOCAB: usize = 64;
const D_MODEL: usize = 8;
const CLASSES: usize = 2;

fn registry(n_tasks: usize) -> TaskRegistry {
    let registry = TaskRegistry::new(LAYERS, VOCAB, D_MODEL, CLASSES);
    let mut rng = Pcg64::new(7);
    for i in 0..n_tasks {
        let table = TaskP::new(
            LAYERS,
            VOCAB,
            D_MODEL,
            rng.normal_vec(LAYERS * VOCAB * D_MODEL, 0.3),
        )
        .unwrap();
        let head_w =
            Tensor::from_f32(&[D_MODEL, CLASSES], rng.normal_vec(D_MODEL * CLASSES, 0.2));
        let head_b = Tensor::from_f32(&[CLASSES], vec![0.0; CLASSES]);
        registry.register_fused(&format!("task{i}"), table, &head_w, &head_b).unwrap();
    }
    registry
}

fn coordinator(backend: Arc<dyn Backend>, n_tasks: usize) -> Coordinator {
    Coordinator::with_backend(
        registry(n_tasks),
        vec![Bucket { batch: 4, seq: 16 }],
        CLASSES,
        CoordinatorConfig {
            model: "host".into(),
            linger_ms: 1,
            signature: "aot".into(),
            ..Default::default()
        },
        backend,
    )
    .unwrap()
}

fn ids(seed: u64) -> Vec<i32> {
    let mut rng = Pcg64::new(seed);
    (0..6).map(|_| rng.range(0, VOCAB as i64) as i32).collect()
}

/// HostBackend with a fixed stall per batch — long enough that a burst
/// of submits piles up in the queue behind the first batch.
struct StalledBackend {
    stall: Duration,
    batches: AtomicUsize,
}

impl Backend for StalledBackend {
    fn execute(&self, plan: &BatchPlan, bufs: &BatchBuffers) -> aotpt::Result<Vec<f32>> {
        self.batches.fetch_add(1, Ordering::SeqCst);
        std::thread::sleep(self.stall);
        HostBackend.execute(plan, bufs)
    }

    fn name(&self) -> &'static str {
        "stalled-host"
    }
}

struct PanickingBackend;

impl Backend for PanickingBackend {
    fn execute(&self, _plan: &BatchPlan, _bufs: &BatchBuffers) -> aotpt::Result<Vec<f32>> {
        panic!("synthetic backend crash");
    }

    fn name(&self) -> &'static str {
        "panicking"
    }
}

/// The admitted-then-worker-exits race: hard shutdown while a burst is
/// queued behind a stalled execute.  Every receiver must still get an
/// answer (success or "shut down") and the gauge must settle to 0.
#[test]
fn hard_shutdown_answers_residual_queue_and_settles_gauge() {
    let backend = Arc::new(StalledBackend {
        stall: Duration::from_millis(150),
        batches: AtomicUsize::new(0),
    });
    let c = coordinator(backend, 2);
    let mut receivers = Vec::new();
    for i in 0..12u64 {
        let rx = c
            .submit(Request { task: format!("task{}", i % 2), ids: ids(i) })
            .unwrap();
        receivers.push(rx);
    }
    // Let the worker dequeue the first batch and stall inside execute,
    // then pull the rug out while the rest is still queued.
    std::thread::sleep(Duration::from_millis(40));
    c.shutdown();
    let mut answered = 0;
    for rx in receivers {
        // Every admitted request is answered — no hung receiver.  The
        // generous timeout only bounds a deadlock; normally this is
        // immediate because shutdown() joined the worker already.
        let result = rx.recv_timeout(Duration::from_secs(10)).expect("reply arrives");
        answered += 1;
        if let Err(e) = result {
            let msg = format!("{e:#}");
            assert!(
                msg.contains("shut down") || msg.contains("dropped"),
                "unexpected shutdown error: {msg}"
            );
        }
    }
    assert_eq!(answered, 12);
    assert_eq!(c.metrics().snapshot().queue_depth, 0, "gauge leaked");
}

/// Graceful drain under load: the backlog is flushed, every reply is a
/// success, and the gauge reads 0.
#[test]
fn drain_flushes_backlog_with_all_successes() {
    let backend = Arc::new(StalledBackend {
        stall: Duration::from_millis(30),
        batches: AtomicUsize::new(0),
    });
    let c = coordinator(Arc::clone(&backend) as Arc<dyn Backend>, 2);
    let mut receivers = Vec::new();
    for i in 0..10u64 {
        let rx = c
            .submit(Request { task: format!("task{}", i % 2), ids: ids(100 + i) })
            .unwrap();
        receivers.push(rx);
    }
    c.drain();
    for rx in receivers {
        let response = rx
            .recv_timeout(Duration::from_secs(10))
            .expect("reply arrives")
            .expect("drain answers with success");
        assert_eq!(response.logits.len(), CLASSES);
    }
    let snap = c.metrics().snapshot();
    assert_eq!(snap.requests, 10);
    assert_eq!(snap.queue_depth, 0);
    assert!(backend.batches.load(Ordering::SeqCst) >= 1);
    // Drain is terminal: new submits are refused, not queued forever.
    assert!(c.submit(Request { task: "task0".into(), ids: ids(1) }).is_err());
}

/// A worker that panics after dequeue (backend panic) must fail the
/// request instead of hanging the client — and the coordinator keeps
/// answering subsequent requests.
#[test]
fn backend_panic_fails_requests_instead_of_hanging() {
    let c = coordinator(Arc::new(PanickingBackend), 1);
    for i in 0..3u64 {
        let err = c.classify("task0", ids(i)).expect_err("panicking backend errors");
        assert!(format!("{err:#}").contains("panicked"), "{err:#}");
    }
    assert_eq!(c.metrics().snapshot().queue_depth, 0);
    c.shutdown();
}

/// Deadline-aware receive: a stalled execute turns into a deadline error
/// for the caller, and the (eventually produced) reply is dropped
/// harmlessly with the gauge still settling once.
#[test]
fn classify_deadline_times_out_on_stalled_execute() {
    let backend = Arc::new(StalledBackend {
        stall: Duration::from_millis(300),
        batches: AtomicUsize::new(0),
    });
    let c = coordinator(backend, 1);
    let err = c
        .classify_deadline("task0", ids(5), Some(Duration::from_millis(20)))
        .expect_err("deadline fires first");
    assert!(format!("{err:#}").contains("deadline exceeded"), "{err:#}");
    // The batch is still in flight; drain flushes it and the gauge
    // settles even though the receiver is gone.
    c.drain();
    assert_eq!(c.metrics().snapshot().queue_depth, 0);
}

/// Submitting after shutdown is a fast error, not a hang.
#[test]
fn submit_after_shutdown_errors() {
    let c = coordinator(Arc::new(HostBackend), 1);
    assert!(c.classify("task0", ids(2)).is_ok());
    c.shutdown();
    let err = c
        .submit(Request { task: "task0".into(), ids: ids(3) })
        .expect_err("shut down coordinator refuses work");
    assert!(format!("{err:#}").contains("shut down"), "{err:#}");
    assert_eq!(c.metrics().snapshot().queue_depth, 0);
}
