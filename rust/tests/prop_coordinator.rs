//! Property tests (seeded-fuzz style, no proptest crate offline) on the
//! coordinator's pure invariants: bucket selection, gather correctness,
//! batch packing, staged-pipeline/legacy-assembly equivalence, EVP
//! monotonicity, metric bounds.

use std::sync::Arc;

use aotpt::coordinator::{
    BatchBuffers, BatchPlanner, Bucket, BucketSet, GatherStage, Request, TaskRegistry,
};
use aotpt::peft::{PStore, RowSource, TaskP};
use aotpt::tensor::Tensor;
use aotpt::tokenizer::PAD;
use aotpt::train::evp;
use aotpt::util::{stats, Pcg64};

const TRIALS: usize = 300;

/// Invariant: `select` always returns a fitting bucket, minimal in padded
/// area among the fitting ones.
#[test]
fn prop_bucket_selection_fits_and_is_minimal() {
    let mut rng = Pcg64::new(1);
    for _ in 0..TRIALS {
        let n_buckets = rng.range(1, 8) as usize;
        let buckets: Vec<Bucket> = (0..n_buckets)
            .map(|_| Bucket {
                batch: 1 << rng.range(0, 7),
                seq: 8 << rng.range(0, 6),
            })
            .collect();
        let set = BucketSet::new(buckets.clone());
        let count = rng.range(1, 130) as usize;
        let len = rng.range(1, 600) as usize;
        match set.select(count, len) {
            Ok(chosen) => {
                assert!(chosen.batch >= count && chosen.seq >= len);
                for b in set.all() {
                    if b.batch >= count && b.seq >= len {
                        assert!(
                            chosen.batch * chosen.seq <= b.batch * b.seq,
                            "chosen {chosen:?} not minimal vs {b:?}"
                        );
                    }
                }
            }
            Err(_) => {
                // Must only fail when NOTHING fits.
                assert!(
                    !set.all().iter().any(|b| b.batch >= count && b.seq >= len),
                    "select failed though a bucket fits"
                );
            }
        }
    }
}

/// Invariant: gather output equals element-wise table lookup for random
/// stores, assignments and id matrices.
#[test]
fn prop_gather_matches_lookup() {
    let mut rng = Pcg64::new(2);
    for trial in 0..60 {
        let layers = rng.range(1, 4) as usize;
        let vocab = rng.range(8, 64) as usize;
        let d = (rng.range(1, 5) as usize) * 2;
        let n_tasks = rng.range(1, 4) as usize;
        let store = PStore::new(layers, vocab, d);
        let names: Vec<String> = (0..n_tasks).map(|i| format!("t{i}")).collect();
        for name in &names {
            let data = rng.normal_vec(layers * vocab * d, 1.0);
            store.insert(name, TaskP::new(layers, vocab, d, data).unwrap()).unwrap();
        }
        let b = rng.range(1, 6) as usize;
        let n = rng.range(1, 12) as usize;
        let assignments: Vec<&str> =
            (0..b).map(|_| names[rng.below(n_tasks as u64) as usize].as_str()).collect();
        let ids: Vec<i32> = (0..b * n).map(|_| rng.range(0, vocab as i64) as i32).collect();
        let out = store.gather(&assignments, &ids, n).unwrap();
        let data = out.as_f32().unwrap();
        for layer in 0..layers {
            for (j, task) in assignments.iter().enumerate() {
                for t in 0..n {
                    let tok = ids[j * n + t] as usize;
                    let mut expect = vec![0f32; d];
                    store.get(task).unwrap().copy_row(layer, tok, &mut expect).unwrap();
                    let base = ((layer * b + j) * n + t) * d;
                    assert_eq!(&data[base..base + d], &expect[..], "trial {trial}");
                }
            }
        }
    }
}

/// Invariant: for random request mixes, the staged pipeline's batch plan
/// and staged buffers equal the pre-refactor `build_and_run` assembly —
/// same bucket, same padded ids/mask, same packed heads and same gathered
/// bias for every live row.  (Filler rows are the one intended change:
/// the legacy path gathered real data for them and packed row-0's head;
/// the pipeline skips their gather and zeroes their head.)
#[test]
fn prop_staged_plan_matches_legacy_assembly() {
    let mut rng = Pcg64::new(7);
    for trial in 0..40 {
        // Random geometry + registry.
        let layers = rng.range(1, 4) as usize;
        let vocab = rng.range(20, 60) as usize;
        let d = (rng.range(1, 5) as usize) * 2;
        let max_classes = 4usize;
        let reg = TaskRegistry::new(layers, vocab, d, max_classes);
        let n_tasks = rng.range(1, 4) as usize;
        let names: Vec<String> = (0..n_tasks).map(|i| format!("t{i}")).collect();
        for name in &names {
            let classes = rng.range(2, 5) as usize;
            let table =
                TaskP::new(layers, vocab, d, rng.normal_vec(layers * vocab * d, 1.0)).unwrap();
            let head_w = Tensor::from_f32(&[d, classes], rng.normal_vec(d * classes, 0.3));
            let head_b = Tensor::from_f32(&[classes], rng.normal_vec(classes, 0.3));
            reg.register_fused(name, table, &head_w, &head_b).unwrap();
        }
        let reg = Arc::new(reg);

        let buckets = vec![
            Bucket { batch: 1, seq: 8 },
            Bucket { batch: 2, seq: 8 },
            Bucket { batch: 4, seq: 16 },
            Bucket { batch: 8, seq: 32 },
        ];
        let planner = BatchPlanner::new(BucketSet::new(buckets.clone()), Arc::clone(&reg));

        // Random request mix that always fits the largest bucket.
        let count = rng.range(1, 9) as usize;
        let requests: Vec<Request> = (0..count)
            .map(|_| Request {
                task: names[rng.below(n_tasks as u64) as usize].clone(),
                ids: (0..rng.range(1, 33) as usize)
                    .map(|_| rng.range(0, vocab as i64) as i32)
                    .collect(),
            })
            .collect();
        let refs: Vec<&Request> = requests.iter().collect();

        // ---- legacy assembly (the old build_and_run, verbatim) ----------
        let max_len = requests.iter().map(|r| r.ids.len()).max().unwrap();
        let legacy_bucket = BucketSet::new(buckets).select(count, max_len).unwrap();
        let (b, n) = (legacy_bucket.batch, legacy_bucket.seq);
        let mut legacy_ids = vec![PAD; b * n];
        let mut legacy_mask = vec![0f32; b * n];
        let mut legacy_assignments: Vec<&str> = Vec::with_capacity(b);
        for (j, req) in requests.iter().enumerate() {
            for (t, &tok) in req.ids.iter().enumerate() {
                legacy_ids[j * n + t] = tok;
                legacy_mask[j * n + t] = 1.0;
            }
            legacy_assignments.push(&req.task);
        }
        for _ in count..b {
            legacy_assignments.push(&requests[0].task);
        }
        let mut legacy_head_w = vec![0f32; b * d * max_classes];
        let mut legacy_head_b = vec![0f32; b * max_classes];
        for (j, task) in legacy_assignments.iter().enumerate() {
            let state = reg.get(task).unwrap();
            for di in 0..d {
                let src = &state.head_w[di * state.classes..(di + 1) * state.classes];
                legacy_head_w[(j * d + di) * max_classes
                    ..(j * d + di) * max_classes + state.classes]
                    .copy_from_slice(src);
            }
            legacy_head_b[j * max_classes..j * max_classes + state.classes]
                .copy_from_slice(&state.head_b);
        }
        let legacy_bias = reg.pstore().gather(&legacy_assignments, &legacy_ids, n).unwrap();
        let legacy_bias = legacy_bias.as_f32().unwrap();

        // ---- staged pipeline ---------------------------------------------
        let plan = planner.plan(&refs).unwrap();
        assert_eq!(plan.bucket, legacy_bucket, "trial {trial}: bucket diverged");
        assert_eq!(plan.live(), count);
        let mut bufs = BatchBuffers {
            bucket: plan.bucket,
            layers,
            d_model: d,
            classes: max_classes,
            // Poisoned buffers prove the staging overwrites its regions.
            ids: vec![77; b * n],
            mask: vec![5.0; b * n],
            bias: vec![1234.5; layers * b * n * d],
            head_w: vec![9.0; b * d * max_classes],
            head_b: vec![9.0; b * max_classes],
        };
        planner.stage(&plan, &refs, &mut bufs).unwrap();
        let gather = GatherStage::new(Arc::clone(&reg), rng.range(1, 4) as usize);
        gather.gather(&plan, &mut bufs).unwrap();

        assert_eq!(bufs.ids, legacy_ids, "trial {trial}: ids diverged");
        assert_eq!(bufs.mask, legacy_mask, "trial {trial}: mask diverged");
        // Heads: identical over live rows; zero over filler rows.
        let live_w = count * d * max_classes;
        assert_eq!(
            &bufs.head_w[..live_w],
            &legacy_head_w[..live_w],
            "trial {trial}: live head_w diverged"
        );
        assert!(bufs.head_w[live_w..].iter().all(|&x| x == 0.0));
        let live_b = count * max_classes;
        assert_eq!(&bufs.head_b[..live_b], &legacy_head_b[..live_b]);
        assert!(bufs.head_b[live_b..].iter().all(|&x| x == 0.0));
        // Bias: identical over live rows of every layer; filler rows are
        // untouched (still the poison value).
        for layer in 0..layers {
            let base = layer * b * n * d;
            let live = count * n * d;
            assert_eq!(
                &bufs.bias[base..base + live],
                &legacy_bias[base..base + live],
                "trial {trial}: layer {layer} live bias diverged"
            );
            assert!(bufs.bias[base + live..base + b * n * d].iter().all(|&x| x == 1234.5));
        }
    }
}

/// Invariant: EVP curves are monotone non-decreasing and bounded by the
/// max score, for random score pools.
#[test]
fn prop_evp_monotone_and_bounded() {
    let mut rng = Pcg64::new(3);
    for _ in 0..TRIALS {
        let n = rng.range(1, 40) as usize;
        let scores: Vec<f64> = (0..n).map(|_| rng.f64()).collect();
        let max = scores.iter().cloned().fold(f64::MIN, f64::max);
        let curve = evp::evp_curve(&scores, 30);
        for w in curve.windows(2) {
            assert!(w[1].1 >= w[0].1 - 1e-12);
        }
        assert!(curve.last().unwrap().1 <= max + 1e-12);
        let mean = scores.iter().sum::<f64>() / n as f64;
        assert!((curve[0].1 - mean).abs() < 1e-9);
    }
}

/// Invariant: every classification metric stays within its bounds on
/// random prediction/gold pairs.
#[test]
fn prop_metrics_bounded() {
    let mut rng = Pcg64::new(4);
    for _ in 0..TRIALS {
        let n = rng.range(2, 60) as usize;
        let classes = rng.range(2, 4) as i64;
        let gold: Vec<i64> = (0..n).map(|_| rng.range(0, classes)).collect();
        let pred: Vec<i64> = (0..n).map(|_| rng.range(0, classes)).collect();
        let acc = stats::accuracy(&pred, &gold);
        assert!((0.0..=1.0).contains(&acc));
        let f1 = stats::f1_macro(&pred, &gold);
        assert!((0.0..=1.0).contains(&f1));
        let mcc = stats::matthews(&pred, &gold);
        assert!((-1.0..=1.0).contains(&mcc));
        let gf: Vec<f64> = gold.iter().map(|&x| x as f64).collect();
        let pf: Vec<f64> = pred.iter().map(|&x| x as f64).collect();
        let rho = stats::spearman(&pf, &gf);
        assert!((-1.0 - 1e-9..=1.0 + 1e-9).contains(&rho));
    }
}

/// Invariant: the pack_pair layout always starts with CLS, masks exactly
/// the used prefix, and never exceeds the requested length.
#[test]
fn prop_pack_pair_layout() {
    let mut rng = Pcg64::new(5);
    for _ in 0..TRIALS {
        let a_len = rng.range(0, 30) as usize;
        let b_len = rng.range(0, 30) as usize;
        let seq = rng.range(4, 70) as usize;
        let a: Vec<i32> = (0..a_len).map(|_| rng.range(5, 100) as i32).collect();
        let b: Vec<i32> = (0..b_len).map(|_| rng.range(5, 100) as i32).collect();
        let with_b = rng.bool(0.5);
        let (ids, mask) =
            aotpt::tokenizer::pack_pair(&a, if with_b { Some(&b) } else { None }, seq);
        assert_eq!(ids.len(), seq);
        assert_eq!(mask.len(), seq);
        assert_eq!(ids[0], aotpt::tokenizer::CLS);
        // mask is a prefix of ones
        let used = mask.iter().filter(|&&m| m > 0.0).count();
        assert!(mask[..used].iter().all(|&m| m == 1.0));
        assert!(mask[used..].iter().all(|&m| m == 0.0));
        assert!(ids[used..].iter().all(|&i| i == aotpt::tokenizer::PAD));
    }
}
