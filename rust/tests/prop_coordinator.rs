//! Property tests (seeded-fuzz style, no proptest crate offline) on the
//! coordinator's pure invariants: bucket selection, gather correctness,
//! batch packing, EVP monotonicity, metric bounds.

use aotpt::coordinator::{Bucket, BucketSet};
use aotpt::peft::{PStore, TaskP};
use aotpt::train::evp;
use aotpt::util::{stats, Pcg64};

const TRIALS: usize = 300;

/// Invariant: `select` always returns a fitting bucket, minimal in padded
/// area among the fitting ones.
#[test]
fn prop_bucket_selection_fits_and_is_minimal() {
    let mut rng = Pcg64::new(1);
    for _ in 0..TRIALS {
        let n_buckets = rng.range(1, 8) as usize;
        let buckets: Vec<Bucket> = (0..n_buckets)
            .map(|_| Bucket {
                batch: 1 << rng.range(0, 7),
                seq: 8 << rng.range(0, 6),
            })
            .collect();
        let set = BucketSet::new(buckets.clone());
        let count = rng.range(1, 130) as usize;
        let len = rng.range(1, 600) as usize;
        match set.select(count, len) {
            Ok(chosen) => {
                assert!(chosen.batch >= count && chosen.seq >= len);
                for b in set.all() {
                    if b.batch >= count && b.seq >= len {
                        assert!(
                            chosen.batch * chosen.seq <= b.batch * b.seq,
                            "chosen {chosen:?} not minimal vs {b:?}"
                        );
                    }
                }
            }
            Err(_) => {
                // Must only fail when NOTHING fits.
                assert!(
                    !set.all().iter().any(|b| b.batch >= count && b.seq >= len),
                    "select failed though a bucket fits"
                );
            }
        }
    }
}

/// Invariant: gather output equals element-wise table lookup for random
/// stores, assignments and id matrices.
#[test]
fn prop_gather_matches_lookup() {
    let mut rng = Pcg64::new(2);
    for trial in 0..60 {
        let layers = rng.range(1, 4) as usize;
        let vocab = rng.range(8, 64) as usize;
        let d = (rng.range(1, 5) as usize) * 2;
        let n_tasks = rng.range(1, 4) as usize;
        let mut store = PStore::new(layers, vocab, d);
        let names: Vec<String> = (0..n_tasks).map(|i| format!("t{i}")).collect();
        for name in &names {
            let data = rng.normal_vec(layers * vocab * d, 1.0);
            store.insert(name, TaskP::new(layers, vocab, d, data).unwrap()).unwrap();
        }
        let b = rng.range(1, 6) as usize;
        let n = rng.range(1, 12) as usize;
        let assignments: Vec<&str> =
            (0..b).map(|_| names[rng.below(n_tasks as u64) as usize].as_str()).collect();
        let ids: Vec<i32> = (0..b * n).map(|_| rng.range(0, vocab as i64) as i32).collect();
        let out = store.gather(&assignments, &ids, n).unwrap();
        let data = out.as_f32().unwrap();
        for layer in 0..layers {
            for (j, task) in assignments.iter().enumerate() {
                for t in 0..n {
                    let tok = ids[j * n + t] as usize;
                    let expect = store.get(task).unwrap().row(layer, tok);
                    let base = ((layer * b + j) * n + t) * d;
                    assert_eq!(&data[base..base + d], expect, "trial {trial}");
                }
            }
        }
    }
}

/// Invariant: EVP curves are monotone non-decreasing and bounded by the
/// max score, for random score pools.
#[test]
fn prop_evp_monotone_and_bounded() {
    let mut rng = Pcg64::new(3);
    for _ in 0..TRIALS {
        let n = rng.range(1, 40) as usize;
        let scores: Vec<f64> = (0..n).map(|_| rng.f64()).collect();
        let max = scores.iter().cloned().fold(f64::MIN, f64::max);
        let curve = evp::evp_curve(&scores, 30);
        for w in curve.windows(2) {
            assert!(w[1].1 >= w[0].1 - 1e-12);
        }
        assert!(curve.last().unwrap().1 <= max + 1e-12);
        let mean = scores.iter().sum::<f64>() / n as f64;
        assert!((curve[0].1 - mean).abs() < 1e-9);
    }
}

/// Invariant: every classification metric stays within its bounds on
/// random prediction/gold pairs.
#[test]
fn prop_metrics_bounded() {
    let mut rng = Pcg64::new(4);
    for _ in 0..TRIALS {
        let n = rng.range(2, 60) as usize;
        let classes = rng.range(2, 4) as i64;
        let gold: Vec<i64> = (0..n).map(|_| rng.range(0, classes)).collect();
        let pred: Vec<i64> = (0..n).map(|_| rng.range(0, classes)).collect();
        let acc = stats::accuracy(&pred, &gold);
        assert!((0.0..=1.0).contains(&acc));
        let f1 = stats::f1_macro(&pred, &gold);
        assert!((0.0..=1.0).contains(&f1));
        let mcc = stats::matthews(&pred, &gold);
        assert!((-1.0..=1.0).contains(&mcc));
        let gf: Vec<f64> = gold.iter().map(|&x| x as f64).collect();
        let pf: Vec<f64> = pred.iter().map(|&x| x as f64).collect();
        let rho = stats::spearman(&pf, &gf);
        assert!((-1.0 - 1e-9..=1.0 + 1e-9).contains(&rho));
    }
}

/// Invariant: the pack_pair layout always starts with CLS, masks exactly
/// the used prefix, and never exceeds the requested length.
#[test]
fn prop_pack_pair_layout() {
    let mut rng = Pcg64::new(5);
    for _ in 0..TRIALS {
        let a_len = rng.range(0, 30) as usize;
        let b_len = rng.range(0, 30) as usize;
        let seq = rng.range(4, 70) as usize;
        let a: Vec<i32> = (0..a_len).map(|_| rng.range(5, 100) as i32).collect();
        let b: Vec<i32> = (0..b_len).map(|_| rng.range(5, 100) as i32).collect();
        let with_b = rng.bool(0.5);
        let (ids, mask) =
            aotpt::tokenizer::pack_pair(&a, if with_b { Some(&b) } else { None }, seq);
        assert_eq!(ids.len(), seq);
        assert_eq!(mask.len(), seq);
        assert_eq!(ids[0], aotpt::tokenizer::CLS);
        // mask is a prefix of ones
        let used = mask.iter().filter(|&&m| m > 0.0).count();
        assert!(mask[..used].iter().all(|&m| m == 1.0));
        assert!(mask[used..].iter().all(|&m| m == 0.0));
        assert!(ids[used..].iter().all(|&i| i == aotpt::tokenizer::PAD));
    }
}
