//! Spill-file fault injection for the cold tier (DESIGN.md §13).
//!
//! The disk tier's contract under corruption: every fault — truncation
//! before or after open, a bit-flipped dtype code, short or missing
//! sidecar tensors, an out-of-range dedup index — is a **typed error**
//! that fails only the affected task; the store never panics, other
//! tasks keep serving, and the residency accounting stays exact.  Every
//! fault case runs in both `--adapter-mmap` modes (except
//! truncation-after-open, which is positioned-read-only: poking a live
//! mapping past EOF is SIGBUS territory, which is exactly why
//! `ColdTable::open` validates the payload extent against the mapping
//! up front).
//!
//! The suite ends with the acceptance parity property: mapped and
//! positioned cold serving are bit-identical across f32/f16/int8 and
//! dedup'd tables, including the `load_resident` fault-in path.

use std::fs;
use std::path::{Path, PathBuf};
use std::sync::atomic::Ordering;
use std::sync::Arc;

use aotpt::peft::{
    AdapterConfig, AdapterDType, ColdCounters, ColdTable, PStore, RowSource, TaskP,
};
use aotpt::tensor::{ckpt, DType, Tensor};
use aotpt::util::Pcg64;

const L: usize = 2;
const V: usize = 16;
const D: usize = 4;

/// A fresh per-test scratch directory under the system temp dir.
fn tmp_dir(tag: &str) -> PathBuf {
    let name = format!("aotpt-spill-faults-{tag}-{}", std::process::id());
    let dir = std::env::temp_dir().join(name);
    let _ = fs::remove_dir_all(&dir);
    fs::create_dir_all(&dir).unwrap();
    dir
}

/// Build a store whose single task "t" is guaranteed to live on the disk
/// tier: a 1-byte RAM budget spills every insert (0 would mean
/// *unlimited*), with the spill file landing in `dir`.
fn spilled_store(dir: &Path, cfg0: AdapterConfig, data: Vec<f32>) -> PStore {
    let cfg = AdapterConfig {
        ram_budget_bytes: 1,
        spill_dir: Some(dir.to_path_buf()),
        ..cfg0
    };
    let store = PStore::with_config(L, V, D, cfg);
    store.insert("t", TaskP::new(L, V, D, data).unwrap()).unwrap();
    store
}

/// The single spill file inside `dir`.
fn spill_file(dir: &Path) -> PathBuf {
    let mut files: Vec<PathBuf> = fs::read_dir(dir)
        .unwrap()
        .map(|e| e.unwrap().path())
        .filter(|p| p.extension().is_some_and(|x| x == "aotckpt"))
        .collect();
    assert_eq!(files.len(), 1, "expected one spill file in {}", dir.display());
    files.pop().unwrap()
}

/// The spill file of `task` inside `dir` (name prefix `{task}-`).
fn spill_file_for(dir: &Path, task: &str) -> PathBuf {
    let prefix = format!("{task}-");
    let mut files: Vec<PathBuf> = fs::read_dir(dir)
        .unwrap()
        .map(|e| e.unwrap().path())
        .filter(|p| {
            p.file_name()
                .is_some_and(|n| n.to_string_lossy().starts_with(&prefix))
        })
        .collect();
    assert_eq!(files.len(), 1, "expected one spill file for {task}");
    files.pop().unwrap()
}

fn all_rows(src: &dyn RowSource) -> Vec<Vec<f32>> {
    let mut rows = Vec::with_capacity(L * V);
    for layer in 0..L {
        for tok in 0..V {
            let mut out = vec![0f32; D];
            src.copy_row(layer, tok, &mut out).unwrap();
            rows.push(out);
        }
    }
    rows
}

/// A file truncated before open is rejected by `ckpt::locate`'s extent
/// check — in both mmap modes, before anything is mapped.
#[test]
fn truncated_spill_file_is_rejected_at_open() {
    let dir = tmp_dir("trunc-open");
    let mut rng = Pcg64::new(11);
    let data = rng.normal_vec(L * V * D, 1.0);
    let _store = spilled_store(
        &dir,
        AdapterConfig { mmap: false, ..Default::default() },
        data,
    );
    let raw = fs::read(spill_file(&dir)).unwrap();
    let cut = dir.join("cut.aotckpt");
    fs::write(&cut, &raw[..raw.len() / 2]).unwrap();
    for use_mmap in [false, true] {
        let counters = Arc::new(ColdCounters::default());
        let err = ColdTable::open(
            &cut,
            L,
            V,
            D,
            AdapterDType::F32,
            false,
            use_mmap,
            Arc::clone(&counters),
        )
        .err()
        .expect("truncated spill file must fail to open");
        let msg = format!("{err:#}");
        assert!(msg.contains("truncated"), "mmap={use_mmap}: {msg}");
        assert_eq!(counters.mapped_bytes.load(Ordering::Relaxed), 0);
    }
}

/// Truncation *after* open (positioned-read mode — a live mapping would
/// SIGBUS instead of erroring, which is why the mapped path re-validates
/// extents at open): reads past the cut fail with a typed error, reads
/// before it keep serving, other tasks are untouched, and a failed
/// fault-in rolls its budget reservation back to the byte.
#[test]
fn truncation_after_open_fails_only_that_task_and_keeps_accounting() {
    let dir = tmp_dir("trunc-live");
    let table_bytes = L * V * D * 4;
    let cfg = AdapterConfig {
        ram_budget_bytes: table_bytes,
        spill_dir: Some(dir.clone()),
        mmap: false,
        ..Default::default()
    };
    let store = PStore::with_config(L, V, D, cfg);
    store.insert("ok", TaskP::new(L, V, D, vec![1.0; L * V * D]).unwrap()).unwrap();
    store.pin("ok", true).unwrap();
    // "ok" is pinned and fills the budget, so "bad" spills itself.
    store.insert("bad", TaskP::new(L, V, D, vec![2.0; L * V * D]).unwrap()).unwrap();
    let bad_file = spill_file_for(&dir, "bad");
    let raw = fs::read(&bad_file).unwrap();
    fs::write(&bad_file, &raw[..raw.len() / 2]).unwrap();

    let src = store.get("bad").unwrap();
    assert_eq!(src.tier(), "disk");
    let before = store.stats();
    let mut row = vec![0f32; D];
    let err = src.copy_row(L - 1, V - 1, &mut row).unwrap_err();
    assert!(
        format!("{err:#}").contains("unexpected end of file"),
        "{err:#}"
    );
    // Rows before the cut still decode...
    src.copy_row(0, 0, &mut row).unwrap();
    assert_eq!(row, vec![2.0; D]);
    // ...and the failed read changed no accounting.
    let after = store.stats();
    assert_eq!(after.resident_bytes, before.resident_bytes);
    assert_eq!(after.resident_tasks, before.resident_tasks);
    assert_eq!(after.spilled_tasks, before.spilled_tasks);
    // The healthy task is untouched.
    store.get("ok").unwrap().copy_row(0, 0, &mut row).unwrap();
    assert_eq!(row, vec![1.0; D]);

    // Unpin "ok" so resolving "bad" attempts a full fault-in: the load
    // hits the cut and the budget reservation must roll back exactly.
    store.pin("ok", false).unwrap();
    let err = store.get("bad").err().expect("fault-in of a truncated file must fail");
    assert!(
        format!("{err:#}").contains("unexpected end of file"),
        "{err:#}"
    );
    let stats = store.stats();
    assert_eq!(stats.resident_bytes, 0, "leaked reservation: {stats:?}");
    assert_eq!(stats.resident_tasks, 0, "{stats:?}");
    assert_eq!(stats.spilled_tasks, 2, "{stats:?}");
    // "ok" (evicted to make room for the failed fault-in) comes back.
    let ok = store.get("ok").unwrap();
    ok.copy_row(L - 1, 0, &mut row).unwrap();
    assert_eq!(row, vec![1.0; D]);
}

/// A bit-flipped dtype code byte — an unknown code or a valid-but-wrong
/// one — is rejected at open in both mmap modes.
#[test]
fn bit_flipped_dtype_code_is_rejected() {
    let dir = tmp_dir("dtype-flip");
    let mut rng = Pcg64::new(13);
    let data = rng.normal_vec(L * V * D, 1.0);
    let _store = spilled_store(
        &dir,
        AdapterConfig { mmap: false, ..Default::default() },
        data,
    );
    let raw = fs::read(spill_file(&dir)).unwrap();
    // The first tensor is "p": its dtype code byte sits at absolute
    // offset 15 (12-byte header + name_len u16 + 1-byte name).
    assert_eq!(raw[15], DType::F32.code(), "spill layout changed under the test");
    for (code, needle) in [(9u8, "unknown dtype code"), (DType::F16.code(), "dtype")] {
        let mut flipped = raw.clone();
        flipped[15] = code;
        let path = dir.join(format!("flipped-{code}.aotckpt"));
        fs::write(&path, &flipped).unwrap();
        for use_mmap in [false, true] {
            let err = ColdTable::open(
                &path,
                L,
                V,
                D,
                AdapterDType::F32,
                false,
                use_mmap,
                Arc::new(ColdCounters::default()),
            )
            .err()
            .expect("flipped dtype code must fail to open");
            let msg = format!("{err:#}");
            assert!(msg.contains(needle), "code {code}, mmap={use_mmap}: {msg}");
        }
    }
}

/// Sidecar faults on an int8+dedup spill: missing `p.index`, missing or
/// short `p.scale`/`p.zero`, and an out-of-range index entry are all
/// typed open errors in both mmap modes.
#[test]
fn short_or_missing_sidecars_are_rejected() {
    let dir = tmp_dir("sidecars");
    let u = 3usize; // stored pool rows
    let idx: Vec<i32> = (0..L * V).map(|i| (i % (u + 1)) as i32).collect();
    let pool = || Tensor::from_i8(&[1, u, D], vec![7i8; u * D]);
    let index = || Tensor::from_i32(&[L, V], idx.clone());
    let scale = |len: usize| Tensor::from_f32(&[len], vec![0.5; len]);

    let write = |name: &str, tensors: Vec<(&str, Tensor)>| -> PathBuf {
        let path = dir.join(format!("{name}.aotckpt"));
        let map: std::collections::BTreeMap<String, Tensor> =
            tensors.into_iter().map(|(k, v)| (k.to_string(), v)).collect();
        ckpt::save(&path, &map).unwrap();
        path
    };

    let mut bad_index = idx.clone();
    bad_index[5] = (u + 1) as i32; // points past the pool
    let cases = [
        (
            write("no-index", vec![("p", pool()), ("p.scale", scale(u)), ("p.zero", scale(u))]),
            "p.index",
        ),
        (
            write("no-scale", vec![("p", pool()), ("p.index", index()), ("p.zero", scale(u))]),
            "p.scale",
        ),
        (
            write("no-zero", vec![("p", pool()), ("p.index", index()), ("p.scale", scale(u))]),
            "p.zero",
        ),
        (
            write(
                "short-scale",
                vec![
                    ("p", pool()),
                    ("p.index", index()),
                    ("p.scale", scale(u - 1)),
                    ("p.zero", scale(u)),
                ],
            ),
            "wrong dtype/length",
        ),
        (
            write(
                "short-zero",
                vec![
                    ("p", pool()),
                    ("p.index", index()),
                    ("p.scale", scale(u)),
                    ("p.zero", scale(u - 1)),
                ],
            ),
            "wrong dtype/length",
        ),
        (
            write(
                "bad-index",
                vec![
                    ("p", pool()),
                    ("p.index", Tensor::from_i32(&[L, V], bad_index)),
                    ("p.scale", scale(u)),
                    ("p.zero", scale(u)),
                ],
            ),
            "exceeds pool",
        ),
    ];
    for (path, needle) in &cases {
        for use_mmap in [false, true] {
            let err = ColdTable::open(
                path,
                L,
                V,
                D,
                AdapterDType::I8,
                true,
                use_mmap,
                Arc::new(ColdCounters::default()),
            )
            .err()
            .expect("sidecar fault must fail to open");
            let msg = format!("{err:#}");
            assert!(
                msg.contains(needle),
                "{}: mmap={use_mmap}: wanted {needle:?} in {msg}",
                path.display()
            );
        }
    }
}

/// Unlink-while-open (unix): deleting the spill file after the cold
/// table opened it keeps serving through the live inode — for both the
/// mapping and the positioned-read descriptor.
#[cfg(unix)]
#[test]
fn file_deleted_after_open_keeps_serving() {
    for use_mmap in [false, true] {
        let dir = tmp_dir(&format!("unlink-{use_mmap}"));
        let mut rng = Pcg64::new(17);
        let data = rng.normal_vec(L * V * D, 1.0);
        let reference = TaskP::new(L, V, D, data.clone()).unwrap();
        let store = spilled_store(
            &dir,
            AdapterConfig { mmap: use_mmap, ..Default::default() },
            data,
        );
        let src = store.get("t").unwrap();
        assert_eq!(src.tier(), "disk");
        fs::remove_file(spill_file(&dir)).unwrap();
        for layer in 0..L {
            for tok in 0..V {
                let mut out = vec![0f32; D];
                src.copy_row(layer, tok, &mut out).unwrap();
                assert_eq!(out.as_slice(), reference.row(layer, tok), "mmap={use_mmap}");
            }
        }
        assert_eq!(store.stats().spilled_tasks, 1);
    }
}

/// The acceptance parity property: mapped and positioned cold serving
/// are bit-identical for every storage dtype, dense and dedup'd — both
/// row by row through the store and for the `load_resident` fault-in
/// path — and the mapped-bytes gauge settles to zero when the tables
/// drop.
#[test]
fn mapped_vs_positioned_cold_parity_across_tiers() {
    for dtype in [AdapterDType::F32, AdapterDType::F16, AdapterDType::I8] {
        for dedup in [false, true] {
            let tag = format!("parity-{}-{dedup}", dtype.name());
            let mut rng = Pcg64::new(19);
            let mut data = rng.normal_vec(L * V * D, 1.0);
            if dedup {
                // Shared rows for the dedup pass to collapse.
                for row in (0..L * V).step_by(3) {
                    data[row * D..(row + 1) * D].fill(0.0);
                }
            }
            let dir_m = tmp_dir(&format!("{tag}-mmap"));
            let dir_p = tmp_dir(&format!("{tag}-pread"));
            let cfg = AdapterConfig { dtype, dedup, ..Default::default() };
            let mapped = spilled_store(
                &dir_m,
                AdapterConfig { mmap: true, ..cfg.clone() },
                data.clone(),
            );
            let positioned = spilled_store(&dir_p, AdapterConfig { mmap: false, ..cfg }, data);
            let m = mapped.get("t").unwrap();
            let p = positioned.get("t").unwrap();
            assert_eq!(m.tier(), "disk", "{tag}");
            assert_eq!(p.tier(), "disk", "{tag}");
            let m_rows = all_rows(m.as_ref());
            assert_eq!(m_rows, all_rows(p.as_ref()), "{tag}: cold rows diverge");

            // The fault-in path: a table loaded resident from the spill
            // file serves the same bits, whichever way it was read.
            let path = spill_file(&dir_m);
            for use_mmap in [false, true] {
                let counters = Arc::new(ColdCounters::default());
                let cold = ColdTable::open(
                    &path,
                    L,
                    V,
                    D,
                    dtype,
                    dedup,
                    use_mmap,
                    Arc::clone(&counters),
                )
                .unwrap();
                let warm = cold.load_resident().unwrap();
                assert_eq!(
                    all_rows(warm.as_ref()),
                    m_rows,
                    "{tag}: mmap={use_mmap} fault-in diverges"
                );
                drop(warm);
                drop(cold);
                assert_eq!(
                    counters.mapped_bytes.load(Ordering::Relaxed),
                    0,
                    "{tag}: mapping leaked"
                );
            }
        }
    }
}
