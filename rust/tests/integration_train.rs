//! Integration: the Rust training driver over AOT train-step executables.
//! Loss must decrease, learned tasks must beat chance, fuse paths must
//! agree, and the trained P must weight the task's cue tokens (§4.3).

use std::sync::Arc;

use aotpt::analyze;
use aotpt::config::Manifest;
use aotpt::data::{self, Lexicon};
use aotpt::peft::fuse;
use aotpt::runtime::{Runtime, WeightCache};
use aotpt::tensor::Tensor;
use aotpt::train::{grid, TrainConfig, Trainer};

struct Ctx {
    runtime: Arc<Runtime>,
    manifest: Manifest,
    weights: Arc<WeightCache>,
    lex: Lexicon,
}

/// `None` (and the test is skipped) when the AOT artifacts have not been
/// built — `make artifacts` needs the Python L1/L2 toolchain, and the
/// default `cargo test` run must stay green without it.
fn ctx() -> Option<Ctx> {
    let dir = aotpt::artifacts_dir();
    if !dir.join("manifest.json").exists() {
        eprintln!("skipping: AOT artifacts not built (run `make artifacts`)");
        return None;
    }
    let manifest = Manifest::load(&dir).expect("manifest loads");
    let runtime = Runtime::new().unwrap();
    let weights = Arc::new(
        WeightCache::from_ckpt(&runtime, &dir.join("backbone_tiny.aotckpt")).unwrap(),
    );
    Some(Ctx { runtime, manifest, weights, lex: Lexicon::generate(0) })
}

type Trained = (f64, Vec<f32>, std::collections::BTreeMap<String, Tensor>);

fn train(c: &Ctx, method: &str, task_name: &str, steps: usize, seed: u64) -> Trained {
    let classes = data::tasks::task_classes(task_name);
    let task = data::make_task(&c.lex, task_name, 55, 384, 192, 64).unwrap();
    let assignments = grid::assignments_for(&c.manifest, "tiny", method, classes, &[5e-3]);
    let a = assignments.first().expect("artifact available");
    let trainer =
        Trainer::new(&c.runtime, &c.manifest, Arc::clone(&c.weights), &a.train_stem, &a.eval_stem)
            .unwrap();
    let result = trainer
        .run(&task, &TrainConfig { lr: a.lr, seed, max_epochs: 8, patience: 4, max_steps: steps })
        .unwrap();
    (result.best_metric, result.losses, result.best_state)
}

#[test]
fn aot_fc_learns_sst2_above_chance() {
    let Some(c) = ctx() else { return };
    let (metric, losses, _) = train(&c, "aot-fc", "sst2", 192, 0);
    assert!(losses.last().unwrap() < losses.first().unwrap(), "{losses:?}");
    assert!(metric > 0.65, "sst2 accuracy {metric} not above chance");
}

#[test]
fn bitfit_learns_but_aot_fc_matches_or_beats_it() {
    // The paper's core quality claim (Table 2): AoT P-Tuning outperforms
    // BitFit.  At this scale we assert the weak ordering on a cue task.
    let Some(c) = ctx() else { return };
    let (bitfit, _, _) = train(&c, "bitfit", "sst2", 192, 0);
    let (aot, _, _) = train(&c, "aot-fc", "sst2", 192, 0);
    assert!(bitfit > 0.5, "bitfit should learn something: {bitfit}");
    assert!(aot + 0.05 >= bitfit, "aot-fc {aot} far below bitfit {bitfit}");
}

#[test]
fn training_is_seed_deterministic() {
    let Some(c) = ctx() else { return };
    let (m1, l1, _) = train(&c, "aot-fc", "rte", 64, 3);
    let (m2, l2, _) = train(&c, "aot-fc", "rte", 64, 3);
    assert_eq!(l1, l2);
    assert!((m1 - m2).abs() < 1e-12);
}

#[test]
fn fused_table_weights_cue_tokens() {
    // §4.3 as a quantitative check: after training FC AoT on sst2, the
    // top-norm rows of P must over-represent sentiment cue tokens.
    let Some(c) = ctx() else { return };
    let (_, _, state) = train(&c, "aot-fc", "sst2", 256, 0);
    let emb = c.weights.host("emb_tok").unwrap();
    let p = fuse::fuse_fc(emb, &state).unwrap();
    let task = data::make_task(&c.lex, "sst2", 55, 8, 8, 64).unwrap();
    let last = p.layers - 1;
    let recall = analyze::cue_recall_at(&p, last, 50, &task.cue_tokens);
    // cue tokens are 300 of 8192 (3.7%); any real signal blows past 10x.
    assert!(recall > 0.3, "cue recall@50 only {recall}");
}

#[test]
fn host_fuse_matches_hlo_fuse_artifact() {
    // The two fuse paths (rust host math vs fuse_fc_*.hlo.txt) must agree.
    let Some(c) = ctx() else { return };
    let spec = c.manifest.artifact("fuse_fc_tiny_r32").unwrap();
    let exe = c.runtime.load(&c.manifest, &spec.stem).unwrap();
    let mut rng = aotpt::util::Pcg64::new(17);
    let mut trained = std::collections::BTreeMap::new();
    let mut args: Vec<Tensor> = Vec::new();
    for input in &exe.spec.inputs {
        let t = if input.name == "w.emb_tok" {
            c.weights.host("emb_tok").unwrap().clone()
        } else {
            Tensor::from_f32(&input.shape, rng.normal_vec(input.numel(), 0.05))
        };
        if input.name.starts_with("t.") {
            trained.insert(input.name.clone(), t.clone());
        }
        args.push(t);
    }
    let hlo_p = exe.run(&args).unwrap().remove(0);
    let host_p = fuse::fuse_fc(c.weights.host("emb_tok").unwrap(), &trained).unwrap();
    let hlo = hlo_p.as_f32().unwrap();
    let vocab = c.manifest.vocab_size;
    let d = c.manifest.model("tiny").unwrap().d_model;
    for layer in 0..2 {
        for tok in (0..vocab).step_by(997) {
            let row = host_p.row(layer, tok);
            let base = (layer * vocab + tok) * d;
            for (i, &x) in row.iter().enumerate() {
                let y = hlo[base + i];
                assert!(
                    (x - y).abs() < 1e-3 * (1.0 + y.abs()),
                    "l{layer} t{tok} i{i}: {x} vs {y}"
                );
            }
        }
    }
}

#[test]
fn mlm_pretraining_reduces_loss() {
    // The synthetic-pretraining substrate: a few MLM super-steps on the
    // corpus must reduce the masked-token loss.
    let Some(c) = ctx() else { return };
    let spec = c.manifest.artifact("pretrain_tiny_mlm_b16n64").unwrap().clone();
    let exe = c.runtime.load(&c.manifest, &spec.stem).unwrap();
    let (k, b, n) = (spec.steps_per_call, spec.batch, spec.seq);
    let corpus = data::corpus(&c.lex, 3, k * b * 4, n - 2);
    let mut rng = aotpt::util::Pcg64::new(8);

    // state = backbone copy; moments = zeros
    let mut state: Vec<Tensor> = exe
        .spec
        .inputs
        .iter()
        .filter_map(|i| i.name.strip_prefix("t.").map(|nm| c.weights.host(nm).unwrap().clone()))
        .collect();
    let mut moments: Vec<Tensor> = exe
        .spec
        .inputs
        .iter()
        .filter(|i| i.name.starts_with("m.") || i.name.starts_with("v."))
        .map(|i| Tensor::zeros(i.dtype, &i.shape))
        .collect();
    let mut step = 0i32;
    let mut losses = Vec::new();

    for call in 0..3 {
        let mut ids = Vec::with_capacity(k * b * n);
        let mut mask = Vec::with_capacity(k * b * n);
        let mut labels = Vec::with_capacity(k * b * n);
        for s in 0..k * b {
            let sent = &corpus[(call * k * b + s) % corpus.len()];
            let mut row = vec![aotpt::tokenizer::CLS];
            row.extend_from_slice(sent);
            row.push(aotpt::tokenizer::SEP);
            row.truncate(n);
            let used = row.len();
            row.resize(n, aotpt::tokenizer::PAD);
            for t in 0..n {
                let tok = row[t];
                let maskable = t > 0 && t + 1 < used;
                if maskable && rng.bool(0.15) {
                    labels.push(tok as f32);
                    row[t] = aotpt::tokenizer::MASK;
                } else {
                    labels.push(-100.0);
                }
                mask.push(if t < used { 1.0 } else { 0.0 });
            }
            ids.extend_from_slice(&row);
        }
        let mut args: Vec<Tensor> = Vec::new();
        let mut ti = 0;
        let mut mi = 0;
        for input in &exe.spec.inputs {
            let t = if input.name.starts_with("t.") {
                ti += 1;
                state[ti - 1].clone()
            } else if input.name.starts_with("m.") || input.name.starts_with("v.") {
                mi += 1;
                moments[mi - 1].clone()
            } else {
                match input.name.as_str() {
                    "in.step" => Tensor::scalar_i32(step),
                    "in.ids" => Tensor::from_i32(&[k, b, n], ids.clone()),
                    "in.mask" => Tensor::from_f32(&[k, b, n], mask.clone()),
                    "in.labels" => Tensor::from_f32(&[k, b, n], labels.clone()),
                    "in.lr" => Tensor::scalar_f32(3e-4),
                    other => panic!("unexpected input {other}"),
                }
            };
            args.push(t);
        }
        let outs = exe.run(&args).unwrap();
        let mut t_out = Vec::new();
        let mut m_out = Vec::new();
        for (name, value) in exe.spec.outputs.iter().zip(outs) {
            if name == "step" {
                step = value.as_i32().unwrap()[0];
            } else if name == "loss" {
                losses.push(value.as_f32().unwrap()[0]);
            } else if name.starts_with("t.") {
                t_out.push(value);
            } else {
                m_out.push(value);
            }
        }
        state = t_out;
        moments = m_out;
    }
    assert_eq!(losses.len(), 3);
    assert!(losses[2] < losses[0], "MLM loss did not decrease: {losses:?}");
    assert_eq!(step, 3 * k as i32);
}
