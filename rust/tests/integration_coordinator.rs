//! Integration: the multi-task coordinator end to end — registration,
//! mixed-task batching exactness, metrics, error paths.

use std::collections::BTreeMap;
use std::sync::Arc;

use aotpt::config::Manifest;
use aotpt::coordinator::{Coordinator, CoordinatorConfig, Request, TaskRegistry};
use aotpt::runtime::{Runtime, WeightCache};
use aotpt::tensor::Tensor;
use aotpt::util::Pcg64;

/// `None` (and the test is skipped) when the AOT artifacts are missing —
/// the default `cargo test` run must stay green without the Python
/// toolchain.  The artifact-free pipeline coverage lives in
/// `pipeline_stages.rs` over the HostBackend.
fn setup() -> Option<(Arc<Runtime>, Manifest, TaskRegistry, WeightCache)> {
    let dir = aotpt::artifacts_dir();
    if !dir.join("manifest.json").exists() {
        eprintln!("skipping: AOT artifacts not built (run `make artifacts`)");
        return None;
    }
    let manifest = Manifest::load(&dir).expect("manifest loads");
    let runtime = Runtime::new().unwrap();
    let model = manifest.model("tiny").unwrap();
    let weights = WeightCache::from_ckpt(
        &runtime,
        &aotpt::artifacts_dir().join("backbone_tiny.aotckpt"),
    )
    .unwrap();
    let registry = TaskRegistry::new(
        model.n_layers,
        model.vocab_size,
        model.d_model,
        manifest.multitask_classes,
    );
    Some((runtime, manifest, registry, weights))
}

fn register_random_task(
    registry: &TaskRegistry,
    emb: &Tensor,
    model: &aotpt::config::ModelInfo,
    name: &str,
    seed: u64,
    classes: usize,
) {
    let (l, d, r) = (model.n_layers, model.d_model, 8);
    let mut rng = Pcg64::new(seed);
    let mut tr = BTreeMap::new();
    tr.insert("t.fc.w1".into(), Tensor::from_f32(&[l, d, r], rng.normal_vec(l * d * r, 0.05)));
    tr.insert("t.fc.b1".into(), Tensor::from_f32(&[l, r], rng.normal_vec(l * r, 0.02)));
    tr.insert("t.fc.w2".into(), Tensor::from_f32(&[l, r, d], rng.normal_vec(l * r * d, 0.05)));
    tr.insert("t.fc.b2".into(), Tensor::from_f32(&[l, d], rng.normal_vec(l * d, 0.02)));
    tr.insert("t.head_w".into(), Tensor::from_f32(&[d, classes], rng.normal_vec(d * classes, 0.05)));
    tr.insert("t.head_b".into(), Tensor::from_f32(&[classes], rng.normal_vec(classes, 0.05)));
    registry.register_fc(name, emb, &tr).unwrap();
}

fn coordinator() -> Option<Coordinator> {
    let (runtime, manifest, registry, weights) = setup()?;
    let model = manifest.model("tiny").unwrap().clone();
    let emb = weights.host("emb_tok").unwrap().clone();
    register_random_task(&registry, &emb, &model, "a", 1, 2);
    register_random_task(&registry, &emb, &model, "b", 2, 3);
    match Coordinator::new(
        runtime,
        &manifest,
        registry,
        CoordinatorConfig {
            model: "tiny".into(),
            linger_ms: 5,
            signature: "aot".into(),
            ..Default::default()
        },
    ) {
        Ok(c) => Some(c),
        Err(e) => {
            eprintln!("skipping: PJRT coordinator unavailable ({e:#})");
            None
        }
    }
}

fn ids(seed: u64, len: usize) -> Vec<i32> {
    let mut rng = Pcg64::new(seed);
    let mut v = vec![aotpt::tokenizer::CLS];
    for _ in 0..len {
        v.push(rng.range(5, 8192) as i32);
    }
    v
}

#[test]
fn classify_returns_task_class_count() {
    let Some(c) = coordinator() else { return };
    let ra = c.classify("a", ids(3, 10)).unwrap();
    assert_eq!(ra.logits.len(), 2);
    let rb = c.classify("b", ids(3, 10)).unwrap();
    assert_eq!(rb.logits.len(), 3);
    assert!(ra.logits.iter().all(|x| x.is_finite()));
}

#[test]
fn mixed_task_batch_equals_solo() {
    let Some(c) = coordinator() else { return };
    let ia = ids(4, 12);
    let ib = ids(5, 9);
    let solo_a = c.classify("a", ia.clone()).unwrap().logits;
    let solo_b = c.classify("b", ib.clone()).unwrap().logits;
    // Submit together so they share one invocation.
    let rx_a = c.submit(Request { task: "a".into(), ids: ia }).unwrap();
    let rx_b = c.submit(Request { task: "b".into(), ids: ib }).unwrap();
    let mixed_a = rx_a.recv().unwrap().unwrap();
    let mixed_b = rx_b.recv().unwrap().unwrap();
    for (s, m) in solo_a.iter().zip(&mixed_a.logits) {
        assert!((s - m).abs() < 1e-4, "{s} vs {m}");
    }
    for (s, m) in solo_b.iter().zip(&mixed_b.logits) {
        assert!((s - m).abs() < 1e-4, "{s} vs {m}");
    }
}

#[test]
fn unknown_task_and_bad_lengths_rejected() {
    let Some(c) = coordinator() else { return };
    assert!(c.classify("nope", ids(1, 5)).is_err());
    assert!(c.submit(Request { task: "a".into(), ids: vec![] }).is_err());
    let too_long = ids(1, 4000);
    assert!(c.submit(Request { task: "a".into(), ids: too_long }).is_err());
}

#[test]
fn zero_table_task_equals_frozen_backbone_plus_head() {
    // A zero P table must not perturb the backbone at all: two zero-table
    // tasks with the same head give identical logits for the same input.
    let Some((runtime, manifest, registry, _weights)) = setup() else { return };
    let model = manifest.model("tiny").unwrap().clone();
    let mut rng = Pcg64::new(9);
    let head_w = Tensor::from_f32(&[model.d_model, 2], rng.normal_vec(model.d_model * 2, 0.05));
    let head_b = Tensor::from_f32(&[2], vec![0.1, -0.1]);
    registry.register_zero("z1", &head_w, &head_b).unwrap();
    registry.register_zero("z2", &head_w, &head_b).unwrap();
    let c = match Coordinator::new(
        runtime,
        &manifest,
        registry,
        CoordinatorConfig {
            model: "tiny".into(),
            linger_ms: 1,
            signature: "aot".into(),
            ..Default::default()
        },
    ) {
        Ok(c) => c,
        Err(e) => {
            eprintln!("skipping: PJRT coordinator unavailable ({e:#})");
            return;
        }
    };
    let input = ids(10, 8);
    let r1 = c.classify("z1", input.clone()).unwrap();
    let r2 = c.classify("z2", input).unwrap();
    assert_eq!(r1.logits, r2.logits);
}

#[test]
fn metrics_accumulate() {
    let Some(c) = coordinator() else { return };
    for i in 0..6 {
        c.classify(if i % 2 == 0 { "a" } else { "b" }, ids(20 + i, 7)).unwrap();
    }
    let snap = c.metrics().snapshot();
    assert_eq!(snap.requests, 6);
    assert!(snap.batches >= 1 && snap.batches <= 6);
    assert!(snap.mean_exec_ms > 0.0);
    assert!(snap.gather_fraction >= 0.0 && snap.gather_fraction < 0.9);
}

#[test]
fn concurrent_submitters_all_get_answers() {
    let Some(c) = coordinator() else { return };
    let c = Arc::new(c);
    let mut handles = Vec::new();
    for t in 0..4u64 {
        let c = Arc::clone(&c);
        handles.push(std::thread::spawn(move || {
            let task = if t % 2 == 0 { "a" } else { "b" };
            let mut answers = Vec::new();
            for i in 0..5 {
                let resp = c.classify(task, ids(100 * t + i, 10)).unwrap();
                answers.push(resp.argmax());
            }
            answers
        }));
    }
    for h in handles {
        let answers = h.join().unwrap();
        assert_eq!(answers.len(), 5);
    }
    assert_eq!(c.metrics().snapshot().requests, 20);
}
