//! SIMD-vs-scalar bit-parity sweep for the row kernels (DESIGN.md §14).
//!
//! The dispatch contract is that every SIMD kernel produces the exact
//! bit pattern of the portable scalar reference on every input.  This
//! suite sweeps that claim across
//!
//! * all 65536 f16 bit patterns (every NaN payload, every subnormal),
//! * all 256 int8 codes under several scale/zero pairs,
//! * odd row widths (d = 1, 7, 8, 15, 16, 31, 64) so vector bodies and
//!   scalar tails both run,
//! * unaligned byte slices (the mmap cold tier hands out payloads at
//!   arbitrary file offsets),
//! * end-to-end gathers over f32/f16/int8 × dedup × resident/spilled
//!   stores with the global kernel flipped per leg.
//!
//! Concurrency rule: tests in this binary run on parallel threads, so
//! only ONE test (`gather_bit_parity_across_kernels`) may touch the
//! global dispatch state; every other test drives kernels through
//! direct `&RowKernel` references from `kernel::available()`.

use aotpt::peft::kernel::{self, RowKernel};
use aotpt::peft::{AdapterConfig, AdapterDType, PStore, TaskP};
use aotpt::util::Pcg64;

/// The sweep widths: one short of / exactly / one past the 4-, 8-, 16-
/// and 32-lane boundaries, plus a realistic row width.
const WIDTHS: [usize; 7] = [1, 7, 8, 15, 16, 31, 64];

fn simd_kernels() -> Vec<&'static RowKernel> {
    kernel::available().into_iter().filter(|k| k.name != "scalar").collect()
}

#[test]
fn f16_parity_is_exhaustive_over_all_bit_patterns() {
    // Every f16 value that exists: zeros, subnormals, normals, both
    // infinities and every NaN payload (signaling and quiet).
    let bits: Vec<u16> = (0..=u16::MAX).collect();
    let mut reference = vec![0f32; bits.len()];
    kernel::scalar().dequant_f16(&bits, &mut reference);
    for k in simd_kernels() {
        let mut out = vec![0f32; bits.len()];
        k.dequant_f16(&bits, &mut out);
        for (i, (r, o)) in reference.iter().zip(&out).enumerate() {
            assert_eq!(
                r.to_bits(),
                o.to_bits(),
                "kernel {} diverges on f16 bits {:#06x}: scalar {:#010x} vs {:#010x}",
                k.name,
                bits[i],
                r.to_bits(),
                o.to_bits()
            );
        }
    }
}

#[test]
fn f16_parity_holds_on_odd_widths_and_unaligned_tails() {
    // A payload dense in special values, served at every width from
    // every byte offset 0..4 — the mmap cold tier does not align rows.
    let specials: [u16; 12] = [
        0x0000, 0x8000, // ±0
        0x0001, 0x83ff, // subnormals
        0x7c00, 0xfc00, // ±inf
        0x7c01, 0x7e00, 0xfeaa, // NaNs (signaling + quiet payloads)
        0x3c00, 0xbc00, 0x7bff, // ±1, f16::MAX
    ];
    let mut rng = Pcg64::new(41);
    for &d in &WIDTHS {
        let row: Vec<u16> = (0..d)
            .map(|i| {
                if i % 3 == 0 {
                    specials[rng.range(0, specials.len() as i64) as usize]
                } else {
                    rng.range(0, u16::MAX as i64 + 1) as u16
                }
            })
            .collect();
        for offset in 0..4usize {
            let mut bytes = vec![0u8; offset + 2 * d];
            for (i, &b) in row.iter().enumerate() {
                bytes[offset + 2 * i..offset + 2 * i + 2].copy_from_slice(&b.to_le_bytes());
            }
            let payload = &bytes[offset..];
            let mut reference = vec![0f32; d];
            kernel::scalar().dequant_f16_le(payload, &mut reference);
            for k in simd_kernels() {
                let mut out = vec![0f32; d];
                k.dequant_f16_le(payload, &mut out);
                let same = reference.iter().zip(&out).all(|(r, o)| r.to_bits() == o.to_bits());
                assert!(same, "kernel {} d={d} offset={offset}", k.name);
            }
        }
    }
}

#[test]
fn i8_parity_covers_every_code_at_every_width() {
    // Scale/zero pairs: a typical quantizer output, exact zero scale
    // (constant rows), a negative scale, and a subnormal-producing pair.
    let params: [(f32, f32); 4] =
        [(0.031, -1.5), (0.0, 4.25), (-2.25e-3, 7.0), (1.0e-41, 0.0)];
    for &d in &WIDTHS {
        for shift in 0..3usize {
            // Rotate through all 256 codes so every width sees the full
            // range across shifts.
            let codes: Vec<i8> = (0..d).map(|i| ((i * 37 + shift * 11) % 256) as u8 as i8).collect();
            for &(scale, zero) in &params {
                let mut reference = vec![0f32; d];
                kernel::scalar().dequant_i8(&codes, scale, zero, &mut reference);
                for k in simd_kernels() {
                    let mut out = vec![0f32; d];
                    k.dequant_i8(&codes, scale, zero, &mut out);
                    let same = reference.iter().zip(&out).all(|(r, o)| r.to_bits() == o.to_bits());
                    assert!(same, "kernel {} d={d} shift={shift} scale={scale}", k.name);
                }
            }
        }
    }
}

#[test]
fn f32_decode_and_copy_preserve_bits_at_every_width() {
    let mut rng = Pcg64::new(43);
    for &d in &WIDTHS {
        let mut row: Vec<f32> = rng.normal_vec(d, 1.0);
        row[0] = f32::NAN;
        if d > 2 {
            row[1] = -0.0;
            row[2] = f32::INFINITY;
        }
        for offset in 0..4usize {
            let mut bytes = vec![0u8; offset + 4 * d];
            for (i, v) in row.iter().enumerate() {
                bytes[offset + 4 * i..offset + 4 * i + 4].copy_from_slice(&v.to_le_bytes());
            }
            let payload = &bytes[offset..];
            for k in simd_kernels() {
                let mut out = vec![0f32; d];
                k.decode_f32_le(payload, &mut out);
                let same = row.iter().zip(&out).all(|(r, o)| r.to_bits() == o.to_bits());
                assert!(same, "kernel {} decode d={d} offset={offset}", k.name);
            }
        }
        for k in simd_kernels() {
            let mut out = vec![0f32; d];
            k.copy_f32(&row, &mut out);
            let same = row.iter().zip(&out).all(|(r, o)| r.to_bits() == o.to_bits());
            assert!(same, "kernel {} copy d={d}", k.name);
        }
    }
}

#[test]
fn rows_equal_agrees_with_scalar_at_every_length_and_diff_position() {
    for len in 0..70usize {
        let a: Vec<u8> = (0..len).map(|i| (i * 31 + 5) as u8).collect();
        for k in simd_kernels() {
            assert!(k.rows_equal(&a, &a), "{} len={len} self-equality", k.name);
        }
        for diff in 0..len {
            let mut b = a.clone();
            b[diff] ^= 0x80;
            for k in simd_kernels() {
                assert!(!k.rows_equal(&a, &b), "{} len={len} missed diff at {diff}", k.name);
            }
        }
    }
}

/// One store per (dtype, dedup, spilled) leg at width `d`, filled with a
/// payload that keeps shared/zero/special rows in play for dedup.
fn build_store(dtype: AdapterDType, dedup: bool, spilled: bool, d: usize) -> PStore {
    let (layers, vocab) = (2usize, 48usize);
    let cfg = AdapterConfig {
        // 1 byte of budget forces every insert straight to the disk
        // tier, so gathers exercise the cold decode + plan sort.
        ram_budget_bytes: if spilled { 1 } else { 0 },
        dtype,
        dedup,
        ..AdapterConfig::default()
    };
    let store = PStore::with_config(layers, vocab, d, cfg);
    let mut rng = Pcg64::new(7 + d as u64);
    for task in ["a", "b"] {
        let mut data = rng.normal_vec(layers * vocab * d, 0.8);
        for row in 0..layers * vocab {
            match row % 5 {
                // Zero and repeated rows give the dedup pass something
                // to collapse; tiny values quantize to f16 subnormals.
                0 => data[row * d..(row + 1) * d].fill(0.0),
                1 => data[row * d..(row + 1) * d].fill(1.0),
                2 => data[row * d..(row + 1) * d].fill(1.0e-5),
                _ => {}
            }
        }
        store.insert(task, TaskP::new(layers, vocab, d, data).unwrap()).unwrap();
    }
    store
}

/// The ONLY test allowed to flip the global kernel (see module doc).
/// Drives the full gather path — tier dispatch, dedup indirection, cold
/// decode, gather plan sort — under every kernel and asserts the output
/// is bit-identical to the scalar leg.
#[test]
fn gather_bit_parity_across_kernels() {
    let n = 5usize;
    let legs: [(AdapterDType, bool, bool); 5] = [
        (AdapterDType::F32, false, false),
        (AdapterDType::F16, false, false),
        (AdapterDType::I8, false, false),
        (AdapterDType::F16, true, false),
        (AdapterDType::F16, false, true),
    ];
    let mut rng = Pcg64::new(11);
    for &d in &WIDTHS {
        for &(dtype, dedup, spilled) in &legs {
            let store = build_store(dtype, dedup, spilled, d);
            let ids: Vec<i32> = (0..2 * n).map(|_| rng.range(0, 48) as i32).collect();
            kernel::force(kernel::scalar());
            let reference = store.gather(&["a", "b"], &ids, n).unwrap();
            let reference = reference.as_f32().unwrap();
            for k in kernel::available() {
                kernel::force(k);
                let got = store.gather(&["a", "b"], &ids, n).unwrap();
                let got = got.as_f32().unwrap();
                let same =
                    reference.iter().zip(got.iter()).all(|(r, o)| r.to_bits() == o.to_bits());
                assert!(
                    same,
                    "kernel {} gather diverges: dtype {:?} dedup={dedup} spilled={spilled} d={d}",
                    k.name, dtype
                );
            }
        }
    }
    kernel::set_active(kernel::KernelMode::Auto);
}
