//! Integration: the HTTP serving front end over a raw `TcpStream`
//! client — framing edge cases, the error-code table, deadline/overload
//! behavior, bit-exactness vs in-process classify, the management plane
//! round trip, and graceful drain under load.

use std::collections::BTreeMap;
use std::io::{Read, Write};
use std::net::{Shutdown, SocketAddr, TcpStream};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;
use std::time::Duration;

use aotpt::coordinator::{
    Backend, BatchBuffers, BatchPlan, Bucket, Coordinator, CoordinatorConfig, HostBackend,
    TaskRegistry,
};
use aotpt::json::{self, Json};
use aotpt::peft::TaskP;
use aotpt::server::{Server, ServerConfig};
use aotpt::tensor::{ckpt, Tensor};
use aotpt::util::Pcg64;

const LAYERS: usize = 2;
const VOCAB: usize = 64;
const D_MODEL: usize = 8;
const CLASSES: usize = 2;

fn registry(n_tasks: usize) -> TaskRegistry {
    let registry = TaskRegistry::new(LAYERS, VOCAB, D_MODEL, CLASSES);
    let mut rng = Pcg64::new(11);
    for i in 0..n_tasks {
        let table = TaskP::new(
            LAYERS,
            VOCAB,
            D_MODEL,
            rng.normal_vec(LAYERS * VOCAB * D_MODEL, 0.3),
        )
        .unwrap();
        let head_w =
            Tensor::from_f32(&[D_MODEL, CLASSES], rng.normal_vec(D_MODEL * CLASSES, 0.2));
        let head_b = Tensor::from_f32(&[CLASSES], vec![0.0; CLASSES]);
        registry.register_fused(&format!("task{i}"), table, &head_w, &head_b).unwrap();
    }
    registry
}

fn coordinator(backend: Arc<dyn Backend>, n_tasks: usize) -> Arc<Coordinator> {
    Arc::new(
        Coordinator::with_backend(
            registry(n_tasks),
            vec![Bucket { batch: 4, seq: 16 }],
            CLASSES,
            CoordinatorConfig {
                model: "host".into(),
                linger_ms: 1,
                signature: "aot".into(),
                ..Default::default()
            },
            backend,
        )
        .unwrap(),
    )
}

fn test_config() -> ServerConfig {
    ServerConfig {
        addr: "127.0.0.1:0".into(),
        mgmt_addr: Some("127.0.0.1:0".into()),
        request_deadline: Duration::from_secs(5),
        io_timeout: Duration::from_secs(2),
        ..ServerConfig::default()
    }
}

fn server(backend: Arc<dyn Backend>, n_tasks: usize) -> Server {
    Server::bind(coordinator(backend, n_tasks), test_config()).unwrap()
}

struct StalledBackend {
    stall: Duration,
    batches: AtomicUsize,
}

impl StalledBackend {
    fn new(stall_ms: u64) -> Arc<StalledBackend> {
        Arc::new(StalledBackend {
            stall: Duration::from_millis(stall_ms),
            batches: AtomicUsize::new(0),
        })
    }
}

impl Backend for StalledBackend {
    fn execute(&self, plan: &BatchPlan, bufs: &BatchBuffers) -> aotpt::Result<Vec<f32>> {
        self.batches.fetch_add(1, Ordering::SeqCst);
        std::thread::sleep(self.stall);
        HostBackend.execute(plan, bufs)
    }

    fn name(&self) -> &'static str {
        "stalled-host"
    }
}

// ------------------------------------------------------------ raw client

struct HttpResponse {
    status: u16,
    headers: Vec<(String, String)>,
    body: Vec<u8>,
}

impl HttpResponse {
    fn header(&self, name: &str) -> Option<&str> {
        self.headers.iter().find(|(k, _)| k == name).map(|(_, v)| v.as_str())
    }

    fn json(&self) -> Json {
        json::parse(std::str::from_utf8(&self.body).expect("UTF-8 body")).expect("JSON body")
    }
}

/// Send raw bytes, read the (connection-close) response to EOF, parse.
fn raw_round_trip(addr: SocketAddr, raw: &[u8]) -> HttpResponse {
    let mut stream = TcpStream::connect(addr).expect("connect");
    stream.set_read_timeout(Some(Duration::from_secs(20))).unwrap();
    stream.write_all(raw).expect("send");
    let mut buf = Vec::new();
    stream.read_to_end(&mut buf).expect("read response");
    parse_response(&buf)
}

fn parse_response(buf: &[u8]) -> HttpResponse {
    let head_end = buf
        .windows(4)
        .position(|w| w == b"\r\n\r\n")
        .expect("response head terminator");
    let head = std::str::from_utf8(&buf[..head_end]).expect("UTF-8 head");
    let mut lines = head.lines();
    let status_line = lines.next().expect("status line");
    let status: u16 = status_line
        .split_whitespace()
        .nth(1)
        .expect("status code")
        .parse()
        .expect("numeric status");
    let headers = lines
        .filter_map(|l| l.split_once(':'))
        .map(|(k, v)| (k.trim().to_ascii_lowercase(), v.trim().to_string()))
        .collect();
    HttpResponse { status, headers, body: buf[head_end + 4..].to_vec() }
}

/// One request/response on a fresh connection (`connection: close`).
fn request(addr: SocketAddr, method: &str, path: &str, body: Option<&[u8]>) -> HttpResponse {
    let body = body.unwrap_or(b"");
    let head = format!(
        "{method} {path} HTTP/1.1\r\nhost: test\r\nconnection: close\r\ncontent-length: {}\r\n\r\n",
        body.len()
    );
    let mut raw = head.into_bytes();
    raw.extend_from_slice(body);
    raw_round_trip(addr, &raw)
}

fn classify_body(task: &str, ids: &[i32], timeout_ms: Option<u64>) -> Vec<u8> {
    let ids = ids.iter().map(|i| i.to_string()).collect::<Vec<_>>().join(",");
    let timeout = timeout_ms.map(|t| format!(",\"timeout_ms\":{t}")).unwrap_or_default();
    format!("{{\"task\":\"{task}\",\"ids\":[{ids}]{timeout}}}").into_bytes()
}

fn ids(seed: u64) -> Vec<i32> {
    let mut rng = Pcg64::new(seed);
    (0..6).map(|_| rng.range(0, VOCAB as i64) as i32).collect()
}

// ------------------------------------------------------------------- tests

#[test]
fn healthz_on_both_planes() {
    let server = server(Arc::new(HostBackend), 1);
    for addr in [server.data_addr(), server.mgmt_addr().unwrap()] {
        let resp = request(addr, "GET", "/healthz", None);
        assert_eq!(resp.status, 200);
        assert_eq!(resp.body, b"ok\n");
    }
}

#[test]
fn classify_over_http_matches_in_process_bit_exactly() {
    let server = server(Arc::new(HostBackend), 2);
    let input = ids(42);
    let expected = server.coordinator().classify("task1", input.clone()).unwrap();
    let resp = request(
        server.data_addr(),
        "POST",
        "/v1/classify",
        Some(&classify_body("task1", &input, None)),
    );
    assert_eq!(resp.status, 200, "{}", String::from_utf8_lossy(&resp.body));
    let doc = resp.json();
    assert_eq!(doc.get("task").and_then(|t| t.as_str()), Some("task1"));
    let logits: Vec<f32> = doc
        .get("logits")
        .and_then(|l| l.as_arr())
        .expect("logits array")
        .iter()
        .map(|x| x.as_f64().expect("numeric logit") as f32)
        .collect();
    assert_eq!(logits.len(), expected.logits.len());
    // f32 -> f64 -> shortest-repr decimal -> f64 -> f32 is lossless, so
    // the HTTP path must reproduce in-process logits bit for bit.
    for (h, e) in logits.iter().zip(&expected.logits) {
        assert_eq!(h.to_bits(), e.to_bits(), "{h} vs {e}");
    }
    assert_eq!(
        doc.get("argmax").and_then(|a| a.as_f64()).map(|a| a as usize),
        expected.argmax()
    );
}

#[test]
fn error_table_on_the_data_plane() {
    let server = server(Arc::new(HostBackend), 1);
    let addr = server.data_addr();

    // Malformed request line.
    let resp = raw_round_trip(addr, b"NOT-HTTP\r\n\r\n");
    assert_eq!(resp.status, 400);

    // Unsupported protocol version.
    let resp = raw_round_trip(addr, b"GET /healthz SPDY/3\r\n\r\n");
    assert_eq!(resp.status, 505);

    // Oversized head: never reaches a terminator before the cap.
    let mut raw = b"GET /healthz HTTP/1.1\r\n".to_vec();
    let filler = format!("x-filler: {}\r\n", "y".repeat(4000));
    for _ in 0..6 {
        raw.extend_from_slice(filler.as_bytes());
    }
    let resp = raw_round_trip(addr, &raw);
    assert_eq!(resp.status, 431);

    // Truncated body: declared 64 bytes, delivered 9, then EOF.
    let mut stream = TcpStream::connect(addr).unwrap();
    stream.set_read_timeout(Some(Duration::from_secs(20))).unwrap();
    stream
        .write_all(b"POST /v1/classify HTTP/1.1\r\ncontent-length: 64\r\n\r\n{\"task\":\"")
        .unwrap();
    stream.shutdown(Shutdown::Write).unwrap();
    let mut buf = Vec::new();
    stream.read_to_end(&mut buf).unwrap();
    assert_eq!(parse_response(&buf).status, 400);

    // Bad JSON, wrong shapes, unknown task, wrong method.
    let resp = request(addr, "POST", "/v1/classify", Some(b"{not json"));
    assert_eq!(resp.status, 400);
    let resp = request(addr, "POST", "/v1/classify", Some(b"{\"task\":\"task0\"}"));
    assert_eq!(resp.status, 400);
    let resp = request(addr, "POST", "/v1/classify", Some(&classify_body("nope", &ids(1), None)));
    assert_eq!(resp.status, 404);
    let resp = request(addr, "PUT", "/v1/classify", None);
    assert_eq!(resp.status, 405);
    assert_eq!(resp.header("allow"), Some("POST"));
    let resp = request(addr, "GET", "/no/such/route", None);
    assert_eq!(resp.status, 404);

    // Management routes are absent from the data plane.
    let resp = request(addr, "GET", "/metrics", None);
    assert_eq!(resp.status, 404);
    let resp = request(addr, "POST", "/mgmt/shutdown", None);
    assert_eq!(resp.status, 404);
}

#[test]
fn deadline_maps_to_504() {
    let server = server(StalledBackend::new(500), 1);
    let resp = request(
        server.data_addr(),
        "POST",
        "/v1/classify",
        Some(&classify_body("task0", &ids(3), Some(20))),
    );
    assert_eq!(resp.status, 504, "{}", String::from_utf8_lossy(&resp.body));
    let msg = resp.json();
    assert!(
        msg.get("error").and_then(|e| e.as_str()).unwrap().contains("deadline exceeded"),
        "{msg:?}"
    );
}

#[test]
fn overload_maps_to_429_with_retry_after() {
    let mut cfg = test_config();
    cfg.queue_limit = 1;
    let server =
        Server::bind(coordinator(StalledBackend::new(400) as Arc<dyn Backend>, 1), cfg).unwrap();
    let addr = server.data_addr();
    let slow = std::thread::spawn(move || {
        request(addr, "POST", "/v1/classify", Some(&classify_body("task0", &ids(4), None)))
    });
    // Let the slow request occupy the single admission slot.
    std::thread::sleep(Duration::from_millis(100));
    let resp =
        request(addr, "POST", "/v1/classify", Some(&classify_body("task0", &ids(5), None)));
    assert_eq!(resp.status, 429, "{}", String::from_utf8_lossy(&resp.body));
    assert_eq!(resp.header("retry-after"), Some("1"));
    assert_eq!(slow.join().unwrap().status, 200);
}

#[test]
fn metrics_scrape_text_and_json() {
    let server = server(Arc::new(HostBackend), 1);
    let resp = request(
        server.data_addr(),
        "POST",
        "/v1/classify",
        Some(&classify_body("task0", &ids(6), None)),
    );
    assert_eq!(resp.status, 200);
    let mgmt = server.mgmt_addr().unwrap();

    let text = request(mgmt, "GET", "/metrics", None);
    assert_eq!(text.status, 200);
    let rendered = String::from_utf8(text.body).unwrap();
    assert!(rendered.contains("requests=1"), "{rendered}");

    let as_json = request(mgmt, "GET", "/metrics?format=json", None);
    assert_eq!(as_json.status, 200);
    let doc = as_json.json();
    assert_eq!(doc.path("requests").and_then(|r| r.as_usize()), Some(1));
    assert_eq!(doc.path("queue_depth").and_then(|q| q.as_usize()), Some(0));
    assert!(doc.path("adapter.kernel").is_some());
}

#[test]
fn mgmt_adapter_register_pin_unregister_round_trip() {
    let server = server(Arc::new(HostBackend), 1);
    let mgmt = server.mgmt_addr().unwrap();
    let data = server.data_addr();

    // Build a real .aotckpt upload body.
    let mut rng = Pcg64::new(99);
    let mut tensors = BTreeMap::new();
    tensors.insert(
        "p".to_string(),
        Tensor::from_f32(
            &[LAYERS, VOCAB, D_MODEL],
            rng.normal_vec(LAYERS * VOCAB * D_MODEL, 0.3),
        ),
    );
    tensors.insert(
        "head_w".to_string(),
        Tensor::from_f32(&[D_MODEL, CLASSES], rng.normal_vec(D_MODEL * CLASSES, 0.2)),
    );
    tensors.insert("head_b".to_string(), Tensor::from_f32(&[CLASSES], vec![0.25, -0.25]));
    let path = std::env::temp_dir()
        .join(format!("aotpt-server-test-upload-{}.aotckpt", std::process::id()));
    ckpt::save(&path, &tensors).unwrap();
    let upload = std::fs::read(&path).unwrap();
    let _ = std::fs::remove_file(&path);

    // Register (+pin) via streamed upload.
    let resp = request(mgmt, "POST", "/mgmt/adapters?name=uploaded&pin=true", Some(&upload));
    assert_eq!(resp.status, 200, "{}", String::from_utf8_lossy(&resp.body));
    let doc = resp.json();
    assert_eq!(doc.get("task").and_then(|t| t.as_str()), Some("uploaded"));
    assert_eq!(doc.get("classes").and_then(|c| c.as_usize()), Some(CLASSES));
    assert_eq!(doc.get("replaced").and_then(|r| r.as_bool()), Some(false));
    assert_eq!(doc.get("pinned").and_then(|p| p.as_bool()), Some(true));

    // Listed, pinned, and servable.
    let listing = request(mgmt, "GET", "/mgmt/adapters", None).json();
    let tasks = listing.get("tasks").and_then(|t| t.as_arr()).unwrap();
    let uploaded = tasks
        .iter()
        .find(|t| t.get("name").and_then(|n| n.as_str()) == Some("uploaded"))
        .expect("uploaded task listed");
    assert_eq!(uploaded.get("pinned").and_then(|p| p.as_bool()), Some(true));
    assert_eq!(uploaded.get("classes").and_then(|c| c.as_usize()), Some(CLASSES));
    let resp =
        request(data, "POST", "/v1/classify", Some(&classify_body("uploaded", &ids(7), None)));
    assert_eq!(resp.status, 200, "{}", String::from_utf8_lossy(&resp.body));

    // Replace is reported as such.
    let resp = request(mgmt, "POST", "/mgmt/adapters?name=uploaded", Some(&upload));
    assert_eq!(resp.status, 200);
    assert_eq!(resp.json().get("replaced").and_then(|r| r.as_bool()), Some(true));

    // Unpin, unregister, and confirm it is gone end to end.
    let resp = request(mgmt, "POST", "/mgmt/adapters/pin?name=uploaded&state=off", None);
    assert_eq!(resp.status, 200);
    let resp = request(mgmt, "DELETE", "/mgmt/adapters?name=uploaded", None);
    assert_eq!(resp.status, 200);
    let resp = request(mgmt, "DELETE", "/mgmt/adapters?name=uploaded", None);
    assert_eq!(resp.status, 404);
    let resp =
        request(data, "POST", "/v1/classify", Some(&classify_body("uploaded", &ids(7), None)));
    assert_eq!(resp.status, 404);

    // Upload edge cases: empty body, garbage bytes, missing name.
    let resp = request(mgmt, "POST", "/mgmt/adapters?name=empty", Some(b""));
    assert_eq!(resp.status, 400);
    let resp = request(mgmt, "POST", "/mgmt/adapters?name=garbage", Some(b"not a ckpt"));
    assert_eq!(resp.status, 400);
    let resp = request(mgmt, "POST", "/mgmt/adapters", Some(&upload));
    assert_eq!(resp.status, 400);
}

#[test]
fn shutdown_endpoint_latches_drain_request() {
    let server = server(Arc::new(HostBackend), 1);
    assert!(!server.shutdown_requested());
    let resp = request(server.mgmt_addr().unwrap(), "POST", "/mgmt/shutdown", None);
    assert_eq!(resp.status, 200);
    assert_eq!(resp.json().get("status").and_then(|s| s.as_str()), Some("draining"));
    assert!(server.shutdown_requested());
}

#[test]
fn drain_while_serving_loses_no_replies() {
    let server = server(StalledBackend::new(80) as Arc<dyn Backend>, 2);
    let addr = server.data_addr();
    let mut clients = Vec::new();
    for i in 0..8u64 {
        clients.push(std::thread::spawn(move || {
            request(
                addr,
                "POST",
                "/v1/classify",
                Some(&classify_body(&format!("task{}", i % 2), &ids(50 + i), None)),
            )
        }));
    }
    // Let the burst get admitted, then drain underneath it.
    std::thread::sleep(Duration::from_millis(60));
    let snapshot = server.drain();
    let mut served = 0;
    for client in clients {
        let resp = client.join().unwrap();
        // Every client gets a definitive answer: a successful classify,
        // or an explicit drain refusal for stragglers that submitted
        // after admission closed.
        assert!(
            resp.status == 200 || resp.status == 503,
            "unexpected status {}: {}",
            resp.status,
            String::from_utf8_lossy(&resp.body)
        );
        if resp.status == 200 {
            served += 1;
        }
    }
    assert!(served >= 1, "drain answered nothing successfully");
    assert_eq!(snapshot.queue_depth, 0, "drain leaked queue depth");
}
