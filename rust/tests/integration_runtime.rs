//! Integration: AOT artifacts (JAX/Pallas → HLO text) load, compile and
//! execute through the Rust PJRT runtime with correct numerics.
//!
//! Golden inputs/outputs were produced by `python/compile/aot.py`; these
//! tests require `make artifacts` to have run (they panic with a clear
//! message otherwise, as they are the core L1↔L3 composition proof).

use aotpt::config::Manifest;
use aotpt::runtime::{Runtime, WeightCache};
use aotpt::tensor::{ckpt, Tensor};

/// `None` (and the test is skipped) when the AOT artifacts are missing:
/// `make artifacts` needs the Python L1/L2 toolchain, and the default
/// `cargo test` run must stay green without it.  When artifacts exist,
/// these tests are the core L1↔L3 composition proof.
fn manifest() -> Option<Manifest> {
    let dir = aotpt::artifacts_dir();
    if !dir.join("manifest.json").exists() {
        eprintln!("skipping: AOT artifacts not built (run `make artifacts`)");
        return None;
    }
    Some(Manifest::load(&dir).expect("manifest loads"))
}

fn assert_close(a: &[f32], b: &[f32], tol: f32, what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: length");
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        assert!(
            (x - y).abs() <= tol * (1.0 + y.abs()),
            "{what}: element {i}: {x} vs {y}"
        );
    }
}

/// The Pallas aot_bias kernel (interpret-mode) survives the full
/// jax → HLO text → PJRT-compile → execute round trip from Rust.
#[test]
fn pallas_aot_bias_kernel_roundtrip() {
    let Some(m) = manifest() else { return };
    let rt = Runtime::new().unwrap();
    let Ok(exe) = rt.load(&m, "kernel_aot_bias") else {
        eprintln!("skipping: no executable backend (build with --features pjrt)");
        return;
    };

    let golden = ckpt::load(&aotpt::artifacts_dir().join("golden_kernel_aot_bias.aotckpt"))
        .expect("golden checkpoint");
    let args: Vec<Tensor> = exe
        .spec
        .inputs
        .iter()
        .map(|spec| golden[&spec.name].clone())
        .collect();
    let outs = exe.run(&args).unwrap();
    assert_eq!(outs.len(), 1);
    assert_close(
        outs[0].as_f32().unwrap(),
        golden["out"].as_f32().unwrap(),
        1e-5,
        "kernel_aot_bias",
    );
}

/// Full tiny-model multi-task forward (fused AoT host-gather path) matches
/// the Python golden logits.
#[test]
fn fwd_tiny_aot_matches_golden() {
    let Some(m) = manifest() else { return };
    let rt = Runtime::new().unwrap();
    let Ok(exe) = rt.load(&m, "fwd_tiny_aot_b2n16") else {
        eprintln!("skipping: no executable backend (build with --features pjrt)");
        return;
    };

    let weights = WeightCache::from_ckpt(
        &rt,
        &aotpt::artifacts_dir().join("backbone_tiny.aotckpt"),
    )
    .unwrap();
    let golden = ckpt::load(&aotpt::artifacts_dir().join("golden_fwd_tiny_aot.aotckpt")).unwrap();

    let mut args: Vec<Tensor> = Vec::new();
    for spec in &exe.spec.inputs {
        if let Some(name) = spec.name.strip_prefix("w.") {
            args.push(weights.host(name).unwrap().clone());
        } else {
            args.push(golden[&spec.name].clone());
        }
    }
    let outs = exe.run(&args).unwrap();
    assert_close(
        outs[0].as_f32().unwrap(),
        golden["logits"].as_f32().unwrap(),
        1e-4,
        "fwd_tiny_aot logits",
    );
}

/// execute_b with device-resident weight buffers gives the same answer as
/// uploading everything per call (the serving hot path is exact).
#[test]
fn buffer_execution_matches_literal_execution() {
    let Some(m) = manifest() else { return };
    let rt = Runtime::new().unwrap();
    let Ok(exe) = rt.load(&m, "fwd_tiny_aot_b2n16") else {
        eprintln!("skipping: no executable backend (build with --features pjrt)");
        return;
    };
    let weights =
        WeightCache::from_ckpt(&rt, &aotpt::artifacts_dir().join("backbone_tiny.aotckpt"))
            .unwrap();
    let golden = ckpt::load(&aotpt::artifacts_dir().join("golden_fwd_tiny_aot.aotckpt")).unwrap();

    // Literal path.
    let mut args: Vec<Tensor> = Vec::new();
    for spec in &exe.spec.inputs {
        if let Some(name) = spec.name.strip_prefix("w.") {
            args.push(weights.host(name).unwrap().clone());
        } else {
            args.push(golden[&spec.name].clone());
        }
    }
    let lit_out = exe.run(&args).unwrap();

    // Buffer path: weights from the cache, per-call inputs uploaded here.
    let mut uploaded = Vec::new();
    for spec in &exe.spec.inputs {
        if spec.name.starts_with("w.") {
            continue;
        }
        uploaded.push(exe.upload(&golden[&spec.name]).unwrap());
    }
    let mut buf_args: Vec<&xla::PjRtBuffer> = Vec::new();
    let mut up_iter = uploaded.iter();
    for spec in &exe.spec.inputs {
        if let Some(name) = spec.name.strip_prefix("w.") {
            buf_args.push(weights.buffer(name).unwrap());
        } else {
            buf_args.push(up_iter.next().unwrap());
        }
    }
    let buf_out = exe.run_buffers(&buf_args).unwrap();

    assert_close(
        buf_out[0].as_f32().unwrap(),
        lit_out[0].as_f32().unwrap(),
        1e-6,
        "buffer vs literal",
    );
}

/// Executable caching: loading the same stem twice compiles once.
#[test]
fn executable_cache_hits() {
    let Some(m) = manifest() else { return };
    let rt = Runtime::new().unwrap();
    let Ok(a) = rt.load(&m, "kernel_attention") else {
        eprintln!("skipping: no executable backend (build with --features pjrt)");
        return;
    };
    let before = rt.compiled_count();
    let b = rt.load(&m, "kernel_attention").unwrap();
    assert_eq!(rt.compiled_count(), before);
    assert!(std::sync::Arc::ptr_eq(&a, &b));
}

/// A multi-output artifact (train step) returns the declared output count
/// and finite values. Uses the smallest training artifact.
#[test]
fn train_step_outputs_match_manifest() {
    let Some(m) = manifest() else { return };
    let rt = Runtime::new().unwrap();
    let hits = m.find("train", "tiny", "bitfit");
    let spec = hits
        .iter()
        .find(|a| a.classes == 2)
        .expect("tiny bitfit train artifact");
    let Ok(exe) = rt.load(&m, &spec.stem) else {
        eprintln!("skipping: no executable backend (build with --features pjrt)");
        return;
    };
    let weights =
        WeightCache::from_ckpt(&rt, &aotpt::artifacts_dir().join("backbone_tiny.aotckpt"))
            .unwrap();

    let mut rng = aotpt::util::Pcg64::new(7);
    let mut args: Vec<Tensor> = Vec::new();
    for spec_in in &exe.spec.inputs {
        let t = if let Some(name) = spec_in.name.strip_prefix("w.") {
            weights.host(name).unwrap().clone()
        } else if spec_in.name == "in.step" {
            Tensor::scalar_i32(0)
        } else if spec_in.name == "in.seed" {
            Tensor::scalar_i32(42)
        } else if spec_in.name == "in.lr" {
            Tensor::scalar_f32(1e-3)
        } else if spec_in.name == "in.ids" {
            let n = spec_in.numel();
            Tensor::from_i32(
                &spec_in.shape,
                (0..n).map(|_| rng.range(0, 8192) as i32).collect(),
            )
        } else if spec_in.name == "in.mask" {
            Tensor::from_f32(&spec_in.shape, vec![1.0; spec_in.numel()])
        } else if spec_in.name == "in.labels" {
            let n = spec_in.numel();
            Tensor::from_f32(&spec_in.shape, (0..n).map(|_| (rng.below(2)) as f32).collect())
        } else {
            // trainable / adam moments: zeros (valid init for bitfit)
            Tensor::zeros(spec_in.dtype, &spec_in.shape)
        };
        args.push(t);
    }
    let outs = exe.run(&args).unwrap();
    assert_eq!(outs.len(), exe.spec.outputs.len());
    let loss_idx = exe.spec.output_index("loss").unwrap();
    let loss = outs[loss_idx].as_f32().unwrap()[0];
    assert!(loss.is_finite() && loss > 0.0, "loss={loss}");
    let step_idx = exe.spec.output_index("step").unwrap();
    assert_eq!(outs[step_idx].as_i32().unwrap()[0], exe.spec.steps_per_call as i32);
}
