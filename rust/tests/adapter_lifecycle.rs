//! Concurrency and property tests for the tiered adapter store's hot
//! task lifecycle (DESIGN.md §10): registration, replacement,
//! unregistration and LRU eviction racing in-flight gathers.
//!
//! The invariants under test:
//! * **snapshot isolation** — a gather resolves each row's table to an
//!   `Arc` snapshot up front; a concurrent unregister/replace/evict never
//!   corrupts the rows it copies (every gathered element comes from
//!   exactly one table version);
//! * **re-registration visibility** — after a replace, new gathers serve
//!   the new table;
//! * **budget correctness** — with more task bytes registered than the
//!   RAM budget admits, every task still serves exact values via spill +
//!   fault-in, and the residency counters surface in `MetricsSnapshot`;
//! * **dedup snapshot isolation** — on the dedup'd int8 tier (DESIGN.md
//!   §12) a replace swaps the row pool and the `u32` index together:
//!   in-flight gathers never mix one version's index with the other's
//!   rows, and the logical/stored row ratio surfaces in the metrics.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;

use aotpt::coordinator::{
    AdapterConfig, AdapterDType, Bucket, Coordinator, CoordinatorConfig, HostBackend, TaskRegistry,
};
use aotpt::peft::{PStore, RowSource, TaskP};
use aotpt::tensor::Tensor;
use aotpt::util::Pcg64;

const L: usize = 2;
const V: usize = 64;
const D: usize = 8;

fn constant_table(c: f32) -> TaskP {
    TaskP::new(L, V, D, vec![c; L * V * D]).unwrap()
}

/// A dedup fixture: even tokens map to all-zero rows (shared behind the
/// dedup index), odd tokens to a constant-`c` row (one stored row for
/// the whole table).  `V` is even, so row parity == token parity in
/// every layer, and int8 quantization is exact on both row kinds.
fn half_zero_table(c: f32) -> TaskP {
    let mut data = vec![0f32; L * V * D];
    for row in 0..L * V {
        if row % 2 == 1 {
            data[row * D..(row + 1) * D].fill(c);
        }
    }
    TaskP::new(L, V, D, data).unwrap()
}

/// A gather must never observe a torn table: while one thread replaces
/// task "x" between constant tables 1.0 and 2.0, every gathered row is
/// uniformly one of the two versions.
#[test]
fn replace_mid_stream_never_tears_a_gather() {
    let store = Arc::new(PStore::new(L, V, D));
    store.insert("x", constant_table(1.0)).unwrap();
    let stop = Arc::new(AtomicBool::new(false));

    let writer = {
        let store = Arc::clone(&store);
        let stop = Arc::clone(&stop);
        std::thread::spawn(move || {
            let mut version = 0u64;
            while !stop.load(Ordering::Relaxed) {
                version += 1;
                let c = if version % 2 == 0 { 1.0 } else { 2.0 };
                store.insert("x", constant_table(c)).unwrap();
            }
        })
    };

    let readers: Vec<_> = (0..3)
        .map(|seed| {
            let store = Arc::clone(&store);
            let stop = Arc::clone(&stop);
            std::thread::spawn(move || {
                let mut rng = Pcg64::new(100 + seed);
                let mut gathers = 0usize;
                while !stop.load(Ordering::Relaxed) && gathers < 400 {
                    let n = 1 + (rng.below(6) as usize);
                    let b = 1 + (rng.below(3) as usize);
                    let ids: Vec<i32> =
                        (0..b * n).map(|_| rng.range(0, V as i64) as i32).collect();
                    let assignments: Vec<&str> = (0..b).map(|_| "x").collect();
                    let out = store.gather(&assignments, &ids, n).unwrap();
                    let data = out.as_f32().unwrap();
                    // Each row resolved one snapshot: all L layers of a
                    // row must read the same version constant.
                    for j in 0..b {
                        let first = data[j * n * D];
                        assert!(
                            first == 1.0 || first == 2.0,
                            "row {j}: unexpected value {first}"
                        );
                        for layer in 0..L {
                            for t in 0..n {
                                let base = ((layer * b + j) * n + t) * D;
                                for &x in &data[base..base + D] {
                                    assert_eq!(
                                        x, first,
                                        "torn gather: row {j} layer {layer} tok {t}"
                                    );
                                }
                            }
                        }
                    }
                    gathers += 1;
                }
            })
        })
        .collect();

    for r in readers {
        r.join().unwrap();
    }
    stop.store(true, Ordering::Relaxed);
    writer.join().unwrap();
    // After the writer stops, gathers serve exactly the last version.
    let last = store.gather(&["x"], &[0, 1], 2).unwrap();
    let v = last.as_f32().unwrap()[0];
    assert!(v == 1.0 || v == 2.0);
    assert!(last.as_f32().unwrap().iter().all(|&x| x == v));
}

/// Unregister racing gathers: a gather either completes against its
/// snapshot or fails cleanly with "no fused P"; it never panics or
/// returns partial garbage.
#[test]
fn unregister_mid_stream_fails_cleanly_or_serves_snapshot() {
    let store = Arc::new(PStore::new(L, V, D));
    store.insert("x", constant_table(5.0)).unwrap();
    let stop = Arc::new(AtomicBool::new(false));

    let writer = {
        let store = Arc::clone(&store);
        let stop = Arc::clone(&stop);
        std::thread::spawn(move || {
            while !stop.load(Ordering::Relaxed) {
                let _ = store.remove("x");
                store.insert("x", constant_table(5.0)).unwrap();
            }
        })
    };

    let readers: Vec<_> = (0..3)
        .map(|seed| {
            let store = Arc::clone(&store);
            std::thread::spawn(move || {
                let mut rng = Pcg64::new(200 + seed);
                let mut served = 0usize;
                for _ in 0..400 {
                    let n = 1 + (rng.below(5) as usize);
                    let ids: Vec<i32> =
                        (0..n).map(|_| rng.range(0, V as i64) as i32).collect();
                    match store.gather(&["x"], &ids, n) {
                        Ok(out) => {
                            assert!(out.as_f32().unwrap().iter().all(|&x| x == 5.0));
                            served += 1;
                        }
                        Err(e) => {
                            let msg = e.to_string();
                            assert!(
                                msg.contains("no fused P"),
                                "unexpected failure mode: {msg}"
                            );
                        }
                    }
                }
                served
            })
        })
        .collect();

    let served: usize = readers.into_iter().map(|r| r.join().unwrap()).sum();
    stop.store(true, Ordering::Relaxed);
    writer.join().unwrap();
    assert!(served > 0, "every gather failed — the lifecycle starved the readers");
}

/// Eviction racing gathers under a tight budget: two tasks ping-pong
/// through one table's worth of RAM from two threads; every gather is
/// exact, and the store actually evicts/faults.
#[test]
fn eviction_mid_stream_keeps_gathers_exact() {
    let table_bytes = L * V * D * 4;
    let cfg = AdapterConfig { ram_budget_bytes: table_bytes, ..Default::default() };
    let store = Arc::new(PStore::with_config(L, V, D, cfg));
    store.insert("a", constant_table(1.0)).unwrap();
    store.insert("b", constant_table(2.0)).unwrap();

    let workers: Vec<_> = [("a", 1.0f32), ("b", 2.0f32)]
        .into_iter()
        .map(|(name, want)| {
            let store = Arc::clone(&store);
            std::thread::spawn(move || {
                let mut rng = Pcg64::new(want as u64);
                for _ in 0..200 {
                    let n = 1 + (rng.below(4) as usize);
                    let ids: Vec<i32> =
                        (0..n).map(|_| rng.range(0, V as i64) as i32).collect();
                    let out = store.gather(&[name], &ids, n).unwrap();
                    assert!(
                        out.as_f32().unwrap().iter().all(|&x| x == want),
                        "task {name} gathered wrong values"
                    );
                }
            })
        })
        .collect();
    for w in workers {
        w.join().unwrap();
    }
    let stats = store.stats();
    assert!(
        stats.evictions + stats.cold_serves > 0,
        "budget never forced tier traffic: {stats:?}"
    );
    assert!(stats.resident_bytes <= table_bytes);
}

/// A re-registered task serves its new table through the full pipeline
/// (registry + coordinator), not just the raw store.
#[test]
fn re_registered_task_serves_new_table_through_pipeline() {
    let registry = TaskRegistry::new(L, V, D, 2);
    let head_w = Tensor::from_f32(&[D, 2], vec![0.0; D * 2]);
    // Head bias passes the table sum through untouched logits-wise: with
    // zero head weights, logits equal head_b exactly, so distinguish
    // versions via head_b.
    let head_b1 = Tensor::from_f32(&[2], vec![1.0, -1.0]);
    let head_b2 = Tensor::from_f32(&[2], vec![2.0, -2.0]);
    registry.register_fused("t", constant_table(0.5), &head_w, &head_b1).unwrap();

    let coordinator = Coordinator::with_backend(
        registry,
        vec![Bucket { batch: 2, seq: 8 }],
        2,
        CoordinatorConfig {
            model: "host".into(),
            linger_ms: 1,
            signature: "aot".into(),
            ..Default::default()
        },
        Arc::new(HostBackend),
    )
    .unwrap();

    let before = coordinator.classify("t", vec![1, 2, 3]).unwrap();
    assert_eq!(before.logits, vec![1.0, -1.0]);
    // Hot replace while the coordinator is live (&self registration).
    coordinator
        .registry()
        .register_fused("t", constant_table(0.25), &head_w, &head_b2)
        .unwrap();
    let after = coordinator.classify("t", vec![1, 2, 3]).unwrap();
    assert_eq!(after.logits, vec![2.0, -2.0]);
    // Hot unregister: admission now rejects the task.
    coordinator.registry().unregister("t").unwrap();
    assert!(coordinator.classify("t", vec![1]).is_err());
    coordinator.shutdown();
}

/// The acceptance demo: register more task bytes than the RAM budget,
/// serve every task correctly through the full HostBackend pipeline, and
/// observe eviction/residency counters in `MetricsSnapshot`.
#[test]
fn over_budget_registry_serves_all_tasks_with_visible_counters() {
    let table_bytes = L * V * D * 4;
    let n_tasks = 6usize;
    // Budget fits two of six tables.
    let cfg = AdapterConfig { ram_budget_bytes: 2 * table_bytes, ..Default::default() };
    let registry = TaskRegistry::with_adapter_config(L, V, D, 2, cfg);
    let head_w = Tensor::from_f32(&[D, 2], vec![0.0; D * 2]);
    for i in 0..n_tasks {
        let head_b = Tensor::from_f32(&[2], vec![i as f32, -(i as f32)]);
        registry
            .register_fused(&format!("t{i}"), constant_table(0.1), &head_w, &head_b)
            .unwrap();
    }
    assert!(registry.ram_bytes() <= 2 * table_bytes);

    let coordinator = Coordinator::with_backend(
        registry,
        vec![Bucket { batch: 1, seq: 8 }, Bucket { batch: 4, seq: 8 }],
        2,
        CoordinatorConfig {
            model: "host".into(),
            linger_ms: 1,
            signature: "aot".into(),
            ..Default::default()
        },
        Arc::new(HostBackend),
    )
    .unwrap();

    for round in 0..3 {
        for i in 0..n_tasks {
            let r = coordinator.classify(&format!("t{i}"), vec![1, 2, 3, 4]).unwrap();
            // Zero head weights → logits equal the per-task head bias
            // exactly, whatever tier the table served from.
            assert_eq!(r.logits, vec![i as f32, -(i as f32)], "round {round} task {i}");
        }
    }
    let snapshot = coordinator.metrics().snapshot();
    let a = snapshot.adapter;
    assert_eq!(a.resident_tasks + a.spilled_tasks, n_tasks);
    assert!(a.spilled_tasks > 0, "{a:?}");
    assert!(a.evictions > 0 || a.cold_serves > 0, "{a:?}");
    assert!(a.faults > 0 || a.cold_serves > 0, "{a:?}");
    assert!(a.resident_bytes <= 2 * table_bytes);
    let rendered = snapshot.render();
    assert!(rendered.contains("adapters="), "{rendered}");
    coordinator.shutdown();
}

/// f16-tier gathers stay within the 1e-2 tier tolerance of the f32
/// reference end to end, and halve resident RAM.
#[test]
fn f16_tier_matches_f32_reference_within_tolerance() {
    let mut rng = Pcg64::new(31);
    let data = rng.normal_vec(L * V * D, 1.0);
    let f32_store = PStore::new(L, V, D);
    let f16_store = PStore::with_config(
        L,
        V,
        D,
        AdapterConfig { dtype: AdapterDType::F16, ..Default::default() },
    );
    f32_store.insert("t", TaskP::new(L, V, D, data.clone()).unwrap()).unwrap();
    f16_store.insert("t", TaskP::new(L, V, D, data).unwrap()).unwrap();
    assert_eq!(2 * f16_store.bytes(), f32_store.bytes());
    for trial in 0..20 {
        let n = 1 + (rng.below(10) as usize);
        let b = 1 + (rng.below(3) as usize);
        let ids: Vec<i32> = (0..b * n).map(|_| rng.range(0, V as i64) as i32).collect();
        let assignments: Vec<&str> = (0..b).map(|_| "t").collect();
        let a = f16_store.gather(&assignments, &ids, n).unwrap();
        let r = f32_store.gather(&assignments, &ids, n).unwrap();
        for (x, y) in a.as_f32().unwrap().iter().zip(r.as_f32().unwrap()) {
            assert!((x - y).abs() < 1e-2, "trial {trial}: {x} vs {y}");
        }
    }
}

/// Replace racing gathers on the dedup'd int8 tier (DESIGN.md §12):
/// while one thread flips task "x" between two half-zero tables
/// (constants 1.0 and 2.0), every in-flight gather holds a consistent
/// `Arc` snapshot of both the row pool and the dedup index — even
/// tokens always read the shared zero row, odd tokens read exactly one
/// version's constant, and no row mixes versions.
#[test]
fn dedup_int8_replace_mid_stream_keeps_snapshots_consistent() {
    let cfg = AdapterConfig { dtype: AdapterDType::I8, dedup: true, ..Default::default() };
    let store = Arc::new(PStore::with_config(L, V, D, cfg));
    store.insert("x", half_zero_table(1.0)).unwrap();
    assert_eq!(store.get("x").unwrap().tier(), "ram-int8+dedup");
    let stop = Arc::new(AtomicBool::new(false));

    let writer = {
        let store = Arc::clone(&store);
        let stop = Arc::clone(&stop);
        std::thread::spawn(move || {
            let mut version = 0u64;
            while !stop.load(Ordering::Relaxed) {
                version += 1;
                let c = if version % 2 == 0 { 1.0 } else { 2.0 };
                store.insert("x", half_zero_table(c)).unwrap();
            }
        })
    };

    let readers: Vec<_> = (0..3)
        .map(|seed| {
            let store = Arc::clone(&store);
            let stop = Arc::clone(&stop);
            std::thread::spawn(move || {
                let mut rng = Pcg64::new(400 + seed);
                let mut gathers = 0usize;
                while !stop.load(Ordering::Relaxed) && gathers < 300 {
                    let n = 1 + (rng.below(6) as usize);
                    let b = 1 + (rng.below(3) as usize);
                    let ids: Vec<i32> =
                        (0..b * n).map(|_| rng.range(0, V as i64) as i32).collect();
                    let assignments: Vec<&str> = (0..b).map(|_| "x").collect();
                    let out = store.gather(&assignments, &ids, n).unwrap();
                    let data = out.as_f32().unwrap();
                    for j in 0..b {
                        // The version this row's snapshot serves is fixed
                        // by its first odd-token element; even tokens hit
                        // the shared zero row in every version.
                        let mut version = None;
                        for layer in 0..L {
                            for t in 0..n {
                                let tok = ids[j * n + t];
                                let base = ((layer * b + j) * n + t) * D;
                                for &x in &data[base..base + D] {
                                    if tok % 2 == 0 {
                                        assert_eq!(x, 0.0, "row {j} tok {tok}: zero row dirty");
                                    } else {
                                        assert!(
                                            x == 1.0 || x == 2.0,
                                            "row {j}: unexpected value {x}"
                                        );
                                        match version {
                                            None => version = Some(x),
                                            Some(v) => assert_eq!(
                                                x, v,
                                                "torn dedup gather: row {j} layer {layer} tok {t}"
                                            ),
                                        }
                                    }
                                }
                            }
                        }
                    }
                    gathers += 1;
                }
            })
        })
        .collect();

    for r in readers {
        r.join().unwrap();
    }
    stop.store(true, Ordering::Relaxed);
    writer.join().unwrap();
    // Replacement preserved the dedup accounting: one logical table, its
    // even half collapsed to the shared zero row, the odd half to one
    // stored row.
    let stats = store.stats();
    assert_eq!(stats.dedup_logical_rows, L * V, "{stats:?}");
    assert_eq!(stats.dedup_zero_rows, L * V / 2, "{stats:?}");
    assert!(stats.dedup_ratio() >= 2.0, "{stats:?}");
}

/// The dedup ratio reaches `MetricsSnapshot` through the full pipeline:
/// three half-zero int8 tasks (≥50% near-zero rows) serve exact logits
/// via the HostBackend and report a ≥2× logical/stored row ratio.
#[test]
fn dedup_ratio_surfaces_in_metrics_through_pipeline() {
    let cfg = AdapterConfig { dtype: AdapterDType::I8, dedup: true, ..Default::default() };
    let registry = TaskRegistry::with_adapter_config(L, V, D, 2, cfg);
    let head_w = Tensor::from_f32(&[D, 2], vec![0.0; D * 2]);
    for i in 0..3 {
        let head_b = Tensor::from_f32(&[2], vec![i as f32, -(i as f32)]);
        registry
            .register_fused(&format!("t{i}"), half_zero_table(i as f32 + 1.0), &head_w, &head_b)
            .unwrap();
    }
    let coordinator = Coordinator::with_backend(
        registry,
        vec![Bucket { batch: 2, seq: 8 }],
        2,
        CoordinatorConfig {
            model: "host".into(),
            linger_ms: 1,
            signature: "aot".into(),
            ..Default::default()
        },
        Arc::new(HostBackend),
    )
    .unwrap();

    for i in 0..3 {
        // Zero head weights → logits equal the per-task head bias
        // exactly, proving the dedup'd int8 gather fed the backbone.
        let r = coordinator.classify(&format!("t{i}"), vec![1, 2, 3]).unwrap();
        assert_eq!(r.logits, vec![i as f32, -(i as f32)], "task {i}");
    }
    let snapshot = coordinator.metrics().snapshot();
    let a = snapshot.adapter;
    assert_eq!(a.dedup_logical_rows, 3 * L * V, "{a:?}");
    assert_eq!(a.dedup_zero_rows, 3 * L * V / 2, "{a:?}");
    assert!(a.dedup_ratio() >= 2.0, "{a:?}");
    let rendered = snapshot.render();
    assert!(rendered.contains("dedup="), "{rendered}");
    assert!(rendered.contains("zero_rows="), "{rendered}");
    coordinator.shutdown();
}

/// Gather-aware prefetch racing the hot unregister (DESIGN.md §11):
/// requests for the removed task fail individually ("unknown task" at
/// admission or flush, "no fused P" from the gather); every other task
/// keeps serving exact logits; and once the prefetch backlog drains the
/// residency books balance — an unregister mid-prefetch must not leak a
/// reservation.
#[test]
fn prefetch_unregister_race_fails_only_its_task_and_leaks_nothing() {
    let table_bytes = L * V * D * 4;
    let n_tasks = 6usize;
    // Budget fits two of six tables: every mixed burst spills, faults and
    // prefetches.
    let cfg = AdapterConfig { ram_budget_bytes: 2 * table_bytes, ..Default::default() };
    let registry = TaskRegistry::with_adapter_config(L, V, D, 2, cfg);
    let head_w = Tensor::from_f32(&[D, 2], vec![0.0; D * 2]);
    for i in 0..n_tasks {
        let head_b = Tensor::from_f32(&[2], vec![i as f32, -(i as f32)]);
        registry
            .register_fused(&format!("t{i}"), constant_table(0.1), &head_w, &head_b)
            .unwrap();
    }
    let coordinator = Arc::new(
        Coordinator::with_backend(
            registry,
            vec![Bucket { batch: 1, seq: 8 }, Bucket { batch: 4, seq: 8 }],
            2,
            CoordinatorConfig {
                model: "host".into(),
                linger_ms: 1,
                signature: "aot".into(),
                ..Default::default()
            },
            Arc::new(HostBackend),
        )
        .unwrap(),
    );

    // Submitters hammer every task (tier traffic + prefetch under the
    // tight budget) while the main thread unregisters "t0" mid-stream.
    let workers: Vec<_> = (0..3)
        .map(|seed| {
            let coordinator = Arc::clone(&coordinator);
            std::thread::spawn(move || {
                let mut rng = Pcg64::new(300 + seed);
                for round in 0..120 {
                    let i = rng.below(n_tasks as u64) as usize;
                    let task = format!("t{i}");
                    match coordinator.classify(&task, vec![1, 2, 3]) {
                        Ok(r) => assert_eq!(
                            r.logits,
                            vec![i as f32, -(i as f32)],
                            "round {round}: task {task} served wrong logits"
                        ),
                        Err(e) => {
                            let msg = format!("{e:#}");
                            if i == 0 {
                                assert!(
                                    msg.contains("unknown task") || msg.contains("no fused P"),
                                    "unexpected failure mode: {msg}"
                                );
                            } else {
                                // The only legal collateral: the unregister
                                // landed inside the stages of a mixed batch
                                // that contained t0, so the batch-level
                                // error names t0 — never another task.
                                assert!(
                                    msg.contains("t0"),
                                    "task {task} failed unrelated to the unregister: {msg}"
                                );
                            }
                        }
                    }
                }
            })
        })
        .collect();
    std::thread::sleep(std::time::Duration::from_millis(5));
    coordinator.registry().unregister("t0").unwrap();
    for w in workers {
        w.join().unwrap();
    }
    assert!(coordinator.classify("t0", vec![1]).is_err());

    // Drain the prefetcher, then check the books for the survivors.
    let store = coordinator.registry().pstore();
    for _ in 0..2000 {
        if store.prefetch_backlog() == 0 {
            break;
        }
        std::thread::sleep(std::time::Duration::from_millis(1));
    }
    assert_eq!(store.prefetch_backlog(), 0, "prefetch backlog never drained");
    let a = coordinator.registry().adapter_stats();
    assert_eq!(a.resident_tasks + a.spilled_tasks, n_tasks - 1, "{a:?}");
    assert!(a.resident_bytes <= 2 * table_bytes, "{a:?}");

    // Unregister the rest: every byte must come back.
    for i in 1..n_tasks {
        coordinator.registry().unregister(&format!("t{i}")).unwrap();
    }
    let a = coordinator.registry().adapter_stats();
    assert_eq!(a.resident_bytes, 0, "leaked residency bytes: {a:?}");
    assert_eq!(a.resident_tasks, 0, "{a:?}");
    assert_eq!(a.spilled_tasks, 0, "{a:?}");
    coordinator.shutdown();
}

/// Property (direct store level): random interleavings of prefetch
/// requests — including for names already removed — with removals and
/// gathers settle to exact residency accounting once the backlog drains,
/// for every seed.
#[test]
fn prefetch_removal_interleavings_settle_to_exact_accounting() {
    let table_bytes = L * V * D * 4;
    for seed in 0..8u64 {
        let cfg = AdapterConfig { ram_budget_bytes: 2 * table_bytes, ..Default::default() };
        let store = PStore::with_config(L, V, D, cfg);
        let names: Vec<String> = (0..5).map(|i| format!("p{i}")).collect();
        for (i, name) in names.iter().enumerate() {
            store.insert(name, constant_table(i as f32 + 1.0)).unwrap();
        }
        let mut rng = Pcg64::new(900 + seed);
        let mut live = names.clone();
        for _ in 0..40 {
            match rng.below(3) {
                0 => {
                    // Prefetch a random mix of live and removed names.
                    let wanted: Vec<String> = (0..1 + rng.below(3))
                        .map(|_| names[rng.below(5) as usize].clone())
                        .collect();
                    store.prefetch(&wanted);
                }
                1 if !live.is_empty() => {
                    let victim = live.swap_remove(rng.below(live.len() as u64) as usize);
                    store.remove(&victim).unwrap();
                }
                _ => {
                    if let Some(name) = live.first() {
                        let out = store.gather(&[name.as_str()], &[0, 1], 2).unwrap();
                        assert!(out.as_f32().unwrap().iter().all(|&x| x > 0.0));
                    }
                }
            }
        }
        for _ in 0..2000 {
            if store.prefetch_backlog() == 0 {
                break;
            }
            std::thread::sleep(std::time::Duration::from_millis(1));
        }
        assert_eq!(store.prefetch_backlog(), 0, "seed {seed}: backlog never drained");
        let stats = store.stats();
        assert_eq!(
            stats.resident_tasks + stats.spilled_tasks,
            live.len(),
            "seed {seed}: {stats:?}"
        );
        assert!(stats.resident_bytes <= 2 * table_bytes, "seed {seed}: {stats:?}");
        for name in live.drain(..) {
            store.remove(&name).unwrap();
        }
        let stats = store.stats();
        assert_eq!(stats.resident_bytes, 0, "seed {seed}: leaked bytes: {stats:?}");
        assert_eq!(stats.resident_tasks + stats.spilled_tasks, 0, "seed {seed}: {stats:?}");
    }
}

/// Disk-tier gathers are bit-identical to the resident f32 reference
/// (the spill file round-trips exact bytes).
#[test]
fn disk_tier_matches_f32_reference_bit_exact() {
    let mut rng = Pcg64::new(37);
    let data = rng.normal_vec(L * V * D, 1.0);
    let resident = PStore::new(L, V, D);
    // Budget below one table: the task lives on disk and serves cold.
    let table_bytes = L * V * D * 4;
    let spilled = PStore::with_config(
        L,
        V,
        D,
        AdapterConfig { ram_budget_bytes: table_bytes / 4, ..Default::default() },
    );
    resident.insert("t", TaskP::new(L, V, D, data.clone()).unwrap()).unwrap();
    spilled.insert("t", TaskP::new(L, V, D, data).unwrap()).unwrap();
    assert_eq!(spilled.get("t").unwrap().tier(), "disk");
    for _ in 0..10 {
        let n = 1 + (rng.below(8) as usize);
        let ids: Vec<i32> = (0..n).map(|_| rng.range(0, V as i64) as i32).collect();
        let a = spilled.gather(&["t"], &ids, n).unwrap();
        let r = resident.gather(&["t"], &ids, n).unwrap();
        assert_eq!(a.as_f32().unwrap(), r.as_f32().unwrap());
    }
    assert!(spilled.stats().cold_serves > 0);
}

/// An mmap-backed cold snapshot outlives the task's unregistration
/// (DESIGN.md §13): the spill file's mapping is held by the snapshot's
/// `Arc`, so rows keep serving after `remove`, and the `mapped_bytes`
/// gauge only settles to zero on the last drop.
#[test]
fn mmap_cold_snapshot_survives_unregister_and_unmaps_on_last_drop() {
    let table_bytes = L * V * D * 4;
    let cfg = AdapterConfig {
        ram_budget_bytes: table_bytes / 4,
        mmap: true,
        ..Default::default()
    };
    let store = PStore::with_config(L, V, D, cfg);
    store.insert("x", constant_table(3.0)).unwrap();
    let snap = store.get("x").unwrap();
    assert_eq!(snap.tier(), "disk");
    let stats = store.stats();
    if stats.mmap_opens == 0 {
        // Platform without the mmap binding: the fallback must be
        // counted and the table still serves; nothing more to assert.
        assert!(stats.mmap_fallbacks > 0, "{stats:?}");
        let mut row = vec![0f32; D];
        snap.copy_row(0, 0, &mut row).unwrap();
        assert!(row.iter().all(|&x| x == 3.0));
        return;
    }
    assert!(stats.mapped_bytes > 0, "{stats:?}");

    store.remove("x").unwrap();
    // The mapping is still alive through the snapshot...
    let mut row = vec![0f32; D];
    snap.copy_row(L - 1, V - 1, &mut row).unwrap();
    assert!(row.iter().all(|&x| x == 3.0), "{row:?}");
    assert!(store.stats().mapped_bytes > 0, "unmapped with a snapshot in flight");
    // ...and the last drop unmaps it.
    drop(snap);
    let stats = store.stats();
    assert_eq!(stats.mapped_bytes, 0, "{stats:?}");
    assert_eq!(stats.resident_bytes, 0, "{stats:?}");
    assert!(stats.cold_rows_mapped > 0, "{stats:?}");
}

/// Cold mmap gathers racing a replace loop: every gather observes a
/// uniform table version (no torn rows across the remap), and once the
/// task is removed the mapped-bytes gauge settles to zero.
#[test]
fn mmap_cold_gathers_race_replace_and_settle_to_zero_mapped_bytes() {
    let table_bytes = L * V * D * 4;
    let cfg = AdapterConfig {
        ram_budget_bytes: table_bytes / 2,
        mmap: true,
        ..Default::default()
    };
    let store = Arc::new(PStore::with_config(L, V, D, cfg));
    store.insert("x", constant_table(1.0)).unwrap();
    let stop = Arc::new(AtomicBool::new(false));

    let writer = {
        let store = Arc::clone(&store);
        let stop = Arc::clone(&stop);
        std::thread::spawn(move || {
            let mut version = 0u64;
            while !stop.load(Ordering::Relaxed) {
                version += 1;
                let c = if version % 2 == 0 { 1.0 } else { 2.0 };
                store.insert("x", constant_table(c)).unwrap();
            }
        })
    };

    let readers: Vec<_> = (0..3)
        .map(|seed| {
            let store = Arc::clone(&store);
            std::thread::spawn(move || {
                let mut rng = Pcg64::new(500 + seed);
                for _ in 0..200 {
                    let n = 1 + (rng.below(4) as usize);
                    let ids: Vec<i32> =
                        (0..n).map(|_| rng.range(0, V as i64) as i32).collect();
                    let out = store.gather(&["x"], &ids, n).unwrap();
                    let data = out.as_f32().unwrap();
                    let first = data[0];
                    assert!(first == 1.0 || first == 2.0, "unexpected value {first}");
                    for &x in data {
                        assert_eq!(x, first, "torn cold gather across a replace");
                    }
                }
            })
        })
        .collect();
    for r in readers {
        r.join().unwrap();
    }
    stop.store(true, Ordering::Relaxed);
    writer.join().unwrap();

    let stats = store.stats();
    assert!(stats.cold_serves > 0, "budget never forced cold serving: {stats:?}");
    if stats.mmap_opens > 0 {
        assert!(stats.cold_rows_mapped > 0, "{stats:?}");
    } else {
        assert!(stats.mmap_fallbacks > 0, "{stats:?}");
    }
    store.remove("x").unwrap();
    let stats = store.stats();
    assert_eq!(stats.mapped_bytes, 0, "mapping leaked past removal: {stats:?}");
    assert_eq!(stats.resident_bytes, 0, "{stats:?}");
}
