"""L2 correctness: PEFT forwards, zero-init claims, serve/train parity."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import peft
from compile import model
from compile.configs import MODEL_CONFIGS
from compile.kernels import ref

CFG = MODEL_CONFIGS["tiny"]
HP = peft.MethodHP(rank=4, prefix=5, classes=3)
B, N = 3, 12
L = CFG.n_layers


@pytest.fixture(scope="module")
def backbone():
    return model.init_backbone(CFG, jax.random.PRNGKey(0))


@pytest.fixture(scope="module")
def batch():
    ids = jax.random.randint(jax.random.PRNGKey(1), (B, N), 0, CFG.vocab_size)
    mask = jnp.ones((B, N), jnp.float32)
    return ids, mask


@pytest.fixture(scope="module")
def head():
    return peft.init_head(CFG, HP, jax.random.PRNGKey(7))


def tile(x):
    return jnp.broadcast_to(x, (B,) + x.shape)


def serve_sp(ids, mask, head, extra):
    sp = {
        "in.ids": ids,
        "in.mask": mask,
        "in.head_w": tile(head["head_w"]),
        "in.head_b": tile(head["head_b"]),
    }
    sp.update(extra)
    return sp


# ---------------------------------------------------------------------------
# Zero-init: every fusable method equals the frozen backbone at init
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("method", ["lora", "adapters", "aot-kron", "aot-fc", "fine-tune"])
def test_zero_init_matches_backbone(backbone, batch, head, method):
    ids, mask = batch
    base_mp = {**peft.init_method_params(CFG, "bitfit", HP, jax.random.PRNGKey(2)), **head}
    base = model.forward_train(CFG, backbone, base_mp, "bitfit", ids, mask, HP)
    mp = {
        **peft.init_method_params(
            CFG, method, HP, jax.random.PRNGKey(3), backbone=backbone
        ),
        **head,
    }
    out = model.forward_train(CFG, backbone, mp, method, ids, mask, HP)
    np.testing.assert_allclose(np.asarray(out), np.asarray(base), rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("method", ["pt1", "pt2"])
def test_prompt_methods_run(backbone, batch, head, method):
    ids, mask = batch
    mp = {**peft.init_method_params(CFG, method, HP, jax.random.PRNGKey(3)), **head}
    out = model.forward_train(CFG, backbone, mp, method, ids, mask, HP)
    assert out.shape == (B, HP.classes)
    assert np.isfinite(np.asarray(out)).all()


def test_param_count_ordering():
    """Parameter efficiency (paper's axis): every PEFT method must train
    orders of magnitude fewer parameters than fine-tuning."""
    counts = {m: peft.count_trainable(CFG, m, HP) for m in peft.METHOD_PROPERTIES}
    for m, c in counts.items():
        if m != "fine-tune":
            assert c < counts["fine-tune"] / 50, (m, c)


# ---------------------------------------------------------------------------
# Serve/train parity per method (multi-task batching is exact, §3.1)
# ---------------------------------------------------------------------------

def randomized_params(method, key):
    mp = peft.init_method_params(CFG, method, HP, jax.random.PRNGKey(key))
    out = {}
    for i, (name, val) in enumerate(mp.items()):
        out[name] = jax.random.normal(jax.random.PRNGKey(key + i + 1), val.shape) * 0.05
    return out


def test_bitfit_serve_parity(backbone, batch, head):
    ids, mask = batch
    mp = randomized_params("bitfit", 10)
    want = model.forward_train(CFG, backbone, {**mp, **head}, "bitfit", ids, mask, HP)
    sp = serve_sp(ids, mask, head, {
        "in.proj_b": jnp.stack([jnp.stack([tile(mp["bf.proj_b"][i, j]) for j in range(4)]) for i in range(L)]),
        "in.ffn_b1": jnp.stack([tile(mp["bf.ffn_b1"][i]) for i in range(L)]),
        "in.ffn_b2": jnp.stack([tile(mp["bf.ffn_b2"][i]) for i in range(L)]),
        "in.ln_b": jnp.stack([jnp.stack([tile(mp["bf.ln_b"][i, j]) for j in range(2)]) for i in range(L)]),
        "in.emb_ln_b": tile(mp["bf.emb_ln_b"]),
    })
    got = model.forward_serve(CFG, backbone, sp, "bitfit", HP)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-4)


def test_lora_serve_parity(backbone, batch, head):
    ids, mask = batch
    mp = randomized_params("lora", 20)
    want = model.forward_train(CFG, backbone, {**mp, **head}, "lora", ids, mask, HP)
    sp = serve_sp(ids, mask, head, {
        "in.lora_a_q": jnp.stack([tile(mp["lora.a_q"][i]) for i in range(L)]),
        "in.lora_b_q": jnp.stack([tile(mp["lora.b_q"][i]) for i in range(L)]),
        "in.lora_a_v": jnp.stack([tile(mp["lora.a_v"][i]) for i in range(L)]),
        "in.lora_b_v": jnp.stack([tile(mp["lora.b_v"][i]) for i in range(L)]),
    })
    got = model.forward_serve(CFG, backbone, sp, "lora", HP)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-4)


def test_adapters_serve_parity(backbone, batch, head):
    ids, mask = batch
    mp = randomized_params("adapters", 30)
    want = model.forward_train(CFG, backbone, {**mp, **head}, "adapters", ids, mask, HP)
    sp = serve_sp(ids, mask, head, {
        f"in.ad_{name}": jnp.stack([tile(mp[f"ad.{name}"][i]) for i in range(L)])
        for name in ("attn_wd", "attn_bd", "attn_wu", "attn_bu",
                     "ffn_wd", "ffn_bd", "ffn_wu", "ffn_bu")
    })
    got = model.forward_serve(CFG, backbone, sp, "adapters", HP)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-4)


def test_pt1_serve_parity(backbone, batch, head):
    ids, mask = batch
    mp = randomized_params("pt1", 40)
    want = model.forward_train(CFG, backbone, {**mp, **head}, "pt1", ids, mask, HP)
    sp = serve_sp(ids, mask, head, {"in.prompt": tile(mp["pt1.prompt"])})
    got = model.forward_serve(CFG, backbone, sp, "pt1", HP)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-4)


def test_pt2_serve_parity(backbone, batch, head):
    ids, mask = batch
    mp = randomized_params("pt2", 50)
    want = model.forward_train(CFG, backbone, {**mp, **head}, "pt2", ids, mask, HP)
    sp = serve_sp(ids, mask, head, {
        "in.pk": jnp.stack([tile(mp["pt2.pk"][i]) for i in range(L)]),
        "in.pv": jnp.stack([tile(mp["pt2.pv"][i]) for i in range(L)]),
    })
    got = model.forward_serve(CFG, backbone, sp, "pt2", HP)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-4)


@pytest.fixture(scope="module")
def fc_setup(backbone, batch, head):
    """A trained-looking FC AoT state + its fused table (Equation 3)."""
    ids, mask = batch
    mp = randomized_params("aot-fc", 60)
    want = model.forward_train(CFG, backbone, {**mp, **head}, "aot-fc", ids, mask, HP)
    fused = jnp.stack([
        ref.fc_fuse_ref(
            backbone["emb_tok"], mp["fc.w1"][i], mp["fc.b1"][i],
            mp["fc.w2"][i], mp["fc.b2"][i],
        )
        for i in range(L)
    ])
    return mp, fused, want


def test_aot_fused_host_gather_parity(backbone, batch, head, fc_setup):
    """The zero-cost serving path: host-side row gather == training forward."""
    ids, mask = batch
    _, fused, want = fc_setup
    bias = fused[:, ids, :]
    sp = serve_sp(ids, mask, head, {"in.bias": bias})
    got = model.forward_serve(CFG, backbone, sp, "aot", HP)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("use_pallas", [False, True])
def test_aot_device_gather_parity(backbone, batch, head, fc_setup, use_pallas):
    ids, mask = batch
    _, fused, want = fc_setup
    bb2 = dict(backbone)
    bb2["P"] = fused
    sp = serve_sp(ids, mask, head, {})
    got = model.forward_serve(CFG, bb2, sp, "aot-gather", HP, use_pallas_gather=use_pallas)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-4)


def test_aot_unfused_parity(backbone, batch, head, fc_setup):
    ids, mask = batch
    mp, _, want = fc_setup
    sp = serve_sp(ids, mask, head, {
        "in.fc_w1": jnp.stack([tile(mp["fc.w1"][i]) for i in range(L)]),
        "in.fc_b1": jnp.stack([tile(mp["fc.b1"][i]) for i in range(L)]),
        "in.fc_w2": jnp.stack([tile(mp["fc.w2"][i]) for i in range(L)]),
        "in.fc_b2": jnp.stack([tile(mp["fc.b2"][i]) for i in range(L)]),
    })
    got = model.forward_serve(CFG, backbone, sp, "aot-unfused", HP)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-4)


def test_multitask_batch_mixes_tasks(backbone, batch, head):
    """Two different tasks in one batch == each task served alone.

    This is the paper's multi-task inference claim (§3.1) at the model
    level; the Rust coordinator test repeats it end-to-end.
    """
    ids, mask = batch
    mp_a = randomized_params("aot-fc", 70)
    mp_b = randomized_params("aot-fc", 80)
    fused = []
    for mp in (mp_a, mp_b):
        fused.append(jnp.stack([
            ref.fc_fuse_ref(
                backbone["emb_tok"], mp["fc.w1"][i], mp["fc.b1"][i],
                mp["fc.w2"][i], mp["fc.b2"][i],
            )
            for i in range(L)
        ]))
    # Batch rows 0,2 -> task A; row 1 -> task B.
    assign = [0, 1, 0]
    bias = jnp.stack(
        [fused[assign[j]][:, ids[j], :] for j in range(B)], axis=1
    )  # [l, b, n, d]
    sp = serve_sp(ids, mask, head, {"in.bias": bias})
    mixed = model.forward_serve(CFG, backbone, sp, "aot", HP)

    for j, task in enumerate(assign):
        solo_bias = fused[task][:, ids, :]
        sp_solo = serve_sp(ids, mask, head, {"in.bias": solo_bias})
        solo = model.forward_serve(CFG, backbone, sp_solo, "aot", HP)
        np.testing.assert_allclose(
            np.asarray(mixed[j]), np.asarray(solo[j]), rtol=1e-5, atol=1e-5
        )


def test_serve_input_shapes_cover_all_methods():
    for method in ["fine-tune", "aot", "aot-gather", "aot-unfused", "bitfit",
                   "lora", "adapters", "pt1", "pt2"]:
        shapes = model.serve_input_shapes(CFG, "fine-tune" if method == "lora-fused" else method, 4, 16, HP)
        assert list(shapes)[:2] == ["in.ids", "in.mask"]
        assert list(shapes)[-2:] == ["in.head_w", "in.head_b"]
