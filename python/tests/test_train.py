"""L2 training graphs: losses, Adam, the scanned K-step train function,
and the config helpers the manifest relies on."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import peft, model as M
from compile.configs import MODEL_CONFIGS, kron_factors
from compile.peft import MethodHP
from compile.train import adam_update, ce_loss, make_train_fn, mse_loss

CFG = MODEL_CONFIGS["tiny"]


def test_ce_loss_known_values():
    logits = jnp.array([[10.0, -10.0], [-10.0, 10.0]])
    labels = jnp.array([0.0, 1.0])
    assert float(ce_loss(logits, labels)) < 1e-6
    wrong = jnp.array([1.0, 0.0])
    assert float(ce_loss(logits, wrong)) > 10.0


def test_mse_loss_on_first_logit():
    logits = jnp.array([[1.0, 99.0], [3.0, -7.0]])
    labels = jnp.array([2.0, 3.0])
    # ((1-2)^2 + (3-3)^2) / 2 = 0.5; the second logit must be ignored.
    assert float(mse_loss(logits, labels)) == pytest.approx(0.5)


def test_adam_moves_against_gradient():
    p = jnp.array([1.0])
    g = jnp.array([2.0])
    m = jnp.zeros(1)
    v = jnp.zeros(1)
    p2, m2, v2 = adam_update(p, g, m, v, jnp.float32(1.0), 0.1)
    assert float(p2[0]) < 1.0  # moved against the positive gradient
    assert float(m2[0]) > 0.0
    assert float(v2[0]) > 0.0


@settings(max_examples=10, deadline=None)
@given(v=st.integers(100, 200_000))
def test_kron_factors_cover_vocab(v):
    a, b = kron_factors(v)
    assert a * b >= v
    # the paper's footnote-1 trick: only slightly larger than |V|
    assert a * b - v < max(a, b)


def test_kron_factors_paper_example():
    # DeBERTa in the paper uses a = b = 360 for |V| = 128100 ≈ 360².  Our
    # search minimizes waste first, then imbalance: 350 × 366 = 128100
    # exactly (zero waste), which is an even tighter factorization than
    # the paper's 360 × 360 = 129600.
    a, b = kron_factors(128_100)
    assert a * b >= 128_100
    assert a * b - 128_100 <= 360 * 360 - 128_100  # at least as tight
    assert abs(a - b) <= 32  # still near-balanced


def test_train_fn_k_steps_decrease_loss_and_count_steps():
    hp = MethodHP(rank=8, classes=2)
    order = peft.trainable_param_order(CFG, "aot-fc", hp)
    fn = make_train_fn(CFG, "aot-fc", hp, order, "ce")
    bb = M.init_backbone(CFG, jax.random.PRNGKey(20230517))
    mp = peft.init_method_params(CFG, "aot-fc", hp, jax.random.PRNGKey(1))
    mp.update(peft.init_head(CFG, hp, jax.random.PRNGKey(2)))
    tr = [mp[n] for n in order]
    m = [jnp.zeros_like(x) for x in tr]
    v = [jnp.zeros_like(x) for x in tr]

    k, b, n = 4, 8, 16
    rng = np.random.default_rng(0)
    # one fixed batch repeated K times: loss must drop within the call
    ids1 = rng.integers(5, CFG.vocab_size, (1, b, n)).astype(np.int32)
    ids = jnp.asarray(np.repeat(ids1, k, axis=0))
    labels = jnp.asarray(np.repeat((ids1[:, :, 1] % 2).astype(np.float32), k, axis=0))
    mask = jnp.ones((k, b, n), jnp.float32)

    outs = fn(bb, tr, m, v, jnp.int32(0), ids, mask, labels, jnp.float32(1e-2), jnp.int32(0))
    nt = len(order)
    step, loss1 = outs[3 * nt], outs[3 * nt + 1]
    assert int(step) == k
    outs2 = fn(
        bb, outs[:nt], outs[nt:2 * nt], outs[2 * nt:3 * nt], step,
        ids, mask, labels, jnp.float32(1e-2), jnp.int32(0),
    )
    loss2 = outs2[3 * nt + 1]
    assert float(loss2) < float(loss1), (float(loss1), float(loss2))
    assert int(outs2[3 * nt]) == 2 * k


def test_trainable_order_is_stable_and_matches_init_spec():
    hp = MethodHP(rank=8, classes=3)
    for method in ["bitfit", "lora", "adapters", "pt1", "pt2", "aot-kron", "aot-fc"]:
        order = peft.trainable_param_order(CFG, method, hp)
        spec = peft.init_spec(CFG, method, hp)
        assert order == [e["name"] for e in spec]
        assert order[-2:] == ["head_w", "head_b"]
