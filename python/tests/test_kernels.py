"""L1 correctness: every Pallas kernel vs its pure-jnp oracle.

Hypothesis sweeps shapes, block sizes and seeds; numerics are asserted with
``assert_allclose`` at float32 tolerance.  These tests are the CORE
correctness signal for the kernels that end up inside the AOT artifacts.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.aot_bias import aot_bias, vmem_bytes as aot_vmem
from compile.kernels.attention import (
    attention,
    mxu_utilization,
    prefix_attention,
    vmem_bytes as attn_vmem,
)
from compile.kernels.kron import kron_fuse, vmem_bytes as kron_vmem

SETTINGS = dict(max_examples=12, deadline=None)


def rand(key, shape, dtype=jnp.float32):
    return jax.random.normal(jax.random.PRNGKey(key), shape, dtype)


# ---------------------------------------------------------------------------
# aot_bias: H' = H + P[ids]   (paper Equation 1)
# ---------------------------------------------------------------------------

@settings(**SETTINGS)
@given(
    b=st.integers(1, 4),
    n=st.integers(1, 70),
    d=st.sampled_from([8, 16, 32]),
    v=st.sampled_from([64, 200, 513]),
    block_n=st.sampled_from([8, 16, 64]),
    seed=st.integers(0, 2**31 - 1),
)
def test_aot_bias_matches_ref(b, n, d, v, block_n, seed):
    h = rand(seed, (b, n, d))
    p = rand(seed + 1, (v, d))
    ids = jax.random.randint(jax.random.PRNGKey(seed + 2), (b, n), 0, v)
    out = aot_bias(h, p, ids, block_n=block_n)
    np.testing.assert_allclose(
        np.asarray(out), np.asarray(ref.aot_bias_ref(h, p, ids)), rtol=1e-6, atol=1e-6
    )


def test_aot_bias_zero_table_is_identity():
    """With P == 0 the op must be exactly the identity (zero-init claim)."""
    h = rand(0, (2, 9, 16))
    p = jnp.zeros((50, 16))
    ids = jax.random.randint(jax.random.PRNGKey(1), (2, 9), 0, 50)
    np.testing.assert_array_equal(np.asarray(aot_bias(h, p, ids)), np.asarray(h))


def test_aot_bias_repeated_tokens_share_rows():
    """All positions holding the same token must receive the same bias."""
    d, v = 8, 32
    h = jnp.zeros((1, 6, d))
    p = rand(3, (v, d))
    ids = jnp.array([[5, 5, 5, 7, 7, 5]], dtype=jnp.int32)
    out = np.asarray(aot_bias(h, p, ids))
    np.testing.assert_allclose(out[0, 0], out[0, 1], rtol=0, atol=0)
    np.testing.assert_allclose(out[0, 0], out[0, 5], rtol=0, atol=0)
    np.testing.assert_allclose(out[0, 3], out[0, 4], rtol=0, atol=0)
    assert not np.allclose(out[0, 0], out[0, 3])


# ---------------------------------------------------------------------------
# attention (+ prefix variant used by P-Tuning v2)
# ---------------------------------------------------------------------------

@settings(**SETTINGS)
@given(
    b=st.integers(1, 3),
    h=st.sampled_from([1, 2, 4]),
    n=st.integers(2, 80),
    dh=st.sampled_from([8, 16]),
    block=st.sampled_from([16, 32, 128]),
    seed=st.integers(0, 2**31 - 1),
)
def test_attention_matches_ref(b, h, n, dh, block, seed):
    q = rand(seed, (b, h, n, dh))
    k = rand(seed + 1, (b, h, n, dh))
    v = rand(seed + 2, (b, h, n, dh))
    mask = (jax.random.uniform(jax.random.PRNGKey(seed + 3), (b, n)) > 0.25).astype(
        jnp.float32
    )
    mask = mask.at[:, 0].set(1.0)  # at least one attendable key
    out = attention(q, k, v, mask, block_q=block, block_k=block)
    np.testing.assert_allclose(
        np.asarray(out), np.asarray(ref.attention_ref(q, k, v, mask)),
        rtol=2e-5, atol=2e-5,
    )


@settings(**SETTINGS)
@given(
    p=st.integers(1, 24),
    n=st.integers(2, 40),
    block=st.sampled_from([16, 64]),
    seed=st.integers(0, 2**31 - 1),
)
def test_prefix_attention_matches_ref(p, n, block, seed):
    b, h, dh = 2, 2, 8
    q = rand(seed, (b, h, n, dh))
    k = rand(seed + 1, (b, h, n, dh))
    v = rand(seed + 2, (b, h, n, dh))
    pk = rand(seed + 3, (b, h, p, dh))
    pv = rand(seed + 4, (b, h, p, dh))
    mask = jnp.ones((b, n), jnp.float32)
    out = prefix_attention(q, k, v, mask, pk, pv, block_q=block, block_k=block)
    np.testing.assert_allclose(
        np.asarray(out),
        np.asarray(ref.prefix_attention_ref(q, k, v, mask, pk, pv)),
        rtol=2e-5, atol=2e-5,
    )


def test_prefix_attention_longer_prefix_changes_output():
    """The prefix must actually participate (P-Tuning v2 is not a no-op)."""
    b, h, n, dh = 1, 1, 8, 8
    q, k, v = rand(0, (b, h, n, dh)), rand(1, (b, h, n, dh)), rand(2, (b, h, n, dh))
    mask = jnp.ones((b, n), jnp.float32)
    base = attention(q, k, v, mask)
    pk, pv = rand(3, (b, h, 4, dh)), rand(4, (b, h, 4, dh))
    with_prefix = prefix_attention(q, k, v, mask, pk, pv)
    assert not np.allclose(np.asarray(base), np.asarray(with_prefix), atol=1e-4)


# ---------------------------------------------------------------------------
# Kronecker fuse (paper Equation 2 + footnote-1 truncation)
# ---------------------------------------------------------------------------

@settings(**SETTINGS)
@given(
    a=st.integers(2, 24),
    bf=st.integers(2, 16),
    r=st.sampled_from([2, 4, 8]),
    d=st.sampled_from([4, 16]),
    block_a=st.sampled_from([4, 8, 32]),
    seed=st.integers(0, 2**31 - 1),
)
def test_kron_fuse_matches_ref(a, bf, r, d, block_a, seed):
    vocab = a * bf - min(3, a * bf - 1)  # exercise the truncation
    wl = rand(seed, (a, r))
    wm = rand(seed + 1, (bf, r))
    wr = rand(seed + 2, (r * r, d))
    out = kron_fuse(wl, wm, wr, vocab=vocab, block_a=block_a)
    np.testing.assert_allclose(
        np.asarray(out), np.asarray(ref.kron_fuse_ref(wl, wm, wr, vocab)),
        rtol=2e-5, atol=2e-5,
    )


@settings(**SETTINGS)
@given(seed=st.integers(0, 2**31 - 1))
def test_kron_rows_consistent_with_fuse(seed):
    """Training-path row gather == fused-table lookup (paper §3.3)."""
    a, bf, r, d, vocab = 12, 9, 4, 8, 100
    wl = rand(seed, (a, r))
    wm = rand(seed + 1, (bf, r))
    wr = rand(seed + 2, (r * r, d))
    full = ref.kron_fuse_ref(wl, wm, wr, vocab)
    ids = jax.random.randint(jax.random.PRNGKey(seed + 3), (2, 11), 0, vocab)
    rows = ref.kron_rows_ref(wl, wm, wr, ids)
    np.testing.assert_allclose(
        np.asarray(rows), np.asarray(full)[np.asarray(ids)], rtol=2e-5, atol=2e-5
    )


@settings(**SETTINGS)
@given(seed=st.integers(0, 2**31 - 1))
def test_fc_rows_consistent_with_fuse(seed):
    """FC reparametrization: row path == fused-table lookup (Equation 3)."""
    v, d, r = 64, 16, 8
    e = rand(seed, (v, d))
    w1 = rand(seed + 1, (d, r))
    b1 = rand(seed + 2, (r,))
    w2 = rand(seed + 3, (r, d))
    b2 = rand(seed + 4, (d,))
    full = ref.fc_fuse_ref(e, w1, b1, w2, b2)
    ids = jax.random.randint(jax.random.PRNGKey(seed + 5), (3, 7), 0, v)
    rows = ref.fc_rows_ref(e[ids], w1, b1, w2, b2)
    np.testing.assert_allclose(
        np.asarray(rows), np.asarray(full)[np.asarray(ids)], rtol=2e-5, atol=2e-5
    )


# ---------------------------------------------------------------------------
# Analytic VMEM/MXU models (perf plan §9) — sanity bounds
# ---------------------------------------------------------------------------

VMEM_BUDGET = 16 * 1024 * 1024


def test_default_blocks_fit_vmem():
    assert aot_vmem(block_n=128, d=1024) < VMEM_BUDGET
    assert attn_vmem(block_q=128, block_k=128, dh=64) < VMEM_BUDGET
    # Kronecker fuse at DeBERTa-XL scale (r=50, d=1024): the default
    # block_a=32 does NOT fit (the analytic model is what tells us to
    # shrink the tile), block_a=8 does.
    assert kron_vmem(block_a=32, r=50, bf=90, d=1024) > VMEM_BUDGET
    assert kron_vmem(block_a=8, r=50, bf=90, d=1024) < VMEM_BUDGET


def test_mxu_utilization_bounds():
    assert 0.0 < mxu_utilization(384, 64, 128, 128) <= 1.0
    # Full 128-wide tiles with dh=128 would be perfectly utilized.
    assert mxu_utilization(384, 128, 128, 128) == pytest.approx(1.0)
