"""Model shape families and artifact buckets for the AoT pipeline.

The paper evaluates RoBERTa-Base/Large and DeBERTa-XL.  Offline, we build
matched *shape families* (same geometry, scaled dims; see DESIGN.md §5) and
treat `base`/`large`/`xl` as the stand-ins for the paper's three backbones.

Everything here is consumed both by the JAX model (L2) and serialized into
``artifacts/manifest.json`` so the Rust coordinator (L3) agrees on every
shape without parsing HLO.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Tuple

VOCAB_SIZE = 8192
MAX_POSITIONS = 512
# Fixed number of classes for multi-task (batched-head) serving artifacts.
# Single-task training artifacts use the task's true class count.
MULTITASK_CLASSES = 4


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """Geometry of one backbone shape family (RoBERTa-style encoder)."""

    name: str
    d_model: int
    n_layers: int
    n_heads: int
    d_ff: int
    vocab_size: int = VOCAB_SIZE
    max_positions: int = MAX_POSITIONS

    @property
    def head_dim(self) -> int:
        assert self.d_model % self.n_heads == 0
        return self.d_model // self.n_heads

    def param_count(self) -> int:
        """Approximate backbone parameter count (embeddings included)."""
        d, l, ff, v = self.d_model, self.n_layers, self.d_ff, self.vocab_size
        emb = v * d + self.max_positions * d + 2 * d  # tok + pos + emb LN
        per_layer = (
            4 * (d * d + d)  # q, k, v, o projections
            + d * ff + ff + ff * d + d  # FFN
            + 4 * d  # two LayerNorms
        )
        return emb + l * per_layer


MODEL_CONFIGS: Dict[str, ModelConfig] = {
    # name                      d     l   h   ff
    "tiny": ModelConfig("tiny", 64, 2, 2, 256),
    "small": ModelConfig("small", 128, 4, 4, 512),
    "base": ModelConfig("base", 256, 6, 8, 1024),
    "large": ModelConfig("large", 512, 12, 8, 2048),
    "xl": ModelConfig("xl", 768, 16, 12, 3072),
}

# Which paper backbone each family stands in for (documentation + manifest).
# Shifted one tier down for the single-CPU-core testbed (DESIGN.md §5).
PAPER_ANALOG = {
    "small": "RoBERTa-Base",
    "base": "RoBERTa-Large",
    "large": "DeBERTa-XL",
}


def kron_factors(vocab_size: int) -> Tuple[int, int]:
    """Pick (a, b) with a*b >= vocab_size, as balanced as possible.

    Implements the paper's footnote-1 trick: |V| often factorizes badly
    (50265 = 1117 * 3^2 * 5), so P is factorized *slightly larger* than the
    vocabulary and the excess rows are ignored.
    """
    a = int(math.isqrt(vocab_size))
    # Search near sqrt(V) for the pair minimizing a*b - V, preferring
    # balanced factors (parameter efficiency: params ~ (a + b) * r).
    best = None
    for cand_a in range(max(2, a - 64), a + 65):
        cand_b = (vocab_size + cand_a - 1) // cand_a
        waste = cand_a * cand_b - vocab_size
        key = (waste, abs(cand_a - cand_b))
        if best is None or key < best[0]:
            best = (key, (cand_a, cand_b))
    return best[1]


# ---------------------------------------------------------------------------
# Methods
# ---------------------------------------------------------------------------

# Every fine-tuning method in the paper (Table 1).  ``lora-fused`` shares the
# vanilla forward artifact (weights are fused per task), so it has no
# separate serving signature.
METHODS = [
    "fine-tune",
    "bitfit",
    "lora",        # unfused: batched low-rank factors as inputs
    "lora-fused",
    "adapters",
    "pt1",
    "pt2",
    "aot-kron",
    "aot-fc",
]

# Methods that can serve many tasks from one backbone invocation.
MULTITASK_METHODS = ["bitfit", "lora", "adapters", "pt1", "pt2", "aot-kron", "aot-fc"]

# Methods whose trained weights fuse to a per-task P (serving artifact is the
# shared "aot" signature: bias rows gathered ahead of time).
AOT_METHODS = ["aot-kron", "aot-fc"]

DEFAULT_RANKS = {
    "lora": 8,
    "adapters": 32,
    "aot-kron": 16,
    "aot-fc": 64,
}
DEFAULT_PREFIX_LEN = 20  # p for pt1 / pt2


@dataclasses.dataclass(frozen=True)
class Bucket:
    """A static (batch, seq) instantiation of an artifact."""

    batch: int
    seq: int

    def tag(self) -> str:
        return f"b{self.batch}n{self.seq}"


# Serving buckets cover the paper's speed grid (§4.4): batch ∈ {1, 16, 64},
# seq ∈ {16, 64, 128, 384}.  Training buckets are fixed-seq.
SPEED_BATCHES = [1, 16, 64]
SPEED_SEQS = [16, 64, 128, 384]

TRAIN_BUCKET = Bucket(batch=16, seq=64)
TRAIN_STEPS_PER_CALL = 8  # scan this many optimizer steps inside one call


def serving_buckets() -> List[Bucket]:
    return [Bucket(b, n) for b in SPEED_BATCHES for n in SPEED_SEQS]


def artifact_name(kind: str, model: str, method: str, bucket: Bucket, **extra) -> str:
    """Canonical artifact file stem, shared with the Rust loader."""
    parts = [kind, model, method, bucket.tag()]
    for key in sorted(extra):
        parts.append(f"{key}{extra[key]}")
    return "_".join(parts)
