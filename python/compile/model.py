"""L2: RoBERTa-style Transformer encoder with every PEFT method as a hook.

Layer weights are *stacked* along a leading layer axis (``wq: [l, d, d]``,
…) and the encoder runs as one ``lax.scan`` over layers.  This keeps the
artifact input signature at a fixed 20 backbone tensors regardless of
depth, makes trace/lowering time depth-independent (hundreds of artifacts
are generated on one core), and is also the layout the Rust runtime feeds.

Two forward entry points share the scanned layer implementation:

* ``forward_train`` — single-task, unbatched method parameters (the shapes
  produced by ``peft.init_method_params``).  Differentiable; used by the
  train/eval artifacts.
* ``forward_serve`` — multi-task, per-batch-element method state (each
  request in the batch may belong to a different task, paper §3.1).  Used
  by the serving artifacts the Rust coordinator loads.

AoT P-Tuning appears in three flavors:

* training (``aot-kron`` / ``aot-fc``): rows of the reparametrized ``P``
  are computed in-graph only for the tokens present (paper §3.3) and added
  before each layer;
* serving, host-gather (``aot``): the coordinator gathers rows of the
  fused ``P`` from host RAM and ships a dense ``bias[l, b, n, d]`` — the
  model just adds it (the "zero-cost" path of Figure 3);
* serving, device-gather (``aot-gather``): the fused ``P[l, V, d]`` is
  device-resident and rows are gathered in-graph by the Pallas
  ``aot_bias`` kernel (validates L1↔L3 composition; not the Figure 3
  path, where all methods share the pure-jnp attention for fairness).
"""

from __future__ import annotations

from typing import Dict, List, Optional

import jax
import jax.numpy as jnp

from .configs import ModelConfig
from .kernels import ref
from .kernels.aot_bias import aot_bias
from .peft import MethodHP

LN_EPS = 1e-5
LORA_ALPHA = 16.0

# Backbone tensors whose leading axis is the layer index.
LAYER_TENSORS = [
    "wq", "bq", "wk", "bk", "wv", "bv", "wo", "bo",
    "ln1_g", "ln1_b", "w1", "b1", "w2", "b2", "ln2_g", "ln2_b",
]
EMB_TENSORS = ["emb_tok", "emb_pos", "emb_ln_g", "emb_ln_b"]


def backbone_shapes(cfg: ModelConfig) -> Dict[str, tuple]:
    """Ordered name -> shape map for every frozen backbone tensor."""
    d, ff, v, l = cfg.d_model, cfg.d_ff, cfg.vocab_size, cfg.n_layers
    shapes: Dict[str, tuple] = {
        "emb_tok": (v, d),
        "emb_pos": (cfg.max_positions, d),
        "emb_ln_g": (d,),
        "emb_ln_b": (d,),
        "wq": (l, d, d), "bq": (l, d),
        "wk": (l, d, d), "bk": (l, d),
        "wv": (l, d, d), "bv": (l, d),
        "wo": (l, d, d), "bo": (l, d),
        "ln1_g": (l, d), "ln1_b": (l, d),
        "w1": (l, d, ff), "b1": (l, ff),
        "w2": (l, ff, d), "b2": (l, d),
        "ln2_g": (l, d), "ln2_b": (l, d),
    }
    return shapes


def backbone_order(cfg: ModelConfig) -> List[str]:
    return list(backbone_shapes(cfg).keys())


# Consecutive-id block size sharing one embedding centroid (see
# init_backbone).  The synthetic lexicon (rust/src/data/lexicon.rs) assigns
# cluster words contiguous ids, so blocks align with semantic clusters.
EMB_CLUSTER_BLOCK = 50


def init_backbone(cfg: ModelConfig, key) -> Dict[str, jnp.ndarray]:
    """Deterministic synthetic 'pre-trained' backbone (DESIGN.md §2).

    Two properties real pre-training provides are reproduced synthetically,
    because the PEFT methods depend on them:

    * **semantic embedding clusters** — `emb_tok[t] = centroid[t // B] +
      noise`: words of one lexicon cluster (contiguous ids) share a
      centroid direction, which is exactly the structure FC AoT P-Tuning's
      `P = f(E W1) W2` exploits (paper §3.3: "utilize knowledge stored in
      the pre-trained embeddings matrix");
    * **non-degenerate attention** — 1/sqrt(fan_in) weight scaling keeps
      attention logits O(1) so frozen-feature methods receive signal.
    """
    params = {}
    shapes = backbone_shapes(cfg)
    keys = jax.random.split(key, len(shapes) + 1)
    centroid_key = keys[-1]
    for k, (name, shape) in zip(keys, shapes.items()):
        if "_g" in name:
            params[name] = jnp.ones(shape, jnp.float32)
        elif name.startswith("b") or name.endswith("_b") or name in ("bq", "bk", "bv", "bo"):
            params[name] = jnp.zeros(shape, jnp.float32)
        elif name == "emb_tok":
            v, d = shape
            n_clusters = (v + EMB_CLUSTER_BLOCK - 1) // EMB_CLUSTER_BLOCK
            centroids = jax.random.normal(centroid_key, (n_clusters, d), jnp.float32)
            cluster_of = jnp.arange(v) // EMB_CLUSTER_BLOCK
            noise = jax.random.normal(k, shape, jnp.float32)
            emb = 0.75 * centroids[cluster_of] + 0.66 * noise
            params[name] = emb / jnp.sqrt(jnp.float32(d))
        else:
            fan_in = shape[-2] if len(shape) >= 2 else shape[-1]
            params[name] = jax.random.normal(k, shape, jnp.float32) / jnp.sqrt(
                jnp.float32(fan_in)
            )
    return params


def _ln(x, g, b):
    return ref.layer_norm_ref(x, g, b, LN_EPS)


def _split_heads(x, n_heads):
    b, n, d = x.shape
    return x.reshape(b, n, n_heads, d // n_heads).transpose(0, 2, 1, 3)


def _merge_heads(x):
    b, h, n, dh = x.shape
    return x.transpose(0, 2, 1, 3).reshape(b, n, h * dh)


def _dropout(x, rate, key, train):
    if not train or rate <= 0.0:
        return x
    keep = jax.random.bernoulli(key, 1.0 - rate, x.shape)
    return jnp.where(keep, x / (1.0 - rate), 0.0)


def _layer_body(cfg: ModelConfig, method: str, hp: MethodHP, mask, *, batched: bool):
    """Scan body over layers, shared by the train and serve paths.

    ``batched=False``: method state in ``xs`` is single-task (train path).
    ``batched=True``:  method state carries a per-batch-element axis
                       (multi-task serving, §3.1).
    """
    h_heads = cfg.n_heads
    bitfit = method == "bitfit"

    def pe(x):  # "per-element": insert the broadcast axis for serve tensors
        return x[:, None, :] if batched else x

    def body(hidden, xs):
        bb = xs["bb"]

        if "aot_rows" in xs:
            # Equation 1: input-dependent bias before the layer.
            hidden = hidden + xs["aot_rows"]
        if "p_table" in xs:
            # Device-gather flavor: fused P rows gathered in-graph (L1 kernel).
            if xs.get("use_pallas", False):
                hidden = aot_bias(hidden, xs["p_table"], xs["ids"])
            else:
                hidden = ref.aot_bias_ref(hidden, xs["p_table"], xs["ids"])

        def proj_b(j, base):
            if bitfit:
                return base + pe(xs["bf.proj_b"][j])
            return base

        q = hidden @ bb["wq"] + proj_b(0, bb["bq"])
        k = hidden @ bb["wk"] + proj_b(1, bb["bk"])
        v = hidden @ bb["wv"] + proj_b(2, bb["bv"])

        if method == "lora":
            scale = LORA_ALPHA / hp.rank
            if batched:
                q = q + jnp.einsum(
                    "bnr,brd->bnd",
                    jnp.einsum("bnd,bdr->bnr", hidden, xs["lora.a_q"]),
                    xs["lora.b_q"],
                ) * scale
                v = v + jnp.einsum(
                    "bnr,brd->bnd",
                    jnp.einsum("bnd,bdr->bnr", hidden, xs["lora.a_v"]),
                    xs["lora.b_v"],
                ) * scale
            else:
                q = q + (hidden @ xs["lora.a_q"]) @ xs["lora.b_q"] * scale
                v = v + (hidden @ xs["lora.a_v"]) @ xs["lora.b_v"] * scale

        qh, kh, vh = (_split_heads(x, h_heads) for x in (q, k, v))

        if method == "pt2":
            pk, pv = xs["pt2.pk"], xs["pt2.pv"]
            if not batched:
                b = hidden.shape[0]
                pk = jnp.broadcast_to(pk, (b,) + pk.shape)
                pv = jnp.broadcast_to(pv, (b,) + pv.shape)
            attn = ref.prefix_attention_ref(
                qh, kh, vh, mask, _split_heads(pk, h_heads), _split_heads(pv, h_heads)
            )
        else:
            attn = ref.attention_ref(qh, kh, vh, mask)

        a = _merge_heads(attn) @ bb["wo"] + proj_b(3, bb["bo"])

        if method == "adapters":
            if batched:
                low = ref.gelu(
                    jnp.einsum("bnd,bdr->bnr", a, xs["ad.attn_wd"]) + pe(xs["ad.attn_bd"])
                )
                a = a + jnp.einsum("bnr,brd->bnd", low, xs["ad.attn_wu"]) + pe(xs["ad.attn_bu"])
            else:
                low = ref.gelu(a @ xs["ad.attn_wd"] + xs["ad.attn_bd"])
                a = a + low @ xs["ad.attn_wu"] + xs["ad.attn_bu"]

        ln1_b = bb["ln1_b"] + (pe(xs["bf.ln_b"][0]) if bitfit else 0.0)
        hidden = _ln(hidden + a, bb["ln1_g"], ln1_b)

        f_b1 = bb["b1"] + (pe(xs["bf.ffn_b1"]) if bitfit else 0.0)
        f_b2 = bb["b2"] + (pe(xs["bf.ffn_b2"]) if bitfit else 0.0)
        f = ref.gelu(hidden @ bb["w1"] + f_b1) @ bb["w2"] + f_b2

        if method == "adapters":
            if batched:
                low = ref.gelu(
                    jnp.einsum("bnd,bdr->bnr", f, xs["ad.ffn_wd"]) + pe(xs["ad.ffn_bd"])
                )
                f = f + jnp.einsum("bnr,brd->bnd", low, xs["ad.ffn_wu"]) + pe(xs["ad.ffn_bu"])
            else:
                low = ref.gelu(f @ xs["ad.ffn_wd"] + xs["ad.ffn_bd"])
                f = f + low @ xs["ad.ffn_wu"] + xs["ad.ffn_bu"]

        ln2_b = bb["ln2_b"] + (pe(xs["bf.ln_b"][1]) if bitfit else 0.0)
        hidden = _ln(hidden + f, bb["ln2_g"], ln2_b)
        return hidden, None

    return body


def _pool(hidden, mask):
    """Masked mean pooling.

    With a synthetic (not genuinely pre-trained) frozen backbone, CLS
    pooling buries the per-token signal the PEFT methods inject; the
    masked mean exposes the paper's Equation-4 mechanism directly: the
    last layer's AoT bias reaches the pooled vector through the residual
    path.  Documented substitution (DESIGN.md §2).
    """
    denom = jnp.maximum(mask.sum(axis=1, keepdims=True), 1.0)
    return (hidden * mask[:, :, None]).sum(axis=1) / denom


def _embed(cfg: ModelConfig, bb, ids, emb_ln_b_extra=None):
    n = ids.shape[1]
    hidden = bb["emb_tok"][ids] + bb["emb_pos"][:n][None, :, :]
    beta = bb["emb_ln_b"] if emb_ln_b_extra is None else bb["emb_ln_b"] + emb_ln_b_extra
    return _ln(hidden, bb["emb_ln_g"], beta)


def _layer_stack(bb):
    return {name: bb[name] for name in LAYER_TENSORS}


# ---------------------------------------------------------------------------
# Single-task (training) forward
# ---------------------------------------------------------------------------

def forward_train(
    cfg: ModelConfig,
    backbone: Dict[str, jnp.ndarray],
    mp: Dict[str, jnp.ndarray],
    method: str,
    ids: jnp.ndarray,
    mask: jnp.ndarray,
    hp: MethodHP,
    *,
    train: bool = False,
    dropout_key: Optional[jnp.ndarray] = None,
) -> jnp.ndarray:
    """Logits [b, classes] for one task.

    ``mp`` holds the method's trainable tensors plus ``head_w``/``head_b``.
    For ``fine-tune`` the ``ft.``-prefixed tensors in ``mp`` replace the
    frozen backbone.
    """
    if method == "fine-tune":
        backbone = {k[3:]: v for k, v in mp.items() if k.startswith("ft.")}
    if method == "lora-fused":
        method = "lora"  # identical during training; fusing is a serve-time act

    ids = ids.astype(jnp.int32)
    b, n = ids.shape
    l = cfg.n_layers
    bb = backbone
    key_p = dropout_key if dropout_key is not None else jax.random.PRNGKey(0)

    hidden = _embed(cfg, bb, ids, mp["bf.emb_ln_b"] if method == "bitfit" else None)
    cls_index = 0

    if method == "pt1":
        # Soft prompt prepended to the embedded sequence (Equation 7); the
        # CLS token moves to position p.
        prompt = jnp.broadcast_to(mp["pt1.prompt"], (b,) + mp["pt1.prompt"].shape)
        hidden = jnp.concatenate([prompt, hidden], axis=1)
        mask = jnp.concatenate([jnp.ones((b, hp.prefix), mask.dtype), mask], axis=1)
        cls_index = hp.prefix

    xs: Dict[str, jnp.ndarray] = {"bb": _layer_stack(bb)}

    if method == "aot-kron":
        keys = jax.random.split(key_p, l)
        rows = jax.vmap(
            lambda wl, wm, wr, k: _dropout(
                ref.kron_rows_ref(wl, wm, wr, ids), hp.dropout, k, train
            )
        )(mp["kron.wl"], mp["kron.wm"], mp["kron.wr"], keys)
        xs["aot_rows"] = rows  # [l, b, n, d], paper §4.1 dropout on P_x
    elif method == "aot-fc":
        e_rows = bb["emb_tok"][ids]
        keys = jax.random.split(key_p, l)
        rows = jax.vmap(
            lambda w1, b1, w2, b2, k: ref.fc_rows_ref(
                _dropout(e_rows, hp.dropout, k, train), w1, b1, w2, b2
            )
        )(mp["fc.w1"], mp["fc.b1"], mp["fc.w2"], mp["fc.b2"], keys)
        xs["aot_rows"] = rows  # paper §4.1 dropout on E before W1
    elif method == "bitfit":
        xs["bf.proj_b"] = mp["bf.proj_b"]  # [l, 4, d]; scan slices the layer axis
        xs["bf.ffn_b1"] = mp["bf.ffn_b1"]
        xs["bf.ffn_b2"] = mp["bf.ffn_b2"]
        xs["bf.ln_b"] = mp["bf.ln_b"]  # [l, 2, d]
    elif method == "lora":
        for k in ("lora.a_q", "lora.b_q", "lora.a_v", "lora.b_v"):
            xs[k] = mp[k]
    elif method == "adapters":
        for k in (
            "ad.attn_wd", "ad.attn_bd", "ad.attn_wu", "ad.attn_bu",
            "ad.ffn_wd", "ad.ffn_bd", "ad.ffn_wu", "ad.ffn_bu",
        ):
            xs[k] = mp[k]
    elif method == "pt2":
        xs["pt2.pk"] = mp["pt2.pk"]
        xs["pt2.pv"] = mp["pt2.pv"]

    body = _layer_body(cfg, method, hp, mask, batched=False)
    hidden, _ = jax.lax.scan(body, hidden, xs)

    pooled = _pool(hidden, mask)
    return pooled @ mp["head_w"] + mp["head_b"]


# ---------------------------------------------------------------------------
# Multi-task (serving) forward
# ---------------------------------------------------------------------------

def serve_input_shapes(
    cfg: ModelConfig, method: str, batch: int, seq: int, hp: MethodHP
) -> Dict[str, tuple]:
    """Ordered name -> shape of the per-call (non-weight) serving inputs.

    These are what the Rust coordinator assembles per batch.  ``ids``/
    ``mask`` come first; per-task state is stacked per batch element
    (multi-task inference, §3.1); the batched classification head closes.
    """
    d, ff, l = cfg.d_model, cfg.d_ff, cfg.n_layers
    r, p, c = hp.rank, hp.prefix, hp.classes
    shapes: Dict[str, tuple] = {
        "in.ids": (batch, seq),
        "in.mask": (batch, seq),
    }
    if method in ("fine-tune", "lora-fused", "aot-gather"):
        pass  # no extra per-call state (aot-gather's P is a weight input)
    elif method == "aot":
        shapes["in.bias"] = (l, batch, seq, d)
    elif method == "aot-unfused":
        # Paper §4.4's "no fusing" reference setup: FC reparam weights ship
        # with the request and P rows are recomputed in-graph.
        shapes["in.fc_w1"] = (l, batch, d, r)
        shapes["in.fc_b1"] = (l, batch, r)
        shapes["in.fc_w2"] = (l, batch, r, d)
        shapes["in.fc_b2"] = (l, batch, d)
    elif method == "bitfit":
        shapes["in.proj_b"] = (l, 4, batch, d)
        shapes["in.ffn_b1"] = (l, batch, ff)
        shapes["in.ffn_b2"] = (l, batch, d)
        shapes["in.ln_b"] = (l, 2, batch, d)
        shapes["in.emb_ln_b"] = (batch, d)
    elif method == "lora":
        shapes["in.lora_a_q"] = (l, batch, d, r)
        shapes["in.lora_b_q"] = (l, batch, r, d)
        shapes["in.lora_a_v"] = (l, batch, d, r)
        shapes["in.lora_b_v"] = (l, batch, r, d)
    elif method == "adapters":
        shapes["in.ad_attn_wd"] = (l, batch, d, r)
        shapes["in.ad_attn_bd"] = (l, batch, r)
        shapes["in.ad_attn_wu"] = (l, batch, r, d)
        shapes["in.ad_attn_bu"] = (l, batch, d)
        shapes["in.ad_ffn_wd"] = (l, batch, d, r)
        shapes["in.ad_ffn_bd"] = (l, batch, r)
        shapes["in.ad_ffn_wu"] = (l, batch, r, d)
        shapes["in.ad_ffn_bu"] = (l, batch, d)
    elif method == "pt1":
        shapes["in.prompt"] = (batch, p, d)
    elif method == "pt2":
        shapes["in.pk"] = (l, batch, p, d)
        shapes["in.pv"] = (l, batch, p, d)
    else:
        raise ValueError(f"unknown serving method: {method}")
    shapes["in.head_w"] = (batch, d, c)
    shapes["in.head_b"] = (batch, c)
    return shapes


def forward_serve(
    cfg: ModelConfig,
    backbone: Dict[str, jnp.ndarray],
    sp: Dict[str, jnp.ndarray],
    method: str,
    hp: MethodHP,
    *,
    use_pallas_gather: bool = False,
) -> jnp.ndarray:
    """Multi-task batched forward.  ``sp`` follows ``serve_input_shapes``.

    Every batch element carries its own task state (``[b, ...]`` axes), so
    one backbone invocation serves many tasks — the batched multi-task
    evaluation of §3.1.  For ``aot-gather`` the fused tables ride in
    ``backbone["P"]`` with shape [l, V, d].
    """
    ids = sp["in.ids"].astype(jnp.int32)
    mask = sp["in.mask"]
    b, n = ids.shape
    bb = backbone
    bitfit = method == "bitfit"

    hidden = _embed(
        cfg, bb, ids, sp["in.emb_ln_b"][:, None, :] if bitfit else None
    )
    cls_index = 0

    if method == "pt1":
        hidden = jnp.concatenate([sp["in.prompt"], hidden], axis=1)
        mask = jnp.concatenate([jnp.ones((b, hp.prefix), mask.dtype), mask], axis=1)
        cls_index = hp.prefix

    xs: Dict[str, jnp.ndarray] = {"bb": _layer_stack(bb)}

    if method == "aot":
        xs["aot_rows"] = sp["in.bias"]
    elif method == "aot-unfused":
        e_rows = bb["emb_tok"][ids]
        rows = ref.gelu(
            jnp.einsum("bnd,lbdr->lbnr", e_rows, sp["in.fc_w1"])
            + sp["in.fc_b1"][:, :, None, :]
        )
        rows = (
            jnp.einsum("lbnr,lbrd->lbnd", rows, sp["in.fc_w2"])
            + sp["in.fc_b2"][:, :, None, :]
        )
        xs["aot_rows"] = rows
    elif bitfit:
        xs["bf.proj_b"] = sp["in.proj_b"]  # [l, 4, b, d]
        xs["bf.ffn_b1"] = sp["in.ffn_b1"]
        xs["bf.ffn_b2"] = sp["in.ffn_b2"]
        xs["bf.ln_b"] = sp["in.ln_b"]  # [l, 2, b, d]
    elif method == "lora":
        xs["lora.a_q"] = sp["in.lora_a_q"]
        xs["lora.b_q"] = sp["in.lora_b_q"]
        xs["lora.a_v"] = sp["in.lora_a_v"]
        xs["lora.b_v"] = sp["in.lora_b_v"]
    elif method == "adapters":
        for name in (
            "attn_wd", "attn_bd", "attn_wu", "attn_bu",
            "ffn_wd", "ffn_bd", "ffn_wu", "ffn_bu",
        ):
            xs[f"ad.{name}"] = sp[f"in.ad_{name}"]
    elif method == "pt2":
        xs["pt2.pk"] = sp["in.pk"]
        xs["pt2.pv"] = sp["in.pv"]

    if method == "aot-gather":
        # Device-gather flavor: explicit scan so the pallas/ref choice (a
        # static flag) stays out of the traced xs dict.
        body_inner = _layer_body(cfg, "fine-tune", hp, mask, batched=True)

        def body(h, per_layer):
            p_table = per_layer["p_table"]
            if use_pallas_gather:
                h = aot_bias(h, p_table, ids)
            else:
                h = ref.aot_bias_ref(h, p_table, ids)
            return body_inner(h, {"bb": per_layer["bb"]})

        hidden, _ = jax.lax.scan(
            body, hidden, {"bb": xs["bb"], "p_table": bb["P"]}
        )
    else:
        body = _layer_body(cfg, method, hp, mask, batched=True)
        hidden, _ = jax.lax.scan(body, hidden, xs)

    pooled = _pool(hidden, mask)
    return jnp.einsum("bd,bdc->bc", pooled, sp["in.head_w"]) + sp["in.head_b"]
