"""The `aotckpt` binary tensor-checkpoint format, shared with Rust.

Little-endian layout (mirrored by ``rust/src/tensor/ckpt.rs``):

    magic   b"ACKP"
    u32     version (1)
    u32     tensor count
    per tensor:
        u16   name length, then UTF-8 name bytes
        u8    dtype: 0 = f32, 1 = i32, 2 = i64, 3 = f16, 4 = i8
        u8    ndim
        u32   dims[ndim]
        u64   payload byte length
        raw   payload (row-major)

Used for: synthetic pre-trained backbones (written here), trained task state
and fused P matrices (written by the Rust training driver), and golden
outputs for integration tests.
"""

from __future__ import annotations

import struct
from typing import Dict

import numpy as np

MAGIC = b"ACKP"
VERSION = 1
_DTYPES = {
    np.dtype(np.float32): 0,
    np.dtype(np.int32): 1,
    np.dtype(np.int64): 2,
    np.dtype(np.float16): 3,
    np.dtype(np.int8): 4,
}
_DTYPES_INV = {0: np.float32, 1: np.int32, 2: np.int64, 3: np.float16, 4: np.int8}


def save(path: str, tensors: Dict[str, np.ndarray]) -> None:
    with open(path, "wb") as f:
        f.write(MAGIC)
        f.write(struct.pack("<II", VERSION, len(tensors)))
        for name, arr in tensors.items():
            arr = np.ascontiguousarray(arr)
            if arr.dtype not in _DTYPES:
                raise ValueError(f"unsupported dtype {arr.dtype} for {name}")
            raw = arr.tobytes()
            nb = name.encode("utf-8")
            f.write(struct.pack("<H", len(nb)))
            f.write(nb)
            f.write(struct.pack("<BB", _DTYPES[arr.dtype], arr.ndim))
            f.write(struct.pack(f"<{arr.ndim}I", *arr.shape))
            f.write(struct.pack("<Q", len(raw)))
            f.write(raw)


def load(path: str) -> Dict[str, np.ndarray]:
    out: Dict[str, np.ndarray] = {}
    with open(path, "rb") as f:
        if f.read(4) != MAGIC:
            raise ValueError(f"{path}: not an aotckpt file")
        version, count = struct.unpack("<II", f.read(8))
        if version != VERSION:
            raise ValueError(f"{path}: unsupported version {version}")
        for _ in range(count):
            (nlen,) = struct.unpack("<H", f.read(2))
            name = f.read(nlen).decode("utf-8")
            dtype_code, ndim = struct.unpack("<BB", f.read(2))
            dims = struct.unpack(f"<{ndim}I", f.read(4 * ndim)) if ndim else ()
            (nbytes,) = struct.unpack("<Q", f.read(8))
            arr = np.frombuffer(f.read(nbytes), dtype=_DTYPES_INV[dtype_code])
            out[name] = arr.reshape(dims)
    return out
