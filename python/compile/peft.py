"""Parameter initialization + metadata for every fine-tuning method.

One module owns, for each of the paper's nine methods (Table 1):

* which tensors are trainable and how they are initialized (the paper's
  zero-init conventions from §4.1 are reproduced exactly: ``W_R`` zero for
  Kronecker AoT, ``W_2``/``b_1``/``b_2`` zero for FC AoT, LoRA ``B`` zero,
  adapter up-projections zero — so every method's forward equals the frozen
  backbone at initialization, asserted in ``python/tests/test_model.py``);
* the serving-time input signature (how per-task state is batched for
  multi-task inference, §3.1);
* the Table 1 property triple (parameter-efficient / zero-cost /
  multi-task), which the Rust method registry mirrors.

Init specs are emitted into the artifact manifest so the Rust training
driver can materialize fresh trainable parameters for any seed without
Python on the path.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp

from .configs import ModelConfig, kron_factors


@dataclasses.dataclass(frozen=True)
class MethodHP:
    """Hyperparameters that change trainable-parameter shapes."""

    rank: int = 16  # r for lora / adapters / aot-kron / aot-fc
    prefix: int = 20  # p for pt1 / pt2
    classes: int = 2
    dropout: float = 0.1  # on P_x (kron) / on E (fc), train only


# (parameter_efficient, zero_cost, multi_task) — paper Table 1.
METHOD_PROPERTIES: Dict[str, Tuple[bool, bool, bool]] = {
    "fine-tune": (False, True, False),
    "lora": (True, False, True),
    "lora-fused": (True, True, False),
    "adapters": (True, False, True),
    "bitfit": (True, True, True),
    "pt1": (True, False, True),
    "pt2": (True, False, True),
    "aot-kron": (True, True, True),
    "aot-fc": (True, True, True),
}


def _norm(key, shape, std=0.02):
    return jax.random.normal(key, shape, jnp.float32) * std


def _zeros(shape):
    return jnp.zeros(shape, jnp.float32)


# ---------------------------------------------------------------------------
# Trainable parameter construction
# ---------------------------------------------------------------------------

def init_head(cfg: ModelConfig, hp: MethodHP, key) -> Dict[str, jnp.ndarray]:
    """Per-task classification head (trained for every method, paper §3.2)."""
    return {
        "head_w": _norm(key, (cfg.d_model, hp.classes)),
        "head_b": _zeros((hp.classes,)),
    }


def init_method_params(
    cfg: ModelConfig, method: str, hp: MethodHP, key, backbone=None
) -> Dict[str, jnp.ndarray]:
    """Trainable parameters for `method` (excluding the classification head).

    For ``fine-tune`` the caller passes the backbone; a copy of every
    backbone tensor becomes trainable.
    """
    d, ff, l, v = cfg.d_model, cfg.d_ff, cfg.n_layers, cfg.vocab_size
    r, p = hp.rank, hp.prefix
    keys = iter(jax.random.split(key, 16 * max(l, 1) + 8))
    params: Dict[str, jnp.ndarray] = {}

    if method == "fine-tune":
        assert backbone is not None
        for name, val in backbone.items():
            params[f"ft.{name}"] = val
        return params

    if method == "bitfit":
        # All bias terms of the model (Ben Zaken et al. 2022): projection
        # biases, FFN biases, LayerNorm betas, embedding-LN beta.  Stacked
        # across layers so the serving signature is a handful of tensors.
        params["bf.proj_b"] = _zeros((l, 4, d))  # q, k, v, o
        params["bf.ffn_b1"] = _zeros((l, ff))
        params["bf.ffn_b2"] = _zeros((l, d))
        params["bf.ln_b"] = _zeros((l, 2, d))  # ln1, ln2 betas
        params["bf.emb_ln_b"] = _zeros((d,))
        return params

    if method in ("lora", "lora-fused"):
        # Low-rank deltas on W_q and W_v (Hu et al. 2022). A ~ N(0, .02), B = 0.
        params["lora.a_q"] = jnp.stack([_norm(next(keys), (d, r)) for _ in range(l)])
        params["lora.b_q"] = _zeros((l, r, d))
        params["lora.a_v"] = jnp.stack([_norm(next(keys), (d, r)) for _ in range(l)])
        params["lora.b_v"] = _zeros((l, r, d))
        return params

    if method == "adapters":
        # Houlsby adapters after the attention block and after the FFN.
        # Up-projection zero-initialized => identity at init.
        params["ad.attn_wd"] = jnp.stack([_norm(next(keys), (d, r)) for _ in range(l)])
        params["ad.attn_bd"] = _zeros((l, r))
        params["ad.attn_wu"] = _zeros((l, r, d))
        params["ad.attn_bu"] = _zeros((l, d))
        params["ad.ffn_wd"] = jnp.stack([_norm(next(keys), (d, r)) for _ in range(l)])
        params["ad.ffn_bd"] = _zeros((l, r))
        params["ad.ffn_wu"] = _zeros((l, r, d))
        params["ad.ffn_bu"] = _zeros((l, d))
        return params

    if method == "pt1":
        params["pt1.prompt"] = _norm(next(keys), (p, d))
        return params

    if method == "pt2":
        params["pt2.pk"] = jnp.stack([_norm(next(keys), (p, d)) for _ in range(l)])
        params["pt2.pv"] = jnp.stack([_norm(next(keys), (p, d)) for _ in range(l)])
        return params

    if method == "aot-kron":
        a, bf_dim = kron_factors(v)
        # W_L, W_M random; W_R zero (paper §4.1) => P == 0 at init.
        params["kron.wl"] = jnp.stack([_norm(next(keys), (a, r)) for _ in range(l)])
        params["kron.wm"] = jnp.stack([_norm(next(keys), (bf_dim, r)) for _ in range(l)])
        params["kron.wr"] = _zeros((l, r * r, d))
        return params

    if method == "aot-fc":
        # W_1 random; W_2, b_1, b_2 zero (paper §4.1) => P == 0 at init.
        params["fc.w1"] = jnp.stack([_norm(next(keys), (d, r)) for _ in range(l)])
        params["fc.b1"] = _zeros((l, r))
        params["fc.w2"] = _zeros((l, r, d))
        params["fc.b2"] = _zeros((l, d))
        return params

    raise ValueError(f"unknown method: {method}")


def init_spec(
    cfg: ModelConfig, method: str, hp: MethodHP
) -> List[dict]:
    """Manifest description of each trainable tensor: name, shape, init.

    The Rust driver materializes these (with its own seeded RNG) so seed
    sweeps never call back into Python.
    """
    dummy_key = jax.random.PRNGKey(0)
    spec = []
    if method == "fine-tune":
        # Full fine-tuning trains a copy of every backbone tensor; the Rust
        # driver initializes them by copying the backbone checkpoint.
        from .model import backbone_shapes  # local import avoids a cycle

        for name, shape in backbone_shapes(cfg).items():
            spec.append(
                {
                    "name": f"ft.{name}",
                    "shape": list(shape),
                    "dtype": "f32",
                    "init": "backbone",
                    "std": 0.0,
                }
            )
    else:
        params = init_method_params(cfg, method, hp, dummy_key)
        for name, val in params.items():
            # Zero-init tensors stay zero for every seed; everything else is
            # N(0, 0.02) per the paper's init convention.
            is_zero = bool((val == 0).all())
            spec.append(
                {
                    "name": name,
                    "shape": list(val.shape),
                    "dtype": "f32",
                    "init": "zeros" if is_zero else "normal",
                    "std": 0.0 if is_zero else 0.02,
                }
            )
    head = init_head(cfg, hp, dummy_key)
    for name, val in head.items():
        spec.append(
            {
                "name": name,
                "shape": list(val.shape),
                "dtype": "f32",
                "init": "zeros" if name.endswith("_b") else "normal",
                "std": 0.0 if name.endswith("_b") else 0.02,
            }
        )
    return spec


def trainable_param_order(cfg: ModelConfig, method: str, hp: MethodHP) -> List[str]:
    """Stable flattening order for trainable tensors (incl. head)."""
    return [entry["name"] for entry in init_spec(cfg, method, hp)]


def count_trainable(cfg: ModelConfig, method: str, hp: MethodHP) -> int:
    """Number of optimized parameters (paper's parameter-efficiency axis)."""
    total = 0
    for entry in init_spec(cfg, method, hp):
        n = 1
        for s in entry["shape"]:
            n *= s
        total += n
    return total
