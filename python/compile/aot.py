"""AOT pipeline: lower every (model, method, bucket) graph to HLO text.

Python runs ONCE, at build time (``make artifacts``); the Rust binary is
self-contained afterwards.  Interchange is **HLO text**, not serialized
``HloModuleProto`` — jax ≥ 0.5 emits protos with 64-bit instruction ids that
the image's xla_extension 0.5.1 rejects, while the text parser reassigns ids
(see /opt/xla-example/README.md).

Outputs, under ``artifacts/``:

* ``<stem>.hlo.txt``        — one per artifact (see DESIGN.md §8)
* ``backbone_<shape>.aotckpt`` — deterministic synthetic backbone weights
* ``golden_<name>.aotckpt`` — input/output pairs for Rust integration tests
* ``manifest.json``         — every artifact's positional input/output
  signature, trainable-init specs, model geometry, method properties;
  the single source of truth the Rust loader builds against.

Usage:
    python -m compile.aot --out ../artifacts            # default set
    python -m compile.aot --out ../artifacts --quick    # tiny/small only
"""

from __future__ import annotations

import argparse
import functools
import json
import os
import time
from typing import Callable, Dict, List, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import ckpt
from .configs import (
    MODEL_CONFIGS,
    MULTITASK_CLASSES,
    PAPER_ANALOG,
    TRAIN_BUCKET,
    TRAIN_STEPS_PER_CALL,
    Bucket,
    ModelConfig,
    artifact_name,
    kron_factors,
)
from .kernels import ref
from .kernels.aot_bias import aot_bias
from .kernels.attention import attention
from .kernels.kron import kron_fuse
from .model import (
    backbone_order,
    backbone_shapes,
    forward_serve,
    init_backbone,
    serve_input_shapes,
)
from .peft import MethodHP, METHOD_PROPERTIES, init_spec, trainable_param_order
from .train import make_eval_fn, make_mlm_fn, make_train_fn

# Serving methods measured in the Figure 3/8/9 overhead study.
SPEED_METHODS = [
    "fine-tune",  # the normalization baseline (= fused LoRA = vanilla)
    "bitfit",
    "lora",
    "adapters",
    "pt1",
    "pt2",
    "aot",
    "aot-unfused",
]

# Trainable methods for the quality tables (Table 2 / Appendix Table 3).
TRAIN_METHODS = [
    "fine-tune", "bitfit", "lora", "adapters", "pt1", "pt2", "aot-kron", "aot-fc",
]


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (the interchange format)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=False
    )
    return comp.as_hlo_text()


_DTYPE = {"f32": jnp.float32, "i32": jnp.int32}


class Builder:
    """Accumulates artifacts + manifest entries."""

    def __init__(self, out_dir: str, force: bool = False):
        self.out = out_dir
        self.force = force
        os.makedirs(out_dir, exist_ok=True)
        self.manifest: Dict = {
            "version": 1,
            "vocab_size": None,
            "multitask_classes": MULTITASK_CLASSES,
            "models": {},
            "method_properties": {
                m: {
                    "parameter_efficient": p[0],
                    "zero_cost": p[1],
                    "multi_task": p[2],
                }
                for m, p in METHOD_PROPERTIES.items()
            },
            "paper_analog": PAPER_ANALOG,
            "artifacts": {},
        }

    def note_model(self, cfg: ModelConfig):
        a, bf = kron_factors(cfg.vocab_size)
        self.manifest["vocab_size"] = cfg.vocab_size
        self.manifest["models"][cfg.name] = {
            "d_model": cfg.d_model,
            "n_layers": cfg.n_layers,
            "n_heads": cfg.n_heads,
            "d_ff": cfg.d_ff,
            "vocab_size": cfg.vocab_size,
            "max_positions": cfg.max_positions,
            "params": cfg.param_count(),
            "kron_a": a,
            "kron_b": bf,
        }

    def add(
        self,
        stem: str,
        fn: Callable,
        inputs: Sequence[Tuple[str, tuple, str]],
        outputs: Sequence[str],
        meta: Dict,
        force: bool = False,
    ):
        """Lower ``fn(*flat_inputs)`` and record its signature."""
        path = os.path.join(self.out, f"{stem}.hlo.txt")
        entry = {
            "file": f"{stem}.hlo.txt",
            "inputs": [
                {"name": n, "shape": list(s), "dtype": d} for n, s, d in inputs
            ],
            "outputs": list(outputs),
            **meta,
        }
        self.manifest["artifacts"][stem] = entry
        if os.path.exists(path) and not (force or self.force):
            return  # cached from a previous make; manifest still re-recorded
        t0 = time.time()
        specs = [jax.ShapeDtypeStruct(s, _DTYPE[d]) for _, s, d in inputs]
        # keep_unused=True: the manifest promises the full positional
        # signature; jit must not drop inputs that a given method ignores
        # (e.g. `in.seed` for methods without dropout).
        lowered = jax.jit(fn, keep_unused=True).lower(*specs)
        text = to_hlo_text(lowered)
        with open(path, "w") as f:
            f.write(text)
        print(f"  [{time.time() - t0:6.2f}s] {stem} ({len(text) // 1024} KiB)")

    def save_manifest(self):
        path = os.path.join(self.out, "manifest.json")
        with open(path, "w") as f:
            json.dump(self.manifest, f, indent=1, sort_keys=True)
        print(f"manifest: {len(self.manifest['artifacts'])} artifacts -> {path}")


# ---------------------------------------------------------------------------
# Flat wrappers (positional flattening is THE contract with Rust)
# ---------------------------------------------------------------------------

def weight_inputs(cfg: ModelConfig) -> List[Tuple[str, tuple, str]]:
    return [("w." + n, s, "f32") for n, s in backbone_shapes(cfg).items()]


def serve_artifact(cfg: ModelConfig, method: str, bucket: Bucket, hp: MethodHP):
    """(inputs, fn, outputs) for one serving artifact."""
    sig_method = {"fine-tune": "fine-tune"}.get(method, method)
    sv_shapes = serve_input_shapes(cfg, sig_method, bucket.batch, bucket.seq, hp)
    bb_names = backbone_order(cfg)
    w_in = weight_inputs(cfg)
    if method == "aot-gather":
        w_in = w_in + [("w.P", (cfg.n_layers, cfg.vocab_size, cfg.d_model), "f32")]
    sv_in = [
        (n, s, "i32" if n == "in.ids" else "f32") for n, s in sv_shapes.items()
    ]
    nw = len(w_in)

    def fn(*args):
        bb = dict(zip(bb_names, args[:len(bb_names)]))
        if method == "aot-gather":
            bb["P"] = args[len(bb_names)]
        sp = dict(zip(sv_shapes.keys(), args[nw:]))
        return forward_serve(cfg, bb, sp, sig_method, hp)

    return w_in + sv_in, fn, ["logits"]


def train_artifact(
    cfg: ModelConfig, method: str, hp: MethodHP, bucket: Bucket, steps: int,
    loss_type: str,
):
    order = trainable_param_order(cfg, method, hp)
    specs = {e["name"]: tuple(e["shape"]) for e in init_spec(cfg, method, hp)}
    bb_names = backbone_order(cfg)
    w_in = weight_inputs(cfg)
    t_in = [("t." + n, specs[n], "f32") for n in order]
    m_in = [("m." + n, specs[n], "f32") for n in order]
    v_in = [("v." + n, specs[n], "f32") for n in order]
    k, b, n = steps, bucket.batch, bucket.seq
    data_in = [
        ("in.step", (), "i32"),
        ("in.ids", (k, b, n), "i32"),
        ("in.mask", (k, b, n), "f32"),
        ("in.labels", (k, b), "f32"),
        ("in.lr", (), "f32"),
        ("in.seed", (), "i32"),
    ]
    train_fn = make_train_fn(cfg, method, hp, order, loss_type)
    nb, nt = len(w_in), len(order)

    def fn(*args):
        bb = dict(zip(bb_names, args[:nb]))
        tr = args[nb:nb + nt]
        m = args[nb + nt:nb + 2 * nt]
        v = args[nb + 2 * nt:nb + 3 * nt]
        step, ids, mask, labels, lr, seed = args[nb + 3 * nt:]
        return train_fn(bb, tr, m, v, step, ids, mask, labels, lr, seed)

    outputs = (
        ["t." + n for n in order]
        + ["m." + n for n in order]
        + ["v." + n for n in order]
        + ["step", "loss"]
    )
    return w_in + t_in + m_in + v_in + data_in, fn, outputs, order


def eval_artifact(cfg: ModelConfig, method: str, hp: MethodHP, bucket: Bucket):
    order = trainable_param_order(cfg, method, hp)
    specs = {e["name"]: tuple(e["shape"]) for e in init_spec(cfg, method, hp)}
    bb_names = backbone_order(cfg)
    w_in = weight_inputs(cfg)
    t_in = [("t." + n, specs[n], "f32") for n in order]
    data_in = [
        ("in.ids", (bucket.batch, bucket.seq), "i32"),
        ("in.mask", (bucket.batch, bucket.seq), "f32"),
    ]
    eval_fn = make_eval_fn(cfg, method, hp, order)
    nb, nt = len(w_in), len(order)

    def fn(*args):
        bb = dict(zip(bb_names, args[:nb]))
        tr = args[nb:nb + nt]
        ids, mask = args[nb + nt:]
        return eval_fn(bb, tr, ids, mask)

    return w_in + t_in + data_in, fn, ["logits"]


def fuse_fc_artifact(cfg: ModelConfig, rank: int):
    l, v, d = cfg.n_layers, cfg.vocab_size, cfg.d_model
    inputs = [
        ("w.emb_tok", (v, d), "f32"),
        ("t.fc.w1", (l, d, rank), "f32"),
        ("t.fc.b1", (l, rank), "f32"),
        ("t.fc.w2", (l, rank, d), "f32"),
        ("t.fc.b2", (l, d), "f32"),
    ]

    def fn(e, w1, b1, w2, b2):
        return jax.vmap(lambda a, b, c, dd: ref.fc_fuse_ref(e, a, b, c, dd))(
            w1, b1, w2, b2
        )

    return inputs, fn, ["P"]


def fuse_kron_artifact(cfg: ModelConfig, rank: int):
    l, v, d = cfg.n_layers, cfg.vocab_size, cfg.d_model
    a, bf = kron_factors(v)
    inputs = [
        ("t.kron.wl", (l, a, rank), "f32"),
        ("t.kron.wm", (l, bf, rank), "f32"),
        ("t.kron.wr", (l, rank * rank, d), "f32"),
    ]

    def fn(wl, wm, wr):
        return jax.vmap(lambda x, y, z: ref.kron_fuse_ref(x, y, z, v))(wl, wm, wr)

    return inputs, fn, ["P"]


def mlm_artifact(cfg: ModelConfig, bucket: Bucket, steps: int):
    bb_names = backbone_order(cfg)
    shapes = backbone_shapes(cfg)
    t_in = [("t." + n, shapes[n], "f32") for n in bb_names]
    m_in = [("m." + n, shapes[n], "f32") for n in bb_names]
    v_in = [("v." + n, shapes[n], "f32") for n in bb_names]
    k, b, n = steps, bucket.batch, bucket.seq
    data_in = [
        ("in.step", (), "i32"),
        ("in.ids", (k, b, n), "i32"),
        ("in.mask", (k, b, n), "f32"),
        ("in.labels", (k, b, n), "f32"),
        ("in.lr", (), "f32"),
    ]
    train_fn = make_mlm_fn(cfg, bb_names)
    nt = len(bb_names)

    def fn(*args):
        bb = args[:nt]
        m = args[nt:2 * nt]
        v = args[2 * nt:3 * nt]
        step, ids, mask, labels, lr = args[3 * nt:]
        return train_fn(bb, m, v, step, ids, mask, labels, lr)

    outputs = (
        ["t." + n for n in bb_names]
        + ["m." + n for n in bb_names]
        + ["v." + n for n in bb_names]
        + ["step", "loss"]
    )
    return t_in + m_in + v_in + data_in, fn, outputs


# ---------------------------------------------------------------------------
# Kernel artifacts (L1 -> L3 composition proofs)
# ---------------------------------------------------------------------------

def kernel_artifacts(builder: Builder):
    """Standalone Pallas-kernel artifacts executed by Rust integration
    tests: prove interpret-mode Pallas survives the full AOT round trip."""
    b, n, d, v = 2, 32, 16, 128

    def aot_bias_fn(h, p, ids):
        return aot_bias(h, p, ids, block_n=16)

    builder.add(
        "kernel_aot_bias",
        aot_bias_fn,
        [("in.h", (b, n, d), "f32"), ("in.p", (v, d), "f32"), ("in.ids", (b, n), "i32")],
        ["out"],
        {"kind": "kernel", "model": "tiny", "method": "aot", "batch": b, "seq": n},
    )

    h_, dh = 2, 8

    def attn_fn(q, k, v_, mask):
        return attention(q, k, v_, mask, block_q=16, block_k=16)

    builder.add(
        "kernel_attention",
        attn_fn,
        [
            ("in.q", (b, h_, n, dh), "f32"),
            ("in.k", (b, h_, n, dh), "f32"),
            ("in.v", (b, h_, n, dh), "f32"),
            ("in.mask", (b, n), "f32"),
        ],
        ["out"],
        {"kind": "kernel", "model": "tiny", "method": "attention", "batch": b, "seq": n},
    )

    a, bf, r = 16, 8, 4

    def kron_fn(wl, wm, wr):
        return kron_fuse(wl, wm, wr, vocab=v, block_a=8)

    builder.add(
        "kernel_kron_fuse",
        kron_fn,
        [
            ("in.wl", (a, r), "f32"),
            ("in.wm", (bf, r), "f32"),
            ("in.wr", (r * r, d), "f32"),
        ],
        ["out"],
        {"kind": "kernel", "model": "tiny", "method": "aot-kron", "batch": 1, "seq": n},
    )

    # Golden inputs/outputs for the Rust side.
    rng = np.random.default_rng(1234)
    h = rng.standard_normal((b, n, d), dtype=np.float32)
    p = rng.standard_normal((v, d), dtype=np.float32)
    ids = rng.integers(0, v, (b, n)).astype(np.int32)
    out = np.asarray(aot_bias_fn(jnp.asarray(h), jnp.asarray(p), jnp.asarray(ids)))
    ckpt.save(
        os.path.join(builder.out, "golden_kernel_aot_bias.aotckpt"),
        {"in.h": h, "in.p": p, "in.ids": ids, "out": out},
    )


# ---------------------------------------------------------------------------
# Default artifact set
# ---------------------------------------------------------------------------

def default_hp(classes: int = MULTITASK_CLASSES) -> MethodHP:
    return MethodHP(rank=16, prefix=20, classes=classes)


def build_serving(builder: Builder, shapes: List[str], buckets: List[Bucket]):
    hp = default_hp()
    for shape in shapes:
        cfg = MODEL_CONFIGS[shape]
        builder.note_model(cfg)
        for bucket in buckets:
            if bucket.seq > cfg.max_positions - hp.prefix:
                continue
            for method in SPEED_METHODS:
                stem = artifact_name("fwd", shape, method, bucket)
                inputs, fn, outputs = serve_artifact(cfg, method, bucket, hp)
                builder.add(
                    stem, fn, inputs, outputs,
                    {
                        "kind": "fwd", "model": shape, "method": method,
                        "batch": bucket.batch, "seq": bucket.seq,
                        "rank": hp.rank, "prefix": hp.prefix,
                        "classes": hp.classes,
                    },
                )


def build_training(
    builder: Builder,
    shapes: List[str],
    methods: List[str],
    hps: Dict[str, List[MethodHP]],
    bucket: Bucket = TRAIN_BUCKET,
    steps: int = TRAIN_STEPS_PER_CALL,
):
    for shape in shapes:
        cfg = MODEL_CONFIGS[shape]
        builder.note_model(cfg)
        for method in methods:
            for hp in hps.get(method, [default_hp(2)]):
                extra = {}
                if method in ("lora", "adapters", "aot-kron", "aot-fc"):
                    extra["r"] = hp.rank
                if method in ("pt1", "pt2"):
                    extra["p"] = hp.prefix
                for loss_type in ["ce"]:
                    stem = artifact_name(
                        "train", shape, method, bucket, c=hp.classes, **extra
                    )
                    inputs, fn, outputs, order = train_artifact(
                        cfg, method, hp, bucket, steps, loss_type
                    )
                    builder.add(
                        stem, fn, inputs, outputs,
                        {
                            "kind": "train", "model": shape, "method": method,
                            "batch": bucket.batch, "seq": bucket.seq,
                            "rank": hp.rank, "prefix": hp.prefix,
                            "classes": hp.classes, "steps_per_call": steps,
                            "loss": loss_type,
                            "trainable_order": order,
                            "init": init_spec(cfg, method, hp),
                        },
                    )
                # Eval at a larger batch so dev-set scoring is cheap.
                ev_bucket = Bucket(batch=64, seq=bucket.seq)
                stem = artifact_name("eval", shape, method, ev_bucket, c=hp.classes, **extra)
                inputs, fn, outputs = eval_artifact(cfg, method, hp, ev_bucket)
                builder.add(
                    stem, fn, inputs, outputs,
                    {
                        "kind": "eval", "model": shape, "method": method,
                        "batch": ev_bucket.batch, "seq": ev_bucket.seq,
                        "rank": hp.rank, "prefix": hp.prefix,
                        "classes": hp.classes,
                    },
                )


def build_fuse(builder: Builder, shapes: List[str], ranks: Dict[str, List[int]]):
    for shape in shapes:
        cfg = MODEL_CONFIGS[shape]
        for r in ranks.get("aot-fc", [16]):
            inputs, fn, outputs = fuse_fc_artifact(cfg, r)
            builder.add(
                f"fuse_fc_{shape}_r{r}", fn, inputs, outputs,
                {"kind": "fuse", "model": shape, "method": "aot-fc", "rank": r,
                 "batch": 1, "seq": 0},
            )
        for r in ranks.get("aot-kron", [16]):
            inputs, fn, outputs = fuse_kron_artifact(cfg, r)
            builder.add(
                f"fuse_kron_{shape}_r{r}", fn, inputs, outputs,
                {"kind": "fuse", "model": shape, "method": "aot-kron", "rank": r,
                 "batch": 1, "seq": 0},
            )


def build_backbones(builder: Builder, shapes: List[str]):
    for shape in shapes:
        cfg = MODEL_CONFIGS[shape]
        builder.note_model(cfg)
        path = os.path.join(builder.out, f"backbone_{shape}.aotckpt")
        if os.path.exists(path) and not builder.force:
            continue
        t0 = time.time()
        bb = init_backbone(cfg, jax.random.PRNGKey(20230517))  # paper-id seed
        ckpt.save(path, {k: np.asarray(v) for k, v in bb.items()})
        print(f"  [{time.time() - t0:6.2f}s] backbone_{shape}.aotckpt")


def build_golden_fwd(builder: Builder):
    """Golden end-to-end forward for Rust integration tests (tiny, aot)."""
    cfg = MODEL_CONFIGS["tiny"]
    hp = default_hp()
    bucket = Bucket(batch=2, seq=16)
    stem = artifact_name("fwd", "tiny", "aot", bucket)
    # ensure the artifact exists
    inputs, fn, outputs = serve_artifact(cfg, "aot", bucket, hp)
    builder.add(
        stem, fn, inputs, outputs,
        {"kind": "fwd", "model": "tiny", "method": "aot",
         "batch": bucket.batch, "seq": bucket.seq, "rank": hp.rank,
         "prefix": hp.prefix, "classes": hp.classes},
    )
    bb = init_backbone(cfg, jax.random.PRNGKey(20230517))
    rng = np.random.default_rng(99)
    golden: Dict[str, np.ndarray] = {}
    sv = serve_input_shapes(cfg, "aot", bucket.batch, bucket.seq, hp)
    args = []
    for name in backbone_order(cfg):
        args.append(bb[name])
    for name, shape in sv.items():
        if name == "in.ids":
            arr = rng.integers(0, cfg.vocab_size, shape).astype(np.int32)
        elif name == "in.mask":
            arr = np.ones(shape, np.float32)
        else:
            arr = rng.standard_normal(shape).astype(np.float32) * 0.05
        golden[name] = arr
        args.append(jnp.asarray(arr))
    logits = np.asarray(fn(*args))
    golden["logits"] = logits
    ckpt.save(os.path.join(builder.out, "golden_fwd_tiny_aot.aotckpt"), golden)
    print("  golden_fwd_tiny_aot.aotckpt")


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--quick", action="store_true", help="tiny/small only")
    ap.add_argument("--force", action="store_true", help="regenerate cached files")
    args = ap.parse_args()

    t0 = time.time()
    builder = Builder(args.out, force=args.force)

    # Buckets for the speed study (paper §4.4 grid) + coordinator serving.
    speed_buckets = [
        Bucket(b, n) for b in (1, 16, 64) for n in (16, 64, 128, 384)
    ]
    serve_shapes = ["tiny", "small"] if args.quick else ["tiny", "small", "base", "large"]
    train_shapes = ["tiny", "small"] if args.quick else ["tiny", "small", "base"]
    bb_shapes = serve_shapes

    print("== backbones ==")
    build_backbones(builder, bb_shapes)

    print("== kernels ==")
    kernel_artifacts(builder)

    print("== serving ==")
    # tiny/small get the full bucket grid; larger shapes trim the cells that
    # are too slow for one CPU core (documented in EXPERIMENTS.md).
    per_shape_buckets = {
        "tiny": [Bucket(2, 16), Bucket(1, 64), Bucket(16, 64)],
        "small": speed_buckets,
        "base": speed_buckets,
        "large": [Bucket(1, 16), Bucket(1, 64), Bucket(1, 128), Bucket(1, 384),
                  Bucket(16, 16), Bucket(16, 64), Bucket(16, 128), Bucket(16, 384),
                  Bucket(64, 16), Bucket(64, 64)],
    }
    for shape in serve_shapes:
        build_serving(builder, [shape], per_shape_buckets[shape])

    # Device-gather AoT artifact (L1 kernel on the serving path), tiny+small.
    hp = default_hp()
    for shape in ["tiny", "small"]:
        cfg = MODEL_CONFIGS[shape]
        bucket = Bucket(4, 64) if shape == "small" else Bucket(2, 16)
        inputs, fn, outputs = serve_artifact(cfg, "aot-gather", bucket, hp)
        builder.add(
            artifact_name("fwd", shape, "aot-gather", bucket), fn, inputs, outputs,
            {"kind": "fwd", "model": shape, "method": "aot-gather",
             "batch": bucket.batch, "seq": bucket.seq, "rank": hp.rank,
             "prefix": hp.prefix, "classes": hp.classes},
        )

    print("== training ==")
    # Hyperparameter grids (config-scaled analog of Appendix Table 4).
    grid = {
        "fine-tune": [MethodHP(classes=2)],
        "bitfit": [MethodHP(classes=2)],
        "lora": [MethodHP(rank=r, classes=2) for r in (4, 16)],
        "adapters": [MethodHP(rank=r, classes=2) for r in (16, 64)],
        "pt1": [MethodHP(prefix=p, classes=2) for p in (5, 20)],
        "pt2": [MethodHP(prefix=p, classes=2) for p in (5, 20)],
        "aot-kron": [MethodHP(rank=r, classes=2) for r in (5, 25)],
        "aot-fc": [MethodHP(rank=r, classes=2) for r in (32, 128)],
    }
    grid3 = {
        m: [MethodHP(rank=h.rank, prefix=h.prefix, classes=3) for h in hs]
        for m, hs in grid.items()
    }
    build_training(builder, train_shapes, TRAIN_METHODS, grid)
    # 3-class variants (CB/MNLI-analog tasks) for tiny/small only.
    build_training(builder, ["tiny", "small"], TRAIN_METHODS, grid3)

    print("== fuse ==")
    build_fuse(
        builder, train_shapes,
        {"aot-fc": [32, 128], "aot-kron": [5, 25]},
    )

    print("== mlm pretrain ==")
    for shape in (["tiny"] if args.quick else ["tiny", "small"]):
        cfg = MODEL_CONFIGS[shape]
        inputs, fn, outputs = mlm_artifact(cfg, TRAIN_BUCKET, TRAIN_STEPS_PER_CALL)
        builder.add(
            artifact_name("pretrain", shape, "mlm", TRAIN_BUCKET), fn, inputs, outputs,
            {"kind": "pretrain", "model": shape, "method": "mlm",
             "batch": TRAIN_BUCKET.batch, "seq": TRAIN_BUCKET.seq,
             "steps_per_call": TRAIN_STEPS_PER_CALL},
        )

    print("== golden ==")
    build_golden_fwd(builder)

    builder.save_manifest()
    print(f"total: {time.time() - t0:.1f}s")


if __name__ == "__main__":
    main()
