"""Flash-style Pallas attention kernel (+ the P-Tuning v2 prefix variant).

The paper's central speed claim (Figure 3) is that AoT P-Tuning leaves the
attention computation untouched — the same kernel serves the vanilla model,
BitFit, fused LoRA and fused AoT P-Tuning — while P-Tuning v1/v2 grow the
key/value sequence length and therefore the attention cost.  We implement
both kernels so the overhead study measures real work, not emulation:

* ``attention``       — softmax(QKᵀ/√dh + mask)·V, tiled over query blocks
                        with a running-softmax accumulator over key blocks
                        (the FlashAttention schedule, expressed with a
                        3-D Pallas grid + VMEM scratch).
* ``prefix_attention``— identical, but K/V are the concatenation of per-task
                        soft prefixes (length p) with the real keys/values,
                        exactly P-Tuning v2's Equation 8.

TPU mapping (DESIGN.md §3): Q/K/V blocks are MXU-shaped (block_q × dh,
block_k × dh matmuls hit the 128×128 systolic array); the running max/sum
rescaling runs on the VPU in f32.  ``interpret=True`` is mandatory on this
CPU-only setup.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .common import scratch

NEG_INF = -1e30


def _attention_kernel(
    q_ref, k_ref, v_ref, mask_ref, out_ref, acc_ref, m_ref, l_ref, *, scale: float
):
    """Grid = (batch*heads, nq_blocks, nk_blocks); innermost axis is nk.

    q_ref:    [block_q, dh]   current query tile
    k_ref:    [block_k, dh]   current key tile
    v_ref:    [block_k, dh]   current value tile
    mask_ref: [block_k]       key-side mask tile (1.0 = attend)
    out_ref:  [block_q, dh]
    acc/m/l:  VMEM scratch carrying the running softmax across nk blocks.
    """
    nk_index = pl.program_id(2)
    nk_total = pl.num_programs(2)

    @pl.when(nk_index == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[...]
    k = k_ref[...]
    v = v_ref[...]
    mask = mask_ref[...]

    logits = jnp.dot(q, k.T) * scale  # [block_q, block_k] — MXU matmul
    logits = logits + (1.0 - mask)[None, :] * NEG_INF

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(logits, axis=-1))
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(logits - m_new[:, None])

    l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=-1)
    acc_ref[...] = acc_ref[...] * alpha[:, None] + jnp.dot(p, v)
    m_ref[...] = m_new

    @pl.when(nk_index == nk_total - 1)
    def _finalize():
        out_ref[...] = acc_ref[...] / l_ref[...][:, None]


@functools.partial(jax.jit, static_argnames=("block_q", "block_k", "interpret"))
def attention(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    mask: jnp.ndarray,
    *,
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool = True,
) -> jnp.ndarray:
    """Masked MHA.  q/k/v: [b, h, n, dh]; mask: [b, nk] (key side)."""
    b, h, nq, dh = q.shape
    nk = k.shape[2]
    block_q = min(block_q, nq)
    block_k = min(block_k, nk)

    pad_q = (-nq) % block_q
    pad_k = (-nk) % block_k
    if pad_q:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, pad_q), (0, 0)))
    if pad_k:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad_k), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad_k), (0, 0)))
        mask = jnp.pad(mask, ((0, 0), (0, pad_k)))  # pads with 0.0 = masked
    nq_p, nk_p = nq + pad_q, nk + pad_k

    qf = q.reshape(b * h, nq_p, dh)
    kf = k.reshape(b * h, nk_p, dh)
    vf = v.reshape(b * h, nk_p, dh)
    # Mask is per batch row; expand to per (batch, head) program.
    maskf = jnp.repeat(mask, h, axis=0)  # [b*h, nk_p]

    grid = (b * h, nq_p // block_q, nk_p // block_k)
    out = pl.pallas_call(
        functools.partial(_attention_kernel, scale=1.0 / (dh**0.5)),
        grid=grid,
        in_specs=[
            pl.BlockSpec((None, block_q, dh), lambda bh, qi, ki: (bh, qi, 0)),
            pl.BlockSpec((None, block_k, dh), lambda bh, qi, ki: (bh, ki, 0)),
            pl.BlockSpec((None, block_k, dh), lambda bh, qi, ki: (bh, ki, 0)),
            pl.BlockSpec((None, block_k), lambda bh, qi, ki: (bh, ki)),
        ],
        out_specs=pl.BlockSpec((None, block_q, dh), lambda bh, qi, ki: (bh, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((b * h, nq_p, dh), q.dtype),
        scratch_shapes=[
            scratch((block_q, dh), jnp.float32),
            scratch((block_q,), jnp.float32),
            scratch((block_q,), jnp.float32),
        ],
        interpret=interpret,
    )(qf, kf, vf, maskf)
    return out.reshape(b, h, nq_p, dh)[:, :, :nq, :]


def prefix_attention(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    mask: jnp.ndarray,
    pk: jnp.ndarray,
    pv: jnp.ndarray,
    *,
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool = True,
) -> jnp.ndarray:
    """P-Tuning v2 attention: per-task prefixes concatenated to K/V.

    pk, pv: [b, h, p, dh].  The concatenation *lengthens the key axis* —
    that added work is precisely the overhead Figure 3 attributes to
    P-Tuning v2, so it must be real, not simulated.
    """
    k2 = jnp.concatenate([pk, k], axis=2)
    v2 = jnp.concatenate([pv, v], axis=2)
    ones = jnp.ones(mask.shape[:1] + (pk.shape[2],), dtype=mask.dtype)
    mask2 = jnp.concatenate([ones, mask], axis=1)
    return attention(
        q, k2, v2, mask2, block_q=block_q, block_k=block_k, interpret=interpret
    )


def vmem_bytes(block_q: int, block_k: int, dh: int) -> int:
    """Analytic VMEM footprint of one program instance (f32)."""
    tiles = (block_q * dh) * 2  # q tile + out tile
    tiles += (block_k * dh) * 2  # k tile + v tile
    tiles += block_k  # mask tile
    scratch = block_q * dh + 2 * block_q  # acc + m + l
    return 4 * (tiles + scratch)


def mxu_utilization(n: int, dh: int, block_q: int, block_k: int) -> float:
    """Fraction of MXU-issue slots doing useful MACs for one head.

    The two matmuls per (q,k) tile are (block_q×dh)·(dh×block_k) and
    (block_q×block_k)·(block_k×dh).  Utilization is useful MACs over
    128×128-systolic issue slots, i.e. the efficiency loss from dh < 128
    and edge tiles.
    """
    mxu = 128
    eff_q = block_q / (((block_q + mxu - 1) // mxu) * mxu)
    eff_k = block_k / (((block_k + mxu - 1) // mxu) * mxu)
    eff_d = dh / (((dh + mxu - 1) // mxu) * mxu)
    return eff_q * eff_k * eff_d
