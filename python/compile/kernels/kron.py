"""Pallas kernel for fusing the Kronecker reparametrization of P.

Kronecker AoT P-Tuning (paper Equation 2) trains
``P = (W_L ⊗ W_M) W_R`` with ``W_L ∈ R^{a×r}``, ``W_M ∈ R^{bf×r}``,
``W_R ∈ R^{r²×d}`` and ``a·bf ≥ |V|``.  After training, P is fused once and
stored in host RAM (paper §3.3) — this kernel is that fuse step.

Materializing the Kronecker product ((a·bf) × r²) is wasteful; instead we
use the identity

    P[i·bf + j, :] = Σ_{u,v} W_L[i,u] · W_M[j,v] · W_R[u·r+v, :]

and compute, per W_L row-block, the contraction
``einsum('iu,jv,uvd->ijd', W_L_block, W_M, W_R)`` as two MXU matmuls:
``T = W_L_block @ W_R.reshape(r, r·d)`` (contracting u), then per-j
``W_M @ T_i`` (contracting v).  The grid walks W_L row blocks; W_M and W_R
tiles stay resident in VMEM across iterations.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kron_fuse_kernel(wl_ref, wm_ref, wr_ref, out_ref):
    """One W_L row block.

    wl_ref:  [block_a, r]
    wm_ref:  [bf, r]
    wr_ref:  [r*r, d]
    out_ref: [block_a, bf, d]
    """
    block_a, r = wl_ref.shape
    bf = wm_ref.shape[0]
    d = wr_ref.shape[1]

    wl = wl_ref[...]
    wm = wm_ref[...]
    wr = wr_ref[...].reshape(r, r * d)

    # Contract u: [block_a, r] @ [r, r*d] -> [block_a, r, d]
    t = jnp.dot(wl, wr).reshape(block_a, r, d)
    # Contract v per row-block: [bf, r] @ [block_a, r, d] -> [block_a, bf, d]
    out_ref[...] = jax.lax.dot_general(
        wm, t, dimension_numbers=(((1,), (1,)), ((), ()))
    ).transpose(1, 0, 2)


@functools.partial(jax.jit, static_argnames=("vocab", "block_a", "interpret"))
def kron_fuse(
    wl: jnp.ndarray,
    wm: jnp.ndarray,
    wr: jnp.ndarray,
    *,
    vocab: int,
    block_a: int = 32,
    interpret: bool = True,
) -> jnp.ndarray:
    """Fuse P = (W_L ⊗ W_M) W_R and truncate to `vocab` rows.

    wl: [a, r], wm: [bf, r], wr: [r*r, d]  ->  [vocab, d]
    """
    a, r = wl.shape
    bf = wm.shape[0]
    d = wr.shape[1]
    assert a * bf >= vocab, "factorization must cover the vocabulary"
    assert wr.shape[0] == r * r

    block_a = min(block_a, a)
    pad = (-a) % block_a
    if pad:
        wl = jnp.pad(wl, ((0, pad), (0, 0)))
    a_p = a + pad

    out = pl.pallas_call(
        _kron_fuse_kernel,
        grid=(a_p // block_a,),
        in_specs=[
            pl.BlockSpec((block_a, r), lambda i: (i, 0)),
            pl.BlockSpec((bf, r), lambda i: (0, 0)),
            pl.BlockSpec((r * r, d), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block_a, bf, d), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((a_p, bf, d), wl.dtype),
        interpret=interpret,
    )(wl, wm, wr)
    return out.reshape(a_p * bf, d)[:vocab]


def vmem_bytes(block_a: int, r: int, bf: int, d: int) -> int:
    """Analytic VMEM footprint of one program instance (f32)."""
    return 4 * (block_a * r + bf * r + r * r * d + block_a * r * d + block_a * bf * d)
