"""Pure-jnp oracles for every Pallas kernel.

These are the correctness ground truth: each kernel in this package must
match its oracle to float32 tolerance under pytest/hypothesis sweeps
(``python/tests/test_kernels.py``).  They are also used directly by the L2
model when ``use_pallas=False`` (e.g. for gradient paths where interpret-mode
Pallas would be needlessly slow).
"""

from __future__ import annotations

import jax.numpy as jnp


def aot_bias_ref(h: jnp.ndarray, p: jnp.ndarray, ids: jnp.ndarray) -> jnp.ndarray:
    """H' = H + P[ids]  (the paper's Equation 1).

    h:   [b, n, d] hidden states
    p:   [V, d]    fused per-layer prompt table
    ids: [b, n]    int32 token ids
    """
    return h + p[ids]


def _softmax(x: jnp.ndarray) -> jnp.ndarray:
    x = x - jnp.max(x, axis=-1, keepdims=True)
    e = jnp.exp(x)
    return e / jnp.sum(e, axis=-1, keepdims=True)


def attention_ref(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    mask: jnp.ndarray,
) -> jnp.ndarray:
    """Masked multi-head scaled dot-product attention.

    q, k, v: [b, h, n, dh]
    mask:    [b, nk] with 1.0 = attend, 0.0 = padding (key-side mask)
    returns  [b, h, nq, dh]
    """
    dh = q.shape[-1]
    logits = jnp.einsum("bhqd,bhkd->bhqk", q, k) / jnp.sqrt(jnp.float32(dh))
    bias = (1.0 - mask)[:, None, None, :] * -1e9
    weights = _softmax(logits + bias)
    return jnp.einsum("bhqk,bhkd->bhqd", weights, v)


def prefix_attention_ref(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    mask: jnp.ndarray,
    pk: jnp.ndarray,
    pv: jnp.ndarray,
) -> jnp.ndarray:
    """P-Tuning v2 attention: prefixes concatenated to K and V (Equation 8).

    pk, pv: [b, h, p, dh] per-task soft prefixes (already batched).
    """
    k2 = jnp.concatenate([pk, k], axis=2)
    v2 = jnp.concatenate([pv, v], axis=2)
    ones = jnp.ones(mask.shape[:1] + (pk.shape[2],), dtype=mask.dtype)
    mask2 = jnp.concatenate([ones, mask], axis=1)
    return attention_ref(q, k2, v2, mask2)


def kron_fuse_ref(wl: jnp.ndarray, wm: jnp.ndarray, wr: jnp.ndarray, vocab: int) -> jnp.ndarray:
    """P = (W_L ⊗ W_M) W_R, truncated to the first `vocab` rows (Equation 2).

    wl: [a, r], wm: [bf, r], wr: [r*r, d]  ->  P: [vocab, d]
    Row (i * bf + j) of the Kronecker product is the outer product
    wl[i] ⊗ wm[j] flattened, so
        P[i*bf+j] = sum_{u,v} wl[i,u] * wm[j,v] * wr[u*r+v].
    """
    a, r = wl.shape
    bf, _ = wm.shape
    d = wr.shape[1]
    wr3 = wr.reshape(r, r, d)
    p = jnp.einsum("iu,jv,uvd->ijd", wl, wm, wr3).reshape(a * bf, d)
    return p[:vocab]


def kron_rows_ref(
    wl: jnp.ndarray, wm: jnp.ndarray, wr: jnp.ndarray, ids: jnp.ndarray
) -> jnp.ndarray:
    """Gathered rows of the Kronecker-parametrized P without materializing it.

    Used on the training path: only rows for tokens present in the batch are
    evaluated (paper §3.3, "we can evaluate only specific rows").
    ids: [b, n] -> [b, n, d]
    """
    r = wl.shape[1]
    d = wr.shape[1]
    bf = wm.shape[0]
    i = ids // bf
    j = ids % bf
    wr3 = wr.reshape(r, r, d)
    return jnp.einsum("bnu,bnv,uvd->bnd", wl[i], wm[j], wr3)


def gelu(x: jnp.ndarray) -> jnp.ndarray:
    """tanh-approximated GELU (matches the kernel implementation)."""
    c = jnp.sqrt(jnp.float32(2.0 / jnp.pi))
    return 0.5 * x * (1.0 + jnp.tanh(c * (x + 0.044715 * x**3)))


def fc_fuse_ref(
    e: jnp.ndarray,
    w1: jnp.ndarray,
    b1: jnp.ndarray,
    w2: jnp.ndarray,
    b2: jnp.ndarray,
) -> jnp.ndarray:
    """P = f(E W1 + b1) W2 + b2 with f = GELU (Equation 3).

    e: [V, d], w1: [d, r], b1: [r], w2: [r, d], b2: [d]  ->  [V, d]
    """
    hidden = gelu(e @ w1 + b1)
    return hidden @ w2 + b2


def fc_rows_ref(
    e_rows: jnp.ndarray,
    w1: jnp.ndarray,
    b1: jnp.ndarray,
    w2: jnp.ndarray,
    b2: jnp.ndarray,
) -> jnp.ndarray:
    """FC reparametrization evaluated only on gathered embedding rows.

    e_rows: [b, n, d] = E[ids]  ->  [b, n, d]
    """
    hidden = gelu(e_rows @ w1 + b1)
    return hidden @ w2 + b2


def layer_norm_ref(x: jnp.ndarray, gamma: jnp.ndarray, beta: jnp.ndarray, eps: float = 1e-5):
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean((x - mu) ** 2, axis=-1, keepdims=True)
    return (x - mu) / jnp.sqrt(var + eps) * gamma + beta
