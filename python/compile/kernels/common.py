"""Shared helpers for the Pallas kernels in this package."""

from __future__ import annotations

import jax.numpy as jnp
from jax._src import core as _jcore
from jax.experimental import pallas as pl


def scratch(shape: tuple, dtype=jnp.float32) -> pl.MemoryRef:
    """A VMEM-style scratch allocation usable under ``interpret=True``.

    On real TPU this would be ``pltpu.VMEM(shape, dtype)``; the portable
    spelling keeps the kernels backend-agnostic for the CPU interpret path.
    """
    return pl.MemoryRef(_jcore.ShapedArray(shape, dtype), pl.ANY)
