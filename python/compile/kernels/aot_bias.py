"""Pallas kernel for the AoT P-Tuning hot-spot: ``H' = H + P[ids]``.

This is the operation the paper is named after (Equation 1): before every
Transformer layer, rows of a fused per-layer prompt table ``P ∈ R^{V×d}``
are looked up for the tokens of the input sequence and added to the hidden
states.

TPU mapping (DESIGN.md §3): ``P`` is far larger than VMEM (V×d, ~16–100 MB),
so it stays in HBM (``memory_space=ANY``) and the kernel performs dynamic
row gathers while streaming ``(block_n, d)`` tiles of ``H`` through VMEM.
The grid iterates ``(batch, n // block_n)``; token ids for the tile ride
along as a VMEM int32 vector.  The gather is bandwidth-bound: bytes moved
are ``3·n·d·4`` per layer (H in, P rows in, H' out), which is why the paper
measures the op as near-zero-cost next to the layer's matmuls.

The kernel MUST run with ``interpret=True`` on this CPU-only setup: real-TPU
lowering emits a Mosaic custom-call the CPU PJRT plugin cannot execute.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _aot_bias_kernel(ids_ref, h_ref, p_ref, out_ref, *, block_n: int):
    """One (batch row, seq tile): out = h + P[ids], gathering rows from HBM.

    ids_ref: [block_n]      int32, VMEM
    h_ref:   [block_n, d]   f32,   VMEM
    p_ref:   [V, d]         f32,   ANY (HBM-resident table)
    out_ref: [block_n, d]   f32,   VMEM
    """
    d = h_ref.shape[-1]

    def body(i, _):
        tok = ids_ref[i]
        # Dynamic single-row gather from the HBM table.  On TPU this is the
        # HBM→VMEM DMA the BlockSpec schedule double-buffers; in interpret
        # mode it is a plain dynamic slice.
        row = pl.load(p_ref, (pl.dslice(tok, 1), pl.dslice(0, d)))
        cur = pl.load(h_ref, (pl.dslice(i, 1), pl.dslice(0, d)))
        pl.store(out_ref, (pl.dslice(i, 1), pl.dslice(0, d)), cur + row)
        return 0

    jax.lax.fori_loop(0, block_n, body, 0)


@functools.partial(jax.jit, static_argnames=("block_n", "interpret"))
def aot_bias(
    h: jnp.ndarray,
    p: jnp.ndarray,
    ids: jnp.ndarray,
    *,
    block_n: int = 128,
    interpret: bool = True,
) -> jnp.ndarray:
    """Pallas-accelerated ``h + p[ids]``.

    h:   [b, n, d] float32
    p:   [V, d]    float32 fused prompt table
    ids: [b, n]    int32
    """
    b, n, d = h.shape
    block_n = min(block_n, n)
    # Pad n up to a multiple of block_n; padded ids point at row 0 but the
    # padded tail of the output is sliced away below.
    pad = (-n) % block_n
    if pad:
        h = jnp.pad(h, ((0, 0), (0, pad), (0, 0)))
        ids = jnp.pad(ids, ((0, 0), (0, pad)))
    n_pad = n + pad

    grid = (b, n_pad // block_n)
    out = pl.pallas_call(
        functools.partial(_aot_bias_kernel, block_n=block_n),
        grid=grid,
        in_specs=[
            pl.BlockSpec((None, block_n), lambda bi, ni: (bi, ni)),
            pl.BlockSpec((None, block_n, d), lambda bi, ni: (bi, ni, 0)),
            # Full table visible to every program instance: stays in HBM.
            pl.BlockSpec(p.shape, lambda bi, ni: (0, 0)),
        ],
        out_specs=pl.BlockSpec((None, block_n, d), lambda bi, ni: (bi, ni, 0)),
        out_shape=jax.ShapeDtypeStruct((b, n_pad, d), h.dtype),
        interpret=interpret,
    )(ids, h, p)
    return out[:, :n, :]


def vmem_bytes(block_n: int, d: int) -> int:
    """Analytic VMEM footprint of one program instance (f32)."""
    ids = block_n * 4
    h_tile = block_n * d * 4
    out_tile = block_n * d * 4
    gathered_row = d * 4 * 2  # double-buffered DMA landing zone
    return ids + h_tile + out_tile + gathered_row
