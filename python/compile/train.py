"""Training-step and eval-step graphs for the AOT pipeline.

The paper's protocol (§4.1): Adam with a constant learning rate, per-task
metric early stopping with patience, grid search over hyperparameters and
seeds.  The *loop* lives in Rust (`rust/src/train/`); this module defines the
*step* as a single XLA computation:

    (trainable, m, v, step, ids[K,b,n], mask, labels, lr, seed)
        -> (trainable', m', v', mean_loss)

with ``K = steps_per_call`` optimizer steps executed by ``lax.scan`` inside
one call, so the host<->device round-trip (this xla-crate build cannot donate
buffers) is amortized K× (DESIGN.md §9, L2 perf).
"""

from __future__ import annotations

from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp

from .configs import ModelConfig
from .model import forward_train
from .peft import MethodHP

ADAM_B1 = 0.9
ADAM_B2 = 0.999
ADAM_EPS = 1e-8


def ce_loss(logits: jnp.ndarray, labels: jnp.ndarray) -> jnp.ndarray:
    """Mean softmax cross-entropy; labels arrive as f32 and are cast."""
    lab = labels.astype(jnp.int32)
    logp = jax.nn.log_softmax(logits, axis=-1)
    return -jnp.mean(jnp.take_along_axis(logp, lab[:, None], axis=1))


def mse_loss(logits: jnp.ndarray, labels: jnp.ndarray) -> jnp.ndarray:
    """Regression loss on the first logit (STS-B-analog tasks)."""
    return jnp.mean((logits[:, 0] - labels) ** 2)


def adam_update(p, g, m, v, step, lr):
    m = ADAM_B1 * m + (1.0 - ADAM_B1) * g
    v = ADAM_B2 * v + (1.0 - ADAM_B2) * g * g
    mh = m / (1.0 - ADAM_B1**step)
    vh = v / (1.0 - ADAM_B2**step)
    return p - lr * mh / (jnp.sqrt(vh) + ADAM_EPS), m, v


def make_train_fn(
    cfg: ModelConfig,
    method: str,
    hp: MethodHP,
    order: List[str],
    loss_type: str = "ce",
):
    """Build the K-step train function over *positional* trainable tensors.

    ``order`` fixes the flattening of the trainable dict so the Rust driver
    and the manifest agree on argument positions.
    """

    def loss_fn(trainable: Dict[str, jnp.ndarray], backbone, ids, mask, labels, key):
        logits = forward_train(
            cfg, backbone, trainable, method, ids, mask, hp,
            train=True, dropout_key=key,
        )
        if loss_type == "mse":
            return mse_loss(logits, labels)
        return ce_loss(logits, labels)

    def train_fn(backbone, trainable_flat, m_flat, v_flat, step0, ids, mask, labels, lr, seed):
        """One XLA call = K scanned optimizer steps.

        ids/mask: [K, b, n] f32/i32; labels: [K, b] f32; step0: i32 scalar
        (global step count BEFORE this call, for Adam bias correction);
        seed: i32 scalar for dropout.
        """

        def one_step(carry, batch):
            tr, m, v, step = carry
            b_ids, b_mask, b_labels = batch
            key = jax.random.fold_in(jax.random.PRNGKey(seed), step)
            trainable = dict(zip(order, tr))
            loss, grads = jax.value_and_grad(loss_fn)(
                trainable, backbone, b_ids, b_mask, b_labels, key
            )
            step = step + 1
            new_tr, new_m, new_v = [], [], []
            for name, mi, vi in zip(order, m, v):
                pi, gi = trainable[name], grads[name]
                p2, m2, v2 = adam_update(pi, gi, mi, vi, step.astype(jnp.float32), lr)
                new_tr.append(p2)
                new_m.append(m2)
                new_v.append(v2)
            return (tuple(new_tr), tuple(new_m), tuple(new_v), step), loss

        carry0 = (tuple(trainable_flat), tuple(m_flat), tuple(v_flat), step0)
        (tr, m, v, step), losses = jax.lax.scan(one_step, carry0, (ids, mask, labels))
        return list(tr) + list(m) + list(v) + [step, jnp.mean(losses)]

    return train_fn


def make_eval_fn(cfg: ModelConfig, method: str, hp: MethodHP, order: List[str]):
    """Eval forward: (backbone, trainable..., ids, mask) -> logits [b, C]."""

    def eval_fn(backbone, trainable_flat, ids, mask):
        trainable = dict(zip(order, trainable_flat))
        return forward_train(cfg, backbone, trainable, method, ids, mask, hp, train=False)

    return eval_fn


# ---------------------------------------------------------------------------
# MLM pre-training (synthetic "pre-trained backbone" story, DESIGN.md §2)
# ---------------------------------------------------------------------------

def make_mlm_fn(cfg: ModelConfig, order: List[str]):
    """Masked-LM train step over the full backbone (tied output embedding).

    ids arrive already masked by the Rust data pipeline; ``labels`` holds the
    original token id at masked positions and -100 elsewhere.
    """

    def mlm_loss(backbone, ids, mask, labels):
        # Encoder trunk + projection back onto the tied embedding.
        hidden = _encode(backbone, cfg, ids, mask)
        logits = hidden @ backbone["emb_tok"].T  # [b, n, V]
        lab = labels.astype(jnp.int32)
        valid = (lab >= 0).astype(jnp.float32)
        lab_safe = jnp.maximum(lab, 0)
        logp = jax.nn.log_softmax(logits, axis=-1)
        tok_lp = jnp.take_along_axis(logp, lab_safe[..., None], axis=-1)[..., 0]
        return -jnp.sum(tok_lp * valid) / jnp.maximum(jnp.sum(valid), 1.0)

    def train_fn(backbone_flat, m_flat, v_flat, step0, ids, mask, labels, lr):
        def one_step(carry, batch):
            bb, m, v, step = carry
            b_ids, b_mask, b_labels = batch
            backbone = dict(zip(order, bb))
            loss, grads = jax.value_and_grad(mlm_loss)(backbone, b_ids, b_mask, b_labels)
            step = step + 1
            new_bb, new_m, new_v = [], [], []
            for name, mi, vi in zip(order, m, v):
                p2, m2, v2 = adam_update(
                    backbone[name], grads[name], mi, vi, step.astype(jnp.float32), lr
                )
                new_bb.append(p2)
                new_m.append(m2)
                new_v.append(v2)
            return (tuple(new_bb), tuple(new_m), tuple(new_v), step), loss

        carry0 = (tuple(backbone_flat), tuple(m_flat), tuple(v_flat), step0)
        (bb, m, v, step), losses = jax.lax.scan(one_step, carry0, (ids, mask, labels))
        return list(bb) + list(m) + list(v) + [step, jnp.mean(losses)]

    return train_fn


def _encode(backbone, cfg: ModelConfig, ids, mask):
    """Encoder trunk shared with forward_train (no head, no PEFT)."""
    from .model import _embed, _layer_body, _layer_stack

    ids = ids.astype(jnp.int32)
    hidden = _embed(cfg, backbone, ids)
    body = _layer_body(cfg, "fine-tune", MethodHP(), mask, batched=False)
    hidden, _ = jax.lax.scan(body, hidden, {"bb": _layer_stack(backbone)})
    return hidden
